from repro.parallel.sharding import (  # noqa: F401
    batch_pspec,
    cache_pspecs,
    opt_pspecs,
    param_pspecs,
)
