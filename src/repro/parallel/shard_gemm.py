"""Shard_map wrapper around the fused emulated GEMM: GSPMD-native TP.

Historically ``dispatch.resolve_policy`` clamped every fused impl to the
XLA expansion the moment a mesh had more than one device, because GSPMD
cannot partition the sequential interpret-mode pallas grid.  This module
is the lift: instead of handing the partitioner a fused ``pallas_call``
it cannot split, the emulated 2-D core runs *per shard* under
``jax.shard_map`` with the collectives written out explicitly — so
tensor-parallel meshes keep the decomposition traffic out of HBM exactly
like a single device does.

Partitioning mirrors the parameter rules of
:mod:`repro.parallel.sharding` (``_param_rule``'s column-parallel
preference for ``_UP`` weights): the weight's N axis goes on ``'model'``
when it divides (no collective at all — each shard owns whole output
columns and the full K, so the per-shard fused GEMM is **bit-identical**
to the single-device kernel on its slice of the output); otherwise K
goes on ``'model'`` with a ``psum`` over the partial products (exact int
interior per shard, float summation across shards — allclose, not
bit-identical, to the unsharded reference).  Leading batch/M dims shard
over the data axes (``('pod', 'data')``) in either case.

Prepared operands shard with the model: a ``PreparedOperand`` /
``PreparedResidues`` rhs is *localized* — its slice/residue stack and
scale enter the shard body column-sharded via matching pytree in_specs,
with the static ``n`` rewritten to the per-shard width — so ``+cached``
weights never gather.  K-sharded prepared consumption is unsupported
(the interleave granularity pins K); those cases fall back to the
caller's unsharded route.

Every entry point returns ``None`` when it cannot partition the problem
(no axis divides, complex activations at a dense site, a 1-device mesh
…); callers fall back to the existing single-device routes, which still
compile under GSPMD — just unpartitioned.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, PartitionSpec as P

from repro import telemetry
from repro.parallel import sharding as shd
from repro.telemetry import record as _tele


def _record_partition(part: "GemmPartition", cfg, mesh_shape,
                      m: int, k: int, n: int) -> int:
    """Record the partition choice; return modeled collective bytes per
    device for the shard body to stage (0 when collective-free or
    telemetry is disabled)."""
    if not telemetry.enabled():
        return 0
    telemetry.record_event(_tele.SHARD_PARTITION, {
        "kind": part.kind, "mesh_shape": _tele.mesh_label(mesh_shape)})
    if part.kind != "row":
        return 0
    try:
        from repro.core import traffic
        p = (len(cfg.resolved_moduli()) if cfg.scheme == "ozaki2"
             else cfg.p)
        t = traffic.sharded_gemm_traffic(
            traffic.GemmShape(int(m), int(n), int(k)), p, mesh_shape,
            partition="row", scheme=cfg.scheme)
        return int(t["collective_bytes_per_device"])
    except Exception:
        return 0


@dataclasses.dataclass(frozen=True)
class GemmPartition:
    """How one (lead..., K) @ (K, N) splits over the mesh.

    ``kind`` is 'column' (N on the model axis, collective-free),
    'row' (K on the model axis, psum over partials) or 'batch'
    (data-parallel only).  ``batch_axes`` is the data-axes tuple the
    leading dim shards over (None = replicated rows), ``model_axis``
    the TP axis name (None for 'batch').
    """
    kind: str
    batch_axes: tuple | None
    model_axis: str | None

    @property
    def reduce_axes(self) -> tuple:
        """Axes the shard body must psum over (K-contracted shards)."""
        return (self.model_axis,) if self.kind == "row" else ()

    def specs(self, x_ndim: int):
        """(x_spec, w_spec, out_spec) for (lead..., K) @ (K, N)."""
        mid = [None] * (x_ndim - 2)
        if self.kind == "column":
            return (P(self.batch_axes, *mid, None),
                    P(None, self.model_axis),
                    P(self.batch_axes, *mid, self.model_axis))
        if self.kind == "row":
            return (P(self.batch_axes, *mid, self.model_axis),
                    P(self.model_axis, None),
                    P(self.batch_axes, *mid, None))
        return (P(self.batch_axes, *mid, None),
                P(None, None),
                P(self.batch_axes, *mid, None))


def _model_axis(mesh: Mesh) -> str | None:
    return "model" if dict(mesh.shape).get("model", 1) > 1 else None


def gemm_partition(lead: int, k: int, n: int, mesh: Mesh,
                   *, allow_row: bool = True) -> GemmPartition | None:
    """Pick the partitioning for a (lead..., K) @ (K, N) on ``mesh``.

    Mirrors ``sharding._param_rule``'s ``_UP`` preference: column
    parallel (N on 'model') when N divides — the collective-free,
    bit-identical layout the parameter specs already use — else row
    parallel (K on 'model', psum).  The leading dim shards over the
    data axes when it divides.  None when nothing divides (caller
    falls back to the unsharded route).
    """
    bax = shd._fit(lead, shd.data_axes(mesh), mesh)
    mdl = _model_axis(mesh)
    if mdl is not None and shd._fit(n, mdl, mesh):
        return GemmPartition("column", bax, mdl)
    if allow_row and mdl is not None and shd._fit(k, mdl, mesh):
        return GemmPartition("row", bax, mdl)
    if bax is not None:
        return GemmPartition("batch", bax, None)
    return None


def _pin_row_cfg(cfg, k_global: int):
    """Pin K-global numerics before a row-parallel (K-sharded) launch.

    Scheme I derives beta from the contraction length; each shard sees
    only K/tp, so an unpinned config would slice at the looser local
    beta and drift further from the unsharded reference.  Pinning
    ``safe_beta`` of the (padded) global K reproduces the single-device
    slice budget exactly — the remaining difference is only the float
    summation order of the psum.  Scheme II's CRT budget is derived
    inside the kernel from the local K (a *larger* product bound than
    the global run — still exact per shard) and needs no pin.
    """
    from repro.kernels import dispatch
    if cfg.scheme == "ozaki1" and cfg.beta is None:
        return dataclasses.replace(
            cfg, beta=cfg.resolved_beta(dispatch.round_up(k_global)))
    return cfg


def _local_spec(leaf) -> P:
    """Column-shard a prepared stack/scale: last (N) dim on 'model'."""
    return P(*([None] * (leaf.ndim - 1)), "model")


def _localize_prepared(prep, mesh: Mesh):
    """(local_template, in_spec_tree) for a column-sharded prepared rhs.

    The slice/residue stack and scale all carry N as their last dim, so
    one pytree of ``P(..., 'model')`` in_specs shards them; the static
    aux ``n`` is rewritten to the per-shard width (aux travels in the
    treedef, so the shard body's ``matmul_prepared`` slices the right
    logical columns).  The twin (backward layout) is dropped — this is
    the serving consumption path, and the twin's N is the *contraction*
    axis of dA, which column sharding would split.  None when the
    padded width does not divide the model axis or padding columns
    would straddle a shard boundary.
    """
    tp = dict(mesh.shape).get("model", 1)
    if tp <= 1:
        return None
    if prep.n != prep.padded_n or prep.n % tp:
        telemetry.record_event(_tele.PREPARED_REFUSALS,
                               {"reason": "n_indivisible"})
        return None
    pinned = getattr(prep, "mesh_shape", None)
    if pinned is not None and pinned != _mesh_shape(mesh):
        # Prepared under a different mesh layout: the block granularity
        # was pinned for that layout's shard widths — refuse rather
        # than consume it with a foreign tiling.
        telemetry.record_event(_tele.PREPARED_REFUSALS,
                               {"reason": "mesh_mismatch"})
        return None
    local = dataclasses.replace(prep, n=prep.n // tp, twin=None)
    return local, jax.tree.map(_local_spec, local)


def _mesh_shape(mesh: Mesh):
    from repro.kernels import dispatch
    return dispatch._mesh_shape_tuple(mesh)


def sharded_matmul(a: jax.Array, b: jax.Array, cfg, mesh: Mesh, *,
                   out_dtype=None) -> jax.Array | None:
    """2-D a: (M, K) @ b: (K, N) per-shard fused under shard_map.

    The collective-free column layout is preferred (bit-identical to
    ``dispatch.emulated_matmul`` on one device); K-sharded problems
    psum float partials (allclose).  Returns None when no mesh axis
    divides the problem.  Complex operands ride along — the per-shard
    call routes them through the same 4M/3M expansions as the
    single-device dispatcher.
    """
    if a.ndim != 2 or getattr(b, "ndim", 0) != 2:
        return None
    part = gemm_partition(a.shape[0], a.shape[1], b.shape[1], mesh)
    if part is None:
        return None
    from repro.kernels import dispatch
    body_cfg = cfg if part.kind != "row" else _pin_row_cfg(cfg, a.shape[1])
    mesh_shape = _mesh_shape(mesh)
    a_spec, b_spec, out_spec = part.specs(2)
    coll_bytes = _record_partition(part, cfg, mesh_shape,
                                   a.shape[0], a.shape[1], b.shape[1])

    def body(al, bl):
        out = dispatch.emulated_matmul(al, bl, cfg=body_cfg,
                                       out_dtype=out_dtype,
                                       mesh_shape=mesh_shape)
        for ax in part.reduce_axes:
            out = jax.lax.psum(out, ax)
        telemetry.record_collective("psum", mesh_shape, coll_bytes)
        return out

    return shard_map(body, mesh=mesh, in_specs=(a_spec, b_spec),
                     out_specs=out_spec, check_rep=False)(a, b)


def sharded_dense(x: jax.Array, w, cfg, mesh: Mesh) -> jax.Array | None:
    """x: (..., K) @ w: (K, N) per-shard fused — the model-layer entry.

    ``w`` may be a float weight, a ``StepPrepared`` pair (the float
    weight shards and each model shard prepares its own slice stack
    inside the body — local K equals global K under the column layout,
    so the per-shard prep is bit-identical and never gathers; the
    once-per-step hoist is traded for shard-local residency), or a
    bare ``PreparedOperand``/``PreparedResidues`` (localized, see
    ``_localize_prepared``).  Float routes go through ``emulated_dot``
    so the custom VJP (and ``cfg.cache_weights``) works under
    ``jax.grad`` exactly as on one device.  Returns None whenever this
    module cannot partition — caller falls back to the direct routes.
    """
    from repro.core.emulated import emulated_dot, prepared_dot

    if jnp.issubdtype(x.dtype, jnp.complexfloating):
        return None

    # Bare prepared rhs (serving): localized column-parallel consumption.
    if not isinstance(w, jax.Array) and (hasattr(w, "slices")
                                         or hasattr(w, "residues")):
        localized = _localize_prepared(w, mesh)
        if localized is None:
            return None
        local, prep_specs = localized
        part = GemmPartition(
            "column", shd._fit(x.shape[0], shd.data_axes(mesh), mesh),
            "model")
        x_spec, _, out_spec = part.specs(x.ndim)
        body = shard_map(
            lambda xl, pl: prepared_dot(xl, pl), mesh=mesh,
            in_specs=(x_spec, prep_specs), out_specs=out_spec,
            check_rep=False)
        return body(x, local)

    weight = w.w if not isinstance(w, jax.Array) and hasattr(w, "prep") \
        else w
    if getattr(weight, "ndim", 0) != 2 \
            or jnp.issubdtype(weight.dtype, jnp.complexfloating):
        return None
    k, n = weight.shape
    part = gemm_partition(x.shape[0], k, n, mesh)
    if part is None:
        return None
    body_cfg = cfg if part.kind != "row" else _pin_row_cfg(cfg, k)
    x_spec, w_spec, out_spec = part.specs(x.ndim)
    mesh_shape = _mesh_shape(mesh)
    lead = 1
    for d in x.shape[:-1]:
        lead *= d
    coll_bytes = _record_partition(part, cfg, mesh_shape, lead, k, n)

    def body(xl, wl):
        out = emulated_dot(xl, wl, body_cfg)
        for ax in part.reduce_axes:
            out = jax.lax.psum(out, ax)
        telemetry.record_collective("psum", mesh_shape, coll_bytes)
        return out

    return shard_map(body, mesh=mesh, in_specs=(x_spec, w_spec),
                     out_specs=out_spec, check_rep=False)(x, weight)
