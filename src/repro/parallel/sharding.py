"""GSPMD sharding rules: parameter / optimizer / cache PartitionSpecs.

Axes convention (launch/mesh.py):
  * data axes ``('pod', 'data')`` (multi-pod) or ``('data',)`` — batch and
    FSDP parameter sharding;
  * ``'model'`` — tensor parallelism (attention heads / FFN width / experts
    / padded vocab).

Rules are name+shape driven with divisibility fallbacks: a dim that does
not divide the mesh axis is simply left unsharded (e.g. qwen1.5's 40 heads
on a 16-way model axis fall back to contraction-dim sharding). Scanned
parameter stacks get a leading ``None`` for the group axis automatically.
"""

from __future__ import annotations

import math

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


def _axes_size(mesh: Mesh, axes) -> int:
    if axes is None:
        return 1
    if isinstance(axes, str):
        axes = (axes,)
    return math.prod(mesh.shape[a] for a in axes)


def _fit(dim: int, axes, mesh: Mesh):
    """axes if dim divides their product else None (unsharded fallback)."""
    return axes if axes and dim % _axes_size(mesh, axes) == 0 else None


def data_axes(mesh: Mesh):
    return tuple(a for a in ("pod", "data") if a in mesh.shape) or None


def batch_pspec(mesh: Mesh, extra_dims: int = 1) -> P:
    return P(data_axes(mesh), *([None] * extra_dims))


# Projections whose *output* dim carries TP ("column parallel") ...
_UP = {"wq", "wk", "wv", "wi", "wi_gate", "wi_up", "w_y", "w_gate", "w_in",
       "wq_b", "wkv_b", "w_r", "w_i", "proj"}
# ... and whose *input* dim carries TP ("row parallel", after a TP output).
_DOWN = {"wo", "w_out"}
# Low-rank down-projections: keep the small latent dim replicated.
_LATENT = {"wq_a", "wkv_a", "frontend_proj"}


def _param_rule(path: tuple[str, ...], shape, mesh: Mesh, fsdp: bool,
                attn_sp: bool = False):
    mdl = "model"
    dp = data_axes(mesh) if fsdp else None
    name = path[-1]
    stacked = "layers" in path          # scan-stacked: leading group axis
    core = shape[1:] if stacked else shape
    rank = len(core)

    def spec(*parts):
        parts = list(parts) + [None] * (rank - len(parts))
        if stacked:
            parts = [None] + parts
        return P(*parts)

    if name == "emb":
        return spec(_fit(core[0], mdl, mesh), _fit(core[1], dp, mesh))
    if name == "head":
        return spec(_fit(core[0], dp, mesh), _fit(core[1], mdl, mesh))
    if name == "router":
        return spec(None, None)
    if name in ("conv_w",):
        return spec(None, _fit(core[1], mdl, mesh))
    if rank == 3 and name in ("wi_gate", "wi_up"):      # experts (E, D, F)
        return spec(_fit(core[0], mdl, mesh), _fit(core[1], dp, mesh), None)
    if rank == 3 and name == "wo":                      # experts (E, F, D)
        return spec(_fit(core[0], mdl, mesh), None, _fit(core[2], dp, mesh))
    if rank == 2 and name in _LATENT:
        return spec(_fit(core[0], dp, mesh), None)
    if attn_sp and "mixer" in path and name in ("wq", "wk", "wv", "wo"):
        # Sequence-parallel attention: activations carry the model axis
        # along S, so attention weights cannot shard over 'model' — they
        # shard over the data axes instead (gathered per use, ZeRO-3
        # style), regardless of the global fsdp setting.
        dpa = data_axes(mesh)
        return spec(_fit(core[0], dpa, mesh), None)
    if rank == 2 and name in _UP:
        out_ax = _fit(core[1], mdl, mesh)
        if out_ax is None:  # fall back to sharding the contraction dim
            return spec(_fit(core[0], mdl, mesh), None)
        return spec(_fit(core[0], dp, mesh), out_ax)
    if rank == 2 and name in _DOWN:
        in_ax = _fit(core[0], mdl, mesh)
        if in_ax is None:
            return spec(None, _fit(core[1], mdl, mesh))
        return spec(in_ax, _fit(core[1], dp, mesh))
    return spec()                                        # norms, biases, 1-D


def param_pspecs(params, mesh: Mesh, fsdp: bool = False,
                 attn_sp: bool = False):
    """Pytree of PartitionSpec matching ``params`` (shapes or arrays)."""
    flat = jax.tree_util.tree_flatten_with_path(params)[0]

    def key_names(kp):
        out = []
        for k in kp:
            if hasattr(k, "key"):
                out.append(str(k.key))
            elif hasattr(k, "idx"):
                out.append(str(k.idx))
        return tuple(out)

    specs = [_param_rule(key_names(kp), leaf.shape, mesh, fsdp, attn_sp)
             for kp, leaf in flat]
    return jax.tree_util.tree_unflatten(
        jax.tree_util.tree_structure(params), specs)


def add_dp_to_spec(spec: P, shape, mesh: Mesh) -> P:
    """ZeRO-2: shard the first unsharded, divisible dim over the data
    axes (applied to optimizer states and the gradient accumulator).
    No-op if the spec already uses the data axes."""
    dp = data_axes(mesh)
    used = {a for part in spec if part
            for a in ((part,) if isinstance(part, str) else part)}
    if dp and any(a in used for a in dp):
        return spec
    parts = list(spec) + [None] * (len(shape) - len(spec))
    for i, (ax, dim) in enumerate(zip(parts, shape)):
        if ax is None and _fit(dim, dp, mesh):
            parts[i] = dp
            return P(*parts)
    return spec


def grad_pspecs(params, params_specs, mesh: Mesh, zero2: bool):
    if not zero2:
        return params_specs
    flat_p = jax.tree_util.tree_leaves(params)
    flat_s = jax.tree_util.tree_leaves(
        params_specs, is_leaf=lambda x: isinstance(x, P))
    out = [add_dp_to_spec(s, p.shape, mesh)
           for p, s in zip(flat_p, flat_s)]
    return jax.tree_util.tree_unflatten(
        jax.tree_util.tree_structure(params), out)


def opt_pspecs(opt_state, params_specs, mesh: Mesh, zero2: bool = False):
    """Optimizer states mirror parameter specs (plus data-axis sharding
    under ZeRO-2); adafactor's factored statistics drop the corresponding
    parameter axis."""
    def moment(sub):
        if not zero2:
            return jax.tree.map(lambda s: s, params_specs)
        return grad_pspecs(sub, params_specs, mesh, True)

    out = {}
    for k, v in opt_state.items():
        if k == "step":
            out[k] = P()
        elif k in ("m", "v"):
            out[k] = moment(v)
        elif k == "vr":      # param spec minus last axis
            out[k] = jax.tree.map(
                lambda s: P(*s[:-1]) if len(s) else P(), params_specs)
        elif k == "vc":      # param spec minus second-to-last axis
            out[k] = jax.tree.map(
                lambda s: P(*(s[:-2] + s[-1:])) if len(s) >= 2 else P(),
                params_specs)
        else:
            out[k] = jax.tree.map(lambda _: P(), v)
    return out


def _cache_rule(path: tuple[str, ...], shape, mesh: Mesh):
    dp = data_axes(mesh)
    mdl = "model"
    name = path[-1]
    stacked = "layers" in path
    core = shape[1:] if stacked else shape
    rank = len(core)

    def spec(*parts):
        parts = list(parts) + [None] * (rank - len(parts))
        if stacked:
            parts = [None] + parts
        return P(*parts)

    if name in ("k", "v", "k_scale", "v_scale"):   # (B, S, KVH, HD)
        b_ax = _fit(core[0], dp, mesh)
        kvh_ax = _fit(core[2], mdl, mesh)
        if kvh_ax is not None:
            return spec(b_ax, None, kvh_ax, None)
        return spec(b_ax, _fit(core[1], mdl, mesh), None, None)
    if name in ("c_kv", "k_pe"):                   # MLA latent (B, S, L)
        return spec(_fit(core[0], dp, mesh), _fit(core[1], mdl, mesh), None)
    if name == "h":                                # RG-LRU state (B, W)
        return spec(_fit(core[0], dp, mesh), _fit(core[1], mdl, mesh))
    if name == "conv":                             # (B, k-1, W)
        return spec(_fit(core[0], dp, mesh), None,
                    _fit(core[2], mdl, mesh))
    if name == "ssm":                              # (B, H, P, N)
        return spec(_fit(core[0], dp, mesh), _fit(core[1], mdl, mesh),
                    None, None)
    return spec(_fit(core[0], dp, mesh))


def cache_pspecs(cache, mesh: Mesh):
    flat = jax.tree_util.tree_flatten_with_path(cache)[0]

    def key_names(kp):
        out = []
        for k in kp:
            if hasattr(k, "key"):
                out.append(str(k.key))
            elif hasattr(k, "idx"):
                out.append(str(k.idx))
        return tuple(out)

    specs = [_cache_rule(key_names(kp), leaf.shape, mesh)
             for kp, leaf in flat]
    return jax.tree_util.tree_unflatten(
        jax.tree_util.tree_structure(cache), specs)


def shardings(specs, mesh: Mesh):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), specs,
                        is_leaf=lambda x: isinstance(x, P))
