"""Scheme-II gradient compression for data-parallel reduction.

A beyond-paper extension of the Ozaki Scheme II idea to *collectives*:
gradients are scaled to integers, reduced to ``p`` int8-range residues mod
pairwise-coprime moduli, **psum'd in exact int32 modular arithmetic**, and
CRT-reconstructed. Because every step is exact integer math:

  * the reduction is bitwise deterministic regardless of reduction order
    or participant count (floating-point psum is not), and
  * the wire format is p bytes/element (p~4-6) instead of 4 — with p=4 a
    int8-residue all-reduce moves the same bytes as int32 but carries
    ~float32-grade magnitude range, and p=6 covers it with margin.

Exactness bound: n_devices * 2^(2*budget)... not applicable here — the sum
of n integerized gradients needs |sum| < P/2, i.e.
budget <= log2(P) - 1 - ceil(log2 n). ``compressed_psum`` picks the budget
automatically from the modulus set and axis size.
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.core.precision import default_moduli


def compressed_psum(x: jax.Array, axis_name: str, n_devices: int,
                    p: int = 6):
    """Exact, deterministic psum of float32 ``x`` over ``axis_name``.

    Must run inside shard_map/pmap where ``axis_name`` is bound.
    Values are clamped into a power-of-two scale chosen from the *global*
    max magnitude (one scalar psum), so all devices integerize identically.
    """
    moduli = default_moduli(p)
    log2_p_prod = sum(math.log2(m) for m in moduli)
    budget = int(log2_p_prod - 2 - math.ceil(math.log2(max(2, n_devices))))
    budget = min(budget, 30)  # int32 residue math headroom

    amax = jax.lax.pmax(jnp.max(jnp.abs(x)), axis_name)
    amax = jnp.maximum(amax, 1e-30)
    exp = jnp.ceil(jnp.log2(amax))
    scale = jnp.exp2(budget - 1 - exp)          # |x*scale| < 2^(budget-1)
    xi = jnp.round(x * scale).astype(jnp.int32)

    # Residues in balanced form, psum'd exactly in int32: the sum of n
    # balanced residues is < n*128*m << 2^31 for p<=16, n<=2^20.
    res = []
    for m in moduli:
        half = m // 2
        r = jnp.remainder(xi + half, m) - half
        res.append(jax.lax.psum(r, axis_name))

    # CRT via balanced Garner digits (exact int32), then float assembly.
    from repro.core.scheme2 import garner_digits, mixed_radix_to_dd
    canon = [jnp.remainder(r, m) for r, m in zip(res, moduli)]
    digits = garner_digits(jnp.stack(canon), moduli)
    hi, lo = mixed_radix_to_dd(digits, moduli)
    total = hi.astype(jnp.float32) + lo.astype(jnp.float32)
    return total / scale


def compressed_pmean(x: jax.Array, axis_name: str, n_devices: int,
                     p: int = 6):
    return compressed_psum(x, axis_name, n_devices, p) / n_devices
