"""internvl2-1b [vlm]: 24L d=896 14H (GQA kv=2) d_ff=4864 vocab=151655.

InternLM2/Qwen2-0.5B-class LM backbone [arXiv:2404.16821]. The InternViT
vision tower is a STUB per the assignment: ``input_specs`` provides
precomputed 1024-dim patch embeddings that are projected and placed at the
first ``n_image_tokens`` positions of the sequence.
"""

from __future__ import annotations

import dataclasses

from repro.configs.base import ArchConfig, ModelConfig, TrainPolicy

CONFIG = ArchConfig(
    model=ModelConfig(
        name="internvl2-1b", family="vlm",
        n_layers=24, d_model=896, n_heads=14, n_kv_heads=2,
        d_ff=4864, vocab=151655,
        qkv_bias=True, norm="rms", act="swiglu", rope_theta=1000000.0,
        frontend="vision_stub", frontend_dim=1024, n_image_tokens=256,
        dtype="bfloat16", attn_sharding="sp",
    ),
    train=TrainPolicy(microbatches=1, fsdp=False, zero2=True),
    shape_skips=("long_500k",),
    skip_reason="full quadratic attention: 512k decode KV infeasible",
)


def smoke() -> ArchConfig:
    return dataclasses.replace(
        CONFIG,
        model=dataclasses.replace(
            CONFIG.model, n_layers=2, d_model=56, n_heads=7, n_kv_heads=1,
            d_ff=112, vocab=500, frontend_dim=48, n_image_tokens=16,
            dtype="float32", q_chunk=64, kv_chunk=64),
        train=TrainPolicy(microbatches=1))
