"""mamba2-780m [ssm]: 48L d=1536 attn-free, vocab=50280, ssm_state=128 —
SSD state-space duality [arXiv:2405.21060].

Every block is a Mamba-2 SSD mixer (no attention, no separate FFN).
Decode state is O(1) per layer, so this arch runs the long_500k cell.
Intra-chunk SSD compute is all matmuls (MXU-friendly); the emulated-GEMM
backend applies to the projections, and chunk-level GEMMs are small enough
that emulation overhead is documented as unattractive (DESIGN.md
§Arch-applicability).
"""

from __future__ import annotations

import dataclasses

from repro.configs.base import ArchConfig, ModelConfig, SSDConfig, TrainPolicy

CONFIG = ArchConfig(
    model=ModelConfig(
        name="mamba2-780m", family="ssm",
        n_layers=48, d_model=1536, n_heads=1, n_kv_heads=1,
        d_ff=0, vocab=50280,
        norm="rms", act="swiglu",
        block_pattern=("ssd",),
        ssd=SSDConfig(d_state=128, head_dim=64, expand=2, conv_kernel=4,
                      chunk=256),
        tie_embeddings=True,
        sub_quadratic=True,
        dtype="bfloat16",
    ),
    train=TrainPolicy(microbatches=2, fsdp=False),
)


def smoke() -> ArchConfig:
    return dataclasses.replace(
        CONFIG,
        model=dataclasses.replace(
            CONFIG.model, n_layers=3, d_model=64, vocab=500,
            ssd=SSDConfig(d_state=16, head_dim=16, expand=2, conv_kernel=4,
                          chunk=32),
            dtype="float32", q_chunk=32, kv_chunk=32),
        train=TrainPolicy(microbatches=1))
