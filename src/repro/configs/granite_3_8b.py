"""granite-3-8b [dense]: 40L d=4096 32H (GQA kv=8) d_ff=12800 vocab=49155.

GQA decoder, SwiGLU, RMSNorm [hf:ibm-granite/granite-3.0-8b-base family].
Full attention => long_500k skipped.
"""

from __future__ import annotations

import dataclasses

from repro.configs.base import ArchConfig, ModelConfig, TrainPolicy

CONFIG = ArchConfig(
    model=ModelConfig(
        name="granite-3-8b", family="dense",
        n_layers=40, d_model=4096, n_heads=32, n_kv_heads=8,
        d_ff=12800, vocab=49155,
        norm="rms", act="swiglu", rope_theta=10000.0,
        dtype="bfloat16", attn_sharding="sp",
    ),
    train=TrainPolicy(microbatches=4, fsdp=False, zero2=True),
    shape_skips=("long_500k",),
    skip_reason="full quadratic attention: 512k decode KV infeasible",
)


def smoke() -> ArchConfig:
    return dataclasses.replace(
        CONFIG,
        model=dataclasses.replace(
            CONFIG.model, n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
            d_ff=160, vocab=503, dtype="float32",
            q_chunk=64, kv_chunk=64),
        train=TrainPolicy(microbatches=1))
