"""olmo-1b-emu [dense]: olmo-1b with the paper's emulated-GEMM site
policy shipped in the config instead of CLI flags.

Dense projections (attention/FFN/logits) run Scheme I at p=4 with cached
weight decomposition — the serving-style sweet spot of Table 3 — while
the attention score contraction uses Scheme II with 6 moduli (the
narrow-K shape where modular slices beat mantissa slices). The weighted-
value contraction stays on plain Scheme I (its operand is a fresh
softmax output every step, so ``+cached`` would never hit).
"""

from __future__ import annotations

import dataclasses

from repro.configs import olmo_1b
from repro.configs.base import ArchConfig

_SITES = (
    ("default", "ozaki1-p4+cached"),
    ("attn_qk", "ozaki2-m6"),
    ("attn_av", "ozaki1-p4"),
)

CONFIG = dataclasses.replace(
    olmo_1b.CONFIG,
    model=dataclasses.replace(olmo_1b.CONFIG.model, name="olmo-1b-emu"),
    gemm_sites=_SITES,
)


def smoke() -> ArchConfig:
    base = olmo_1b.smoke()
    return dataclasses.replace(
        base,
        model=dataclasses.replace(base.model, name="olmo-1b-emu"),
        gemm_sites=_SITES,
    )
