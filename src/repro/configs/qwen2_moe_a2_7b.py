"""qwen2-moe-a2.7b [moe]: 24L d=2048 16H (kv=16) expert d_ff=1408,
MoE 60 routed top-4 + 4 gated shared experts [hf:Qwen/Qwen1.5-MoE-A2.7B].

Every layer is MoE. The 60 routed experts are padded to 64 so the expert
axis shards over the 16-way model axis (padding experts are routing-dead).
Shared experts total 4x1408 = 5632 hidden width with a learned sigmoid
gate.
"""

from __future__ import annotations

import dataclasses

from repro.configs.base import ArchConfig, ModelConfig, MoEConfig, TrainPolicy

CONFIG = ArchConfig(
    model=ModelConfig(
        name="qwen2-moe-a2.7b", family="moe",
        n_layers=24, d_model=2048, n_heads=16, n_kv_heads=16,
        d_ff=1408, vocab=151936,
        qkv_bias=True, norm="rms", act="swiglu", rope_theta=1000000.0,
        moe=MoEConfig(n_experts=60, top_k=4, d_ff_expert=1408,
                      n_shared=4, d_ff_shared=5632, shared_gate=True,
                      scoring="softmax", norm_topk=False, pad_multiple=64),
        dtype="bfloat16",
    ),
    train=TrainPolicy(microbatches=2, fsdp=False),
    shape_skips=("long_500k",),
    skip_reason="full quadratic attention: 512k decode KV infeasible",
)


def smoke() -> ArchConfig:
    return dataclasses.replace(
        CONFIG,
        model=dataclasses.replace(
            CONFIG.model, n_layers=2, d_model=64, n_heads=4, n_kv_heads=4,
            d_ff=96, vocab=500,
            moe=MoEConfig(n_experts=6, top_k=2, d_ff_expert=96,
                          n_shared=2, d_ff_shared=192, shared_gate=True,
                          scoring="softmax", norm_topk=False, pad_multiple=8,
                          n_groups=4),
            dtype="float32", q_chunk=64, kv_chunk=64),
        train=TrainPolicy(microbatches=1))
