"""Architecture registry: ``--arch <id>`` -> ArchConfig.

Each assigned architecture lives in its own module with the exact
published configuration plus a reduced ``smoke()`` variant for CPU tests.
"""

from __future__ import annotations

import importlib

from repro.configs.base import (ALL_SHAPES, ArchConfig, ModelConfig,  # noqa
                                ShapeSpec, TrainPolicy)

ARCH_IDS = (
    "hubert-xlarge",
    "granite-3-8b",
    "deepseek-coder-33b",
    "olmo-1b",
    "qwen1.5-32b",
    "internvl2-1b",
    "qwen2-moe-a2.7b",
    "deepseek-v3-671b",
    "recurrentgemma-2b",
    "mamba2-780m",
)

_MODULES = {a: "repro.configs." + a.replace("-", "_").replace(".", "_")
            for a in ARCH_IDS}

# Emulation-policy variants: the same published architectures with the
# paper's per-site GEMM emulation specs baked into ``gemm_sites`` (no CLI
# flags needed). Registered for ``--arch`` lookup but kept out of
# ARCH_IDS so the full-zoo test/benchmark matrices don't run each dense
# architecture twice.
_MODULES["olmo-1b-emu"] = "repro.configs.olmo_1b_emu"
_MODULES["qwen2-moe-a2.7b-emu"] = "repro.configs.qwen2_moe_a2_7b_emu"


def get_config(arch: str) -> ArchConfig:
    if arch not in _MODULES:
        raise KeyError(f"unknown arch {arch!r}; known: {sorted(_MODULES)}")
    return importlib.import_module(_MODULES[arch]).CONFIG


def get_smoke_config(arch: str) -> ArchConfig:
    if arch not in _MODULES:
        raise KeyError(f"unknown arch {arch!r}; known: {sorted(_MODULES)}")
    return importlib.import_module(_MODULES[arch]).smoke()
