"""qwen2-moe-a2.7b-emu [moe]: qwen2-moe-a2.7b with a per-site emulated-
GEMM policy shipped in the config.

The grouped expert matmuls are the dominant FLOP sink and run Scheme I
at p=4 — their (E, G*C, d) stacks are exactly the strided-batched fused
path this config exercises — while the router stays on Scheme II
(tiny K, exactness matters for top-k stability) and the dense
projections default to cached Scheme I. The gating/combine one-hot
einsums stay native: their operands are exact 0/1 masks.
"""

from __future__ import annotations

import dataclasses

from repro.configs import qwen2_moe_a2_7b
from repro.configs.base import ArchConfig

_SITES = (
    ("default", "ozaki1-p4+cached"),
    ("moe_expert", "ozaki1-p4"),
    ("moe_gate", "ozaki2-m6"),
    ("attn_qk", "ozaki2-m6"),
    ("attn_av", "ozaki1-p4"),
)

CONFIG = dataclasses.replace(
    qwen2_moe_a2_7b.CONFIG,
    model=dataclasses.replace(qwen2_moe_a2_7b.CONFIG.model,
                              name="qwen2-moe-a2.7b-emu"),
    gemm_sites=_SITES,
)


def smoke() -> ArchConfig:
    base = qwen2_moe_a2_7b.smoke()
    return dataclasses.replace(
        base,
        model=dataclasses.replace(base.model, name="qwen2-moe-a2.7b-emu"),
        gemm_sites=_SITES,
    )
