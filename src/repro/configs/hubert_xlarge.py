"""hubert-xlarge [audio]: 48L d=1280 16H (kv=16) d_ff=5120 vocab=504.

Encoder-only (bidirectional), LayerNorm, GELU FFN, same backbone as
wav2vec 2.0 [arXiv:2106.07447]. The convolutional waveform frontend is a
STUB per the assignment: ``input_specs`` provides precomputed 512-dim
frame embeddings. Encoder-only => no decode step (decode_32k / long_500k
cells skipped).
"""

from __future__ import annotations

import dataclasses

from repro.configs.base import ArchConfig, ModelConfig, TrainPolicy

CONFIG = ArchConfig(
    model=ModelConfig(
        name="hubert-xlarge", family="audio",
        n_layers=48, d_model=1280, n_heads=16, n_kv_heads=16,
        d_ff=5120, vocab=504,
        norm="layernorm", act="gelu", causal=False, qkv_bias=True,
        frontend="audio_stub", frontend_dim=512,
        dtype="bfloat16",
    ),
    train=TrainPolicy(microbatches=2, fsdp=False),
    shape_skips=("decode_32k", "long_500k"),
    skip_reason="encoder-only: no autoregressive decode step exists",
)


def smoke() -> ArchConfig:
    return dataclasses.replace(
        CONFIG,
        model=dataclasses.replace(
            CONFIG.model, n_layers=3, d_model=64, n_heads=4, n_kv_heads=4,
            d_ff=128, frontend_dim=32, dtype="float32",
            q_chunk=64, kv_chunk=64),
        train=TrainPolicy(microbatches=1))
