"""olmo-1b [dense]: 16L d=2048 16H (kv=16) d_ff=8192 vocab=50304.

Non-parametric LayerNorm (no scale/bias), SwiGLU, tied embeddings
[arXiv:2402.00838].
"""

from __future__ import annotations

import dataclasses

from repro.configs.base import ArchConfig, ModelConfig, TrainPolicy

CONFIG = ArchConfig(
    model=ModelConfig(
        name="olmo-1b", family="dense",
        n_layers=16, d_model=2048, n_heads=16, n_kv_heads=16,
        d_ff=8192, vocab=50304,
        norm="nonparam", act="swiglu", tie_embeddings=True,
        dtype="bfloat16",
    ),
    train=TrainPolicy(microbatches=1, fsdp=False),
    shape_skips=("long_500k",),
    skip_reason="full quadratic attention: 512k decode KV infeasible",
)


def smoke() -> ArchConfig:
    return dataclasses.replace(
        CONFIG,
        model=dataclasses.replace(
            CONFIG.model, n_layers=2, d_model=64, n_heads=4, n_kv_heads=4,
            d_ff=128, vocab=500, dtype="float32",
            q_chunk=64, kv_chunk=64),
        train=TrainPolicy(microbatches=1))
