"""deepseek-v3-671b [moe]: 61L d=7168 128H MLA, expert d_ff=2048,
MoE 1 shared + 256 routed top-8, MTP [arXiv:2412.19437].

MLA: q_lora=1536, kv_lora=512, qk_nope=128, qk_rope=64, v=128 — the KV
cache stores only the 576-dim latent per token. Routing is sigmoid-scored
with a selection-only bias (aux-loss-free balancing hook). One extra MTP
block predicts token t+2 through the shared head (weight 0.3 in the loss).
Adafactor + 16 microbatches + full scan remat keep the 512-chip memory
plan under 16 GiB/device (see EXPERIMENTS.md §Dry-run).
"""

from __future__ import annotations

import dataclasses

from repro.configs.base import (ArchConfig, MLAConfig, ModelConfig, MoEConfig,
                                TrainPolicy)

CONFIG = ArchConfig(
    model=ModelConfig(
        name="deepseek-v3-671b", family="moe",
        n_layers=61, d_model=7168, n_heads=128, n_kv_heads=128,
        d_ff=2048, vocab=129280,
        norm="rms", act="swiglu", rope_theta=10000.0,
        mla=MLAConfig(q_lora_rank=1536, kv_lora_rank=512,
                      qk_nope_dim=128, qk_rope_dim=64, v_dim=128),
        moe=MoEConfig(n_experts=256, top_k=8, d_ff_expert=2048,
                      n_shared=1, d_ff_shared=2048,
                      scoring="sigmoid", norm_topk=True, pad_multiple=0),
        mtp=True,
        dtype="bfloat16",
    ),
    train=TrainPolicy(microbatches=16, fsdp=True, optimizer="adafactor"),
    shape_skips=("long_500k",),
    skip_reason="full quadratic (latent) attention: 512k decode skipped",
)


def smoke() -> ArchConfig:
    return dataclasses.replace(
        CONFIG,
        model=dataclasses.replace(
            CONFIG.model, n_layers=2, d_model=64, n_heads=4, n_kv_heads=4,
            d_ff=64, vocab=500,
            mla=MLAConfig(q_lora_rank=32, kv_lora_rank=16,
                          qk_nope_dim=16, qk_rope_dim=8, v_dim=16),
            moe=MoEConfig(n_experts=8, top_k=2, d_ff_expert=64,
                          n_shared=1, d_ff_shared=64,
                          scoring="sigmoid", norm_topk=True, pad_multiple=0,
                          n_groups=4),
            dtype="float32", q_chunk=64, kv_chunk=64),
        train=TrainPolicy(microbatches=1))
