"""recurrentgemma-2b [hybrid]: 26L d=2560 10H (MQA kv=1) d_ff=7680
vocab=256000 — RG-LRU + local attention in a 1:2 pattern
[arXiv:2402.19427 (Griffin)].

Block pattern (rec, rec, attn) x 8 + (rec, rec) = 26 layers. Attention is
local (window 2048) MQA, so decode caches are O(window): this arch runs
the long_500k cell. The RG-LRU recurrence itself is elementwise — the
paper's GEMM emulation applies to the block projections but not the scan
(DESIGN.md §Arch-applicability).
"""

from __future__ import annotations

import dataclasses

from repro.configs.base import ArchConfig, ModelConfig, RGLRUConfig, TrainPolicy

CONFIG = ArchConfig(
    model=ModelConfig(
        name="recurrentgemma-2b", family="hybrid",
        n_layers=26, d_model=2560, n_heads=10, n_kv_heads=1,
        d_ff=7680, vocab=256000,
        norm="rms", act="geglu", attn_window=2048,
        block_pattern=("rec", "rec", "attn"),
        rglru=RGLRUConfig(lru_width=2560, conv_kernel=4),
        sub_quadratic=True,
        dtype="bfloat16", attn_sharding="sp",
    ),
    train=TrainPolicy(microbatches=2, fsdp=False, zero2=True),
)


def smoke() -> ArchConfig:
    return dataclasses.replace(
        CONFIG,
        model=dataclasses.replace(
            CONFIG.model, n_layers=5, d_model=64, n_heads=4, n_kv_heads=1,
            d_ff=128, vocab=500, attn_window=32,
            rglru=RGLRUConfig(lru_width=64, conv_kernel=4),
            dtype="float32", q_chunk=32, kv_chunk=32),
        train=TrainPolicy(microbatches=1))
