"""Configuration schema for the architecture zoo and its input shapes.

Every assigned architecture is a ``ArchConfig`` instance in its own module
under ``repro.configs``; ``repro.configs.registry`` maps ``--arch`` ids to
them. ``input_specs`` builds the ShapeDtypeStruct stand-ins the dry-run
lowers against (no allocation).
"""

from __future__ import annotations

import dataclasses
from typing import Literal

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    d_ff_expert: int
    n_shared: int = 0
    d_ff_shared: int = 0          # total shared-expert hidden width
    scoring: Literal["softmax", "sigmoid"] = "softmax"
    norm_topk: bool = True
    shared_gate: bool = False     # qwen2-moe gates the shared expert
    capacity_factor: float = 1.25
    n_groups: int = 512           # GShard-style dispatch groups (>= dp size)
    aux_loss_weight: float = 0.01
    pad_multiple: int = 64        # pad experts so the E axis shards cleanly


@dataclasses.dataclass(frozen=True)
class MLAConfig:
    q_lora_rank: int = 1536
    kv_lora_rank: int = 512
    qk_nope_dim: int = 128
    qk_rope_dim: int = 64
    v_dim: int = 128


@dataclasses.dataclass(frozen=True)
class SSDConfig:
    d_state: int = 128
    head_dim: int = 64            # mamba2 P
    expand: int = 2
    conv_kernel: int = 4
    chunk: int = 256
    dt_min: float = 0.001
    dt_max: float = 0.1


@dataclasses.dataclass(frozen=True)
class RGLRUConfig:
    lru_width: int = 0            # 0 => d_model
    conv_kernel: int = 4
    c: float = 8.0


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: Literal["train", "prefill", "decode"]


TRAIN_4K = ShapeSpec("train_4k", 4096, 256, "train")
PREFILL_32K = ShapeSpec("prefill_32k", 32768, 32, "prefill")
DECODE_32K = ShapeSpec("decode_32k", 32768, 128, "decode")
LONG_500K = ShapeSpec("long_500k", 524288, 1, "decode")
ALL_SHAPES = (TRAIN_4K, PREFILL_32K, DECODE_32K, LONG_500K)


@dataclasses.dataclass(frozen=True)
class TrainPolicy:
    """Per-arch distribution / memory knobs for the production mesh."""
    microbatches: int = 1
    remat: bool = True
    optimizer: Literal["adamw", "adafactor"] = "adamw"
    fsdp: bool = False            # ZeRO-3: shard params over 'data'
    zero2: bool = False           # ZeRO-2: params replicated over 'data',
    #                               optimizer states + grad accumulator
    #                               sharded — no per-microbatch weight
    #                               gathers (one AG per step instead)
    learning_rate: float = 3e-4


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                   # audio|dense|vlm|moe|hybrid|ssm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int = 0             # 0 => d_model // n_heads
    qkv_bias: bool = False
    norm: Literal["rms", "layernorm", "nonparam"] = "rms"
    act: Literal["swiglu", "gelu", "geglu"] = "swiglu"
    rope_theta: float = 10000.0
    tie_embeddings: bool = False
    causal: bool = True           # False => encoder (bidirectional)
    attn_window: int | None = None
    block_pattern: tuple[str, ...] = ("attn",)   # attn | rec | ssd
    moe: MoEConfig | None = None
    mla: MLAConfig | None = None
    ssd: SSDConfig | None = None
    rglru: RGLRUConfig | None = None
    mtp: bool = False             # deepseek-v3 multi-token prediction
    frontend: Literal["none", "audio_stub", "vision_stub"] = "none"
    frontend_dim: int = 0         # stub embedding input width
    n_image_tokens: int = 256     # vlm prefix length
    dtype: str = "float32"
    kv_cache_dtype: str = "auto"  # 'auto' (= dtype) | 'int8' (quantized)
    attn_sharding: str = "heads"  # 'heads' (TP over heads) | 'sp' (context)
    q_chunk: int = 1024
    kv_chunk: int = 1024
    sub_quadratic: bool = False   # may run long_500k

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    def pattern_for_layers(self) -> list[str]:
        pat = list(self.block_pattern)
        return [pat[i % len(pat)] for i in range(self.n_layers)]


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    model: ModelConfig
    train: TrainPolicy = TrainPolicy()
    shape_skips: tuple[str, ...] = ()
    skip_reason: str = ""
    # Per-call-site GEMM emulation specs, e.g.
    #     (("ffn", "ozaki1-p4+cached"), ("attn_qk", "ozaki2-m6"))
    # Spec strings use the ``repro.precision`` grammar; the pseudo-site
    # 'default' sets the policy default. Ships emulation choices with the
    # config zoo instead of CLI flags — see :meth:`gemm_policy`.
    gemm_sites: tuple[tuple[str, str], ...] = ()

    def gemm_policy(self):
        """The :class:`repro.models.common.GemmPolicy` of ``gemm_sites``.

        Each ``(site, spec)`` entry is parsed with :func:`repro.precision`
        ('ozaki1-p4+cached', 'ozaki2-m6', 'native', ...). 'default' sets
        the policy default; every other key becomes a per-site override
        ('attn', 'ffn', 'logits', 'attn_qk', 'attn_av', 'moe_gate',
        'moe_expert', 'mla_latent', 'ssd_state', ...). An empty table
        returns the bare ambient-deferring ``GemmPolicy()`` — exactly the
        policy launchers historically built when no ``--gemm`` was given.
        """
        from repro import api
        from repro.models.common import GemmPolicy
        default = None
        overrides = []
        for site, spec in self.gemm_sites:
            cfg = api.precision(spec)
            if site == "default":
                default = cfg
            else:
                overrides.append((site, cfg))
        return GemmPolicy(default=default, overrides=tuple(overrides))

    def shapes(self) -> list[ShapeSpec]:
        out = []
        for s in ALL_SHAPES:
            if s.name in self.shape_skips:
                continue
            # encoder-only archs have no decode step at all
            if s.kind == "decode" and not self.model.causal:
                continue
            out.append(s)
        return out

    def input_specs(self, shape: ShapeSpec, batch: int | None = None):
        """ShapeDtypeStruct stand-ins for every model input (dry-run)."""
        m = self.model
        b = batch if batch is not None else shape.global_batch
        i32 = jnp.int32
        if shape.kind == "train":
            specs = {"tokens": jax.ShapeDtypeStruct((b, shape.seq_len), i32),
                     "labels": jax.ShapeDtypeStruct((b, shape.seq_len), i32)}
        elif shape.kind == "prefill":
            specs = {"tokens": jax.ShapeDtypeStruct((b, shape.seq_len), i32)}
        else:  # decode: one new token against a seq_len cache
            specs = {"tokens": jax.ShapeDtypeStruct((b, 1), i32)}
        if m.frontend == "audio_stub":
            # precomputed frame embeddings replace the token stream
            for k in ("tokens",):
                if k in specs:
                    specs[k] = jax.ShapeDtypeStruct(
                        (b, shape.seq_len if shape.kind != "decode" else 1,
                         m.frontend_dim), jnp.dtype(m.dtype))
        if m.frontend == "vision_stub" and shape.kind != "decode":
            specs["image_embeds"] = jax.ShapeDtypeStruct(
                (b, m.n_image_tokens, m.frontend_dim), jnp.dtype(m.dtype))
        return specs
