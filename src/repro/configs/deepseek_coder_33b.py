"""deepseek-coder-33b [dense]: 62L d=7168 56H (GQA kv=8) d_ff=19200
vocab=32256. Llama-architecture decoder [arXiv:2401.14196].
"""

from __future__ import annotations

import dataclasses

from repro.configs.base import ArchConfig, ModelConfig, TrainPolicy

CONFIG = ArchConfig(
    model=ModelConfig(
        name="deepseek-coder-33b", family="dense",
        n_layers=62, d_model=7168, n_heads=56, n_kv_heads=8,
        d_ff=19200, vocab=32256,
        norm="rms", act="swiglu", rope_theta=100000.0,
        dtype="bfloat16", attn_sharding="sp",
    ),
    train=TrainPolicy(microbatches=8, fsdp=False, zero2=True),
    shape_skips=("long_500k",),
    skip_reason="full quadratic attention: 512k decode KV infeasible",
)


def smoke() -> ArchConfig:
    return dataclasses.replace(
        CONFIG,
        model=dataclasses.replace(
            CONFIG.model, n_layers=2, d_model=64, n_heads=8, n_kv_heads=2,
            d_ff=192, vocab=500, dtype="float32",
            q_chunk=64, kv_chunk=64),
        train=TrainPolicy(microbatches=1))
