"""qwen1.5-32b [dense]: 64L d=5120 40H (kv=40) d_ff=27392 vocab=152064.

QKV bias (Qwen signature) [hf:Qwen/Qwen1.5 family]. 40 heads do not divide
the 16-way model axis: attention projections fall back to
contraction-dim (row) sharding, and the decode KV cache (full 40-head MHA,
the largest of the pool) shards its sequence axis over 'model' and is
stored int8-quantized (see DESIGN.md §Distribution).
"""

from __future__ import annotations

import dataclasses

from repro.configs.base import ArchConfig, ModelConfig, TrainPolicy

CONFIG = ArchConfig(
    model=ModelConfig(
        name="qwen1.5-32b", family="dense",
        n_layers=64, d_model=5120, n_heads=40, n_kv_heads=40,
        d_ff=27392, vocab=152064,
        qkv_bias=True, norm="rms", act="swiglu", rope_theta=1000000.0,
        dtype="bfloat16", kv_cache_dtype="int8", attn_sharding="sp",
    ),
    train=TrainPolicy(microbatches=8, fsdp=False, zero2=True),
    shape_skips=("long_500k",),
    skip_reason="full quadratic attention: 512k decode KV infeasible",
)


def smoke() -> ArchConfig:
    return dataclasses.replace(
        CONFIG,
        model=dataclasses.replace(
            CONFIG.model, n_layers=2, d_model=80, n_heads=5, n_kv_heads=5,
            d_ff=192, vocab=500, dtype="float32", kv_cache_dtype="auto",
            q_chunk=64, kv_chunk=64),
        train=TrainPolicy(microbatches=1))
