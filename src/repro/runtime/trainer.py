"""Fault-tolerant training runtime.

* auto-resume: on construction the Trainer restores the newest valid
  checkpoint (possibly onto a different mesh — elastic re-mesh);
* failure injection: ``FailureInjector`` raises at a chosen step so tests
  can assert bit-exact continuation after restart;
* straggler detection: per-step wall-time EMA + z-score; slow steps are
  logged and counted (the hook where a real cluster would re-slice or
  evict the slow host);
* guard consumption: when emulated GEMMs run with a ``+guard`` spec
  (docs/robustness.md), ``GuardMonitor`` folds the per-step delta of
  ``repro.guard.stats()`` into the metrics log, and a strict-mode
  accuracy trip (``EmulationAccuracyError``) becomes a step-level
  retry-with-backoff instead of a run abort;
* preemption: SIGTERM triggers a final synchronous checkpoint before
  exit (the TPU maintenance-event pattern).
"""

from __future__ import annotations

import signal
import time

import jax
import numpy as np

from repro.checkpoint import CheckpointManager
from repro.core.precision import EmulationAccuracyError


class FailureInjector:
    def __init__(self, fail_at_step: int | None = None):
        self.fail_at_step = fail_at_step
        self.fired = False

    def check(self, step: int):
        if self.fail_at_step is not None and step == self.fail_at_step \
                and not self.fired:
            self.fired = True
            raise RuntimeError(f"injected failure at step {step}")


class StragglerMonitor:
    """EMA of step time; steps slower than mean + z*std are stragglers."""

    def __init__(self, z: float = 3.0, warmup: int = 5):
        self.z = z
        self.warmup = warmup
        self.times: list[float] = []
        self.stragglers: list[tuple[int, float]] = []

    def observe(self, step: int, seconds: float) -> bool:
        self.times.append(seconds)
        if len(self.times) <= self.warmup:
            return False
        hist = np.asarray(self.times[:-1][-50:])
        mu, sd = hist.mean(), hist.std() + 1e-9
        if seconds > mu + self.z * sd:
            self.stragglers.append((step, seconds))
            return True
        return False


class GuardMonitor:
    """Per-step deltas of the process-wide ``repro.guard`` counters.

    ``observe(step)`` is called after the step's metrics sync (the
    ``float(v)`` conversion), so every eager guard event of the step has
    been recorded and every traced one has had its debug callback flushed.
    Steps whose delta shows a trip are collected in ``trip_steps`` — the
    hook a real cluster would alarm on.
    """

    def __init__(self):
        from repro import guard  # cheap: the guard package is pallas-free
        self._stats = guard.stats
        self._last = self._stats()
        self.trip_steps: list[tuple[int, int]] = []

    def observe(self, step: int) -> dict[str, int]:
        now = self._stats()
        delta = {f: getattr(now, f) - getattr(self._last, f)
                 for f in ("calls", "trips", "escalations", "recoveries",
                           "native_fallbacks", "masked")}
        self._last = now
        if delta["trips"]:
            self.trip_steps.append((step, delta["trips"]))
        return delta


class Trainer:
    def __init__(self, *, step_fn, init_state_fn, batch_iterator,
                 ckpt_dir: str, state_shardings=None,
                 ckpt_every: int = 50, keep: int = 3,
                 failure: FailureInjector | None = None,
                 log_every: int = 10, handle_sigterm: bool = False,
                 guard_retries: int = 2, guard_backoff: float = 0.25,
                 metrics_jsonl: str | None = None,
                 tokens_per_step: int | None = None):
        self.step_fn = step_fn
        self.batch_iterator = batch_iterator
        self.ckpt = CheckpointManager(ckpt_dir, keep=keep)
        self.ckpt_every = ckpt_every
        self.failure = failure or FailureInjector()
        self.monitor = StragglerMonitor()
        self.guard_monitor = GuardMonitor()
        self.guard_retries = guard_retries
        self.guard_backoff = guard_backoff
        self.log_every = log_every
        self.metrics_log: list[dict] = []
        self._preempted = False
        # Per-step telemetry records (docs/observability.md): a JSONL
        # sink implies telemetry; otherwise records are written only when
        # the process already enabled it (REPRO_TELEMETRY=1 / enable()).
        from repro import telemetry
        self._telemetry = telemetry
        self._tokens_per_step = tokens_per_step
        self._sink = None
        if metrics_jsonl:
            telemetry.enable()
            self._sink = telemetry.jsonl_sink(metrics_jsonl)
        self._tracker = telemetry.StepTracker() if telemetry.enabled() \
            else None

        latest = self.ckpt.latest_step()
        if latest is not None:
            like = jax.eval_shape(init_state_fn)
            self.state = self.ckpt.restore(latest, like, state_shardings)
            self.start_step = latest + 1
            print(f"[trainer] resumed from step {latest}")
        else:
            self.state = init_state_fn()
            self.start_step = 0

        if handle_sigterm:
            signal.signal(signal.SIGTERM, self._on_sigterm)

    def _on_sigterm(self, *_):
        self._preempted = True

    def run(self, n_steps: int) -> list[dict]:
        step = self.start_step
        end = self.start_step + n_steps
        it = iter(self.batch_iterator)
        # Fast-forward the deterministic stream to the resume point.
        for _ in range(self.start_step):
            next(it)
        while step < end:
            data_step, batch = next(it)
            t0 = time.time()
            self.failure.check(step)
            # A strict guard (`+guard:strict`, docs/robustness.md) raises
            # EmulationAccuracyError when the escalation ladder runs out.
            # The step function is pure (state in, state out), so the
            # step is retried with backoff before giving up; self.state
            # only advances once metrics have synced cleanly.
            attempt = 0
            while True:
                try:
                    new_state, metrics = self.step_fn(self.state, batch)
                    metrics = {k: float(v) for k, v in metrics.items()}
                    break
                except EmulationAccuracyError as e:
                    if attempt >= self.guard_retries:
                        raise
                    attempt += 1
                    pause = self.guard_backoff * attempt
                    print(f"[trainer] guard trip at step {step} "
                          f"(retry {attempt}/{self.guard_retries} "
                          f"after {pause:.2f}s): {e}")
                    time.sleep(pause)
            self.state = new_state
            dt = time.time() - t0
            slow = self.monitor.observe(step, dt)
            metrics.update(step=step, seconds=dt,
                           guard_retries=attempt,
                           **{f"guard_{k}": v for k, v in
                              self.guard_monitor.observe(step).items()
                              if k in ("trips", "native_fallbacks")})
            self.metrics_log.append(metrics)
            if self._tracker is not None:
                self._tracker.step_metrics(
                    step, dt, kind="train",
                    tokens=self._tokens_per_step,
                    loss=metrics.get("loss"),
                    extra={"guard_retries": attempt,
                           "straggler": bool(slow)})
            if slow:
                print(f"[trainer] straggler step {step}: {dt:.3f}s")
            if step % self.log_every == 0:
                print(f"[trainer] step {step} "
                      f"loss {metrics.get('loss', float('nan')):.4f} "
                      f"({dt:.2f}s)")
            if (step + 1) % self.ckpt_every == 0 or step + 1 == end \
                    or self._preempted:
                self.ckpt.save(step, self.state)
            if self._preempted:
                print(f"[trainer] preempted; checkpointed at step {step}")
                break
            step += 1
        self.ckpt.wait()
        self.start_step = step
        return self.metrics_log

    def close(self):
        if self._sink is not None:
            self._sink.close()
            self._sink = None
        self.ckpt.close()
