"""Fault-tolerant training runtime.

* auto-resume: on construction the Trainer restores the newest valid
  checkpoint (possibly onto a different mesh — elastic re-mesh);
* failure injection: ``FailureInjector`` raises at a chosen step so tests
  can assert bit-exact continuation after restart;
* straggler detection: per-step wall-time EMA + z-score; slow steps are
  logged and counted (the hook where a real cluster would re-slice or
  evict the slow host);
* preemption: SIGTERM triggers a final synchronous checkpoint before
  exit (the TPU maintenance-event pattern).
"""

from __future__ import annotations

import signal
import time

import jax
import numpy as np

from repro.checkpoint import CheckpointManager


class FailureInjector:
    def __init__(self, fail_at_step: int | None = None):
        self.fail_at_step = fail_at_step
        self.fired = False

    def check(self, step: int):
        if self.fail_at_step is not None and step == self.fail_at_step \
                and not self.fired:
            self.fired = True
            raise RuntimeError(f"injected failure at step {step}")


class StragglerMonitor:
    """EMA of step time; steps slower than mean + z*std are stragglers."""

    def __init__(self, z: float = 3.0, warmup: int = 5):
        self.z = z
        self.warmup = warmup
        self.times: list[float] = []
        self.stragglers: list[tuple[int, float]] = []

    def observe(self, step: int, seconds: float) -> bool:
        self.times.append(seconds)
        if len(self.times) <= self.warmup:
            return False
        hist = np.asarray(self.times[:-1][-50:])
        mu, sd = hist.mean(), hist.std() + 1e-9
        if seconds > mu + self.z * sd:
            self.stragglers.append((step, seconds))
            return True
        return False


class Trainer:
    def __init__(self, *, step_fn, init_state_fn, batch_iterator,
                 ckpt_dir: str, state_shardings=None,
                 ckpt_every: int = 50, keep: int = 3,
                 failure: FailureInjector | None = None,
                 log_every: int = 10, handle_sigterm: bool = False):
        self.step_fn = step_fn
        self.batch_iterator = batch_iterator
        self.ckpt = CheckpointManager(ckpt_dir, keep=keep)
        self.ckpt_every = ckpt_every
        self.failure = failure or FailureInjector()
        self.monitor = StragglerMonitor()
        self.log_every = log_every
        self.metrics_log: list[dict] = []
        self._preempted = False

        latest = self.ckpt.latest_step()
        if latest is not None:
            like = jax.eval_shape(init_state_fn)
            self.state = self.ckpt.restore(latest, like, state_shardings)
            self.start_step = latest + 1
            print(f"[trainer] resumed from step {latest}")
        else:
            self.state = init_state_fn()
            self.start_step = 0

        if handle_sigterm:
            signal.signal(signal.SIGTERM, self._on_sigterm)

    def _on_sigterm(self, *_):
        self._preempted = True

    def run(self, n_steps: int) -> list[dict]:
        step = self.start_step
        end = self.start_step + n_steps
        it = iter(self.batch_iterator)
        # Fast-forward the deterministic stream to the resume point.
        for _ in range(self.start_step):
            next(it)
        while step < end:
            data_step, batch = next(it)
            t0 = time.time()
            self.failure.check(step)
            self.state, metrics = self.step_fn(self.state, batch)
            metrics = {k: float(v) for k, v in metrics.items()}
            dt = time.time() - t0
            slow = self.monitor.observe(step, dt)
            metrics.update(step=step, seconds=dt)
            self.metrics_log.append(metrics)
            if slow:
                print(f"[trainer] straggler step {step}: {dt:.3f}s")
            if step % self.log_every == 0:
                print(f"[trainer] step {step} "
                      f"loss {metrics.get('loss', float('nan')):.4f} "
                      f"({dt:.2f}s)")
            if (step + 1) % self.ckpt_every == 0 or step + 1 == end \
                    or self._preempted:
                self.ckpt.save(step, self.state)
            if self._preempted:
                print(f"[trainer] preempted; checkpointed at step {step}")
                break
            step += 1
        self.ckpt.wait()
        self.start_step = step
        return self.metrics_log

    def close(self):
        self.ckpt.close()
