from repro.runtime.trainer import Trainer, StragglerMonitor  # noqa: F401
