"""The unified precision API: specs, ambient scopes, and emulated einsum.

This module is the package's front door (re-exported from ``repro``):

* :func:`precision` — normalize a spec string (the mini-language parsed
  by :meth:`EmulationConfig.parse`: ``"ozaki1-p4"``, ``"ozaki2-m6"``,
  ``"bits=50"``, ``"native"``, with ``@backend`` / ``+cached`` /
  ``+xla`` / ``+pallas`` suffixes) or an EmulationConfig into an
  EmulationConfig, so configs are loggable one-liners.
* :func:`emulation` — an ambient scope, modeled on
  ``jax.default_matmul_precision``: ``with repro.emulation("ozaki1-p4"):``
  makes every emulation-aware call-site inside the block (model dense
  projections, ``repro.dot_general``/``einsum``, the kernel dispatcher)
  that was not given an explicit config use the scoped one. The stack is
  thread-local; scopes nest, innermost wins.
* :func:`resolve_config` — THE resolver. One documented precedence,
  consumed by every emulation-aware call-site::

      explicit argument > innermost emulation() scope
                        > REPRO_EMULATION env var > platform default

  The platform default is ``NATIVE`` (no emulation): emulation is always
  an opt-in, per call, per scope, or per process.
* :func:`dot_general` / :func:`einsum` — emulated general contractions.
  Arbitrary batched/multi-axis problems canonicalize (transpose +
  reshape + vmap over batch axes) onto the 2-D emulated GEMM core, so
  any ``jnp.einsum`` call-site can switch to emulation by swapping the
  namespace. Both are differentiable (the 2-D core carries the custom
  VJP) and accept a :class:`repro.kernels.prepared.PreparedOperand` rhs
  for pre-decomposed weights.

Deprecated entry points (``emulated_matmul(scheme=..., precision=...)``,
``maybe_emulated_matmul``, ``parse_gemm_spec``) keep working through
shims that emit DeprecationWarning; see docs/api.md for the migration
table.
"""

from __future__ import annotations

import contextlib
import dataclasses
import functools
import math
import os
import threading

import jax
import jax.numpy as jnp

from repro.core.precision import EmulationConfig, NATIVE

__all__ = [
    "EMULATION_ENV_VAR",
    "precision",
    "emulation",
    "current_emulation",
    "resolve_config",
    "dot_general",
    "einsum",
]

# Process-wide spec override, the env leg of the resolver. Parsed
# per-resolve through a small cache (the string is almost always
# identical across calls).
EMULATION_ENV_VAR = "REPRO_EMULATION"


# ---------------------------------------------------------------------------
# Pillar 1: precision specs.
# ---------------------------------------------------------------------------

def precision(spec: str | EmulationConfig, /, **overrides) -> EmulationConfig:
    """Normalize a spec string or EmulationConfig into an EmulationConfig.

    ``overrides`` are dataclass field replacements applied on top, for
    the fields the grammar does not carry::

        repro.precision("ozaki1-p4", bwd_p=2)   # fewer backward slices
    """
    if isinstance(spec, EmulationConfig):
        cfg = spec
    elif isinstance(spec, str):
        cfg = EmulationConfig.parse(spec)
    else:
        raise TypeError("precision spec must be a str or EmulationConfig, "
                        f"got {type(spec).__name__}")
    if overrides:
        cfg = dataclasses.replace(cfg, **overrides)
    return cfg


# ---------------------------------------------------------------------------
# Pillar 2: ambient emulation scopes + the one resolver.
# ---------------------------------------------------------------------------

_TLS = threading.local()


def _scope_stack() -> list:
    stack = getattr(_TLS, "stack", None)
    if stack is None:
        stack = _TLS.stack = []
    return stack


@functools.lru_cache(maxsize=32)
def _parse_env_spec(spec: str) -> EmulationConfig:
    return EmulationConfig.parse(spec)


@contextlib.contextmanager
def emulation(spec_or_cfg: str | EmulationConfig):
    """Ambient emulation scope: ``with repro.emulation("ozaki1-p4"): ...``.

    Every emulation-aware call-site inside the block that received no
    explicit config resolves to the scoped one. Scopes nest (innermost
    wins) and are thread-local: a scope entered on one thread is
    invisible to others, and threads spawned inside a scope start with
    an empty stack (hand the config over explicitly if a worker should
    inherit it). ``with repro.emulation("native")`` re-disables emulation
    inside an outer emulated scope.

    Note the config is read at *trace* time: entering a scope does not
    retroactively change already-jitted computations, exactly like
    ``jax.default_matmul_precision``.
    """
    cfg = precision(spec_or_cfg)
    stack = _scope_stack()
    stack.append(cfg)
    try:
        yield cfg
    finally:
        stack.pop()


def current_emulation() -> EmulationConfig | None:
    """The ambient config: innermost scope, else the env spec, else None."""
    stack = _scope_stack()
    if stack:
        return stack[-1]
    env = os.environ.get(EMULATION_ENV_VAR)
    if env:
        return _parse_env_spec(env)
    return None


def resolve_config(explicit: str | EmulationConfig | None = None, *,
                   default: str | EmulationConfig | None = None,
                   ) -> EmulationConfig:
    """The one emulation-config resolver (see module doc for precedence).

    ``explicit`` is the call-site's own argument (a spec string or
    config); ``default`` replaces the platform default (``NATIVE``) for
    entry points whose historical no-argument behavior was emulated
    (``emulated_matmul``) — it ranks *below* the ambient scope and env.
    """
    if explicit is not None:
        return precision(explicit)
    ambient = current_emulation()
    if ambient is not None:
        return ambient
    if default is not None:
        return precision(default)
    return NATIVE


# ---------------------------------------------------------------------------
# Pillar 3: general contractions.
# ---------------------------------------------------------------------------

def _is_prepared(x) -> bool:
    from repro.kernels.prepared import PreparedOperand, PreparedResidues
    return isinstance(x, (PreparedOperand, PreparedResidues))


def _with_out_dtype(cfg: EmulationConfig, out_dtype) -> EmulationConfig:
    if out_dtype is None:
        return cfg
    return dataclasses.replace(cfg, out_dtype=jnp.dtype(out_dtype).name)


def _norm_dnums(dimension_numbers, a_ndim: int, b_ndim: int):
    (lc, rc), (lb, rb) = dimension_numbers

    def norm(dims, ndim, what, side):
        dims = tuple(int(d) for d in dims)
        for d in dims:
            if not -ndim <= d < ndim:
                raise ValueError(f"{side} {what} dim {d} out of range for "
                                 f"rank-{ndim} operand")
        dims = tuple(d % ndim for d in dims)
        if len(set(dims)) != len(dims):
            raise ValueError(f"repeated {side} {what} dims {dims}")
        return dims

    lc = norm(lc, a_ndim, "contracting", "lhs")
    rc = norm(rc, b_ndim, "contracting", "rhs")
    lb = norm(lb, a_ndim, "batch", "lhs")
    rb = norm(rb, b_ndim, "batch", "rhs")
    if len(lc) != len(rc):
        raise ValueError(f"contracting dim count mismatch: {lc} vs {rc}")
    if len(lb) != len(rb):
        raise ValueError(f"batch dim count mismatch: {lb} vs {rb}")
    if set(lc) & set(lb):
        raise ValueError(f"lhs dims {set(lc) & set(lb)} are both "
                         "contracting and batch")
    if set(rc) & set(rb):
        raise ValueError(f"rhs dims {set(rc) & set(rb)} are both "
                         "contracting and batch")
    return lc, rc, lb, rb


def _sharded_2d(a2, b, cfg, mesh):
    """Route (..., K) @ (K, N) through the shard_map wrapper when the
    mesh is concrete + multi-device and the config is fused; None means
    'not partitioned here' and the caller takes the unsharded route."""
    if mesh is None:
        return None
    from repro.kernels import dispatch
    if not dispatch._shardable_mesh(mesh) \
            or cfg.impl not in ("auto", "pallas") or cfg.scheme == "native":
        return None
    from repro.parallel import shard_gemm
    if cfg.guard is not None and not jnp.issubdtype(
            jnp.asarray(a2).dtype, jnp.complexfloating):
        # Guard wraps the sharded route at the *global* level: sanitize
        # and verify whole operands/results once, not per shard.  The
        # escalation rungs re-enter here; a rung the partitioner cannot
        # run (impl='xla') takes the unsharded dispatcher instead.
        from repro import guard

        lead = a2.shape[:-1]
        a2f = a2.reshape(-1, a2.shape[-1])

        def run(aa, bb, rung_cfg):
            if rung_cfg.impl in ("auto", "pallas"):
                return shard_gemm.sharded_dense(aa, bb, rung_cfg, mesh)
            return dispatch.emulated_matmul(aa, bb, cfg=rung_cfg)

        n = b.n if _is_prepared(b) else b.shape[-1]
        return guard.guarded_call(a2f, b, cfg, run).reshape(*lead, n)
    return shard_gemm.sharded_dense(a2, b, cfg, mesh)


def _dot_general_prepared(a, b, dimension_numbers, cfg, out_dtype,
                          mesh=None):
    """Prepared rhs: only (..., K) x prepared (K, N) shapes exist — the
    slices/residues were laid out at prepare time and cannot be
    transposed."""
    from repro.core.emulated import prepared_dot
    from repro.kernels.prepared import PreparedResidues
    (lc, rc), (lb, rb) = dimension_numbers
    lc, rc, lb, rb = (tuple(lc), tuple(rc), tuple(lb), tuple(rb))
    if lb or rb or rc != (0,) or len(lc) != 1:
        raise ValueError(
            "a prepared rhs supports only dimension_numbers "
            f"(((k,), (0,)), ((), ())); got {dimension_numbers} — "
            "prepare_rhs fixes the (K, N) layout at decomposition time")
    if cfg.scheme == "native":
        raise ValueError("a prepared rhs is pre-decomposed emulation data; "
                         "it cannot be consumed under a 'native' precision "
                         "spec")
    if isinstance(b, PreparedResidues) and cfg.scheme != "ozaki2":
        raise ValueError("a PreparedResidues rhs is Scheme-II (ozaki2) "
                         f"data; it cannot be consumed under "
                         f"scheme={cfg.scheme!r}")
    if not isinstance(b, PreparedResidues) and cfg.scheme == "ozaki2":
        raise ValueError("a PreparedOperand rhs is Scheme-I (ozaki1) data; "
                         "it cannot be consumed under scheme='ozaki2'")
    if not -a.ndim <= lc[0] < a.ndim:
        raise ValueError(f"lhs contracting dim {lc[0]} out of range for "
                         f"rank-{a.ndim} operand")
    k_axis = lc[0] % a.ndim
    if a.shape[k_axis] != b.k:
        raise ValueError(f"lhs contracting dim {a.shape[k_axis]} vs "
                         f"prepared K={b.k}")
    if k_axis != a.ndim - 1:
        a = jnp.moveaxis(a, k_axis, -1)
    if out_dtype is None and cfg.out_dtype is not None:
        out_dtype = cfg.out_dtype
    if out_dtype is None:
        out_dtype = jnp.promote_types(a.dtype, jnp.float32)
    out = _sharded_2d(a, b, cfg, mesh)
    if out is not None:
        return out.astype(out_dtype)
    if cfg.guard is not None:
        # Guarded prepared consumption routes through the dispatcher's
        # guard seam (verification reconstructs the dense weight from
        # the prepared stack).
        from repro.kernels import dispatch
        lead = a.shape[:-1]
        out = dispatch.emulated_matmul(a.reshape(-1, a.shape[-1]), b,
                                       cfg=cfg, out_dtype=out_dtype)
        return out.reshape(*lead, b.n)
    return prepared_dot(a, b, out_dtype=out_dtype)


def dot_general(a: jax.Array, b, dimension_numbers, *,
                precision: str | EmulationConfig | None = None,
                out_dtype=None, backend: str | None = None,
                mesh=None) -> jax.Array:
    """Emulated ``jax.lax.dot_general``: any batched/multi-axis contraction.

    ``dimension_numbers`` follows the lax convention
    ``((lhs_contract, rhs_contract), (lhs_batch, rhs_batch))`` and the
    output is laid out ``(*batch, *lhs_free, *rhs_free)``. ``precision``
    is a spec string or EmulationConfig; when omitted, the ambient
    resolver decides (innermost ``repro.emulation`` scope, then the
    ``REPRO_EMULATION`` env var, then native). The contraction
    canonicalizes — transpose + reshape to (M, K) @ (K, N), vmapped over
    batch axes — onto the emulated 2-D core, which carries the custom
    VJP, so the result is differentiable under every scheme.

    ``b`` may be a :class:`repro.kernels.prepared.PreparedOperand`
    (pre-decomposed Scheme-I weight); the dimension numbers must then
    name its fixed (K, N) layout: ``(((k_axis,), (0,)), ((), ()))``.

    ``mesh`` (a concrete multi-device ``jax.sharding.Mesh`` with the
    launch layer's ``('data', 'model')`` axes) runs the fused kernels
    *per shard* under ``shard_map`` instead of handing GSPMD an
    unpartitionable kernel body: non-batched contractions partition via
    :func:`repro.parallel.shard_gemm.gemm_partition` (column-parallel
    when N divides the model axis — collective-free and bit-identical
    to the unsharded call — else K-sharded with a psum). Problems the
    partitioner cannot fit, batched contractions, and non-fused configs
    silently take the regular route; ``mesh=None`` (the default) is
    exactly the historical behavior.
    """
    cfg = resolve_config(precision)
    if backend is not None:
        cfg = dataclasses.replace(cfg, backend=backend)
    if _is_prepared(b):
        return _dot_general_prepared(a, b, dimension_numbers, cfg, out_dtype,
                                     mesh=mesh)

    lc, rc, lb, rb = _norm_dnums(dimension_numbers, a.ndim, b.ndim)
    for i, (dl, dr) in enumerate(zip(lc, rc)):
        if a.shape[dl] != b.shape[dr]:
            raise ValueError(
                f"contracting dim {i} mismatch: lhs axis {dl} has "
                f"{a.shape[dl]}, rhs axis {dr} has {b.shape[dr]}")
    for i, (dl, dr) in enumerate(zip(lb, rb)):
        if a.shape[dl] != b.shape[dr]:
            raise ValueError(
                f"batch dim {i} mismatch: lhs axis {dl} has "
                f"{a.shape[dl]}, rhs axis {dr} has {b.shape[dr]}")

    if cfg.scheme == "native":
        pet = out_dtype or cfg.out_dtype
        return jax.lax.dot_general(
            a, b, ((lc, rc), (lb, rb)),
            preferred_element_type=None if pet is None else jnp.dtype(pet))

    from repro.core.emulated import emulated_dot

    cfg2 = _with_out_dtype(cfg, out_dtype)
    a_free = tuple(d for d in range(a.ndim) if d not in lc and d not in lb)
    b_free = tuple(d for d in range(b.ndim) if d not in rc and d not in rb)
    batch_shape = tuple(a.shape[d] for d in lb)
    a_free_shape = tuple(a.shape[d] for d in a_free)
    b_free_shape = tuple(b.shape[d] for d in b_free)
    k = math.prod(a.shape[d] for d in lc)
    n = math.prod(b_free_shape)

    # Canonical layouts: lhs (batch..., free..., K), rhs (batch..., K, N).
    a_t = jnp.transpose(a, lb + a_free + lc)
    b_t = jnp.transpose(b, rb + rc + b_free)
    a2 = a_t.reshape(batch_shape + a_free_shape + (k,))
    b2 = b_t.reshape(batch_shape + (k, n))

    if not lb:
        out = _sharded_2d(a2, b2, cfg2, mesh)
        if out is None:
            out = emulated_dot(a2, b2, cfg2)
    else:
        nb = len(lb)
        a3 = a2.reshape((-1,) + a2.shape[nb:])
        b3 = b2.reshape((-1,) + b2.shape[nb:])
        from repro.kernels import dispatch as _dispatch  # lazy: pallas
        if (cfg2.impl in ("auto", "pallas")
                and _dispatch.batched_fused_eligible(a3, b3, cfg2)):
            # The canonicalized batched core: free lhs axes fold into M
            # and the whole (B, M, K) @ (B, K, N) stack runs as ONE
            # strided-batched fused launch (bit-identical to the vmap
            # lowering below; see emulated_dot_batched).
            from repro.core.emulated import emulated_dot_batched
            a4 = a3.reshape(a3.shape[0], -1, a3.shape[-1])
            out = emulated_dot_batched(a4, b3, cfg2)
        else:
            out = jax.vmap(lambda x, y: emulated_dot(x, y, cfg2))(a3, b3)
    return out.reshape(batch_shape + a_free_shape + b_free_shape)


# -- einsum -----------------------------------------------------------------

_EINSUM_HINT = ("repro.einsum covers two-operand contractions without "
                "repeated in-operand labels; use jnp.einsum for "
                "diagonals/traces and >2 operands")


def _expand_operand(part: str, ndim: int, what: str):
    """One operand's subscript -> per-axis labels ('...<i>' for ellipsis
    dims, right-aligned like numpy)."""
    if part.count(".") not in (0, 3) or (".." in part and "..." not in part):
        raise ValueError(f"bad ellipsis in {what} subscript {part!r}")
    if "..." in part:
        head, _, tail = part.partition("...")
        n_ell = ndim - len(head) - len(tail)
        if n_ell < 0:
            raise ValueError(
                f"{what} subscript {part!r} names more axes than the "
                f"rank-{ndim} operand has")
        labels = (list(head)
                  + [f"...{i}" for i in range(-n_ell, 0)]
                  + list(tail))
    else:
        if len(part) != ndim:
            raise ValueError(
                f"{what} subscript {part!r} names {len(part)} axes for a "
                f"rank-{ndim} operand")
        labels = list(part)
    for lab in labels:
        if len(lab) == 1 and not lab.isalpha():
            raise ValueError(f"bad label {lab!r} in {what} subscript "
                             f"{part!r}")
    single = [lab for lab in labels if len(lab) == 1]
    if len(set(single)) != len(single):
        raise ValueError(f"repeated label in {what} subscript {part!r}; "
                         + _EINSUM_HINT)
    return labels


def _parse_einsum(subscripts: str, a_ndim: int, b_ndim: int):
    """'bik,bkj->bij' -> (a_labels, b_labels, out_labels)."""
    s = subscripts.replace(" ", "")
    if "->" in s:
        ins, _, out = s.partition("->")
    else:
        ins, out = s, None
    parts = ins.split(",")
    if len(parts) != 2:
        raise ValueError(f"repro.einsum takes exactly two operands; got "
                         f"{len(parts)} in {subscripts!r} ({_EINSUM_HINT})")
    a_labels = _expand_operand(parts[0], a_ndim, "lhs")
    b_labels = _expand_operand(parts[1], b_ndim, "rhs")
    ell = [lab for lab in a_labels + b_labels if lab.startswith("...")]
    ell_out = sorted(set(ell), key=lambda lab: int(lab[3:]))
    if out is None:
        # numpy implicit output: ellipsis dims first, then the letters
        # appearing exactly once across both operands, alphabetically.
        letters = [lab for lab in a_labels + b_labels
                   if not lab.startswith("...")]
        once = sorted(lab for lab in set(letters)
                      if letters.count(lab) == 1)
        out_labels = ell_out + once
    else:
        if "..." in out:
            head, _, tail = out.partition("...")
            out_labels = list(head) + ell_out + list(tail)
        else:
            if ell_out:
                raise ValueError(
                    f"output subscript of {subscripts!r} drops ellipsis "
                    f"dims; {_EINSUM_HINT}")
            out_labels = list(out)
        if len(set(out_labels)) != len(out_labels):
            raise ValueError(f"repeated output label in {subscripts!r}")
        for lab in out_labels:
            if lab not in a_labels and lab not in b_labels:
                raise ValueError(f"output label {lab!r} of {subscripts!r} "
                                 "appears in neither operand")
    return a_labels, b_labels, out_labels


def einsum(subscripts: str, a: jax.Array, b, *,
           precision: str | EmulationConfig | None = None,
           out_dtype=None, backend: str | None = None,
           mesh=None) -> jax.Array:
    """Emulated two-operand ``jnp.einsum``.

    Supports batch dims, multiple contraction axes, ellipses and summed
    free axes — everything a two-operand einsum without in-operand
    repeats (diagonals) can express. The contraction lowers through
    :func:`dot_general`, so precision resolution, differentiability,
    PreparedOperand handling and the ``mesh`` shard_map pass-through are
    identical. Example::

        with repro.emulation("ozaki2-m8"):
            attn = repro.einsum("bqhd,bkhd->bhqk", q, k)
    """
    if _is_prepared(b):
        a_labels, b_labels, out_labels = _parse_einsum(subscripts, a.ndim, 2)
    else:
        a_labels, b_labels, out_labels = _parse_einsum(subscripts, a.ndim,
                                                       b.ndim)
    a_set, b_set, out_set = set(a_labels), set(b_labels), set(out_labels)

    # Sum out free axes that the output drops (e.g. 'ij,jk->k' sums i) —
    # they do not interact with the contraction.
    def presum(x, labels, other_set):
        drop = [i for i, lab in enumerate(labels)
                if lab not in other_set and lab not in out_set]
        if drop:
            x = x.sum(axis=tuple(drop))
            labels = [lab for lab in labels if lab in other_set
                      or lab in out_set]
        return x, labels

    if _is_prepared(b):
        ok = (len(b_labels) == 2
              and b_labels[0] in a_set and b_labels[0] not in out_set
              and b_labels[1] in out_set and b_labels[1] not in a_set)
        if not ok:
            raise ValueError(
                f"a prepared rhs supports only '...k,kn->...n'-shaped "
                f"subscripts (fixed (K, N) layout); got {subscripts!r}")
        a, a_labels = presum(a, a_labels, b_set)
        k_axis = a_labels.index(b_labels[0])
        dnums = (((k_axis,), (0,)), ((), ()))
        out = dot_general(a, b, dnums, precision=precision,
                          out_dtype=out_dtype, backend=backend, mesh=mesh)
        canon = [lab for lab in a_labels if lab != b_labels[0]] \
            + [b_labels[1]]
    else:
        a, a_labels = presum(a, a_labels, b_set)
        b, b_labels = presum(b, b_labels, a_set)
        shared = [lab for lab in a_labels if lab in b_labels]
        batch = [lab for lab in shared if lab in out_set]
        contract = [lab for lab in shared if lab not in out_set]
        lc = tuple(a_labels.index(lab) for lab in contract)
        rc = tuple(b_labels.index(lab) for lab in contract)
        lb = tuple(a_labels.index(lab) for lab in batch)
        rb = tuple(b_labels.index(lab) for lab in batch)
        # einsum broadcasts a size-1 dim that meets a larger dim under the
        # same label; mirror that here — dot_general stays strict like lax.
        a_shape, b_shape = list(a.shape), list(b.shape)
        for dl, dr in zip(lb + lc, rb + rc):
            if a_shape[dl] == 1 and b_shape[dr] != 1:
                a_shape[dl] = b_shape[dr]
            elif b_shape[dr] == 1 and a_shape[dl] != 1:
                b_shape[dr] = a_shape[dl]
        if a_shape != list(a.shape):
            a = jnp.broadcast_to(a, a_shape)
        if b_shape != list(b.shape):
            b = jnp.broadcast_to(b, b_shape)
        out = dot_general(a, b, ((lc, rc), (lb, rb)), precision=precision,
                          out_dtype=out_dtype, backend=backend, mesh=mesh)
        canon = batch + [lab for lab in a_labels if lab not in shared] \
            + [lab for lab in b_labels if lab not in shared]
    if canon != out_labels:
        out = jnp.transpose(out, tuple(canon.index(lab)
                                       for lab in out_labels))
    return out
