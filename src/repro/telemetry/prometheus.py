"""Prometheus text exposition of the metrics registry.

:func:`render_prometheus` emits the text format (version 0.0.4): counters
and gauges as single samples, histogram summaries as ``_count``/``_sum``/
``_min``/``_max`` samples.  :func:`serve_metrics` serves it from a stdlib
``http.server`` daemon thread on ``GET /metrics`` — wired to
``launch/serve.py --metrics-port``; ``launch/train.py`` dumps the same text
at exit.
"""

from __future__ import annotations

import http.server
import threading
from typing import Mapping

from repro.telemetry.registry import REGISTRY, MetricsRegistry

_HELP = {
    "repro_emulated_calls_total": "Emulated GEMM executions by site/scheme/backend/impl.",
    "repro_emulated_traces_total": "Emulated GEMM trace/plan events.",
    "repro_modeled_hbm_bytes_total": "Modeled fused HBM bytes (paper Eq. 10/15/18) per execution.",
    "repro_modeled_bytes_traced_total": "Modeled HBM bytes recorded at trace time, by emugemm tag.",
    "repro_modeled_collective_bytes_total": "Modeled collective bytes per device execution.",
    "repro_block_cache_total": "Block-selection cache lookups by result.",
    "repro_pad_total": "Traces that padded operands to meet backend alignment.",
    "repro_fallback_total": "Backend/impl fallback events with reasons.",
    "repro_prepared_consume_total": "Prepared-operand consume routes (fused vs xla).",
    "repro_prepared_build_total": "Prepared-operand builds/rebuilds.",
    "repro_prepared_refusal_total": "Prepared-operand layout refusals.",
    "repro_guard_events_total": "Guard ladder events (guard.stats() backing store).",
    "repro_shard_partition_total": "shard_map GEMM partition kinds chosen.",
    "repro_step_seconds": "Per-step wall-clock seconds.",
    "repro_step_tokens_per_s": "Most recent decode throughput.",
}


def _escape(value: str) -> str:
    return (
        str(value)
        .replace("\\", "\\\\")
        .replace("\n", "\\n")
        .replace('"', '\\"')
    )


def _fmt_labels(labels: Mapping[str, str]) -> str:
    if not labels:
        return ""
    inner = ",".join(
        f'{k}="{_escape(v)}"' for k, v in sorted(labels.items())
    )
    return "{" + inner + "}"


def _fmt_value(value: float) -> str:
    f = float(value)
    return str(int(f)) if f == int(f) else repr(f)


def render_prometheus(registry: MetricsRegistry = REGISTRY) -> str:
    """Render the registry in Prometheus text exposition format."""
    snap = registry.snapshot()
    lines: list[str] = []
    seen_header: set[str] = set()

    def header(name: str, mtype: str) -> None:
        if name in seen_header:
            return
        seen_header.add(name)
        help_text = _HELP.get(name, name.replace("_", " "))
        lines.append(f"# HELP {name} {help_text}")
        lines.append(f"# TYPE {name} {mtype}")

    for item in snap["counters"]:
        header(item["name"], "counter")
        lines.append(
            f"{item['name']}{_fmt_labels(item['labels'])} "
            f"{_fmt_value(item['value'])}"
        )
    for item in snap["gauges"]:
        header(item["name"], "gauge")
        lines.append(
            f"{item['name']}{_fmt_labels(item['labels'])} "
            f"{_fmt_value(item['value'])}"
        )
    for item in snap["histograms"]:
        name = item["name"]
        header(name, "summary")
        labels = _fmt_labels(item["labels"])
        lines.append(f"{name}_count{labels} {_fmt_value(item['count'])}")
        lines.append(f"{name}_sum{labels} {_fmt_value(item['sum'])}")
        lines.append(f"{name}_min{labels} {_fmt_value(item['min'])}")
        lines.append(f"{name}_max{labels} {_fmt_value(item['max'])}")
    return "\n".join(lines) + ("\n" if lines else "")


class _MetricsHandler(http.server.BaseHTTPRequestHandler):
    registry: MetricsRegistry = REGISTRY

    def do_GET(self) -> None:  # noqa: N802 (stdlib handler name)
        if self.path.split("?")[0] not in ("/", "/metrics"):
            self.send_error(404)
            return
        body = render_prometheus(self.registry).encode("utf-8")
        self.send_response(200)
        self.send_header("Content-Type",
                         "text/plain; version=0.0.4; charset=utf-8")
        self.send_header("Content-Length", str(len(body)))
        self.end_headers()
        self.wfile.write(body)

    def log_message(self, *args) -> None:  # keep serve stdout clean
        pass


class MetricsServer:
    """A daemon-threaded /metrics endpoint over the registry."""

    def __init__(self, port: int, registry: MetricsRegistry = REGISTRY) -> None:
        handler = type("Handler", (_MetricsHandler,), {"registry": registry})
        self._httpd = http.server.ThreadingHTTPServer(("", int(port)), handler)
        self.port = self._httpd.server_address[1]
        self._thread = threading.Thread(
            target=self._httpd.serve_forever, name="repro-metrics", daemon=True
        )
        self._thread.start()

    def close(self) -> None:
        self._httpd.shutdown()
        self._httpd.server_close()
        self._thread.join(timeout=5)

    def __enter__(self) -> "MetricsServer":
        return self

    def __exit__(self, *exc) -> None:
        self.close()


def serve_metrics(port: int, registry: MetricsRegistry = REGISTRY) -> MetricsServer:
    """Start serving ``GET /metrics`` on ``port`` (0 picks a free port)."""
    return MetricsServer(port, registry)
