"""repro.telemetry — per-site emulation metrics, trace annotations, sinks.

The one counter store in the process (docs/observability.md).  Hot-path
instrumentation is a strict no-op until enabled::

    import repro

    repro.telemetry.enable()                  # or REPRO_TELEMETRY=1
    with repro.telemetry.recording("steps.jsonl"):
        train(...)                            # scoped enable + JSONL sink

    print(repro.telemetry.render_prometheus())

Layers record through :mod:`repro.telemetry.record`; exports are the JSONL
step sink (:func:`jsonl_sink`, ``python -m repro.telemetry.report``) and
the Prometheus text endpoint (:func:`render_prometheus`,
:func:`serve_metrics`).
"""

from __future__ import annotations

import contextlib
from typing import Iterator

from repro.telemetry.registry import (
    REGISTRY,
    MetricsRegistry,
    disable,
    enable,
    enabled,
)
from repro.telemetry.record import (
    call_site,
    current_site,
    gemm_tag,
    mesh_label,
    modeled_gemm_bytes,
    record_collective,
    record_event,
    record_gemm,
    shape_class,
    site_scope,
)
from repro.telemetry.trace import gemm_scope
from repro.telemetry.steps import (
    JsonlSink,
    StepMetrics,
    StepTracker,
    emit,
    jsonl_sink,
)
from repro.telemetry.prometheus import (
    MetricsServer,
    render_prometheus,
    serve_metrics,
)

__all__ = [
    "REGISTRY",
    "MetricsRegistry",
    "JsonlSink",
    "MetricsServer",
    "StepMetrics",
    "StepTracker",
    "call_site",
    "current_site",
    "disable",
    "emit",
    "enable",
    "enabled",
    "gemm_scope",
    "gemm_tag",
    "jsonl_sink",
    "mesh_label",
    "modeled_gemm_bytes",
    "record_collective",
    "record_event",
    "record_gemm",
    "recording",
    "render_prometheus",
    "serve_metrics",
    "shape_class",
    "site_scope",
]


@contextlib.contextmanager
def recording(jsonl: str | None = None) -> Iterator[MetricsRegistry]:
    """Enable telemetry for the scope (optionally with a JSONL sink).

    Restores the previous enabled/disabled state on exit; a sink opened
    for ``jsonl`` is closed.  Yields the process registry so callers can
    query it inline.
    """
    was_enabled = enabled()
    enable()
    sink = jsonl_sink(jsonl) if jsonl else None
    try:
        yield REGISTRY
    finally:
        if sink is not None:
            sink.close()
        if not was_enabled:
            disable()
