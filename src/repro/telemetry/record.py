"""Instrumentation helpers: site context, labels, and recording primitives.

The label schema is fixed (docs/observability.md):

    site        logical call site ('attn', 'ffn', 'logits', 'emb', '-')
    scheme      emulation scheme ('ozaki1', 'ozaki2', 'ozaki2-3m')
    backend     kernel backend that ran ('tpu', 'gpu', 'xla')
    impl        lowering route ('pallas', 'xla', 'prepared-pallas',
                'prepared-xla')
    shape_class 'MxKxN' of the logical 2-D contraction, or 'BxMxKxN'
                when the call ran as one strided-batched launch
    mesh_shape  'axis=size,...' of the launch mesh, or '-'

Two recording moments, matching how the stack executes:

* **Trace time** (plan/route decisions, modeled bytes): recorded eagerly
  with a plain ``REGISTRY.inc`` while JAX traces — this is what compile-only
  flows (``launch.dryrun``, ``utils.perf_probe``) observe.
* **Execution time** (call counts, modeled HBM/collective bytes per run):
  staged as a ``jax.debug.callback`` with the labels captured statically in
  the closure — the same pattern ``repro.guard`` uses.  ``debug.callback``
  also runs immediately on eager calls, so one helper covers both.

Every helper is a no-op unless :func:`repro.telemetry.enabled` — checked
first, before any label work — so the disabled path stages nothing into
jaxprs and costs one global read.
"""

from __future__ import annotations

import contextlib
import functools
import threading
from typing import Any, Iterator, Mapping

from repro.telemetry import registry as _reg
from repro.telemetry.registry import REGISTRY

# Metric names (the catalog in docs/observability.md).
EMULATED_CALLS = "repro_emulated_calls_total"          # per execution
EMULATED_TRACES = "repro_emulated_traces_total"        # per trace/plan
MODELED_HBM_BYTES = "repro_modeled_hbm_bytes_total"    # per execution
MODELED_BYTES_TRACED = "repro_modeled_bytes_traced_total"  # per trace, by tag
BLOCK_CACHE = "repro_block_cache_total"                # hit/miss, per lookup
PAD_EVENTS = "repro_pad_total"                         # per padded trace
FALLBACK_EVENTS = "repro_fallback_total"               # per fallback, w/ reason
BATCHED_LAUNCHES = "repro_emulated_batched_launches_total"  # per batched trace
PREPARED_CONSUME = "repro_prepared_consume_total"      # fused vs xla routes
PREPARED_BUILD = "repro_prepared_build_total"          # prepare/rebuild calls
PREPARED_REFUSALS = "repro_prepared_refusal_total"     # layout refusals
GUARD_EVENTS = "repro_guard_events_total"              # guard.stats() backing
SHARD_PARTITION = "repro_shard_partition_total"        # partition kind chosen
MODELED_COLLECTIVE_BYTES = "repro_modeled_collective_bytes_total"
STEP_SECONDS = "repro_step_seconds"                    # histogram
STEP_TOKENS_PER_S = "repro_step_tokens_per_s"          # gauge

# Continuous-batching serve engine (repro.serving; docs/serving.md).
SERVE_QUEUE_DEPTH = "repro_serve_queue_depth"          # gauge, per step
SERVE_PAGE_OCCUPANCY = "repro_serve_page_occupancy"    # gauge, 0..1
SERVE_LANES_ACTIVE = "repro_serve_lanes_active"        # gauge, per step
SERVE_TOKENS = "repro_serve_tokens_total"              # counter, kind label
SERVE_REQUESTS = "repro_serve_requests_total"          # counter, outcome label
SERVE_EVICTIONS = "repro_serve_evictions_total"        # counter
SERVE_GUARD_TRIPS = "repro_serve_guard_trips_total"    # counter, per request
SERVE_TTFT_SECONDS = "repro_serve_ttft_seconds"        # histogram
SERVE_TPOT_SECONDS = "repro_serve_tpot_seconds"        # histogram

enabled = _reg.enabled

_tls = threading.local()


def current_site() -> str:
    """Innermost ambient call-site label, '-' when none is set."""
    stack = getattr(_tls, "sites", None)
    return stack[-1] if stack else "-"


@contextlib.contextmanager
def call_site(name: str) -> Iterator[None]:
    """Label emulated calls (traced or eager) inside the scope with ``site``."""
    stack = getattr(_tls, "sites", None)
    if stack is None:
        stack = _tls.sites = []
    stack.append(str(name))
    try:
        yield
    finally:
        stack.pop()


@contextlib.contextmanager
def site_scope(name: str) -> Iterator[None]:
    """Re-establish a previously captured site label ('-' is a no-op).

    JAX re-traces custom-VJP rules at partial-eval/transpose time (grad,
    ``jax.checkpoint``) *after* the originating ``call_site`` block has
    exited, so the rules carry the site captured at the first, in-scope
    call as a static argument and re-enter it here on every re-trace.
    """
    if name == "-":
        yield
        return
    with call_site(name):
        yield


def shape_class(m: int, k: int, n: int, batch: int | None = None) -> str:
    """'MxKxN' of the 2-D contraction; 'BxMxKxN' for a strided-batched
    launch (``batch`` is the leading grid extent, not a vmap axis)."""
    core = f"{int(m)}x{int(k)}x{int(n)}"
    return core if batch is None else f"{int(batch)}x{core}"


def mesh_label(mesh_shape: Any = None) -> str:
    """'axis=size,...' for a ``((axis, size), ...)`` tuple / mapping, or '-'."""
    if not mesh_shape:
        return "-"
    items = mesh_shape.items() if hasattr(mesh_shape, "items") else mesh_shape
    return ",".join(f"{a}={int(s)}" for a, s in items) or "-"


def gemm_tag(scheme: str, count: int, backend: str, impl: str) -> str:
    """Profiler scope tag: ``emugemm/<scheme>-<p|m><count>/<backend>/<impl>``.

    Scheme I counts mantissa slices (``p``); Scheme II counts moduli
    (``m``).  Digits are meaningful here — perf_probe's tag normalizer
    preserves them inside ``emugemm/`` scopes.
    """
    unit = "m" if scheme.startswith("ozaki2") else "p"
    return f"emugemm/{scheme}-{unit}{int(count)}/{backend}/{impl}"


def gemm_labels(
    scheme: str,
    backend: str,
    impl: str,
    m: int,
    k: int,
    n: int,
    mesh_shape: Any = None,
    batch: int | None = None,
) -> dict[str, str]:
    return {
        "site": current_site(),
        "scheme": scheme,
        "backend": backend,
        "impl": impl,
        "shape_class": shape_class(m, k, n, batch),
        "mesh_shape": mesh_label(mesh_shape),
    }


def modeled_gemm_bytes(
    scheme: str, count: int, m: int, k: int, n: int,
    out_bytes: int = 4, complex_3m: bool = False,
) -> int:
    """Modeled fused HBM bytes of one emulated GEMM (paper Eq. 10/15/18)."""
    from repro.core import traffic

    s = traffic.GemmShape(int(m), int(n), int(k))
    if scheme.startswith("ozaki2"):
        complex_3m = complex_3m or scheme == "ozaki2-3m"
        per_mod = (
            traffic.scheme2_3m_fused_bytes_per_modulus(s)
            if complex_3m
            else traffic.scheme2_fused_bytes_per_modulus(s)
        )
        n_out = 2 if complex_3m else 1
        return int(count) * per_mod + n_out * out_bytes * s.m * s.n
    mult = 4 if scheme.endswith("-4m") else 1  # Scheme-I complex: 4 GEMMs
    return mult * traffic.scheme1_fused_bytes(s, int(count), out_bytes)


def _bump_gemm(labels: Mapping[str, str], nbytes: int) -> None:
    REGISTRY.inc(EMULATED_CALLS, 1, labels)
    if nbytes:
        REGISTRY.inc(MODELED_HBM_BYTES, nbytes, labels)


def record_gemm(
    *,
    scheme: str,
    count: int,
    backend: str,
    impl: str,
    m: int,
    k: int,
    n: int,
    mesh_shape: Any = None,
    out_bytes: int = 4,
    batch: int | None = None,
) -> None:
    """Record one emulated GEMM call site.

    Bumps trace-time counters eagerly (the call is being traced or run
    right now) and stages a per-execution callback for the call/byte
    counters.  All values — labels, modeled bytes — are static per call,
    so the callback closure carries them and the device sends nothing.
    ``batch`` marks a strided-batched launch: it enters the shape class
    ('BxMxKxN') and multiplies the modeled bytes (one launch moving the
    whole stack).
    """
    if not _reg.enabled():
        return
    labels = gemm_labels(scheme, backend, impl, m, k, n, mesh_shape, batch)
    tag = gemm_tag(scheme, count, backend, impl)
    try:
        nbytes = modeled_gemm_bytes(scheme, count, m, k, n, out_bytes)
        nbytes *= batch or 1
    except Exception:
        nbytes = 0
    REGISTRY.inc(EMULATED_TRACES, 1, labels)
    if nbytes:
        REGISTRY.inc(MODELED_BYTES_TRACED, nbytes,
                     {"tag": tag, "site": labels["site"]})
    import jax

    jax.debug.callback(functools.partial(_bump_gemm, labels, nbytes))


def _bump_collective(labels: Mapping[str, str], nbytes: int) -> None:
    REGISTRY.inc(MODELED_COLLECTIVE_BYTES, nbytes, labels)


def record_collective(kind: str, mesh_shape: Any, nbytes_per_device: int) -> None:
    """Stage a per-execution modeled-collective-bytes bump.

    Called from inside a ``shard_map`` body, the callback fires once per
    shard, so the counter sums per-device bytes across the mesh.
    """
    if not _reg.enabled() or not nbytes_per_device:
        return
    labels = {
        "kind": kind,
        "mesh_shape": mesh_label(mesh_shape),
        "site": current_site(),
    }
    import jax

    jax.debug.callback(
        functools.partial(_bump_collective, labels, int(nbytes_per_device))
    )


def record_event(name: str, labels: Mapping[str, Any] | None = None,
                 value: float = 1) -> None:
    """Eager trace-time counter bump, gated on :func:`enabled`."""
    if not _reg.enabled():
        return
    REGISTRY.inc(name, value, labels)
