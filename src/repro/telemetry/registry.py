"""Process-wide metrics registry for the emulation stack.

One counter store per process: every layer that makes a routing decision
(dispatch, prepared consumption, the guard ladder, shard_gemm, the
trainer/serve loops) records into the module-level :data:`REGISTRY`.

Two kinds of state live here, with different lifecycles:

* **The registry itself is always functional.**  ``guard.stats()`` and the
  one-shot fallback-warning bookkeeping are views over it, and those must
  work whether or not the user opted into telemetry — the guard-strict CI
  row never sets ``REPRO_TELEMETRY``.
* **Hot-path instrumentation is gated on :func:`enabled`.**  When telemetry
  is off (the default), dispatch/prepared/shard call-sites do not touch the
  registry and do not stage ``jax.debug.callback`` ops into traced
  programs: jaxprs are bit-identical to a build without telemetry.

``enabled()`` is a plain module-global read so the disabled check costs one
attribute lookup.  Enable via :func:`enable`, the
:func:`~repro.telemetry.recording` scope, or ``REPRO_TELEMETRY=1`` in the
environment (read once at import).
"""

from __future__ import annotations

import dataclasses
import math
import os
import threading
from typing import Any, Iterator, Mapping

ENV_VAR = "REPRO_TELEMETRY"
_TRUTHY = ("1", "true", "yes", "on")

LabelKey = tuple[tuple[str, str], ...]


def _label_key(labels: Mapping[str, Any] | None) -> LabelKey:
    if not labels:
        return ()
    return tuple(sorted((str(k), str(v)) for k, v in labels.items()))


@dataclasses.dataclass
class HistogramSummary:
    """Streaming summary of observed values (no bucket boundaries)."""

    count: int = 0
    total: float = 0.0
    min: float = math.inf
    max: float = -math.inf

    def observe(self, value: float) -> None:
        v = float(value)
        self.count += 1
        self.total += v
        if v < self.min:
            self.min = v
        if v > self.max:
            self.max = v

    @property
    def mean(self) -> float:
        return self.total / self.count if self.count else 0.0

    def to_dict(self) -> dict[str, float]:
        return {
            "count": self.count,
            "sum": self.total,
            "min": self.min if self.count else 0.0,
            "max": self.max if self.count else 0.0,
        }


class MetricsRegistry:
    """Thread-safe store of labeled counters, gauges and histograms.

    Metric identity is ``(name, frozenset of label items)``.  Label values
    are stringified on entry so numeric and string labels compare equal in
    queries and exports.
    """

    def __init__(self) -> None:
        self._lock = threading.Lock()
        self._counters: dict[tuple[str, LabelKey], float] = {}
        self._gauges: dict[tuple[str, LabelKey], float] = {}
        self._histograms: dict[tuple[str, LabelKey], HistogramSummary] = {}
        self._once: set[Any] = set()

    # ------------------------------------------------------------------
    # recording
    # ------------------------------------------------------------------
    def inc(
        self,
        name: str,
        value: float = 1,
        labels: Mapping[str, Any] | None = None,
    ) -> None:
        key = (name, _label_key(labels))
        with self._lock:
            self._counters[key] = self._counters.get(key, 0.0) + float(value)

    def set_gauge(
        self,
        name: str,
        value: float,
        labels: Mapping[str, Any] | None = None,
    ) -> None:
        key = (name, _label_key(labels))
        with self._lock:
            self._gauges[key] = float(value)

    def observe(
        self,
        name: str,
        value: float,
        labels: Mapping[str, Any] | None = None,
    ) -> None:
        key = (name, _label_key(labels))
        with self._lock:
            hist = self._histograms.get(key)
            if hist is None:
                hist = self._histograms[key] = HistogramSummary()
            hist.observe(value)

    # ------------------------------------------------------------------
    # one-shot bookkeeping (always active; backs _warn_fallback_once)
    # ------------------------------------------------------------------
    def once(self, key: Any) -> bool:
        """True the first time ``key`` is seen, False afterwards."""
        with self._lock:
            if key in self._once:
                return False
            self._once.add(key)
            return True

    def forget_once(self, prefix: Any = None) -> None:
        """Drop one-shot keys; tuple keys matching ``prefix[0]`` only, or all."""
        with self._lock:
            if prefix is None:
                self._once.clear()
            else:
                self._once = {
                    k
                    for k in self._once
                    if not (isinstance(k, tuple) and k and k[0] == prefix)
                }

    # ------------------------------------------------------------------
    # queries
    # ------------------------------------------------------------------
    def _matches(self, key: LabelKey, where: Mapping[str, Any]) -> bool:
        if not where:
            return True
        d = dict(key)
        return all(d.get(str(k)) == str(v) for k, v in where.items())

    def total(self, name: str, **where: Any) -> float:
        """Sum of all counter series named ``name`` whose labels match."""
        with self._lock:
            return sum(
                v
                for (n, lk), v in self._counters.items()
                if n == name and self._matches(lk, where)
            )

    def counters(
        self, name: str | None = None, **where: Any
    ) -> dict[tuple[str, LabelKey], float]:
        with self._lock:
            return {
                (n, lk): v
                for (n, lk), v in self._counters.items()
                if (name is None or n == name) and self._matches(lk, where)
            }

    def series(self, name: str, **where: Any) -> Iterator[tuple[dict[str, str], float]]:
        for (_, lk), v in sorted(self.counters(name, **where).items()):
            yield dict(lk), v

    def snapshot(self) -> dict[str, Any]:
        """Deep copy of all metric state, JSON-friendly."""
        with self._lock:
            return {
                "counters": [
                    {"name": n, "labels": dict(lk), "value": v}
                    for (n, lk), v in sorted(self._counters.items())
                ],
                "gauges": [
                    {"name": n, "labels": dict(lk), "value": v}
                    for (n, lk), v in sorted(self._gauges.items())
                ],
                "histograms": [
                    {"name": n, "labels": dict(lk), **h.to_dict()}
                    for (n, lk), h in sorted(self._histograms.items())
                ],
            }

    def counter_snapshot(self) -> dict[tuple[str, LabelKey], float]:
        with self._lock:
            return dict(self._counters)

    # ------------------------------------------------------------------
    # lifecycle
    # ------------------------------------------------------------------
    def clear(self, name_prefix: str | None = None) -> None:
        """Drop metric series; only those whose name starts with the prefix
        when one is given.  One-shot keys are untouched (see forget_once)."""
        with self._lock:
            if name_prefix is None:
                self._counters.clear()
                self._gauges.clear()
                self._histograms.clear()
                return
            for store in (self._counters, self._gauges, self._histograms):
                for key in [k for k in store if k[0].startswith(name_prefix)]:
                    del store[key]


#: The process-wide registry every instrumented layer records into.
REGISTRY = MetricsRegistry()

_enabled = os.environ.get(ENV_VAR, "").strip().lower() in _TRUTHY


def enabled() -> bool:
    """Whether hot-path telemetry instrumentation is active."""
    return _enabled


def enable() -> None:
    global _enabled
    _enabled = True


def disable() -> None:
    global _enabled
    _enabled = False
