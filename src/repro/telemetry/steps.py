"""Per-step metrics records and the JSONL event sink.

``StepTracker`` snapshots the registry's counters and, once per step,
derives a :class:`StepMetrics` record from the *deltas* since the previous
step — emulated-call counts, modeled HBM/collective bytes, cache hit
ratios, guard/retry deltas — alongside wall-clock step time and tokens/s.
Trainer, serve engine and dryrun each write one JSONL record per
step/request/cell through :func:`jsonl_sink`;
``python -m repro.telemetry.report`` aggregates the file back into the
per-site table.
"""

from __future__ import annotations

import dataclasses
import json
import threading
from typing import Any, IO

from repro.telemetry import record as _rec
from repro.telemetry.registry import REGISTRY, LabelKey, MetricsRegistry

RECORD_VERSION = "repro.telemetry/v1"


@dataclasses.dataclass
class StepMetrics:
    """One JSONL record: a step's wall-clock + registry deltas."""

    step: int
    kind: str = "step"  # 'train' | 'serve' | 'cell' | 'step'
    seconds: float = 0.0
    tokens_per_s: float | None = None
    loss: float | None = None
    emulated_calls: float = 0.0
    modeled_hbm_bytes: float = 0.0
    modeled_collective_bytes: float = 0.0
    block_cache_hit_ratio: float | None = None
    prepared_hit_ratio: float | None = None
    guard: dict[str, float] = dataclasses.field(default_factory=dict)
    counters: list[dict[str, Any]] = dataclasses.field(default_factory=list)
    extra: dict[str, Any] = dataclasses.field(default_factory=dict)

    def to_json(self) -> dict[str, Any]:
        d = dataclasses.asdict(self)
        d["record"] = RECORD_VERSION
        return d


class JsonlSink:
    """Append-mode JSONL writer; registered as a process-default sink."""

    def __init__(self, path: str, register: bool = True) -> None:
        self.path = str(path)
        self._lock = threading.Lock()
        self._fh: IO[str] | None = open(self.path, "a", encoding="utf-8")
        if register:
            _SINKS.append(self)

    def write(self, record: StepMetrics | dict[str, Any]) -> None:
        payload = record.to_json() if isinstance(record, StepMetrics) else dict(record)
        line = json.dumps(payload, sort_keys=True, default=str)
        with self._lock:
            if self._fh is None:
                return
            self._fh.write(line + "\n")
            self._fh.flush()

    def close(self) -> None:
        with self._lock:
            if self._fh is not None:
                self._fh.close()
                self._fh = None
        if self in _SINKS:
            _SINKS.remove(self)

    def __enter__(self) -> "JsonlSink":
        return self

    def __exit__(self, *exc: Any) -> None:
        self.close()


_SINKS: list[JsonlSink] = []


def jsonl_sink(path: str) -> JsonlSink:
    """Open ``path`` for appending and register it as a step-record sink."""
    return JsonlSink(path)


def emit(record: StepMetrics | dict[str, Any]) -> None:
    """Write a record to every registered sink."""
    for sink in list(_SINKS):
        sink.write(record)


def _delta(
    new: dict[tuple[str, LabelKey], float],
    old: dict[tuple[str, LabelKey], float],
) -> dict[tuple[str, LabelKey], float]:
    out: dict[tuple[str, LabelKey], float] = {}
    for key, value in new.items():
        d = value - old.get(key, 0.0)
        if d:
            out[key] = d
    return out


def _sum(deltas: dict[tuple[str, LabelKey], float], name: str,
         **where: str) -> float:
    total = 0.0
    for (n, lk), v in deltas.items():
        if n != name:
            continue
        d = dict(lk)
        if all(d.get(k) == str(val) for k, val in where.items()):
            total += v
    return total


def _ratio(hit: float, miss: float) -> float | None:
    total = hit + miss
    return hit / total if total else None


class StepTracker:
    """Derives per-step :class:`StepMetrics` from registry counter deltas."""

    def __init__(self, registry: MetricsRegistry = REGISTRY) -> None:
        self._registry = registry
        self._last = registry.counter_snapshot()

    def step_metrics(
        self,
        step: int,
        seconds: float,
        *,
        kind: str = "step",
        tokens: int | None = None,
        loss: float | None = None,
        extra: dict[str, Any] | None = None,
        write: bool = True,
    ) -> StepMetrics:
        now = self._registry.counter_snapshot()
        deltas = _delta(now, self._last)
        self._last = now

        guard = {}
        for (n, lk), v in deltas.items():
            if n == _rec.GUARD_EVENTS:
                event = dict(lk).get("event", "?")
                guard[event] = guard.get(event, 0.0) + v

        metrics = StepMetrics(
            step=int(step),
            kind=kind,
            seconds=float(seconds),
            tokens_per_s=(tokens / seconds if tokens and seconds > 0 else None),
            loss=loss,
            emulated_calls=_sum(deltas, _rec.EMULATED_CALLS),
            modeled_hbm_bytes=_sum(deltas, _rec.MODELED_HBM_BYTES),
            modeled_collective_bytes=_sum(deltas, _rec.MODELED_COLLECTIVE_BYTES),
            block_cache_hit_ratio=_ratio(
                _sum(deltas, _rec.BLOCK_CACHE, result="hit"),
                _sum(deltas, _rec.BLOCK_CACHE, result="miss"),
            ),
            prepared_hit_ratio=_ratio(
                _sum(deltas, _rec.PREPARED_CONSUME, route="fused"),
                _sum(deltas, _rec.PREPARED_CONSUME, route="xla"),
            ),
            guard=guard,
            counters=[
                {"name": n, "labels": dict(lk), "value": v}
                for (n, lk), v in sorted(deltas.items())
            ],
            extra=dict(extra or {}),
        )
        self._registry.observe(_rec.STEP_SECONDS, metrics.seconds,
                               {"kind": kind})
        if metrics.tokens_per_s is not None:
            self._registry.set_gauge(_rec.STEP_TOKENS_PER_S,
                                     metrics.tokens_per_s, {"kind": kind})
        if write:
            emit(metrics)
        return metrics
