"""Profiler trace annotations for emulation sites.

Every fused kernel launch and XLA-expansion site runs under a
``jax.named_scope`` named ``emugemm/<scheme>-<p|m><count>/<backend>/<impl>``.
The scope becomes part of the ``op_name`` metadata XLA attaches to every op
lowered inside it, so profiler timelines and compiled-HLO dumps attribute
time/bytes per emulation site — ``utils.perf_probe --by-emulation-site``
groups on exactly these tags.

Scopes are pure trace metadata: they change no numerics and cost nothing at
run time, so they are applied unconditionally (not gated on
``telemetry.enabled()``).
"""

from __future__ import annotations

from typing import ContextManager

from repro.telemetry.record import gemm_tag


def gemm_scope(scheme: str, count: int, backend: str, impl: str) -> ContextManager[None]:
    """``jax.named_scope`` for one emulated-GEMM lowering site."""
    import jax

    return jax.named_scope(gemm_tag(scheme, count, backend, impl))
