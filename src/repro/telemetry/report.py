"""Aggregate a telemetry JSONL file into per-site tables.

Usage::

    python -m repro.telemetry.report steps.jsonl [--json]

Reads the step records the trainer/serve engine/dryrun wrote through
``telemetry.jsonl_sink`` and prints (a) a run summary (steps, mean step
time, tokens/s) and (b) the per-site table: one row per
(site, scheme, backend, impl) with call counts, modeled GB, and the
cache/guard/fallback counters attributed to it.
"""

from __future__ import annotations

import argparse
import json
import sys
from typing import Any, Iterable

from repro.telemetry import record as _rec

SITE_KEY = ("site", "scheme", "backend", "impl")


def load(path: str) -> list[dict[str, Any]]:
    records = []
    with open(path, "r", encoding="utf-8") as fh:
        for line in fh:
            line = line.strip()
            if line:
                records.append(json.loads(line))
    return records


def aggregate(records: Iterable[dict[str, Any]]) -> dict[str, Any]:
    """Fold step records into a run summary + per-site rows."""
    steps = 0
    seconds = 0.0
    tokens_rates: list[float] = []
    kinds: dict[str, int] = {}
    sites: dict[tuple[str, ...], dict[str, float]] = {}
    guard: dict[str, float] = {}
    fallbacks: dict[str, float] = {}
    cache = {"hit": 0.0, "miss": 0.0}
    prepared = {"fused": 0.0, "xla": 0.0}
    collective_bytes = 0.0

    for rec in records:
        steps += 1
        seconds += float(rec.get("seconds") or 0.0)
        kinds[rec.get("kind", "step")] = kinds.get(rec.get("kind", "step"), 0) + 1
        if rec.get("tokens_per_s"):
            tokens_rates.append(float(rec["tokens_per_s"]))
        for g, v in (rec.get("guard") or {}).items():
            guard[g] = guard.get(g, 0.0) + float(v)
        for item in rec.get("counters") or []:
            name = item.get("name")
            labels = item.get("labels") or {}
            value = float(item.get("value") or 0.0)
            if name in (_rec.EMULATED_CALLS, _rec.EMULATED_TRACES,
                        _rec.MODELED_HBM_BYTES):
                key = tuple(labels.get(k, "-") for k in SITE_KEY)
                row = sites.setdefault(
                    key, {"calls": 0.0, "traces": 0.0, "hbm_bytes": 0.0})
                if name == _rec.EMULATED_CALLS:
                    row["calls"] += value
                elif name == _rec.EMULATED_TRACES:
                    row["traces"] += value
                else:
                    row["hbm_bytes"] += value
            elif name == _rec.BLOCK_CACHE:
                result = labels.get("result", "miss")
                cache[result] = cache.get(result, 0.0) + value
            elif name == _rec.PREPARED_CONSUME:
                route = labels.get("route", "xla")
                prepared[route] = prepared.get(route, 0.0) + value
            elif name == _rec.FALLBACK_EVENTS:
                reason = labels.get("reason", "?")
                fallbacks[reason] = fallbacks.get(reason, 0.0) + value
            elif name == _rec.MODELED_COLLECTIVE_BYTES:
                collective_bytes += value

    return {
        "steps": steps,
        "kinds": kinds,
        "total_seconds": seconds,
        "mean_step_seconds": seconds / steps if steps else 0.0,
        "mean_tokens_per_s": (
            sum(tokens_rates) / len(tokens_rates) if tokens_rates else None
        ),
        "sites": [
            {
                "site": key[0], "scheme": key[1],
                "backend": key[2], "impl": key[3],
                **row,
            }
            for key, row in sorted(sites.items())
        ],
        "block_cache": cache,
        "prepared": prepared,
        "guard": guard,
        "fallbacks": fallbacks,
        "modeled_collective_bytes": collective_bytes,
    }


def _gb(nbytes: float) -> str:
    return f"{nbytes / 1e9:.3f}"


def render(summary: dict[str, Any]) -> str:
    lines = []
    lines.append(
        f"steps={summary['steps']} "
        f"total_s={summary['total_seconds']:.3f} "
        f"mean_step_s={summary['mean_step_seconds']:.4f} "
        + (
            f"mean_tokens_per_s={summary['mean_tokens_per_s']:.1f}"
            if summary["mean_tokens_per_s"] is not None
            else "mean_tokens_per_s=-"
        )
    )
    header = (
        f"{'site':>8} {'scheme':>10} {'backend':>8} {'impl':>14} "
        f"{'calls':>8} {'traces':>7} {'modeled_GB':>11}"
    )
    lines.append(header)
    lines.append("-" * len(header))
    for row in summary["sites"]:
        lines.append(
            f"{row['site']:>8} {row['scheme']:>10} {row['backend']:>8} "
            f"{row['impl']:>14} {row['calls']:>8.0f} {row['traces']:>7.0f} "
            f"{_gb(row['hbm_bytes']):>11}"
        )
    if not summary["sites"]:
        lines.append("(no emulated-call records — was REPRO_TELEMETRY=1 set?)")
    cache = summary["block_cache"]
    total = cache.get("hit", 0) + cache.get("miss", 0)
    lines.append(
        f"block_cache: hit={cache.get('hit', 0):.0f} "
        f"miss={cache.get('miss', 0):.0f} "
        f"ratio={cache.get('hit', 0) / total if total else 0:.3f}"
    )
    prep = summary["prepared"]
    lines.append(
        f"prepared_consume: fused={prep.get('fused', 0):.0f} "
        f"xla={prep.get('xla', 0):.0f}"
    )
    if summary["guard"]:
        lines.append(
            "guard: "
            + " ".join(f"{k}={v:.0f}" for k, v in sorted(summary["guard"].items()))
        )
    if summary["fallbacks"]:
        lines.append(
            "fallbacks: "
            + " ".join(
                f"{k}={v:.0f}" for k, v in sorted(summary["fallbacks"].items())
            )
        )
    if summary["modeled_collective_bytes"]:
        lines.append(
            f"modeled_collective_GB={_gb(summary['modeled_collective_bytes'])}"
        )
    return "\n".join(lines)


def main(argv: list[str] | None = None) -> int:
    parser = argparse.ArgumentParser(
        prog="python -m repro.telemetry.report", description=__doc__
    )
    parser.add_argument("jsonl", help="telemetry JSONL file to aggregate")
    parser.add_argument(
        "--json", action="store_true", help="emit the aggregate as JSON"
    )
    args = parser.parse_args(argv)
    summary = aggregate(load(args.jsonl))
    if args.json:
        print(json.dumps(summary, indent=2, sort_keys=True))
    else:
        print(render(summary))
    return 0


if __name__ == "__main__":
    sys.exit(main())
