"""Render dry-run JSON results into the EXPERIMENTS.md markdown tables."""

import json
import sys


def render(path, mesh_filter=None):
    rows = json.load(open(path))
    out = []
    out.append("| arch | shape | mesh | compute s | memory s | collective s"
               " | bottleneck | rf | useful | args GB/dev |")
    out.append("|---|---|---|---|---|---|---|---|---|---|")
    for r in sorted(rows, key=lambda r: (r["arch"], r["shape"], r["mesh"])):
        if mesh_filter and r["mesh"] != mesh_filter:
            continue
        t = r["roofline"]
        peak = max(t["compute_s"], t["memory_s"], t["collective_s"])
        rf = t["compute_s"] / peak if peak else 0
        mem = (r["memory"]["argument_bytes"] or 0) / 1e9
        out.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} "
            f"| {t['compute_s']:.4f} | {t['memory_s']:.4f} "
            f"| {t['collective_s']:.4f} | {t['bottleneck']} "
            f"| {rf:.1%} | {r['useful_flops_ratio']:.2f} | {mem:.2f} |")
    return "\n".join(out)


if __name__ == "__main__":
    print(render(sys.argv[1], sys.argv[2] if len(sys.argv) > 2 else None))
