"""Roofline-term extraction from compiled HLO.

``jax``'s ``compiled.cost_analysis()`` reports *per-device* numbers and
counts ``while`` bodies (lax.scan layers, microbatch loops) **once**, so a
scanned 61-layer model would look 61x cheaper than it is. This module
re-derives trip-count-correct per-device terms by walking the compiled HLO
text:

  * builds the computation call graph (fusion ``calls=``, ``to_apply=``,
    ``while`` bodies/conditions, conditional branches),
  * scales every computation's contribution by the product of enclosing
    ``while`` trip counts (read from the ``known_trip_count`` backend
    config the XLA scheduler attaches),
  * FLOPs: 2 * prod(result_dims) * prod(contracting_dims) per ``dot``
    (operand shapes resolved through a per-computation symbol table),
  * collective bytes: result-shape bytes of every all-gather / all-reduce
    (x2: reduce-scatter + all-gather phases of a ring) / reduce-scatter /
    all-to-all / collective-permute,
  * memory bytes: op-boundary traffic (result + operand bytes of
    non-trivial top-level ops) — a standard proxy for HBM traffic given
    fusion boundaries.

Hardware model (TPU v5e class, per chip): 197 TFLOP/s bf16, 819 GB/s HBM,
~50 GB/s/link ICI.
"""

from __future__ import annotations

import dataclasses
import json
import math
import re

PEAK_FLOPS = 197e12          # bf16 per chip
PEAK_INT8_OPS = 394e12       # int8 per chip (2x bf16)
HBM_BW = 819e9               # bytes/s per chip
ICI_BW = 50e9                # bytes/s per link

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "s32": 4, "u32": 4,
    "s64": 8, "u64": 8, "f8e4m3fn": 1, "f8e5m2": 1, "bf16": 2, "f16": 2,
    "f32": 4, "f64": 8, "c64": 8, "c128": 16,
}

_SHAPE_RE = re.compile(r"\b([a-z]\d*[a-z]*\d*)\[([\d,]*)\]")
_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")
_SKIP_OPS = {"parameter", "constant", "tuple", "get-tuple-element",
             "bitcast", "after-all", "partition-id", "replica-id", "iota"}


def _shape_bytes(dtype: str, dims: str) -> int:
    nbytes = _DTYPE_BYTES.get(dtype)
    if nbytes is None:
        return 0
    if not dims:
        return nbytes
    return nbytes * math.prod(int(d) for d in dims.split(",") if d)


def _all_shape_bytes(segment: str) -> int:
    return sum(_shape_bytes(d, s) for d, s in _SHAPE_RE.findall(segment))


def _shape_dims(segment: str):
    m = _SHAPE_RE.search(segment)
    if not m:
        return None
    return [int(d) for d in m.group(2).split(",") if d]


_NAME_RE = re.compile(r"%([\w\.\-]+)")


def _operand_dims(operands: str, index: int, symtab: dict):
    """Dims of the ``index``-th operand in an op's argument list.

    Prefers the inline operand types modern HLO prints
    (``dot(f32[8,64]{1,0} %lhs, ...)``); name-only lists resolve through
    the per-computation symbol table."""
    shapes = _SHAPE_RE.findall(operands)
    names = _NAME_RE.findall(operands)
    if len(shapes) > index and len(shapes) >= len(names):
        return [int(d) for d in shapes[index][1].split(",") if d]
    if len(names) > index:
        t = symtab.get(names[index])
        if t:
            return _shape_dims(t)
    return None


def _operand_bytes(operands: str, symtab: dict) -> int:
    """Total byte size of every operand in an op's argument list."""
    shapes = _SHAPE_RE.findall(operands)
    if shapes:
        return sum(_shape_bytes(d, s) for d, s in shapes)
    total = 0
    for name in _NAME_RE.findall(operands):
        t = symtab.get(name)
        if t:
            total += _all_shape_bytes(t)
    return total


@dataclasses.dataclass
class _Comp:
    flops: float = 0.0
    coll_bytes: float = 0.0
    mem_bytes: float = 0.0
    # (child_name, multiplier, kind); kind in {fusion, apply, while, branch}
    calls: list = dataclasses.field(default_factory=list)


# Memory traffic only flows through control-flow edges: fusion internals
# live in registers (the fusion op's own result is counted at its call
# site), and to_apply computations are scalar reducers.
_MEM_EDGE_KINDS = {"while", "branch"}


_OP_RE = re.compile(r"^\s*(?:ROOT\s+)?%([\w\.\-]+)\s*=\s*(\([^)]*\)|\S+)\s+"
                    r"([\w\-]+)\(")
_HDR_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w\.\-]+)\s*\(.*\)\s*->.*\{")


def parse_hlo(text: str) -> dict:
    """Parse compiled HLO into per-computation stats + call graph."""
    comps: dict[str, _Comp] = {}
    entry = None
    cur = None
    symtab: dict[str, str] = {}

    for line in text.splitlines():
        hdr = _HDR_RE.match(line)
        if hdr:
            cur = hdr.group(1)
            comps[cur] = _Comp()
            symtab = {}
            if line.startswith("ENTRY"):
                entry = cur
            continue
        if cur is None:
            continue
        m = _OP_RE.match(line)
        if not m:
            continue
        name, rtype, opcode = m.groups()
        symtab[name] = rtype
        comp = comps[cur]

        trip = 1.0
        tm = re.search(r'"known_trip_count":\{"n":"(\d+)"', line)
        if tm:
            trip = float(tm.group(1))

        # call graph edges
        for pat, mult, kind in (
                (r"calls=%?([\w\.\-]+)", 1.0, "fusion"),
                (r"to_apply=%?([\w\.\-]+)", 1.0, "apply"),
                (r"body=%?([\w\.\-]+)", trip, "while"),
                (r"condition=%?([\w\.\-]+)", trip, "while"),
                (r"true_computation=%?([\w\.\-]+)", 1.0, "branch"),
                (r"false_computation=%?([\w\.\-]+)", 1.0, "branch")):
            for g in re.finditer(pat, line):
                comp.calls.append((g.group(1), mult, kind))
        bm = re.search(r"branch_computations=\{([^}]*)\}", line)
        if bm:
            for b in bm.group(1).split(","):
                comp.calls.append((b.strip().lstrip("%"), 1.0, "branch"))

        if opcode in _COLLECTIVES:
            factor = 2.0 if opcode == "all-reduce" else 1.0
            comp.coll_bytes += factor * _all_shape_bytes(rtype)

        if opcode == "dot":
            # 2 * prod(result_dims) * prod(contracting_dims). The lhs shape
            # is read from the inline operand type (modern HLO prints
            # `dot(f32[8,64] %lhs, ...)`; splitting the operand list on
            # bare commas would truncate it at `f32[8`), falling back to
            # the symbol table for name-only operand lists.
            dims = _shape_dims(rtype) or []
            out = math.prod(dims) if dims else 1
            ops = re.search(r"dot\(([^)]*)\)", line)
            kprod = 1
            cdims = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", line)
            if ops and cdims:
                ldims = _operand_dims(ops.group(1), 0, symtab)
                for ci in cdims.group(1).split(","):
                    if ci and ldims and int(ci) < len(ldims):
                        kprod *= ldims[int(ci)]
            comp.flops += 2.0 * out * kprod

        if opcode not in _SKIP_OPS:
            # HBM-traffic proxy: every produced tensor is written once and
            # read once downstream (2x result bytes); dots additionally read
            # their operands (weight streams), and custom-calls (the lowered
            # Pallas kernels — the fused emulated GEMMs and the
            # decompose/prepare passes) likewise stream every operand from
            # HBM exactly once, so the decomposition-side saving of the
            # in-kernel prologue (int8 slice intermediates never written)
            # is visible in dry-run mem_bytes rather than hidden inside an
            # opaque call.
            bytes_ = 2 * _all_shape_bytes(rtype)
            if opcode in ("dot", "custom-call"):
                ops = re.search(opcode + r"\(([^)]*)\)", line)
                if ops:
                    bytes_ += _operand_bytes(ops.group(1), symtab)
            comp.mem_bytes += bytes_

    return {"comps": comps, "entry": entry}


def _total(comps: dict, name: str, field: str, memo: dict) -> float:
    key = (name, field)
    if key in memo:
        return memo[key]
    memo[key] = 0.0  # break cycles defensively
    c = comps.get(name)
    if c is None:
        return 0.0
    total = getattr(c, field)
    for child, mult, kind in c.calls:
        if field == "mem_bytes" and kind not in _MEM_EDGE_KINDS:
            continue
        total += mult * _total(comps, child, field, memo)
    memo[key] = total
    return total


def analyze_hlo(text: str) -> dict:
    """Trip-count-correct per-device {flops, coll_bytes, mem_bytes}."""
    g = parse_hlo(text)
    memo: dict = {}
    entry = g["entry"]
    return {
        "flops": _total(g["comps"], entry, "flops", memo),
        "coll_bytes": _total(g["comps"], entry, "coll_bytes", memo),
        "mem_bytes": _total(g["comps"], entry, "mem_bytes", memo),
    }


def roofline_terms(per_device_flops: float, per_device_mem_bytes: float,
                   per_device_coll_bytes: float) -> dict:
    """The three §Roofline terms, in seconds (per step)."""
    t_compute = per_device_flops / PEAK_FLOPS
    t_memory = per_device_mem_bytes / HBM_BW
    t_coll = per_device_coll_bytes / ICI_BW
    terms = {"compute_s": t_compute, "memory_s": t_memory,
             "collective_s": t_coll}
    dom = max(terms, key=terms.get)
    terms["bottleneck"] = dom.replace("_s", "")
    total = max(t_compute, t_memory, t_coll)
    terms["roofline_fraction_compute"] = t_compute / total if total else 0.0
    return terms


def projected_throughput(m: int, k: int, n: int, p: int,
                         scheme: str = "ozaki1", backend: str = "gpu",
                         out_bytes: int = 4,
                         complex_3m: bool = False) -> dict:
    """Roofline-projected Top/s of one fused emulated GEMM, per hardware
    peak of the selected kernel backend (paper Fig. 4/5 framing: fraction
    of INT8 Tensor Core peak).

    Uses the analytical fused-traffic models (Eq. 10 / Eq. 15 / Eq. 18)
    and the per-backend peak tables in ``repro.core.traffic
    .BACKEND_PEAKS`` — for the 'gpu' backend that means both the Hopper
    (H100) and Blackwell (B200) entries, so reports show projections for
    both generations alongside the TPU accounting.

    On hardware with a native FP64 rate each entry also carries the
    paper's headline framing: ``baseline_speedup`` — projected fused
    time vs an FP64 BLAS baseline (``zgemm`` for ``complex_3m``, else
    ``dgemm``) of the same logical GEMM at that hardware's FP64 peak
    (the 2.3x-over-cuBLAS-ZGEMM-on-Hopper number of Sec. V).
    """
    from repro.core import traffic as T
    s = T.GemmShape(m, n, k)
    if scheme == "ozaki1":
        flops = T.scheme1_flops(s, p)
        bytes_ = T.scheme1_fused_bytes(s, p, out_bytes)
    elif scheme == "ozaki2":
        flops = T.scheme2_flops(s, p, complex_3m=complex_3m)
        per_mod = (T.scheme2_3m_fused_bytes_per_modulus(s) if complex_3m
                   else T.scheme2_fused_bytes_per_modulus(s))
        n_out = 2 if complex_3m else 1
        bytes_ = p * per_mod + n_out * out_bytes * s.m * s.n
    else:
        raise ValueError(f"no projection for scheme {scheme!r}")
    # FP64 BLAS baseline of the same logical GEMM: ZGEMM does 8 real
    # flops per complex MAC over complex128 operands, DGEMM 2 over f64.
    if complex_3m:
        base_name, base_flops, elem = "zgemm", 8 * s.m * s.n * s.k, 16
    else:
        base_name, base_flops, elem = "dgemm", 2 * s.m * s.n * s.k, 8
    base_bytes = elem * ((s.m + s.n) * s.k + s.m * s.n)
    out = {"backend": backend, "scheme": scheme,
           "int8_flops": float(flops), "traffic_bytes": float(bytes_),
           "hardware": {}}
    for key, peak in T.backend_peaks(backend).items():
        t_c = flops / peak.int8_ops
        t_m = bytes_ / peak.hbm_bw
        t = max(t_c, t_m)
        cell = {
            "name": peak.name,
            "peak_int8_tops": peak.int8_ops / 1e12,
            "projected_tops": flops / t / 1e12 if t else 0.0,
            "fraction_of_peak": (flops / t) / peak.int8_ops if t else 0.0,
            "bound": "compute" if t_c >= t_m else "memory",
        }
        if peak.fp64_flops and t:
            t_base = max(base_flops / peak.fp64_flops,
                         base_bytes / peak.hbm_bw)
            cell["fp64_baseline"] = base_name
            cell["baseline_speedup"] = t_base / t
        out["hardware"][key] = cell
    return out


def batched_projected_throughput(m: int, k: int, n: int, batch: int, p: int,
                                 scheme: str = "ozaki1",
                                 backend: str = "gpu",
                                 out_bytes: int = 4) -> dict:
    """Roofline projection of one strided-batched emulated GEMM stack,
    fused single-launch vs the vmapped 2-D fallback.

    Uses the batched traffic models (``repro.core.traffic
    .scheme{1,2}_batched_bytes``): the compute side is identical on both
    routes (B x the per-element int8 flops), so the projected columns
    differ only by the decomposition-byte term — which is exactly what
    the batched bench cells gate.  Per hardware entry the cell carries
    ``fused_projected_tops`` / ``vmap_projected_tops`` and their ratio
    ``projected_speedup``.
    """
    from repro.core import traffic as T
    s = T.GemmShape(m, n, k)
    if scheme == "ozaki1":
        model = T.scheme1_batched_bytes(s, p, batch, out_bytes)
        flops = batch * T.scheme1_flops(s, p)
    elif scheme == "ozaki2":
        model = T.scheme2_batched_bytes(s, p, batch, out_bytes)
        flops = batch * T.scheme2_flops(s, p)
    else:
        raise ValueError(f"no batched projection for scheme {scheme!r}")
    out = {"backend": backend, "scheme": scheme, "batch": int(batch),
           "int8_flops": float(flops), "paths": model, "hardware": {}}
    for key, peak in T.backend_peaks(backend).items():
        cell = {"name": peak.name}
        for path in ("fused", "vmap"):
            t = max(flops / peak.int8_ops,
                    model[path]["total_bytes"] / peak.hbm_bw)
            cell[f"{path}_projected_tops"] = flops / t / 1e12 if t else 0.0
        vm = cell["vmap_projected_tops"]
        cell["projected_speedup"] = (
            cell["fused_projected_tops"] / vm if vm else 0.0)
        out["hardware"][key] = cell
    return out


def sharded_projected_throughput(m: int, k: int, n: int, p: int,
                                 mesh_shape,
                                 partition: str = "column",
                                 scheme: str = "ozaki1",
                                 backend: str = "gpu", out_bytes: int = 4,
                                 complex_3m: bool = False) -> dict:
    """Roofline projection of one shard_map'ed emulated GEMM: per-shard
    fused Top/s next to the interconnect bytes the mesh adds.

    ``mesh_shape`` / ``partition`` follow ``repro.core.traffic
    .sharded_gemm_traffic``: the fused-traffic models are evaluated on
    the shard-local (m, n, k) — each device runs exactly the
    single-device fused kernel on its slice — and the collective cost
    (zero for the column/batch layouts, a ring all-reduce of the output
    partials for row) is reported side by side in bytes and seconds at
    ``ICI_BW``.  Each hardware entry carries the per-shard projection
    plus an ``effective_tops`` that charges the collective time against
    the shard's useful int8 flops, so column vs row layouts compare
    directly.
    """
    from repro.core import traffic as T
    cell = T.sharded_gemm_traffic(
        T.GemmShape(m, n, k), p, mesh_shape, partition,
        scheme=scheme, out_bytes=out_bytes, complex_3m=complex_3m)
    shard = projected_throughput(
        cell["shard_m"], cell["shard_k"], cell["shard_n"], p,
        scheme=scheme, backend=backend, out_bytes=out_bytes,
        complex_3m=complex_3m)
    coll_bytes = cell["collective_bytes_per_device"]
    coll_s = coll_bytes / ICI_BW
    out = {
        "backend": backend, "scheme": scheme, "partition": partition,
        "devices": cell["devices"],
        "shard_shape": (cell["shard_m"], cell["shard_k"], cell["shard_n"]),
        "fused_bytes_per_shard": cell["fused_bytes_per_shard"],
        "int8_flops_per_shard": cell["int8_flops_per_shard"],
        "collective_bytes_per_device": coll_bytes,
        "collective_s": coll_s,
        "hardware": {},
    }
    flops = cell["int8_flops_per_shard"]
    for key, hw in shard["hardware"].items():
        t_shard = flops / hw["projected_tops"] / 1e12 \
            if hw["projected_tops"] else 0.0
        t_total = t_shard + coll_s
        out["hardware"][key] = {
            "name": hw["name"],
            "peak_int8_tops": hw["peak_int8_tops"],
            "shard_projected_tops": hw["projected_tops"],
            "effective_tops": flops / t_total / 1e12 if t_total else 0.0,
            "bound": ("collective" if coll_s > t_shard else hw["bound"]),
        }
    return out


def scheme1_decomposition_terms(m: int, k: int, n: int, p: int,
                                uses: int = 3) -> dict:
    """Decomposition-side HBM bytes (and seconds at HBM_BW) for one
    emulated (M, K) @ (K, N) weight GEMM per training step, under the
    three Scheme-I data paths (repro.core.traffic counting):

      xla      — split -> interleave -> kernel, re-decomposed ``uses``
                 times (forward, remat re-forward, backward B^T),
      prologue — in-kernel VMEM slicing, only the scale pass and the
                 fp32 operand stream touch HBM,
      prepared — one dual-layout prep per step, reused by every use.

    Both operands count for xla/prologue (each call decomposes lhs and
    rhs); 'prepared' preps only the rhs — its lhs (the activation) still
    runs the prologue.
    """
    from repro.core import traffic as T
    lhs, rhs = m * k, k * n
    out = {}
    out["xla_bytes"] = T.scheme1_decomp_xla_bytes(lhs + rhs, p, uses)
    out["prologue_bytes"] = T.scheme1_decomp_prologue_bytes(lhs + rhs, p,
                                                            uses)
    out["prepared_bytes"] = (T.scheme1_decomp_prologue_bytes(lhs, p, uses)
                             + T.scheme1_decomp_prepared_bytes(rhs, p, 1))
    for key in ("xla", "prologue", "prepared"):
        out[f"{key}_s"] = out[f"{key}_bytes"] / HBM_BW
    return out


def scheme2_decomposition_terms(m: int, k: int, n: int, p: int,
                                uses: int = 3,
                                complex_3m: bool = False) -> dict:
    """Residue-side HBM bytes (and seconds at HBM_BW) for one emulated
    Scheme-II (M, K) @ (K, N) GEMM per training step, under the three
    residue data paths (repro.core.traffic counting):

      xla      — encode both operands + round-trip the (p, M, N) int32
                 accumulators and canonical residues through HBM into
                 the CRT, re-paid ``uses`` times,
      fused    — the gpu backend's fused residue pipeline: only the
                 scale pass and the fp32 operand stream touch HBM,
      prepared — one rhs residue encode per step (PreparedResidues),
                 reused by every use; the lhs still runs the prologue.
    """
    from repro.core import traffic as T
    s = T.GemmShape(m, n, k)
    out = {
        "xla_bytes": T.scheme2_decomp_xla_bytes(s, p, uses, complex_3m),
        "prologue_bytes": T.scheme2_decomp_prologue_bytes(
            s, p, uses, complex_3m),
        "prepared_bytes": T.scheme2_decomp_prepared_bytes(
            s, p, uses, 1, complex_3m),
    }
    for key in ("xla", "prologue", "prepared"):
        out[f"{key}_s"] = out[f"{key}_bytes"] / HBM_BW
    return out


# ---------------------------------------------------------------------------
# Analytic model FLOPs (6ND / 6 N_active D), for the 'useful compute' ratio.
# ---------------------------------------------------------------------------

def model_flops(arch, shape, params_total: int, params_routed: int) -> float:
    """MODEL_FLOPS for one step of this (arch, shape) cell, global."""
    m = arch.model
    active = params_total - params_routed
    if m.moe is not None:
        per_expert = params_routed // max(1, _n_routed(arch))
        active += per_expert * m.moe.top_k * m.n_layers
    tokens = shape.global_batch * (shape.seq_len if shape.kind != "decode"
                                   else 1)
    mult = 6.0 if shape.kind == "train" else 2.0
    return mult * active * tokens


def _n_routed(arch) -> int:
    from repro.models.moe import padded_experts
    return padded_experts(arch.model.moe) * arch.model.n_layers


def routed_param_count(params) -> int:
    """Total parameters in routed-expert tensors (3-D leaves under 'moe')."""
    import jax
    flat = jax.tree_util.tree_flatten_with_path(params)[0]
    total = 0
    for kp, leaf in flat:
        keys = [getattr(k, "key", None) for k in kp]
        if "moe" in keys and leaf.ndim >= 3:
            total += math.prod(leaf.shape)
    return total
