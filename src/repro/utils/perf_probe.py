"""Hillclimb profiling aid: attribute collective/memory bytes in a
compiled dry-run cell to model regions via op_name metadata.

  PYTHONPATH=src python -m repro.utils.perf_probe --arch deepseek-coder-33b \
      --shape train_4k
"""

import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=512")

import argparse
import collections
import math
import re

import jax

from repro import configs
from repro.launch import steps as S
from repro.launch.mesh import make_production_mesh
from repro import api
from repro.models.common import GemmPolicy
from repro.utils import roofline


def compile_cell(arch_id, shape_name, gemm="native", multi=False):
    arch = configs.get_config(arch_id)
    shape = [s for s in arch.shapes() if s.name == shape_name][0]
    mesh = make_production_mesh(multi_pod=multi)
    policy = GemmPolicy(default=api.precision(gemm))
    with mesh:
        if shape.kind == "train":
            step = S.make_train_step(arch, mesh, shape, policy, donate=False)
            state = {"params": S.abstract_params(arch)}
            state["opt"] = S.abstract_opt(arch, state["params"])
            return step.lower(state, arch.input_specs(shape)).compile()
        if shape.kind == "prefill":
            step = S.make_prefill_step(arch, shape, mesh, policy)
            return step.lower(S.abstract_params(arch),
                              arch.input_specs(shape)).compile()
        step = S.make_decode_step(arch, shape, mesh, policy, donate=False)
        cache = S.abstract_cache(arch, shape.global_batch, shape.seq_len)
        return step.lower(S.abstract_params(arch), cache,
                          arch.input_specs(shape)["tokens"], 0).compile()


def attribute(txt, top=20):
    """Collective bytes per (opcode, op_name tag), trip-count scaled."""
    g = roofline.parse_hlo(txt)
    comps = g["comps"]
    mult = {g["entry"]: 1.0}
    order = [g["entry"]]
    i = 0
    while i < len(order):
        n = order[i]
        i += 1
        for child, m, kind in comps[n].calls:
            if child in comps:
                mult[child] = mult.get(child, 0.0) + mult[n] * m
                if child not in order:
                    order.append(child)
    # per-line attribution pass
    hdr = re.compile(r"^(?:ENTRY\s+)?%?([\w\.\-]+)\s*\(.*\)\s*->.*\{")
    rows = collections.Counter()
    cur = None
    for line in txt.splitlines():
        h = hdr.match(line)
        if h:
            cur = h.group(1)
            continue
        m = re.match(r"^\s*(?:ROOT\s+)?%[\w\.\-]+\s*=\s*(\([^)]*\)|\S+)\s+"
                     r"([\w\-]+)\(", line)
        if not m or cur not in mult:
            continue
        rtype, opcode = m.groups()
        if opcode not in roofline._COLLECTIVES:
            continue
        nbytes = roofline._all_shape_bytes(rtype) * mult.get(cur, 0.0)
        if opcode == "all-reduce":
            nbytes *= 2
        meta = re.search(r'op_name="([^"]+)"', line)
        tag = meta.group(1) if meta else "?"
        tag = re.sub(r"\[[^\]]*\]|\d+", "", tag)[:110]
        rows[(opcode, tag)] += nbytes
    return rows.most_common(top)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True)
    ap.add_argument("--gemm", default="native")
    ap.add_argument("--top", type=int, default=20)
    args = ap.parse_args()
    compiled = compile_cell(args.arch, args.shape, args.gemm)
    txt = compiled.as_text()
    total = roofline.analyze_hlo(txt)
    print(f"flops/dev {total['flops']:.3e}  mem {total['mem_bytes']/1e9:.1f}GB"
          f"  coll {total['coll_bytes']/1e9:.1f}GB")
    for (opcode, tag), b in attribute(txt, args.top):
        print(f"{b/1e9:10.1f} GB  {opcode:20s} {tag}")


if __name__ == "__main__":
    main()
