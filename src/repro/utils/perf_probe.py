"""Hillclimb profiling aid: attribute collective/memory bytes in a
compiled dry-run cell to model regions via op_name metadata.

  PYTHONPATH=src python -m repro.utils.perf_probe --arch deepseek-coder-33b \
      --shape train_4k
"""

import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=512")

import argparse
import collections
import math
import re

import jax

from repro import configs
from repro.launch import steps as S
from repro.launch.mesh import make_production_mesh
from repro import api
from repro.models.common import GemmPolicy
from repro.utils import roofline


def compile_cell(arch_id, shape_name, gemm=None, multi=False):
    arch = configs.get_config(arch_id)
    shape = [s for s in arch.shapes() if s.name == shape_name][0]
    mesh = make_production_mesh(multi_pod=multi)
    # An explicit --gemm wins; otherwise the arch's own gemm_sites table
    # (the -emu zoo variants) decides, which for plain archs is an empty
    # policy that defers to the ambient resolver (native by default).
    policy = (GemmPolicy(default=api.precision(gemm)) if gemm
              else arch.gemm_policy())
    with mesh:
        if shape.kind == "train":
            step = S.make_train_step(arch, mesh, shape, policy, donate=False)
            state = {"params": S.abstract_params(arch)}
            state["opt"] = S.abstract_opt(arch, state["params"])
            return step.lower(state, arch.input_specs(shape)).compile()
        if shape.kind == "prefill":
            step = S.make_prefill_step(arch, shape, mesh, policy)
            return step.lower(S.abstract_params(arch),
                              arch.input_specs(shape)).compile()
        step = S.make_decode_step(arch, shape, mesh, policy, donate=False)
        cache = S.abstract_cache(arch, shape.global_batch, shape.seq_len)
        return step.lower(S.abstract_params(arch), cache,
                          arch.input_specs(shape)["tokens"], 0).compile()


# Telemetry scope tags carry load-bearing digits (emugemm/ozaki1-p4/...):
# the generic digit-stripping normalization below must not turn them into
# the ambiguous "emugemm/ozaki-p/...".
_EMUTAG_RE = re.compile(r"emugemm/[^/\s\"(),]+/[^/\s\"(),]+/[^/\s\"(),]+")
_HDR_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w\.\-]+)\s*\(.*\)\s*->.*\{")


def normalize_tag(tag):
    """Collapse shape suffixes / layer indices so per-layer duplicates
    fold into one row, while preserving the digits inside emugemm scope
    tags (p-count, moduli count — `ozaki1-p4` vs `ozaki1-p3` are
    different kernels, not different layers)."""
    m = _EMUTAG_RE.search(tag)
    strip = lambda s: re.sub(r"\[[^\]]*\]|\d+", "", s)
    if m is None:
        return strip(tag)[:110]
    return (strip(tag[:m.start()]) + m.group(0)
            + strip(tag[m.end():]))[:110]


def _comp_multipliers(g):
    """Trip-count multiplier of every computation reachable from entry."""
    comps = g["comps"]
    mult = {g["entry"]: 1.0}
    order = [g["entry"]]
    i = 0
    while i < len(order):
        n = order[i]
        i += 1
        for child, m, kind in comps[n].calls:
            if child in comps:
                mult[child] = mult.get(child, 0.0) + mult[n] * m
                if child not in order:
                    order.append(child)
    return mult


def attribute(txt, top=20):
    """Collective bytes per (opcode, op_name tag), trip-count scaled."""
    mult = _comp_multipliers(roofline.parse_hlo(txt))
    # per-line attribution pass
    rows = collections.Counter()
    cur = None
    for line in txt.splitlines():
        h = _HDR_RE.match(line)
        if h:
            cur = h.group(1)
            continue
        m = re.match(r"^\s*(?:ROOT\s+)?%[\w\.\-]+\s*=\s*(\([^)]*\)|\S+)\s+"
                     r"([\w\-]+)\(", line)
        if not m or cur not in mult:
            continue
        rtype, opcode = m.groups()
        if opcode not in roofline._COLLECTIVES:
            continue
        nbytes = roofline._all_shape_bytes(rtype) * mult.get(cur, 0.0)
        if opcode == "all-reduce":
            nbytes *= 2
        meta = re.search(r'op_name="([^"]+)"', line)
        tag = normalize_tag(meta.group(1)) if meta else "?"
        rows[(opcode, tag)] += nbytes
    return rows.most_common(top)


def attribute_emulation(txt):
    """HBM-proxy and collective bytes per emugemm scope tag.

    Walks the compiled HLO once, mirrors roofline.parse_hlo's memory
    accounting (2x result bytes per non-trivial op, plus operand bytes
    for dot/custom-call), and credits each op whose op_name metadata
    carries an ``emugemm/<scheme>-<pN|mN>/<backend>/<impl>`` scope to
    that tag, trip-count scaled.  Returns
    {tag: {"mem_bytes": float, "coll_bytes": float, "ops": int}}.
    """
    mult = _comp_multipliers(roofline.parse_hlo(txt))
    out = {}
    cur = None
    symtab = {}
    for line in txt.splitlines():
        h = _HDR_RE.match(line)
        if h:
            cur = h.group(1)
            symtab = {}
            continue
        m = re.match(r"^\s*(?:ROOT\s+)?%([\w\.\-]+)\s*=\s*"
                     r"(\([^)]*\)|\S+)\s+([\w\-]+)\(", line)
        if not m:
            continue
        name, rtype, opcode = m.groups()
        symtab[name] = rtype
        if cur not in mult:
            continue
        meta = re.search(r'op_name="([^"]+)"', line)
        if not meta:
            continue
        emu = _EMUTAG_RE.search(meta.group(1))
        if not emu:
            continue
        tag = emu.group(0)
        scale = mult.get(cur, 0.0)
        row = out.setdefault(tag, {"mem_bytes": 0.0, "coll_bytes": 0.0,
                                   "ops": 0})
        row["ops"] += 1
        if opcode in roofline._COLLECTIVES:
            factor = 2.0 if opcode == "all-reduce" else 1.0
            row["coll_bytes"] += \
                factor * roofline._all_shape_bytes(rtype) * scale
        if opcode not in roofline._SKIP_OPS:
            nbytes = 2 * roofline._all_shape_bytes(rtype)
            if opcode in ("dot", "custom-call"):
                ops = re.search(opcode + r"\(([^)]*)\)", line)
                if ops:
                    nbytes += roofline._operand_bytes(ops.group(1), symtab)
            row["mem_bytes"] += nbytes * scale
    return out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True)
    ap.add_argument("--gemm", default=None,
                    help="precision spec override; omitted, the arch "
                         "config's gemm_sites table decides (native for "
                         "plain archs)")
    ap.add_argument("--top", type=int, default=20)
    ap.add_argument("--by-emulation-site", action="store_true",
                    help="group attributed HLO bytes on emugemm scope "
                         "tags, next to the analytic traffic model's "
                         "modeled bytes for the same tags (telemetry is "
                         "enabled for the compile)")
    args = ap.parse_args()
    before = {}
    if args.by_emulation_site:
        from repro import telemetry
        from repro.telemetry import record as _tele
        telemetry.enable()
        for labels, v in telemetry.REGISTRY.series(
                _tele.MODELED_BYTES_TRACED):
            key = (labels.get("tag", "?"), labels.get("site", "-"))
            before[key] = before.get(key, 0.0) + v
    compiled = compile_cell(args.arch, args.shape, args.gemm)
    txt = compiled.as_text()
    total = roofline.analyze_hlo(txt)
    print(f"flops/dev {total['flops']:.3e}  mem {total['mem_bytes']/1e9:.1f}GB"
          f"  coll {total['coll_bytes']/1e9:.1f}GB")
    if args.by_emulation_site:
        # Modeled bytes: the per-(tag, site) analytic fused-traffic
        # counters the trace just recorded (delta against pre-existing
        # state).  The site comes from telemetry.call_site scopes — the
        # model-zoo einsum sites (attn_qk, attn_av, moe_gate,
        # moe_expert, mla_latent, ssd_state) plus the launcher's dense
        # projections — so one emugemm tag fans out into per-site rows.
        modeled = {}
        for labels, v in telemetry.REGISTRY.series(
                _tele.MODELED_BYTES_TRACED):
            key = (labels.get("tag", "?"), labels.get("site", "-"))
            modeled[key] = modeled.get(key, 0.0) + v
        modeled = {k: v - before.get(k, 0.0) for k, v in modeled.items()
                   if v - before.get(k, 0.0) > 0}
        attributed = attribute_emulation(txt)
        tags = sorted({t for t, _ in modeled} | set(attributed))
        if not tags:
            print("no emugemm scopes in this cell (gemm=native?)")
        else:
            # HLO op_name scope tags carry no site segment, so the hlo
            # columns are per-tag totals printed on the tag's first row.
            print(f"{'modeled GB':>12} {'hlo mem GB':>12} "
                  f"{'hlo coll GB':>12}  {'site':<12} tag")
            for tag in tags:
                a = attributed.get(tag, {})
                sites = sorted(s for t, s in modeled if t == tag) or ["-"]
                for i, site in enumerate(sites):
                    hlo_mem = a.get("mem_bytes", 0.0) if i == 0 else 0.0
                    hlo_coll = a.get("coll_bytes", 0.0) if i == 0 else 0.0
                    print(f"{modeled.get((tag, site), 0.0)/1e9:12.3f} "
                          f"{hlo_mem/1e9:12.3f} "
                          f"{hlo_coll/1e9:12.3f}  {site:<12} {tag}")
        return
    for (opcode, tag), b in attribute(txt, args.top):
        print(f"{b/1e9:10.1f} GB  {opcode:20s} {tag}")


if __name__ == "__main__":
    main()
