"""Atomic, shardable, mesh-elastic checkpoints.

Layout:  <dir>/step_<N>/arrays.npz + manifest.json   (+ tmp dirs during
writes, renamed atomically on completion). Arrays are stored *logically*
(unsharded) keyed by their pytree path, so a checkpoint written on a
(16,16) mesh restores onto (2,16,16) — or a single CPU — unchanged:
``restore`` re-device_puts every leaf under the target sharding
(elastic re-mesh). Saves can run on a background thread off the step
path (async checkpointing); the previous save is joined before a new one
starts and on ``close``.
"""

from __future__ import annotations

import json
import os
import shutil
import threading

import jax
import numpy as np


def _flatten(tree):
    flat = jax.tree_util.tree_flatten_with_path(tree)
    out = {}
    for kp, leaf in flat[0]:
        key = "/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                       for k in kp)
        out[key] = leaf
    return out, flat[1]


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3,
                 async_save: bool = True):
        self.dir = directory
        self.keep = keep
        self.async_save = async_save
        self._thread: threading.Thread | None = None
        os.makedirs(directory, exist_ok=True)

    # -- save ---------------------------------------------------------------

    def save(self, step: int, state) -> None:
        self.wait()
        flat, _ = _flatten(state)
        # Snapshot to host memory synchronously (cheap vs the write), so
        # the training step can continue while the file IO happens async.
        arrays = {k: np.asarray(v) for k, v in flat.items()}

        def write():
            tmp = os.path.join(self.dir, f".tmp_step_{step}")
            final = os.path.join(self.dir, f"step_{step:08d}")
            os.makedirs(tmp, exist_ok=True)
            np.savez(os.path.join(tmp, "arrays.npz"), **arrays)
            with open(os.path.join(tmp, "manifest.json"), "w") as f:
                json.dump({"step": step, "keys": sorted(arrays)}, f)
            if os.path.exists(final):
                shutil.rmtree(final)
            os.rename(tmp, final)   # atomic publish
            self._gc()

        if self.async_save:
            self._thread = threading.Thread(target=write, daemon=True)
            self._thread.start()
        else:
            write()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _gc(self) -> None:
        steps = self.all_steps()
        for s in steps[:-self.keep]:
            shutil.rmtree(os.path.join(self.dir, f"step_{s:08d}"),
                          ignore_errors=True)

    # -- restore ------------------------------------------------------------

    def all_steps(self) -> list[int]:
        out = []
        for name in os.listdir(self.dir):
            if name.startswith("step_"):
                if os.path.exists(os.path.join(self.dir, name,
                                               "manifest.json")):
                    out.append(int(name[5:]))
        return sorted(out)

    def latest_step(self) -> int | None:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(self, step: int, like, shardings=None):
        """Restore into the structure of ``like`` (arrays or
        ShapeDtypeStructs); ``shardings`` optionally re-shards every leaf
        onto a (possibly different) mesh — the elastic path."""
        path = os.path.join(self.dir, f"step_{step:08d}", "arrays.npz")
        data = np.load(path)
        flat, treedef = _flatten(like)
        sflat = _flatten(shardings)[0] if shardings is not None else {}
        leaves = []
        for key in flat:
            arr = data[key]
            if key in sflat:
                arr = jax.device_put(arr, sflat[key])
            leaves.append(arr)
        return jax.tree_util.tree_unflatten(treedef, leaves)

    def close(self) -> None:
        self.wait()
