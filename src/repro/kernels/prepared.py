"""PreparedOperand: a pre-decomposed Scheme-I rhs, reused across GEMMs.

The Scheme-I pipeline re-decomposes the *same weight matrix* on every
emulated call: forward, the remat re-forward, and the backward
dA = dC @ B^T (which splits B^T from scratch) each pay the full
scale-read + split + interleave round-trips — 3x per layer per step in
training, and once per decode step in serving.  A ``PreparedOperand``
holds the finished artifact instead:

  * ``slices``  — the p int8 slices, interleaved ((p*K, N), paper Eq. 11)
                  for the fused kernels or stacked ((p, K, N)) for the XLA
                  expansion,
  * ``scale``   — the (1, N) power-of-two column scale,
  * ``beta``/``p`` and ``blocks`` (the interleave granularity lives in
    ``blocks.bk``),
  * ``twin``    — the same weight prepared in the K-transposed rhs layout
                  of B^T, consumed by the backward dA GEMM.

``prepare_rhs`` builds one with a *single fp32 read* of the weight (the
``decompose_interleave_pair`` kernel emits both layouts in one pass);
``matmul_prepared`` consumes it through the mixed fused kernel (fp32 lhs
decomposed in-VMEM, prepared int8 rhs streamed).  Traffic accounting:
``repro.core.traffic.scheme1_decomp_prepared_bytes``.

Plumbing: ``dispatch.emulated_matmul`` accepts a PreparedOperand rhs,
``core.emulated.emulated_dot`` prepares weights once per step when
``cfg.cache_weights`` is set, and ``prepare_params`` wraps a model's
projection weights for once-per-session serving reuse
(``launch/serve.py --prepare``).
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.core import scheme1
from repro.core.precision import EmulationConfig
from repro.kernels.common import Blocks


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class PreparedOperand:
    """A pre-split, pre-interleaved Scheme-I rhs operand (see module doc).

    ``k``/``n`` are the *unpadded* logical dims; ``slices`` and ``scale``
    are 128-aligned.  ``layout`` is 'interleaved' ((p*Kp, Np) int8, fused
    kernels) or 'stacked' ((p, Kp, Np) int8, XLA expansion).
    """
    slices: jax.Array
    scale: jax.Array
    p: int
    beta: int
    blocks: Blocks | None
    layout: str
    k: int
    n: int
    twin: "PreparedOperand | None" = None

    @property
    def padded_k(self) -> int:
        if self.layout == "interleaved":
            return self.slices.shape[0] // self.p
        return self.slices.shape[1]

    @property
    def padded_n(self) -> int:
        return self.slices.shape[-1]

    def stacked(self) -> jax.Array:
        """The (p, Kp, Np) slice stack, deinterleaving if needed."""
        if self.layout == "stacked":
            return self.slices
        return scheme1.deinterleave_k(self.slices, self.p, "b",
                                      self.blocks.bk)

    def tree_flatten(self):
        return ((self.slices, self.scale, self.twin),
                (self.p, self.beta, self.blocks, self.layout,
                 self.k, self.n))

    @classmethod
    def tree_unflatten(cls, aux, children):
        slices, scale, twin = children
        p, beta, blocks, layout, k, n = aux
        return cls(slices, scale, p, beta, blocks, layout, k, n, twin)


def _pad2(x: jax.Array, align: int = 128) -> jax.Array:
    from repro.kernels.dispatch import round_up
    k, n = x.shape
    kp, np_ = round_up(k, align), round_up(n, align)
    if (kp, np_) == (k, n):
        return x
    return jnp.pad(x, ((0, kp - k), (0, np_ - n)))


def _use_kernel(cfg: EmulationConfig) -> bool:
    return cfg.impl in ("auto", "pallas") and cfg.decomp != "xla"


def prepare_rhs(b: jax.Array, cfg: EmulationConfig, *,
                with_twin: bool = False,
                m_hint: int = 512) -> PreparedOperand:
    """Decompose a (K, N) float rhs once, for reuse across GEMMs.

    With ``with_twin`` the K-transposed layout for the backward dA GEMM is
    produced too; when forward and backward share p, both layouts come out
    of one fp32 read (the pair kernel).  ``m_hint`` sizes the lhs the
    block search assumes — consumers re-select with the granularity
    pinned, so only bK must be right.
    """
    if isinstance(b, PreparedOperand):
        return b
    if b.ndim != 2:
        raise ValueError(f"prepare_rhs is 2-D; got {b.shape}")
    if jnp.issubdtype(b.dtype, jnp.complexfloating):
        raise ValueError("prepare_rhs is real-valued; decompose the real "
                         "and imaginary parts separately (4M formulation)")
    from repro.kernels import decompose, dispatch

    k, n = b.shape
    if not jnp.issubdtype(b.dtype, jnp.floating):
        b = b.astype(jnp.float32)
    b_pad = _pad2(b)
    kp, np_ = b_pad.shape
    p = cfg.p
    beta = cfg.resolved_beta(kp)
    nu = scheme1._pow2_row_scale(b_pad, axis=0)          # (1, Np)

    p_bwd = cfg.bwd_p or p
    beta_bwd = cfg.resolved_beta(np_)

    if not _use_kernel(cfg):
        slices, _ = scheme1.split(b_pad, p, beta, axis=0)
        twin = None
        if with_twin:
            t_slices, tau = scheme1.split(b_pad.T, p_bwd, beta_bwd, axis=0)
            twin = PreparedOperand(t_slices, tau, p_bwd, beta_bwd, None,
                                   "stacked", n, k)
        return PreparedOperand(slices, nu, p, beta, None, "stacked",
                               k, n, twin)

    blocks = dispatch.select_blocks(m_hint, np_, kp, p, backend="tpu",
                                    prologue_a=True)
    if blocks is None:
        blocks = Blocks(128, 128, 128)
    if with_twin:
        t_blocks = dispatch.select_blocks(m_hint, kp, np_, p_bwd,
                                          backend="tpu", prologue_a=True)
        if t_blocks is None:
            t_blocks = Blocks(128, 128, 128)
        tau = scheme1._pow2_row_scale(b_pad.T, axis=0)   # (1, Kp)
        if p_bwd == p:
            # One fp32 read of B emits both layouts.
            hat, t_hat = decompose.decompose_interleave_pair(
                b_pad, nu, tau, p, beta, beta_bwd,
                bk=blocks.bk, bt=t_blocks.bk)
        else:
            hat = decompose.decompose_interleave_rhs(b_pad, nu, p, beta,
                                                     bk=blocks.bk)
            t_hat = decompose.decompose_interleave_rhs(
                b_pad.T, tau, p_bwd, beta_bwd, bk=t_blocks.bk)
        twin = PreparedOperand(t_hat, tau, p_bwd, beta_bwd, t_blocks,
                               "interleaved", n, k)
        return PreparedOperand(hat, nu, p, beta, blocks, "interleaved",
                               k, n, twin)
    hat = decompose.decompose_interleave_rhs(b_pad, nu, p, beta,
                                             bk=blocks.bk)
    return PreparedOperand(hat, nu, p, beta, blocks, "interleaved", k, n)


def matmul_prepared(a: jax.Array, prep: PreparedOperand,
                    out_dtype=jnp.float32) -> jax.Array:
    """(M, K) float @ prepared (K, N) -> (M, N) ``out_dtype``.

    The lhs decomposes in the kernel prologue (interleaved layout) or via
    ``scheme1.split`` (stacked layout); the rhs slices are reused as-is.
    Non-aligned lhs rows/K are zero-padded and the result sliced back.
    """
    from repro.kernels import dispatch, ozaki1

    m, k = a.shape
    if k != prep.k:
        raise ValueError(f"lhs K={k} vs prepared K={prep.k}")
    if jnp.issubdtype(a.dtype, jnp.complexfloating):
        # A silent float32 cast would drop the imaginary half; complex
        # problems must go through the 4M expansion on real parts.
        raise ValueError("matmul_prepared is real-valued; got complex lhs "
                         f"{a.dtype}")
    kp, np_ = prep.padded_k, prep.padded_n
    mp = dispatch.round_up(m)
    if (mp, kp) != (m, k):
        a = jnp.pad(a, ((0, mp - m), (0, kp - k)))
    if not jnp.issubdtype(a.dtype, jnp.floating):
        a = a.astype(jnp.float32)

    if prep.layout == "interleaved":
        blocks = dispatch.select_blocks(
            mp, np_, kp, prep.p, out_bytes=jnp.dtype(out_dtype).itemsize,
            backend="tpu", prologue_a=True, fixed_bk=prep.blocks.bk)
        if blocks is not None:
            mu = scheme1._pow2_row_scale(a, axis=1)      # (Mp, 1)
            out = ozaki1.fused_matmul_mixed(
                a, prep.slices, mu.astype(jnp.float32),
                prep.scale.astype(jnp.float32), prep.p, prep.beta, blocks,
                out_dtype=out_dtype)
            return out[:m, :prep.n]

    # XLA expansion from the stored slices (stacked layout, or no block
    # fit at the pinned granularity).
    a_sl, mu = scheme1.split(a, prep.p, prep.beta, axis=1)
    accs = scheme1.triangular_accumulators(a_sl, prep.stacked(), prep.p)
    out = scheme1.shift_reduce(accs, prep.beta, mu, prep.scale, out_dtype)
    return out[:m, :prep.n]


# ---------------------------------------------------------------------------
# Once-per-step preparation under gradient accumulation.
# ---------------------------------------------------------------------------

@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class StepPrepared:
    """A float weight paired with its once-per-step PreparedOperand.

    Built *outside* the microbatch scan by ``build_step_preps`` and
    attached to the params tree by ``attach_step_preps``: the scan body
    then closes over the finished slices (a loop-invariant constant of
    the compiled while loop), so every microbatch streams them instead
    of re-running the prep — the decomposition executes once per
    optimizer step, not once per microbatch.  ``w`` stays the
    differentiable leaf: ``emulated_dot_prepared`` (repro.core.emulated)
    computes the forward from ``prep`` and routes dB to ``w``.
    """
    w: jax.Array
    prep: PreparedOperand

    def tree_flatten(self):
        return ((self.w, self.prep), None)

    @classmethod
    def tree_unflatten(cls, aux, children):
        del aux
        return cls(*children)


def _site_of(path, site_default: str = "ffn") -> str:
    keys = [getattr(kp, "key", None) for kp in path]
    if "mixer" in keys:
        return "attn"
    if "head" in keys or "emb" in keys:
        return "logits"
    return site_default


def _step_cacheable(cfg) -> bool:
    return cfg.scheme == "ozaki1" and cfg.cache_weights


def policy_caches_weights(policy) -> bool:
    """Does any call-site family of this GemmPolicy cache weights?

    An unset (None) default defers to the ambient resolver, exactly as
    ``for_site`` would; launch callers run ``dispatch.resolve_policy``
    first, which materializes the ambient config into ``default``.
    """
    sites = [policy.default] + [cfg for _, cfg in policy.overrides]
    if policy.default is None:
        from repro import api
        sites[0] = api.resolve_config()
    return any(_step_cacheable(cfg) for cfg in sites)


def _path_key(path) -> str:
    return "/".join(str(getattr(kp, "key", kp)) for kp in path)


def _stack_preps(preps: list) -> PreparedOperand:
    """Stack per-layer PreparedOperands along a new leading axis.

    The static aux (p, beta, blocks, layout) is shape-derived and thus
    identical across layers; stacking only the array leaves yields a
    pytree ``jax.lax.scan`` slices back into per-layer operands."""
    return jax.tree.map(lambda *xs: jnp.stack(xs), *preps)


def build_step_preps(params, policy, *, site_default: str = "ffn",
                     names=None) -> dict:
    """Prepare every cacheable dense weight once, keyed by tree path.

    Returns {path: PreparedOperand (with twin)} for the float leaves in
    ``names`` whose site config caches weights.  Scan-stacked layer
    groups (3-D leaves under 'layers') are prepared per layer and
    re-stacked, so the model's layer scan slices finished slices instead
    of re-splitting each layer's weight inside the microbatch scan.
    """
    if names is None:
        names = DENSE_WEIGHT_NAMES
    preps: dict = {}

    def visit(path, leaf):
        name = getattr(path[-1], "key", None) if path else None
        keys = {getattr(kp, "key", None) for kp in path}
        ndim = getattr(leaf, "ndim", 0)
        stacked = ndim == 3 and "layers" in keys
        # MoE expert tensors reuse dense names but are consumed through
        # raw einsums (and carry an expert axis) — never prepped.
        if (name not in names or "moe" in keys or not (ndim == 2 or stacked)
                or not jnp.issubdtype(leaf.dtype, jnp.floating)):
            return leaf
        cfg = policy.for_site(_site_of(path, site_default))
        if not _step_cacheable(cfg):
            return leaf
        if stacked:
            preps[_path_key(path)] = _stack_preps(
                [prepare_rhs(leaf[g], cfg, with_twin=True)
                 for g in range(leaf.shape[0])])
        else:
            preps[_path_key(path)] = prepare_rhs(leaf, cfg, with_twin=True)
        return leaf

    jax.tree_util.tree_map_with_path(visit, params)
    return preps


def attach_step_preps(params, preps: dict):
    """Swap each prepared weight leaf for a StepPrepared(w, prep) pair."""
    if not preps:
        return params

    def wrap(path, leaf):
        prep = preps.get(_path_key(path))
        return StepPrepared(leaf, prep) if prep is not None else leaf

    return jax.tree_util.tree_map_with_path(wrap, params)


# ---------------------------------------------------------------------------
# Whole-model preparation (once-per-session serving reuse).
# ---------------------------------------------------------------------------

# Projection-weight leaf names consumed via models.common.dense — the only
# places a PreparedOperand rhs is legal.  Deliberately excludes lookalikes
# used through raw einsums (w_r/w_i of RG-LRU, wkv_b of MLA, moe experts,
# frontend_proj) and the tied-embedding table.
DENSE_WEIGHT_NAMES = frozenset({
    "wq", "wk", "wv", "wo", "wq_a", "wq_b", "wkv_a",
    "wi", "wi_gate", "wi_up", "w_y", "w_gate", "w_out", "w_in",
    "head",
})


def prepare_params(params, policy, *, site_default: str = "ffn",
                   names=DENSE_WEIGHT_NAMES):
    """Wrap a model's 2-D dense projection weights as PreparedOperands.

    Run once per serve session (outside jit): every subsequent prefill /
    decode step streams the finished int8 slices instead of re-splitting
    the weight.  Leaves under vmap/scan-stacked layer groups are 3-D and
    pass through untouched (their per-layer slices are decomposed by the
    per-step cache instead).
    """
    def wrap(path, leaf):
        name = getattr(path[-1], "key", None) if path else None
        if (name not in names or getattr(leaf, "ndim", 0) != 2
                or not jnp.issubdtype(leaf.dtype, jnp.floating)):
            return leaf
        cfg = policy.for_site(_site_of(path, site_default))
        if cfg.scheme != "ozaki1":
            return leaf
        return prepare_rhs(leaf, cfg)

    return jax.tree_util.tree_map_with_path(wrap, params)
