"""Pre-decomposed rhs operands, reused across GEMMs.

The emulation pipelines re-decompose the *same weight matrix* on every
emulated call: forward, the remat re-forward, and the backward
dA = dC @ B^T each pay the full scale-read + encode round-trips — 3x
per layer per step in training, and once per decode step in serving.
Two prepared artifacts hold the finished encode instead:
``PreparedOperand`` (Scheme-I int8 mantissa slices) and
``PreparedResidues`` (Scheme-II balanced int8 residues — consumed by
the fused GPU residue kernel, whose prologue then skips the rhs
encode, or expanded in XLA from the stored residue stack).

A ``PreparedOperand`` holds:

  * ``slices``  — the p int8 slices, interleaved ((p*K, N), paper Eq. 11)
                  for the fused kernels or stacked ((p, K, N)) for the XLA
                  expansion,
  * ``scale``   — the (1, N) power-of-two column scale,
  * ``beta``/``p`` and ``blocks`` (the interleave granularity lives in
    ``blocks.bk``),
  * ``twin``    — the same weight prepared in the K-transposed rhs layout
                  of B^T, consumed by the backward dA GEMM.

``prepare_rhs`` builds one with a *single fp32 read* of the weight (the
``decompose_interleave_pair`` kernel emits both layouts in one pass);
``matmul_prepared`` consumes it through the mixed fused kernel (fp32 lhs
decomposed in-VMEM, prepared int8 rhs streamed).  Traffic accounting:
``repro.core.traffic.scheme1_decomp_prepared_bytes``.

Plumbing: ``dispatch.emulated_matmul`` accepts a PreparedOperand rhs,
``core.emulated.emulated_dot`` prepares weights once per step when
``cfg.cache_weights`` is set, and ``prepare_params`` wraps a model's
projection weights for once-per-session serving reuse
(``launch/serve.py --prepare``).
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro import telemetry
from repro.core import scheme1
from repro.core.precision import EmulationConfig, scheme2_budget
from repro.kernels.common import Blocks
from repro.telemetry import record as _tele


def _record_consume(scheme: str, count: int, backend: str, route: str,
                    reason: str, m: int, k: int, prep) -> None:
    """One prepared-consume routing decision + the per-execution GEMM."""
    if not telemetry.enabled():
        return
    telemetry.record_event(_tele.PREPARED_CONSUME, {
        "scheme": scheme, "route": route, "reason": reason})
    telemetry.record_gemm(
        scheme=scheme, count=count, backend=backend,
        impl=("prepared-pallas" if route == "fused" else "prepared-xla"),
        m=m, k=k, n=prep.n, mesh_shape=prep.mesh_shape)


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class PreparedOperand:
    """A pre-split, pre-interleaved Scheme-I rhs operand (see module doc).

    ``k``/``n`` are the *unpadded* logical dims; ``slices`` and ``scale``
    are 128-aligned.  ``layout`` is 'interleaved' ((p*Kp, Np) int8, fused
    kernels) or 'stacked' ((p, Kp, Np) int8, XLA expansion).
    """
    slices: jax.Array
    scale: jax.Array
    p: int
    beta: int
    blocks: Blocks | None
    layout: str
    k: int
    n: int
    twin: "PreparedOperand | None" = None
    # Launch-mesh axis sizes this operand was prepared under (the
    # consume-route pinning of the layout field, extended to GSPMD:
    # shard_gemm checks it when localizing the stack for column-parallel
    # consumption).  None = prepared for single-device launches.
    mesh_shape: tuple | None = None

    @property
    def padded_k(self) -> int:
        if self.layout == "interleaved":
            return self.slices.shape[0] // self.p
        return self.slices.shape[1]

    @property
    def padded_n(self) -> int:
        return self.slices.shape[-1]

    def stacked(self) -> jax.Array:
        """The (p, Kp, Np) slice stack, deinterleaving if needed."""
        if self.layout == "stacked":
            return self.slices
        return scheme1.deinterleave_k(self.slices, self.p, "b",
                                      self.blocks.bk)

    def reconstruct(self) -> jax.Array:
        """The dense (k, n) float32 weight the slices represent.

        Exact up to the decomposition residual (scale * 2^(-beta*p)
        elementwise) — what the guard's a posteriori verifier
        (repro.guard.verify) compares emulated results against when the
        original float weight is no longer around.
        """
        st = self.stacked().astype(jnp.float32)
        w = jnp.zeros(st.shape[1:], jnp.float32)
        for i in range(self.p):
            # Python 2.0**e is exact; see scheme1.shift_reduce.
            w = w + jnp.float32(2.0 ** (-self.beta * (i + 1))) * st[i]
        return (w * self.scale.astype(jnp.float32))[:self.k, :self.n]

    def tree_flatten(self):
        return ((self.slices, self.scale, self.twin),
                (self.p, self.beta, self.blocks, self.layout,
                 self.k, self.n, self.mesh_shape))

    @classmethod
    def tree_unflatten(cls, aux, children):
        slices, scale, twin = children
        p, beta, blocks, layout, k, n, mesh_shape = aux
        return cls(slices, scale, p, beta, blocks, layout, k, n, twin,
                   mesh_shape)


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class PreparedResidues:
    """A pre-encoded Scheme-II rhs: balanced int8 residues of the
    integerized weight, reused across GEMMs.

    ``residues`` is the (p, Kp, Np) balanced int8 residue stack —
    16-aligned for the fused GPU residue kernel, which streams it
    directly while its prologue integerizes only the lhs; ``scale`` is
    the (1, Np) power-of-two integerization scale and ``budget_bits``
    the per-operand magnitude budget pinned at encode time (the
    consumer integerizes the lhs at the *same* budget, exactly as the
    unprepared ``scheme2.matmul`` shares one budget across operands).
    Unlike the Scheme-I interleaved layout there is no pinned K
    granularity: the residue stack is consumable at any ``bK``.

    ``layout`` mirrors the Scheme-I 'interleaved'/'stacked' split: it
    records at prepare time whether consumption may run the fused GPU
    kernel ('fused', ``cfg.impl`` auto/pallas) or must stay on the XLA
    expansion ('stacked', ``cfg.impl='xla'`` — e.g. after
    ``resolve_policy`` clamped a multi-device launch whose sequential
    interpret-mode grid GSPMD cannot partition).  The stored stack is
    identical either way; only the consumption route differs.

    ``twin`` is the same weight encoded in the K-transposed layout of
    B^T (its own scale axis and budget — the dA GEMM contracts over N),
    consumed by the backward pass under ``cfg.cache_weights``.
    """
    residues: jax.Array
    scale: jax.Array
    moduli: tuple
    budget_bits: int
    blocks: Blocks | None
    k: int
    n: int
    layout: str = "fused"
    twin: "PreparedResidues | None" = None
    # Launch-mesh axis sizes at prepare time (see PreparedOperand).
    mesh_shape: tuple | None = None

    # Spec-compat with PreparedOperand consumers (p = modulus count).
    @property
    def p(self) -> int:
        return len(self.moduli)

    @property
    def padded_k(self) -> int:
        return self.residues.shape[1]

    @property
    def padded_n(self) -> int:
        return self.residues.shape[2]

    def reconstruct(self) -> jax.Array:
        """The dense (k, n) float32 weight the residues represent.

        CRT-reconstructs the integerized weight from the balanced
        residue stack and undoes the power-of-two scale — exact up to
        the integerization truncation (1/scale elementwise), for the
        guard's a posteriori verifier (repro.guard.verify).
        """
        from repro.core import scheme2  # lazy: avoid import-order knots
        res = scheme2.modular_reduce(self.residues.astype(jnp.int32),
                                     self.moduli)
        w_int = scheme2.crt_reconstruct(res, self.moduli, jnp.float32)
        return (w_int / self.scale.astype(jnp.float32))[:self.k, :self.n]

    def tree_flatten(self):
        return ((self.residues, self.scale, self.twin),
                (self.moduli, self.budget_bits, self.blocks,
                 self.k, self.n, self.layout, self.mesh_shape))

    @classmethod
    def tree_unflatten(cls, aux, children):
        residues, scale, twin = children
        moduli, budget_bits, blocks, k, n, layout, mesh_shape = aux
        return cls(residues, scale, moduli, budget_bits, blocks, k, n,
                   layout, twin, mesh_shape)


def _pad2(x: jax.Array, align: int = 128) -> jax.Array:
    from repro.kernels.dispatch import round_up
    k, n = x.shape
    kp, np_ = round_up(k, align), round_up(n, align)
    if (kp, np_) == (k, n):
        return x
    return jnp.pad(x, ((0, kp - k), (0, np_ - n)))


def _use_kernel(cfg: EmulationConfig) -> bool:
    return cfg.impl in ("auto", "pallas") and cfg.decomp != "xla"


def prepare_rhs(b: jax.Array, cfg: EmulationConfig, *,
                with_twin: bool = False,
                m_hint: int = 512, mesh=None):
    """Decompose a (K, N) float rhs once, for reuse across GEMMs.

    Under Scheme I returns a :class:`PreparedOperand` (int8 mantissa
    slices); under Scheme II a :class:`PreparedResidues` (balanced int8
    residues — the fused GPU kernel streams them and skips the rhs
    encode).  With ``with_twin`` the K-transposed layout for the
    backward dA GEMM is produced too; when forward and backward share p,
    the Scheme-I pair comes out of one fp32 read (the pair kernel).
    ``m_hint`` sizes the lhs the block search assumes — consumers
    re-select with the granularity pinned, so only bK must be right.

    ``mesh`` records the launch mesh the operand is prepared under (the
    GSPMD leg of the consume-route pinning): its axis sizes key the
    block-granularity cache and travel on the artifact, so
    ``shard_gemm`` can refuse to localize a stack pinned for a
    different mesh layout.
    """
    if cfg.scheme == "ozaki2":
        return prepare_rhs_scheme2(b, cfg, with_twin=with_twin, mesh=mesh)
    if isinstance(b, PreparedResidues):
        raise ValueError("got a PreparedResidues (Scheme-II) operand "
                         f"under scheme={cfg.scheme!r}; pass the float "
                         "weight instead")
    if isinstance(b, PreparedOperand):
        return b
    if b.ndim != 2:
        raise ValueError(f"prepare_rhs is 2-D; got {b.shape}")
    if jnp.issubdtype(b.dtype, jnp.complexfloating):
        raise ValueError("prepare_rhs is real-valued; decompose the real "
                         "and imaginary parts separately (4M formulation)")
    from repro.kernels import decompose, dispatch

    telemetry.record_event(_tele.PREPARED_BUILD,
                           {"scheme": "ozaki1",
                            "layout": ("interleaved" if _use_kernel(cfg)
                                       else "stacked")})
    k, n = b.shape
    if not jnp.issubdtype(b.dtype, jnp.floating):
        b = b.astype(jnp.float32)
    b_pad = _pad2(b)
    kp, np_ = b_pad.shape
    p = cfg.p
    beta = cfg.resolved_beta(kp)
    nu = scheme1._pow2_row_scale(b_pad, axis=0)          # (1, Np)
    mesh_shape = dispatch._mesh_shape_tuple(mesh)

    p_bwd = cfg.bwd_p or p
    beta_bwd = cfg.resolved_beta(np_)

    if not _use_kernel(cfg):
        slices, _ = scheme1.split(b_pad, p, beta, axis=0)
        twin = None
        if with_twin:
            t_slices, tau = scheme1.split(b_pad.T, p_bwd, beta_bwd, axis=0)
            twin = PreparedOperand(t_slices, tau, p_bwd, beta_bwd, None,
                                   "stacked", n, k, mesh_shape=mesh_shape)
        return PreparedOperand(slices, nu, p, beta, None, "stacked",
                               k, n, twin, mesh_shape)

    blocks = dispatch.select_blocks(m_hint, np_, kp, p, backend="tpu",
                                    prologue_a=True, mesh_shape=mesh_shape)
    if blocks is None:
        blocks = Blocks(128, 128, 128)
    if with_twin:
        t_blocks = dispatch.select_blocks(m_hint, kp, np_, p_bwd,
                                          backend="tpu", prologue_a=True,
                                          mesh_shape=mesh_shape)
        if t_blocks is None:
            t_blocks = Blocks(128, 128, 128)
        tau = scheme1._pow2_row_scale(b_pad.T, axis=0)   # (1, Kp)
        if p_bwd == p:
            # One fp32 read of B emits both layouts.
            hat, t_hat = decompose.decompose_interleave_pair(
                b_pad, nu, tau, p, beta, beta_bwd,
                bk=blocks.bk, bt=t_blocks.bk)
        else:
            hat = decompose.decompose_interleave_rhs(b_pad, nu, p, beta,
                                                     bk=blocks.bk)
            t_hat = decompose.decompose_interleave_rhs(
                b_pad.T, tau, p_bwd, beta_bwd, bk=t_blocks.bk)
        twin = PreparedOperand(t_hat, tau, p_bwd, beta_bwd, t_blocks,
                               "interleaved", n, k, mesh_shape=mesh_shape)
        return PreparedOperand(hat, nu, p, beta, blocks, "interleaved",
                               k, n, twin, mesh_shape)
    hat = decompose.decompose_interleave_rhs(b_pad, nu, p, beta,
                                             bk=blocks.bk)
    return PreparedOperand(hat, nu, p, beta, blocks, "interleaved", k, n,
                           mesh_shape=mesh_shape)


def _encode_residues(b: jax.Array, moduli, k_dim: int):
    """One Scheme-II rhs encode: 16-aligned balanced residue stack +
    power-of-two scale + the pinned budget.  The encode mirrors
    ``scheme2.matmul`` exactly (integerize at the shared budget, then
    ``balanced_residues``), so consumption is bit-identical to the
    unprepared pipeline; zero-padded rows/cols encode to zero residues,
    which contribute nothing mod any m_l.
    """
    from repro.core import scheme2
    from repro.kernels.backends import gpu as gpu_backend

    b_pad = _pad2(b, align=gpu_backend.ALIGN)
    budget = min(scheme2_budget(moduli, k_dim),
                 jnp.finfo(b.dtype).nmant + 1)
    nu = scheme2._pow2_int_scale(b_pad, axis=0, budget_bits=budget)
    res = scheme2.balanced_residues(jnp.trunc(b_pad * nu), moduli)
    return res, nu, budget


def prepare_rhs_scheme2(b: jax.Array, cfg: EmulationConfig, *,
                        with_twin: bool = False,
                        mesh=None) -> PreparedResidues:
    """Encode a (K, N) float rhs's balanced Scheme-II residues once.

    The fused GPU residue kernel streams the stack directly (its
    prologue skips the rhs encode); off-GPU consumers expand from the
    same stack in XLA.  ``with_twin`` also encodes B^T for the backward
    dA GEMM — a separate encode (the twin's scale reduces over the
    other axis and its budget is set by its own contraction length N).
    """
    if isinstance(b, PreparedResidues):
        return b
    if isinstance(b, PreparedOperand):
        raise ValueError("got a PreparedOperand (Scheme-I) operand under "
                         "scheme='ozaki2'; pass the float weight instead")
    if b.ndim != 2:
        raise ValueError(f"prepare_rhs is 2-D; got {b.shape}")
    if jnp.issubdtype(b.dtype, jnp.complexfloating):
        raise ValueError("prepare_rhs is real-valued; decompose the real "
                         "and imaginary parts separately (the complex 3M "
                         "path re-encodes per call)")
    if not jnp.issubdtype(b.dtype, jnp.floating):
        b = b.astype(jnp.float32)
    k, n = b.shape
    moduli = tuple(int(m) for m in cfg.resolved_moduli())
    # The consumption route is pinned now, like the Scheme-I
    # interleaved/stacked split: the fused GPU kernel is taken only when
    # the config would run fused AND the backend resolution lands on
    # 'gpu' — an impl='xla' config (resolve_policy's GSPMD clamp) or a
    # TPU/CPU launch without an explicit gpu request must never re-enter
    # an interpret-mode pallas_call at consume time; they expand the
    # same stack in XLA instead.
    from repro.kernels import backends, dispatch
    layout = ("fused" if _use_kernel(cfg)
              and backends.resolve_backend_name(None, cfg) == "gpu"
              else "stacked")
    mesh_shape = dispatch._mesh_shape_tuple(mesh)
    telemetry.record_event(_tele.PREPARED_BUILD,
                           {"scheme": "ozaki2", "layout": layout})
    res, nu, budget = _encode_residues(b, moduli, k_dim=k)
    twin = None
    if with_twin:
        # Mixed-precision backward: a reduced bwd_p keeps the leading
        # bwd_p moduli, mirroring _bwd_core's replace(p=bwd_p) on a
        # default-moduli config.
        t_moduli = moduli[:cfg.bwd_p] if cfg.bwd_p else moduli
        t_res, tau, t_budget = _encode_residues(b.T, t_moduli, k_dim=n)
        twin = PreparedResidues(t_res, tau, t_moduli, t_budget, None, n, k,
                                layout, mesh_shape=mesh_shape)
    return PreparedResidues(res, nu, moduli, budget, None, k, n, layout,
                            twin, mesh_shape)


def matmul_prepared_scheme2(a: jax.Array, prep: PreparedResidues,
                            out_dtype=jnp.float32) -> jax.Array:
    """(M, K) float @ prepared Scheme-II residues (K, N) -> (M, N).

    The lhs integerizes at the prep's pinned budget and carves its
    residues in the fused GPU kernel's prologue while the stored rhs
    stack streams as-is ('fused' layout); a 'stacked' prep (impl='xla'
    configs) or a missing block fit expands the same stack through the
    XLA reference ops.  Both routes are bit-identical to
    ``scheme2.matmul`` on the same operands whenever the lhs mantissa
    does not bound the shared budget below the encode-time budget (any
    same-precision pair, e.g. f32 @ f32); a lower-precision lhs stays
    exact under the CRT bound but integerizes the two operands at
    different budgets, unlike the single-budget unprepared call.
    """
    from repro.core import scheme2
    from repro.kernels import dispatch
    from repro.kernels.backends import gpu as gpu_backend

    m, k = a.shape
    if k != prep.k:
        raise ValueError(f"lhs K={k} vs prepared K={prep.k}")
    if jnp.issubdtype(a.dtype, jnp.complexfloating):
        raise ValueError("matmul_prepared is real-valued; got complex lhs "
                         f"{a.dtype}")
    moduli = prep.moduli
    scheme2.check_exact_k(k, moduli)
    if not jnp.issubdtype(a.dtype, jnp.floating):
        a = a.astype(jnp.float32)
    # The lhs integerizes in its own dtype at the encode-pinned budget,
    # capped by its own mantissa (mirrors scheme2.matmul's shared cap).
    budget = min(prep.budget_bits, jnp.finfo(a.dtype).nmant + 1)
    kp, np_ = prep.padded_k, prep.padded_n
    mp = dispatch.round_up(m, gpu_backend.ALIGN)
    if (mp, kp) != (m, k):
        a = jnp.pad(a, ((0, mp - m), (0, kp - k)))
    mu = scheme2._pow2_int_scale(a, axis=1, budget_bits=budget)

    if prep.layout == "fused":
        blocks = dispatch.select_blocks(
            mp, np_, kp, len(moduli),
            out_bytes=jnp.dtype(out_dtype).itemsize, backend="gpu",
            scheme="ozaki2")
        if blocks is not None and blocks.aligned(mp, np_, kp):
            _record_consume("ozaki2", len(moduli), "gpu", "fused", "-",
                            m, k, prep)
            with telemetry.gemm_scope("ozaki2", len(moduli), "gpu",
                                      "prepared-pallas"):
                out = gpu_backend.fused_matmul_scheme2(
                    a, prep.residues, mu, prep.scale, moduli, blocks,
                    out_dtype=out_dtype)
            return out[:m, :prep.n]
        reason = "no_block_fit"
    else:
        reason = "stacked_layout"

    # XLA expansion from the stored residue stack ('stacked' layout, or
    # no block fit at the fused tile grid).
    _record_consume("ozaki2", len(moduli), "xla", "xla", reason, m, k, prep)
    with telemetry.gemm_scope("ozaki2", len(moduli), "xla", "prepared-xla"):
        a_res = scheme2.balanced_residues(jnp.trunc(a * mu), moduli)
        acc = scheme2.residue_gemms(a_res, prep.residues)
        c_res = scheme2.modular_reduce(acc, moduli)
        c_int = scheme2.crt_reconstruct(c_res, moduli, out_dtype)
        out = c_int / (mu.astype(out_dtype) * prep.scale.astype(out_dtype))
    return out[:m, :prep.n]


def matmul_prepared(a: jax.Array, prep,
                    out_dtype=jnp.float32) -> jax.Array:
    """(M, K) float @ prepared (K, N) -> (M, N) ``out_dtype``.

    A :class:`PreparedResidues` rhs streams its Scheme-II residue stack
    (fused GPU kernel, or the XLA expansion off the tile grid).  For a
    Scheme-I :class:`PreparedOperand`, the lhs decomposes in the kernel
    prologue (interleaved layout) or via ``scheme1.split`` (stacked
    layout); the rhs slices are reused as-is.  Non-aligned lhs rows/K
    are zero-padded and the result sliced back.
    """
    from repro.kernels import dispatch, ozaki1

    if isinstance(prep, PreparedResidues):
        return matmul_prepared_scheme2(a, prep, out_dtype=out_dtype)

    m, k = a.shape
    if k != prep.k:
        raise ValueError(f"lhs K={k} vs prepared K={prep.k}")
    if jnp.issubdtype(a.dtype, jnp.complexfloating):
        # A silent float32 cast would drop the imaginary half; complex
        # problems must go through the 4M expansion on real parts.
        raise ValueError("matmul_prepared is real-valued; got complex lhs "
                         f"{a.dtype}")
    kp, np_ = prep.padded_k, prep.padded_n
    mp = dispatch.round_up(m)
    if (mp, kp) != (m, k):
        a = jnp.pad(a, ((0, mp - m), (0, kp - k)))
    if not jnp.issubdtype(a.dtype, jnp.floating):
        a = a.astype(jnp.float32)

    if prep.layout == "interleaved":
        blocks = dispatch.select_blocks(
            mp, np_, kp, prep.p, out_bytes=jnp.dtype(out_dtype).itemsize,
            backend="tpu", prologue_a=True, fixed_bk=prep.blocks.bk)
        if blocks is not None:
            _record_consume("ozaki1", prep.p, "tpu", "fused", "-",
                            m, k, prep)
            with telemetry.gemm_scope("ozaki1", prep.p, "tpu",
                                      "prepared-pallas"):
                mu = scheme1._pow2_row_scale(a, axis=1)      # (Mp, 1)
                out = ozaki1.fused_matmul_mixed(
                    a, prep.slices, mu.astype(jnp.float32),
                    prep.scale.astype(jnp.float32), prep.p, prep.beta,
                    blocks, out_dtype=out_dtype)
            return out[:m, :prep.n]
        reason = "no_block_fit"
    else:
        reason = "stacked_layout"

    # XLA expansion from the stored slices (stacked layout, or no block
    # fit at the pinned granularity).
    _record_consume("ozaki1", prep.p, "xla", "xla", reason, m, k, prep)
    with telemetry.gemm_scope("ozaki1", prep.p, "xla", "prepared-xla"):
        a_sl, mu = scheme1.split(a, prep.p, prep.beta, axis=1)
        accs = scheme1.triangular_accumulators(a_sl, prep.stacked(), prep.p)
        out = scheme1.shift_reduce(accs, prep.beta, mu, prep.scale,
                                   out_dtype)
    return out[:m, :prep.n]


# ---------------------------------------------------------------------------
# Once-per-step preparation under gradient accumulation.
# ---------------------------------------------------------------------------

@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class StepPrepared:
    """A float weight paired with its once-per-step PreparedOperand.

    Built *outside* the microbatch scan by ``build_step_preps`` and
    attached to the params tree by ``attach_step_preps``: the scan body
    then closes over the finished slices (a loop-invariant constant of
    the compiled while loop), so every microbatch streams them instead
    of re-running the prep — the decomposition executes once per
    optimizer step, not once per microbatch.  ``w`` stays the
    differentiable leaf: ``emulated_dot_prepared`` (repro.core.emulated)
    computes the forward from ``prep`` and routes dB to ``w``.
    """
    w: jax.Array
    prep: PreparedOperand

    def tree_flatten(self):
        return ((self.w, self.prep), None)

    @classmethod
    def tree_unflatten(cls, aux, children):
        del aux
        return cls(*children)


def _site_of(path, site_default: str = "ffn") -> str:
    keys = [getattr(kp, "key", None) for kp in path]
    if "mixer" in keys:
        return "attn"
    if "head" in keys or "emb" in keys:
        return "logits"
    return site_default


def _step_cacheable(cfg) -> bool:
    # Scheme I caches int8 slices, Scheme II balanced residues.
    return cfg.scheme in ("ozaki1", "ozaki2") and cfg.cache_weights


def policy_caches_weights(policy) -> bool:
    """Does any call-site family of this GemmPolicy cache weights?

    An unset (None) default defers to the ambient resolver, exactly as
    ``for_site`` would; launch callers run ``dispatch.resolve_policy``
    first, which materializes the ambient config into ``default``.
    """
    sites = [policy.default] + [cfg for _, cfg in policy.overrides]
    if policy.default is None:
        from repro import api
        sites[0] = api.resolve_config()
    return any(_step_cacheable(cfg) for cfg in sites)


def _path_key(path) -> str:
    return "/".join(str(getattr(kp, "key", kp)) for kp in path)


def _stack_preps(preps: list) -> PreparedOperand:
    """Stack per-layer PreparedOperands along a new leading axis.

    The static aux (p, beta, blocks, layout) is shape-derived and thus
    identical across layers; stacking only the array leaves yields a
    pytree ``jax.lax.scan`` slices back into per-layer operands."""
    return jax.tree.map(lambda *xs: jnp.stack(xs), *preps)


def build_step_preps(params, policy, *, site_default: str = "ffn",
                     names=None, mesh=None) -> dict:
    """Prepare every cacheable dense weight once, keyed by tree path.

    Returns {path: PreparedOperand (with twin)} for the float leaves in
    ``names`` whose site config caches weights.  Scan-stacked layer
    groups (3-D leaves under 'layers') are prepared per layer and
    re-stacked, so the model's layer scan slices finished slices instead
    of re-splitting each layer's weight inside the microbatch scan.
    ``mesh`` (default: the policy's recorded launch mesh, if any) pins
    each prep to the mesh layout it was built under.
    """
    if names is None:
        names = DENSE_WEIGHT_NAMES
    if mesh is None:
        mesh = getattr(policy, "mesh", None)
    preps: dict = {}

    def visit(path, leaf):
        name = getattr(path[-1], "key", None) if path else None
        keys = {getattr(kp, "key", None) for kp in path}
        ndim = getattr(leaf, "ndim", 0)
        stacked = ndim == 3 and "layers" in keys
        # MoE expert tensors reuse dense names but are consumed through
        # raw einsums (and carry an expert axis) — never prepped.
        if (name not in names or "moe" in keys or not (ndim == 2 or stacked)
                or not jnp.issubdtype(leaf.dtype, jnp.floating)):
            return leaf
        cfg = policy.for_site(_site_of(path, site_default))
        if not _step_cacheable(cfg):
            return leaf
        if stacked:
            preps[_path_key(path)] = _stack_preps(
                [prepare_rhs(leaf[g], cfg, with_twin=True, mesh=mesh)
                 for g in range(leaf.shape[0])])
        else:
            preps[_path_key(path)] = prepare_rhs(leaf, cfg, with_twin=True,
                                                 mesh=mesh)
        return leaf

    jax.tree_util.tree_map_with_path(visit, params)
    return preps


def attach_step_preps(params, preps: dict):
    """Swap each prepared weight leaf for a StepPrepared(w, prep) pair."""
    if not preps:
        return params

    def wrap(path, leaf):
        prep = preps.get(_path_key(path))
        return StepPrepared(leaf, prep) if prep is not None else leaf

    return jax.tree_util.tree_map_with_path(wrap, params)


# ---------------------------------------------------------------------------
# Whole-model preparation (once-per-session serving reuse).
# ---------------------------------------------------------------------------

# Projection-weight leaf names consumed via models.common.dense — the only
# places a PreparedOperand rhs is legal.  Deliberately excludes lookalikes
# used through raw einsums (w_r/w_i of RG-LRU, wkv_b of MLA, moe experts,
# frontend_proj) and the tied-embedding table.
DENSE_WEIGHT_NAMES = frozenset({
    "wq", "wk", "wv", "wo", "wq_a", "wq_b", "wkv_a",
    "wi", "wi_gate", "wi_up", "w_y", "w_gate", "w_out", "w_in",
    "head",
})


def prepare_params(params, policy, *, site_default: str = "ffn",
                   names=DENSE_WEIGHT_NAMES, mesh=None):
    """Wrap a model's 2-D dense projection weights as PreparedOperands.

    Run once per serve session (outside jit): every subsequent prefill /
    decode step streams the finished int8 slices instead of re-splitting
    the weight.  Leaves under vmap/scan-stacked layer groups are 3-D and
    pass through untouched (their per-layer slices are decomposed by the
    per-step cache instead).  ``mesh`` (default: the policy's recorded
    launch mesh) pins each prep to the mesh it was built under.
    """
    if mesh is None:
        mesh = getattr(policy, "mesh", None)

    def wrap(path, leaf):
        name = getattr(path[-1], "key", None) if path else None
        if (name not in names or getattr(leaf, "ndim", 0) != 2
                or not jnp.issubdtype(leaf.dtype, jnp.floating)):
            return leaf
        cfg = policy.for_site(_site_of(path, site_default))
        if cfg.scheme not in ("ozaki1", "ozaki2"):
            return leaf
        return prepare_rhs(leaf, cfg, mesh=mesh)

    return jax.tree_util.tree_map_with_path(wrap, params)


def prep_pspecs(prep, weight_spec):
    """PartitionSpec pytree for a prepared rhs, derived from the source
    weight's (K, N) spec — the slice/residue stacks are built under the
    same spec as the weight, so ``+cached`` params shard with the model
    and never gather.

    Every forward array (slices/residues/scale) carries N as its last
    dim and takes the weight's N axis there; the twin's layout is the
    K-transpose of B, so its arrays end in K and take the weight's K
    axis.  Pair with :func:`repro.parallel.sharding.shardings` to place
    a prepared params tree on a mesh.
    """
    from jax.sharding import PartitionSpec as P
    parts = tuple(weight_spec) + (None, None)
    k_part, n_part = parts[0], parts[1]

    def last_dim(part):
        return lambda leaf: P(*([None] * (leaf.ndim - 1)), part)

    specs = jax.tree.map(last_dim(n_part),
                         dataclasses.replace(prep, twin=None))
    if prep.twin is not None:
        twin_specs = jax.tree.map(
            last_dim(k_part), dataclasses.replace(prep.twin, twin=None))
        specs = dataclasses.replace(specs, twin=twin_specs)
    return specs
