"""Plain tiled int8 GEMM Pallas kernel — the 'native INT8' baseline.

This is what a *naive* emulation implementation composes p (or p(p+1)/2)
launches of, each materializing its int32 output to HBM (paper Fig. 4's
'cuBLAS native INT8' reference: the ceiling of any non-fused emulation).
Used by the benchmarks' naive paths and as the simplest oracle-checked
kernel of the suite.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.backends.base import build_pallas_call
from repro.kernels.common import Blocks
from repro.kernels.dispatch import select_blocks


def _kernel(a_ref, b_ref, out_ref, acc_ref):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jax.lax.dot_general(
        a_ref[...], b_ref[...], (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.int32)

    @pl.when(k == pl.num_programs(2) - 1)
    def _write():
        out_ref[...] = acc_ref[...]


def int8_matmul(a8: jax.Array, b8: jax.Array,
                blocks: Blocks | None = None) -> jax.Array:
    """(M, K) int8 @ (K, N) int8 -> (M, N) int32, exact."""
    m, k = a8.shape
    _, n = b8.shape
    if blocks is None:
        blocks = select_blocks(m, n, k, p=1, backend="tpu")
    if blocks is None or not blocks.aligned(m, n, k):
        raise ValueError(f"no aligned blocks for {(m, n, k)}")
    bm, bn, bk = blocks.bm, blocks.bn, blocks.bk
    return build_pallas_call(
        _kernel,
        grid=(m // bm, n // bn, k // bk),
        in_specs=[pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
                  pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j))],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.int32),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.int32)],
        dimension_semantics=("parallel", "parallel", "arbitrary"),
        name="int8_gemm",
    )(a8, b8)
