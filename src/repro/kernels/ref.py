"""Pure-jnp oracles for every Pallas kernel in this package.

Each oracle mirrors the corresponding kernel *at the same granularity*
(same operand layout, same reduction order where it matters) so that
tests/test_kernels_*.py can assert exact or allclose agreement in
interpret mode across shape/dtype sweeps.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import scheme1, scheme2


def int8_matmul(a8: jax.Array, b8: jax.Array) -> jax.Array:
    """Oracle for kernels.matmul_int8.int8_matmul (exact int32)."""
    return jax.lax.dot_general(a8, b8, (((1,), (0,)), ((), ())),
                               preferred_element_type=jnp.int32)


def scheme1_interleaved(a_hat: jax.Array, b_hat: jax.Array,
                        mu: jax.Array, nu: jax.Array,
                        p: int, beta: int, t_k: int,
                        out_dtype=jnp.float32) -> jax.Array:
    """Oracle for kernels.ozaki1.fused_matmul_interleaved.

    De-interleaves, runs the triangular contraction (Eq. 2) and the
    shift-reduce (Eq. 3) with the same s-ascending summation order as the
    kernel epilogue.
    """
    a_sl = scheme1.deinterleave_k(a_hat, p, "a", t_k)
    b_sl = scheme1.deinterleave_k(b_hat, p, "b", t_k)
    accs = scheme1.triangular_accumulators(a_sl, b_sl, p)
    return scheme1.shift_reduce(accs, beta, mu, nu, jnp.dtype(out_dtype).type)


def _balanced(x_int32: jax.Array, m: int) -> jax.Array:
    half = m // 2
    return (jnp.remainder(x_int32 + half, m) - half).astype(jnp.int8)


def scheme2_residues(a_res: jax.Array, b_res: jax.Array, moduli) -> jax.Array:
    """Oracle for kernels.ozaki2.fused_residue_matmul.

    Returns (p, M, N) *balanced* int8 residues of A'B' mod m_l.
    """
    acc = scheme2.residue_gemms(a_res, b_res)  # (p, M, N) int32
    return jnp.stack([_balanced(acc[l], int(m)) for l, m in enumerate(moduli)])


def flash_attention(q, k, v, causal=True, window=None, softmax_scale=None):
    """Oracle for kernels.flash_attn.flash_attention.

    q: (B, H, Sq, D); k/v: (B, KVH, Sk, D). Plain softmax attention with
    GQA head grouping and causal/local masking.
    """
    import math
    b, h, sq, d = q.shape
    kvh, sk = k.shape[1], k.shape[2]
    g = h // kvh
    scale = softmax_scale or 1.0 / math.sqrt(d)
    qg = q.reshape(b, kvh, g, sq, d)
    s = jnp.einsum("bkgqd,bkjd->bkgqj", qg.astype(jnp.float32),
                   k.astype(jnp.float32)) * scale
    rel = jnp.arange(sq)[:, None] - jnp.arange(sk)[None, :]
    mask = jnp.ones((sq, sk), bool)
    if causal:
        mask &= rel >= 0
    if window is not None:
        mask &= rel < window
    s = jnp.where(mask, s, -1e30)
    w = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bkgqj,bkjd->bkgqd", w, v.astype(jnp.float32))
    return out.reshape(b, h, sq, d).astype(q.dtype)


def scheme2_3m(a3: jax.Array, b3: jax.Array, moduli):
    """Oracle for kernels.ozaki3m.fused_3m_residue_matmul.

    a3/b3: (p, 3, M/K, K/N) int8 phases [re, im, re+im].
    Returns (c_re, c_im) balanced int8 (p, M, N).
    """
    c_re, c_im = [], []
    for l, m in enumerate(moduli):
        m = int(m)
        t1 = int8_matmul(a3[l, 0], b3[l, 0])
        t2 = int8_matmul(a3[l, 1], b3[l, 1])
        t3 = int8_matmul(a3[l, 2], b3[l, 2])
        t1b = _balanced(t1, m).astype(jnp.int32)
        t2b = _balanced(t2, m).astype(jnp.int32)
        t3b = _balanced(t3, m).astype(jnp.int32)
        c_re.append(_balanced(t1b - t2b, m))
        c_im.append(_balanced(t3b - t1b - t2b, m))
    return jnp.stack(c_re), jnp.stack(c_im)
