"""JAX-version compatibility shims for the Pallas TPU kernel layer.

The Pallas TPU surface has drifted across jax releases: the compiler-params
dataclass was renamed (``TPUCompilerParams`` -> ``CompilerParams``), fields
like ``dimension_semantics`` come and go, and ``PrefetchScalarGridSpec``
predates the unified ``pl.GridSpec`` scalar-prefetch support. Every kernel
in this package previously hardcoded one vintage of that API, so a single
upstream rename broke all five kernels identically.

This module absorbs the drift in one place. Everything is *feature-probed*
(attribute/field introspection) rather than keyed off ``jax.__version__``,
so forks and backports that cherry-pick the rename still resolve correctly.
The probes are unit-tested in tests/test_dispatch.py.
"""

from __future__ import annotations

import dataclasses
import functools
import inspect
from typing import Any

from jax.experimental import pallas as pl  # noqa: F401  (re-export surface)
from jax.experimental.pallas import tpu as pltpu

# Names the compiler-params dataclass has carried, newest first.
_COMPILER_PARAMS_NAMES = ("CompilerParams", "TPUCompilerParams")


@functools.cache
def compiler_params_cls() -> type | None:
    """The TPU compiler-params class of the installed jax, or None."""
    for name in _COMPILER_PARAMS_NAMES:
        cls = getattr(pltpu, name, None)
        if cls is not None:
            return cls
    return None


@functools.cache
def compiler_params_fields() -> frozenset[str]:
    """Constructor fields accepted by the installed compiler-params class."""
    cls = compiler_params_cls()
    if cls is None:
        return frozenset()
    if dataclasses.is_dataclass(cls):
        return frozenset(f.name for f in dataclasses.fields(cls))
    params = inspect.signature(cls).parameters
    return frozenset(p for p in params if p != "self")


def supports_dimension_semantics() -> bool:
    return "dimension_semantics" in compiler_params_fields()


def tpu_compiler_params(*, dimension_semantics=None, **kwargs) -> Any | None:
    """Build the compiler-params object for this jax version.

    Unknown fields are dropped (they are performance hints, not semantics);
    returns None when no compiler-params class exists at all, in which case
    the caller must omit the ``compiler_params=`` argument entirely.
    """
    cls = compiler_params_cls()
    if cls is None:
        return None
    accepted = compiler_params_fields()
    kw = {k: v for k, v in kwargs.items() if k in accepted and v is not None}
    if dimension_semantics is not None and supports_dimension_semantics():
        kw["dimension_semantics"] = tuple(dimension_semantics)
    return cls(**kw)


# ---------------------------------------------------------------------------
# GPU (Mosaic-GPU / Triton) compiler params — same feature-probe treatment.
# ---------------------------------------------------------------------------

@functools.cache
def gpu_pallas_module():
    """The installed jax's GPU Pallas extension module, or None.

    The module has moved (``pallas.gpu`` -> ``pallas.triton``) and a
    Mosaic-GPU variant exists on newer jax; probe newest-first.  Interpret
    mode never needs it — only a real GPU lowering does.
    """
    for mod_name in ("jax.experimental.pallas.mosaic_gpu",
                     "jax.experimental.pallas.triton",
                     "jax.experimental.pallas.gpu"):
        try:
            import importlib
            return importlib.import_module(mod_name)
        except Exception:  # noqa: BLE001 — absent/broken extras both mean "no"
            continue
    return None


_GPU_PARAMS_NAMES = ("CompilerParams", "TritonCompilerParams",
                     "GPUCompilerParams")


@functools.cache
def gpu_compiler_params_cls() -> type | None:
    mod = gpu_pallas_module()
    if mod is None:
        return None
    for name in _GPU_PARAMS_NAMES:
        cls = getattr(mod, name, None)
        if cls is not None:
            return cls
    return None


def gpu_compiler_params(*, dimension_semantics=None, **kwargs) -> Any | None:
    """Build GPU (Triton/Mosaic-GPU) compiler params, dropping unknown
    fields, or None when the installed jax has no GPU Pallas extension
    (interpret-mode runs never reach a real GPU lowering anyway).
    ``dimension_semantics`` is a TPU Mosaic concept and is discarded."""
    del dimension_semantics
    cls = gpu_compiler_params_cls()
    if cls is None:
        return None
    if dataclasses.is_dataclass(cls):
        accepted = frozenset(f.name for f in dataclasses.fields(cls))
    else:
        accepted = frozenset(p for p in inspect.signature(cls).parameters
                             if p != "self")
    kw = {k: v for k, v in kwargs.items() if k in accepted and v is not None}
    return cls(**kw)


@functools.cache
def has_scalar_prefetch_grid_spec() -> bool:
    return hasattr(pltpu, "PrefetchScalarGridSpec")


def scalar_prefetch_grid_spec(*, num_scalar_prefetch: int, grid,
                              in_specs, out_specs, scratch_shapes=()):
    """A grid spec whose first ``num_scalar_prefetch`` operands are SMEM
    scalar-prefetch arguments (moduli tables etc.)."""
    if has_scalar_prefetch_grid_spec():
        return pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=num_scalar_prefetch,
            grid=grid,
            in_specs=in_specs,
            out_specs=out_specs,
            scratch_shapes=scratch_shapes,
        )
    # Unified-GridSpec jax versions: pl.GridSpec grew the same keyword.
    spec_params = inspect.signature(pl.GridSpec).parameters
    if "num_scalar_prefetch" in spec_params:
        return pl.GridSpec(
            num_scalar_prefetch=num_scalar_prefetch,
            grid=grid,
            in_specs=in_specs,
            out_specs=out_specs,
            scratch_shapes=scratch_shapes,
        )
    raise NotImplementedError(
        "installed jax exposes neither pltpu.PrefetchScalarGridSpec nor a "
        "scalar-prefetch-capable pl.GridSpec")
