"""EmuGEMM-II complex: fused 3M Scheme-II Pallas TPU kernel (paper Sec. IV-B).

For each modulus, three sequential K-loop passes compute T1 = Ar'Br',
T2 = Ai'Bi', T3 = (Ar'+Ai')(Br'+Bi') reusing a *single* int32 VMEM
accumulator (paper Fig. 3(b)): after each pass the accumulator is reduced
mod m to a balanced-int8 tile kept in VMEM scratch (negligible next to the
int32 accumulator it replaces). After the third pass the 3M combination

    C'_re = T1 - T2 ,  C'_im = T3 - T1 - T2      (mod m, exact)

is formed on-chip and only the two int8 residue tiles are written —
Eq. 18's traffic; the naive Eq. 17's 24*MN int32 round-trip term vanishes.
In modular arithmetic the 3M subtraction is exact: no catastrophic
cancellation, so 3M is strictly better than 4M here.

Operand layout: the wrapper stacks [re, im, re+im] residues on a phase axis,
so the phase grid coordinate t selects the operand pair via the BlockSpec
index map — no in-kernel data movement.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels import compat
from repro.kernels.backends.base import build_pallas_call
from repro.kernels.common import Blocks
from repro.kernels.dispatch import select_blocks


def _kernel(mods_ref, a_ref, b_ref, out_re_ref, out_im_ref,
            acc_ref, t1_ref, t2_ref):
    t = pl.program_id(3)
    k = pl.program_id(4)
    m = mods_ref[pl.program_id(0)]

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jax.lax.dot_general(
        a_ref[0, 0], b_ref[0, 0], (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.int32)

    @pl.when(k == pl.num_programs(4) - 1)
    def _end_of_pass():
        half = m // 2

        def bal(x):
            return jnp.remainder(x + half, m) - half

        @pl.when(t == 0)
        def _t1():
            t1_ref[...] = bal(acc_ref[...]).astype(jnp.int8)

        @pl.when(t == 1)
        def _t2():
            t2_ref[...] = bal(acc_ref[...]).astype(jnp.int8)

        @pl.when(t == 2)
        def _combine():
            t3 = bal(acc_ref[...])
            t1 = t1_ref[...].astype(jnp.int32)
            t2 = t2_ref[...].astype(jnp.int32)
            out_re_ref[0] = bal(t1 - t2).astype(jnp.int8)
            out_im_ref[0] = bal(t3 - t1 - t2).astype(jnp.int8)


def fused_3m_residue_matmul(a3: jax.Array, b3: jax.Array, moduli,
                            blocks: Blocks | None = None):
    """Fused complex 3M residue GEMMs.

    a3: (p, 3, M, K) int8 — phases [re, im, re+im] balanced residues;
    b3: (p, 3, K, N). Returns (c_re, c_im), each (p, M, N) balanced int8.
    """
    p, three, m, k = a3.shape
    assert three == 3
    _, _, _, n = b3.shape
    if blocks is None:
        blocks = select_blocks(m, n, k, p=1, backend="tpu")
    if blocks is None or not blocks.aligned(m, n, k):
        raise ValueError(f"no aligned blocks for {(m, n, k)}")
    bm, bn, bk = blocks.bm, blocks.bn, blocks.bk
    mods = jnp.asarray(moduli, dtype=jnp.int32)

    grid_spec = compat.scalar_prefetch_grid_spec(
        num_scalar_prefetch=1,
        grid=(p, m // bm, n // bn, 3, k // bk),
        in_specs=[
            pl.BlockSpec((1, 1, bm, bk),
                         lambda l, i, j, t, kk, mods: (l, t, i, kk)),
            pl.BlockSpec((1, 1, bk, bn),
                         lambda l, i, j, t, kk, mods: (l, t, kk, j)),
        ],
        out_specs=[
            pl.BlockSpec((1, bm, bn), lambda l, i, j, t, kk, mods: (l, i, j)),
            pl.BlockSpec((1, bm, bn), lambda l, i, j, t, kk, mods: (l, i, j)),
        ],
        scratch_shapes=[
            pltpu.VMEM((bm, bn), jnp.int32),  # the single live accumulator
            pltpu.VMEM((bm, bn), jnp.int8),   # T1 residue (on-chip retain)
            pltpu.VMEM((bm, bn), jnp.int8),   # T2 residue
        ],
    )
    return build_pallas_call(
        _kernel,
        grid_spec=grid_spec,
        out_shape=[jax.ShapeDtypeStruct((p, m, n), jnp.int8),
                   jax.ShapeDtypeStruct((p, m, n), jnp.int8)],
        dimension_semantics=("arbitrary", "parallel", "parallel",
                             "arbitrary", "arbitrary"),
        name=f"emugemm2_3m_p{p}",
    )(mods, a3, b3)
