"""Fused flash-attention Pallas TPU kernel.

EXPERIMENTS.md §Perf cell B showed the prefill memory term is dominated
by S^2 score-chunk round-trips in the unfused XLA lowering (43 GB/layer
at 32k). This kernel applies the paper's own argument — keep the
intermediate on chip — to attention: the (bq, bk) score tile, the online-
softmax statistics and the output accumulator live in VMEM scratch
across the KV grid axis, so per layer only the q/k/v/o streams touch HBM.

Grid: (batch, q-heads, Sq/bq, Sk/bk), KV innermost ('arbitrary').
GQA is handled in the BlockSpec index maps (kv head = h // group) — the
k/v tiles are fetched once per kv-head group, never materialized per
q-head in HBM.
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.backends.base import build_pallas_call

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref, *,
            scale: float, causal: bool, window: int | None,
            bq: int, bk: int):
    j = pl.program_id(3)

    @pl.when(j == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q = q_ref[0, 0]                       # (bq, d)
    k = k_ref[0, 0]                       # (bk, d)
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale

    i = pl.program_id(2)
    q_pos = i * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
    k_pos = j * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
    rel = q_pos - k_pos
    mask = jnp.ones((bq, bk), jnp.bool_)
    if causal:
        mask &= rel >= 0
    if window is not None:
        mask &= rel < window
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_ref[...]                   # (bq, 1)
    m_new = jnp.maximum(m_prev, s.max(axis=1, keepdims=True))
    p = jnp.exp(s - m_new)
    alpha = jnp.exp(m_prev - m_new)
    l_ref[...] = l_ref[...] * alpha + p.sum(axis=1, keepdims=True)
    m_ref[...] = m_new
    pv = jax.lax.dot_general(p.astype(v_ref.dtype), v_ref[0, 0],
                             (((1,), (0,)), ((), ())),
                             preferred_element_type=jnp.float32)
    acc_ref[...] = acc_ref[...] * alpha + pv

    @pl.when(j == pl.num_programs(3) - 1)
    def _epilogue():
        out = acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)
        o_ref[0, 0] = out.astype(o_ref.dtype)


def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True, window: int | None = None,
                    softmax_scale: float | None = None,
                    bq: int = 256, bk: int = 256) -> jax.Array:
    """q: (B, H, Sq, D); k/v: (B, KVH, Sk, D) with H % KVH == 0.

    Returns (B, H, Sq, D). Scores/statistics never leave VMEM.
    """
    b, h, sq, d = q.shape
    _, kvh, sk, _ = k.shape
    assert h % kvh == 0, (h, kvh)
    g = h // kvh
    bq = min(bq, sq)
    bk = min(bk, sk)
    assert sq % bq == 0 and sk % bk == 0, (sq, bq, sk, bk)
    scale = softmax_scale or 1.0 / math.sqrt(d)

    kernel = functools.partial(_kernel, scale=scale, causal=causal,
                               window=window, bq=bq, bk=bk)
    return build_pallas_call(
        kernel,
        grid=(b, h, sq // bq, sk // bk),
        in_specs=[
            pl.BlockSpec((1, 1, bq, d), lambda b, h, i, j: (b, h, i, 0)),
            pl.BlockSpec((1, 1, bk, d),
                         lambda b, h, i, j, g=g: (b, h // g, j, 0)),
            pl.BlockSpec((1, 1, bk, d),
                         lambda b, h, i, j, g=g: (b, h // g, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, d), lambda b, h, i, j: (b, h, i, 0)),
        out_shape=jax.ShapeDtypeStruct((b, h, sq, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, d), jnp.float32),   # output accumulator
            pltpu.VMEM((bq, 1), jnp.float32),   # running max
            pltpu.VMEM((bq, 1), jnp.float32),   # running denominator
        ],
        dimension_semantics=("parallel", "parallel", "parallel",
                             "arbitrary"),
        name="flash_attention",
    )(q, k, v)
