"""EmuGEMM-II: fused Ozaki Scheme-II Pallas TPU kernel (paper Sec. IV-A).

One grid axis runs over the p moduli; for each modulus a standard tiled
int8 GEMM accumulates into a single int32 VMEM accumulator, and the
*modular reduction is fused into the epilogue*: the kernel writes only the
int8 residue (paper Eq. 15), never round-tripping the int32 product through
HBM (the 8x write amplification of Eq. 14).

TPU adaptation: residues are emitted in *balanced* form (in [-m/2, m/2)) so
they stay int8 for any m <= 256 on the signed-only MXU path; congruence
mod m is preserved so the downstream CRT is unchanged (DESIGN.md Sec. 2).

The moduli are delivered via scalar prefetch (SMEM) and indexed by the
modulus grid coordinate — the dynamic analogue of the paper's compile-time
modulus constants (one kernel serves all p moduli in a single launch, which
the paper issues as p launches).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels import compat
from repro.kernels.backends.base import build_pallas_call
from repro.kernels.common import Blocks
from repro.kernels.dispatch import select_blocks


def _kernel(mods_ref, a_ref, b_ref, out_ref, acc_ref):
    k = pl.program_id(3)
    m = mods_ref[pl.program_id(0)]

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jax.lax.dot_general(
        a_ref[0], b_ref[0], (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.int32)

    @pl.when(k == pl.num_programs(3) - 1)
    def _epilogue():
        # In-register modular reduction (paper Fig. 3(a)), balanced int8.
        half = m // 2
        bal = jnp.remainder(acc_ref[...] + half, m) - half
        out_ref[0] = bal.astype(jnp.int8)


def fused_residue_matmul(a_res: jax.Array, b_res: jax.Array,
                         moduli, blocks: Blocks | None = None) -> jax.Array:
    """p fused residue GEMMs in one launch.

    a_res: (p, M, K) int8 balanced residues; b_res: (p, K, N).
    Returns (p, M, N) int8 balanced residues of A'B' mod m_l.
    """
    p, m, k = a_res.shape
    _, _, n = b_res.shape
    if blocks is None:
        # Single accumulator (Sec. IV-C); this is a Mosaic kernel — TPU tiles.
        blocks = select_blocks(m, n, k, p=1, backend="tpu")
    if blocks is None or not blocks.aligned(m, n, k):
        raise ValueError(f"no aligned blocks for {(m, n, k)}")
    bm, bn, bk = blocks.bm, blocks.bn, blocks.bk
    mods = jnp.asarray(moduli, dtype=jnp.int32)

    grid_spec = compat.scalar_prefetch_grid_spec(
        num_scalar_prefetch=1,
        grid=(p, m // bm, n // bn, k // bk),
        in_specs=[
            pl.BlockSpec((1, bm, bk), lambda l, i, j, kk, mods: (l, i, kk)),
            pl.BlockSpec((1, bk, bn), lambda l, i, j, kk, mods: (l, kk, j)),
        ],
        out_specs=pl.BlockSpec((1, bm, bn), lambda l, i, j, kk, mods: (l, i, j)),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.int32)],
    )
    return build_pallas_call(
        _kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((p, m, n), jnp.int8),
        dimension_semantics=("arbitrary", "parallel", "parallel",
                             "arbitrary"),
        name=f"emugemm2_p{p}",
    )(mods, a_res, b_res)
