"""Pluggable kernel-backend registry for the emulated-GEMM dispatcher.

Three backends ship built-in:

  ``tpu``  — the Mosaic kernels (ozaki1/ozaki2/ozaki3m/decompose/
             matmul_int8), 128-lane MXU alignment, VMEM budget model.
  ``gpu``  — the Mosaic-GPU/Triton Scheme-I lowering (16-lane tiles,
             shared-memory staging, register/TMEM accumulators);
             interpret-mode runnable on CPU for CI bit-parity checks.
  ``xla``  — the reference expansions in ``repro.core`` (no pallas_call;
             always available; GSPMD-partitionable).

Selection precedence (``resolve_backend``):

  explicit argument > ``REPRO_BACKEND`` env var > ``EmulationConfig
  .backend`` > platform default (the jax backend: 'gpu' on GPU, 'tpu'
  otherwise — CPU runs the TPU kernels in interpret mode, the historical
  behavior).

Names resolve leniently: a platform-qualified name like ``tpu-v5e``
falls back to its family prefix, and unknown names fall back to the
platform default so an exotic ``jax.default_backend()`` string never
crashes block selection (the dispatcher's block cache still buckets by
the *requested* name, keeping entries distinct per target).

Register out-of-tree backends with :func:`register_backend`; the
dispatcher, launch-policy resolution, and roofline projections pick them
up by name.
"""

from __future__ import annotations

import os

import jax

from repro.kernels.backends.base import (  # noqa: F401  (re-export surface)
    BackendCapabilities,
    KernelBackend,
    build_pallas_call,
)
from repro.kernels.backends.gpu import GpuBackend
from repro.kernels.backends.tpu import TpuBackend
from repro.kernels.backends.xla import XlaBackend

ENV_VAR = "REPRO_BACKEND"

_REGISTRY: dict[str, KernelBackend] = {}


def register_backend(backend: KernelBackend, *,
                     overwrite: bool = False) -> KernelBackend:
    """Add a backend to the registry (name taken from ``backend.name``)."""
    name = backend.name
    if not overwrite and name in _REGISTRY:
        raise ValueError(f"backend {name!r} already registered "
                         "(pass overwrite=True to replace)")
    _REGISTRY[name] = backend
    return backend


def unregister_backend(name: str) -> None:
    _REGISTRY.pop(name, None)


def available_backends() -> tuple[str, ...]:
    return tuple(sorted(_REGISTRY))


def get_backend(name: str) -> KernelBackend:
    """Exact-name lookup; raises KeyError for unknown backends."""
    try:
        return _REGISTRY[name]
    except KeyError:
        raise KeyError(f"unknown kernel backend {name!r}; registered: "
                       f"{available_backends()}") from None


def default_backend_name() -> str:
    """Platform default: follow the jax backend, with CPU running the TPU
    kernels in interpret mode (the pre-registry behavior)."""
    return "gpu" if jax.default_backend() == "gpu" else "tpu"


def resolve_backend_name(name: str | None = None, cfg=None) -> str:
    """Apply the selection precedence; always returns a *registered* name."""
    requested = (name
                 or os.environ.get(ENV_VAR)
                 or getattr(cfg, "backend", None)
                 or default_backend_name())
    if requested in _REGISTRY:
        return requested
    # 'tpu-v5e' -> 'tpu'; anything else -> platform default.
    family = requested.split("-")[0]
    if family in _REGISTRY:
        return family
    return default_backend_name()


def resolve_backend(name: str | None = None, cfg=None) -> KernelBackend:
    return _REGISTRY[resolve_backend_name(name, cfg)]


register_backend(TpuBackend())
register_backend(GpuBackend())
register_backend(XlaBackend())
