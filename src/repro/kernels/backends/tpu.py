"""The TPU Mosaic backend: the existing fused kernels, re-registered.

This is the original lowering target of the reproduction — the Mosaic
kernels in ``ozaki1``/``ozaki2``/``ozaki3m``/``decompose``/``matmul_int8``
— wrapped behind the :class:`~repro.kernels.backends.base.KernelBackend`
interface so the dispatcher selects it like any other backend.  Block
selection is the VMEM budget model of :func:`repro.kernels.common
.choose_blocks` (128-lane MXU alignment); peaks key the TPU v5e entry of
``repro.core.traffic.BACKEND_PEAKS``.
"""

from __future__ import annotations

import jax.numpy as jnp

from repro.kernels.backends.base import BackendCapabilities, KernelBackend
from repro.kernels.common import Blocks, VMEM_BUDGET, choose_blocks

ALIGN = 128  # MXU lane/tile alignment on every GEMM dimension.

_CAPS = BackendCapabilities(
    align=ALIGN,
    schemes=frozenset({"ozaki1", "ozaki2"}),
    operand_dtypes=frozenset({"float32", "float64", "bfloat16", "float16",
                              "int8", "int16", "int32"}),
    staging_budget=VMEM_BUDGET,
    accumulator_budget=VMEM_BUDGET,
    peak_key="tpu",
    shardable=True,
    # No strided-batched lowering yet: the Mosaic kernels run a
    # sequential K grid with VMEM scratch accumulators, and a leading
    # batch grid dimension would need the scratch re-zeroed per batch
    # element (dimension_semantics don't express that today).  Batched
    # contractions on this backend keep the vmap fallback.
    batched=False,
)


class TpuBackend(KernelBackend):
    name = "tpu"

    @property
    def capabilities(self) -> BackendCapabilities:
        return _CAPS

    def choose_blocks(self, m, n, k, p, *, out_bytes=4, prologue_a=False,
                      prologue_b=False, fixed_bk=None,
                      scheme="ozaki1") -> Blocks | None:
        # One VMEM model serves every scheme here (the Mosaic Scheme-II
        # kernels run a single live accumulator and re-select with p=1).
        del scheme
        return choose_blocks(m, n, k, p, out_bytes=out_bytes,
                             prologue_a=prologue_a, prologue_b=prologue_b,
                             fixed_bk=fixed_bk)

    def matmul(self, a, b, cfg, out_dtype, blocks):
        from repro.kernels import ops  # lazy: ops imports the kernel modules
        if cfg.scheme == "ozaki1":
            return ops.fused_scheme1_matmul(a, b, cfg, out_dtype=out_dtype,
                                            blocks=blocks)
        if cfg.scheme == "ozaki2":
            if (jnp.issubdtype(a.dtype, jnp.complexfloating)
                    or jnp.issubdtype(b.dtype, jnp.complexfloating)):
                return ops.fused_3m_matmul(a, b, cfg, out_dtype=out_dtype)
            return ops.fused_scheme2_matmul(a, b, cfg, out_dtype=out_dtype)
        raise ValueError(f"tpu backend has no fused kernel for scheme "
                         f"{cfg.scheme!r}")
