"""Mosaic-GPU/Triton backend: fused EmuGEMM-I and EmuGEMM-II for Hopper.

The lowering mirrors the paper's Hopper/Blackwell kernel structure
(Sec. III-B, IV) in the Triton program model rather than the TPU grid
model:

  * one program instance per (bM, bN) output tile — the grid is 2-D,
    with the K reduction as an *in-kernel* loop (``fori_loop``) instead
    of a third grid axis, matching a Triton/Mosaic-GPU persistent-tile
    kernel where accumulators live in registers (RF on Hopper, TMEM on
    Blackwell) for the whole K sweep;
  * each K step loads the fp32 operand tiles once and carves the
    on-chip int8 operands in place — Scheme I carves the p mantissa
    slices via the exact truncate-and-subtract recurrence
    (``carve_slices``), Scheme II carves the p balanced residues via the
    exact integerize + mod recurrence (``scheme2.balanced_residues``).
    The operand BlockSpecs describe the program's full K *strip*, but in
    the Triton lowering a BlockSpec is a GMEM block pointer — only the
    ``pl.ds`` slice loaded inside the K loop materializes on-chip, so
    the shared-memory working set is the per-K-step tile pair that
    ``choose_blocks_gpu`` budgets (interpret mode materializes the strip
    in host memory, which is fine);
  * Scheme I accumulates the p(p+1)/2 slice-pair products into p int32
    register accumulators; Scheme II accumulates one int32 accumulator
    per modulus (3 per modulus for complex 3M) — exact as long as
    K <= (2^31 - 1) / 2^14 (balanced residues are bounded by 128;
    ``scheme2.check_exact_k`` enforces this);
  * the epilogue runs before the single (bM, bN) output write: Scheme I
    does the shift-reduce (paper Eq. 3), Scheme II does the *entire
    residue tail* in registers — ``modular_reduce`` (paper Eq. 7),
    Garner's balanced mixed-radix digits (exact int32 with Python-int
    inverse-table constants), the double-double Horner reconstruction,
    and the inverse power-of-two scaling.  Neither the (p, M, K)
    balanced residues nor the (p, M, N) int32 accumulators of the XLA
    reference ever touch HBM — the data-movement bottleneck the paper's
    Scheme-II fusion targets (Eq. 14 vs 15, Eq. 17 vs 18).

Tiles align to the 16-lane WGMMA/MMA granularity (not the TPU's 128) and
the block search budgets shared memory per K step plus the register/TMEM
accumulator footprint, both residue-count-aware.  On CPU the kernels run
in Pallas interpret mode, which is how CI verifies bit-parity against
the ``scheme1.matmul`` / ``scheme2.matmul`` / ``complex3m.matmul``
oracles; on a real GPU the same kernel bodies lower through
Triton/Mosaic-GPU with feature-probed compiler params
(:func:`repro.kernels.compat.gpu_compiler_params`).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels import compat
from repro.kernels.backends.base import (BackendCapabilities, KernelBackend,
                                         build_pallas_call)
from repro.kernels.common import Blocks, carve_slices

# WGMMA tile granularity: every GEMM dimension aligns to 16 lanes.
ALIGN = 16

# H100-class shared memory per SM is 228 KiB; leave pipeline headroom.
SMEM_BUDGET = 192 * 1024
# Register file / Blackwell TMEM available to the int32 accumulators.
ACC_BUDGET = 128 * 1024

# The fused Scheme-II kernels unroll one MMA + one epilogue chain per
# modulus and keep every balanced residue in int8: the moduli table is
# capped at the default 16 pairwise-coprime moduli <= 256.  Larger or
# wider moduli sets fall back to the 'xla' reference backend.
MAX_MODULI = 16

_CAPS = BackendCapabilities(
    align=ALIGN,
    schemes=frozenset({"ozaki1", "ozaki2"}),
    operand_dtypes=frozenset({"float32", "float64", "bfloat16", "float16"}),
    staging_budget=SMEM_BUDGET,
    accumulator_budget=ACC_BUDGET,
    peak_key="gpu",
    shardable=True,
    batched=True,
)


# Per-scheme resource model of one (bM, bN) program:
#   acc_phases — int32 accumulator sets (1 for Scheme I/II, 3 for 3M),
#   fp_sides   — fp32 operand tiles staged per side (2 for 3M: re + im),
#   res_mult   — carved int8 tiles per side per modulus/slice (3 for 3M:
#                the [re, im, re+im] residue phases),
#   n_out      — output tiles (3M writes re and im),
#   dd_bytes   — the double-double hi/lo pair the Scheme-II CRT
#                epilogue holds per output element (0 for shift-reduce).
_SCHEME_MODEL = {
    #           acc_phases, fp_sides, res_mult, n_out, dd_bytes
    "ozaki1": (1, 1, 1, 1, 0),
    "ozaki2": (1, 1, 1, 1, 8),
    "ozaki2-3m": (3, 2, 3, 2, 8),
}


def choose_blocks_gpu(m: int, n: int, k: int, p: int,
                      out_bytes: int = 4,
                      smem_budget: int = SMEM_BUDGET,
                      acc_budget: int = ACC_BUDGET,
                      fixed_bk: int | None = None,
                      scheme: str = "ozaki1") -> Blocks | None:
    """Largest 16-aligned blocks fitting the SMEM/accumulator budgets.

    The budget models the *per-K-step* working set — what a Triton
    lowering actually materializes on-chip per loop iteration (the
    BlockSpec strip itself is a GMEM block pointer, not an SMEM
    allocation; see the module doc) — and is residue-count-aware: ``p``
    is the slice count (Scheme I) or modulus count (Scheme II), and
    ``scheme`` selects the resource model.  One K step stages the fp32
    operand tiles (double-buffered by the async-copy pipeline) plus the
    carved int8 slices/residues of each:

      S_smem = (2*4 + p) * (bM + bN) * bK          (scheme1 / scheme2)
      S_smem = (2*2*4 + 3p) * (bM + bN) * bK       (complex 3M)

    while the int32 accumulators occupy 4 p bM bN (12 p bM bN for 3M)
    of RF/TMEM and the epilogue tile — output plus the Scheme-II CRT's
    double-double hi/lo pair — shares the staging space.  Preference
    mirrors the TPU search: maximize bM*bN, then bK.
    """
    try:
        acc_phases, fp_sides, res_mult, n_out, dd_bytes = \
            _SCHEME_MODEL[scheme]
    except KeyError:
        raise ValueError(f"choose_blocks_gpu: unknown scheme {scheme!r} "
                         f"(expected one of {sorted(_SCHEME_MODEL)})") \
            from None
    stage = fp_sides * 2 * 4 + res_mult * p
    epi = n_out * out_bytes + dd_bytes
    best: tuple[tuple[int, int], Blocks] | None = None
    bk_candidates = ((fixed_bk,) if fixed_bk is not None
                     else (128, 64, 32, 16))
    for bm in (128, 64, 32, 16):
        if m % bm:
            continue
        for bn in (128, 64, 32, 16):
            if n % bn:
                continue
            for bk in bk_candidates:
                if k % bk:
                    continue
                acc = 4 * acc_phases * p * bm * bn
                smem = stage * (bm + bn) * bk + epi * bm * bn
                if acc > acc_budget or smem > smem_budget:
                    continue
                key = (bm * bn, bk)
                if best is None or key > best[0]:
                    best = (key, Blocks(bm, bn, bk))
    return best[1] if best else None


# ---------------------------------------------------------------------------
# Scheme I: the fused mantissa-slice kernel (PR 3).
# ---------------------------------------------------------------------------

def _kernel(a_ref, b_ref, mu_ref, nu_ref, out_ref, *,
            p: int, beta: int, bk: int, nk: int, out_dtype):
    """One (bM, bN) output tile: in-kernel K loop, register accumulators."""
    mu = mu_ref[...]                 # (bM, 1) power-of-two row scales
    nu = nu_ref[...]                 # (1, bN) power-of-two col scales
    bm, bn = out_ref.shape

    def k_step(t, acc):
        # Stage this K step's fp32 tiles (shared memory) and carve the
        # p int8 slices in-place — elementwise, so tile-local carving is
        # bit-identical to the full-array scheme1.split.
        a_t = a_ref[:, pl.ds(t * bk, bk)] / mu       # (bM, bK)
        b_t = b_ref[pl.ds(t * bk, bk), :] / nu       # (bK, bN)
        a_slices = list(carve_slices(a_t, p, beta))
        b_slices = list(carve_slices(b_t, p, beta))
        # Triangular MMA schedule (Alg. 1 lines 6-8): C_s += A'_i B'_{s-i}.
        for s in range(p):
            partial = None
            for i in range(s + 1):
                prod = jax.lax.dot_general(
                    a_slices[i], b_slices[s - i], (((1,), (0,)), ((), ())),
                    preferred_element_type=jnp.int32)
                partial = prod if partial is None else partial + prod
            acc = acc.at[s].add(partial)
        return acc

    acc = jax.lax.fori_loop(0, nk, k_step,
                            jnp.zeros((p, bm, bn), jnp.int32))

    # Shift-reduce epilogue: C = diag(mu) (sum_s 2^{-beta(s+2)} C_s) diag(nu),
    # summed highest-weight-first exactly like scheme1.shift_reduce.
    c = jnp.zeros((bm, bn), dtype=out_dtype)
    for s in range(p):
        # Exact Python power of two (see scheme1.shift_reduce).
        w = jnp.asarray(2.0 ** (-beta * (s + 2)), dtype=out_dtype)
        c = c + w * acc[s].astype(out_dtype)
    out_ref[...] = c * mu.astype(out_dtype) * nu.astype(out_dtype)


def fused_matmul_scheme1(a: jax.Array, b: jax.Array,
                         mu: jax.Array, nu: jax.Array,
                         p: int, beta: int, blocks: Blocks,
                         out_dtype=jnp.float32) -> jax.Array:
    """Fused Scheme-I GEMM, GPU lowering: a (M, K) x b (K, N) fp32 with
    (M, 1)/(1, N) power-of-two scales -> (M, N) ``out_dtype``."""
    m, k = a.shape
    k2, n = b.shape
    assert k == k2, (a.shape, b.shape)
    if not blocks.aligned(m, n, k):
        raise ValueError(f"blocks {blocks} not aligned for {(m, n, k)}")
    bm, bn, bk = blocks.bm, blocks.bn, blocks.bk
    kernel = functools.partial(_kernel, p=p, beta=beta, bk=bk, nk=k // bk,
                               out_dtype=out_dtype)
    # Unlike the Mosaic kernels (interpret everywhere off-TPU, see
    # common.interpret), this lowering compiles on a real GPU and
    # interprets everywhere else — including TPU hosts, which cannot run
    # a Triton/Mosaic-GPU program.
    return build_pallas_call(
        kernel,
        interpret_mode=jax.default_backend() != "gpu",
        grid=(m // bm, n // bn),
        in_specs=[
            # Each program walks its K strip tile-by-tile (pl.ds above).
            pl.BlockSpec((bm, k), lambda i, j: (i, 0)),
            pl.BlockSpec((k, bn), lambda i, j: (0, j)),
            pl.BlockSpec((bm, 1), lambda i, j: (i, 0)),
            pl.BlockSpec((1, bn), lambda i, j: (0, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), out_dtype),
        compiler_params_fn=compat.gpu_compiler_params,
        num_warps=8,
        num_stages=2,
        name=f"emugemm1_gpu_p{p}",
    )(a, b, mu, nu)


def fused_matmul_scheme1_batched(a: jax.Array, b: jax.Array,
                                 mu: jax.Array, nu: jax.Array,
                                 p: int, beta: int, blocks: Blocks,
                                 out_dtype=jnp.float32) -> jax.Array:
    """Strided-batched fused Scheme-I GEMM: (B, M, K) x (B, K, N) fp32
    with (B, M, 1)/(B, 1, N) power-of-two scales -> (B, M, N) in ONE
    ``pallas_call``.

    The grid grows a third (leading) dimension over batch and every
    BlockSpec squeezes it with a ``None`` block dim — each program
    instance therefore sees exactly the 2-D refs of the non-batched
    launch and runs the *same* kernel body (``_kernel``), which is what
    makes the batched lowering bit-identical to vmapping
    :func:`fused_matmul_scheme1` by construction.  What changes is the
    launch economics: one kernel launch instead of B, and the operand
    blocks are addressed with a batch stride (cuBLAS
    ``gemm_strided_batched`` layout) rather than re-described per
    element.
    """
    batch, m, k = a.shape
    b2, k2, n = b.shape
    assert (batch, k) == (b2, k2), (a.shape, b.shape)
    if not blocks.aligned(m, n, k):
        raise ValueError(f"blocks {blocks} not aligned for {(m, n, k)}")
    bm, bn, bk = blocks.bm, blocks.bn, blocks.bk
    kernel = functools.partial(_kernel, p=p, beta=beta, bk=bk, nk=k // bk,
                               out_dtype=out_dtype)
    return build_pallas_call(
        kernel,
        interpret_mode=jax.default_backend() != "gpu",
        grid=(batch, m // bm, n // bn),
        in_specs=[
            pl.BlockSpec((None, bm, k), lambda bb, i, j: (bb, i, 0)),
            pl.BlockSpec((None, k, bn), lambda bb, i, j: (bb, 0, j)),
            pl.BlockSpec((None, bm, 1), lambda bb, i, j: (bb, i, 0)),
            pl.BlockSpec((None, 1, bn), lambda bb, i, j: (bb, 0, j)),
        ],
        out_specs=pl.BlockSpec((None, bm, bn), lambda bb, i, j: (bb, i, j)),
        out_shape=jax.ShapeDtypeStruct((batch, m, n), out_dtype),
        compiler_params_fn=compat.gpu_compiler_params,
        num_warps=8,
        num_stages=2,
        name=f"emugemm1_gpu_p{p}_b{batch}",
    )(a, b, mu, nu)


# ---------------------------------------------------------------------------
# Scheme II: the fused residue pipeline.
# ---------------------------------------------------------------------------

def _carve_residues(x_int: jax.Array, moduli) -> jax.Array:
    """Balanced int8 residues of an exact-integer float tile.

    Defers to ``scheme2.balanced_residues`` — the elementwise integer
    recurrence is tile-local, so the in-kernel carve is bit-identical to
    the full-array encode of the XLA reference.
    """
    from repro.core import scheme2
    return scheme2.balanced_residues(x_int, moduli)


def _crt_epilogue(acc, moduli, out_dtype):
    """(p, bM, bN) int32 accumulators -> reconstructed integer tile.

    The entire residue tail of the reference pipeline — ``modular_reduce``
    (Eq. 7), balanced Garner digits, double-double mixed-radix Horner —
    runs in registers.  All moduli and inverse-table constants enter as
    exact Python ints (``garner_constants``), so there is no eager-exp2
    style constant hazard; every op is exact integer / IEEE arithmetic
    and therefore bit-identical to the full-array reference restricted
    to this tile.
    """
    from repro.core import scheme2
    c_res = scheme2.modular_reduce(acc, moduli)
    return scheme2.crt_reconstruct(c_res, moduli, out_dtype)


def _kernel2(a_ref, b_ref, mu_ref, nu_ref, out_ref, *,
             moduli, bk: int, nk: int, out_dtype, b_res: bool):
    """One (bM, bN) tile of the fused Scheme-II pipeline: integerize +
    residue-carve prologue, p modular int8 MMAs per K step into p int32
    register accumulators, modular reduction + Garner + double-double
    CRT epilogue — one store, nothing else leaves the chip.

    ``b_res`` switches the rhs to a pre-encoded residue operand (a
    :class:`repro.kernels.prepared.PreparedResidues` weight): its
    (p, K, N) int8 residues stream directly and the prologue skips the
    rhs encode.
    """
    p = len(moduli)
    mu = mu_ref[...]                 # (bM, 1) power-of-two int scales
    nu = nu_ref[...]                 # (1, bN)
    bm, bn = out_ref.shape

    def k_step(t, acc):
        # Integerize the staged fp32 tiles (trunc of the power-of-two
        # scaled operand — exact, mirrors scheme2.integerize) and carve
        # the balanced residues of all p moduli from the one staged read.
        a_t = jnp.trunc(a_ref[:, pl.ds(t * bk, bk)] * mu)     # (bM, bK)
        a_res = _carve_residues(a_t, moduli)                  # (p, bM, bK)
        if b_res:
            b_sl = [b_ref[l, pl.ds(t * bk, bk), :] for l in range(p)]
        else:
            b_t = jnp.trunc(b_ref[pl.ds(t * bk, bk), :] * nu)
            b_stack = _carve_residues(b_t, moduli)
            b_sl = [b_stack[l] for l in range(p)]
        for l in range(p):
            prod = jax.lax.dot_general(
                a_res[l], b_sl[l], (((1,), (0,)), ((), ())),
                preferred_element_type=jnp.int32)
            acc = acc.at[l].add(prod)
        return acc

    acc = jax.lax.fori_loop(0, nk, k_step,
                            jnp.zeros((p, bm, bn), jnp.int32))
    c_int = _crt_epilogue(acc, moduli, out_dtype)
    out_ref[...] = c_int / (mu.astype(out_dtype) * nu.astype(out_dtype))


def fused_matmul_scheme2(a: jax.Array, b: jax.Array,
                         mu: jax.Array, nu: jax.Array,
                         moduli, blocks: Blocks,
                         out_dtype=jnp.float32) -> jax.Array:
    """Fused Scheme-II GEMM, GPU lowering.

    a: (M, K) float; b: (K, N) float, or (p, K, N) int8 pre-encoded
    balanced residues (the PreparedResidues consumption path — the
    prologue then skips the rhs encode).  mu: (M, 1) / nu: (1, N)
    power-of-two integerization scales (full-K reductions, computed by
    the caller at the shared operand budget).
    """
    moduli = tuple(int(mm) for mm in moduli)
    p = len(moduli)
    m, k = a.shape
    b_is_res = b.ndim == 3
    if b_is_res:
        pb, k2, n = b.shape
        assert pb == p, (b.shape, p)
    else:
        k2, n = b.shape
    assert k == k2, (a.shape, b.shape)
    if not blocks.aligned(m, n, k):
        raise ValueError(
            f"fused gpu ozaki2 kernel: blocks {blocks} not aligned for "
            f"{(m, n, k)}")
    bm, bn, bk = blocks.bm, blocks.bn, blocks.bk
    kernel = functools.partial(_kernel2, moduli=moduli, bk=bk, nk=k // bk,
                               out_dtype=out_dtype, b_res=b_is_res)
    b_spec = (pl.BlockSpec((p, k, bn), lambda i, j: (0, 0, j)) if b_is_res
              else pl.BlockSpec((k, bn), lambda i, j: (0, j)))
    return build_pallas_call(
        kernel,
        interpret_mode=jax.default_backend() != "gpu",
        grid=(m // bm, n // bn),
        in_specs=[
            pl.BlockSpec((bm, k), lambda i, j: (i, 0)),
            b_spec,
            pl.BlockSpec((bm, 1), lambda i, j: (i, 0)),
            pl.BlockSpec((1, bn), lambda i, j: (0, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), out_dtype),
        compiler_params_fn=compat.gpu_compiler_params,
        num_warps=8,
        num_stages=2,
        name=f"emugemm2_gpu_p{p}{'_prep' if b_is_res else ''}",
    )(a, b, mu, nu)


def fused_matmul_scheme2_batched(a: jax.Array, b: jax.Array,
                                 mu: jax.Array, nu: jax.Array,
                                 moduli, blocks: Blocks,
                                 out_dtype=jnp.float32) -> jax.Array:
    """Strided-batched fused Scheme-II GEMM: (B, M, K) x (B, K, N) float
    with (B, M, 1)/(B, 1, N) power-of-two integerization scales
    -> (B, M, N) in ONE ``pallas_call``.

    Same construction as :func:`fused_matmul_scheme1_batched`: a leading
    batch grid dimension whose BlockSpecs squeeze it away, so each
    program runs the unchanged 2-D residue pipeline (``_kernel2`` —
    integerize + balanced-residue carve, p modular int8 MMAs per K step,
    the full Garner/double-double CRT tail in the epilogue) and the
    result is bit-identical to vmapping :func:`fused_matmul_scheme2`.
    Pre-encoded (p, K, N) residue operands are per-weight, not
    per-batch-element — the prepared consumption path stays on the
    2-D kernel (one shared rhs never needs a batch stride).
    """
    moduli = tuple(int(mm) for mm in moduli)
    p = len(moduli)
    batch, m, k = a.shape
    b2, k2, n = b.shape
    assert (batch, k) == (b2, k2), (a.shape, b.shape)
    if not blocks.aligned(m, n, k):
        raise ValueError(
            f"fused gpu ozaki2 batched kernel: blocks {blocks} not aligned "
            f"for {(m, n, k)}")
    bm, bn, bk = blocks.bm, blocks.bn, blocks.bk
    kernel = functools.partial(_kernel2, moduli=moduli, bk=bk, nk=k // bk,
                               out_dtype=out_dtype, b_res=False)
    return build_pallas_call(
        kernel,
        interpret_mode=jax.default_backend() != "gpu",
        grid=(batch, m // bm, n // bn),
        in_specs=[
            pl.BlockSpec((None, bm, k), lambda bb, i, j: (bb, i, 0)),
            pl.BlockSpec((None, k, bn), lambda bb, i, j: (bb, 0, j)),
            pl.BlockSpec((None, bm, 1), lambda bb, i, j: (bb, i, 0)),
            pl.BlockSpec((None, 1, bn), lambda bb, i, j: (bb, 0, j)),
        ],
        out_specs=pl.BlockSpec((None, bm, bn), lambda bb, i, j: (bb, i, j)),
        out_shape=jax.ShapeDtypeStruct((batch, m, n), out_dtype),
        compiler_params_fn=compat.gpu_compiler_params,
        num_warps=8,
        num_stages=2,
        name=f"emugemm2_gpu_p{p}_b{batch}",
    )(a, b, mu, nu)


def _kernel2_3m(ar_ref, ai_ref, br_ref, bi_ref, mu_ref, nu_ref,
                out_re_ref, out_im_ref, *,
                moduli, bk: int, nk: int, out_dtype):
    """One (bM, bN) tile of the fused complex-3M Scheme-II pipeline.

    The three residue phases ([re, im, re+im], paper Sec. IV-B) are
    carved from *one* staged read of the re/im fp32 tile pair — the sum
    phase is re-balanced on-chip (``complex3m._balanced``) — and feed
    3p modular MMAs per K step into (3, p) int32 register accumulators.
    The epilogue forms the exact modular 3M combination

        C'_re = T1 - T2 ,  C'_im = T3 - T1 - T2    (mod m_l)

    then runs two full CRT reconstructions in registers and writes only
    the two scaled output tiles (paper Eq. 18 — the 24MN int32
    round-trip term of Eq. 17 vanishes).
    """
    from repro.core import complex3m
    p = len(moduli)
    mu = mu_ref[...]
    nu = nu_ref[...]
    bm, bn = out_re_ref.shape

    def k_step(t, acc):
        ks = pl.ds(t * bk, bk)
        ar_res = _carve_residues(jnp.trunc(ar_ref[:, ks] * mu), moduli)
        ai_res = _carve_residues(jnp.trunc(ai_ref[:, ks] * mu), moduli)
        br_res = _carve_residues(jnp.trunc(br_ref[ks, :] * nu), moduli)
        bi_res = _carve_residues(jnp.trunc(bi_ref[ks, :] * nu), moduli)
        for l, mm in enumerate(moduli):
            as_res = complex3m._balanced(
                ar_res[l].astype(jnp.int32) + ai_res[l].astype(jnp.int32),
                mm)
            bs_res = complex3m._balanced(
                br_res[l].astype(jnp.int32) + bi_res[l].astype(jnp.int32),
                mm)
            pairs = ((ar_res[l], br_res[l]), (ai_res[l], bi_res[l]),
                     (as_res, bs_res))
            for t_i, (x8, y8) in enumerate(pairs):
                prod = jax.lax.dot_general(
                    x8, y8, (((1,), (0,)), ((), ())),
                    preferred_element_type=jnp.int32)
                acc = acc.at[t_i, l].add(prod)
        return acc

    acc = jax.lax.fori_loop(0, nk, k_step,
                            jnp.zeros((3, p, bm, bn), jnp.int32))

    # Exact modular 3M combination per modulus (mirrors complex3m.matmul).
    c_re_res, c_im_res = [], []
    for l, mm in enumerate(moduli):
        t1m = jnp.remainder(acc[0, l], mm)
        t2m = jnp.remainder(acc[1, l], mm)
        t3m = jnp.remainder(acc[2, l], mm)
        c_re_res.append(jnp.remainder(t1m - t2m, mm).astype(jnp.int32))
        c_im_res.append(jnp.remainder(t3m - t1m - t2m, mm).astype(jnp.int32))
    from repro.core import scheme2
    c_re = scheme2.crt_reconstruct(jnp.stack(c_re_res), moduli, out_dtype)
    c_im = scheme2.crt_reconstruct(jnp.stack(c_im_res), moduli, out_dtype)
    inv = 1.0 / (mu.astype(out_dtype) * nu.astype(out_dtype))
    out_re_ref[...] = c_re * inv
    out_im_ref[...] = c_im * inv


def fused_matmul_3m(ar, ai, br, bi, mu, nu, moduli, blocks: Blocks,
                    out_dtype=jnp.float32):
    """Fused complex-3M Scheme-II GEMM, GPU lowering.

    ar/ai: (M, K) float real/imaginary parts; br/bi: (K, N); mu/nu the
    shared per-row/col power-of-two integerization scales.  Returns
    (c_re, c_im) real ``out_dtype`` arrays — the caller assembles the
    complex result (and divides nothing: the inverse scaling runs in
    the epilogue).
    """
    moduli = tuple(int(mm) for mm in moduli)
    p = len(moduli)
    m, k = ar.shape
    k2, n = br.shape
    assert k == k2, (ar.shape, br.shape)
    if not blocks.aligned(m, n, k):
        raise ValueError(
            f"fused gpu ozaki2 3M kernel: blocks {blocks} not aligned for "
            f"{(m, n, k)}")
    bm, bn, bk = blocks.bm, blocks.bn, blocks.bk
    kernel = functools.partial(_kernel2_3m, moduli=moduli, bk=bk,
                               nk=k // bk, out_dtype=out_dtype)
    a_spec = pl.BlockSpec((bm, k), lambda i, j: (i, 0))
    b_spec = pl.BlockSpec((k, bn), lambda i, j: (0, j))
    return build_pallas_call(
        kernel,
        interpret_mode=jax.default_backend() != "gpu",
        grid=(m // bm, n // bn),
        in_specs=[
            a_spec, a_spec, b_spec, b_spec,
            pl.BlockSpec((bm, 1), lambda i, j: (i, 0)),
            pl.BlockSpec((1, bn), lambda i, j: (0, j)),
        ],
        out_specs=[
            pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
            pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        ],
        out_shape=[jax.ShapeDtypeStruct((m, n), out_dtype),
                   jax.ShapeDtypeStruct((m, n), out_dtype)],
        compiler_params_fn=compat.gpu_compiler_params,
        num_warps=8,
        num_stages=2,
        name=f"emugemm2_3m_gpu_p{p}",
    )(ar, ai, br, bi, mu, nu)


def supported_moduli(moduli) -> bool:
    """Can the fused GPU Scheme-II kernels lower this moduli set?"""
    moduli = tuple(int(mm) for mm in moduli)
    return 0 < len(moduli) <= MAX_MODULI and max(moduli) <= 256


def _widen(x):
    # Match scheme1.split: ints and half floats widen to f32 before the
    # truncate-subtract recurrence; f64 keeps its mantissa.
    if (not jnp.issubdtype(x.dtype, jnp.floating)
            or jnp.dtype(x.dtype).itemsize < 4):
        return x.astype(jnp.float32)
    return x


def _float_or_f32(x):
    # Match scheme2.matmul/complex3m.matmul: floats keep their dtype
    # (the whole integerize chain runs in it), everything else -> f32.
    if jnp.issubdtype(x.dtype, jnp.floating):
        return x
    return x.astype(jnp.float32)


class GpuBackend(KernelBackend):
    name = "gpu"

    @property
    def capabilities(self) -> BackendCapabilities:
        return _CAPS

    def choose_blocks(self, m, n, k, p, *, out_bytes=4, prologue_a=False,
                      prologue_b=False, fixed_bk=None,
                      scheme="ozaki1") -> Blocks | None:
        # The GPU kernels always decompose in the prologue (fp32 staged
        # in SMEM, slices/residues carved in place), so the prologue
        # flags are moot; ``scheme`` selects the residue-count-aware
        # resource model instead.
        del prologue_a, prologue_b
        return choose_blocks_gpu(m, n, k, p, out_bytes=out_bytes,
                                 fixed_bk=fixed_bk, scheme=scheme)

    def supports(self, cfg, a_dtype=None, b_dtype=None) -> bool:
        if not super().supports(cfg, a_dtype, b_dtype):
            return False
        if cfg.scheme == "ozaki2":
            # The fused kernels unroll per modulus and carry balanced
            # int8 residues: moduli beyond the 16-entry <=256 table have
            # no lowering here (dispatch falls back to 'xla').
            return supported_moduli(cfg.resolved_moduli())
        return True

    def matmul(self, a, b, cfg, out_dtype, blocks):
        if cfg.scheme == "ozaki1":
            return self._matmul_scheme1(a, b, cfg, out_dtype, blocks)
        if cfg.scheme == "ozaki2":
            cplx = (jnp.issubdtype(a.dtype, jnp.complexfloating)
                    or jnp.issubdtype(b.dtype, jnp.complexfloating))
            if cplx:
                return self._matmul_3m(a, b, cfg, out_dtype, blocks)
            return self._matmul_scheme2(a, b, cfg, out_dtype, blocks)
        raise ValueError(f"gpu backend has no fused kernel for scheme "
                         f"{cfg.scheme!r}")

    def matmul_batched(self, a, b, cfg, out_dtype, blocks):
        if cfg.scheme == "ozaki1":
            return self._matmul_scheme1_batched(a, b, cfg, out_dtype, blocks)
        if cfg.scheme == "ozaki2":
            if (jnp.issubdtype(a.dtype, jnp.complexfloating)
                    or jnp.issubdtype(b.dtype, jnp.complexfloating)):
                # The 3M kernel's three residue phases would triple the
                # grid bookkeeping; complex batches stay on the vmap
                # fallback until there is a workload that needs them.
                raise NotImplementedError(
                    "gpu backend: no strided-batched complex-3M lowering")
            return self._matmul_scheme2_batched(a, b, cfg, out_dtype, blocks)
        raise ValueError(f"gpu backend has no fused batched kernel for "
                         f"scheme {cfg.scheme!r}")

    def _matmul_scheme1_batched(self, a, b, cfg, out_dtype, blocks):
        from repro.core import scheme1
        batch, m, k = a.shape
        _, _, n = b.shape
        beta = cfg.resolved_beta(k)
        if blocks is None:
            blocks = self.choose_blocks(
                m, n, k, cfg.p, out_bytes=jnp.dtype(out_dtype).itemsize)
        if blocks is None or not blocks.aligned(m, n, k):
            raise ValueError(
                f"fused gpu ozaki1 batched kernel: shapes {(m, n, k)} not "
                "16-aligned (dispatch pads automatically)")
        a, b = _widen(a), _widen(b)
        # One scale pass over the whole stack: keepdims reductions give
        # (B, M, 1) / (B, 1, N), exactly the per-element scales the
        # vmapped 2-D launch computes B times.
        mu = scheme1._pow2_row_scale(a, axis=-1)
        nu = scheme1._pow2_row_scale(b, axis=1)
        return fused_matmul_scheme1_batched(a, b, mu, nu, cfg.p, beta,
                                            blocks, out_dtype=out_dtype)

    def _matmul_scheme2_batched(self, a, b, cfg, out_dtype, blocks):
        from repro.core import scheme2
        from repro.core.precision import scheme2_budget
        moduli = cfg.resolved_moduli()
        self._check_moduli(moduli)
        batch, m, k = a.shape
        _, _, n = b.shape
        scheme2.check_exact_k(k, moduli)
        if blocks is None or not blocks.aligned(m, n, k):
            blocks = self.choose_blocks(
                m, n, k, len(moduli),
                out_bytes=jnp.dtype(out_dtype).itemsize, scheme="ozaki2")
        if blocks is None or not blocks.aligned(m, n, k):
            raise ValueError(
                f"fused gpu ozaki2 batched kernel: shapes {(m, n, k)} not "
                "16-aligned (dispatch pads automatically)")
        a, b = _float_or_f32(a), _float_or_f32(b)
        budget = scheme2_budget(moduli, k)
        budget = min(budget, jnp.finfo(a.dtype).nmant + 1)
        mu = scheme2._pow2_int_scale(a, axis=-1, budget_bits=budget)
        nu = scheme2._pow2_int_scale(b, axis=1, budget_bits=budget)
        return fused_matmul_scheme2_batched(a, b, mu, nu, moduli, blocks,
                                            out_dtype=out_dtype)

    def _matmul_scheme1(self, a, b, cfg, out_dtype, blocks):
        from repro.core import scheme1  # lazy: keep import graph acyclic
        m, k = a.shape
        _, n = b.shape
        beta = cfg.resolved_beta(k)
        if blocks is None:
            blocks = self.choose_blocks(
                m, n, k, cfg.p, out_bytes=jnp.dtype(out_dtype).itemsize)
        if blocks is None or not blocks.aligned(m, n, k):
            raise ValueError(
                f"fused gpu ozaki1 kernel: shapes {(m, n, k)} not "
                "16-aligned (dispatch.emulated_matmul pads automatically)")
        a, b = _widen(a), _widen(b)
        mu = scheme1._pow2_row_scale(a, axis=1)
        nu = scheme1._pow2_row_scale(b, axis=0)
        return fused_matmul_scheme1(a, b, mu, nu, cfg.p, beta, blocks,
                                    out_dtype=out_dtype)

    def _check_moduli(self, moduli):
        if not supported_moduli(moduli):
            raise ValueError(
                f"fused gpu ozaki2 kernel supports at most {MAX_MODULI} "
                f"moduli, each <= 256 (balanced int8 residues); got "
                f"{len(moduli)} moduli, max {max(moduli)} — larger counts "
                "fall back to the 'xla' reference backend (moduli > 256 "
                "have no int8 residue representation on any backend)")

    def _matmul_scheme2(self, a, b, cfg, out_dtype, blocks):
        from repro.core import scheme2
        from repro.core.precision import scheme2_budget
        moduli = cfg.resolved_moduli()
        self._check_moduli(moduli)
        m, k = a.shape
        _, n = b.shape
        scheme2.check_exact_k(k, moduli)
        if blocks is None or not blocks.aligned(m, n, k):
            blocks = self.choose_blocks(
                m, n, k, len(moduli),
                out_bytes=jnp.dtype(out_dtype).itemsize, scheme="ozaki2")
        if blocks is None or not blocks.aligned(m, n, k):
            raise ValueError(
                f"fused gpu ozaki2 kernel: shapes {(m, n, k)} not "
                "16-aligned (dispatch.emulated_matmul pads automatically)")
        # Mirror scheme2.matmul exactly: no widening — the oracle
        # integerizes in the operand's own dtype (a bf16 exp2 scale is
        # not even an exact power of two, so a widened-f32 interior
        # would diverge bitwise) and caps the shared budget at that
        # dtype's mantissa.  Only non-float operands cast to f32.
        a, b = _float_or_f32(a), _float_or_f32(b)
        budget = scheme2_budget(moduli, k)
        budget = min(budget, jnp.finfo(a.dtype).nmant + 1)
        mu = scheme2._pow2_int_scale(a, axis=1, budget_bits=budget)
        nu = scheme2._pow2_int_scale(b, axis=0, budget_bits=budget)
        return fused_matmul_scheme2(a, b, mu, nu, moduli, blocks,
                                    out_dtype=out_dtype)

    def _matmul_3m(self, a, b, cfg, out_dtype, blocks=None):
        from repro.core import scheme2
        from repro.core.precision import scheme2_budget
        moduli = cfg.resolved_moduli()
        self._check_moduli(moduli)
        m, k = a.shape
        _, n = b.shape
        scheme2.check_exact_k(k, moduli)
        # The dispatcher's plan already selected (and cached) blocks with
        # the phase-aware 'ozaki2-3m' model; re-select only without one.
        if blocks is None or not blocks.aligned(m, n, k):
            blocks = self.choose_blocks(
                m, n, k, len(moduli),
                out_bytes=jnp.dtype(out_dtype).itemsize, scheme="ozaki2-3m")
        if blocks is None or not blocks.aligned(m, n, k):
            raise ValueError(
                f"fused gpu ozaki2 3M kernel: shapes {(m, n, k)} not "
                "16-aligned (dispatch.emulated_matmul pads automatically)")
        budget = scheme2_budget(moduli, k, complex_guard=True)
        real_t = jnp.real(a).dtype
        budget = min(budget, jnp.finfo(real_t).nmant + 1)
        ar, ai = _widen(jnp.real(a)), _widen(jnp.imag(a))
        br, bi = _widen(jnp.real(b)), _widen(jnp.imag(b))
        # One power-of-two scale per row/col shared by re/im parts
        # (mirrors complex3m.matmul).
        mu = scheme2._pow2_int_scale(jnp.maximum(jnp.abs(ar), jnp.abs(ai)),
                                     axis=1, budget_bits=budget)
        nu = scheme2._pow2_int_scale(jnp.maximum(jnp.abs(br), jnp.abs(bi)),
                                     axis=0, budget_bits=budget)
        c_re, c_im = fused_matmul_3m(ar, ai, br, bi, mu, nu, moduli,
                                     blocks, out_dtype=out_dtype)
        return jax.lax.complex(c_re, c_im)
