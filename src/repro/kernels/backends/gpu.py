"""Mosaic-GPU/Triton Scheme-I backend: fused EmuGEMM-I for Hopper-class GPUs.

The lowering mirrors the paper's Hopper/Blackwell kernel structure
(Sec. III-B) in the Triton program model rather than the TPU grid model:

  * one program instance per (bM, bN) output tile — the grid is 2-D,
    with the K reduction as an *in-kernel* loop (``fori_loop``) instead
    of a third grid axis, matching a Triton/Mosaic-GPU persistent-tile
    kernel where accumulators live in registers (RF on Hopper, TMEM on
    Blackwell) for the whole K sweep;
  * each K step loads a (bM, bK) + (bK, bN) fp32 tile and carves the p
    signed int8 slices in-place via the exact truncate-and-subtract
    recurrence (``carve_slices`` — the same recurrence the TPU prologue
    and ``scheme1.split`` run, so the GPU path is bit-identical to the
    ``scheme1.matmul`` oracle).  The operand BlockSpecs describe the
    program's full K *strip*, but in the Triton lowering a BlockSpec is
    a GMEM block pointer — only the ``pl.ds`` slice loaded inside the K
    loop materializes on-chip, so the shared-memory working set is the
    per-K-step tile pair that ``choose_blocks_gpu`` budgets (interpret
    mode materializes the strip in host memory, which is fine);
  * the p(p+1)/2 slice-pair products accumulate into p int32 register
    accumulators (exact: safe_beta bounds the K-long dot below 2^31);
  * the shift-reduce epilogue (paper Eq. 3) runs before the single
    (bM, bN) output write — no int32 round-trips to HBM.

Tiles align to the 16-lane WGMMA/MMA granularity (not the TPU's 128) and
the block search budgets shared memory per K step plus the register/TMEM
accumulator footprint.  On CPU the kernel runs in Pallas interpret mode,
which is how CI verifies bit-parity against ``scheme1.matmul``; on a real
GPU the same kernel body lowers through Triton/Mosaic-GPU with
feature-probed compiler params (:func:`repro.kernels.compat
.gpu_compiler_params`).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels import compat
from repro.kernels.backends.base import (BackendCapabilities, KernelBackend,
                                         build_pallas_call)
from repro.kernels.common import Blocks, carve_slices

# WGMMA tile granularity: every GEMM dimension aligns to 16 lanes.
ALIGN = 16

# H100-class shared memory per SM is 228 KiB; leave pipeline headroom.
SMEM_BUDGET = 192 * 1024
# Register file / Blackwell TMEM available to the p int32 accumulators.
ACC_BUDGET = 128 * 1024

_CAPS = BackendCapabilities(
    align=ALIGN,
    schemes=frozenset({"ozaki1"}),
    operand_dtypes=frozenset({"float32", "float64", "bfloat16", "float16"}),
    staging_budget=SMEM_BUDGET,
    accumulator_budget=ACC_BUDGET,
    peak_key="gpu",
)


def choose_blocks_gpu(m: int, n: int, k: int, p: int,
                      out_bytes: int = 4,
                      smem_budget: int = SMEM_BUDGET,
                      acc_budget: int = ACC_BUDGET,
                      fixed_bk: int | None = None) -> Blocks | None:
    """Largest 16-aligned blocks fitting the SMEM/accumulator budgets.

    The budget models the *per-K-step* working set — what a Triton
    lowering actually materializes on-chip per loop iteration (the
    BlockSpec strip itself is a GMEM block pointer, not an SMEM
    allocation; see the module doc).  One K step stages the fp32 operand
    tiles (double-buffered by the async-copy pipeline) plus the p carved
    int8 slices of each:

      S_smem = (2*4 + p) * (bM + bN) * bK

    while the p int32 accumulators occupy 4 p bM bN of RF/TMEM and the
    epilogue tile ``out_bytes * bM * bN`` shares the staging space.
    Preference mirrors the TPU search: maximize bM*bN, then bK.
    """
    best: tuple[tuple[int, int], Blocks] | None = None
    bk_candidates = ((fixed_bk,) if fixed_bk is not None
                     else (128, 64, 32, 16))
    for bm in (128, 64, 32, 16):
        if m % bm:
            continue
        for bn in (128, 64, 32, 16):
            if n % bn:
                continue
            for bk in bk_candidates:
                if k % bk:
                    continue
                acc = 4 * p * bm * bn
                smem = (2 * 4 + p) * (bm + bn) * bk + out_bytes * bm * bn
                if acc > acc_budget or smem > smem_budget:
                    continue
                key = (bm * bn, bk)
                if best is None or key > best[0]:
                    best = (key, Blocks(bm, bn, bk))
    return best[1] if best else None


def _kernel(a_ref, b_ref, mu_ref, nu_ref, out_ref, *,
            p: int, beta: int, bk: int, nk: int, out_dtype):
    """One (bM, bN) output tile: in-kernel K loop, register accumulators."""
    mu = mu_ref[...]                 # (bM, 1) power-of-two row scales
    nu = nu_ref[...]                 # (1, bN) power-of-two col scales
    bm, bn = out_ref.shape

    def k_step(t, acc):
        # Stage this K step's fp32 tiles (shared memory) and carve the
        # p int8 slices in-place — elementwise, so tile-local carving is
        # bit-identical to the full-array scheme1.split.
        a_t = a_ref[:, pl.ds(t * bk, bk)] / mu       # (bM, bK)
        b_t = b_ref[pl.ds(t * bk, bk), :] / nu       # (bK, bN)
        a_slices = list(carve_slices(a_t, p, beta))
        b_slices = list(carve_slices(b_t, p, beta))
        # Triangular MMA schedule (Alg. 1 lines 6-8): C_s += A'_i B'_{s-i}.
        for s in range(p):
            partial = None
            for i in range(s + 1):
                prod = jax.lax.dot_general(
                    a_slices[i], b_slices[s - i], (((1,), (0,)), ((), ())),
                    preferred_element_type=jnp.int32)
                partial = prod if partial is None else partial + prod
            acc = acc.at[s].add(partial)
        return acc

    acc = jax.lax.fori_loop(0, nk, k_step,
                            jnp.zeros((p, bm, bn), jnp.int32))

    # Shift-reduce epilogue: C = diag(mu) (sum_s 2^{-beta(s+2)} C_s) diag(nu),
    # summed highest-weight-first exactly like scheme1.shift_reduce.
    c = jnp.zeros((bm, bn), dtype=out_dtype)
    for s in range(p):
        # Exact Python power of two (see scheme1.shift_reduce).
        w = jnp.asarray(2.0 ** (-beta * (s + 2)), dtype=out_dtype)
        c = c + w * acc[s].astype(out_dtype)
    out_ref[...] = c * mu.astype(out_dtype) * nu.astype(out_dtype)


def fused_matmul_scheme1(a: jax.Array, b: jax.Array,
                         mu: jax.Array, nu: jax.Array,
                         p: int, beta: int, blocks: Blocks,
                         out_dtype=jnp.float32) -> jax.Array:
    """Fused Scheme-I GEMM, GPU lowering: a (M, K) x b (K, N) fp32 with
    (M, 1)/(1, N) power-of-two scales -> (M, N) ``out_dtype``."""
    m, k = a.shape
    k2, n = b.shape
    assert k == k2, (a.shape, b.shape)
    if not blocks.aligned(m, n, k):
        raise ValueError(f"blocks {blocks} not aligned for {(m, n, k)}")
    bm, bn, bk = blocks.bm, blocks.bn, blocks.bk
    kernel = functools.partial(_kernel, p=p, beta=beta, bk=bk, nk=k // bk,
                               out_dtype=out_dtype)
    # Unlike the Mosaic kernels (interpret everywhere off-TPU, see
    # common.interpret), this lowering compiles on a real GPU and
    # interprets everywhere else — including TPU hosts, which cannot run
    # a Triton/Mosaic-GPU program.
    return build_pallas_call(
        kernel,
        interpret_mode=jax.default_backend() != "gpu",
        grid=(m // bm, n // bn),
        in_specs=[
            # Each program walks its K strip tile-by-tile (pl.ds above).
            pl.BlockSpec((bm, k), lambda i, j: (i, 0)),
            pl.BlockSpec((k, bn), lambda i, j: (0, j)),
            pl.BlockSpec((bm, 1), lambda i, j: (i, 0)),
            pl.BlockSpec((1, bn), lambda i, j: (0, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), out_dtype),
        compiler_params_fn=compat.gpu_compiler_params,
        num_warps=8,
        num_stages=2,
        name=f"emugemm1_gpu_p{p}",
    )(a, b, mu, nu)


class GpuBackend(KernelBackend):
    name = "gpu"

    @property
    def capabilities(self) -> BackendCapabilities:
        return _CAPS

    def choose_blocks(self, m, n, k, p, *, out_bytes=4, prologue_a=False,
                      prologue_b=False, fixed_bk=None) -> Blocks | None:
        # The GPU kernel always decomposes in the prologue (fp32 staged in
        # SMEM, slices carved in-place), so the prologue flags are moot.
        del prologue_a, prologue_b
        return choose_blocks_gpu(m, n, k, p, out_bytes=out_bytes,
                                 fixed_bk=fixed_bk)

    def matmul(self, a, b, cfg, out_dtype, blocks):
        if cfg.scheme != "ozaki1":
            raise ValueError(f"gpu backend has no fused kernel for scheme "
                             f"{cfg.scheme!r}")
        from repro.core import scheme1  # lazy: keep import graph acyclic
        m, k = a.shape
        _, n = b.shape
        beta = cfg.resolved_beta(k)
        if blocks is None:
            blocks = self.choose_blocks(
                m, n, k, cfg.p, out_bytes=jnp.dtype(out_dtype).itemsize)
        if blocks is None or not blocks.aligned(m, n, k):
            raise ValueError(f"shapes {(m, n, k)} not 16-aligned")

        def widen(x):
            # Match scheme1.split: ints/half floats widen to f32 before the
            # truncate-subtract recurrence; f64 keeps its mantissa.
            if (not jnp.issubdtype(x.dtype, jnp.floating)
                    or jnp.dtype(x.dtype).itemsize < 4):
                return x.astype(jnp.float32)
            return x
        a, b = widen(a), widen(b)
        mu = scheme1._pow2_row_scale(a, axis=1)
        nu = scheme1._pow2_row_scale(b, axis=0)
        return fused_matmul_scheme1(a, b, mu, nu, cfg.p, beta, blocks,
                                    out_dtype=out_dtype)
