"""The XLA reference backend: the always-available fallback lowering.

No ``pallas_call`` at all — the scheme reference implementations in
``repro.core`` (plain jnp/lax ops that partition under GSPMD like any
other dot).  The dispatcher falls back here whenever the selected
backend has no fused kernel for a (scheme, dtype) pair — e.g. Scheme-II
on the GPU backend until its residue kernel lands.

Alignment is 1 (XLA tiles internally), so every shape is "aligned" and
the padded path never engages.
"""

from __future__ import annotations

import jax.numpy as jnp

from repro.kernels.backends.base import BackendCapabilities, KernelBackend
from repro.kernels.common import Blocks

_CAPS = BackendCapabilities(
    align=1,
    schemes=frozenset({"ozaki1", "ozaki2"}),
    operand_dtypes=frozenset({"float32", "float64", "bfloat16", "float16",
                              "int8", "int16", "int32"}),
    staging_budget=0,
    accumulator_budget=0,
    peak_key="xla",
    shardable=True,
    # "Batched" here means the scheme references vectorize over a leading
    # batch axis inside one XLA program — a single conceptual launch with
    # fusion left to XLA, not B python-level re-dispatches.  It keeps the
    # reference backend route-compatible with the fused GPU path so
    # parity tests and REPRO_BACKEND=xla runs exercise the same
    # dispatcher branch.
    batched=True,
)


class XlaBackend(KernelBackend):
    name = "xla"

    @property
    def capabilities(self) -> BackendCapabilities:
        return _CAPS

    def choose_blocks(self, m, n, k, p, *, out_bytes=4, prologue_a=False,
                      prologue_b=False, fixed_bk=None,
                      scheme="ozaki1") -> Blocks | None:
        # XLA chooses its own tiling; a unit block makes every shape
        # "aligned" so the dispatcher never pads for this backend.
        del p, out_bytes, prologue_a, prologue_b, scheme
        return Blocks(1, 1, fixed_bk if fixed_bk is not None else 1)

    def matmul(self, a, b, cfg, out_dtype, blocks):
        del blocks
        from repro.core import complex3m, scheme1, scheme2
        cplx = (jnp.issubdtype(a.dtype, jnp.complexfloating)
                or jnp.issubdtype(b.dtype, jnp.complexfloating))
        if cfg.scheme == "ozaki1":
            if cplx:
                # out_dtype arrives real (dispatch converts a complex
                # request to its real interior before routing).
                return scheme1.matmul_complex_4m(a, b, cfg,
                                                 out_dtype=out_dtype)
            return scheme1.matmul(a, b, cfg, out_dtype=out_dtype)
        if cfg.scheme == "ozaki2":
            if cplx:
                return complex3m.matmul(a, b, cfg, out_dtype=out_dtype)
            return scheme2.matmul(a, b, cfg, out_dtype=out_dtype)
        raise ValueError(f"xla backend: unknown scheme {cfg.scheme!r}")

    def matmul_batched(self, a, b, cfg, out_dtype, blocks):
        # One traced program over the stack: vmap of the 2-D scheme
        # reference.  Bit-identical to the per-element fallback by
        # definition (it IS the per-element computation, batched), but
        # staged as a single launch so the dispatcher's batched route —
        # plan reuse, telemetry, traffic accounting — is exercised
        # end to end on hosts without a fused backend.
        import jax
        return jax.vmap(
            lambda x, y: self.matmul(x, y, cfg, out_dtype, blocks))(a, b)
