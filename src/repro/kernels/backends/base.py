"""KernelBackend interface + the one place a ``pl.pallas_call`` is built.

A backend owns everything hardware-specific about lowering an emulated
GEMM: tile alignment, operand dtypes, on-chip staging budgets, which
Ozaki schemes it has fused kernels for, and the peak tables the
roofline/traffic reporting projects against.  The registry in
:mod:`repro.kernels.backends` maps names ('tpu', 'gpu', 'xla') to
instances; :mod:`repro.kernels.dispatch` routes every
``emulated_matmul`` / ``plan_emulated`` / ``select_blocks`` call through
it, selected by ``EmulationConfig.backend`` or the ``REPRO_BACKEND``
environment override.

``build_pallas_call`` (historically ``dispatch.build_pallas_call``) is
the version-portable call builder every Mosaic kernel in this package
uses; it lives here so backends and kernels share one construction site.
"""

from __future__ import annotations

import abc
import dataclasses

import jax
from jax.experimental import pallas as pl

from repro.kernels import compat
from repro.kernels.common import Blocks, interpret


# ---------------------------------------------------------------------------
# The one place a pl.pallas_call is constructed.
# ---------------------------------------------------------------------------

def build_pallas_call(kernel, *, out_shape, grid=None, in_specs=None,
                      out_specs=None, grid_spec=None, scratch_shapes=None,
                      dimension_semantics=None, name=None,
                      interpret_mode: bool | None = None,
                      compiler_params_fn=compat.tpu_compiler_params,
                      **compiler_kwargs):
    """Construct a ``pl.pallas_call`` with version-portable compiler params.

    Exactly one of ``grid`` (+ ``in_specs``/``out_specs``) or ``grid_spec``
    must be given. ``compiler_kwargs`` (e.g. ``vmem_limit_bytes``) are
    forwarded to the compiler-params object when the installed jax accepts
    them and silently dropped otherwise.  ``compiler_params_fn`` selects
    the platform's params builder (TPU Mosaic by default; the GPU backend
    passes :func:`repro.kernels.compat.gpu_compiler_params`).
    """
    kw: dict = {}
    if grid_spec is not None:
        if grid is not None or in_specs is not None or out_specs is not None:
            raise ValueError("pass either grid_spec or grid/in_specs/out_specs")
        kw["grid_spec"] = grid_spec
    else:
        kw["grid"] = grid
        kw["in_specs"] = in_specs
        kw["out_specs"] = out_specs
    if scratch_shapes is not None:
        kw["scratch_shapes"] = scratch_shapes
    interp = interpret() if interpret_mode is None else interpret_mode
    if not interp or compiler_params_fn is compat.tpu_compiler_params:
        # Interpret mode ignores compiler hints; platform-foreign params
        # objects (Triton hints on a CPU run) are dropped rather than
        # handed to a lowering that would reject them.
        params = compiler_params_fn(
            dimension_semantics=dimension_semantics, **compiler_kwargs)
        if params is not None:
            kw["compiler_params"] = params
    return pl.pallas_call(
        kernel,
        out_shape=out_shape,
        interpret=interp,
        name=name,
        **kw)


# ---------------------------------------------------------------------------
# Capabilities + the backend interface.
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class BackendCapabilities:
    """What a kernel backend can lower and under which resource model.

    Attributes:
      align:            tile alignment every GEMM dimension must meet
                        before the fused kernels run (operands are
                        zero-padded up to it by the dispatcher),
      schemes:          Ozaki schemes with a fused lowering here,
      operand_dtypes:   real operand dtypes the fused kernels accept
                        (complex inputs route through the 4M expansion
                        on their real parts before reaching a backend),
      staging_budget:   bytes of on-chip operand staging (TPU VMEM /
                        GPU shared memory) the block search may claim,
      accumulator_budget: bytes available for the p int32 accumulators
                        (VMEM scratch on TPU, registers/TMEM on GPU),
      peak_key:         key into ``repro.core.traffic.BACKEND_PEAKS`` —
                        the hardware table roofline projections use,
      shardable:        whether the fused lowerings may run per-shard
                        under ``shard_map`` on a multi-device mesh
                        (``resolve_policy`` keeps fused impls on such
                        meshes only when this is set; the default False
                        keeps out-of-tree backends on the conservative
                        multi-device clamp until they opt in),
      batched:          whether :meth:`KernelBackend.matmul_batched` is
                        a real strided-batched lowering — one launch
                        whose grid carries a third dimension over batch
                        (or an equivalent single-launch reference).  The
                        dispatcher routes ``emulated_matmul_batched``'s
                        matching-leading-axes case through it; backends
                        without it (the default) keep the per-element
                        ``jax.vmap`` fallback.
    """
    align: int
    schemes: frozenset
    operand_dtypes: frozenset
    staging_budget: int
    accumulator_budget: int
    peak_key: str
    shardable: bool = False
    batched: bool = False


class KernelBackend(abc.ABC):
    """One lowering target for the fused emulated-GEMM kernels."""

    name: str

    @property
    @abc.abstractmethod
    def capabilities(self) -> BackendCapabilities:
        ...

    @abc.abstractmethod
    def choose_blocks(self, m: int, n: int, k: int, p: int, *,
                      out_bytes: int = 4, prologue_a: bool = False,
                      prologue_b: bool = False,
                      fixed_bk: int | None = None,
                      scheme: str = "ozaki1") -> Blocks | None:
        """Largest aligned blocks whose working set fits this backend's
        staging/accumulator budgets, or None when nothing aligns.

        ``p`` is the slice count (Scheme I) or modulus count (Scheme
        II); ``scheme`` ('ozaki1' | 'ozaki2' | 'ozaki2-3m') selects the
        residue-count-aware resource model on backends whose budgets
        differ per scheme — backends with one model may ignore it.
        """
        ...

    @abc.abstractmethod
    def matmul(self, a: jax.Array, b: jax.Array, cfg, out_dtype,
               blocks: Blocks | None) -> jax.Array:
        """Fused 2-D real (M, K) @ (K, N) for ``cfg.scheme`` on aligned
        operands.  Complex routing (Scheme-I 4M) happens in dispatch."""
        ...

    def matmul_batched(self, a: jax.Array, b: jax.Array, cfg, out_dtype,
                       blocks: Blocks | None) -> jax.Array:
        """Strided-batched real (B, M, K) @ (B, K, N) in ONE launch.

        Only called when :attr:`BackendCapabilities.batched` is set —
        the grid grows a third dimension over batch, operands are
        indexed with a batch stride, and scales/plan are computed once
        for the whole stack.  Must be bit-identical to vmapping
        :meth:`matmul` over the leading axis.  The default raises so
        out-of-tree backends that don't advertise the capability fail
        loudly rather than silently mis-lowering.
        """
        raise NotImplementedError(
            f"backend {self.name!r} has no strided-batched lowering "
            "(BackendCapabilities.batched is not set)")

    def supports(self, cfg, a_dtype=None, b_dtype=None) -> bool:
        """Can this backend lower ``cfg`` on these (real) operand dtypes?
        The dispatcher falls back to the 'xla' reference backend when not.
        """
        caps = self.capabilities
        if cfg.scheme not in caps.schemes:
            return False
        for dt in (a_dtype, b_dtype):
            if dt is None:
                continue
            name = jax.numpy.dtype(dt).name
            if name.startswith("complex"):
                # 4M expansion hands the backend the real parts.
                name = {"complex64": "float32",
                        "complex128": "float64"}[name]
            if name not in caps.operand_dtypes:
                return False
        return True
