"""Centralized pallas-call construction and emulated-GEMM dispatch.

Every fused kernel in this package (``ozaki1``, ``ozaki2``, ``ozaki3m``,
``matmul_int8``, ``flash_attn``) builds its ``pl.pallas_call`` through
:func:`build_pallas_call`, which resolves the JAX-version compiler-params
drift once via :mod:`repro.kernels.compat` — an API rename upstream is a
one-file fix here instead of five identical kernel breakages.

On top of the call builder this module owns the *routing* policy:

* :func:`select_blocks` — ``choose_blocks`` memoized per
  (shape, p, out_bytes, backend) key, so repeated call-sites (training
  steps re-tracing the same projection shapes) never re-run the VMEM
  budget search, and a future GPU (Mosaic/Triton) backend can return
  different tiles for the same problem.
* :func:`plan_emulated` — one (dtype, blocks, alignment) resolution per
  call, shared by ``emulated_matmul`` and ``maybe_emulated_matmul`` and
  threaded down to the fused wrappers, so the VMEM search never runs
  twice for one GEMM.
* :func:`emulated_matmul` — the single entry point for an emulated GEMM.
  Non-128-aligned operands are zero-padded to the nearest aligned tile,
  run through the fused kernel, and sliced back — zero rows/columns are
  exact under both schemes (they decompose to zero slices / zero
  residues), so padding changes traffic, never values. A
  :class:`repro.kernels.prepared.PreparedOperand` rhs skips decomposition
  entirely and streams its finished int8 slices.
* :func:`emulated_matmul_batched` — leading batch dims on the activation
  flatten into M (the usual ``activations @ weights`` pattern); a shared
  leading axis on both operands maps the fused kernel with ``jax.vmap``.
* :func:`resolve_policy` — clamps a model ``GemmPolicy`` to what the
  launch target supports: the interpret-mode Pallas lowering is a
  sequential grid loop GSPMD cannot partition, so multi-device meshes and
  non-TPU backends pin ``impl='xla'`` (previously a comment in
  ``parse_gemm_spec`` that every caller had to remember).
"""

from __future__ import annotations

import dataclasses
import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.precision import EmulationConfig
from repro.kernels import compat
from repro.kernels.common import Blocks, choose_blocks, interpret

# MXU lane/tile alignment the fused kernels require on every dimension.
ALIGN = 128


# ---------------------------------------------------------------------------
# The one place a pl.pallas_call is constructed.
# ---------------------------------------------------------------------------

def build_pallas_call(kernel, *, out_shape, grid=None, in_specs=None,
                      out_specs=None, grid_spec=None, scratch_shapes=None,
                      dimension_semantics=None, name=None,
                      interpret_mode: bool | None = None,
                      **compiler_kwargs):
    """Construct a ``pl.pallas_call`` with version-portable compiler params.

    Exactly one of ``grid`` (+ ``in_specs``/``out_specs``) or ``grid_spec``
    must be given. ``compiler_kwargs`` (e.g. ``vmem_limit_bytes``) are
    forwarded to the compiler-params object when the installed jax accepts
    them and silently dropped otherwise.
    """
    kw: dict = {}
    if grid_spec is not None:
        if grid is not None or in_specs is not None or out_specs is not None:
            raise ValueError("pass either grid_spec or grid/in_specs/out_specs")
        kw["grid_spec"] = grid_spec
    else:
        kw["grid"] = grid
        kw["in_specs"] = in_specs
        kw["out_specs"] = out_specs
    if scratch_shapes is not None:
        kw["scratch_shapes"] = scratch_shapes
    params = compat.tpu_compiler_params(
        dimension_semantics=dimension_semantics, **compiler_kwargs)
    if params is not None:
        kw["compiler_params"] = params
    return pl.pallas_call(
        kernel,
        out_shape=out_shape,
        interpret=interpret() if interpret_mode is None else interpret_mode,
        name=name,
        **kw)


# ---------------------------------------------------------------------------
# Block selection, cached per (shape, p, dtype-bytes, backend).
# ---------------------------------------------------------------------------

@functools.lru_cache(maxsize=4096)
def _select_blocks_cached(m: int, n: int, k: int, p: int, out_bytes: int,
                          backend: str, prologue_a: bool, prologue_b: bool,
                          fixed_bk: int | None) -> Blocks | None:
    # `backend` keys the cache only: tile search is TPU-modelled today, but
    # a Mosaic-GPU/Triton backend will pick different tiles for the same
    # problem without invalidating TPU entries.
    del backend
    return choose_blocks(m, n, k, p, out_bytes=out_bytes,
                         prologue_a=prologue_a, prologue_b=prologue_b,
                         fixed_bk=fixed_bk)


def select_blocks(m: int, n: int, k: int, p: int, out_bytes: int = 4,
                  backend: str | None = None, prologue_a: bool = False,
                  prologue_b: bool = False,
                  fixed_bk: int | None = None) -> Blocks | None:
    return _select_blocks_cached(m, n, k, p, out_bytes,
                                 backend or jax.default_backend(),
                                 prologue_a, prologue_b, fixed_bk)


def block_cache_info():
    """Cache statistics, exposed for tests and perf probes."""
    return _select_blocks_cached.cache_info()


def block_cache_clear() -> None:
    _select_blocks_cached.cache_clear()


# ---------------------------------------------------------------------------
# Padding: route non-aligned problems through the fused kernels.
# ---------------------------------------------------------------------------

def round_up(x: int, mult: int = ALIGN) -> int:
    return -(-x // mult) * mult


def padded_mkn(m: int, k: int, n: int,
               align: int = ALIGN) -> tuple[int, int, int]:
    return round_up(m, align), round_up(k, align), round_up(n, align)


def pad_operands(a: jax.Array, b: jax.Array, align: int = ALIGN):
    """Zero-pad (M, K) x (K, N) up to ``align`` multiples.

    Zero padding is exact for every scheme here: zero rows/cols slice to
    all-zero int8 slices (Scheme I) and integerize to all-zero residues
    (Scheme II), contributing nothing to the padded products.
    """
    m, k = a.shape
    k2, n = b.shape
    assert k == k2, (a.shape, b.shape)
    mp, kp, np_ = padded_mkn(m, k, n, align)
    if (mp, kp, np_) == (m, k, n):
        return a, b
    return (jnp.pad(a, ((0, mp - m), (0, kp - k))),
            jnp.pad(b, ((0, kp - k), (0, np_ - n))))


# ---------------------------------------------------------------------------
# The emulated-GEMM entry point.
# ---------------------------------------------------------------------------

def _is_complex(x) -> bool:
    return jnp.issubdtype(x.dtype, jnp.complexfloating)


def _resolve_cfg(cfg, scheme, precision) -> EmulationConfig:
    if cfg is not None:
        return cfg
    return EmulationConfig(scheme=scheme,
                           p=precision if precision is not None else 4)


def _prologue(cfg: EmulationConfig) -> bool:
    """Does this config run Scheme-I decomposition in the kernel prologue?"""
    return cfg.scheme == "ozaki1" and cfg.decomp in ("auto", "kernel")


@dataclasses.dataclass(frozen=True)
class GemmPlan:
    """One block-selection + dtype resolution, shared by every entry point.

    Built by :func:`plan_emulated`; both ``emulated_matmul`` and
    ``maybe_emulated_matmul`` consume the same plan, and the fused
    wrappers in :mod:`repro.kernels.ops` receive ``blocks`` instead of
    re-running the VMEM search on the padded problem.
    """
    cfg: EmulationConfig
    m: int
    n: int
    k: int
    p_eff: int
    out_dtype: object
    blocks: Blocks | None

    @property
    def aligned(self) -> bool:
        return (self.blocks is not None
                and self.blocks.aligned(self.m, self.n, self.k))


def plan_emulated(a: jax.Array, b: jax.Array, cfg: EmulationConfig,
                  out_dtype=None) -> GemmPlan:
    """Resolve output dtype and cached blocks for one 2-D emulated GEMM."""
    m, k = a.shape
    _, n = b.shape
    if out_dtype is None:
        out_dtype = cfg.out_dtype
    if out_dtype is None:
        out_dtype = jnp.promote_types(jnp.real(a).dtype, jnp.real(b).dtype)
    p_eff = cfg.p if cfg.scheme == "ozaki1" else 1
    pro = _prologue(cfg)
    blocks = select_blocks(m, n, k, p_eff,
                           out_bytes=jnp.dtype(out_dtype).itemsize,
                           prologue_a=pro, prologue_b=pro)
    return GemmPlan(cfg, m, n, k, p_eff, out_dtype, blocks)


def _replan_padded(plan: GemmPlan) -> GemmPlan:
    mp, kp, np_ = padded_mkn(plan.m, plan.k, plan.n)
    pro = _prologue(plan.cfg)
    blocks = select_blocks(mp, np_, kp, plan.p_eff,
                           out_bytes=jnp.dtype(plan.out_dtype).itemsize,
                           prologue_a=pro, prologue_b=pro)
    return dataclasses.replace(plan, m=mp, n=np_, k=kp, blocks=blocks)


def _fused_2d(a: jax.Array, b: jax.Array, cfg: EmulationConfig, out_dtype,
              blocks: Blocks | None = None):
    """Aligned 2-D problem -> the fused kernel for cfg.scheme."""
    from repro.kernels import ops  # lazy: ops imports the kernel modules
    cplx = _is_complex(a) or _is_complex(b)
    if cplx and jnp.issubdtype(jnp.dtype(out_dtype), jnp.complexfloating):
        # Real-valued interior: the complex result is assembled at the end.
        out_dtype = jnp.real(jnp.zeros((), out_dtype)).dtype
    if cfg.scheme == "ozaki1":
        if cplx:
            # Scheme-I complex (4M) has no fused kernel: four fused real
            # GEMMs (paper Sec. V-D runs EmuGEMM-I complex exactly so).
            ar, ai = jnp.real(a), jnp.imag(a)
            br, bi = jnp.real(b), jnp.imag(b)
            rr = ops.fused_scheme1_matmul(ar, br, cfg, out_dtype=out_dtype,
                                          blocks=blocks)
            ii = ops.fused_scheme1_matmul(ai, bi, cfg, out_dtype=out_dtype,
                                          blocks=blocks)
            ri = ops.fused_scheme1_matmul(ar, bi, cfg, out_dtype=out_dtype,
                                          blocks=blocks)
            ir = ops.fused_scheme1_matmul(ai, br, cfg, out_dtype=out_dtype,
                                          blocks=blocks)
            return jax.lax.complex(rr - ii, ri + ir)
        return ops.fused_scheme1_matmul(a, b, cfg, out_dtype=out_dtype,
                                        blocks=blocks)
    if cfg.scheme == "ozaki2":
        if cplx:
            return ops.fused_3m_matmul(a, b, cfg, out_dtype=out_dtype)
        return ops.fused_scheme2_matmul(a, b, cfg, out_dtype=out_dtype)
    raise ValueError(f"no fused kernel for scheme {cfg.scheme!r}")


def _is_prepared(b) -> bool:
    from repro.kernels.prepared import PreparedOperand
    return isinstance(b, PreparedOperand)


def emulated_matmul(a: jax.Array, b, *,
                    scheme: str = "ozaki1", precision: int | None = None,
                    cfg: EmulationConfig | None = None,
                    out_dtype=None) -> jax.Array:
    """Emulated (M, K) @ (K, N) through the fused Pallas kernels.

    Blocks come from the per-(shape, p, dtype, backend) cache; operands
    that are not 128-aligned are zero-padded to the nearest aligned tile,
    run fused, and the (M, N) result sliced back out — this path replaces
    the historical ``ValueError("no aligned blocks")``.

    ``b`` may be a :class:`repro.kernels.prepared.PreparedOperand`: its
    finished int8 slices are streamed as-is and only the lhs decomposes
    (in the kernel prologue).
    """
    cfg = _resolve_cfg(cfg, scheme, precision)
    if _is_prepared(b):
        from repro.kernels import prepared
        if a.ndim != 2:
            raise ValueError(f"emulated_matmul is 2-D; got lhs {a.shape} "
                             "(use emulated_matmul_batched)")
        if out_dtype is None:
            out_dtype = cfg.out_dtype
        if out_dtype is None:
            out_dtype = jnp.promote_types(a.dtype, jnp.float32)
        return prepared.matmul_prepared(a, b, out_dtype=out_dtype)
    if a.ndim != 2 or b.ndim != 2:
        raise ValueError(f"emulated_matmul is 2-D; got {a.shape} @ {b.shape} "
                         "(use emulated_matmul_batched)")
    if cfg.scheme == "native":
        out_dtype = (out_dtype or cfg.out_dtype
                     or jnp.promote_types(a.dtype, b.dtype))
        return jax.lax.dot_general(a, b, (((1,), (0,)), ((), ())),
                                   preferred_element_type=out_dtype)
    plan = plan_emulated(a, b, cfg, out_dtype)
    if plan.aligned:
        return _fused_2d(a, b, cfg, plan.out_dtype, plan.blocks)
    a_p, b_p = pad_operands(a, b)
    plan_p = _replan_padded(plan)
    return _fused_2d(a_p, b_p, cfg, plan.out_dtype,
                     plan_p.blocks)[:plan.m, :plan.n]


def emulated_matmul_batched(a: jax.Array, b, **kw) -> jax.Array:
    """vmap-compatible batched wrapper around :func:`emulated_matmul`.

    * ``b`` 2-D (or a PreparedOperand): leading dims of ``a`` flatten into
      M (activations @ weights) — one fused launch.
    * matching leading axes: the 2-D dispatcher is vmapped over them.
    """
    if _is_prepared(b):
        if a.ndim == 2:
            return emulated_matmul(a, b, **kw)
        lead = a.shape[:-1]
        out = emulated_matmul(a.reshape(-1, a.shape[-1]), b, **kw)
        return out.reshape(*lead, b.n)
    if a.ndim == 2 and b.ndim == 2:
        return emulated_matmul(a, b, **kw)
    if b.ndim == 2:
        lead = a.shape[:-1]
        out = emulated_matmul(a.reshape(-1, a.shape[-1]), b, **kw)
        return out.reshape(*lead, b.shape[-1])
    if a.ndim != b.ndim or a.shape[:-2] != b.shape[:-2]:
        raise ValueError(f"incompatible batch dims {a.shape} @ {b.shape}")
    fn = functools.partial(emulated_matmul_batched, **kw)
    return jax.vmap(fn)(a, b)


def maybe_emulated_matmul(a: jax.Array, b, cfg: EmulationConfig):
    """'auto'-impl hook: the fused kernel when the 2-D problem is naturally
    tile-aligned, else None (caller falls back to the XLA expansion —
    padding is reserved for explicit ``impl='pallas'`` requests, where the
    copy+slice overhead was asked for). A PreparedOperand rhs is the other
    exception: preparing *was* the commitment to the kernel path, so a
    non-aligned lhs is padded rather than refused."""
    if _is_prepared(b):
        if a.ndim != 2 or cfg.scheme == "native" or _is_complex(a):
            return None
        return emulated_matmul(a, b, cfg=cfg)
    if a.ndim != 2 or b.ndim != 2 or cfg.scheme == "native":
        return None
    if cfg.scheme == "ozaki1" and (_is_complex(a) or _is_complex(b)):
        return None  # 4x fused launches is not an 'auto' win; XLA path
    plan = plan_emulated(a, b, cfg)
    if not plan.aligned:
        return None
    return _fused_2d(a, b, cfg, plan.out_dtype, plan.blocks)


# ---------------------------------------------------------------------------
# Launch-layer policy resolution.
# ---------------------------------------------------------------------------

def _mesh_devices(mesh) -> int:
    if mesh is None:
        return len(jax.devices())
    size = getattr(mesh, "size", None)
    if size is not None:
        return int(size)
    shape = getattr(mesh, "shape", None)
    if hasattr(shape, "values"):
        return math.prod(shape.values())
    return len(jax.devices())


def resolve_policy(policy, mesh=None):
    """Pin emulated call-sites to impls the launch target can execute.

    The fused kernels' interpret-mode lowering is a sequential grid loop
    that GSPMD cannot partition: on a multi-device mesh or a non-TPU
    backend, 'auto'/'pallas' impls are rewritten to 'xla' so the emulation
    partitions like any other dot. Single-device TPU keeps the request.
    """
    sites = [policy.default] + [cfg for _, cfg in policy.overrides]
    if all(c.scheme == "native" or c.impl == "xla" for c in sites):
        return policy
    if _mesh_devices(mesh) <= 1 and jax.default_backend() == "tpu":
        return policy

    def fix(cfg: EmulationConfig) -> EmulationConfig:
        if cfg.scheme == "native" or cfg.impl == "xla":
            return cfg
        return dataclasses.replace(cfg, impl="xla")

    return dataclasses.replace(
        policy, default=fix(policy.default),
        overrides=tuple((s, fix(c)) for s, c in policy.overrides))
