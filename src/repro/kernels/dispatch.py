"""Emulated-GEMM dispatch: backend routing, block caching, padding, policy.

Every fused kernel in this package builds its ``pl.pallas_call`` through
:func:`repro.kernels.backends.base.build_pallas_call` (re-exported here
for compatibility), which resolves the JAX-version compiler-params drift
once via :mod:`repro.kernels.compat`.

On top of the call builder this module owns the *routing* policy, which
since the backend-registry subsystem landed is expressed per
:class:`repro.kernels.backends.KernelBackend`:

* :func:`select_blocks` — the selected backend's ``choose_blocks``
  memoized per (shape, p, out_bytes, prologue, fixed_bk) key in a
  *per-backend* cache, so repeated call-sites (training steps re-tracing
  the same projection shapes) never re-run the staging-budget search and
  the TPU/GPU backends keep distinct tiles for the same problem.
  ``block_cache_info()`` / ``block_cache_clear()`` report and clear
  per-backend entries.
* :func:`plan_emulated` — one (backend, dtype, blocks) resolution per
  call, shared by ``emulated_matmul`` and ``auto_fused_matmul`` and
  threaded down to the fused wrappers.  Backend selection precedence:
  explicit argument > ``REPRO_BACKEND`` env var > ``cfg.backend`` >
  platform default; a backend with no fused kernel for the requested
  (scheme, dtype) falls back to the ``xla`` reference backend.
* :func:`emulated_matmul` — the single entry point for an emulated GEMM.
  Operands not aligned to the backend's capability (128 on TPU, 16 on
  GPU) are zero-padded to the nearest aligned tile, run through the
  fused kernel, and sliced back — zero rows/columns are exact under both
  schemes, so padding changes traffic, never values.  A
  :class:`repro.kernels.prepared.PreparedOperand` rhs skips
  decomposition entirely and streams its finished int8 slices.
* :func:`emulated_matmul_batched` — leading batch dims on the activation
  flatten into M; a shared leading axis runs ONE strided-batched fused
  launch on backends advertising ``BackendCapabilities.batched`` (the
  grid grows a third dimension over batch, scales/plan computed once for
  the stack), falling back to vmapping the 2-D dispatcher elsewhere.
* :func:`resolve_policy` — clamps a model ``GemmPolicy`` to what the
  launch target supports: (scheme, backend) pairs the selected backend
  cannot lower pin ``impl='xla'``, and fused impls survive only on a
  single-device mesh whose jax platform natively compiles the selected
  backend (the interpret-mode lowering is a sequential grid loop GSPMD
  cannot partition).
"""

from __future__ import annotations

import collections
import dataclasses
import functools
import math
import warnings

import jax
import jax.numpy as jnp

from repro import telemetry
from repro.core.precision import EmulationConfig
from repro.kernels import backends
from repro.kernels.backends.base import build_pallas_call  # noqa: F401
from repro.kernels.common import Blocks
from repro.telemetry import record as _tele

# Historical MXU alignment; kept as the default for the padding helpers
# (the TPU backend's capability). Backend-aware callers pass
# ``backends.get_backend(name).capabilities.align`` instead.
ALIGN = 128


# ---------------------------------------------------------------------------
# Block selection: the backend's choose_blocks, cached per backend.
# ---------------------------------------------------------------------------

BLOCK_CACHE_MAXSIZE = 4096


class _BlockCache:
    """One backend's memoized block selections, with lru_cache-style stats.

    Bounded at BLOCK_CACHE_MAXSIZE entries (FIFO eviction — dict preserves
    insertion order) so shape-ragged serving loops cannot grow it forever.
    """

    __slots__ = ("data", "hits", "misses")

    def __init__(self) -> None:
        self.data: dict = {}
        self.hits = 0
        self.misses = 0

    def put(self, key, blocks) -> None:
        if len(self.data) >= BLOCK_CACHE_MAXSIZE:
            self.data.pop(next(iter(self.data)))
        self.data[key] = blocks


_BLOCK_CACHES: dict[str, _BlockCache] = {}

BlockCacheInfo = collections.namedtuple(
    "BlockCacheInfo", ["hits", "misses", "maxsize", "currsize",
                       "per_backend"])


def select_blocks(m: int, n: int, k: int, p: int, out_bytes: int = 4,
                  backend: str | None = None, prologue_a: bool = False,
                  prologue_b: bool = False,
                  fixed_bk: int | None = None,
                  scheme: str = "ozaki1",
                  mesh_shape: tuple | None = None,
                  batch: int = 1) -> Blocks | None:
    """Cached block selection through the backend registry.

    ``backend`` may be any string — platform-qualified names bucket their
    own cache entries ('tpu-v5e' and 'tpu' stay distinct) while resolving
    to the nearest registered backend for the actual tile search.
    ``scheme`` ('ozaki1' | 'ozaki2' | 'ozaki2-3m') keys the cache and
    selects the backend's residue-count-aware resource model.
    ``mesh_shape`` is the launch mesh's axis sizes when (m, n, k) are
    *shard-local* dims of a shard_map'ed GEMM: the same local shape on
    two different meshes keys distinct entries, so per-shard selections
    never collide across mesh layouts (single-device callers pass None).
    ``batch`` is the strided-batched launch's leading extent: it keys the
    cache (one selection per (B, M, K, N, scheme, p) problem) without
    entering the tile search — a batch grid dimension multiplies program
    count, not the per-program working set.
    """
    bucket = backend or backends.resolve_backend_name()
    cache = _BLOCK_CACHES.setdefault(bucket, _BlockCache())
    key = (m, n, k, p, out_bytes, prologue_a, prologue_b, fixed_bk, scheme,
           mesh_shape, batch)
    try:
        blocks = cache.data[key]
        cache.hits += 1
        telemetry.record_event(_tele.BLOCK_CACHE,
                               {"backend": bucket, "result": "hit"})
        return blocks
    except KeyError:
        cache.misses += 1
        telemetry.record_event(_tele.BLOCK_CACHE,
                               {"backend": bucket, "result": "miss"})
    bk_obj = backends.resolve_backend(bucket)
    try:
        blocks = bk_obj.choose_blocks(
            m, n, k, p, out_bytes=out_bytes, prologue_a=prologue_a,
            prologue_b=prologue_b, fixed_bk=fixed_bk, scheme=scheme)
    except TypeError:
        # Out-of-tree backends registered before the scheme kwarg grew:
        # one resource model per backend was the old contract, so the
        # argument is safely dropped.
        blocks = bk_obj.choose_blocks(
            m, n, k, p, out_bytes=out_bytes, prologue_a=prologue_a,
            prologue_b=prologue_b, fixed_bk=fixed_bk)
    cache.put(key, blocks)
    return blocks


def block_cache_info(backend: str | None = None) -> BlockCacheInfo:
    """Cache statistics, exposed for tests and perf probes.

    Without ``backend``: aggregate hits/misses/size across every backend
    bucket, with the per-backend breakdown under ``.per_backend``.
    """
    if backend is not None:
        c = _BLOCK_CACHES.get(backend, _BlockCache())
        return BlockCacheInfo(c.hits, c.misses, BLOCK_CACHE_MAXSIZE,
                              len(c.data),
                              {backend: (c.hits, c.misses, len(c.data))})
    per = {name: (c.hits, c.misses, len(c.data))
           for name, c in sorted(_BLOCK_CACHES.items())}
    return BlockCacheInfo(sum(c.hits for c in _BLOCK_CACHES.values()),
                          sum(c.misses for c in _BLOCK_CACHES.values()),
                          BLOCK_CACHE_MAXSIZE,
                          sum(len(c.data) for c in _BLOCK_CACHES.values()),
                          per)


def block_cache_clear(backend: str | None = None) -> None:
    """Clear one backend's cached selections, or every backend's."""
    if backend is not None:
        _BLOCK_CACHES.pop(backend, None)
    else:
        _BLOCK_CACHES.clear()


# ---------------------------------------------------------------------------
# Padding: route non-aligned problems through the fused kernels.
# ---------------------------------------------------------------------------

def round_up(x: int, mult: int = ALIGN) -> int:
    return -(-x // mult) * mult


def padded_mkn(m: int, k: int, n: int,
               align: int = ALIGN) -> tuple[int, int, int]:
    return round_up(m, align), round_up(k, align), round_up(n, align)


def pad_operands(a: jax.Array, b: jax.Array, align: int = ALIGN):
    """Zero-pad (M, K) x (K, N) up to ``align`` multiples.

    Zero padding is exact for every scheme here: zero rows/cols slice to
    all-zero int8 slices (Scheme I) and integerize to all-zero residues
    (Scheme II), contributing nothing to the padded products.
    """
    m, k = a.shape
    k2, n = b.shape
    assert k == k2, (a.shape, b.shape)
    mp, kp, np_ = padded_mkn(m, k, n, align)
    if (mp, kp, np_) == (m, k, n):
        return a, b
    return (jnp.pad(a, ((0, mp - m), (0, kp - k))),
            jnp.pad(b, ((0, kp - k), (0, np_ - n))))


# ---------------------------------------------------------------------------
# The emulated-GEMM entry point.
# ---------------------------------------------------------------------------

def _is_complex(x) -> bool:
    return jnp.issubdtype(x.dtype, jnp.complexfloating)


# Historical no-argument behavior of emulated_matmul: Scheme I at p=4.
# Ranks below the ambient scope / env in the resolver — an explicit
# `with repro.emulation(...)` or REPRO_EMULATION spec wins.
_LEGACY_DEFAULT = EmulationConfig(scheme="ozaki1", p=4)


def _resolve_cfg(cfg, scheme, precision) -> EmulationConfig:
    """Resolve this call's config through repro.api.resolve_config.

    ``scheme=``/``precision=`` are the deprecated pre-spec kwargs; they
    keep working (with a DeprecationWarning) so old call-sites survive,
    but new code passes ``cfg=`` (an EmulationConfig or a spec string)
    or relies on the ambient scope.
    """
    from repro import api
    if scheme is not None or precision is not None:
        if cfg is not None:
            raise TypeError("pass either cfg= or the deprecated "
                            "scheme=/precision= kwargs, not both")
        warnings.warn(
            "emulated_matmul(scheme=..., precision=...) is deprecated; "
            "pass cfg=repro.precision('<scheme>-p<N>') or wrap the call "
            "in `with repro.emulation(...)`",
            DeprecationWarning, stacklevel=3)
        return EmulationConfig(
            scheme=scheme if scheme is not None else "ozaki1",
            p=precision if precision is not None else 4)
    return api.resolve_config(cfg, default=_LEGACY_DEFAULT)


def _prologue(cfg: EmulationConfig) -> bool:
    """Does this config run Scheme-I decomposition in the kernel prologue?"""
    return cfg.scheme == "ozaki1" and cfg.decomp in ("auto", "kernel")


@dataclasses.dataclass(frozen=True)
class GemmPlan:
    """One backend + block-selection + dtype resolution per GEMM.

    Built by :func:`plan_emulated`; both ``emulated_matmul`` and
    ``auto_fused_matmul`` consume the same plan, and the fused
    wrappers receive ``blocks`` instead of re-running the staging-budget
    search on the padded problem.  ``backend`` is the *resolved* name —
    after the env override and the unsupported-(scheme, dtype) fallback
    to 'xla'.
    """
    cfg: EmulationConfig
    m: int
    n: int
    k: int
    p_eff: int
    out_dtype: object
    blocks: Blocks | None
    backend: str = "tpu"
    # Block-model key: 'ozaki1' | 'ozaki2' | 'ozaki2-3m' (complex inputs
    # under Scheme II plan for the fused 3M kernel's larger footprint).
    scheme: str = "ozaki1"
    # Axis sizes of the launch mesh when (m, n, k) are shard-local dims
    # of a shard_map'ed GEMM (keys the block cache; None = unsharded).
    mesh_shape: tuple | None = None
    # Input-sentinel probe (repro.guard.sentinel.SentinelProbe) when the
    # plan was built with probe=True: NaN/Inf row/col masks + per-row
    # exponent-spread estimates, computed pre-dispatch so the guard can
    # mask special values and flag wide-dynamic-range operands without
    # touching the fused kernels.
    probe: object | None = None
    # Leading extent of a strided-batched launch (1 = plain 2-D GEMM).
    batch: int = 1

    @property
    def aligned(self) -> bool:
        return (self.blocks is not None
                and self.blocks.aligned(self.m, self.n, self.k))

    @property
    def align(self) -> int:
        return backends.get_backend(self.backend).capabilities.align


def _plan_backend(cfg: EmulationConfig, a, b,
                  backend: str | None = None) -> str:
    """Resolve the backend for one GEMM, falling back to the 'xla'
    reference when the selected backend cannot lower (scheme, dtype)."""
    name = backends.resolve_backend_name(backend, cfg)
    bk = backends.get_backend(name)
    if not bk.supports(cfg, getattr(a, "dtype", None),
                       getattr(b, "dtype", None)):
        if name != "xla":
            telemetry.record_event(_tele.FALLBACK_EVENTS, {
                "requested": name, "scheme": cfg.scheme,
                "reason": "unsupported"})
        return "xla"
    return name


def plan_emulated(a: jax.Array, b: jax.Array, cfg: EmulationConfig,
                  out_dtype=None, backend: str | None = None,
                  mesh_shape: tuple | None = None,
                  probe: bool = False) -> GemmPlan:
    """Resolve backend, output dtype and cached blocks for one 2-D GEMM.

    ``p_eff`` is the residue count the block search budgets for: the
    slice count under Scheme I, the modulus count under Scheme II
    (backends whose Scheme-II kernels run a single live accumulator —
    the TPU Mosaic lowering — re-select internally with p=1 and ignore
    the plan's blocks).

    ``probe=True`` additionally runs the guard's cheap input sentinel
    (finite masks + exponent-spread estimate, O(MK + KN) elementwise)
    and attaches it as ``GemmPlan.probe`` — the pre-dispatch leg of the
    ``+guard`` pipeline (see repro.guard).
    """
    m, k = a.shape
    _, n = b.shape
    if out_dtype is None:
        out_dtype = cfg.out_dtype
    if out_dtype is None:
        out_dtype = jnp.promote_types(jnp.real(a).dtype, jnp.real(b).dtype)
    p_eff = cfg.p
    scheme = cfg.scheme
    if scheme == "ozaki2":
        # The residue count is the moduli count — an explicit tuple may
        # disagree with cfg.p, and the kernels carve len(moduli)
        # residues/accumulators.
        p_eff = len(cfg.resolved_moduli())
        if _is_complex(a) or _is_complex(b):
            scheme = "ozaki2-3m"
    name = _plan_backend(cfg, a, b, backend)
    pro = _prologue(cfg)
    blocks = select_blocks(m, n, k, p_eff,
                           out_bytes=jnp.dtype(out_dtype).itemsize,
                           backend=name, prologue_a=pro, prologue_b=pro,
                           scheme=scheme, mesh_shape=mesh_shape)
    sentinel_probe = None
    if probe:
        from repro.guard import sentinel as _sentinel
        sentinel_probe = _sentinel.probe_operands(a, b)
    return GemmPlan(cfg, m, n, k, p_eff, out_dtype, blocks, name, scheme,
                    mesh_shape, sentinel_probe)


def plan_emulated_batched(a: jax.Array, b: jax.Array, cfg: EmulationConfig,
                          out_dtype=None,
                          backend: str | None = None) -> GemmPlan:
    """Resolve backend, dtype and blocks for one strided-batched
    (B, M, K) @ (B, K, N) GEMM.

    The tile search is the 2-D one — the batch grid dimension multiplies
    program count, not the per-program working set — but the selection
    is keyed per (B, M, K, N, scheme, p), so batched and per-element
    call-sites on the same 2-D problem keep distinct cache entries and
    ``block_cache_info`` attributes them separately.
    """
    batch, m, k = a.shape
    _, _, n = b.shape
    if out_dtype is None:
        out_dtype = cfg.out_dtype
    if out_dtype is None:
        out_dtype = jnp.promote_types(jnp.real(a).dtype, jnp.real(b).dtype)
    p_eff = cfg.p
    scheme = cfg.scheme
    if scheme == "ozaki2":
        p_eff = len(cfg.resolved_moduli())
    name = _plan_backend(cfg, a, b, backend)
    pro = _prologue(cfg)
    blocks = select_blocks(m, n, k, p_eff,
                           out_bytes=jnp.dtype(out_dtype).itemsize,
                           backend=name, prologue_a=pro, prologue_b=pro,
                           scheme=scheme, batch=batch)
    return GemmPlan(cfg, m, n, k, p_eff, out_dtype, blocks, name, scheme,
                    batch=batch)


def _replan_padded(plan: GemmPlan) -> GemmPlan:
    mp, kp, np_ = padded_mkn(plan.m, plan.k, plan.n, plan.align)
    pro = _prologue(plan.cfg)
    blocks = select_blocks(mp, np_, kp, plan.p_eff,
                           out_bytes=jnp.dtype(plan.out_dtype).itemsize,
                           backend=plan.backend, prologue_a=pro,
                           prologue_b=pro, scheme=plan.scheme,
                           mesh_shape=plan.mesh_shape)
    return dataclasses.replace(plan, m=mp, n=np_, k=kp, blocks=blocks)


def _record_plan_call(plan: GemmPlan) -> None:
    """Telemetry for one dispatched GEMM (no-op unless enabled)."""
    if not telemetry.enabled():
        return
    impl = "pallas" if plan.backend != "xla" else "xla"
    telemetry.record_gemm(
        scheme=plan.scheme, count=plan.p_eff, backend=plan.backend,
        impl=impl, m=plan.m, k=plan.k, n=plan.n,
        mesh_shape=plan.mesh_shape,
        out_bytes=jnp.dtype(plan.out_dtype).itemsize,
        batch=plan.batch if plan.batch != 1 else None)


def _scope_scheme(cfg: EmulationConfig, cplx: bool) -> tuple[str, int]:
    """(scheme tag, residue count) of one lowering for trace annotation."""
    if cfg.scheme == "ozaki2":
        return ("ozaki2-3m" if cplx else "ozaki2",
                len(cfg.resolved_moduli()))
    return ("ozaki1-4m" if cplx else cfg.scheme, cfg.p)


def _fused_2d(a: jax.Array, b: jax.Array, cfg: EmulationConfig, out_dtype,
              blocks: Blocks | None = None, backend: str | None = None):
    """Aligned 2-D problem -> the selected backend's fused lowering."""
    bk = backends.get_backend(backend) if backend \
        else backends.resolve_backend(cfg=cfg)
    cplx = _is_complex(a) or _is_complex(b)
    if cplx and jnp.issubdtype(jnp.dtype(out_dtype), jnp.complexfloating):
        # Real-valued interior: the complex result is assembled at the end.
        out_dtype = jnp.real(jnp.zeros((), out_dtype)).dtype
    scheme_tag, count = _scope_scheme(cfg, cplx)
    impl = "pallas" if bk.name != "xla" else "xla"
    with telemetry.gemm_scope(scheme_tag, count, bk.name, impl):
        if cfg.scheme == "ozaki1":
            if cplx:
                # Scheme-I complex (4M) has no fused kernel on any backend:
                # four fused real GEMMs (paper Sec. V-D runs EmuGEMM-I
                # complex exactly so).
                ar, ai = jnp.real(a), jnp.imag(a)
                br, bi = jnp.real(b), jnp.imag(b)
                rr = bk.matmul(ar, br, cfg, out_dtype, blocks)
                ii = bk.matmul(ai, bi, cfg, out_dtype, blocks)
                ri = bk.matmul(ar, bi, cfg, out_dtype, blocks)
                ir = bk.matmul(ai, br, cfg, out_dtype, blocks)
                return jax.lax.complex(rr - ii, ri + ir)
            return bk.matmul(a, b, cfg, out_dtype, blocks)
        if cfg.scheme == "ozaki2":
            return bk.matmul(a, b, cfg, out_dtype, blocks)
    raise ValueError(f"no fused kernel for scheme {cfg.scheme!r}")


def _fused_batched(a: jax.Array, b: jax.Array, cfg: EmulationConfig,
                   plan: GemmPlan) -> jax.Array:
    """One strided-batched fused launch for an eligible (B, M, K) @
    (B, K, N) problem — padding the trailing two axes when needed
    (exact: zero rows/cols carve to zero slices/residues)."""
    bk = backends.get_backend(plan.backend)
    scheme_tag, count = _scope_scheme(cfg, False)
    impl = "pallas" if bk.name != "xla" else "xla"
    telemetry.record_event(_tele.BATCHED_LAUNCHES, {
        "backend": plan.backend, "scheme": scheme_tag,
        "shape_class": _tele.shape_class(plan.m, plan.k, plan.n,
                                         batch=plan.batch)})
    with telemetry.gemm_scope(scheme_tag, count, bk.name, impl):
        if plan.aligned:
            return bk.matmul_batched(a, b, cfg, plan.out_dtype, plan.blocks)
        telemetry.record_event(_tele.PAD_EVENTS, {
            "backend": plan.backend, "scheme": plan.scheme,
            "shape_class": _tele.shape_class(plan.m, plan.k, plan.n,
                                             batch=plan.batch)})
        mp, kp, np_ = padded_mkn(plan.m, plan.k, plan.n, plan.align)
        a_p = jnp.pad(a, ((0, 0), (0, mp - plan.m), (0, kp - plan.k)))
        b_p = jnp.pad(b, ((0, 0), (0, kp - plan.k), (0, np_ - plan.n)))
        pro = _prologue(cfg)
        blocks = select_blocks(mp, np_, kp, plan.p_eff,
                               out_bytes=jnp.dtype(plan.out_dtype).itemsize,
                               backend=plan.backend, prologue_a=pro,
                               prologue_b=pro, scheme=plan.scheme,
                               batch=plan.batch)
        out = bk.matmul_batched(a_p, b_p, cfg, plan.out_dtype, blocks)
        return out[:, :plan.m, :plan.n]


def batched_fused_eligible(a, b, cfg: EmulationConfig,
                           backend: str | None = None) -> bool:
    """Would :func:`emulated_matmul_batched` take the strided-batched
    fused path for these operands under ``cfg``?

    Telemetry-free twin of the route check inside the dispatcher, for
    front doors (``repro.dot_general``) deciding between the batched
    core and their historical vmap-of-2-D lowering.
    """
    if cfg.scheme not in ("ozaki1", "ozaki2") or cfg.guard is not None:
        return False
    if _is_complex(a) or _is_complex(b):
        return False
    name = backends.resolve_backend_name(backend, cfg)
    bk = backends.get_backend(name)
    if not bk.supports(cfg, getattr(a, "dtype", None),
                       getattr(b, "dtype", None)):
        bk = backends.get_backend("xla")
    return bk.capabilities.batched


def _fused_batched_or_none(a: jax.Array, b: jax.Array, kw: dict):
    """The strided-batched fast path of :func:`emulated_matmul_batched`,
    or None when this (config, operands, backend) combination keeps the
    per-element vmap fallback.

    Eligible: real operands under a guard-free ozaki1/ozaki2 config on a
    backend whose :class:`BackendCapabilities` advertise ``batched``.
    Leading axes collapse into one batch dimension; scales and the block
    plan are computed once for the whole stack; the result is
    bit-identical to the vmapped 2-D dispatch (the batched kernels run
    the unchanged 2-D kernel body per batch grid step).
    """
    if kw.get("scheme") is not None or kw.get("precision") is not None:
        return None          # deprecated-shim callers keep the legacy path
    if kw.get("mesh_shape") is not None:
        return None          # shard-local tiles dispatch per element (2-D)
    if _is_complex(a) or _is_complex(b):
        return None          # no batched 4M/3M lowering yet
    from repro import api
    cfg = api.resolve_config(kw.get("cfg"), default=_LEGACY_DEFAULT)
    if cfg.scheme not in ("ozaki1", "ozaki2") or cfg.guard is not None:
        return None
    name = _plan_backend(cfg, a, b, kw.get("backend"))
    if not backends.get_backend(name).capabilities.batched:
        return None
    lead = a.shape[:-2]
    a3 = a.reshape((-1,) + a.shape[-2:])
    b3 = b.reshape((-1,) + b.shape[-2:])
    plan = plan_emulated_batched(a3, b3, cfg, kw.get("out_dtype"), name)
    _record_plan_call(plan)
    out = _fused_batched(a3, b3, cfg, plan)
    return out.reshape(lead + out.shape[-2:])


def _is_prepared(b) -> bool:
    from repro.kernels.prepared import PreparedOperand, PreparedResidues
    return isinstance(b, (PreparedOperand, PreparedResidues))


def _is_prepared_residues(b) -> bool:
    from repro.kernels.prepared import PreparedResidues
    return isinstance(b, PreparedResidues)


def emulated_matmul(a: jax.Array, b, *,
                    cfg: "EmulationConfig | str | None" = None,
                    out_dtype=None, backend: str | None = None,
                    scheme: str | None = None,
                    precision: int | None = None,
                    mesh_shape: tuple | None = None) -> jax.Array:
    """Emulated (M, K) @ (K, N) through the fused kernels of the selected
    backend (``backend`` arg > ``REPRO_BACKEND`` > ``cfg.backend`` >
    platform default; unsupported (scheme, dtype) pairs fall back to the
    'xla' reference backend).

    ``cfg`` is an EmulationConfig or a precision-spec string; omitted, it
    resolves through the ambient scope / ``REPRO_EMULATION`` env (see
    ``repro.resolve_config``), defaulting to the historical ozaki1-p4.
    ``scheme=``/``precision=`` are deprecated shims for pre-spec callers.

    Blocks come from the per-(shape, p, dtype, backend) cache; operands
    not aligned to the backend's capability are zero-padded to the
    nearest aligned tile, run fused, and the (M, N) result sliced back.

    ``b`` may be a :class:`repro.kernels.prepared.PreparedOperand`: its
    finished int8 slices are streamed as-is and only the lhs decomposes
    (in the kernel prologue).

    ``mesh_shape`` (the launch mesh's axis sizes) marks the operands as
    *shard-local* tiles of a shard_map'ed GEMM — it keys the block cache
    so per-shard selections never collide across mesh layouts; see
    ``repro.parallel.shard_gemm``.
    """
    cfg = _resolve_cfg(cfg, scheme, precision)
    if (cfg.guard is not None and cfg.scheme != "native"
            and a.ndim == 2 and (_is_prepared(b) or b.ndim == 2)
            and not _is_complex(a)
            and not (not _is_prepared(b) and _is_complex(b))):
        # The guard pipeline (sanitize -> run -> verify -> escalate,
        # repro.guard.ladder) wraps this entry point and re-enters it
        # with the guard stripped for every ladder rung.  Invalid shapes
        # fall through so the usual refusals fire first.
        from repro import guard
        return guard.guarded_matmul(a, b, cfg, out_dtype=out_dtype,
                                    backend=backend, mesh_shape=mesh_shape)
    if _is_prepared(b):
        from repro.kernels import prepared
        if cfg.scheme == "native":
            # Mirrors repro.dot_general: the slices/residues are emulation
            # data, so honoring a native request is impossible — refuse
            # rather than silently emulate.
            raise ValueError(
                "a prepared rhs is pre-decomposed emulation data; it "
                "cannot be consumed under a 'native' config (pass the "
                "float weight instead)")
        if _is_prepared_residues(b) and cfg.scheme != "ozaki2":
            raise ValueError(
                "a PreparedResidues rhs is Scheme-II (ozaki2) data; it "
                f"cannot be consumed under scheme={cfg.scheme!r} (pass "
                "the float weight, or prepare under the matching config)")
        if not _is_prepared_residues(b) and cfg.scheme == "ozaki2":
            raise ValueError(
                "a PreparedOperand rhs is Scheme-I (ozaki1) data; it "
                "cannot be consumed under scheme='ozaki2' (pass the "
                "float weight, or prepare under the matching config)")
        if a.ndim != 2:
            raise ValueError(
                f"emulated_matmul is strictly 2-D; got lhs {a.shape} — use "
                "repro.dot_general / repro.einsum for batched or "
                "higher-rank contractions (or emulated_matmul_batched)")
        if out_dtype is None:
            out_dtype = cfg.out_dtype
        if out_dtype is None:
            out_dtype = jnp.promote_types(a.dtype, jnp.float32)
        return prepared.matmul_prepared(a, b, out_dtype=out_dtype)
    if a.ndim != 2 or b.ndim != 2:
        raise ValueError(
            f"emulated_matmul is strictly 2-D; got {a.shape} @ {b.shape} — "
            "use repro.dot_general / repro.einsum for batched or "
            "higher-rank contractions (or emulated_matmul_batched)")
    if cfg.scheme == "native":
        out_dtype = (out_dtype or cfg.out_dtype
                     or jnp.promote_types(a.dtype, b.dtype))
        return jax.lax.dot_general(a, b, (((1,), (0,)), ((), ())),
                                   preferred_element_type=out_dtype)
    plan = plan_emulated(a, b, cfg, out_dtype, backend,
                         mesh_shape=mesh_shape)
    _record_plan_call(plan)
    if plan.aligned:
        return _fused_2d(a, b, cfg, plan.out_dtype, plan.blocks,
                         plan.backend)
    telemetry.record_event(_tele.PAD_EVENTS, {
        "backend": plan.backend, "scheme": plan.scheme,
        "shape_class": _tele.shape_class(plan.m, plan.k, plan.n)})
    a_p, b_p = pad_operands(a, b, plan.align)
    plan_p = _replan_padded(plan)
    return _fused_2d(a_p, b_p, cfg, plan.out_dtype, plan_p.blocks,
                     plan.backend)[:plan.m, :plan.n]


def emulated_matmul_batched(a: jax.Array, b, **kw) -> jax.Array:
    """Batched wrapper around :func:`emulated_matmul`.

    * ``b`` 2-D (or a PreparedOperand): leading dims of ``a`` flatten into
      M (activations @ weights) — one fused launch.
    * matching leading axes: ONE strided-batched fused launch when the
      selected backend's capabilities advertise ``batched`` (the grid
      grows a third dimension over batch; bit-identical to the vmapped
      2-D dispatch); otherwise the 2-D dispatcher is vmapped over the
      leading axes.
    """
    if _is_prepared(b):
        if a.ndim == 2:
            return emulated_matmul(a, b, **kw)
        lead = a.shape[:-1]
        out = emulated_matmul(a.reshape(-1, a.shape[-1]), b, **kw)
        return out.reshape(*lead, b.n)
    if a.ndim == 2 and b.ndim == 2:
        return emulated_matmul(a, b, **kw)
    if b.ndim == 2:
        lead = a.shape[:-1]
        out = emulated_matmul(a.reshape(-1, a.shape[-1]), b, **kw)
        return out.reshape(*lead, b.shape[-1])
    if a.ndim != b.ndim or a.shape[:-2] != b.shape[:-2]:
        raise ValueError(
            f"emulated_matmul_batched needs matching leading (batch) axes; "
            f"got lhs {a.shape} (leading {a.shape[:-2]}) @ rhs {b.shape} "
            f"(leading {b.shape[:-2]}) — repro.dot_general handles "
            "asymmetric batch/contraction layouts")
    out = _fused_batched_or_none(a, b, kw)
    if out is not None:
        return out
    fn = functools.partial(emulated_matmul_batched, **kw)
    return jax.vmap(fn)(a, b)


# Fallback RuntimeWarnings are deduped by (reason, shape-class): the
# requested backend/scheme/dtype pair that fell back plus the operand
# shape class — the (K, N) contraction geometry only, NOT the full
# operand shapes.  Batched call-sites flatten their leading axes into M
# (emulated_matmul_batched), so a full-shape key minted a fresh entry
# per batch size and the "once" warning fired once per ragged batch;
# K x N identifies the weight/call-site independent of batching.
# Scanned training steps re-trace the same call-site once per
# microbatch/layer combination; without the dedupe every re-trace
# re-warned and multi-device logs drowned in the repeat.  The one-shot
# bookkeeping lives on the telemetry registry (the process's single
# counter store; always active, independent of REPRO_TELEMETRY) under
# keys namespaced "fallback".


def fallback_warnings_clear() -> None:
    """Forget which fused-fallback warnings fired (tests/log hygiene)."""
    telemetry.REGISTRY.forget_once("fallback")


def _warn_fallback_once(reason: tuple, shape_class: tuple, message: str,
                        stacklevel: int = 3) -> None:
    if not telemetry.REGISTRY.once(("fallback", reason, shape_class)):
        return
    warnings.warn(message, RuntimeWarning, stacklevel=stacklevel)


def auto_fused_matmul(a: jax.Array, b, cfg: EmulationConfig):
    """'auto'-impl hook: the fused kernel when the 2-D problem is naturally
    tile-aligned for the selected backend, else None (caller falls back to
    the XLA expansion — padding is reserved for explicit ``impl='pallas'``
    requests, where the copy+slice overhead was asked for). A
    PreparedOperand rhs is the other exception: preparing *was* the
    commitment to the kernel path, so a non-aligned lhs is padded rather
    than refused."""
    if _is_prepared(b):
        if a.ndim != 2 or cfg.scheme == "native" or _is_complex(a):
            return None
        return emulated_matmul(a, b, cfg=cfg)
    if a.ndim != 2 or b.ndim != 2 or cfg.scheme == "native":
        return None
    if cfg.scheme == "ozaki1" and (_is_complex(a) or _is_complex(b)):
        return None  # 4x fused launches is not an 'auto' win; XLA path
    plan = plan_emulated(a, b, cfg)
    requested = backends.resolve_backend_name(None, cfg)
    if plan.backend == "xla" and requested != "xla":
        # Fell back — nothing fused to offer the 'auto' site. Name the
        # fused path being skipped (and its limits) instead of silently
        # degrading to the reference expansion.
        from repro.kernels.backends import gpu as _gpu
        detail = ""
        if requested == "gpu" and cfg.scheme == "ozaki2":
            detail = (f" (the fused gpu Scheme-II kernel takes at most "
                      f"{_gpu.MAX_MODULI} moduli, each <= 256)")
        a_name, b_name = jnp.dtype(a.dtype).name, jnp.dtype(b.dtype).name
        _warn_fallback_once(
            (requested, cfg.scheme, a_name, b_name),
            (a.shape[-1], b.shape[-1]),
            f"backend {requested!r} has no fused {cfg.scheme} lowering "
            f"for operands {a_name} @ {b_name}{detail}; this call-site "
            "expands in XLA instead")
        return None
    if not plan.aligned:
        telemetry.record_event(_tele.FALLBACK_EVENTS, {
            "requested": plan.backend, "scheme": plan.scheme,
            "reason": "unaligned-auto"})
        return None
    _record_plan_call(plan)
    return _fused_2d(a, b, cfg, plan.out_dtype, plan.blocks, plan.backend)


def maybe_emulated_matmul(a: jax.Array, b, cfg: EmulationConfig):
    """Deprecated name for :func:`auto_fused_matmul`."""
    warnings.warn(
        "maybe_emulated_matmul is deprecated; call auto_fused_matmul "
        "(or the repro.dot_general/einsum front door)",
        DeprecationWarning, stacklevel=2)
    return auto_fused_matmul(a, b, cfg)


# ---------------------------------------------------------------------------
# Launch-layer policy resolution.
# ---------------------------------------------------------------------------

def _mesh_devices(mesh) -> int:
    """Device count of a launch mesh.

    Handles every mesh flavor the launch layer produces consistently: a
    concrete ``jax.sharding.Mesh`` and a device-free ``AbstractMesh``
    both answer through ``.size`` when present; meshes exposing only a
    ``shape`` answer through it whether it is mapping-shaped
    ({axis: size}, the Mesh/AbstractMesh convention) or a plain tuple of
    axis sizes; ``None`` means the process-global device count.
    """
    if mesh is None:
        return len(jax.devices())
    size = getattr(mesh, "size", None)
    if size is not None:
        return int(size)
    shape = getattr(mesh, "shape", None)
    if hasattr(shape, "values"):             # mapping: {axis_name: size}
        return math.prod(shape.values())
    if shape is not None:                    # plain tuple of axis sizes
        try:
            return math.prod(int(s) for s in shape)
        except (TypeError, ValueError):
            pass
    return len(jax.devices())


def _mesh_shape_tuple(mesh) -> tuple | None:
    """((axis, size), ...) of a mesh, or None — the hashable mesh
    identity the block cache and prepared-operand pinning key on."""
    if mesh is None:
        return None
    shape = getattr(mesh, "shape", None)
    if hasattr(shape, "items"):
        return tuple((str(a), int(s)) for a, s in shape.items())
    if shape is not None:
        try:
            return tuple((str(i), int(s)) for i, s in enumerate(shape))
        except (TypeError, ValueError):
            return None
    return None


def _shardable_mesh(mesh) -> bool:
    """Can fused call-sites run per-shard under shard_map on this mesh?

    Requires a *concrete* multi-device Mesh: shard_map needs named axes
    backed by real devices. Device-free AbstractMeshes (dry-run
    lowering) and a bare device count (mesh=None on a multi-device
    host) keep the conservative clamp — there is nothing to map over.
    """
    from jax.sharding import Mesh
    return (isinstance(mesh, Mesh) and _mesh_devices(mesh) > 1
            and bool(getattr(mesh, "axis_names", ())))


def resolve_policy(policy, mesh=None):
    """Pin emulated call-sites to impls the launch target can execute.

    Two clamps, in order:

    1. (scheme, backend) pairs the selected kernel backend cannot lower
       (e.g. a >16-moduli Scheme-II set on the 'gpu' backend) rewrite to
       ``impl='xla'`` — the reference expansion rather than a run-time
       registry fallback buried inside a jitted step.
    2. Fused 'auto'/'pallas' impls survive in exactly two launch
       geometries:

       * a single-device mesh whose jax platform natively compiles the
         selected kernel backend (TPU host + 'tpu' backend, GPU host +
         'gpu' backend), or
       * a concrete multi-device mesh whose selected backend declares
         ``BackendCapabilities.shardable`` — the call-sites then run the
         fused kernel *per shard* under ``shard_map`` (see
         ``repro.parallel.shard_gemm``), with explicit collectives
         instead of GSPMD partitioning of the kernel body. The mesh is
         recorded on the returned policy (``GemmPolicy.mesh``) so the
         model layer knows which axes to map over.

       Everything else — device-free AbstractMeshes, a bare multi-device
       host with no mesh to map over, non-shardable out-of-tree
       backends, cross-platform single-device requests — rewrites to
       'xla' so the emulation partitions like any other dot.

    A policy whose ``default`` is None (unset) first materializes the
    ambient config through ``repro.resolve_config`` — the launch layer
    consumes the documented resolver, so ``with repro.emulation(...)``
    and ``REPRO_EMULATION`` configure whole training/serving runs and
    still pass through the clamps above.
    """
    default = policy.default
    if default is None:
        from repro import api
        default = api.resolve_config()
        if default.scheme != "native":
            # Materialize the ambient config NOW (even when no clamp will
            # fire, e.g. '+xla' specs): the step functions built from this
            # policy trace lazily, possibly after the scope has exited.
            policy = dataclasses.replace(policy, default=default)
    sites = [default] + [cfg for _, cfg in policy.overrides]
    if all(c.scheme == "native" or c.impl == "xla" for c in sites):
        return policy

    single = _mesh_devices(mesh) <= 1
    sharded = _shardable_mesh(mesh)

    def fix(cfg: EmulationConfig) -> EmulationConfig:
        if cfg.scheme == "native" or cfg.impl == "xla":
            return cfg
        bk = backends.resolve_backend(cfg=cfg)
        # supports() without dtypes: the scheme-level clamp (including
        # per-backend limits like the gpu kernels' moduli cap).
        if not bk.supports(cfg):
            return dataclasses.replace(cfg, impl="xla")
        if single and bk.name == jax.default_backend():
            return cfg  # this host compiles the selected backend natively
        if sharded and bk.capabilities.shardable:
            # GSPMD-native: the shard_map wrapper launches the fused
            # kernel on each shard's local tile and issues the
            # collectives itself, so the old multi-device clamp no
            # longer applies.
            return cfg
        return dataclasses.replace(cfg, impl="xla")

    fixed_default = fix(policy.default)
    fixed_overrides = tuple((s, fix(c)) for s, c in policy.overrides)
    fixed = dataclasses.replace(policy, default=fixed_default,
                                overrides=fixed_overrides)
    if sharded and hasattr(policy, "mesh") and any(
            c.scheme != "native" and c.impl != "xla"
            for c in [fixed_default] + [c for _, c in fixed_overrides]):
        fixed = dataclasses.replace(fixed, mesh=mesh)
    return fixed
