"""Shared utilities for the EmuGEMM Pallas TPU kernels.

Hardware model (TPU v5e target):
  * MXU systolic array: 128x128, int8 x int8 -> int32 exact.
  * VMEM ~16 MiB/core staging both operand blocks (double-buffered by the
    Pallas pipeline) and the p int32 accumulators (Scheme I).
  * int8 VMEM tiling (32, 128): block dims multiples of (32, 128)-friendly
    sizes; we keep everything 128-aligned for the MXU.

``choose_blocks`` is the TPU analogue of the paper's Eq. 12 resource budget:
  Acc^(p) = 4 p bM bN     (int32 accumulators, VMEM scratch)
  S_op    = 2 p (bM+bN) bK  (double-buffered int8 operand blocks)
  S_epi   = out_bytes bM bN
all of which must fit the per-core VMEM budget; larger tiles raise the
MXU pipeline depth (the omega of Fig. 1(c)) until the budget binds.
"""

from __future__ import annotations

import dataclasses
import functools

import jax


@dataclasses.dataclass(frozen=True)
class Blocks:
    bm: int
    bn: int
    bk: int

    def aligned(self, m: int, n: int, k: int) -> bool:
        return m % self.bm == 0 and n % self.bn == 0 and k % self.bk == 0


# Per-core VMEM we allow the kernel to claim (leave headroom of the 16 MiB).
VMEM_BUDGET = 12 * 2**20


def choose_blocks(m: int, n: int, k: int, p: int,
                  out_bytes: int = 4,
                  vmem_budget: int = VMEM_BUDGET) -> Blocks | None:
    """Largest 128-aligned blocks whose working set fits VMEM.

    Preference order: maximize bM*bN (accumulator tile = MXU work per
    operand byte), then bK (pipeline depth). Mirrors paper Eq. 12's
    alpha_max trade-off: higher p forces smaller tiles.
    """
    best: tuple[tuple[int, int], Blocks] | None = None
    for bm in (512, 256, 128, 64, 32):
        if m % bm:
            continue
        for bn in (512, 256, 128):
            if n % bn:
                continue
            for bk in (512, 256, 128, 64, 32):
                if k % bk:
                    continue
                acc = 4 * p * bm * bn
                s_op = 2 * p * (bm + bn) * bk
                s_epi = out_bytes * bm * bn
                if acc + s_op + s_epi > vmem_budget:
                    continue
                key = (bm * bn, bk)
                if best is None or key > best[0]:
                    best = (key, Blocks(bm, bn, bk))
    return best[1] if best else None


@functools.cache
def interpret() -> bool:
    """Pallas interpret mode everywhere except on a real TPU backend."""
    return jax.default_backend() != "tpu"


def mma_pipeline_depth(blocks: Blocks, p: int, scheme: int) -> int:
    """Effective MMA instructions per K-step (paper Eq. 13 analogue).

    On TPU the '128x128x128 MXU pass' stands in for one MMA. Scheme I's
    triangular schedule multiplies the per-K-step count by p(p+1)/2.
    """
    per_dot = (blocks.bm // 128) * (blocks.bn // 128) * max(1, blocks.bk // 128)
    tri = p * (p + 1) // 2 if scheme == 1 else 1
    return per_dot * tri
