"""Shared utilities for the EmuGEMM Pallas TPU kernels.

Hardware model (TPU v5e target):
  * MXU systolic array: 128x128, int8 x int8 -> int32 exact.
  * VMEM ~16 MiB/core staging both operand blocks (double-buffered by the
    Pallas pipeline) and the p int32 accumulators (Scheme I).
  * int8 VMEM tiling (32, 128): block dims multiples of (32, 128)-friendly
    sizes; we keep everything 128-aligned for the MXU.

``choose_blocks`` is the TPU analogue of the paper's Eq. 12 resource budget:
  Acc^(p) = 4 p bM bN     (int32 accumulators, VMEM scratch)
  S_op    = 2 p (bM+bN) bK  (double-buffered int8 operand blocks)
  S_epi   = out_bytes bM bN
all of which must fit the per-core VMEM budget; larger tiles raise the
MXU pipeline depth (the omega of Fig. 1(c)) until the budget binds.

With the in-kernel decomposition prologue (``prologue_a`` / ``prologue_b``)
an operand side stages the *fp32* tile instead of the p int8 slices, and
the slices it carves live in VMEM alongside it:

  S_op(side) = 2 * 4 dim bK   (double-buffered fp32 block)
             + 4 dim bK       (fp32 remainder of the truncate-subtract chain)
             + p dim bK       (the carved int8 slices)

Traffic-wise this swaps the Eq. 10 operand term p*dim*K for 4*dim*K *and*
deletes the decomposition round-trips entirely (the split's (p, M, K)
write, the interleave's read+write, and the scale pass's extra fp32 read
— the decomposition-side bytes that Eqs. 9/10 never charged; see
repro.core.traffic.scheme1_decomp_*_bytes).
"""

from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class Blocks:
    bm: int
    bn: int
    bk: int

    def aligned(self, m: int, n: int, k: int) -> bool:
        return m % self.bm == 0 and n % self.bn == 0 and k % self.bk == 0


# Per-core VMEM we allow the kernel to claim (leave headroom of the 16 MiB).
VMEM_BUDGET = 12 * 2**20


def _operand_stage_bytes(dim: int, bk: int, p: int, prologue: bool) -> int:
    """VMEM bytes one operand side stages per K-step (see module doc)."""
    if prologue:
        # double-buffered fp32 block + fp32 remainder + carved int8 slices
        return (2 * 4 + 4 + p) * dim * bk
    return 2 * p * dim * bk  # double-buffered pre-interleaved int8 block


def choose_blocks(m: int, n: int, k: int, p: int,
                  out_bytes: int = 4,
                  vmem_budget: int = VMEM_BUDGET,
                  prologue_a: bool = False,
                  prologue_b: bool = False,
                  fixed_bk: int | None = None) -> Blocks | None:
    """Largest 128-aligned blocks whose working set fits VMEM.

    Preference order: maximize bM*bN (accumulator tile = MXU work per
    operand byte), then bK (pipeline depth). Mirrors paper Eq. 12's
    alpha_max trade-off: higher p forces smaller tiles.

    ``prologue_a`` / ``prologue_b`` switch that side's operand budget to
    the fp32-staging model of the in-kernel decomposition prologue.
    ``fixed_bk`` pins the K block — required when consuming a
    PreparedOperand whose interleave granularity was already chosen.
    """
    best: tuple[tuple[int, int], Blocks] | None = None
    bk_candidates = ((fixed_bk,) if fixed_bk is not None
                     else (512, 256, 128, 64, 32))
    for bm in (512, 256, 128, 64, 32):
        if m % bm:
            continue
        for bn in (512, 256, 128):
            if n % bn:
                continue
            for bk in bk_candidates:
                if k % bk:
                    continue
                acc = 4 * p * bm * bn
                s_op = (_operand_stage_bytes(bm, bk, p, prologue_a)
                        + _operand_stage_bytes(bn, bk, p, prologue_b))
                s_epi = out_bytes * bm * bn
                if acc + s_op + s_epi > vmem_budget:
                    continue
                key = (bm * bn, bk)
                if best is None or key > best[0]:
                    best = (key, Blocks(bm, bn, bk))
    return best[1] if best else None


def carve_slices(r: jax.Array, p: int, beta: int):
    """Yield the p signed int8 beta-bit slices of ``r`` (already divided
    by its power-of-two scale) via iterated truncate-and-subtract.

    Every step is elementwise and exact in floating point (power-of-two
    shift, trunc, exact fractional remainder), so a tile-local run inside
    a kernel is bit-identical to the full-array ``scheme1.split``
    restricted to that tile.  This is the ONE in-kernel copy of the
    recurrence — the matmul prologue (ozaki1) and the decompose kernels
    both consume it, so the bit-identity the tests and the CI traffic
    gate assert can only drift in one place.
    """
    two_beta = float(2 ** beta)
    for _ in range(p):
        shifted = r * two_beta            # exact power-of-two shift
        s = jnp.trunc(shifted)            # |s| <= 2^beta - 1
        yield s.astype(jnp.int8)
        r = shifted - s                   # exact fractional remainder


@functools.cache
def interpret() -> bool:
    """Pallas interpret mode everywhere except on a real TPU backend."""
    return jax.default_backend() != "tpu"


def mma_pipeline_depth(blocks: Blocks, p: int, scheme: int) -> int:
    """Effective MMA instructions per K-step (paper Eq. 13 analogue).

    On TPU the '128x128x128 MXU pass' stands in for one MMA. Scheme I's
    triangular schedule multiplies the per-K-step count by p(p+1)/2.
    """
    per_dot = (blocks.bm // 128) * (blocks.bn // 128) * max(1, blocks.bk // 128)
    tri = p * (p + 1) // 2 if scheme == 1 else 1
    return per_dot * tri
