"""EmuGEMM-I: fused Ozaki Scheme-I Pallas TPU kernel (paper Sec. III).

One kernel executes all p(p+1)/2 slice-pair int8 GEMMs:

  * operands arrive either in the *interleaved* layout (paper Eq. 11):
    Ahat is (M, p*K) with the p slices of each K-chunk adjacent, so one
    BlockSpec fetch of (bM, p*bK) delivers every slice of the chunk to
    VMEM — the TPU analogue of the single-TMA-descriptor property; or as
    the raw *fp32* operand, in which case the kernel's decomposition
    prologue carves the p int8 slices in VMEM via the exact
    truncate-and-subtract recurrence (bit-identical to
    ``repro.core.scheme1.split``) and the (M, p*K) HBM intermediate never
    exists;
  * slice i sits at a static offset (i*bK into the fetched block, or the
    i-th carve of the prologue), so the triangular schedule indexes
    operands with compile-time constants;
  * p int32 accumulators live in VMEM scratch across the K grid dimension
    (paper: RF on Hopper / TMEM on Blackwell);
  * the shift-reduce epilogue (paper Eq. 3 / Alg. 1 lines 9-12) runs
    in-kernel at the last K step, including the diag(mu)/diag(nu) row/col
    scaling — only the final FP tile is written to HBM.

Traffic: Eq. 10 — p(M+N)K operand bytes + b*MN output, vs the naive
Eq. 9's extra 4p(p+1)MN int32 round-trips.  The decomposition side, which
Eqs. 9/10 never charged, is accounted in
``repro.core.traffic.scheme1_decomp_*_bytes``: the interleaved path pays
(8+3p)*dim*K bytes of split/interleave round-trips per operand before the
kernel even starts, the prologue path pays only the 4*dim*K fp32 operand
stream it decomposes in VMEM.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.backends.base import build_pallas_call
from repro.kernels.common import Blocks, carve_slices
from repro.kernels.dispatch import select_blocks


def _kernel(a_ref, b_ref, mu_ref, nu_ref, out_ref, acc_ref, *,
            p: int, beta: int, bk: int, out_dtype,
            a_fp: bool, b_fp: bool):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    if a_fp:
        # Prologue: (bM, bK) fp32 block -> p int8 slices, all in VMEM.
        a_slices = list(carve_slices(a_ref[...] / mu_ref[...], p, beta))
    else:
        a = a_ref[...]  # (bM, p*bK) int8 — all p A-slices of this K-chunk
        a_slices = [a[:, i * bk:(i + 1) * bk] for i in range(p)]
    if b_fp:
        b_slices = list(carve_slices(b_ref[...] / nu_ref[...], p, beta))
    else:
        b = b_ref[...]  # (p*bK, bN) int8 — all p B-slices of this K-chunk
        b_slices = [b[i * bk:(i + 1) * bk, :] for i in range(p)]

    # Triangular MMA schedule (Alg. 1 lines 6-8): C_s += A'_i B'_{s-i}.
    # Slice offsets are python constants — resolved at compile time.
    for s in range(p):
        partial = None
        for i in range(s + 1):
            prod = jax.lax.dot_general(
                a_slices[i], b_slices[s - i], (((1,), (0,)), ((), ())),
                preferred_element_type=jnp.int32)
            partial = prod if partial is None else partial + prod
        acc_ref[s] += partial

    @pl.when(k == pl.num_programs(2) - 1)
    def _epilogue():
        # Shift-reduce: C = diag(mu) (sum_s 2^{-beta(s+2)} C_s) diag(nu).
        c = jnp.zeros(out_ref.shape, dtype=out_dtype)
        for s in range(p):
            # Exact Python power of two (see scheme1.shift_reduce).
            w = jnp.asarray(2.0 ** (-beta * (s + 2)), dtype=out_dtype)
            c = c + w * acc_ref[s].astype(out_dtype)
        out_ref[...] = c * mu_ref[...].astype(out_dtype) \
                         * nu_ref[...].astype(out_dtype)


def _fused_call(a, b, mu, nu, *, m, n, k, p, beta, blocks, out_dtype,
                a_fp, b_fp):
    bm, bn, bk = blocks.bm, blocks.bn, blocks.bk
    kernel = functools.partial(_kernel, p=p, beta=beta, bk=bk,
                               out_dtype=out_dtype, a_fp=a_fp, b_fp=b_fp)
    a_spec = (pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)) if a_fp
              # One contiguous fetch per K-step carries all p slices.
              else pl.BlockSpec((bm, p * bk), lambda i, j, kk: (i, kk)))
    b_spec = (pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)) if b_fp
              else pl.BlockSpec((p * bk, bn), lambda i, j, kk: (kk, j)))
    tag = f"{'f' if a_fp else 'i'}{'f' if b_fp else 'i'}"
    return build_pallas_call(
        kernel,
        grid=(m // bm, n // bn, k // bk),
        in_specs=[
            a_spec,
            b_spec,
            pl.BlockSpec((bm, 1), lambda i, j, kk: (i, 0)),
            pl.BlockSpec((1, bn), lambda i, j, kk: (0, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), out_dtype),
        scratch_shapes=[pltpu.VMEM((p, bm, bn), jnp.int32)],
        dimension_semantics=("parallel", "parallel", "arbitrary"),
        name=f"emugemm1_p{p}_{tag}",
    )(a, b, mu, nu)


def fused_matmul_interleaved(a_hat: jax.Array, b_hat: jax.Array,
                             mu: jax.Array, nu: jax.Array,
                             p: int, beta: int,
                             blocks: Blocks | None = None,
                             out_dtype=jnp.float32) -> jax.Array:
    """Run the fused kernel on pre-interleaved operands.

    a_hat: (M, p*K) int8; b_hat: (p*K, N) int8 — interleaving granularity
    must equal blocks.bk. mu: (M, 1); nu: (1, N) scales.
    """
    m, pk = a_hat.shape
    pk2, n = b_hat.shape
    assert pk == pk2, (a_hat.shape, b_hat.shape)
    k = pk // p
    if blocks is None:
        blocks = select_blocks(m, n, k, p,
                               out_bytes=jnp.dtype(out_dtype).itemsize,
                               backend="tpu")
    if blocks is None or not blocks.aligned(m, n, k):
        raise ValueError(f"no aligned blocks for {(m, n, k)} p={p}")
    return _fused_call(a_hat, b_hat, mu, nu, m=m, n=n, k=k, p=p, beta=beta,
                       blocks=blocks, out_dtype=out_dtype,
                       a_fp=False, b_fp=False)


def fused_matmul_prologue(a: jax.Array, b: jax.Array,
                          mu: jax.Array, nu: jax.Array,
                          p: int, beta: int,
                          blocks: Blocks | None = None,
                          out_dtype=jnp.float32) -> jax.Array:
    """Fused GEMM with the in-kernel decomposition prologue on both sides.

    a: (M, K) float; b: (K, N) float; mu: (M, 1) / nu: (1, N) power-of-two
    scales (full-K row/col reductions, computed by the caller).  The fp32
    tiles are sliced into int8 in VMEM — no (M, p*K) HBM intermediate, no
    split/interleave round-trips.
    """
    m, k = a.shape
    k2, n = b.shape
    assert k == k2, (a.shape, b.shape)
    if blocks is None:
        blocks = select_blocks(m, n, k, p,
                               out_bytes=jnp.dtype(out_dtype).itemsize,
                               backend="tpu",
                               prologue_a=True, prologue_b=True)
    if blocks is None or not blocks.aligned(m, n, k):
        raise ValueError(f"no aligned blocks for {(m, n, k)} p={p}")
    return _fused_call(a, b, mu, nu, m=m, n=n, k=k, p=p, beta=beta,
                       blocks=blocks, out_dtype=out_dtype,
                       a_fp=True, b_fp=True)


def fused_matmul_mixed(a: jax.Array, b_hat: jax.Array,
                       mu: jax.Array, nu: jax.Array,
                       p: int, beta: int, blocks: Blocks,
                       out_dtype=jnp.float32) -> jax.Array:
    """Fused GEMM: fp32 lhs decomposed in-kernel, pre-interleaved int8 rhs.

    The PreparedOperand consumption path: the weight's slices stream from
    HBM (decomposed once, reused), the activation decomposes in VMEM.
    ``blocks.bk`` must equal the rhs interleave granularity.
    """
    m, k = a.shape
    pk, n = b_hat.shape
    assert pk == p * k, (a.shape, b_hat.shape, p)
    if not blocks.aligned(m, n, k):
        raise ValueError(f"blocks {blocks} not aligned for {(m, n, k)}")
    return _fused_call(a, b_hat, mu, nu, m=m, n=n, k=k, p=p, beta=beta,
                       blocks=blocks, out_dtype=out_dtype,
                       a_fp=True, b_fp=False)
