"""Fused Pallas kernels for EmuGEMM precision emulation.

Layering:

  compat.py      feature-probed JAX-version shims (compiler params for
                 TPU Mosaic and GPU Triton/Mosaic-GPU, scalar-prefetch
                 grid specs) — absorb upstream API drift
  backends/      the pluggable kernel-backend subsystem: KernelBackend
                 interface + registry ('tpu' Mosaic, 'gpu'
                 Mosaic-GPU/Triton Scheme-I, 'xla' reference fallback);
                 owns pallas_call construction, per-backend alignment,
                 staging budgets and peak tables
  dispatch.py    routing: one plan_emulated per GEMM (per-backend cached
                 block selection), padded non-aligned handling, batching,
                 launch-policy resolution; selected by
                 EmulationConfig.backend / REPRO_BACKEND
  common.py      TPU VMEM budget model (choose_blocks, incl. the fp32
                 prologue staging terms) and interpret-mode probe
  ozaki1/2/3m, matmul_int8, flash_attn, decompose
                 the Mosaic (TPU-backend) kernels; all route through
                 dispatch. ozaki1 decomposes fp32 tiles in its VMEM
                 prologue; decompose emits pre-interleaved slices (incl.
                 the dual-layout PreparedOperand prep pass)
  prepared.py    PreparedOperand: pre-decomposed rhs (+ K-transposed
                 twin) reused across forward/remat/backward and across
                 serve sessions; StepPrepared for the once-per-step
                 microbatch-scan hoist in launch/steps.py
  ops.py         jit'd end-to-end pipelines (decompose -> kernel -> CRT)
  ref.py         pure-jnp oracles for the test suite
"""

from repro.kernels.backends import (  # noqa: F401
    KernelBackend,
    available_backends,
    get_backend,
    register_backend,
    resolve_backend,
)
from repro.kernels.dispatch import (  # noqa: F401
    auto_fused_matmul,
    build_pallas_call,
    emulated_matmul,
    emulated_matmul_batched,
    plan_emulated,
    resolve_policy,
    select_blocks,
)
from repro.kernels.prepared import (  # noqa: F401
    PreparedOperand,
    prepare_params,
    prepare_rhs,
)
