"""Fused Pallas TPU kernels for EmuGEMM precision emulation.

Layering:

  compat.py      feature-probed JAX-version shims (compiler params,
                 scalar-prefetch grid specs) — absorb upstream API drift
  dispatch.py    the ONLY place pl.pallas_call is constructed; one
                 plan_emulated per GEMM (cached block selection), padded
                 non-aligned routing, batching, launch-policy resolution
  common.py      VMEM budget model (choose_blocks, incl. the fp32
                 prologue staging terms) and interpret-mode probe
  ozaki1/2/3m, matmul_int8, flash_attn, decompose
                 the kernels themselves; all route through dispatch.
                 ozaki1 decomposes fp32 tiles in its VMEM prologue;
                 decompose emits pre-interleaved slices (incl. the
                 dual-layout PreparedOperand prep pass)
  prepared.py    PreparedOperand: pre-decomposed rhs (+ K-transposed
                 twin) reused across forward/remat/backward and across
                 serve sessions
  ops.py         jit'd end-to-end pipelines (decompose -> kernel -> CRT)
  ref.py         pure-jnp oracles for the test suite
"""

from repro.kernels.dispatch import (  # noqa: F401
    build_pallas_call,
    emulated_matmul,
    emulated_matmul_batched,
    plan_emulated,
    resolve_policy,
    select_blocks,
)
from repro.kernels.prepared import (  # noqa: F401
    PreparedOperand,
    prepare_params,
    prepare_rhs,
)
