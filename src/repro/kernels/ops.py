"""jit'd end-to-end wrappers around the fused Pallas kernels.

The full emulated-GEMM pipelines:

  fused_scheme1_matmul : scales -> EmuGEMM-I kernel with the in-kernel
                         decomposition prologue (cfg.decomp='kernel'/'auto'
                         — the fp32 tiles slice to int8 in VMEM), or the
                         historical split -> interleave (Eq. 11) -> kernel
                         pipeline (cfg.decomp='xla')
  fused_scheme2_matmul : integerize -> residues -> EmuGEMM-II kernel -> CRT
  fused_3m_matmul      : complex residues -> fused-3M kernel -> 2x CRT

The remaining pre/post-processing (scale reductions, CRT) are XLA ops —
full-K reductions and multi-word reconstruction don't tile; everything
that *does* tile (slicing, interleaving, the INT32 accumulation, modular
reduction) now runs inside the kernels.

Routing (alignment checks, block caching, padding, batching) lives in
repro.kernels.dispatch.  ``cfg`` is optional on every wrapper here: when
omitted (or given as a spec string) it resolves through the one
documented resolver, ``repro.resolve_config`` — explicit arg > innermost
``repro.emulation`` scope > ``REPRO_EMULATION`` env > the wrapper's own
scheme default — instead of each call-site threading cfg kwargs by hand.
"""

from __future__ import annotations

import warnings
from functools import partial

import jax
import jax.numpy as jnp

from repro.core import complex3m, scheme1, scheme2
from repro.core.precision import EmulationConfig, scheme2_budget
from repro.kernels import dispatch, ozaki1, ozaki2, ozaki3m
from repro.kernels.matmul_int8 import int8_matmul  # noqa: F401  (re-export)


def _resolve(cfg, scheme: str, p: int) -> EmulationConfig:
    """Resolve an optional cfg/spec for a scheme-pinned wrapper.

    Resolution happens *before* the jitted body (cfg is a static
    argument): a cached trace can never capture a stale ambient scope.
    An *explicit* cfg of the wrong scheme is a caller error; an ambient
    config of another scheme (REPRO_EMULATION=native, an ozaki2 scope
    around a scheme1 wrapper) is simply not for this wrapper — it falls
    back to the pinned default rather than break explicit kernel calls.
    """
    from repro import api
    if cfg is not None:
        cfg = api.precision(cfg)
        if cfg.scheme != scheme:
            raise ValueError(f"this wrapper is {scheme}-only; got "
                             f"scheme={cfg.scheme!r}")
        return cfg
    ambient = api.current_emulation()
    if ambient is not None and ambient.scheme == scheme:
        return ambient
    return EmulationConfig(scheme=scheme, p=p)


@partial(jax.jit, static_argnames=("cfg", "out_dtype", "blocks"))
def _fused_scheme1_matmul(a: jax.Array, b: jax.Array, cfg: EmulationConfig,
                          out_dtype=jnp.float32, blocks=None) -> jax.Array:
    m, k = a.shape
    _, n = b.shape
    p = cfg.p
    beta = cfg.resolved_beta(k)
    prologue = cfg.decomp in ("auto", "kernel")
    if blocks is None:
        blocks = dispatch.select_blocks(
            m, n, k, p, out_bytes=jnp.dtype(out_dtype).itemsize,
            backend="tpu", prologue_a=prologue, prologue_b=prologue)
    if blocks is None or not blocks.aligned(m, n, k):
        raise ValueError(f"shapes {(m, n, k)} not tile-aligned")
    if prologue:
        # Only the power-of-two scales (full-K reductions) run in XLA;
        # slicing happens in the kernel — no (M, p*K) HBM intermediate.
        # The kernel's truncate-subtract runs at >= float32, mirroring
        # split: ints/half floats widen to f32, f64 keeps its mantissa.
        def widen(x):
            if (not jnp.issubdtype(x.dtype, jnp.floating)
                    or jnp.dtype(x.dtype).itemsize < 4):
                return x.astype(jnp.float32)
            return x
        a, b = widen(a), widen(b)
        mu = scheme1._pow2_row_scale(a, axis=1)
        nu = scheme1._pow2_row_scale(b, axis=0)
        return ozaki1.fused_matmul_prologue(
            a, b, mu, nu, p, beta, blocks, out_dtype=out_dtype)
    a_sl, mu = scheme1.split(a, p, beta, axis=1)
    b_sl, nu = scheme1.split(b, p, beta, axis=0)
    a_hat = scheme1.interleave_k(a_sl, "a", blocks.bk)
    b_hat = scheme1.interleave_k(b_sl, "b", blocks.bk)
    return ozaki1.fused_matmul_interleaved(
        a_hat, b_hat, mu.astype(jnp.float32), nu.astype(jnp.float32),
        p, beta, blocks, out_dtype=out_dtype)


def fused_scheme1_matmul(a: jax.Array, b: jax.Array,
                         cfg: "EmulationConfig | str | None" = None,
                         out_dtype=jnp.float32, blocks=None) -> jax.Array:
    """End-to-end EmuGEMM-I: (M,K) x (K,N) float -> (M,N) out_dtype.

    ``cfg`` resolves through ``repro.resolve_config`` (ozaki1-p4 when
    nothing is configured); ``blocks`` (from ``dispatch.plan_emulated``)
    skips the re-search; the decomposition site follows ``cfg.decomp``.
    """
    return _fused_scheme1_matmul(a, b, _resolve(cfg, "ozaki1", 4),
                                 out_dtype=out_dtype, blocks=blocks)


def _canonical_residues(res8: jax.Array, moduli) -> jax.Array:
    """Balanced (p, M, N) int8 residues -> canonical [0, m_l) int32.

    One fused broadcast remainder against the constant moduli array —
    the per-modulus Python loop unrolled p ``remainder`` + ``stack`` ops
    into the graph; this is a single elementwise op.
    """
    mods = jnp.asarray(moduli, jnp.int32).reshape(-1, 1, 1)
    return jnp.remainder(res8.astype(jnp.int32), mods)


@partial(jax.jit, static_argnames=("cfg", "out_dtype"))
def _fused_scheme2_matmul(a: jax.Array, b: jax.Array, cfg: EmulationConfig,
                          out_dtype=jnp.float32) -> jax.Array:
    m, k = a.shape
    _, n = b.shape
    moduli = cfg.resolved_moduli()
    scheme2.check_exact_k(k, moduli)
    budget = min(scheme2_budget(moduli, k), jnp.finfo(a.dtype).nmant + 1)
    a_int, mu = scheme2.integerize(a, axis=1, budget_bits=budget)
    b_int, nu = scheme2.integerize(b, axis=0, budget_bits=budget)
    a_res = scheme2.balanced_residues(a_int, moduli)
    b_res = scheme2.balanced_residues(b_int, moduli)
    c_res8 = ozaki2.fused_residue_matmul(a_res, b_res, moduli)
    c_res = _canonical_residues(c_res8, moduli)
    out_t = jnp.dtype(out_dtype).type
    c_int = scheme2.crt_reconstruct(c_res, moduli, out_t)
    return c_int / (mu.astype(out_t) * nu.astype(out_t))


def fused_scheme2_matmul(a: jax.Array, b: jax.Array,
                         cfg: "EmulationConfig | str | None" = None,
                         out_dtype=jnp.float32) -> jax.Array:
    """End-to-end EmuGEMM-II real GEMM (cfg via ``repro.resolve_config``,
    ozaki2 with the default 8-modulus set when nothing is configured)."""
    return _fused_scheme2_matmul(a, b, _resolve(cfg, "ozaki2", 8),
                                 out_dtype=out_dtype)


@partial(jax.jit, static_argnames=("cfg", "out_dtype"))
def _fused_3m_matmul(a: jax.Array, b: jax.Array, cfg: EmulationConfig,
                     out_dtype=None) -> jax.Array:
    if out_dtype is None:
        out_dtype = jnp.float64 if a.dtype == jnp.complex128 else jnp.float32
    out_t = jnp.dtype(out_dtype).type
    moduli = cfg.resolved_moduli()
    k = a.shape[-1]
    scheme2.check_exact_k(k, moduli)
    real_t = jnp.real(a).dtype
    budget = min(scheme2_budget(moduli, k, complex_guard=True),
                 jnp.finfo(real_t).nmant + 1)
    ar, ai = jnp.real(a), jnp.imag(a)
    br, bi = jnp.real(b), jnp.imag(b)
    mu = scheme2._pow2_int_scale(jnp.maximum(jnp.abs(ar), jnp.abs(ai)),
                                 axis=1, budget_bits=budget)
    nu = scheme2._pow2_int_scale(jnp.maximum(jnp.abs(br), jnp.abs(bi)),
                                 axis=0, budget_bits=budget)
    ar_res = scheme2.balanced_residues(jnp.trunc(ar * mu), moduli)
    ai_res = scheme2.balanced_residues(jnp.trunc(ai * mu), moduli)
    br_res = scheme2.balanced_residues(jnp.trunc(br * nu), moduli)
    bi_res = scheme2.balanced_residues(jnp.trunc(bi * nu), moduli)

    def sum_res(x_res, y_res, mm):
        return complex3m._balanced(
            x_res.astype(jnp.int32) + y_res.astype(jnp.int32), mm)

    a3 = jnp.stack([
        jnp.stack([ar_res[l], ai_res[l],
                   sum_res(ar_res[l], ai_res[l], int(mm))])
        for l, mm in enumerate(moduli)])          # (p, 3, M, K)
    b3 = jnp.stack([
        jnp.stack([br_res[l], bi_res[l],
                   sum_res(br_res[l], bi_res[l], int(mm))])
        for l, mm in enumerate(moduli)])          # (p, 3, K, N)

    c_re8, c_im8 = ozaki3m.fused_3m_residue_matmul(a3, b3, moduli)
    c_re = _canonical_residues(c_re8, moduli)
    c_im = _canonical_residues(c_im8, moduli)
    cr = scheme2.crt_reconstruct(c_re, moduli, out_t)
    ci = scheme2.crt_reconstruct(c_im, moduli, out_t)
    inv = 1.0 / (mu.astype(out_t) * nu.astype(out_t))
    return jax.lax.complex(cr * inv, ci * inv)


def fused_3m_matmul(a: jax.Array, b: jax.Array,
                    cfg: "EmulationConfig | str | None" = None,
                    out_dtype=None) -> jax.Array:
    """End-to-end EmuGEMM-II complex GEMM via fused 3M (cfg via
    ``repro.resolve_config``)."""
    return _fused_3m_matmul(a, b, _resolve(cfg, "ozaki2", 8),
                            out_dtype=out_dtype)


def maybe_fused_matmul(a: jax.Array, b: jax.Array, cfg: EmulationConfig):
    """Deprecated dispatch hook; use ``dispatch.auto_fused_matmul``."""
    warnings.warn(
        "ops.maybe_fused_matmul is deprecated; call "
        "dispatch.auto_fused_matmul (or repro.dot_general/einsum)",
        DeprecationWarning, stacklevel=2)
    return dispatch.auto_fused_matmul(a, b, cfg)
