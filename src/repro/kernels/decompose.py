"""Fused Scheme-I decomposition + interleave kernels (paper Sec. III-A).

The paper's preprocessing pass: split the scaled operand into p signed
β-bit slices by iterated truncate-and-subtract and write each slice's
t_K-wide chunk *directly to its interleaved position* (Eq. 11) — one
read of the operand and one write of the slice matrix, no intermediate
(p, M, K) materialization and no separate interleave transpose.

Three kernels:

  * ``decompose_interleave``      lhs layout:  A (M, K)  -> Â (M, p*K)
  * ``decompose_interleave_rhs``  rhs layout:  B (K, N)  -> B̂ (p*K, N)
  * ``decompose_interleave_pair`` one read of B (K, N) -> B̂ (p*K, N)
    *and* its K-transposed twin T̂ (p*N, K) (the rhs layout of B^T used by
    the backward dA = dC @ B^T) — the PreparedOperand prep pass, paying a
    single fp32 read for both layouts.

Interleave granularity equals the matmul block's K width, so each grid
cell produces the full interleaved column/row group of its chunk.

Scales (power-of-two, |a/scale| < 1) are computed by the caller — they
need a full-K reduction and are reused across operands.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.backends.base import build_pallas_call
from repro.kernels.common import carve_slices


def _kernel(a_ref, mu_ref, out_ref, *, p: int, beta: int, bk: int):
    for j, s in enumerate(carve_slices(a_ref[...] / mu_ref[...], p, beta)):
        out_ref[:, j * bk:(j + 1) * bk] = s


def decompose_interleave(a: jax.Array, mu: jax.Array, p: int, beta: int,
                         bm: int = 256, bk: int = 256) -> jax.Array:
    """a: (M, K) float; mu: (M, 1) power-of-two row scales.

    Returns the interleaved slice matrix Â of shape (M, p*K) int8 with
    interleave granularity ``bk`` (pass the matmul kernel's block K).
    """
    m, k = a.shape
    bm = min(bm, m)
    bk = min(bk, k)
    assert m % bm == 0 and k % bk == 0, (m, k, bm, bk)
    kernel = functools.partial(_kernel, p=p, beta=beta, bk=bk)
    return build_pallas_call(
        kernel,
        grid=(m // bm, k // bk),
        in_specs=[pl.BlockSpec((bm, bk), lambda i, c: (i, c)),
                  pl.BlockSpec((bm, 1), lambda i, c: (i, 0))],
        out_specs=pl.BlockSpec((bm, p * bk), lambda i, c: (i, c)),
        out_shape=jax.ShapeDtypeStruct((m, p * k), jnp.int8),
        dimension_semantics=("parallel", "parallel"),
        name=f"decompose_interleave_p{p}",
    )(a, mu)


def _kernel_rhs(b_ref, nu_ref, out_ref, *, p: int, beta: int, bk: int):
    for j, s in enumerate(carve_slices(b_ref[...] / nu_ref[...], p, beta)):
        out_ref[j * bk:(j + 1) * bk, :] = s


def decompose_interleave_rhs(b: jax.Array, nu: jax.Array, p: int, beta: int,
                             bk: int = 256, bn: int = 256) -> jax.Array:
    """b: (K, N) float; nu: (1, N) power-of-two column scales.

    Returns B̂ of shape (p*K, N) int8: row groups cycling
    B'_0 | ... | B'_{p-1} per ``bk``-wide K-chunk (paper Eq. 11, rhs).
    """
    k, n = b.shape
    bk = min(bk, k)
    bn = min(bn, n)
    assert k % bk == 0 and n % bn == 0, (k, n, bk, bn)
    kernel = functools.partial(_kernel_rhs, p=p, beta=beta, bk=bk)
    return build_pallas_call(
        kernel,
        grid=(k // bk, n // bn),
        in_specs=[pl.BlockSpec((bk, bn), lambda c, j: (c, j)),
                  pl.BlockSpec((1, bn), lambda c, j: (0, j))],
        out_specs=pl.BlockSpec((p * bk, bn), lambda c, j: (c, j)),
        out_shape=jax.ShapeDtypeStruct((p * k, n), jnp.int8),
        dimension_semantics=("parallel", "parallel"),
        name=f"decompose_interleave_rhs_p{p}",
    )(b, nu)


def _kernel_pair(b_ref, nu_ref, tau_ref, fwd_ref, twin_ref, *,
                 p: int, beta_f: int, beta_b: int, bk: int, bt: int):
    b = b_ref[...]                       # (bk, bt) fp32 chunk of B
    for j, s in enumerate(carve_slices(b / nu_ref[...], p, beta_f)):
        fwd_ref[j * bk:(j + 1) * bk, :] = s
    # Same chunk, transposed, rescaled per-row-of-B: the B^T rhs layout.
    bt_tile = b.T / tau_ref[...]         # (bt, bk)
    for j, s in enumerate(carve_slices(bt_tile, p, beta_b)):
        twin_ref[j * bt:(j + 1) * bt, :] = s


def decompose_interleave_pair(b: jax.Array, nu: jax.Array, tau: jax.Array,
                              p: int, beta_fwd: int, beta_bwd: int,
                              bk: int = 256, bt: int = 256):
    """One fp32 read of B (K, N) -> (B̂ (p*K, N), T̂ (p*N, K)) int8.

    ``nu`` (1, N) scales the forward rhs layout at granularity ``bk``;
    ``tau`` (1, K) scales the K-transposed twin (the rhs layout of B^T,
    fed to the backward dA GEMM) at granularity ``bt``.  The two layouts
    decompose with their own β (the contraction dims K and N differ).
    """
    k, n = b.shape
    bk = min(bk, k)
    bt = min(bt, n)
    assert k % bk == 0 and n % bt == 0, (k, n, bk, bt)
    kernel = functools.partial(_kernel_pair, p=p, beta_f=beta_fwd,
                               beta_b=beta_bwd, bk=bk, bt=bt)
    return build_pallas_call(
        kernel,
        grid=(k // bk, n // bt),
        in_specs=[pl.BlockSpec((bk, bt), lambda c, j: (c, j)),
                  pl.BlockSpec((1, bt), lambda c, j: (0, j)),
                  pl.BlockSpec((1, bk), lambda c, j: (0, c))],
        out_specs=[pl.BlockSpec((p * bk, bt), lambda c, j: (c, j)),
                   pl.BlockSpec((p * bt, bk), lambda c, j: (j, c))],
        out_shape=[jax.ShapeDtypeStruct((p * k, n), jnp.int8),
                   jax.ShapeDtypeStruct((p * n, k), jnp.int8)],
        dimension_semantics=("parallel", "parallel"),
        name=f"decompose_interleave_pair_p{p}",
    )(b, nu, tau)
