"""Fused Scheme-I decomposition + interleave kernel (paper Sec. III-A).

The paper's preprocessing pass: split the scaled operand into p signed
β-bit slices by iterated truncate-and-subtract and write each slice's
t_K-wide chunk *directly to its interleaved position* (Eq. 11) — one
read of A and one write of Â, no intermediate (p, M, K) materialization.

Interleave granularity equals the block's K width, so each grid cell
(i, c) produces the full (bm, p*bk) interleaved column group of its
K-chunk: Â[:, (c*p+j)*bk : (c*p+j+1)*bk] = slice_j of chunk c.

Row scales mu (power-of-two, |a/mu| < 1) are computed by the caller —
they need a full-K row reduction and are reused across operands.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.dispatch import build_pallas_call


def _kernel(a_ref, mu_ref, out_ref, *, p: int, beta: int, bk: int):
    r = a_ref[...] / mu_ref[...]          # exact: mu is a power of two
    two_beta = float(2 ** beta)
    for j in range(p):
        shifted = r * two_beta            # exact shift
        s = jnp.trunc(shifted)            # |s| <= 2^beta - 1
        out_ref[:, j * bk:(j + 1) * bk] = s.astype(jnp.int8)
        r = shifted - s                   # exact fractional remainder


def decompose_interleave(a: jax.Array, mu: jax.Array, p: int, beta: int,
                         bm: int = 256, bk: int = 256) -> jax.Array:
    """a: (M, K) float; mu: (M, 1) power-of-two row scales.

    Returns the interleaved slice matrix Â of shape (M, p*K) int8 with
    interleave granularity ``bk`` (pass the matmul kernel's block K).
    """
    m, k = a.shape
    bm = min(bm, m)
    bk = min(bk, k)
    assert m % bm == 0 and k % bk == 0, (m, k, bm, bk)
    kernel = functools.partial(_kernel, p=p, beta=beta, bk=bk)
    return build_pallas_call(
        kernel,
        grid=(m // bm, k // bk),
        in_specs=[pl.BlockSpec((bm, bk), lambda i, c: (i, c)),
                  pl.BlockSpec((bm, 1), lambda i, c: (i, 0))],
        out_specs=pl.BlockSpec((bm, p * bk), lambda i, c: (i, c)),
        out_shape=jax.ShapeDtypeStruct((m, p * k), jnp.int8),
        dimension_semantics=("parallel", "parallel"),
        name=f"decompose_interleave_p{p}",
    )(a, mu)
