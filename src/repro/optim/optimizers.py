"""AdamW and Adafactor over parameter pytrees.

States mirror the parameter tree, so they pick up the exact same
NamedShardings as the parameters under pjit — optimizer sharding (ZeRO)
falls out of GSPMD instead of being a separate mechanism. Adafactor
factors the second moment of >=2-D parameters into row/col statistics
(the memory plan for deepseek-v3-671b depends on this).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def global_norm(tree) -> jax.Array:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32)))
              for x in jax.tree.leaves(tree)]
    return jnp.sqrt(sum(leaves))


def clip_by_global_norm(grads, max_norm: float):
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale), grads), norm


def warmup_cosine(step, peak_lr: float, warmup: int = 100,
                  total: int = 10000, floor: float = 0.1):
    step = step.astype(jnp.float32) if hasattr(step, "astype") else float(step)
    warm = peak_lr * step / max(1, warmup)
    frac = jnp.clip((step - warmup) / max(1, total - warmup), 0.0, 1.0)
    cos = peak_lr * (floor + (1 - floor) * 0.5 * (1 + jnp.cos(jnp.pi * frac)))
    return jnp.where(step < warmup, warm, cos)


# ---------------------------------------------------------------------------
# AdamW
# ---------------------------------------------------------------------------

def adamw_init(params):
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    return {"m": jax.tree.map(zeros, params),
            "v": jax.tree.map(zeros, params),
            "step": jnp.zeros((), jnp.int32)}


def adamw_update(grads, state, params, lr, b1=0.9, b2=0.95, eps=1e-8,
                 weight_decay=0.1):
    step = state["step"] + 1
    t = step.astype(jnp.float32)
    bc1 = 1 - b1 ** t
    bc2 = 1 - b2 ** t

    def upd(g, m, v, p):
        g = g.astype(jnp.float32)
        m = b1 * m + (1 - b1) * g
        v = b2 * v + (1 - b2) * g * g
        u = (m / bc1) / (jnp.sqrt(v / bc2) + eps)
        if p.ndim >= 2:  # decoupled weight decay on matrices only
            u = u + weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * u).astype(p.dtype), m, v

    out = jax.tree.map(upd, grads, state["m"], state["v"], params)
    new_params = jax.tree.map(lambda o: o[0], out,
                              is_leaf=lambda x: isinstance(x, tuple))
    new_m = jax.tree.map(lambda o: o[1], out,
                         is_leaf=lambda x: isinstance(x, tuple))
    new_v = jax.tree.map(lambda o: o[2], out,
                         is_leaf=lambda x: isinstance(x, tuple))
    return new_params, {"m": new_m, "v": new_v, "step": step}


# ---------------------------------------------------------------------------
# Adafactor (factored second moments, no first moment)
# ---------------------------------------------------------------------------

def adafactor_init(params):
    def vr(p):
        return jnp.zeros(p.shape[:-1], jnp.float32) if p.ndim >= 2 else \
            jnp.zeros(p.shape, jnp.float32)

    def vc(p):
        return jnp.zeros(p.shape[:-2] + p.shape[-1:], jnp.float32) \
            if p.ndim >= 2 else jnp.zeros((1,), jnp.float32)

    return {"vr": jax.tree.map(vr, params),
            "vc": jax.tree.map(vc, params),
            "step": jnp.zeros((), jnp.int32)}


def adafactor_update(grads, state, params, lr, decay=0.8, eps=1e-30,
                     clip_threshold=1.0):
    step = state["step"] + 1
    t = step.astype(jnp.float32)
    beta = 1.0 - t ** (-decay)

    def upd(g, vr, vc, p):
        g = g.astype(jnp.float32)
        g2 = g * g + eps
        if p.ndim >= 2:
            vr = beta * vr + (1 - beta) * g2.mean(-1)
            vc = beta * vc + (1 - beta) * g2.mean(-2)
            r = vr / jnp.maximum(vr.mean(-1, keepdims=True), eps)
            u = g / (jnp.sqrt(r)[..., None] * jnp.sqrt(vc)[..., None, :]
                     + 1e-12)
        else:
            vr = beta * vr + (1 - beta) * g2
            u = g / (jnp.sqrt(vr) + 1e-12)
        rms = jnp.sqrt(jnp.mean(u * u) + 1e-12)
        u = u / jnp.maximum(1.0, rms / clip_threshold)
        return (p.astype(jnp.float32) - lr * u).astype(p.dtype), vr, vc

    out = jax.tree.map(upd, grads, state["vr"], state["vc"], params)
    is_t = lambda x: isinstance(x, tuple)
    return (jax.tree.map(lambda o: o[0], out, is_leaf=is_t),
            {"vr": jax.tree.map(lambda o: o[1], out, is_leaf=is_t),
             "vc": jax.tree.map(lambda o: o[2], out, is_leaf=is_t),
             "step": step})


def make_optimizer(kind: str):
    if kind == "adamw":
        return adamw_init, adamw_update
    if kind == "adafactor":
        return adafactor_init, adafactor_update
    raise ValueError(f"unknown optimizer {kind!r}")
