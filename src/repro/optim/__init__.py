"""Optimizers, schedules, gradient clipping — pure-pytree implementations
whose states inherit parameter sharding (ZeRO by construction under pjit)."""

from repro.optim.optimizers import (  # noqa: F401
    adafactor_init,
    adafactor_update,
    adamw_init,
    adamw_update,
    clip_by_global_norm,
    global_norm,
    make_optimizer,
    warmup_cosine,
)
