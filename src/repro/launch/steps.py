"""jit-compiled train / prefill / decode steps with explicit shardings.

These builders are shared by the real entry points (launch/train.py,
launch/serve.py) and the multi-pod dry-run (launch/dryrun.py): the dry-run
lowers exactly the functions that would run on hardware.
"""

from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import ArchConfig, ShapeSpec
from repro.kernels import dispatch
from repro.models import model as M
from repro.models.common import GemmPolicy, cross_entropy_loss
from repro.optim import clip_by_global_norm, make_optimizer, warmup_cosine
from repro.parallel import sharding as shd

MTP_WEIGHT = 0.3


# ---------------------------------------------------------------------------
# Shape/spec helpers (dry-run friendly: everything works on ShapeDtypeStruct).
# ---------------------------------------------------------------------------

def abstract_params(arch: ArchConfig):
    return jax.eval_shape(partial(M.init_params, mcfg=arch.model),
                          jax.random.PRNGKey(0))


def abstract_opt(arch: ArchConfig, params):
    opt_init, _ = make_optimizer(arch.train.optimizer)
    return jax.eval_shape(opt_init, params)


def abstract_cache(arch: ArchConfig, batch: int, max_seq: int):
    return jax.eval_shape(
        partial(M.init_cache, arch.model, batch, max_seq))


def state_specs(arch: ArchConfig, mesh):
    params = abstract_params(arch)
    p_specs = shd.param_pspecs(params, mesh, fsdp=arch.train.fsdp,
                               attn_sp=arch.model.attn_sharding == "sp")
    opt = abstract_opt(arch, params)
    o_specs = shd.opt_pspecs(opt, p_specs, mesh, zero2=arch.train.zero2)
    return {"params": p_specs, "opt": o_specs}


def _batch_axes(mesh, batch: int):
    """Data axes if the global batch divides them, else replicate
    (long_500k has batch 1)."""
    dp = shd.data_axes(mesh)
    return shd._fit(batch, dp, mesh)


def batch_specs(arch: ArchConfig, shape: ShapeSpec, mesh):
    specs = {}
    for name, leaf in arch.input_specs(shape).items():
        specs[name] = P(_batch_axes(mesh, leaf.shape[0]),
                        *([None] * (leaf.ndim - 1)))
    return specs


def named(specs, mesh):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), specs,
                        is_leaf=lambda x: isinstance(x, P))


# ---------------------------------------------------------------------------
# Train step.
# ---------------------------------------------------------------------------

def make_loss_fn(arch: ArchConfig, policy: GemmPolicy):
    mcfg = arch.model
    vocab = mcfg.vocab

    def loss_fn(params, batch, preps=None):
        if preps:
            # Once-per-step prepared weights (built outside the
            # microbatch scan — see make_train_step) replace their float
            # leaves with StepPrepared pairs consumed by dense().
            from repro.kernels import prepared
            params = prepared.attach_step_preps(params, preps)
        logits, mtp_logits, aux = M.forward_train(
            params, mcfg, batch, policy, remat=arch.train.remat)
        loss = cross_entropy_loss(logits, batch["labels"], vocab)
        if mtp_logits is not None:
            # MTP predicts token t+2: shift next-token labels once more.
            mtp_labels = jnp.concatenate(
                [batch["labels"][:, 1:],
                 -jnp.ones_like(batch["labels"][:, :1])], axis=1)
            loss = loss + MTP_WEIGHT * cross_entropy_loss(
                mtp_logits, mtp_labels, vocab)
        return loss + aux

    return loss_fn


def make_train_step(arch: ArchConfig, mesh, shape: ShapeSpec | None = None,
                    policy: GemmPolicy | None = None,
                    donate: bool = True):
    # The dispatcher owns emulation selection: resolve_policy first
    # materializes an unset policy default through the one resolver
    # (explicit policy > ambient repro.emulation scope > REPRO_EMULATION
    # env > native), then decides how fused Pallas call-sites launch:
    # on a concrete multi-device mesh with a shardable backend it
    # *records the mesh on the policy* — dense() then runs the fused
    # kernel per shard under shard_map with explicit collectives
    # (repro.parallel.shard_gemm) — and only the remaining geometries
    # (AbstractMesh dry-runs, non-shardable backends) rewrite to the
    # XLA expansion GSPMD can partition.
    # cfg.cache_weights survives either route: under impl='xla' the
    # once-per-step PreparedOperand slices are plain int8 arrays the
    # partitioner handles like any other operand, and under the
    # shard_map route each model shard prepares its own slice stack
    # (local K == global K in the column-parallel layout), so emulated
    # training still decomposes each projection weight once per step /
    # shard instead of 3x per layer (forward, remat re-forward,
    # backward B^T re-split).
    # No explicit policy: the arch config's gemm_sites table decides
    # (arch.gemm_policy() is the bare ambient-deferring GemmPolicy()
    # when the config ships no site specs — the historical default).
    if policy is None:
        policy = arch.gemm_policy()
    policy = dispatch.resolve_policy(policy, mesh)
    loss_fn = make_loss_fn(arch, policy)
    _, opt_update = make_optimizer(arch.train.optimizer)
    n_micro = arch.train.microbatches
    dp = shd.data_axes(mesh)
    g_shardings = None
    if arch.train.zero2:
        ap = abstract_params(arch)
        g_specs = shd.grad_pspecs(
            ap, shd.param_pspecs(ap, mesh, fsdp=arch.train.fsdp,
                                 attn_sp=arch.model.attn_sharding == "sp"),
            mesh, True)
        g_shardings = named(g_specs, mesh)

    def train_step(state, batch):
        params = state["params"]

        if n_micro > 1:
            def reshard(x):
                x = x.reshape(n_micro, x.shape[0] // n_micro, *x.shape[1:])
                return jax.lax.with_sharding_constraint(
                    x, NamedSharding(mesh, P(None, dp,
                                             *([None] * (x.ndim - 2)))))
            micro = jax.tree.map(reshard, batch)

            # Gradient accumulation: build each cacheable weight's
            # PreparedOperand HERE, outside the scan body, so the
            # decomposition runs once per optimizer step. The scan body
            # closes over the finished slices (loop-invariant constants
            # of the compiled while loop) — previously cache_weights
            # still re-prepared once per *microbatch* inside the VJP.
            preps = None
            from repro.kernels import prepared
            if prepared.policy_caches_weights(policy):
                preps = prepared.build_step_preps(params, policy)

            def acc_fn(carry, mb):
                g_acc, l_acc = carry
                l, g = jax.value_and_grad(loss_fn)(params, mb, preps)
                g_acc = jax.tree.map(
                    lambda a, b: a + b.astype(jnp.float32), g_acc, g)
                return (g_acc, l_acc + l), None

            g0 = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32),
                              params)
            if g_shardings is not None:
                # ZeRO-2: the f32 grad accumulator is data-sharded, so
                # each microbatch's gradient add reduce-scatters instead
                # of living replicated.
                g0 = jax.lax.with_sharding_constraint(g0, g_shardings)
            (grads, loss), _ = jax.lax.scan(acc_fn, (g0, 0.0), micro)
            grads = jax.tree.map(lambda g: g / n_micro, grads)
            loss = loss / n_micro
        else:
            loss, grads = jax.value_and_grad(loss_fn)(params, batch)

        grads, gnorm = clip_by_global_norm(grads, 1.0)
        lr = warmup_cosine(state["opt"]["step"], arch.train.learning_rate)
        new_params, new_opt = opt_update(grads, state["opt"], params, lr)
        metrics = {"loss": loss, "grad_norm": gnorm, "lr": lr}
        return {"params": new_params, "opt": new_opt}, metrics

    specs = state_specs(arch, mesh)
    in_state = named(specs, mesh)
    batch_sh = named(batch_specs(arch, shape, mesh), mesh) if shape else None
    metrics_sh = named({"loss": P(), "grad_norm": P(), "lr": P()}, mesh)
    return jax.jit(
        train_step,
        in_shardings=(in_state, batch_sh),
        out_shardings=(in_state, metrics_sh),
        donate_argnums=(0,) if donate else ())


# ---------------------------------------------------------------------------
# Serve steps.
# ---------------------------------------------------------------------------

def make_prefill_step(arch: ArchConfig, shape: ShapeSpec, mesh,
                      policy: GemmPolicy | None = None):
    policy = dispatch.resolve_policy(
        policy if policy is not None else arch.gemm_policy(), mesh)
    mcfg = arch.model

    if not mcfg.causal:   # encoder: 'prefill' is a plain forward pass
        def prefill(params, inputs):
            logits, _, _ = M.forward_train(params, mcfg, inputs, policy,
                                           remat=False)
            return logits
        out_sh = None
    else:
        def prefill(params, inputs):
            return M.forward_prefill(params, mcfg, inputs, shape.seq_len,
                                     policy)
        cache = abstract_cache(arch, shape.global_batch, shape.seq_len)
        c_specs = shd.cache_pspecs(cache, mesh)
        dp = _batch_axes(mesh, shape.global_batch)
        out_sh = (NamedSharding(mesh, P(dp, None, None)),
                  named(c_specs, mesh))

    params = abstract_params(arch)
    p_specs = shd.param_pspecs(params, mesh, fsdp=arch.train.fsdp,
                               attn_sp=arch.model.attn_sharding == "sp")
    batch_sh = named(batch_specs(arch, shape, mesh), mesh)
    return jax.jit(prefill,
                   in_shardings=(named(p_specs, mesh), batch_sh),
                   out_shardings=out_sh)


def make_decode_step(arch: ArchConfig, shape: ShapeSpec, mesh,
                     policy: GemmPolicy | None = None,
                     donate: bool = True):
    policy = dispatch.resolve_policy(
        policy if policy is not None else arch.gemm_policy(), mesh)
    mcfg = arch.model

    def decode(params, cache, tokens, pos):
        return M.forward_decode(params, mcfg, tokens, pos, cache, policy)

    params = abstract_params(arch)
    p_specs = shd.param_pspecs(params, mesh, fsdp=arch.train.fsdp,
                               attn_sp=arch.model.attn_sharding == "sp")
    cache = abstract_cache(arch, shape.global_batch, shape.seq_len)
    c_specs = shd.cache_pspecs(cache, mesh)
    dp = _batch_axes(mesh, shape.global_batch)
    return jax.jit(
        decode,
        in_shardings=(named(p_specs, mesh), named(c_specs, mesh),
                      NamedSharding(mesh, P(dp, None)), None),
        out_shardings=(NamedSharding(mesh, P(dp, None, None)),
                       named(c_specs, mesh)),
        donate_argnums=(1,) if donate else ())
