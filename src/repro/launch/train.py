"""Training launcher.

Runs a real (CPU-host or TPU) training loop with the full substrate:
sharded step function, deterministic data pipeline, fault-tolerant
trainer with auto-resume. On this container, use ``--smoke`` (reduced
configs) — the full configs are exercised via launch/dryrun.py.

  PYTHONPATH=src python -m repro.launch.train --arch olmo-1b --smoke \
      --steps 50 --batch 8 --seq 128 --gemm ozaki1-p3

Notable flags:
  --gemm      emulated-GEMM backend for every dense projection
  --fail-at   inject a failure at step N (fault-tolerance demo)
  --resume    re-launch after a failure and continue from the checkpoint
"""

from __future__ import annotations

import argparse
import dataclasses

import jax

from repro import api, configs
from repro.configs.base import ShapeSpec
from repro.data import make_batch_iterator
from repro.launch import steps as S
from repro.launch.mesh import make_host_mesh
from repro.models import model as M
from repro.models.common import GemmPolicy
from repro.optim import make_optimizer
from repro.runtime import Trainer
from repro.runtime.trainer import FailureInjector


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="olmo-1b")
    ap.add_argument("--smoke", action="store_true",
                    help="use the reduced config (CPU-runnable)")
    ap.add_argument("--steps", type=int, default=20,
                    help="TOTAL step count — a resumed run only executes "
                         "the remainder")
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--gemm", default=None,
                    help="precision spec (e.g. ozaki1-p3, ozaki1-p4+cached, "
                         "bits=30); omitted, the ambient REPRO_EMULATION "
                         "env / repro.emulation scope decides")
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=25)
    ap.add_argument("--fail-at", type=int, default=None)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--model-parallel", type=int, default=1)
    ap.add_argument("--metrics-jsonl", default=None,
                    help="write one telemetry record per step to this "
                         "JSONL file (implies telemetry; aggregate with "
                         "python -m repro.telemetry.report)")
    ap.add_argument("--metrics-prom", default=None,
                    help="dump the final Prometheus text-format metrics "
                         "to this file at exit (stdout when telemetry is "
                         "enabled and no path is given)")
    args = ap.parse_args(argv)

    arch = (configs.get_smoke_config(args.arch) if args.smoke
            else configs.get_config(args.arch))
    shape = ShapeSpec("cli", args.seq, args.batch, "train")
    mesh = make_host_mesh(args.model_parallel)
    # --gemm overrides everything; otherwise None lets make_train_step
    # pick up the arch config's own gemm_sites policy (the -emu zoo
    # variants), which still defers to the ambient resolver when empty.
    policy = (GemmPolicy(default=api.precision(args.gemm))
              if args.gemm else None)

    opt_init, _ = make_optimizer(arch.train.optimizer)

    def init_state():
        params = M.init_params(jax.random.PRNGKey(args.seed), arch.model)
        return {"params": params, "opt": opt_init(params)}

    with mesh:
        step_fn = S.make_train_step(arch, mesh, shape, policy, donate=False)
        state_sh = S.named(S.state_specs(arch, mesh), mesh)
        trainer = Trainer(
            step_fn=step_fn,
            init_state_fn=init_state,
            batch_iterator=make_batch_iterator(arch, shape, args.seed),
            ckpt_dir=args.ckpt_dir,
            state_shardings=state_sh,
            ckpt_every=args.ckpt_every,
            failure=FailureInjector(args.fail_at),
            metrics_jsonl=args.metrics_jsonl,
            tokens_per_step=args.batch * args.seq,
        )
        log = trainer.run(max(0, args.steps - trainer.start_step))
        trainer.close()
    if log:
        first = log[0].get("loss")
        last = log[-1].get("loss")
        print(f"[train] loss {first:.4f} -> {last:.4f} over "
              f"{len(log)} steps")
    from repro import telemetry
    if telemetry.enabled():
        text = telemetry.render_prometheus()
        if args.metrics_prom:
            with open(args.metrics_prom, "w", encoding="utf-8") as fh:
                fh.write(text)
            print(f"[train] metrics dumped to {args.metrics_prom}")
        else:
            print("[train] final metrics (Prometheus text format):")
            print(text, end="")
    return log


if __name__ == "__main__":
    main()
