import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (architecture x input-shape x
mesh) cell against the production meshes, with no real allocation.

For each live cell this script:
  1. builds the (16,16) single-pod or (2,16,16) multi-pod mesh,
  2. lowers the exact train_step / prefill / decode functions from
     launch/steps.py against ShapeDtypeStruct inputs,
  3. compiles, records memory_analysis() and cost_analysis(),
  4. re-derives trip-count-correct FLOPs / HBM bytes / collective bytes
     from the compiled HLO (utils/roofline), and
  5. appends the cell record to --out (JSON), consumed by EXPERIMENTS.md.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch olmo-1b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all --mesh both
"""

import argparse
import json
import time
import traceback

import jax

from repro import configs
from repro.configs.base import ShapeSpec
from repro.launch import steps as S
from repro.launch.mesh import make_production_mesh
from repro import api
from repro.models.common import GemmPolicy
from repro.utils import roofline


def run_cell(arch_id: str, shape: ShapeSpec, multi_pod: bool,
             gemm: str = "native") -> dict:
    arch = configs.get_config(arch_id)
    mesh = make_production_mesh(multi_pod=multi_pod)
    n_chips = mesh.size
    policy = GemmPolicy(default=api.precision(gemm))
    rec = {"arch": arch_id, "shape": shape.name,
           "mesh": "2x16x16" if multi_pod else "16x16", "gemm": gemm,
           "kind": shape.kind}
    t0 = time.time()

    with mesh:
        if shape.kind == "train":
            step = S.make_train_step(arch, mesh, shape, policy, donate=False)
            state = {"params": S.abstract_params(arch), "opt": None}
            state["opt"] = S.abstract_opt(arch, state["params"])
            batch = arch.input_specs(shape)
            lowered = step.lower(state, batch)
        elif shape.kind == "prefill":
            step = S.make_prefill_step(arch, shape, mesh, policy)
            lowered = step.lower(S.abstract_params(arch),
                                 arch.input_specs(shape))
        else:  # decode
            step = S.make_decode_step(arch, shape, mesh, policy,
                                      donate=False)
            cache = S.abstract_cache(arch, shape.global_batch, shape.seq_len)
            batch = arch.input_specs(shape)
            lowered = step.lower(S.abstract_params(arch), cache,
                                 batch["tokens"], 0)
        rec["lower_s"] = round(time.time() - t0, 1)

        t1 = time.time()
        compiled = lowered.compile()
        rec["compile_s"] = round(time.time() - t1, 1)

    mem = compiled.memory_analysis()
    rec["memory"] = {
        "argument_bytes": getattr(mem, "argument_size_in_bytes", None),
        "output_bytes": getattr(mem, "output_size_in_bytes", None),
        "temp_bytes": getattr(mem, "temp_size_in_bytes", None),
        "generated_code_bytes": getattr(mem, "generated_code_size_in_bytes",
                                        None),
    }
    ca = compiled.cost_analysis() or {}
    if isinstance(ca, (list, tuple)):  # jax < 0.5 returns [dict] per device
        ca = ca[0] if ca else {}
    rec["cost_analysis"] = {"flops": ca.get("flops"),
                            "bytes_accessed": ca.get("bytes accessed")}

    hlo = analyze_compiled(compiled)
    rec["hlo"] = hlo
    terms = roofline.roofline_terms(hlo["flops"], hlo["mem_bytes"],
                                    hlo["coll_bytes"])
    rec["roofline"] = terms

    params = S.abstract_params(arch)
    n_params = sum(int(jax_size(p)) for p in jax.tree.leaves(params))
    n_routed = roofline.routed_param_count(params)
    mf = roofline.model_flops(arch, shape, n_params, n_routed)
    rec["model_flops_global"] = mf
    hlo_global = hlo["flops"] * n_chips
    rec["useful_flops_ratio"] = mf / hlo_global if hlo_global else None
    rec["params"] = n_params
    return rec


def jax_size(p):
    import math
    return math.prod(p.shape) if p.shape else 1


def analyze_compiled(compiled) -> dict:
    return roofline.analyze_hlo(compiled.as_text())


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--mesh", default="both",
                    choices=["single", "multi", "both"])
    ap.add_argument("--gemm", default="native",
                    help="native | ozaki1-pN | ozaki2-pN")
    ap.add_argument("--out", default="dryrun_results.json")
    ap.add_argument("--skip-existing", action="store_true")
    ap.add_argument("--metrics-jsonl", default=None,
                    help="write one telemetry record per compiled cell to "
                         "this JSONL file (implies telemetry; cells are "
                         "compile-only, so the record carries trace-time "
                         "counters: traces, modeled bytes, block-cache, "
                         "prepared builds)")
    args = ap.parse_args()

    from repro import telemetry
    sink = tracker = None
    if args.metrics_jsonl:
        telemetry.enable()
        sink = telemetry.jsonl_sink(args.metrics_jsonl)
        tracker = telemetry.StepTracker()

    arch_ids = configs.ARCH_IDS if args.arch == "all" else (args.arch,)
    meshes = {"single": (False,), "multi": (True,),
              "both": (False, True)}[args.mesh]

    try:
        with open(args.out) as f:
            results = json.load(f)
    except (FileNotFoundError, json.JSONDecodeError):
        results = []
    done = {(r["arch"], r["shape"], r["mesh"], r.get("gemm", "native"))
            for r in results}

    failures = 0
    cell_idx = 0
    for arch_id in arch_ids:
        arch = configs.get_config(arch_id)
        shapes = arch.shapes()
        if args.shape != "all":
            shapes = [s for s in shapes if s.name == args.shape]
        for shape in shapes:
            for multi in meshes:
                mesh_name = "2x16x16" if multi else "16x16"
                key = (arch_id, shape.name, mesh_name, args.gemm)
                if args.skip_existing and key in done:
                    print(f"skip {key}")
                    continue
                print(f"=== {arch_id} x {shape.name} x {mesh_name} "
                      f"(gemm={args.gemm}) ===", flush=True)
                try:
                    t_cell = time.time()
                    rec = run_cell(arch_id, shape, multi, args.gemm)
                    if tracker is not None:
                        tracker.step_metrics(
                            cell_idx, time.time() - t_cell, kind="cell",
                            extra={"arch": arch_id, "shape": shape.name,
                                   "mesh": mesh_name, "gemm": args.gemm})
                    cell_idx += 1
                    r = rec["roofline"]
                    print(f"  lower {rec['lower_s']}s compile "
                          f"{rec['compile_s']}s | compute {r['compute_s']:.4f}s "
                          f"memory {r['memory_s']:.4f}s coll "
                          f"{r['collective_s']:.4f}s -> {r['bottleneck']}",
                          flush=True)
                    results = [x for x in results
                               if (x["arch"], x["shape"], x["mesh"],
                                   x.get("gemm", "native")) != key]
                    results.append(rec)
                except Exception as e:
                    failures += 1
                    print(f"  FAILED: {type(e).__name__}: {e}")
                    traceback.print_exc()
                with open(args.out, "w") as f:
                    json.dump(results, f, indent=1)
    if sink is not None:
        sink.close()
    print(f"done; {failures} failures; results in {args.out}")
    raise SystemExit(1 if failures else 0)


if __name__ == "__main__":
    main()
