"""Serve CLI: continuous batching over the fused emulated GEMMs.

  PYTHONPATH=src python -m repro.launch.serve --arch olmo-1b --smoke \
      --requests 8 --prompt-len 48 --gen 16 --poisson 0.05

The engine prefills each request in chunks that share a single
jit-compiled step with the decode lanes (repro.serving, docs/serving.md):
a paged block-table KV cache replaces the contiguous per-batch slab, an
admission queue replays a (Poisson) arrival trace, and per-request guard
retry isolates strict accuracy trips to the offending request. The
legacy whole-batch engine stays importable as :class:`ServeEngine` and
runnable via ``--lockstep``.

All engine logic lives in :mod:`repro.serving`; this module only parses
flags, builds the trace, and prints the summary.
"""

from __future__ import annotations

import argparse
import time

import numpy as np

from repro import api, configs
from repro.launch.mesh import make_host_mesh
from repro.models.common import GemmPolicy
from repro.serving import ContinuousEngine, LockstepEngine, Request

# Back-compat alias: examples/tests construct the legacy batch engine
# under its original name.
ServeEngine = LockstepEngine


def build_trace(rng: np.random.Generator, vocab: int, requests: int,
                prompt_len: int, gen: int, poisson: float) -> list[Request]:
    """Uniform-random prompts; exponential(mean=``poisson``) interarrival
    gaps when ``poisson`` > 0, all-at-once otherwise."""
    arrivals = (np.cumsum(rng.exponential(poisson, requests))
                if poisson > 0 else np.zeros(requests))
    return [Request(prompt=rng.integers(0, vocab, prompt_len).tolist(),
                    max_new_tokens=gen, arrival=float(arrivals[i]))
            for i in range(requests)]


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="olmo-1b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=48)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--gemm", default=None,
                    help="precision spec (e.g. ozaki1-p4, ozaki2-m8, "
                         "bits=40); omitted, the ambient REPRO_EMULATION "
                         "env / repro.emulation scope decides")
    ap.add_argument("--prepare", action="store_true",
                    help="decompose Scheme-I projection weights once per "
                         "session (PreparedOperand serving; the continuous "
                         "engine also auto-prepares for +cached specs)")
    ap.add_argument("--lanes", type=int, default=4,
                    help="continuous-batching lanes (the fixed batch "
                         "dimension of the serve step)")
    ap.add_argument("--chunk", type=int, default=16,
                    help="prefill tokens per lane per mixed step")
    ap.add_argument("--page-size", type=int, default=16,
                    help="KV-cache page size in tokens")
    ap.add_argument("--num-pages", type=int, default=None,
                    help="total KV pages incl. scratch (default: worst "
                         "case, every lane at max_seq)")
    ap.add_argument("--poisson", type=float, default=0.0,
                    help="mean request interarrival gap in seconds "
                         "(0 = all requests arrive at t=0)")
    ap.add_argument("--queue-policy", default="fcfs",
                    choices=("fcfs", "spf"))
    ap.add_argument("--token-budget", type=int, default=None,
                    help="cap on the summed total tokens of concurrently "
                         "running requests")
    ap.add_argument("--lockstep", action="store_true",
                    help="run the legacy whole-batch engine instead")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--metrics-port", type=int, default=None,
                    help="serve Prometheus text-format metrics on this "
                         "port (GET /metrics; implies telemetry; 0 picks "
                         "a free port)")
    ap.add_argument("--metrics-jsonl", default=None,
                    help="write one telemetry record per serve step to "
                         "this JSONL file (implies telemetry)")
    args = ap.parse_args(argv)

    from repro import telemetry
    metrics_server = None
    sink = None
    if args.metrics_port is not None:
        telemetry.enable()
        metrics_server = telemetry.serve_metrics(args.metrics_port)
        print(f"[serve] metrics on http://127.0.0.1:"
              f"{metrics_server.port}/metrics")
    if args.metrics_jsonl:
        telemetry.enable()
        sink = telemetry.jsonl_sink(args.metrics_jsonl)

    arch = (configs.get_smoke_config(args.arch) if args.smoke
            else configs.get_config(args.arch))
    if not arch.model.causal:
        raise SystemExit(f"{args.arch} is encoder-only: no decode serving")
    mesh = make_host_mesh()
    rng = np.random.default_rng(args.seed)
    # --gemm overrides; otherwise None defers to the arch config's
    # gemm_sites policy inside the engines (then the ambient resolver).
    policy = (GemmPolicy(default=api.precision(args.gemm))
              if args.gemm else None)
    max_seq = args.prompt_len + args.gen

    with mesh:
        if args.lockstep:
            prompts = rng.integers(0, arch.model.vocab,
                                   (args.requests, args.prompt_len)
                                   ).astype(np.int32)
            eng = LockstepEngine(arch, mesh, max_seq, policy,
                                 prepare=args.prepare)
            t0 = time.time()
            toks = eng.generate(prompts, args.gen)
            dt = time.time() - t0
            if eng.last_guard.get("calls"):
                print("[serve] guard:", eng.last_guard)
        else:
            trace = build_trace(rng, arch.model.vocab, args.requests,
                                args.prompt_len, args.gen, args.poisson)
            eng = ContinuousEngine(
                arch, mesh, max_seq=max_seq, policy=policy,
                prepare=True if args.prepare else None,
                max_lanes=args.lanes, chunk=args.chunk,
                page_size=args.page_size, num_pages=args.num_pages,
                queue_policy=args.queue_policy,
                token_budget=args.token_budget)
            t0 = time.time()
            results = eng.run(trace)
            dt = time.time() - t0
            toks = np.asarray([results[r.rid].tokens for r in trace],
                              dtype=np.int32)
            util = eng.utilization()
            ttfts = [results[r.rid].ttft for r in trace
                     if results[r.rid].ttft is not None]
            print(f"[serve] {util['steps']} steps, "
                  f"{util['evictions']} evictions, page high-water "
                  f"{util['kv']['high_water']}/{util['kv']['num_pages']}, "
                  f"ttft p50 {np.median(ttfts):.3f}s"
                  if ttfts else "[serve] no tokens emitted")
            trips = sum(results[r.rid].guard_trips for r in trace)
            if trips:
                print(f"[serve] guard trips (per-request): {trips}")
    print(f"[serve] {args.requests} requests x {args.gen} tokens in "
          f"{dt:.2f}s ({args.requests * args.gen / dt:.1f} tok/s)")
    print("[serve] sample:", np.asarray(toks[0][:12]).tolist())
    if sink is not None:
        sink.close()
    if metrics_server is not None:
        metrics_server.close()
    return toks


if __name__ == "__main__":
    main()
