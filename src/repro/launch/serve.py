"""Batched serving engine: continuous prefill + decode over a request queue.

  PYTHONPATH=src python -m repro.launch.serve --arch olmo-1b --smoke \
      --requests 8 --prompt-len 48 --gen 16

The engine prefises each batch of prompts once, then decodes tokens for
the whole batch step-by-step against the shared sharded KV cache — the
serving analogue of the dry-run's decode cells.
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import api, configs, guard
from repro.core.precision import EmulationAccuracyError
from repro.kernels import dispatch
from repro.launch.mesh import make_host_mesh
from repro.models import model as M
from repro.models.common import GemmPolicy


class ServeEngine:
    def __init__(self, arch, mesh, max_seq: int, policy=None,
                 params=None, seed: int = 0, prepare: bool = False,
                 guard_retries: int = 1, guard_backoff: float = 0.25):
        self.arch = arch
        self.mcfg = arch.model
        self.mesh = mesh
        self.max_seq = max_seq
        # The one resolver decides the engine's emulation: an explicit
        # policy wins, else the ambient repro.emulation scope /
        # REPRO_EMULATION env configures the whole serving session;
        # resolve_policy then clamps impls to what this mesh executes.
        self.policy = dispatch.resolve_policy(policy or GemmPolicy(), mesh)
        self.params = params if params is not None else M.init_params(
            jax.random.PRNGKey(seed), self.mcfg)
        if prepare:
            # Once-per-session weight decomposition: every prefill/decode
            # step streams the finished int8 slices instead of
            # re-splitting the projection weights (Scheme-I sites only).
            from repro.kernels import prepared
            self.params = prepared.prepare_params(self.params, self.policy)
        self._decode = jax.jit(
            lambda p, tok, pos, cache: M.forward_decode(
                p, self.mcfg, tok, pos, cache, self.policy))
        self._prefill = jax.jit(
            lambda p, inputs: M.forward_prefill(
                p, self.mcfg, inputs, self.max_seq, self.policy))
        # Guard consumption (docs/robustness.md): ``last_guard`` holds the
        # per-batch delta of the process-wide guard counters; a strict
        # accuracy trip retries the whole batch with backoff before
        # surfacing (the request-level analogue of the trainer's
        # step retry).
        self.guard_retries = guard_retries
        self.guard_backoff = guard_backoff
        self.last_guard: dict[str, int] = {}
        from repro import telemetry
        self._telemetry = telemetry
        self._tracker = telemetry.StepTracker() if telemetry.enabled() \
            else None
        self._batches = 0

    def _generate_once(self, prompts: np.ndarray, n_tokens: int):
        b, s = prompts.shape
        logits, cache = self._prefill(self.params,
                                      {"tokens": jnp.asarray(prompts)})
        out = []
        tok = jnp.argmax(logits[:, -1:, :self.mcfg.vocab], axis=-1)
        out.append(tok)
        for i in range(1, n_tokens):
            logits, cache = self._decode(self.params, tok, s + i - 1, cache)
            tok = jnp.argmax(logits[:, -1:, :self.mcfg.vocab], axis=-1)
            out.append(tok)
        return np.asarray(jnp.concatenate(out, axis=1))

    def generate(self, prompts: np.ndarray, n_tokens: int,
                 greedy: bool = True):
        """prompts: (B, S) int32. Returns (B, n_tokens) generated ids."""
        before = guard.stats()
        t0 = time.time()
        attempt = 0
        while True:
            try:
                toks = self._generate_once(prompts, n_tokens)
                break
            except EmulationAccuracyError as e:
                if attempt >= self.guard_retries:
                    raise
                attempt += 1
                pause = self.guard_backoff * attempt
                print(f"[serve] guard trip (retry {attempt}/"
                      f"{self.guard_retries} after {pause:.2f}s): {e}")
                time.sleep(pause)
        dt = time.time() - t0
        after = guard.stats()
        self.last_guard = {
            f: getattr(after, f) - getattr(before, f)
            for f in ("calls", "trips", "escalations", "recoveries",
                      "native_fallbacks", "masked")}
        self.last_guard["retries"] = attempt
        # One telemetry record per served batch (docs/observability.md):
        # kind="serve", tokens = generated ids this batch, so
        # tokens_per_s is the decode throughput the operator dashboards.
        if self._tracker is None and self._telemetry.enabled():
            self._tracker = self._telemetry.StepTracker()
        if self._tracker is not None:
            self._tracker.step_metrics(
                self._batches, dt, kind="serve",
                tokens=int(prompts.shape[0]) * int(n_tokens),
                extra={"requests": int(prompts.shape[0]),
                       "guard_retries": attempt})
        self._batches += 1
        return toks


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="olmo-1b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=48)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--gemm", default=None,
                    help="precision spec (e.g. ozaki1-p4, ozaki2-m8, "
                         "bits=40); omitted, the ambient REPRO_EMULATION "
                         "env / repro.emulation scope decides")
    ap.add_argument("--prepare", action="store_true",
                    help="decompose Scheme-I projection weights once per "
                         "session (PreparedOperand serving)")
    ap.add_argument("--metrics-port", type=int, default=None,
                    help="serve Prometheus text-format metrics on this "
                         "port (GET /metrics; implies telemetry; 0 picks "
                         "a free port)")
    ap.add_argument("--metrics-jsonl", default=None,
                    help="write one telemetry record per served batch to "
                         "this JSONL file (implies telemetry)")
    args = ap.parse_args(argv)

    from repro import telemetry
    metrics_server = None
    sink = None
    if args.metrics_port is not None:
        telemetry.enable()
        metrics_server = telemetry.serve_metrics(args.metrics_port)
        print(f"[serve] metrics on http://127.0.0.1:"
              f"{metrics_server.port}/metrics")
    if args.metrics_jsonl:
        telemetry.enable()
        sink = telemetry.jsonl_sink(args.metrics_jsonl)

    arch = (configs.get_smoke_config(args.arch) if args.smoke
            else configs.get_config(args.arch))
    if not arch.model.causal:
        raise SystemExit(f"{args.arch} is encoder-only: no decode serving")
    mesh = make_host_mesh()
    rng = np.random.default_rng(0)
    prompts = rng.integers(0, arch.model.vocab,
                           (args.requests, args.prompt_len)).astype(np.int32)
    with mesh:
        gemm = api.precision(args.gemm) if args.gemm else None
        eng = ServeEngine(arch, mesh, args.prompt_len + args.gen,
                          GemmPolicy(default=gemm),
                          prepare=args.prepare)
        t0 = time.time()
        toks = eng.generate(prompts, args.gen)
        dt = time.time() - t0
    print(f"[serve] {args.requests} requests x {args.gen} tokens in "
          f"{dt:.2f}s ({args.requests * args.gen / dt:.1f} tok/s)")
    if eng.last_guard.get("calls"):
        print("[serve] guard:", eng.last_guard)
    print("[serve] sample:", toks[0][:12].tolist())
    if sink is not None:
        sink.close()
    if metrics_server is not None:
        metrics_server.close()
    return toks


if __name__ == "__main__":
    main()
