"""Production mesh construction.

A function (never a module-level constant) so importing this module never
touches jax device state. Single-pod: (data=16, model=16) = 256 chips
(TPU v5e pod). Multi-pod: (pod=2, data=16, model=16) = 512 chips; the
'pod' axis joins 'data' for batch/FSDP sharding and carries the slower
inter-pod (DCN) collectives.
"""

from __future__ import annotations

import inspect

import jax


def make_abstract_mesh(shape: tuple[int, ...], axes: tuple[str, ...]):
    """Device-free AbstractMesh across the drifting constructor signatures.

    jax 0.4.x takes a single ``shape_tuple`` of (name, size) pairs; newer
    releases take ``(axis_sizes, axis_names)``. Feature-probed like
    repro.kernels.compat, not version-string keyed.
    """
    params = inspect.signature(jax.sharding.AbstractMesh).parameters
    if "shape_tuple" in params:
        return jax.sharding.AbstractMesh(tuple(zip(axes, shape)))
    return jax.sharding.AbstractMesh(shape, axes)


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(model_parallel: int = 1):
    """Whatever fits the local devices — used by examples/tests."""
    n = len(jax.devices())
    return jax.make_mesh((n // model_parallel, model_parallel),
                         ("data", "model"))
