"""The guard escalation ladder: sanitize -> run -> verify -> escalate.

``guarded_call`` wraps one unguarded 2-D GEMM runner with the full
guard pipeline:

  0. probe operands (NaN/Inf lanes, exponent spread) and sanitize the
     non-finite entries so the integer pipelines see finite data;
  1. run the requested config and verify the result a posteriori
     (repro.guard.verify);
  2. on a tripped check, climb the ladder: re-plan with more precision
     bits (plan_precision, same scheme preferred), then pin the XLA
     reference expansion, re-verifying each rung;
  3. an exhausted ladder falls back to the native dot ('on' mode, with
     a one-shot RuntimeWarning through the dispatcher's fallback
     machinery) or raises EmulationAccuracyError ('strict');
  4. finally restore native special-value semantics by NaN-masking the
     output lanes a non-finite operand entry contaminated.

The retry rungs are *eager-only*: under tracing (jit / grad / vmap)
there is no Python control flow over data, so the guard degrades to
sanitize + verify + mask, recording verifications and trips through
``jax.debug.callback`` into ``guard.stats()`` — the runtime layers
(runtime/trainer.py, launch/serve.py) poll those counters between
steps and own the retry there.  Strict mode therefore raises eagerly
but only *counts* under a jit trace.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.precision import (EmulationAccuracyError, EmulationConfig,
                                  plan_precision)

from repro.guard import policy as policy_mod
from repro.guard import sentinel
from repro.guard import verify as verify_mod

GuardPolicy = policy_mod.GuardPolicy


def strip_guard(cfg: EmulationConfig) -> EmulationConfig:
    """The same config with the guard disarmed — what the ladder hands
    to the unguarded runners (prevents recursive guarding)."""
    if cfg.guard is None:
        return cfg
    return dataclasses.replace(cfg, guard=None)


def _is_traced(*xs) -> bool:
    return any(isinstance(x, jax.core.Tracer) for x in xs)


def _record_traced(ok, masked_any):
    """debug.callback target: counts per *execution*, not per trace.

    Under vmap the verdicts arrive batched — count each lane.
    """
    ok = np.asarray(ok)
    policy_mod.record("calls", max(1, ok.size))
    policy_mod.record("verified", max(1, ok.size))
    trips = int(ok.size - np.count_nonzero(ok))
    if trips:
        policy_mod.record("trips", trips)
    masked = int(np.count_nonzero(np.asarray(masked_any)))
    if masked:
        policy_mod.record("masked", masked)


def escalated_config(base: EmulationConfig, k_dim: int,
                     extra_bits: int) -> EmulationConfig | None:
    """First ladder rung: re-plan for ``extra_bits`` more precision bits
    at this contraction length, keeping the scheme when it can deliver.
    None when even the cross-scheme planner cannot reach the target."""
    target = base.bits(k_dim) + extra_bits
    prefer = base.scheme if base.scheme in ("ozaki1", "ozaki2") else None
    try:
        planned = plan_precision(target, k_dim, prefer=prefer)
    except ValueError:
        try:
            planned = plan_precision(target, k_dim)
        except ValueError:
            return None
    return dataclasses.replace(
        planned, impl=base.impl, backend=base.backend,
        out_dtype=base.out_dtype, fused=base.fused, decomp=base.decomp)


def _warn_guard(reason: tuple, shapes: tuple, message: str) -> None:
    from repro.kernels import dispatch
    dispatch._warn_fallback_once(("guard",) + reason, shapes, message,
                                 stacklevel=4)


def guarded_call(a: jax.Array, b, cfg: EmulationConfig, run,
                 probe: "sentinel.SentinelProbe | None" = None) -> jax.Array:
    """Run one (M, K) @ (K, N) emulated GEMM under the guard pipeline.

    ``run(a, b, cfg)`` is the unguarded runner (it receives sanitized
    operands and guard-stripped configs, including the escalation
    rungs' re-planned configs).  ``b`` may be a prepared operand — the
    re-plan rung is then skipped (its slice/modulus count is pinned at
    prepare time) and the ladder goes straight to the XLA expansion.
    ``probe`` is an already-computed sentinel probe (e.g. off a
    ``dispatch.plan_emulated(..., probe=True)`` plan); None computes it
    here.
    """
    guard_policy = GuardPolicy.from_config(cfg)
    assert guard_policy is not None, "guarded_call needs cfg.guard set"
    base = strip_guard(cfg)
    prepared = hasattr(b, "reconstruct")
    b_dense = b.reconstruct() if prepared else b
    if probe is None:
        probe = sentinel.probe_operands(a, b_dense)
    a_s = sentinel.sanitize(a)
    b_s = b if prepared else sentinel.sanitize(b_dense)
    k_dim = a.shape[-1]

    def check(c, rung_cfg):
        return verify_mod.verify_gemm(
            a_s, b_s if not prepared else b_dense, c, rung_cfg,
            probes=guard_policy.probes, tol_factor=guard_policy.tol_factor,
            row_mask=probe.row_mask, col_mask=probe.col_mask)

    c0 = run(a_s, b_s, base)

    if _is_traced(a, b_dense, c0):
        ver = check(c0, base)
        jax.debug.callback(_record_traced, ver.ok, probe.any_nonfinite())
        return sentinel.apply_special_values(c0, probe)

    # -- eager: the full ladder ------------------------------------------
    policy_mod.record("calls")
    if bool(probe.any_nonfinite()):
        policy_mod.record("masked")
    bits = base.bits(k_dim)
    spread = float(jnp.maximum(probe.spread_a, probe.spread_b))
    if spread > bits:
        _warn_guard(
            ("spread", base.scheme, base.p), (a.shape, b_dense.shape),
            f"guard: operand exponent spread ~{spread:.0f} bits exceeds "
            f"the {bits}-bit budget of {base.scheme}-p{base.p}; small "
            "entries fall below the power-of-two row scale (expect a "
            "verification trip or request more bits via a 'bits=' spec)")
    ver = check(c0, base)
    policy_mod.record("verified")
    if bool(ver.ok):
        return sentinel.apply_special_values(c0, probe)

    policy_mod.record("trips")
    rungs: list[EmulationConfig] = []
    if not prepared:
        esc = escalated_config(base, k_dim, guard_policy.escalate_bits)
        if esc is not None:
            rungs.append(esc)
        rungs.append(dataclasses.replace(esc or base, impl="xla"))
    else:
        # Slice/modulus counts are pinned in the prepared stack; the
        # only re-runnable rung is the reference expansion.
        rungs.append(dataclasses.replace(base, impl="xla"))
    for rung_cfg in rungs:
        policy_mod.record("escalations")
        c = run(a_s, b_s, rung_cfg)
        ver = check(c, rung_cfg)
        policy_mod.record("verified")
        if bool(ver.ok):
            policy_mod.record("recoveries")
            return sentinel.apply_special_values(c, probe)

    if guard_policy.strict:
        tried = [f"{r.scheme}-p{r.p}+{r.impl}" for r in rungs]
        raise EmulationAccuracyError(
            f"guarded emulated GEMM {a.shape} @ {b_dense.shape} missed its "
            f"error bound (residual {float(ver.err):.3g} > tol "
            f"{ver.tol:.3g}) and the escalation ladder is exhausted "
            f"(tried {tried}); strict mode refuses the native fallback — "
            "inspect the operands (guard.stats(), repro.guard.sentinel) "
            "or raise the precision budget")
    policy_mod.record("native_fallbacks")
    _warn_guard(
        ("native_fallback", base.scheme, base.p), (a.shape, b_dense.shape),
        f"guard: emulated GEMM missed its error bound (residual "
        f"{float(ver.err):.3g} > tol {ver.tol:.3g}) after "
        f"{len(rungs)} escalation(s); falling back to the native dot "
        "for this call ('+guard:strict' raises instead)")
    c_native = (a_s.astype(jnp.float32)
                @ jnp.asarray(b_dense).astype(jnp.float32)).astype(c0.dtype)
    return sentinel.apply_special_values(c_native, probe)


def guarded_matmul(a: jax.Array, b, cfg: EmulationConfig, *,
                   out_dtype=None, backend: str | None = None,
                   mesh_shape: tuple | None = None) -> jax.Array:
    """The dispatch-level guard seam: ``dispatch.emulated_matmul`` routes
    here when ``cfg.guard`` is set, and every rung routes back through
    ``emulated_matmul`` with the guard stripped."""
    from repro.kernels import dispatch

    probe = None
    if not hasattr(b, "reconstruct"):
        probe = dispatch.plan_emulated(a, b, strip_guard(cfg), out_dtype,
                                       backend, mesh_shape=mesh_shape,
                                       probe=True).probe

    def run(aa, bb, rung_cfg):
        return dispatch.emulated_matmul(aa, bb, cfg=rung_cfg,
                                        out_dtype=out_dtype, backend=backend,
                                        mesh_shape=mesh_shape)

    return guarded_call(a, b, cfg, run, probe=probe)


def guarded_dot_2d(a: jax.Array, b: jax.Array,
                   cfg: EmulationConfig) -> jax.Array:
    """The core-level guard seam: ``repro.core.emulated._dot_2d`` (the
    2-D engine under dot_general/einsum/dense and both VJP backward
    GEMMs) routes here when ``cfg.guard`` is set."""
    from repro.core import emulated

    def run(aa, bb, rung_cfg):
        return emulated._dot_2d(aa, bb, rung_cfg)

    return guarded_call(a, b, cfg, run)
