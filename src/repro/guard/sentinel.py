"""Input sentinels: special-value probes and NaN/Inf masking.

Ozaki decompositions are integer pipelines — a NaN or Inf operand entry
does not propagate, it truncates into garbage int8 slices and the GEMM
returns a *finite wrong number*.  Native ``jnp.matmul`` propagates: any
non-finite entry in row i of A (or column j of B) makes the whole
output row i (column j) NaN — Inf included, because the emulated
product cannot distinguish +Inf·0 from +Inf·x, so (like LAPACK) we map
every non-finite contamination to NaN.

The guard restores that contract *around* the fused kernels: operands
are sanitized (non-finite entries zeroed) before dispatch so the
integer pipeline sees finite data, and the affected output rows/columns
are masked to NaN afterwards with one ``jnp.where``.  The kernels stay
untouched, and when the mask is empty the sanitize/mask pair is the
identity (``where`` with an all-false mask returns the original bits).

``probe_operands`` additionally estimates the per-row exponent spread
(log2(max|row|) - log2(min nonzero |row|)): rows wider than the
decomposition captures (beta * p bits for Scheme I, the integer budget
for Scheme II) lose their small entries to the power-of-two row scale,
which is what the a posteriori verifier (repro.guard.verify) exists to
catch — the probe is the cheap leading indicator.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class SentinelProbe:
    """Result of the pre-dispatch operand probe (all lazily-computed
    jax arrays so the probe adds no synchronization point).

    row_mask: (M,) bool — rows of A containing a non-finite entry.
    col_mask: (N,) bool — columns of B containing a non-finite entry.
    spread_a / spread_b: () float32 — max per-row (per-col) exponent
      spread estimate in bits, 0 for empty/zero operands.
    """
    row_mask: jax.Array
    col_mask: jax.Array
    spread_a: jax.Array
    spread_b: jax.Array

    def any_nonfinite(self) -> jax.Array:
        return jnp.any(self.row_mask) | jnp.any(self.col_mask)


def exponent_spread(x: jax.Array, axis: int) -> jax.Array:
    """Max over rows of log2(max|row|) - log2(min nonzero |row|), in bits.

    Non-finite entries are ignored (they are sanitized away before the
    decomposition ever sees them).  Rows with <= 1 distinct magnitude
    contribute 0.
    """
    ax = jnp.abs(x)
    finite = jnp.isfinite(ax) & (ax > 0)
    hi = jnp.max(jnp.where(finite, ax, 0.0), axis=axis)
    lo = jnp.min(jnp.where(finite, ax, jnp.inf), axis=axis)
    ok = (hi > 0) & jnp.isfinite(lo)
    # frexp exponents are exact on subnormals, unlike log2.
    _, e_hi = jnp.frexp(jnp.where(ok, hi, 1.0))
    _, e_lo = jnp.frexp(jnp.where(ok, lo, 1.0))
    spread = jnp.where(ok, (e_hi - e_lo).astype(jnp.float32), 0.0)
    return jnp.max(spread) if spread.size else jnp.float32(0.0)


def probe_operands(a: jax.Array, b: jax.Array) -> SentinelProbe:
    """Cheap pre-dispatch probe: O(MK + KN) elementwise + reductions."""
    fin_a = jnp.isfinite(a)
    fin_b = jnp.isfinite(b)
    return SentinelProbe(
        row_mask=~jnp.all(fin_a, axis=-1),
        col_mask=~jnp.all(fin_b, axis=0),
        spread_a=exponent_spread(a, axis=-1),
        spread_b=exponent_spread(b, axis=0),
    )


def sanitize(x: jax.Array) -> jax.Array:
    """Zero the non-finite entries so the integer pipeline sees finite
    data.  Identity (bit-for-bit) on fully finite input."""
    return jnp.where(jnp.isfinite(x), x, jnp.zeros_like(x))


def zero_masked_rows(x: jax.Array, mask: jax.Array, axis: int) -> jax.Array:
    """Zero whole rows (axis=0) / columns (axis=1) flagged by ``mask`` —
    used by the verifier so masked lanes contribute nothing to either
    side of the residual."""
    shape = [1, 1]
    shape[axis] = x.shape[axis]
    return jnp.where(jnp.reshape(mask, shape), jnp.zeros_like(x), x)


def apply_special_values(c: jax.Array, probe: SentinelProbe) -> jax.Array:
    """Post-hoc mask: NaN the output rows/columns native matmul would
    have NaN'd.  One fused ``where`` — bit-identity when no entry is
    masked."""
    mask = probe.row_mask[:, None] | probe.col_mask[None, :]
    return jnp.where(mask, jnp.asarray(jnp.nan, dtype=c.dtype), c)
