"""Fault-injection smoke check: ``python -m repro.guard.smoke``.

The CI job that proves the guard closes its loop end to end, outside
pytest: corrupt a decomposition with ``guard.inject``, assert the
a posteriori verifier trips, the escalation ladder recovers within one
retry (the injected fault is one-shot, so the first rung re-decomposes
clean), the recovered result is bit-identical to the uncorrupted
reference, and ``guard.stats()`` reports the whole story.

Integer-valued operands make every Ozaki configuration exact, so
"recovered" is checkable as bit-identity rather than allclose.
"""

from __future__ import annotations

import sys

import numpy as np

import jax.numpy as jnp


def run(m: int = 64, n: int = 48, k: int = 96, seed: int = 0) -> int:
    from repro import guard
    from repro.kernels import dispatch

    rng = np.random.default_rng(seed)
    # Small integers: exactly representable, exactly emulated at any p —
    # the recovered result must match the uncorrupted one bit for bit.
    a = jnp.asarray(rng.integers(-8, 9, (m, k)), jnp.float32)
    b = jnp.asarray(rng.integers(-8, 9, (k, n)), jnp.float32)

    failures: list[str] = []

    def expect(cond: bool, what: str) -> None:
        print(("ok  " if cond else "FAIL") + " " + what)
        if not cond:
            failures.append(what)

    # @xla pins the reference backend: its decomposition runs in plain
    # jnp ops, which is where the injection hooks live (the fused
    # kernels carve slices inside the kernel body).  Scheme II flips a
    # bit in plane 1: plane 0's modulus is 256, and integer operands
    # scaled by a power of two have an identically-zero residue plane
    # there, so corrupting it is a mathematical no-op.
    for scheme, spec, plane in (("ozaki1", "ozaki1-p4@xla+guard", 0),
                                ("ozaki2", "ozaki2-m6@xla+guard", 1)):
        guard.stats_clear()
        reference = dispatch.emulated_matmul(
            a, b, cfg=spec.replace("+guard", ""))
        clean = dispatch.emulated_matmul(a, b, cfg=spec)
        s = guard.stats()
        expect(bool(jnp.array_equal(clean, reference)),
               f"{scheme}: clean guarded result bit-identical")
        expect(s.verified == 1 and s.trips == 0,
               f"{scheme}: clean run verified without a trip ({s})")

        guard.stats_clear()
        with guard.inject("bitflip_slice", count=1, plane=plane) as fault:
            recovered = dispatch.emulated_matmul(a, b, cfg=spec)
        s = guard.stats()
        expect(fault.fired == 1, f"{scheme}: fault fired exactly once")
        expect(s.trips == 1, f"{scheme}: injected corruption tripped the "
                             f"verifier ({s})")
        expect(s.recoveries == 1 and s.escalations == 1,
               f"{scheme}: recovered within one retry ({s})")
        expect(s.native_fallbacks == 0,
               f"{scheme}: no native fallback needed ({s})")
        expect(bool(jnp.array_equal(recovered, reference)),
               f"{scheme}: recovered result bit-identical to the "
               "uncorrupted reference")

    if failures:
        print(f"\nsmoke FAILED: {len(failures)} check(s)")
        return 1
    print("\nsmoke OK: injected corruption detected and recovered "
          "within one retry on both schemes")
    return 0


if __name__ == "__main__":
    sys.exit(run())
