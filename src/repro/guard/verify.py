"""A posteriori verification of emulated GEMM results.

``verify_gemm`` is the stochastic residual check from the
guaranteed-accuracy Ozaki literature (Schwarz et al., PAPERS.md):
instead of recomputing C = A B at higher precision (a full second GEMM),
compare

    C @ x   vs   A @ (B @ x)

for a handful of +-1 (Rademacher) probe vectors x.  Both sides are
matrix-vector products — O(r (MN + MK + KN)) flops for r probes versus
O(p^2 MNK) for the emulated GEMM itself — and any corruption of C that
is not orthogonal to all r probes (probability ~2^-r for adversarial
single-entry corruption, far smaller for realistic faults) shows up as
a residual far above the decomposition's analytic error bound.

The tolerance is *derived, not tuned*: the decomposition residual bound
(2^(1-bits) relative, bits from ``EmulationConfig.bits`` — the same
quantity ``plan_precision`` budgets) plus the float32 rounding of the
verification matvecs themselves, normalized per output row by a bound
that majorizes both the row-scaled Scheme-I residual structure
(mu_i-weighted) and the magnitude of C's row (so the check is
scheme-agnostic and never divides by something smaller than the
quantities it compares).
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.core.precision import EmulationConfig

from repro.guard import sentinel


@dataclasses.dataclass(frozen=True)
class VerifyResult:
    """Outcome of one stochastic residual check (jax arrays, so the
    result is usable both eagerly and under tracing)."""
    ok: jax.Array        # () bool — max normalized residual <= tol
    err: jax.Array       # () float32 — max_i |C x - A (B x)|_i / den_i
    tol: float           # the analytic threshold the residual is held to

    def __bool__(self) -> bool:  # eager convenience: `if verify_gemm(...):`
        return bool(self.ok)


def tolerance(bits: int, m: int, n: int, k: int,
              tol_factor: float = 16.0) -> float:
    """Analytic trip threshold for a ``bits``-bit emulated (M,K)@(K,N).

    2^(1-bits): the decomposition's relative residual (one doubling of
    the elementwise bound to cover both operands).  (k + n) * eps:
    accumulated float32 rounding of the two verification matvec chains.
    ``tol_factor`` is the safety margin on top — the bound is worst-case
    over sign patterns, real residuals sit orders of magnitude below it
    and a single int8 bit flip sits orders of magnitude above.
    """
    eps = float(jnp.finfo(jnp.float32).eps)
    return float(tol_factor) * (2.0 ** (1 - bits) + (k + n) * eps)


def _row_normalizer(a: jax.Array, b: jax.Array) -> jax.Array:
    """Per-row denominator majorizing the row-scaled error structure.

    The Scheme-I residual in C[i, :] summed over columns is bounded by
    2^-bits * (mu_i * sum|B| + rowsum|A|_i * sum_j nu_j) with the
    power-of-two row scales mu_i <= 2 max_k |a_ik|, nu_j <= 2 max_k
    |b_kj|; the same shape bounds Scheme II's integerization error.  It
    also dominates sum_j |C[i, j]|, which bounds the verification
    matvecs' own rounding.
    """
    abs_a = jnp.abs(a)
    abs_b = jnp.abs(b)
    row_max_a = jnp.max(abs_a, axis=1)            # (M,)
    row_sum_a = jnp.sum(abs_a, axis=1)            # (M,)
    sum_b = jnp.sum(abs_b)                        # ()
    sum_col_max_b = jnp.sum(jnp.max(abs_b, axis=0))  # ()
    tiny = jnp.float32(jnp.finfo(jnp.float32).tiny)
    return row_max_a * sum_b + row_sum_a * sum_col_max_b + tiny


def verify_gemm(a: jax.Array, b, c: jax.Array,
                cfg: "EmulationConfig | str | None" = None, *,
                bits: int | None = None, probes: int = 2,
                tol_factor: float = 16.0, seed: int = 0,
                row_mask: jax.Array | None = None,
                col_mask: jax.Array | None = None) -> VerifyResult:
    """Stochastic residual check of an emulated 2-D GEMM result.

    Args:
      a, b: the operands of the emulated product (b may be a prepared
        operand — ``PreparedOperand`` / ``PreparedResidues`` — whose
        dense form is recovered via ``.reconstruct()``).
      c: the emulated result to verify.
      cfg: the EmulationConfig (or spec string) that produced ``c`` —
        sets the error-bound bits via ``cfg.bits(K)``.
      bits: explicit precision bits; overrides ``cfg``.
      probes: number of Rademacher probe vectors.
      row_mask / col_mask: NaN/Inf sentinel masks (see repro.guard
        .sentinel) — masked lanes of a/b/c are zeroed on both sides of
        the residual so special-value handling never trips the check.
    """
    if hasattr(b, "reconstruct"):
        b = b.reconstruct()
    a = jnp.asarray(a, dtype=jnp.float32)
    b = jnp.asarray(b, dtype=jnp.float32)
    c = jnp.asarray(c, dtype=jnp.float32)
    m, k = a.shape
    n = b.shape[1]
    if bits is None:
        if cfg is not None:
            bits = EmulationConfig.parse(cfg).bits(k)
        else:
            bits = 24  # fp32-mantissa default when nothing else is known
    if row_mask is not None:
        a = sentinel.zero_masked_rows(a, row_mask, axis=0)
        c = sentinel.zero_masked_rows(c, row_mask, axis=0)
    if col_mask is not None:
        b = sentinel.zero_masked_rows(b, col_mask, axis=1)
        c = sentinel.zero_masked_rows(c, col_mask, axis=1)
    x = jax.random.rademacher(
        jax.random.key(seed), (n, probes), dtype=jnp.float32)
    lhs = c @ x                    # (M, r)
    rhs = a @ (b @ x)              # (M, r) — never forms A @ B
    resid = jnp.max(jnp.abs(lhs - rhs), axis=1)      # (M,)
    den = _row_normalizer(a, b)
    err = jnp.max(resid / den) if m else jnp.float32(0.0)
    tol = tolerance(bits, m, n, k, tol_factor)
    return VerifyResult(ok=err <= tol, err=err, tol=tol)
