"""Guard policy and the queryable trip statistics.

``GuardPolicy`` is the resolved form of the ``+guard`` / ``+guard:strict``
spec suffixes (parsed into ``EmulationConfig.guard`` by core.precision):
it owns the verification knobs and the escalation-ladder shape.  The
module-level stats counter is what ``runtime/trainer.py`` and
``launch/serve.py`` poll between steps to turn guard trips into
retry-with-backoff events, and what tests assert on.
"""

from __future__ import annotations

import dataclasses
import threading

from repro.core.precision import EmulationConfig


@dataclasses.dataclass(frozen=True)
class GuardPolicy:
    """Resolved guard behaviour for one emulated GEMM call-site.

    mode: 'on' — exhausted ladder falls back to the native dot (with a
      one-shot warning); 'strict' — exhausted ladder raises
      EmulationAccuracyError.
    probes: number of stochastic probe vectors for verify_gemm.
    tol_factor: safety factor on the analytic tolerance (the bound is a
      worst-case; 16x keeps the false-trip rate at zero on conditioned
      inputs while a single injected int8 bit flip overshoots it by
      orders of magnitude).
    escalate_bits: extra precision bits requested from plan_precision on
      the first ladder rung.
    """
    mode: str = "on"
    probes: int = 2
    tol_factor: float = 16.0
    escalate_bits: int = 8

    @classmethod
    def from_config(cls, cfg: EmulationConfig) -> "GuardPolicy | None":
        if cfg.guard is None:
            return None
        return cls(mode=cfg.guard)

    @property
    def strict(self) -> bool:
        return self.mode == "strict"


@dataclasses.dataclass(frozen=True)
class GuardStats:
    """Snapshot of the guard counters since the last ``stats_clear()``."""
    calls: int = 0            # guarded GEMMs executed
    verified: int = 0         # verifications that ran
    trips: int = 0            # verifications that missed the tolerance
    escalations: int = 0      # ladder rungs executed after a trip
    recoveries: int = 0       # trips whose retry verified clean
    native_fallbacks: int = 0 # ladders exhausted into the native dot
    masked: int = 0           # GEMMs with NaN/Inf lanes masked

    @property
    def tripped(self) -> bool:
        return self.trips > 0


_lock = threading.Lock()
_counts: dict[str, int] = {}


def record(event: str, n: int = 1) -> None:
    """Bump one counter (thread-safe; callable from jax.debug.callback)."""
    with _lock:
        _counts[event] = _counts.get(event, 0) + int(n)


def stats() -> GuardStats:
    """Queryable trip counter — the diagnostics surface next to
    ``dispatch.fallback_warnings_clear``."""
    with _lock:
        known = {f.name for f in dataclasses.fields(GuardStats)}
        return GuardStats(**{k: v for k, v in _counts.items() if k in known})


def stats_clear() -> None:
    with _lock:
        _counts.clear()
