"""Guard policy and the queryable trip statistics.

``GuardPolicy`` is the resolved form of the ``+guard`` / ``+guard:strict``
spec suffixes (parsed into ``EmulationConfig.guard`` by core.precision):
it owns the verification knobs and the escalation-ladder shape.  The
guard counters live on the process-wide telemetry registry
(``repro.telemetry.REGISTRY``, metric ``repro_guard_events_total`` labeled
by event and call site) — the single counter store in the process —
independent of whether hot-path telemetry is enabled, so the guard-strict
CI row needs no ``REPRO_TELEMETRY``.  :func:`stats` / :func:`stats_clear`
are the back-compat view ``runtime/trainer.py`` and ``launch/serve.py``
poll between steps to turn guard trips into retry-with-backoff events,
and what tests assert on.
"""

from __future__ import annotations

import dataclasses

from repro.core.precision import EmulationConfig
from repro.telemetry import record as _tele
from repro.telemetry.registry import REGISTRY


@dataclasses.dataclass(frozen=True)
class GuardPolicy:
    """Resolved guard behaviour for one emulated GEMM call-site.

    mode: 'on' — exhausted ladder falls back to the native dot (with a
      one-shot warning); 'strict' — exhausted ladder raises
      EmulationAccuracyError.
    probes: number of stochastic probe vectors for verify_gemm.
    tol_factor: safety factor on the analytic tolerance (the bound is a
      worst-case; 16x keeps the false-trip rate at zero on conditioned
      inputs while a single injected int8 bit flip overshoots it by
      orders of magnitude).
    escalate_bits: extra precision bits requested from plan_precision on
      the first ladder rung.
    """
    mode: str = "on"
    probes: int = 2
    tol_factor: float = 16.0
    escalate_bits: int = 8

    @classmethod
    def from_config(cls, cfg: EmulationConfig) -> "GuardPolicy | None":
        if cfg.guard is None:
            return None
        return cls(mode=cfg.guard)

    @property
    def strict(self) -> bool:
        return self.mode == "strict"


@dataclasses.dataclass(frozen=True)
class GuardStats:
    """Snapshot of the guard counters since the last ``stats_clear()``."""
    calls: int = 0            # guarded GEMMs executed
    verified: int = 0         # verifications that ran
    trips: int = 0            # verifications that missed the tolerance
    escalations: int = 0      # ladder rungs executed after a trip
    recoveries: int = 0       # trips whose retry verified clean
    native_fallbacks: int = 0 # ladders exhausted into the native dot
    masked: int = 0           # GEMMs with NaN/Inf lanes masked

    @property
    def tripped(self) -> bool:
        return self.trips > 0


def record(event: str, n: int = 1) -> None:
    """Bump one guard counter (thread-safe; callable from
    jax.debug.callback).  Events land on the telemetry registry labeled
    with the ambient call site, so per-site guard trip rates fall out of
    the same store ``guard.stats()`` sums over."""
    REGISTRY.inc(_tele.GUARD_EVENTS, int(n),
                 {"event": event, "site": _tele.current_site()})


def stats() -> GuardStats:
    """Queryable trip counter — the diagnostics surface next to
    ``dispatch.fallback_warnings_clear``.  A summed view over the
    registry's ``repro_guard_events_total`` series (all sites)."""
    known = {f.name for f in dataclasses.fields(GuardStats)}
    out = {}
    for labels, value in REGISTRY.series(_tele.GUARD_EVENTS):
        event = labels.get("event")
        if event in known:
            out[event] = out.get(event, 0) + int(value)
    return GuardStats(**out)


def stats_clear() -> None:
    REGISTRY.clear(_tele.GUARD_EVENTS)
