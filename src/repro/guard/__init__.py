"""repro.guard — numerical guardrails and graceful degradation.

Emulated GEMMs that are *fast* but silently wrong are worse than slow
correct ones.  This subsystem (see docs/robustness.md) gives every
emulated call-site three safety layers, armed by the ``+guard`` /
``+guard:strict`` precision-spec suffixes:

* **special-value semantics** (``sentinel``) — NaN/Inf operand entries
  NaN the affected output rows/columns exactly as native ``jnp.matmul``
  would, instead of truncating into finite garbage;
* **a posteriori verification** (``verify_gemm``) — a stochastic
  residual check of the finished result against the analytic error
  bound the configuration promised;
* **escalation ladder** (``ladder``) — tripped checks retry with more
  precision bits, then the XLA reference, then the native dot (or raise
  ``EmulationAccuracyError`` under ``:strict``), with every event
  counted in ``guard.stats()``.

``guard.inject`` corrupts slice/residue stacks under test so CI can
prove the verifier catches what it claims to.
"""

from repro.core.precision import EmulationAccuracyError  # noqa: F401

from repro.guard import inject as _inject_mod  # noqa: F401
from repro.guard import ladder, policy, sentinel  # noqa: F401
from repro.guard import verify as _verify_mod  # noqa: F401
from repro.guard.inject import inject  # noqa: F401
from repro.guard.ladder import guarded_call, guarded_dot_2d  # noqa: F401
from repro.guard.ladder import guarded_matmul  # noqa: F401
from repro.guard.policy import GuardPolicy, GuardStats  # noqa: F401
from repro.guard.policy import stats, stats_clear  # noqa: F401
from repro.guard.sentinel import probe_operands  # noqa: F401
from repro.guard.verify import VerifyResult, verify_gemm  # noqa: F401

__all__ = [
    "EmulationAccuracyError",
    "GuardPolicy",
    "GuardStats",
    "VerifyResult",
    "guarded_call",
    "guarded_dot_2d",
    "guarded_matmul",
    "inject",
    "probe_operands",
    "stats",
    "stats_clear",
    "verify_gemm",
]
