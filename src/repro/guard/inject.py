"""Numerical fault injection for the guard subsystem.

CI must prove the a posteriori verifier catches what it claims to — a
verifier that never trips is indistinguishable from one that cannot
trip.  ``inject(...)`` arms a thread-local fault that corrupts the next
Scheme-I slice stack or Scheme-II residue stack *as it is produced*
(hooks live in ``scheme1.split`` / ``scheme2.balanced_residues``), so
the corruption rides the real decomposition path into the GEMM exactly
like a hardware bit flip in the encoded operand would.

Faults are one-shot by default (``count=1``): the first decomposition
is corrupted, every retry re-decomposes clean — which is what lets the
smoke test assert "detected and recovered within one retry".

The hooks only fire where the decomposition actually runs in traceable
JAX ops: the XLA reference path and the prepared-operand encoders.  The
fused TPU/GPU kernels carve slices/residues inside the kernel body, so
injection tests pin ``+xla``.

Kinds:
  * ``"bitflip_slice"``  — XOR bit ``bit`` into one entry of the int8
    slice/residue stack (a classic SDC single-bit flip).
  * ``"zero_modulus"``   — zero an entire plane of the stack: for
    Scheme II this drops one modulus from the CRT; for Scheme I it
    drops one mantissa slice.
"""

from __future__ import annotations

import contextlib
import threading

import jax.numpy as jnp

KINDS = ("bitflip_slice", "zero_modulus")

_tls = threading.local()


def _active():
    return getattr(_tls, "fault", None)


class _Fault:
    def __init__(self, kind: str, count: int, bit: int, plane: int,
                 operand: str):
        self.kind = kind
        self.remaining = count
        self.bit = bit
        self.plane = plane
        self.operand = operand  # 'a' | 'b' | 'any'
        self.fired = 0
        self._call_parity = 0

    def _claims(self) -> bool:
        """Whether this hook invocation should corrupt.

        ``operand`` targeting relies on call order inside one GEMM: the
        reference paths decompose a first, then b — parity 0 is 'a',
        parity 1 is 'b'.  'any' corrupts the first invocation.
        """
        if self.remaining <= 0:
            return False
        parity = self._call_parity
        self._call_parity ^= 1
        if self.operand == "any":
            return True
        return parity == (0 if self.operand == "a" else 1)


@contextlib.contextmanager
def inject(kind: str, *, count: int = 1, bit: int = 6, plane: int = 0,
           operand: str = "any"):
    """Arm a one-shot (by default) numerical fault for this thread.

    Args:
      kind: one of ``KINDS``.
      count: how many stacks to corrupt before the fault disarms
        (default 1 — the retry after a guard trip runs clean).
      bit: which bit to flip for ``bitflip_slice`` (6 flips a
        high-magnitude bit so the corruption is far outside rounding).
      plane: which slice/modulus plane to target.
      operand: 'a', 'b', or 'any' — which operand's stack to corrupt.
    """
    if kind not in KINDS:
        raise ValueError(f"unknown fault kind {kind!r} (expected one "
                         f"of {KINDS})")
    if operand not in ("a", "b", "any"):
        raise ValueError(f"operand must be 'a', 'b' or 'any', "
                         f"got {operand!r}")
    if not 0 <= bit <= 6:
        raise ValueError(f"bit must be in [0, 6] for signed int8 stacks, "
                         f"got {bit}")
    prev = _active()
    fault = _Fault(kind, count, bit, plane, operand)
    _tls.fault = fault
    try:
        yield fault
    finally:
        _tls.fault = prev


def _corrupt(stack, fault: _Fault):
    plane = min(fault.plane, stack.shape[0] - 1)
    if fault.kind == "zero_modulus":
        return stack.at[plane].set(0)
    # bitflip_slice: XOR one bit into the first entry of the plane.
    flat = stack.reshape(stack.shape[0], -1)
    hit = flat[plane, 0] ^ jnp.int8(1 << fault.bit)
    return flat.at[plane, 0].set(hit).reshape(stack.shape)


def maybe_corrupt_slices(slices):
    """Hook called by ``scheme1.split`` on the freshly built stack."""
    fault = _active()
    if fault is None or not fault._claims():
        return slices
    fault.remaining -= 1
    fault.fired += 1
    return _corrupt(slices, fault)


def maybe_corrupt_residues(residues):
    """Hook called by ``scheme2.balanced_residues``."""
    fault = _active()
    if fault is None or not fault._claims():
        return residues
    fault.remaining -= 1
    fault.fired += 1
    return _corrupt(residues, fault)
