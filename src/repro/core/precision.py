"""Precision planning for Ozaki-scheme emulated GEMM.

This module owns the *numerical* side of the emulation configuration:

* ``safe_beta(K)``       — largest per-slice bit-width such that a K-long
  int8xint8 dot accumulates exactly in int32 (Scheme I).
* ``default_moduli(p)``  — pairwise-coprime moduli <= 256 (Scheme II).
* ``scheme2_budget``     — per-operand integer bit budget under the CRT
  exactness bound 2 * K * max|A'| * max|B'| < P.
* ``plan_precision``     — the Fig.-7 crossover automated: pick scheme + p
  for a target precision (the cuBLAS-ADP analogue the paper lacks).
"""

from __future__ import annotations

import dataclasses
import math
from typing import Literal, Sequence

# Pairwise coprime moduli <= 256, descending. 256 = 2^8; 255 = 3*5*17;
# 253 = 11*23; 247 = 13*19; the rest are primes. Pairwise coprimality is
# asserted by tests/test_scheme2.py::test_moduli_coprime.
DEFAULT_MODULI: tuple[int, ...] = (
    256, 255, 253, 251, 247, 241, 239, 233, 229, 227, 223, 211, 199, 197, 193,
    191,
)

Scheme = Literal["native", "ozaki1", "ozaki2"]


def safe_beta(k_dim: int, max_beta: int = 7) -> int:
    """Largest slice bit-width with exact int32 accumulation over ``k_dim``.

    Each product of two beta-bit signed slices is bounded by (2^beta - 1)^2;
    summing ``k_dim`` of them must stay below 2^31.
    """
    if k_dim <= 0:
        raise ValueError(f"k_dim must be positive, got {k_dim}")
    beta = int((31 - math.ceil(math.log2(k_dim))) // 2)
    return max(1, min(max_beta, beta))


def default_moduli(p: int) -> tuple[int, ...]:
    if not 1 <= p <= len(DEFAULT_MODULI):
        raise ValueError(f"p={p} out of range [1, {len(DEFAULT_MODULI)}]")
    return DEFAULT_MODULI[:p]


def scheme2_budget(moduli: Sequence[int], k_dim: int,
                   complex_guard: bool = False) -> int:
    """Per-operand magnitude bit budget for exact CRT reconstruction.

    Bound: 2 * K * 2^bits_a * 2^bits_b < P  (one extra bit for the signed
    range mapping; one more for 3M complex where C_im sums two products).
    """
    log2_p_prod = sum(math.log2(m) for m in moduli)
    guard = 2 + (1 if complex_guard else 0)
    total = int(log2_p_prod - guard - math.ceil(math.log2(max(2, k_dim))))
    per_operand = total // 2
    # float64 can only represent integers exactly up to 2^53; trunc happens
    # in float, so cap the budget there.
    return max(1, min(per_operand, 52))


def scheme1_bits(p: int, beta: int) -> int:
    """Approximate relative precision (bits) delivered by Scheme I."""
    return p * beta


def scheme2_bits(moduli: Sequence[int], k_dim: int) -> int:
    """Approximate relative precision (bits) delivered by Scheme II."""
    return scheme2_budget(moduli, k_dim)


@dataclasses.dataclass(frozen=True)
class EmulationConfig:
    """Configuration of one emulated GEMM call-site.

    Attributes:
      scheme:  'native' (plain dot), 'ozaki1' (mantissa slicing),
               'ozaki2' (CRT modular).
      p:       slice count (Scheme I) / modulus count (Scheme II).
      beta:    Scheme-I per-slice bit-width; None = derive via safe_beta(K).
      moduli:  Scheme-II moduli; None = default_moduli(p).
      impl:    'xla' (jnp reference path), 'pallas' (fused TPU kernel),
               'auto' (pallas where available, else xla).
      fused:   if False, force the naive (unfused, materializing) path —
               used by benchmarks to reproduce the paper's baselines.
      out_dtype: output dtype; None = result dtype of the inputs.
      decomp:  where Scheme-I decomposition runs on the fused path:
               'kernel' slices the fp32 tile in VMEM (the in-kernel
               prologue — no (M, p*K) HBM intermediate), 'xla' keeps the
               historical split -> interleave -> kernel pipeline, 'auto'
               prefers the prologue.
      cache_weights: Scheme-I training flag — the custom VJP prepares the
               rhs operand once per step (forward layout + K-transposed
               twin for dA) instead of re-splitting it in forward, remat
               re-forward, and backward (see repro.kernels.prepared).
      backend: kernel-backend name from the registry in
               repro.kernels.backends ('tpu' | 'gpu' | 'xla' | an
               out-of-tree registration); None = platform default.  The
               ``REPRO_BACKEND`` environment variable overrides this at
               dispatch time.
    """
    scheme: Scheme = "native"
    p: int = 4
    beta: int | None = None
    moduli: tuple[int, ...] | None = None
    impl: Literal["auto", "xla", "pallas"] = "auto"
    fused: bool = True
    out_dtype: str | None = None
    # Mixed-precision emulated training (beyond-paper): gradients tolerate
    # fewer slices than the forward pass; 0 = same as forward.
    bwd_p: int = 0
    decomp: Literal["auto", "xla", "kernel"] = "auto"
    cache_weights: bool = False
    backend: str | None = None

    def resolved_beta(self, k_dim: int) -> int:
        return self.beta if self.beta is not None else safe_beta(k_dim)

    def resolved_moduli(self) -> tuple[int, ...]:
        return self.moduli if self.moduli is not None else default_moduli(self.p)

    def bits(self, k_dim: int) -> int:
        if self.scheme == "ozaki1":
            return scheme1_bits(self.p, self.resolved_beta(k_dim))
        if self.scheme == "ozaki2":
            return scheme2_bits(self.resolved_moduli(), k_dim)
        return 24  # native fp32 mantissa

    def gemm_count(self) -> int:
        """Paper Table II: number of int8 GEMMs issued."""
        if self.scheme == "ozaki1":
            return self.p * (self.p + 1) // 2
        if self.scheme == "ozaki2":
            return self.p
        return 1


NATIVE = EmulationConfig(scheme="native")


def plan_precision(target_bits: int, k_dim: int,
                   prefer: Scheme | None = None) -> EmulationConfig:
    """Pick the cheaper scheme for ``target_bits`` of relative precision.

    Implements the paper's Fig.-7 crossover: Scheme I wins below ~FP32
    precision (its GEMM count grows quadratically), Scheme II above.
    """
    beta = safe_beta(k_dim)
    p1 = max(1, math.ceil(target_bits / beta))
    # Smallest Scheme-II modulus count that meets the target.
    p2 = None
    for p in range(2, len(DEFAULT_MODULI) + 1):
        if scheme2_bits(default_moduli(p), k_dim) >= target_bits:
            p2 = p
            break
    cost1 = p1 * (p1 + 1) / 2 if p1 * beta >= target_bits else math.inf
    # Scheme II pays residue generation + CRT reconstruction on top of its p
    # GEMMs; empirically ~25% per-GEMM overhead (paper Fig. 7 crossover).
    cost2 = 1.25 * p2 if p2 is not None else math.inf
    if prefer == "ozaki1" and cost1 < math.inf:
        return EmulationConfig(scheme="ozaki1", p=p1)
    if prefer == "ozaki2" and cost2 < math.inf:
        return EmulationConfig(scheme="ozaki2", p=p2)
    if cost1 == math.inf and cost2 == math.inf:
        raise ValueError(
            f"target_bits={target_bits} unreachable at K={k_dim} "
            f"(scheme1 max {len(DEFAULT_MODULI) * beta}, "
            f"scheme2 max {scheme2_bits(DEFAULT_MODULI, k_dim)})")
    if cost1 <= cost2:
        return EmulationConfig(scheme="ozaki1", p=p1)
    return EmulationConfig(scheme="ozaki2", p=p2)
