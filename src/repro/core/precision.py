"""Precision planning for Ozaki-scheme emulated GEMM.

This module owns the *numerical* side of the emulation configuration:

* ``safe_beta(K)``       — largest per-slice bit-width such that a K-long
  int8xint8 dot accumulates exactly in int32 (Scheme I).
* ``default_moduli(p)``  — pairwise-coprime moduli <= 256 (Scheme II).
* ``scheme2_budget``     — per-operand integer bit budget under the CRT
  exactness bound 2 * K * max|A'| * max|B'| < P.
* ``plan_precision``     — the Fig.-7 crossover automated: pick scheme + p
  for a target precision (the cuBLAS-ADP analogue the paper lacks).
"""

from __future__ import annotations

import dataclasses
import math
import re
from typing import Literal, Sequence

# Pairwise coprime moduli <= 256, descending. 256 = 2^8; 255 = 3*5*17;
# 253 = 11*23; 247 = 13*19; the rest are primes. Pairwise coprimality is
# asserted by tests/test_scheme2.py::test_moduli_coprime.
DEFAULT_MODULI: tuple[int, ...] = (
    256, 255, 253, 251, 247, 241, 239, 233, 229, 227, 223, 211, 199, 197, 193,
    191,
)

Scheme = Literal["native", "ozaki1", "ozaki2"]


class EmulationAccuracyError(ValueError):
    """An emulated GEMM cannot (or did not) meet its accuracy contract.

    Raised ahead of time when a configuration provably breaks exactness
    (e.g. ``scheme2.check_exact_k``'s int32 accumulator bound) and at
    runtime by the guard subsystem (``repro.guard``) when a verified
    result misses its error bound and the escalation ladder is exhausted
    (``+guard:strict``).  Subclasses ValueError so existing call-sites
    that caught the old bare ValueError keep working.
    """

# K the spec mini-language assumes when a ``bits=N`` spec names no ``:kK``
# suffix — plan_precision needs a contraction length to budget slices
# against, and 4096 is the model zoo's typical projection K.
DEFAULT_PLAN_K = 4096

# Largest slice/modulus count the planner searches (the moduli table
# bounds Scheme II exactly; Scheme I shares the cap so the planner never
# returns a slice count whose GEMM count is off the paper's Table II).
MAX_PLAN_P = 16


def safe_beta(k_dim: int, max_beta: int = 7) -> int:
    """Largest slice bit-width with exact int32 accumulation over ``k_dim``.

    Each product of two beta-bit signed slices is bounded by (2^beta - 1)^2;
    summing ``k_dim`` of them must stay below 2^31.
    """
    if k_dim <= 0:
        raise ValueError(f"k_dim must be positive, got {k_dim}")
    beta = int((31 - math.ceil(math.log2(k_dim))) // 2)
    return max(1, min(max_beta, beta))


def default_moduli(p: int) -> tuple[int, ...]:
    if not 1 <= p <= len(DEFAULT_MODULI):
        raise ValueError(f"p={p} out of range [1, {len(DEFAULT_MODULI)}]")
    return DEFAULT_MODULI[:p]


def scheme2_budget(moduli: Sequence[int], k_dim: int,
                   complex_guard: bool = False) -> int:
    """Per-operand magnitude bit budget for exact CRT reconstruction.

    Bound: 2 * K * 2^bits_a * 2^bits_b < P  (one extra bit for the signed
    range mapping; one more for 3M complex where C_im sums two products).
    """
    log2_p_prod = sum(math.log2(m) for m in moduli)
    guard = 2 + (1 if complex_guard else 0)
    total = int(log2_p_prod - guard - math.ceil(math.log2(max(2, k_dim))))
    per_operand = total // 2
    # float64 can only represent integers exactly up to 2^53; trunc happens
    # in float, so cap the budget there.
    return max(1, min(per_operand, 52))


def scheme1_bits(p: int, beta: int) -> int:
    """Approximate relative precision (bits) delivered by Scheme I."""
    return p * beta


def scheme2_bits(moduli: Sequence[int], k_dim: int) -> int:
    """Approximate relative precision (bits) delivered by Scheme II."""
    return scheme2_budget(moduli, k_dim)


@dataclasses.dataclass(frozen=True)
class EmulationConfig:
    """Configuration of one emulated GEMM call-site.

    Attributes:
      scheme:  'native' (plain dot), 'ozaki1' (mantissa slicing),
               'ozaki2' (CRT modular).
      p:       slice count (Scheme I) / modulus count (Scheme II).
      beta:    Scheme-I per-slice bit-width; None = derive via safe_beta(K).
      moduli:  Scheme-II moduli; None = default_moduli(p).
      impl:    'xla' (jnp reference path), 'pallas' (fused TPU kernel),
               'auto' (pallas where available, else xla).
      fused:   if False, force the naive (unfused, materializing) path —
               used by benchmarks to reproduce the paper's baselines.
      out_dtype: output dtype; None = result dtype of the inputs.
      decomp:  where Scheme-I decomposition runs on the fused path:
               'kernel' slices the fp32 tile in VMEM (the in-kernel
               prologue — no (M, p*K) HBM intermediate), 'xla' keeps the
               historical split -> interleave -> kernel pipeline, 'auto'
               prefers the prologue.
      cache_weights: training flag — the custom VJP prepares the rhs
               operand once per step (forward layout + K-transposed
               twin for dA) instead of re-encoding it in forward, remat
               re-forward, and backward: Scheme I caches int8 mantissa
               slices, Scheme II balanced int8 residues (see
               repro.kernels.prepared).
      backend: kernel-backend name from the registry in
               repro.kernels.backends ('tpu' | 'gpu' | 'xla' | an
               out-of-tree registration); None = platform default.  The
               ``REPRO_BACKEND`` environment variable overrides this at
               dispatch time.
      guard:   numerical guardrails (repro.guard): None = off, 'on' =
               special-value masking + a posteriori verification with
               the escalation ladder (retry with more bits, then
               fused->xla->native), 'strict' = same but an exhausted
               ladder raises EmulationAccuracyError instead of falling
               back to native.  Spec suffixes '+guard' / '+guard:strict'.
    """
    scheme: Scheme = "native"
    p: int = 4
    beta: int | None = None
    moduli: tuple[int, ...] | None = None
    impl: Literal["auto", "xla", "pallas"] = "auto"
    fused: bool = True
    out_dtype: str | None = None
    # Mixed-precision emulated training (beyond-paper): gradients tolerate
    # fewer slices than the forward pass; 0 = same as forward.
    bwd_p: int = 0
    decomp: Literal["auto", "xla", "kernel"] = "auto"
    cache_weights: bool = False
    backend: str | None = None
    guard: Literal["on", "strict"] | None = None

    def resolved_beta(self, k_dim: int) -> int:
        return self.beta if self.beta is not None else safe_beta(k_dim)

    def resolved_moduli(self) -> tuple[int, ...]:
        return self.moduli if self.moduli is not None else default_moduli(self.p)

    def bits(self, k_dim: int) -> int:
        if self.scheme == "ozaki1":
            return scheme1_bits(self.p, self.resolved_beta(k_dim))
        if self.scheme == "ozaki2":
            return scheme2_bits(self.resolved_moduli(), k_dim)
        return 24  # native fp32 mantissa

    def gemm_count(self) -> int:
        """Paper Table II: number of int8 GEMMs issued."""
        if self.scheme == "ozaki1":
            return self.p * (self.p + 1) // 2
        if self.scheme == "ozaki2":
            return self.p
        return 1

    # -- the precision-spec mini-language (see docs/api.md) -----------------
    #
    #   spec   := base suffix*
    #   base   := "native" | "ozaki1-p" INT | "ozaki2-m" INT
    #           | "bits=" INT [":k" INT]        (routes via plan_precision)
    #   suffix := "@" BACKEND                   (kernel-backend name)
    #           | "+cached"                     (per-step weight cache:
    #                                            slices / residues)
    #           | "+xla" | "+pallas"            (pin impl; default 'auto')
    #           | "+guard" | "+guard:strict"    (numerical guardrails,
    #                                            see docs/robustness.md)
    #
    # ``ozaki2-m6`` pins ``moduli=default_moduli(6)`` so parse/to_spec
    # round-trips survive plan_precision's explicit moduli. ``ozaki2-p6``
    # is accepted as a legacy alias and canonicalized to ``-m``.

    _SPEC_RE = re.compile(r"(?P<base>[^@+\s]+)(?P<suffixes>(?:[@+][^@+\s]+)*)")

    @classmethod
    def parse(cls, spec: "str | EmulationConfig") -> "EmulationConfig":
        """Parse a precision-spec string into an EmulationConfig.

        An EmulationConfig passes through unchanged, so call-sites can
        accept either form. Raises ValueError with the offending token
        for anything outside the grammar.
        """
        if isinstance(spec, cls):
            return spec
        if not isinstance(spec, str):
            raise TypeError(f"precision spec must be a str or "
                            f"EmulationConfig, got {type(spec).__name__}")
        m = cls._SPEC_RE.fullmatch(spec.strip())
        if m is None:
            raise ValueError(f"bad precision spec {spec!r}")
        base = m.group("base")
        backend: str | None = None
        cached = False
        impl = "auto"
        guard: str | None = None
        for tok in re.findall(r"[@+][^@+]+", m.group("suffixes")):
            if tok[0] == "@":
                if backend is not None:
                    raise ValueError(f"duplicate '@backend' in {spec!r}")
                backend = tok[1:]
            elif tok[1:] == "cached":
                cached = True
            elif tok[1:] in ("xla", "pallas"):
                impl = tok[1:]
            elif tok[1:] == "guard":
                guard = "on"
            elif tok[1:] == "guard:strict":
                guard = "strict"
            else:
                raise ValueError(
                    f"unknown suffix {tok!r} in {spec!r} (expected "
                    "'@<backend>', '+cached', '+xla', '+pallas', "
                    "'+guard' or '+guard:strict')")

        if base == "native":
            cfg = cls(scheme="native", impl=impl, backend=backend)
        elif base.startswith("bits="):
            bm = re.fullmatch(r"bits=(\d+)(?::k(\d+))?", base)
            if bm is None:
                raise ValueError(f"bad 'bits=' base in {spec!r} (expected "
                                 "'bits=<N>' or 'bits=<N>:k<K>')")
            planned = plan_precision(int(bm.group(1)),
                                     int(bm.group(2) or DEFAULT_PLAN_K))
            cfg = dataclasses.replace(planned, impl=impl, backend=backend)
        else:
            sm = re.fullmatch(r"(ozaki[12])-([pm])(\d+)", base)
            if sm is None:
                raise ValueError(
                    f"bad precision spec {spec!r}: base must be 'native', "
                    "'ozaki1-p<N>', 'ozaki2-m<N>' or 'bits=<N>[:k<K>]'")
            scheme, kind, num = sm.group(1), sm.group(2), int(sm.group(3))
            if scheme == "ozaki1" and kind != "p":
                raise ValueError(f"{spec!r}: ozaki1 counts slices with "
                                 "'-p<N>'")
            if num < 1:
                raise ValueError(f"{spec!r}: count must be >= 1")
            if scheme == "ozaki2":
                # -m pins the moduli so the config round-trips to_spec.
                cfg = cls(scheme="ozaki2", p=num, moduli=default_moduli(num),
                          impl=impl, backend=backend)
            else:
                cfg = cls(scheme="ozaki1", p=num, impl=impl, backend=backend)
        if cached:
            if cfg.scheme == "native":
                raise ValueError(f"{spec!r}: '+cached' needs an emulation "
                                 "scheme (ozaki1 caches int8 slices, "
                                 "ozaki2 balanced residues)")
            cfg = dataclasses.replace(cfg, cache_weights=True)
        if guard is not None:
            if cfg.scheme == "native":
                raise ValueError(f"{spec!r}: '+guard' needs an emulation "
                                 "scheme (native dots have nothing to "
                                 "verify against)")
            cfg = dataclasses.replace(cfg, guard=guard)
        return cfg

    def to_spec(self) -> str:
        """Print this config as a canonical spec string.

        Inverse of :meth:`parse` on its image: ``parse(cfg.to_spec()) ==
        cfg`` for every config parse can produce. Configs carrying fields
        the grammar cannot express (explicit beta, custom moduli,
        out_dtype, bwd_p, decomp, fused=False) raise ValueError naming
        the field.
        """
        blockers = []
        if self.beta is not None:
            blockers.append("beta")
        if self.out_dtype is not None:
            blockers.append("out_dtype")
        if self.bwd_p:
            blockers.append("bwd_p")
        if not self.fused:
            blockers.append("fused")
        if self.decomp != "auto":
            blockers.append("decomp")
        if self.moduli is not None and (
                self.scheme != "ozaki2"
                or tuple(self.moduli) != default_moduli(self.p)):
            blockers.append("moduli")
        if self.cache_weights and self.scheme == "native":
            blockers.append("cache_weights")
        if self.guard is not None and self.scheme == "native":
            blockers.append("guard")
        if blockers:
            raise ValueError(
                f"config not expressible as a spec (non-default "
                f"{', '.join(blockers)}): {self!r}")
        if self.scheme == "native":
            base = "native"
        elif self.scheme == "ozaki1":
            base = f"ozaki1-p{self.p}"
        else:
            base = f"ozaki2-m{self.p}"
        out = base
        if self.backend:
            out += f"@{self.backend}"
        if self.impl != "auto":
            out += f"+{self.impl}"
        if self.cache_weights:
            out += "+cached"
        if self.guard == "on":
            out += "+guard"
        elif self.guard == "strict":
            out += "+guard:strict"
        return out


NATIVE = EmulationConfig(scheme="native")


def plan_precision(target_bits: int, k_dim: int,
                   prefer: Scheme | None = None) -> EmulationConfig:
    """Pick the cheaper scheme for ``target_bits`` of relative precision.

    Implements the paper's Fig.-7 crossover: Scheme I wins below ~FP32
    precision (its GEMM count grows quadratically), Scheme II above.

    ``prefer`` pins the scheme instead of cost-comparing; a preferred
    scheme that cannot reach ``target_bits`` raises (naming the maximum
    it can deliver at this K) rather than silently handing the choice
    back to the cost comparison. Returned ozaki2 configs pin ``moduli``
    explicitly so they survive a ``to_spec``/``parse`` round-trip.
    """
    if prefer not in (None, "ozaki1", "ozaki2"):
        raise ValueError(f"prefer must be 'ozaki1' or 'ozaki2', "
                         f"got {prefer!r}")
    beta = safe_beta(k_dim)
    p1 = max(1, math.ceil(target_bits / beta))
    max1 = MAX_PLAN_P * beta
    # Smallest Scheme-II modulus count that meets the target.
    p2 = None
    for p in range(2, len(DEFAULT_MODULI) + 1):
        if scheme2_bits(default_moduli(p), k_dim) >= target_bits:
            p2 = p
            break
    max2 = scheme2_bits(DEFAULT_MODULI, k_dim)
    cost1 = p1 * (p1 + 1) / 2 if p1 <= MAX_PLAN_P else math.inf
    # Scheme II pays residue generation + CRT reconstruction on top of its p
    # GEMMs; empirically ~25% per-GEMM overhead (paper Fig. 7 crossover).
    cost2 = 1.25 * p2 if p2 is not None else math.inf

    def scheme1_cfg():
        return EmulationConfig(scheme="ozaki1", p=p1)

    def scheme2_cfg():
        return EmulationConfig(scheme="ozaki2", p=p2,
                               moduli=default_moduli(p2))

    if prefer == "ozaki1":
        if cost1 == math.inf:
            raise ValueError(
                f"prefer='ozaki1' cannot reach target_bits={target_bits} "
                f"at K={k_dim}: p<={MAX_PLAN_P} slices of beta={beta} bits "
                f"deliver at most {max1} bits")
        return scheme1_cfg()
    if prefer == "ozaki2":
        if cost2 == math.inf:
            raise ValueError(
                f"prefer='ozaki2' cannot reach target_bits={target_bits} "
                f"at K={k_dim}: the full {len(DEFAULT_MODULI)}-modulus "
                f"table delivers at most {max2} bits")
        return scheme2_cfg()
    if cost1 == math.inf and cost2 == math.inf:
        raise ValueError(
            f"target_bits={target_bits} unreachable at K={k_dim} "
            f"(scheme1 max {max1}, scheme2 max {max2})")
    if cost1 <= cost2:
        return scheme1_cfg()
    return scheme2_cfg()
