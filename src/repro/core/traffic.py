"""Analytical HBM-traffic models from the paper (Eqs. 9, 10, 14, 15, 17, 18).

These drive the benchmarks' derived columns and the roofline memory terms for
the emulated-GEMM cells, and are validated against operand shapes in
tests/test_traffic.py. All results in bytes; ``out_bytes`` is the output
element size (4 = FP32, 8 = FP64).
"""

from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class GemmShape:
    m: int
    n: int
    k: int


def scheme1_naive_bytes(s: GemmShape, p: int, out_bytes: int = 8) -> int:
    """Paper Eq. 9: per-slice-pair kernel launches + INT32 round-trips."""
    operand = p * (p + 1) // 2 * (s.m + s.n) * s.k
    int32_traffic = 4 * p * (p + 1) * s.m * s.n
    return operand + int32_traffic + out_bytes * s.m * s.n


def scheme1_fused_bytes(s: GemmShape, p: int, out_bytes: int = 8) -> int:
    """Paper Eq. 10: each slice loaded once; accumulators never leave chip."""
    return p * (s.m + s.n) * s.k + out_bytes * s.m * s.n


def scheme2_naive_bytes_per_modulus(s: GemmShape) -> int:
    """Paper Eq. 14: INT32 write+read round-trip plus INT8 residue write."""
    return (s.m + s.n) * s.k + 8 * s.m * s.n + s.m * s.n


def scheme2_fused_bytes_per_modulus(s: GemmShape) -> int:
    """Paper Eq. 15: in-epilogue mod reduce — only the INT8 residue leaves."""
    return (s.m + s.n) * s.k + s.m * s.n


def scheme2_3m_naive_bytes_per_modulus(s: GemmShape) -> int:
    """Paper Eq. 17: three INT32 round-trips + two INT8 writes."""
    return 3 * (s.m + s.n) * s.k + 24 * s.m * s.n + 2 * s.m * s.n


def scheme2_3m_fused_bytes_per_modulus(s: GemmShape) -> int:
    """Paper Eq. 18: the 24MN intermediate term vanishes."""
    return 3 * (s.m + s.n) * s.k + 2 * s.m * s.n


def int8_gemm_flops(s: GemmShape) -> int:
    """MAC-pair ops of one int8 GEMM (2MNK)."""
    return 2 * s.m * s.n * s.k


def scheme1_flops(s: GemmShape, p: int) -> int:
    return p * (p + 1) // 2 * int8_gemm_flops(s)


def scheme2_flops(s: GemmShape, p: int, complex_3m: bool = False) -> int:
    mult = 3 if complex_3m else 1
    return mult * p * int8_gemm_flops(s)


def arithmetic_intensity(flops: int, traffic_bytes: int) -> float:
    return flops / max(1, traffic_bytes)


def scheme1_intensity_gain(p: int) -> float:
    """Fused/naive intensity ratio ~ (p+1)/2 for operand-dominated sizes."""
    return (p + 1) / 2


def scheme1_workspace_bytes(s: GemmShape, p: int) -> int:
    """Interleaved Ahat (M, pK) + Bhat (pK, N), int8."""
    return p * s.k * (s.m + s.n)


# ---------------------------------------------------------------------------
# Decomposition-side traffic (beyond the paper's Eqs. 9/10, which only
# charge the GEMM: the split/interleave preprocessing has its own HBM
# round-trips, and at practical training sizes they dominate once the
# GEMM itself is fused — Mukunoki'25 / Uchino'25 observation).
#
# Counting convention, per operand of `elems` elements (fp32 source,
# p int8 slices): every HBM read/write of fp32 operand data or slice
# intermediates is decomposition-side; streaming the *finished* int8
# interleaved slices into the GEMM kernel is GEMM-side (the Eq. 10
# p(M+N)K term) and NOT counted — except on the prologue path, where the
# kernel's operand stream carries the raw fp32 (decomposition input), so
# that read is charged here instead.
# ---------------------------------------------------------------------------


def scheme1_decomp_xla_bytes(elems: int, p: int, uses: int = 1) -> int:
    """The split -> interleave XLA pipeline, per decomposition:

    4*elems   fp32 read for the power-of-two scale reduction
    4*elems   fp32 re-read by the truncate-subtract slicing pass
    p*elems   int8 write of the (p, M, K) slice stack
    2p*elems  interleave_k transpose: slice read + interleaved write

    ``uses`` = decompositions per step: forward, remat re-forward, and
    the backward's B^T split each pay in full (3x per layer per step).
    """
    return uses * (8 + 3 * p) * elems


def scheme1_decomp_prologue_bytes(elems: int, p: int, uses: int = 1) -> int:
    """The in-kernel prologue: 4*elems scale read + the 4*elems fp32
    operand stream the kernel decomposes in VMEM. No slice intermediates
    ever touch HBM."""
    return uses * 8 * elems


def scheme1_decomp_prepared_bytes(elems: int, p: int,
                                  preps: int = 1) -> int:
    """PreparedOperand: one prep emits forward + twin layouts from a
    single fp32 read (decompose_interleave_pair): 4*elems for the two
    fused scale reductions, 4*elems for the pass itself, 2p*elems of
    int8 slice writes. Consumption streams finished slices (GEMM-side).
    """
    return preps * (8 + 2 * p) * elems


def scheme1_decomp_reduction(p: int, uses: int = 3) -> tuple[float, float]:
    """(prologue, prepared) decomposition-byte reduction factors vs the
    XLA pipeline for one weight over ``uses`` per-step decompositions."""
    xla = scheme1_decomp_xla_bytes(1, p, uses)
    return (xla / scheme1_decomp_prologue_bytes(1, p, uses),
            xla / scheme1_decomp_prepared_bytes(1, p, 1))


# ---------------------------------------------------------------------------
# Scheme-II residue-side traffic (the scheme1 trio's counterpart).
#
# Unlike Scheme I — where the Eq. 9/10 GEMM models already charged the
# int32 output round-trips and only the *operand* decomposition needed a
# per-elems model — the Scheme-II reference pipeline round-trips residue
# intermediates on BOTH sides of the GEMM: the (p, M, K)/(p, K, N)
# balanced residue stacks on the way in, and the (p, M, N) int32
# accumulators -> modular-reduced canonical residues on the way out to
# the CRT.  The fused kernel (gpu backend) keeps all of it on-chip, so
# the honest model is per-GemmShape, not per-operand-elems:
#
#   encode, per operand elem:  4 fp32 scale read + 4 fp32 encode read
#                              + p int8 residue write          = 8 + p
#   (3M complex doubles the fp reads and adds the re-balanced sum
#    phase: 2p int8 reads + p writes                           = 16 + 5p)
#   output side, per MN elem:  int32 accumulator write + read by the
#                              modular reduce (8p) + canonical residue
#                              write + read by the CRT (8p)    = 16p
#   (3M: three int32 accumulator round-trips per modulus (24p, the
#    Eq. 17 term) + two canonical residue round-trips (16p)    = 40p)
#
# Streaming the finished residues into the GEMM is GEMM-side (the
# Eq. 14/15 (M+N)K term) and NOT counted — except on the prologue path,
# where the kernel's operand stream carries the raw fp32, so that read
# is charged here instead (same convention as the scheme1 trio).
# ---------------------------------------------------------------------------


def scheme2_decomp_xla_bytes(s: GemmShape, p: int, uses: int = 1,
                             complex_3m: bool = False) -> int:
    """Residue-side HBM bytes of the XLA reference Scheme-II pipeline
    (encode both operands + the int32/canonical output round-trips),
    re-paid ``uses`` times per step."""
    if complex_3m:
        operand = (16 + 5 * p) * (s.m + s.n) * s.k
        out_side = 40 * p * s.m * s.n
    else:
        operand = (8 + p) * (s.m + s.n) * s.k
        out_side = 16 * p * s.m * s.n
    return uses * (operand + out_side)


def scheme2_decomp_prologue_bytes(s: GemmShape, p: int, uses: int = 1,
                                  complex_3m: bool = False) -> int:
    """The fused residue pipeline: the scale pass and the fp32 operand
    stream are all that touches HBM — residues, accumulators, Garner
    digits and the double-double reconstruction stay on-chip."""
    del p
    mult = 2 if complex_3m else 1
    return uses * 8 * mult * (s.m + s.n) * s.k


def scheme2_decomp_prepared_bytes(s: GemmShape, p: int, uses: int = 1,
                                  preps: int = 1,
                                  complex_3m: bool = False) -> int:
    """PreparedResidues: the rhs is encoded ``preps`` times (scale read
    + encode read + p int8 residue writes) and every use streams the
    finished stack (GEMM-side); the lhs still runs the fused prologue
    per use.  The complex model is analytic only — the prepared path is
    real-valued."""
    enc = (16 + 5 * p) if complex_3m else (8 + p)
    lhs_stream = 16 if complex_3m else 8
    return preps * enc * s.k * s.n + uses * lhs_stream * s.m * s.k


def scheme2_decomp_reduction(s: GemmShape, p: int,
                             uses: int = 3) -> tuple[float, float]:
    """(fused, prepared) residue-side byte reduction factors vs the XLA
    reference for one GEMM over ``uses`` per-step encodes."""
    xla = scheme2_decomp_xla_bytes(s, p, uses)
    return (xla / scheme2_decomp_prologue_bytes(s, p, uses),
            xla / scheme2_decomp_prepared_bytes(s, p, uses, 1))


# ---------------------------------------------------------------------------
# Decode-step traffic (serving; repro.serving, docs/serving.md).
#
# A decode step is a batch of B single-token rows against a full
# projection weight: x (B, K) @ W (K, N).  The weight stream dominates
# and is batch-invariant — it is paid once per *step*, not once per
# token — so the per-token cost is the step cost divided by B.  That
# quotient is the analytic case for continuous batching: a scheduler
# that keeps the decode lanes full divides the (huge) weight term by
# the lane count, while a lockstep engine draining a ragged batch pays
# it over however few lanes are still live.
#
# Weight-side bytes per step, by decomposition path (p int8 slices):
#
#   prepared  p*K*N        finished slice stack streamed from the
#                          PreparedOperand cache (decomposed once per
#                          session by engine.prepare_params)
#   prologue  8*K*N        raw fp32 weight stream + scale read,
#                          re-decomposed in VMEM every step
#   xla       (8+4p)*K*N   split -> interleave round-trips (the
#                          scheme1_decomp_xla_bytes model) plus the
#                          finished-slice GEMM stream
#
# The activation side always runs the in-kernel prologue on the fresh
# tokens (8*B*K: scale read + fp32 stream — activations change every
# step, so preparing them buys nothing), and the logits row write adds
# out_bytes*B*N.
# ---------------------------------------------------------------------------

_DECODE_WEIGHT_PATHS = ("prepared", "prologue", "xla")


def scheme1_decode_step_bytes(k: int, n: int, batch: int, p: int,
                              path: str = "prepared",
                              out_bytes: int = 4) -> int:
    """HBM bytes of one decode-step GEMM x(B, K) @ W(K, N)."""
    if path not in _DECODE_WEIGHT_PATHS:
        raise ValueError(f"unknown decode weight path {path!r}")
    weight = {"prepared": p * k * n,
              "prologue": 8 * k * n,
              "xla": (8 + 4 * p) * k * n}[path]
    return weight + 8 * batch * k + out_bytes * batch * n


def scheme1_decode_per_token_bytes(k: int, n: int, batch: int, p: int,
                                   path: str = "prepared",
                                   out_bytes: int = 4) -> float:
    """Per-token share of one decode step's bytes at batch ``batch``."""
    return scheme1_decode_step_bytes(k, n, batch, p, path, out_bytes) / batch


def decode_batch_amortization(k: int, n: int, p: int, batch: int,
                              path: str = "prepared") -> float:
    """Per-token byte reduction of decoding at ``batch`` vs batch 1 —
    the weight-stream amortization a full continuous-batching step
    realizes over a lockstep engine's last straggler lane."""
    return (scheme1_decode_per_token_bytes(k, n, 1, p, path)
            / scheme1_decode_per_token_bytes(k, n, batch, p, path))


# ---------------------------------------------------------------------------
# Strided-batched contractions (dispatch.emulated_matmul_batched).
#
# A stack of B same-shape GEMMs can run two ways:
#
#   fused  — ONE pallas_call over a (B, bM, bN) grid with strided operand
#            indexing (gpu backend, BackendCapabilities.batched): every
#            batch element decomposes in the kernel prologue, so the
#            decomposition side is B x the prologue model (raw fp32
#            stream + scale read; slice intermediates never touch HBM),
#   vmap   — the fallback lifts a batch axis over the 2-D call; on the
#            route it actually takes (the XLA expansion — the fused 2-D
#            kernel cannot carry a vmap axis) every element re-pays the
#            full slice/residue round-trip pipeline, and the stack costs
#            B kernel launches.
#
# The GEMM-side stream (Eq. 10/15 operand + output terms) is identical
# per element on both routes, so the modeled win is launch count (B -> 1)
# plus the decomposition-byte ratio — (8+3p)/8 per operand elem for
# Scheme I (2.1x at p=3, 3.25x at p=6), and for Scheme II the output-side
# int32/canonical round-trips (16p*MN) on top of (8+p)/8 per operand
# elem.  benchmarks/bench_traffic.py gates both ratios per batched cell.
# ---------------------------------------------------------------------------


def _batched_paths(gemm_per_elem: int, fused_decomp: int, vmap_decomp: int,
                   batch: int) -> dict:
    gemm = batch * gemm_per_elem
    return {
        "fused": {"launches": 1,
                  "decomp_bytes": int(fused_decomp),
                  "gemm_bytes": int(gemm),
                  "total_bytes": int(fused_decomp + gemm)},
        "vmap": {"launches": int(batch),
                 "decomp_bytes": int(vmap_decomp),
                 "gemm_bytes": int(gemm),
                 "total_bytes": int(vmap_decomp + gemm)},
    }


def scheme1_batched_bytes(s: GemmShape, p: int, batch: int,
                          out_bytes: int = 4) -> dict:
    """Modeled HBM bytes + launch counts of a B-stack of Scheme-I GEMMs,
    fused strided-batched vs the vmapped 2-D fallback.  Returns
    ``{"fused": {launches, decomp_bytes, gemm_bytes, total_bytes},
    "vmap": {...}}``."""
    elems = (s.m + s.n) * s.k
    return _batched_paths(
        scheme1_fused_bytes(s, p, out_bytes),
        batch * scheme1_decomp_prologue_bytes(elems, p),
        batch * scheme1_decomp_xla_bytes(elems, p),
        batch)


def scheme2_batched_bytes(s: GemmShape, p: int, batch: int,
                          out_bytes: int = 4) -> dict:
    """Scheme-II analogue of :func:`scheme1_batched_bytes` (``p`` counts
    moduli); the vmap route re-pays the residue encode AND the int32 /
    canonical-residue output round-trips per batch element."""
    return _batched_paths(
        p * scheme2_fused_bytes_per_modulus(s) + out_bytes * s.m * s.n,
        batch * scheme2_decomp_prologue_bytes(s, p),
        batch * scheme2_decomp_xla_bytes(s, p),
        batch)


def batched_decomp_reduction(s: GemmShape, p: int, batch: int,
                             scheme: str = "ozaki1") -> float:
    """vmap/fused decomposition-byte ratio of one batched stack."""
    fn = scheme1_batched_bytes if scheme == "ozaki1" else scheme2_batched_bytes
    d = fn(s, p, batch)
    return d["vmap"]["decomp_bytes"] / max(1, d["fused"]["decomp_bytes"])


# ---------------------------------------------------------------------------
# Per-backend hardware peak tables.
#
# The paper's headline numbers are fractions of INT8 Tensor Core peak on
# NVIDIA Hopper (H100) and Blackwell (B200) — up to 83% and 81%
# respectively — so projected-throughput reporting needs those peaks per
# kernel backend. Keys mirror repro.kernels.backends capability
# ``peak_key``s; the 'xla' reference backend projects against whichever
# hardware the TPU table describes (it runs on the same chip).
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class HardwarePeak:
    """Dense (non-sparsity) peaks of one accelerator.

    ``fp64_flops`` is the native FP64 rate the D/ZGEMM baselines run at
    (tensor-core FP64 on NVIDIA; 0 for accelerators without FP64 units,
    which suppresses the baseline-speedup report).
    """
    name: str
    int8_ops: float      # int8 MAC-pair ops/s (Top/s * 1e12)
    flops: float         # dense fp16/bf16 FLOP/s
    hbm_bw: float        # bytes/s
    fp64_flops: float = 0.0


BACKEND_PEAKS: dict[str, dict[str, HardwarePeak]] = {
    "tpu": {
        "v5e": HardwarePeak("TPU v5e", 394e12, 197e12, 819e9),
    },
    "gpu": {
        "h100": HardwarePeak("H100 SXM (Hopper)", 1979e12, 989e12, 3350e9,
                             fp64_flops=67e12),
        "b200": HardwarePeak("B200 (Blackwell)", 4500e12, 2250e12, 8000e9,
                             fp64_flops=40e12),
    },
}
BACKEND_PEAKS["xla"] = BACKEND_PEAKS["tpu"]


def backend_peaks(backend: str) -> dict[str, HardwarePeak]:
    """Peak table for a backend name ('tpu-v5e'-style names resolve by
    family prefix; unknown backends project against the TPU table)."""
    return (BACKEND_PEAKS.get(backend)
            or BACKEND_PEAKS.get(backend.split("-")[0])
            or BACKEND_PEAKS["tpu"])


# ---------------------------------------------------------------------------
# Collective traffic of the shard_map'ed fused GEMM (repro.parallel
# .shard_gemm).  The per-shard kernel keeps its decomposition traffic
# on-chip exactly like the single-device numbers above; what the mesh
# adds is interconnect bytes, and those depend only on the partitioning:
#
#   column (N on 'model')  — collective-free: each shard owns whole
#                            output columns and the full K,
#   row (K on 'model')     — one psum of the (M, N) partial products,
#                            modeled as a ring all-reduce,
#   batch (data axes only) — collective-free for the GEMM itself.
#
# Ring cost convention (the standard bound): an all-reduce moves
# 2(n-1)/n * payload per device, all-gather / reduce-scatter (n-1)/n.
# ---------------------------------------------------------------------------


def ring_all_reduce_bytes(payload_bytes: int, n_dev: int) -> int:
    """Per-device interconnect bytes of a ring all-reduce."""
    if n_dev <= 1:
        return 0
    return int(2 * (n_dev - 1) * payload_bytes // n_dev)


def all_gather_bytes(payload_bytes: int, n_dev: int) -> int:
    """Per-device interconnect bytes of a ring all-gather of a tensor
    whose *global* size is ``payload_bytes``."""
    if n_dev <= 1:
        return 0
    return int((n_dev - 1) * payload_bytes // n_dev)


reduce_scatter_bytes = all_gather_bytes  # same ring volume, one phase


def _mesh_axis_sizes(mesh_shape) -> dict:
    if mesh_shape is None:
        return {}
    if hasattr(mesh_shape, "items"):
        return {str(a): int(sz) for a, sz in mesh_shape.items()}
    return {str(a): int(sz) for a, sz in mesh_shape}


def sharded_gemm_traffic(s: GemmShape, p: int, mesh_shape,
                         partition: str = "column",
                         scheme: str = "ozaki1", out_bytes: int = 4,
                         complex_3m: bool = False) -> dict:
    """Per-shard fused HBM bytes + per-device collective bytes of one
    shard_map'ed emulated (M, K) @ (K, N) on a mesh.

    ``mesh_shape`` is the launch mesh's axis sizes (a mapping or the
    ``((axis, size), ...)`` tuples ``dispatch._mesh_shape_tuple``
    produces); ``partition`` is a :class:`repro.parallel.shard_gemm
    .GemmPartition` kind ('column' | 'row' | 'batch').  The fused bytes
    are the paper's Eq. 10/15/18 models evaluated on the *shard-local*
    shape; collective bytes follow the ring conventions above.
    """
    axes = _mesh_axis_sizes(mesh_shape)
    dp = axes.get("pod", 1) * axes.get("data", 1)
    tp = axes.get("model", 1)
    m_l, n_l, k_l = s.m, s.n, s.k
    if dp > 1 and s.m % dp == 0:
        m_l = s.m // dp
    coll = 0
    if partition == "column":
        if tp > 1 and s.n % tp:
            raise ValueError(f"N={s.n} does not divide model={tp}")
        n_l = s.n // tp if tp > 1 else s.n
    elif partition == "row":
        if tp > 1 and s.k % tp:
            raise ValueError(f"K={s.k} does not divide model={tp}")
        k_l = s.k // tp if tp > 1 else s.k
        n_out = 2 if complex_3m else 1
        coll = ring_all_reduce_bytes(n_out * out_bytes * m_l * n_l, tp)
    elif partition != "batch":
        raise ValueError(f"unknown partition {partition!r}")
    local = GemmShape(m_l, n_l, k_l)
    if scheme == "ozaki1":
        fused = scheme1_fused_bytes(local, p, out_bytes)
        flops = scheme1_flops(local, p)
    elif scheme == "ozaki2":
        per_mod = (scheme2_3m_fused_bytes_per_modulus(local) if complex_3m
                   else scheme2_fused_bytes_per_modulus(local))
        n_out = 2 if complex_3m else 1
        fused = p * per_mod + n_out * out_bytes * local.m * local.n
        flops = scheme2_flops(local, p, complex_3m=complex_3m)
    else:
        raise ValueError(f"no sharded traffic model for scheme {scheme!r}")
    return {
        "partition": partition,
        "shard_m": m_l, "shard_n": n_l, "shard_k": k_l,
        "devices": dp * tp,
        "fused_bytes_per_shard": int(fused),
        "int8_flops_per_shard": int(flops),
        "collective_bytes_per_device": int(coll),
    }


# ---------------------------------------------------------------------------
# Guard verification traffic (docs/robustness.md cost model).
#
# The a posteriori verifier (repro.guard.verify.verify_gemm) checks
# C @ x against A @ (B @ x) for r Rademacher probe vectors — three GEMVs
# (well, skinny (., r) GEMMs) against matrices the guarded GEMM already
# owns.  Two accounting conventions:
#
#   fused    — the probes piggyback on the GEMM's own operand streams
#              (A, B and C are charged to the GEMM, not the verifier);
#              only the probe-sized vectors round-trip:
#                2Nr  x read by B@x and by C@x,
#                2Kr  Bx written + re-read by A@(Bx),
#                 Mr  Cx written once; the compare runs in the A@(Bx)
#                     epilogue, so A(Bx) never leaves chip.
#              total = 4r (M + 2K + 2N) bytes.  This is the model the
#              benchmark gates at <= 5% of the fused GEMM bytes.
#   unfused  — the XLA reference path re-reads everything: B, A and C
#              once per GEMV, plus the row/col abs-reductions of the
#              error normalizer re-reading A and B.  Reported alongside,
#              not gated (it is the price of verifying a kernel you
#              cannot touch).
# ---------------------------------------------------------------------------


def guard_verify_bytes_fused(s: GemmShape, probes: int = 2) -> int:
    return 4 * probes * (s.m + 2 * s.k + 2 * s.n)


def guard_verify_bytes_unfused(s: GemmShape, probes: int = 2,
                               out_bytes: int = 4) -> int:
    gemv_reads = 4 * (s.m * s.k + s.k * s.n) + out_bytes * s.m * s.n
    vectors = 4 * probes * (3 * s.m + 2 * s.k + 2 * s.n)
    normalizer = 4 * (s.m * s.k + s.k * s.n)
    return gemv_reads + vectors + normalizer


def guard_verify_flops(s: GemmShape, probes: int = 2) -> int:
    """MAC-pair ops of the three probe GEMVs (the O(MK + KN) normalizer
    reductions are add-only and amortize across seeds; not counted)."""
    return 2 * probes * (s.k * s.n + s.m * s.k + s.m * s.n)


def guard_overhead_model(s: GemmShape, p: int, scheme: str = "ozaki1",
                         probes: int = 2, out_bytes: int = 4,
                         peak: "HardwarePeak | None" = None) -> dict:
    """Modeled verification overhead of one guarded fused GEMM.

    Roofline convention: GEMM time = max(fused bytes / HBM BW,
    int8 flops / int8 peak); verify time = max(fused verify bytes /
    HBM BW, verify flops / fp peak) — the probes are fp32 math.  The
    returned ``time_ratio`` uses the given ``peak`` (default: TPU v5e,
    the repo's reference part).
    """
    if peak is None:
        peak = BACKEND_PEAKS["tpu"]["v5e"]
    if scheme == "ozaki1":
        gemm_bytes = scheme1_fused_bytes(s, p, out_bytes)
        gemm_flops = scheme1_flops(s, p)
    elif scheme == "ozaki2":
        gemm_bytes = (p * scheme2_fused_bytes_per_modulus(s)
                      + out_bytes * s.m * s.n)
        gemm_flops = scheme2_flops(s, p)
    else:
        raise ValueError(f"no guard overhead model for scheme {scheme!r}")
    v_bytes = guard_verify_bytes_fused(s, probes)
    v_flops = guard_verify_flops(s, probes)
    t_gemm = max(gemm_bytes / peak.hbm_bw, gemm_flops / peak.int8_ops)
    t_verify = max(v_bytes / peak.hbm_bw, v_flops / peak.flops)
    return {
        "gemm_bytes": int(gemm_bytes),
        "gemm_flops": int(gemm_flops),
        "verify_bytes_fused": int(v_bytes),
        "verify_bytes_unfused": int(
            guard_verify_bytes_unfused(s, probes, out_bytes)),
        "verify_flops": int(v_flops),
        "bytes_ratio": v_bytes / max(1, gemm_bytes),
        "time_ratio": t_verify / t_gemm,
    }


def telemetry_counter_bytes(counters: int = 4,
                            labels: int = 6,
                            label_bytes: int = 16) -> int:
    """Device->host payload of one instrumented GEMM's execution-time
    telemetry callbacks: a handful of scalar counter bumps plus their
    (statically captured, but transferred once per flush) label strings.

    ``counters`` scalars at 8 bytes each, ``labels`` key/value pairs at
    ``label_bytes`` each — tens of bytes, NOT proportional to the GEMM.
    """
    return 8 * counters + labels * 2 * label_bytes


def telemetry_overhead_model(s: GemmShape, p: int, scheme: str = "ozaki1",
                             out_bytes: int = 4,
                             peak: "HardwarePeak | None" = None) -> dict:
    """Modeled observability overhead of one instrumented fused GEMM
    (docs/observability.md), mirroring ``guard_overhead_model``.

    The telemetry path adds (a) trace-time registry bumps — host-side,
    zero device cost, not modeled here — and (b) one ``jax.debug
    .callback`` per executed GEMM whose device-side cost is the transfer
    of its payload (``telemetry_counter_bytes``) over HBM/PCIe; the host
    handler runs asynchronously off the critical path.  Roofline
    convention as in the guard model: GEMM time = max(fused bytes /
    HBM BW, int8 flops / int8 peak); telemetry time = payload bytes /
    HBM BW.  The ratios are the ``TELEMETRY_OVERHEAD_CEILING`` gate in
    benchmarks/bench_traffic.py.
    """
    if peak is None:
        peak = BACKEND_PEAKS["tpu"]["v5e"]
    if scheme == "ozaki1":
        gemm_bytes = scheme1_fused_bytes(s, p, out_bytes)
        gemm_flops = scheme1_flops(s, p)
    elif scheme == "ozaki2":
        gemm_bytes = (p * scheme2_fused_bytes_per_modulus(s)
                      + out_bytes * s.m * s.n)
        gemm_flops = scheme2_flops(s, p)
    else:
        raise ValueError(
            f"no telemetry overhead model for scheme {scheme!r}")
    t_bytes = telemetry_counter_bytes()
    t_gemm = max(gemm_bytes / peak.hbm_bw, gemm_flops / peak.int8_ops)
    t_tele = t_bytes / peak.hbm_bw
    return {
        "gemm_bytes": int(gemm_bytes),
        "gemm_flops": int(gemm_flops),
        "telemetry_bytes": int(t_bytes),
        "bytes_ratio": t_bytes / max(1, gemm_bytes),
        "time_ratio": t_tele / t_gemm,
    }


def scheme2_workspace_bytes(s: GemmShape, p: int,
                            complex_inputs: bool = False) -> int:
    """p residue matrices per operand + p per-modulus output residues
    (paper Sec. V-F: Scheme II workspace exceeds Scheme I at matched p)."""
    operand_ws = p * s.k * (s.m + s.n) * (2 if complex_inputs else 1)
    out_res = p * s.m * s.n * (2 if complex_inputs else 1)
    return operand_ws + out_res
