"""Analytical HBM-traffic models from the paper (Eqs. 9, 10, 14, 15, 17, 18).

These drive the benchmarks' derived columns and the roofline memory terms for
the emulated-GEMM cells, and are validated against operand shapes in
tests/test_traffic.py. All results in bytes; ``out_bytes`` is the output
element size (4 = FP32, 8 = FP64).
"""

from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class GemmShape:
    m: int
    n: int
    k: int


def scheme1_naive_bytes(s: GemmShape, p: int, out_bytes: int = 8) -> int:
    """Paper Eq. 9: per-slice-pair kernel launches + INT32 round-trips."""
    operand = p * (p + 1) // 2 * (s.m + s.n) * s.k
    int32_traffic = 4 * p * (p + 1) * s.m * s.n
    return operand + int32_traffic + out_bytes * s.m * s.n


def scheme1_fused_bytes(s: GemmShape, p: int, out_bytes: int = 8) -> int:
    """Paper Eq. 10: each slice loaded once; accumulators never leave chip."""
    return p * (s.m + s.n) * s.k + out_bytes * s.m * s.n


def scheme2_naive_bytes_per_modulus(s: GemmShape) -> int:
    """Paper Eq. 14: INT32 write+read round-trip plus INT8 residue write."""
    return (s.m + s.n) * s.k + 8 * s.m * s.n + s.m * s.n


def scheme2_fused_bytes_per_modulus(s: GemmShape) -> int:
    """Paper Eq. 15: in-epilogue mod reduce — only the INT8 residue leaves."""
    return (s.m + s.n) * s.k + s.m * s.n


def scheme2_3m_naive_bytes_per_modulus(s: GemmShape) -> int:
    """Paper Eq. 17: three INT32 round-trips + two INT8 writes."""
    return 3 * (s.m + s.n) * s.k + 24 * s.m * s.n + 2 * s.m * s.n


def scheme2_3m_fused_bytes_per_modulus(s: GemmShape) -> int:
    """Paper Eq. 18: the 24MN intermediate term vanishes."""
    return 3 * (s.m + s.n) * s.k + 2 * s.m * s.n


def int8_gemm_flops(s: GemmShape) -> int:
    """MAC-pair ops of one int8 GEMM (2MNK)."""
    return 2 * s.m * s.n * s.k


def scheme1_flops(s: GemmShape, p: int) -> int:
    return p * (p + 1) // 2 * int8_gemm_flops(s)


def scheme2_flops(s: GemmShape, p: int, complex_3m: bool = False) -> int:
    mult = 3 if complex_3m else 1
    return mult * p * int8_gemm_flops(s)


def arithmetic_intensity(flops: int, traffic_bytes: int) -> float:
    return flops / max(1, traffic_bytes)


def scheme1_intensity_gain(p: int) -> float:
    """Fused/naive intensity ratio ~ (p+1)/2 for operand-dominated sizes."""
    return (p + 1) / 2


def scheme1_workspace_bytes(s: GemmShape, p: int) -> int:
    """Interleaved Ahat (M, pK) + Bhat (pK, N), int8."""
    return p * s.k * (s.m + s.n)


def scheme2_workspace_bytes(s: GemmShape, p: int,
                            complex_inputs: bool = False) -> int:
    """p residue matrices per operand + p per-modulus output residues
    (paper Sec. V-F: Scheme II workspace exceeds Scheme I at matched p)."""
    operand_ws = p * s.k * (s.m + s.n) * (2 if complex_inputs else 1)
    out_res = p * s.m * s.n * (2 if complex_inputs else 1)
    return operand_ws + out_res
