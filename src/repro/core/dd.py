"""Double-double (compensated) arithmetic for exact CRT evaluation.

A value is represented as an unevaluated sum hi + lo with |lo| <= ulp(hi)/2,
giving ~2x the mantissa bits of the base dtype (106 bits for float64).  Used
by Scheme II to evaluate Garner's mixed-radix polynomial, whose value can be
a ~120-bit integer, and round it faithfully to the output precision.

No FMA is assumed (CPU interpret / portable): two_prod uses Dekker/Veltkamp
splitting, which is exact in IEEE arithmetic.
"""

from __future__ import annotations

import jax.numpy as jnp


def _split_constant(dtype) -> float:
    # Veltkamp split constant 2^ceil(t/2) + 1 where t = mantissa bits.
    nmant = jnp.finfo(dtype).nmant  # 52 for f64, 23 for f32
    return float(2 ** ((nmant + 2) // 2) + 1)


def two_sum(a, b):
    """Exact: a + b = s + e."""
    s = a + b
    bb = s - a
    e = (a - (s - bb)) + (b - bb)
    return s, e


def quick_two_sum(a, b):
    """Exact when |a| >= |b|: a + b = s + e."""
    s = a + b
    e = b - (s - a)
    return s, e


def _veltkamp(a):
    c = _split_constant(a.dtype) * a
    hi = c - (c - a)
    lo = a - hi
    return hi, lo


def two_prod(a, b):
    """Exact: a * b = p + e (Dekker, FMA-free)."""
    p = a * b
    ah, al = _veltkamp(a)
    bh, bl = _veltkamp(b)
    e = ((ah * bh - p) + ah * bl + al * bh) + al * bl
    return p, e


def mul_scalar(hi, lo, c: float):
    """(hi, lo) * c  for a dtype-exact scalar c (e.g. small moduli)."""
    c = jnp.asarray(c, dtype=hi.dtype)
    p1, p2 = two_prod(hi, c)
    p2 = p2 + lo * c
    return quick_two_sum(p1, p2)


def add_scalar_array(hi, lo, x):
    """(hi, lo) + x  for an array of dtype-exact values (digits < 256)."""
    s, e = two_sum(hi, x)
    e = e + lo
    return quick_two_sum(s, e)


def add2(hi1, lo1, hi2, lo2):
    """(hi1, lo1) + (hi2, lo2), sloppy (single-branch) dd addition."""
    s, e = two_sum(hi1, hi2)
    e = e + lo1 + lo2
    return quick_two_sum(s, e)


def split_const(_: float, exact_int: int):
    """Represent a (possibly >53-bit) python integer as a dd constant."""
    hi = float(exact_int)
    lo = float(exact_int - int(hi))
    return hi, lo
