"""EmuGEMM core: Ozaki Scheme I/II precision-emulated GEMM in JAX."""

from repro.core.precision import (  # noqa: F401
    DEFAULT_MODULI,
    EmulationConfig,
    NATIVE,
    default_moduli,
    plan_precision,
    safe_beta,
    scheme2_budget,
)
from repro.core.emulated import emulated_dot  # noqa: F401
