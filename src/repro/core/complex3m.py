"""Scheme-II complex GEMM via the 3M identity (paper Sec. IV-B).

T1 = Ar'Br', T2 = Ai'Bi', T3 = (Ar'+Ai')(Br'+Bi')   (all mod m_l)
C_re = T1 - T2 ; C_im = T3 - T1 - T2.

In *modular integer* arithmetic every operation is exact, so the 3M
cancellation problem of floating point does not exist — 3M is strictly
preferable, 25% fewer GEMMs than 4M at zero accuracy cost.

The sum residues (Ar'+Ai') are re-reduced (balanced) before the GEMM so the
int8 operand range is preserved.  Exactness needs the slightly tighter bound
2 * K * 2^ba * 2^bb * 2 < P (C_im sums two product matrices), handled by
``scheme2_budget(..., complex_guard=True)``.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.precision import EmulationConfig, scheme2_budget
from repro.core import scheme2


def _balanced(x_int32: jax.Array, m: int) -> jax.Array:
    half = m // 2
    return (jnp.remainder(x_int32 + half, m) - half).astype(jnp.int8)


def matmul(a: jax.Array, b: jax.Array, cfg: EmulationConfig,
           out_dtype=None) -> jax.Array:
    """Emulated complex GEMM via Scheme II + 3M (XLA reference path)."""
    if out_dtype is None:
        out_dtype = jnp.float64 if a.dtype == jnp.complex128 else jnp.float32
    moduli = cfg.resolved_moduli()
    k_dim = a.shape[-1]
    scheme2.check_exact_k(k_dim, moduli)
    budget = scheme2_budget(moduli, k_dim, complex_guard=True)
    real_t = jnp.real(a).dtype
    mant = jnp.finfo(real_t).nmant + 1
    budget = min(budget, mant)

    ar, ai = jnp.real(a), jnp.imag(a)
    br, bi = jnp.real(b), jnp.imag(b)
    # One power-of-two scale per row/col shared by re/im parts.
    mu = scheme2._pow2_int_scale(jnp.maximum(jnp.abs(ar), jnp.abs(ai)),
                                 axis=1, budget_bits=budget)
    nu = scheme2._pow2_int_scale(jnp.maximum(jnp.abs(br), jnp.abs(bi)),
                                 axis=0, budget_bits=budget)
    ar_i, ai_i = jnp.trunc(ar * mu), jnp.trunc(ai * mu)
    br_i, bi_i = jnp.trunc(br * nu), jnp.trunc(bi * nu)

    ar_res = scheme2.balanced_residues(ar_i, moduli)   # (p, M, K) int8
    ai_res = scheme2.balanced_residues(ai_i, moduli)
    br_res = scheme2.balanced_residues(br_i, moduli)
    bi_res = scheme2.balanced_residues(bi_i, moduli)

    c_re_res, c_im_res = [], []
    for l, m in enumerate(moduli):
        # 3M operand sums, re-balanced into int8 range after mod m.
        as_res = _balanced(ar_res[l].astype(jnp.int32)
                           + ai_res[l].astype(jnp.int32), m)
        bs_res = _balanced(br_res[l].astype(jnp.int32)
                           + bi_res[l].astype(jnp.int32), m)
        t1 = scheme2._int8_dot(ar_res[l], br_res[l])
        t2 = scheme2._int8_dot(ai_res[l], bi_res[l])
        t3 = scheme2._int8_dot(as_res, bs_res)
        # Exact modular combination (the fused kernel does this in-epilogue).
        t1m = jnp.remainder(t1, m)
        t2m = jnp.remainder(t2, m)
        t3m = jnp.remainder(t3, m)
        c_re_res.append(jnp.remainder(t1m - t2m, m).astype(jnp.int32))
        c_im_res.append(jnp.remainder(t3m - t1m - t2m, m).astype(jnp.int32))

    c_re = scheme2.crt_reconstruct(jnp.stack(c_re_res), moduli, out_dtype)
    c_im = scheme2.crt_reconstruct(jnp.stack(c_im_res), moduli, out_dtype)
    inv = 1.0 / (mu.astype(out_dtype) * nu.astype(out_dtype))
    return jax.lax.complex(c_re * inv, c_im * inv)


def gemm_count(cfg: EmulationConfig) -> int:
    """3M: 3 GEMMs per modulus (vs 4 for 4M)."""
    return 3 * cfg.p


def fused_matmul(a: jax.Array, b: jax.Array, cfg: EmulationConfig,
                 out_dtype=None) -> jax.Array:
    """Complex Scheme-II GEMM on the fused 3M kernel, via the dispatcher
    (cached block selection; non-aligned shapes are padded, not refused)."""
    import dataclasses
    from repro.kernels import dispatch  # lazy: keep the XLA path pallas-free
    if cfg.scheme != "ozaki2":
        cfg = dataclasses.replace(cfg, scheme="ozaki2")
    return dispatch.emulated_matmul(a, b, cfg=cfg, out_dtype=out_dtype)
