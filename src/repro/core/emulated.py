"""Public emulated-GEMM API: a drop-in for jnp.dot / lax.dot_general.

``emulated_dot(a, b, cfg)`` computes a @ b with the precision emulation
selected by ``cfg`` (repro.core.precision.EmulationConfig):

  * scheme='native'  — plain dot in the input dtype (baseline),
  * scheme='ozaki1'  — mantissa-slice emulation (paper Sec. III),
  * scheme='ozaki2'  — CRT modular emulation (paper Sec. IV),

with impl='xla' (reference, always available) or impl='pallas' (the fused
TPU kernels, validated in interpret mode on CPU). 'auto' uses pallas for
2-D tile-aligned problems, else xla.

The custom VJP re-expresses dA = dC @ B^T and dB = A^T @ dC through the same
emulated GEMM, so models can *train* entirely on the int8 emulated path —
this is what makes the paper's kernel a first-class framework feature rather
than a standalone library call.

With ``cfg.cache_weights`` the VJP decomposes the rhs *once per step*: the
forward prepares B (and its K-transposed twin, see
repro.kernels.prepared) in a single fp32 read, the backward dA consumes
the twin's finished slices instead of re-splitting B^T — killing the
3x-per-layer-per-step decomposition round-trips of the naive pipeline
(forward, remat re-forward, backward each re-splitting the same weight).

Leading batch dimensions of ``a`` are flattened into M (the usual
activations @ weights pattern).
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro import telemetry
from repro.core import complex3m, scheme1, scheme2
from repro.core.precision import EmulationConfig, NATIVE


def _is_complex(x) -> bool:
    return jnp.issubdtype(x.dtype, jnp.complexfloating)


def prepared_dot(x: jax.Array, w, out_dtype=None) -> jax.Array:
    """x: (..., K) @ a PreparedOperand w: (K, N) -> (..., N).

    The once-per-session serving path (no VJP: serving never
    differentiates, and the int8 slices carry no gradient).
    """
    from repro.kernels import prepared  # lazy: pallas import
    if out_dtype is None:
        out_dtype = jnp.promote_types(x.dtype, jnp.float32)
    lead = x.shape[:-1]
    out = prepared.matmul_prepared(x.reshape(-1, x.shape[-1]), w,
                                   out_dtype=out_dtype)
    return out.reshape(*lead, w.n)


def _cacheable(a, b, cfg: EmulationConfig) -> bool:
    # Complex problems route through the 4M/3M expansions, not the
    # real-only prepared paths (a silent cast would drop the imaginary
    # part).  Scheme I caches int8 slices, Scheme II balanced residues.
    return (cfg.scheme in ("ozaki1", "ozaki2") and cfg.cache_weights
            and getattr(b, "ndim", 0) == 2
            and not _is_complex(a) and not _is_complex(b))


def _dot_2d(a: jax.Array, b: jax.Array, cfg: EmulationConfig) -> jax.Array:
    """Dispatch a single (M, K) @ (K, N) according to cfg."""
    if (cfg.guard is not None and cfg.scheme != "native"
            and not _is_complex(a) and not _is_complex(b)):
        # Guard seam for the dot_general/einsum/dense front doors and
        # both VJP backward GEMMs: the ladder re-enters _dot_2d with the
        # guard stripped for every rung (repro.guard.ladder).
        from repro import guard  # lazy: optional subsystem
        return guard.guarded_dot_2d(a, b, cfg)
    out_dtype = cfg.out_dtype or jnp.promote_types(a.dtype, b.dtype)
    if cfg.scheme == "native":
        return jax.lax.dot_general(
            a, b, (((1,), (0,)), ((), ())),
            preferred_element_type=out_dtype)
    if cfg.impl in ("auto", "pallas"):
        from repro.kernels import dispatch  # lazy: pallas import
        out = dispatch.auto_fused_matmul(a, b, cfg)
        if out is not None:
            return out
        if cfg.impl == "pallas":
            # Explicit fused request: the dispatcher pads non-aligned
            # operands to the nearest 128 tile and slices the result.
            return dispatch.emulated_matmul(a, b, cfg=cfg,
                                            out_dtype=out_dtype)
    cplx = _is_complex(a) or _is_complex(b)
    if cfg.scheme == "ozaki1":
        scheme_tag, count = ("ozaki1-4m" if cplx else "ozaki1"), cfg.p
    elif cfg.scheme == "ozaki2":
        scheme_tag = "ozaki2-3m" if cplx else "ozaki2"
        count = len(cfg.resolved_moduli())
    else:
        raise ValueError(f"unknown scheme {cfg.scheme!r}")
    _record_xla_dot(scheme_tag, count, a, b)
    with telemetry.gemm_scope(scheme_tag, count, "xla", "xla"):
        if cfg.scheme == "ozaki1":
            if cplx:
                return scheme1.matmul_complex_4m(a, b, cfg, out_dtype=None)
            return scheme1.matmul(a, b, cfg, out_dtype=out_dtype)
        if cplx:
            return complex3m.matmul(a, b, cfg, out_dtype=None)
        return scheme2.matmul(a, b, cfg, out_dtype=out_dtype)


def _record_xla_dot(scheme_tag: str, count: int, a, b) -> None:
    if not telemetry.enabled():
        return
    telemetry.record_gemm(scheme=scheme_tag, count=count, backend="xla",
                          impl="xla", m=a.shape[0], k=a.shape[1],
                          n=b.shape[1])


# The telemetry call-site rides along as a static (nondiff) argument:
# JAX re-traces custom-VJP rules at partial-eval/transpose time (grad,
# jax.checkpoint) after the originating ``call_site`` block has exited,
# so the ambient thread-local label is gone by then.  Capturing it once
# in the public wrapper and re-entering it inside every rule keeps the
# per-site execution counters correct under grad and remat.
@partial(jax.custom_vjp, nondiff_argnums=(2, 3))
def _emulated_dot(a: jax.Array, b: jax.Array, cfg: EmulationConfig,
                  site: str) -> jax.Array:
    lead = a.shape[:-1]
    a2 = a.reshape(-1, a.shape[-1])
    with telemetry.site_scope(site):
        out = _dot_2d(a2, b, cfg)
    return out.reshape(*lead, b.shape[-1])


def _fwd(a, b, cfg, site):
    # Guarded calls skip the prepared shortcut: the escalation ladder
    # may re-plan the slice count, which a stack prepared up front would
    # pin (verification itself handles prepared rhs via reconstruct()).
    if _cacheable(a, b, cfg) and cfg.guard is None:
        # Decompose the rhs once: forward layout + K-transposed twin.
        from repro.kernels import prepared  # lazy: pallas import
        prep = prepared.prepare_rhs(b, cfg, with_twin=True)
        out_dtype = cfg.out_dtype or jnp.promote_types(a.dtype, b.dtype)
        with telemetry.site_scope(site):
            out = prepared_dot(a, prep, out_dtype)
        return out, (a, b, prep.twin)
    return _emulated_dot(a, b, cfg, site), (a, b, None)


def _bwd_core(cfg, a, b, twin, g):
    """Shared backward: dA = dC B^T (from the twin's finished slices when
    one exists — no re-split), dB = A^T dC, both through the same
    emulated path (exact-int interior), optionally at reduced slice count
    (mixed-precision emulated training — gradients tolerate fewer
    mantissa bits).  Used by both the per-call cache (``emulated_dot``)
    and the pre-prepared once-per-step path (``emulated_dot_prepared``).
    """
    a2 = a.reshape(-1, a.shape[-1])
    g2 = g.reshape(-1, g.shape[-1])
    if cfg.bwd_p and cfg.bwd_p != cfg.p:
        import dataclasses
        cfg = dataclasses.replace(cfg, p=cfg.bwd_p)
    if twin is not None:
        # Same accumulation dtype as the uncached _dot_2d branch.
        da_dtype = cfg.out_dtype or jnp.promote_types(g2.dtype, b.dtype)
        da = prepared_dot(g2, twin, da_dtype).reshape(a.shape) \
            .astype(a.dtype)
    else:
        da = _dot_2d(g2, b.T, cfg).reshape(a.shape).astype(a.dtype)
    db = _dot_2d(a2.T, g2, cfg).astype(b.dtype)
    return da, db


def _bwd(cfg, site, res, g):
    a, b, twin = res
    with telemetry.site_scope(site):
        return _bwd_core(cfg, a, b, twin, g)


_emulated_dot.defvjp(_fwd, _bwd)


def emulated_dot(a: jax.Array, b: jax.Array,
                 cfg: EmulationConfig = NATIVE) -> jax.Array:
    """a: (..., K) float; b: (K, N) float -> (..., N)."""
    return _emulated_dot(a, b, cfg, telemetry.current_site())


# ---------------------------------------------------------------------------
# Strided-batched contractions: one fused launch over the whole stack.
# ---------------------------------------------------------------------------

@partial(jax.custom_vjp, nondiff_argnums=(2, 3))
def _emulated_dot_batched(a: jax.Array, b: jax.Array, cfg: EmulationConfig,
                          site: str) -> jax.Array:
    from repro.kernels import dispatch  # lazy: pallas import
    with telemetry.site_scope(site):
        return dispatch.emulated_matmul_batched(a, b, cfg=cfg)


def _fwd_batched(a, b, cfg, site):
    return _emulated_dot_batched(a, b, cfg, site), (a, b)


def _bwd_batched(cfg, site, res, g):
    # dA = dC @ B^T and dB = A^T @ dC per batch element, each again ONE
    # strided-batched emulated launch (swapaxes is a strided view, not a
    # re-decomposition), optionally at the reduced backward slice count.
    from repro.kernels import dispatch  # lazy: pallas import
    a, b = res
    if cfg.bwd_p and cfg.bwd_p != cfg.p:
        import dataclasses
        cfg = dataclasses.replace(cfg, p=cfg.bwd_p)
    with telemetry.site_scope(site):
        da = dispatch.emulated_matmul_batched(
            g, jnp.swapaxes(b, -1, -2), cfg=cfg).astype(a.dtype)
        db = dispatch.emulated_matmul_batched(
            jnp.swapaxes(a, -1, -2), g, cfg=cfg).astype(b.dtype)
    return da, db


_emulated_dot_batched.defvjp(_fwd_batched, _bwd_batched)


def emulated_dot_batched(a: jax.Array, b: jax.Array,
                         cfg: EmulationConfig = NATIVE) -> jax.Array:
    """a: (..., B, M, K) @ b: (..., B, K, N), matching leading axes ->
    (..., B, M, N) as ONE strided-batched fused launch where the selected
    backend advertises ``BackendCapabilities.batched`` (the dispatcher
    vmaps the 2-D kernel elsewhere).  Differentiable: both backward
    GEMMs re-enter the batched emulated path.
    """
    return _emulated_dot_batched(a, b, cfg, telemetry.current_site())


# ---------------------------------------------------------------------------
# Pre-prepared weights: the once-per-step hoist under gradient accumulation.
# ---------------------------------------------------------------------------

def _zero_cotangent(tree):
    """Structure-matching zero cotangents for a pytree of arrays.

    Integer leaves (the int8 slices) take float0 per the custom_vjp
    contract; float leaves (the power-of-two scales) take zeros."""
    import numpy as np
    from jax import dtypes

    def z(x):
        if jnp.issubdtype(x.dtype, jnp.inexact):
            return jnp.zeros_like(x)
        return np.zeros(jnp.shape(x), dtypes.float0)

    return jax.tree.map(z, tree)


@partial(jax.custom_vjp, nondiff_argnums=(3, 4))
def _emulated_dot_prepared(a: jax.Array, b: jax.Array, prep,
                           cfg: EmulationConfig, site: str) -> jax.Array:
    out_dtype = cfg.out_dtype or jnp.promote_types(a.dtype, b.dtype)
    with telemetry.site_scope(site):
        return prepared_dot(a, prep, out_dtype)


def _fwd_prepared(a, b, prep, cfg, site):
    out_dtype = cfg.out_dtype or jnp.promote_types(a.dtype, b.dtype)
    with telemetry.site_scope(site):
        out = prepared_dot(a, prep, out_dtype)
    return out, (a, b, prep)


def _bwd_prepared(cfg, site, res, g):
    a, b, prep = res
    with telemetry.site_scope(site):
        da, db = _bwd_core(cfg, a, b, prep.twin, g)
    return da, db, _zero_cotangent(prep)


_emulated_dot_prepared.defvjp(_fwd_prepared, _bwd_prepared)


def emulated_dot_prepared(a: jax.Array, b: jax.Array, prep,
                          cfg: EmulationConfig) -> jax.Array:
    """a: (..., K) @ b: (K, N) where ``prep`` is b's already-built
    PreparedOperand (with K-transposed twin).

    The microbatch-scan consumption path (see ``launch/steps.py``): the
    prep was constructed *outside* the scan, once per optimizer step, so
    the forward streams finished slices, the backward dA consumes the
    twin, and dB still flows to the float weight ``b`` — semantically
    ``emulated_dot`` with ``cfg.cache_weights``, minus the per-microbatch
    re-preparation.
    """
    return _emulated_dot_prepared(a, b, prep, cfg, telemetry.current_site())


def emulated_einsum_proj(x: jax.Array, w: jax.Array,
                         cfg: EmulationConfig = NATIVE) -> jax.Array:
    """Convenience for '...k,kn->...n' projections used by the model zoo."""
    return emulated_dot(x, w, cfg)
