"""Ozaki Scheme II: CRT modular-arithmetic emulated GEMM.

Pipeline (paper Sec. II-C2):
  1. scale operands to integers A' = trunc(diag(mu) A) (power-of-two mu),
  2. residues A'_l = A' mod m_l for p pairwise-coprime moduli m_l <= 256,
  3. one exact int8 GEMM per modulus: ~C_l = A'_l B'_l (int32),
  4. modular reduction C'_l = ~C_l mod m_l  (the paper fuses this into the
     GEMM epilogue — here the XLA reference; Pallas kernel in kernels/ozaki2),
  5. CRT reconstruction of C' = A'B' and inverse scaling.

TPU adaptation (DESIGN.md Sec. 2): residues are stored in *balanced* form
r_bal = ((r + m//2) mod m) - m//2 in [-128, 127] so they fit the signed-int8
MXU path (TPU has no unsigned-int8 matmul). Congruence mod m is preserved, so
the CRT is unchanged; |r_bal| <= 128 keeps K <= (2^31 - 1) / 2^14 = 131071
exact (``check_exact_k`` enforces the bound on every pipeline).

CRT reconstruction uses Garner's mixed-radix algorithm: digits d_i < m_i are
computed in exact int32 arithmetic (O(p^2) elementwise ops), then the
mixed-radix polynomial x = d_1 + m_1 (d_2 + m_2 (...)) is evaluated in
double-double (~106 mantissa bits) — enough to round a <=120-bit integer to
FP64 — replacing the paper's multi-word-integer CRT kernel.
"""

from __future__ import annotations

import math
from functools import lru_cache

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.precision import (EmulationAccuracyError, EmulationConfig,
                                  scheme2_budget)
from repro.core import dd
from repro.core.scheme1 import exact_pow2


def _pow2_int_scale(a: jax.Array, axis: int, budget_bits: int) -> jax.Array:
    """Power-of-two mu per row/col s.t. |trunc(mu * a)| < 2^budget_bits.

    mu * amax in [2^(budget-1), 2^budget).  The exponent is built exactly
    (see :func:`repro.core.scheme1.exact_pow2` — jnp.exp2 is inexact at
    large exponents) and clamped below the dtype's overflow point:
    subnormal-only rows, whose exact mu (up to 2^(budget + 149) in fp32)
    is unrepresentable, get the largest finite power-of-two scale and
    integerize to exact zeros — a documented graceful flush, where the
    old exp2 path produced an inf scale and int-wraparound garbage.
    """
    amax = jnp.max(jnp.abs(a), axis=axis, keepdims=True)
    _, exp = jnp.frexp(jnp.where(amax == 0, 1.0, amax))
    info = jnp.finfo(a.dtype)
    e = jnp.minimum(budget_bits - exp, info.maxexp - 1)
    return exact_pow2(e, a.dtype)


def integerize(a: jax.Array, axis: int, budget_bits: int):
    """A' = trunc(diag(mu) A). Returns (a_int (float, exact integer), mu)."""
    mu = _pow2_int_scale(a, axis, budget_bits)
    return jnp.trunc(a * mu), mu


def balanced_residues(a_int: jax.Array, moduli) -> jax.Array:
    """Residues of an exact-integer float array, balanced to [-m//2, ...].

    Returns (p, *a.shape) int8. Works on float inputs holding exact integers
    up to 2^52 (float64) / 2^23 (float32) by reducing via float remainder,
    which is exact for power-of-2-scaled integers within the mantissa.

    Moduli must be <= 256: the balanced form is the int8 representation
    every pipeline here (XLA reference, Mosaic and GPU kernels) carries,
    and a wider modulus would silently wrap in the cast.
    """
    oversized = [int(m) for m in moduli if int(m) > 256]
    if oversized:
        raise ValueError(
            f"moduli {oversized} exceed 256: balanced residues must fit "
            "int8 (DESIGN.md Sec. 2) — no backend lowers wider moduli")
    outs = []
    # Use the widest available int type for the exact mod.
    use_i64 = jax.config.jax_enable_x64 and a_int.dtype == jnp.float64
    int_t = jnp.int64 if use_i64 else jnp.int32
    ai = a_int.astype(int_t)
    for m in moduli:
        half = m // 2
        r = jnp.remainder(ai + half, m) - half  # balanced, in [-half, m-1-half]
        outs.append(r.astype(jnp.int8))
    res = jnp.stack(outs)
    # Lazy: the guard subsystem is optional on this hot path.
    from repro.guard.inject import maybe_corrupt_residues
    return maybe_corrupt_residues(res)


def check_exact_k(k_dim: int, moduli) -> None:
    """Refuse contraction lengths whose int32 residue accumulation could
    wrap: a K-long dot of balanced residues is bounded by
    K * (max m // 2)^2, which must stay below 2^31 (module doc: K <=
    131071 at m = 256).  Applies to every Scheme-II pipeline — the XLA
    reference, the Mosaic kernels and the fused GPU lowering share the
    same int32 accumulators."""
    half = max(int(m) for m in moduli) // 2
    if k_dim * half * half >= 2 ** 31:
        # >=: int32 tops out at 2^31 - 1, and the all-(-half)^2 worst
        # case reaches exactly K * half^2.
        k_max = (2 ** 31 - 1) // (half * half)
        raise EmulationAccuracyError(
            f"Scheme II: K={k_dim} can overflow the int32 residue "
            f"accumulators (bound K * {half}^2 < 2^31, i.e. K <= "
            f"{k_max} for these moduli). Remediation: re-plan with a "
            f"'bits=<N>:k{k_dim}' spec so plan_precision budgets the "
            "moduli for this contraction length, or shard the "
            "contraction (repro.dot_general with a K-sharded mesh "
            f"splits K across devices) so each shard stays <= {k_max}.")


def _int8_dot(a8: jax.Array, b8: jax.Array) -> jax.Array:
    return jax.lax.dot_general(
        a8, b8, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.int32)


def residue_gemms(a_res: jax.Array, b_res: jax.Array) -> jax.Array:
    """Paper Eq. 6: ~C_l = A'_l B'_l, one exact int8 GEMM per modulus."""
    return jax.vmap(_int8_dot)(a_res, b_res)


def modular_reduce(acc: jax.Array, moduli) -> jax.Array:
    """Paper Eq. 7: C'_l = ~C_l mod m_l, elementwise, into [0, m_l)."""
    outs = []
    for l, m in enumerate(moduli):
        outs.append(jnp.remainder(acc[l], m).astype(jnp.int32))
    return jnp.stack(outs)


@lru_cache(maxsize=None)
def garner_constants(moduli: tuple[int, ...]):
    """inv[m_j mod m_i] table for Garner's algorithm (python ints)."""
    p = len(moduli)
    inv = np.zeros((p, p), dtype=np.int32)
    for i in range(p):
        for j in range(i):
            inv[i, j] = pow(moduli[j], -1, moduli[i])
    return inv


def garner_digits(residues: jax.Array, moduli) -> list[jax.Array]:
    """*Balanced* mixed-radix digits d_i in [-m_i/2, m_i/2] with
    x = d_0 + m_0 (d_1 + m_1 (d_2 + ...)), all exact int32 arithmetic.
    ``residues``: (p, M, N) int32 in [0, m_l).

    Balanced digits make the mixed-radix value itself the *centered*
    representative in (-P/2, P/2]: a small |x| has (near-)zero high digits,
    so the downstream double-double Horner evaluation never sees magnitudes
    near P and needs no final mod-P subtraction — the classic catastrophic
    cancellation of 'evaluate then subtract P' disappears. This is the TPU
    (no int128) analogue of the paper's multi-word CRT kernel.
    """
    moduli = tuple(int(m) for m in moduli)
    inv = garner_constants(moduli)
    p = len(moduli)
    digits: list[jax.Array] = []
    for i in range(p):
        t = residues[i]
        for j in range(i):
            # t = (t - d_j) * inv(m_j, m_i) mod m_i; digits are balanced
            # (|d_j| <= 128) so |t - d_j| * inv < 2^17 — exact in int32.
            t = jnp.remainder((t - digits[j]) * int(inv[i, j]), moduli[i])
        half = moduli[i] // 2
        digits.append(jnp.where(t > half, t - moduli[i], t))
    return digits


def mixed_radix_to_dd(digits: list[jax.Array], moduli) -> tuple[jax.Array, jax.Array]:
    """Evaluate the balanced mixed-radix polynomial in double-double (Horner).

    With balanced digits the intermediate Horner values stay at the magnitude
    of the final (centered) result, so ~2x-mantissa double-double precision is
    what bounds the evaluation error — not log2(P).
    """
    p = len(digits)
    hi = digits[p - 1].astype(jnp.float64 if jax.config.jax_enable_x64
                              else jnp.float32)
    lo = jnp.zeros_like(hi)
    for i in range(p - 2, -1, -1):
        hi, lo = dd.mul_scalar(hi, lo, float(moduli[i]))
        hi, lo = dd.add_scalar_array(hi, lo, digits[i].astype(hi.dtype))
    return hi, lo


def crt_reconstruct(residues: jax.Array, moduli, out_dtype) -> jax.Array:
    """Signed CRT via balanced Garner digits: returns the centered
    representative in (-P/2, P/2] as ``out_dtype``.

    Exact provided 2 sum_h |a'_ih||b'_hj| < P (paper Eq. 8 condition).
    """
    moduli = tuple(int(m) for m in moduli)
    digits = garner_digits(residues, moduli)
    hi, lo = mixed_radix_to_dd(digits, moduli)
    return (hi.astype(out_dtype) + lo.astype(out_dtype)).astype(out_dtype)


def matmul(a: jax.Array, b: jax.Array, cfg: EmulationConfig,
           out_dtype=None) -> jax.Array:
    """Emulated real GEMM via Scheme II (XLA reference path)."""
    if out_dtype is None:
        out_dtype = jnp.promote_types(a.dtype, b.dtype)
    moduli = cfg.resolved_moduli()
    k_dim = a.shape[-1]
    check_exact_k(k_dim, moduli)
    budget = scheme2_budget(moduli, k_dim)
    # Operand mantissa limits the useful budget (fp32 in -> 24 bits).
    mant = jnp.finfo(a.dtype).nmant + 1
    budget = min(budget, mant)
    a_int, mu = integerize(a, axis=1, budget_bits=budget)
    b_int, nu = integerize(b, axis=0, budget_bits=budget)
    a_res = balanced_residues(a_int, moduli)
    b_res = balanced_residues(b_int, moduli)
    acc = residue_gemms(a_res, b_res)          # (p, M, N) int32, balanced
    c_res = modular_reduce(acc, moduli)        # [0, m_l)
    c_int = crt_reconstruct(c_res, moduli, out_dtype)
    return c_int / (mu.astype(out_dtype) * nu.astype(out_dtype))


def effective_bits(moduli, k_dim: int) -> int:
    return scheme2_budget(moduli, k_dim)


def fused_matmul(a: jax.Array, b: jax.Array, cfg: EmulationConfig,
                 out_dtype=None) -> jax.Array:
    """Scheme-II GEMM on the fused EmuGEMM-II kernel, via the dispatcher
    (cached block selection; non-aligned shapes are padded, not refused)."""
    import dataclasses
    from repro.kernels import dispatch  # lazy: keep the XLA path pallas-free
    if cfg.scheme != "ozaki2":
        cfg = dataclasses.replace(cfg, scheme="ozaki2")
    return dispatch.emulated_matmul(a, b, cfg=cfg, out_dtype=out_dtype)
