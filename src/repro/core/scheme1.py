"""Ozaki Scheme I: mantissa-slice decomposition for emulated GEMM.

Decomposition (paper Eq. 1):  A ~= diag(mu) * sum_i 2^{-beta(i+1)} A'_i with
A'_i signed int8 slices extracted by iterated truncation; B analogously along
columns.  The p(p+1)/2 exact int8 GEMMs are grouped by positional weight
s = i + j into p int32 accumulators (Eq. 2) and merged by the shift-reduce
(Eq. 3).

This module is the *algorithmic* layer: slicing, interleaved layout
(paper Eq. 11), reference (XLA) triangular contraction and reconstruction.
The fused Pallas kernel lives in repro.kernels.ozaki1.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.precision import EmulationConfig, safe_beta


def exact_pow2(exp: jax.Array, dtype) -> jax.Array:
    """Exact power-of-two array ``2.0 ** exp`` in ``dtype``.

    ``jnp.exp2`` is a polynomial kernel: eagerly it lands a few ulp off
    at large |exp| (exp2(120) != 2^120 in fp32) and flushes subnormal
    results to zero (exp2(-130) == 0), so power-of-two *scales* built
    through it silently stop being powers of two exactly where the
    dynamic range gets interesting.  Building the exponent field
    directly is exact for every representable exponent: values below
    the normal range clamp to the smallest *normal* power (keeping the
    scale nonzero and exactly invertible), values above it saturate to
    +inf (the IEEE all-ones exponent), mirroring what 2^exp would
    overflow to.
    """
    dtype = jnp.dtype(dtype)
    info = jnp.finfo(dtype)
    bias = info.maxexp - 1
    uint = {2: jnp.uint16, 4: jnp.uint32, 8: jnp.uint64}[dtype.itemsize]
    e = jnp.clip(exp, info.minexp, info.maxexp)
    bits = (e + bias).astype(uint) << info.nmant
    return jax.lax.bitcast_convert_type(bits, dtype)


def _pow2_row_scale(a: jax.Array, axis: int) -> jax.Array:
    """Power-of-two scale mu with |a / mu| in [0, 1) along ``axis``.

    mu = 2^e where frexp(max|a|) = (m, e), m in [0.5, 1).  Rows that are
    all zero get mu = 1.  The exponent is clamped at the dtype's smallest
    *normal* power, so subnormal-only rows get a finite normal mu (the
    quotient |a / mu| < 1 still holds, and the division stays exact) —
    with exp2 such rows rounded the scale to zero and the whole row
    divided out to inf.
    """
    amax = jnp.max(jnp.abs(a), axis=axis, keepdims=True)
    _, exp = jnp.frexp(jnp.where(amax == 0, 1.0, amax))
    return exact_pow2(exp, a.dtype)


def split(a: jax.Array, p: int, beta: int, axis: int):
    """Split ``a`` into p signed int8 slices of beta bits each.

    Returns (slices, scale): slices has shape (p, *a.shape) int8; ``scale``
    is the power-of-two row/col scale (broadcastable against ``a``) such that

        a ~= scale * sum_i 2^{-beta (i+1)} slices[i]

    with residual < scale * 2^{-beta p} elementwise. The iterated
    truncate-and-subtract is exact in floating point (each step removes the
    integer part after an exact power-of-two shift).
    """
    if not jnp.issubdtype(a.dtype, jnp.floating):
        a = a.astype(jnp.float32)
    scale = _pow2_row_scale(a, axis)
    r = a / scale  # exact: power-of-two division
    two_beta = float(2 ** beta)
    slices = []
    for _ in range(p):
        shifted = r * two_beta          # exact
        s = jnp.trunc(shifted)          # |s| <= 2^beta - 1  (beta <= 7)
        slices.append(s.astype(jnp.int8))
        r = shifted - s                 # exact (fractional part)
    stacked = jnp.stack(slices)
    # Lazy: the guard subsystem is optional on this hot path.
    from repro.guard.inject import maybe_corrupt_slices
    return maybe_corrupt_slices(stacked), scale


def interleave_k(slices: jax.Array, operand: str, t_k: int) -> jax.Array:
    """Paper Eq. 11: interleave p slices along K at ``t_k`` granularity.

    For operand 'a' (slices: (p, M, K)) returns (M, p*K) with column groups
    cycling A'_0 | A'_1 | ... | A'_{p-1} per K-chunk.  For operand 'b'
    (slices: (p, K, N)) returns (p*K, N) analogously along rows.

    The layout is what lets the fused kernel fetch *all* p slices of a
    K-chunk with one contiguous block copy, and gives each slice a static
    tile-aligned offset inside the fetched block.
    """
    p = slices.shape[0]
    if operand == "a":
        _, m, k = slices.shape
        if k % t_k:
            raise ValueError(f"K={k} not divisible by t_k={t_k}")
        s = slices.reshape(p, m, k // t_k, t_k)
        return s.transpose(1, 2, 0, 3).reshape(m, p * k)
    elif operand == "b":
        _, k, n = slices.shape
        if k % t_k:
            raise ValueError(f"K={k} not divisible by t_k={t_k}")
        s = slices.reshape(p, k // t_k, t_k, n)
        return s.transpose(1, 0, 2, 3).reshape(p * k, n)
    raise ValueError(f"operand must be 'a' or 'b', got {operand!r}")


def deinterleave_k(x: jax.Array, p: int, operand: str, t_k: int) -> jax.Array:
    """Inverse of interleave_k — used by tests and the naive path."""
    if operand == "a":
        m, pk = x.shape
        k = pk // p
        s = x.reshape(m, k // t_k, p, t_k)
        return s.transpose(2, 0, 1, 3).reshape(p, m, k)
    elif operand == "b":
        pk, n = x.shape
        k = pk // p
        s = x.reshape(k // t_k, p, t_k, n)
        return s.transpose(1, 0, 2, 3).reshape(p, k, n)
    raise ValueError(f"operand must be 'a' or 'b', got {operand!r}")


def _int8_dot(a8: jax.Array, b8: jax.Array) -> jax.Array:
    """Exact int8 x int8 -> int32 GEMM (the MXU primitive)."""
    return jax.lax.dot_general(
        a8, b8, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.int32)


def triangular_accumulators(a_slices: jax.Array, b_slices: jax.Array,
                            p: int) -> jax.Array:
    """Paper Eq. 2: C_s = sum_{i<=s} A'_i B'_{s-i}, s = 0..p-1.

    Returns (p, M, N) int32. p(p+1)/2 exact int8 GEMMs.
    """
    accs = []
    for s in range(p):
        acc = _int8_dot(a_slices[0], b_slices[s])
        for i in range(1, s + 1):
            acc = acc + _int8_dot(a_slices[i], b_slices[s - i])
        accs.append(acc)
    return jnp.stack(accs)


def shift_reduce(accs: jax.Array, beta: int, scale_a: jax.Array,
                 scale_b: jax.Array, out_dtype) -> jax.Array:
    """Paper Eq. 3: C = diag(mu) (sum_s 2^{-beta s} C_s) diag(nu).

    Slices carry weight 2^{-beta(i+1)} so the pair (i, j=s-i) has weight
    2^{-beta(s+2)}. Weights are exact powers of two — no rounding beyond the
    decomposition residual. Summed highest-weight-first in ``out_dtype``.
    """
    p = accs.shape[0]
    c = jnp.zeros(accs.shape[1:], dtype=out_dtype)
    for s in range(p):
        # Python 2.0**e is exact (the runtime exp2 kernel is up to a few
        # ulp off eagerly, while jit constant-folds it — a bit-parity
        # hazard between eager oracles and compiled kernels).
        w = jnp.asarray(2.0 ** (-beta * (s + 2)), dtype=out_dtype)
        c = c + w * accs[s].astype(out_dtype)
    return c * scale_a.astype(out_dtype) * scale_b.astype(out_dtype)


def matmul(a: jax.Array, b: jax.Array, cfg: EmulationConfig,
           out_dtype=None) -> jax.Array:
    """Emulated GEMM via Scheme I, XLA reference path (unfused math; XLA may
    still fuse, but every slice product is an independent dot — this is the
    'cuBLAS-backed naive emulation' analogue).

    a: (M, K) float, b: (K, N) float.
    """
    if out_dtype is None:
        out_dtype = jnp.promote_types(a.dtype, b.dtype)
    out_dtype = jnp.dtype(out_dtype).type
    k_dim = a.shape[-1]
    beta = cfg.resolved_beta(k_dim)
    a_sl, mu = split(a, cfg.p, beta, axis=1)    # mu: (M, 1)
    b_sl, nu = split(b, cfg.p, beta, axis=0)    # nu: (1, N)
    accs = triangular_accumulators(a_sl, b_sl, cfg.p)
    return shift_reduce(accs, beta, mu, nu, out_dtype)


def matmul_complex_4m(a: jax.Array, b: jax.Array, cfg: EmulationConfig,
                      out_dtype=None) -> jax.Array:
    """Scheme-I complex GEMM via the 4M formulation (paper Sec. V-D:
    'EmuGEMM-I uses the 4M formulation').

    C_re = Ar Br - Ai Bi ; C_im = Ar Bi + Ai Br — four real emulated GEMMs.
    """
    if out_dtype is None:
        out_dtype = jnp.float32 if a.dtype == jnp.complex64 else jnp.float64
    ar, ai = jnp.real(a), jnp.imag(a)
    br, bi = jnp.real(b), jnp.imag(b)
    rr = matmul(ar, br, cfg, out_dtype)
    ii = matmul(ai, bi, cfg, out_dtype)
    ri = matmul(ar, bi, cfg, out_dtype)
    ir = matmul(ai, br, cfg, out_dtype)
    return jax.lax.complex(rr - ii, ri + ir)


def decomposition_residual_bound(p: int, beta: int) -> float:
    """Elementwise |a - reconstruction| <= scale * 2^{-beta p}."""
    return float(2.0 ** (-beta * p))


def fused_matmul(a: jax.Array, b: jax.Array, cfg: EmulationConfig,
                 out_dtype=None) -> jax.Array:
    """Scheme-I GEMM on the fused EmuGEMM-I kernel, via the dispatcher
    (cached block selection; non-aligned shapes are padded, not refused)."""
    import dataclasses
    from repro.kernels import dispatch  # lazy: keep the XLA path pallas-free
    if cfg.scheme != "ozaki1":
        cfg = dataclasses.replace(cfg, scheme="ozaki1")
    return dispatch.emulated_matmul(a, b, cfg=cfg, out_dtype=out_dtype)
