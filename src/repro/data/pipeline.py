"""Deterministic synthetic data pipeline with per-host sharding.

Real multi-pod runs feed each host only its slice of the global batch;
the pipeline is keyed by (seed, step, host) so that
  * restarts resume mid-epoch bit-exactly (fault tolerance),
  * elastic re-meshes re-slice the same global stream,
  * stragglers can be re-issued identical batches.

The synthetic LM stream is a fixed-vocabulary Markov-ish token generator
(cheap, but with enough structure that a model's loss visibly drops —
used by the examples and integration tests). Frontend-stub architectures
get Gaussian feature frames instead of token ids.
"""

from __future__ import annotations

import dataclasses

import numpy as np

from repro.configs.base import ArchConfig, ShapeSpec


@dataclasses.dataclass
class SyntheticLMDataset:
    vocab: int
    seq_len: int
    seed: int = 0
    # Structured stream: x_{t+1} = (a * x_t + noise) mod vocab, which a
    # model can partially predict — loss decreases during training.
    mult: int = 31

    def batch(self, step: int, batch_size: int, host: int = 0,
              n_hosts: int = 1) -> dict:
        per_host = batch_size // n_hosts
        rng = np.random.default_rng(
            np.random.SeedSequence([self.seed, step, host]))
        x0 = rng.integers(0, self.vocab, (per_host, 1))
        noise = rng.integers(0, 7, (per_host, self.seq_len + 1))
        toks = [x0]
        for t in range(self.seq_len):
            toks.append((toks[-1] * self.mult + noise[:, t:t + 1])
                        % self.vocab)
        seq = np.concatenate(toks, axis=1).astype(np.int32)
        return {"tokens": seq[:, :-1], "labels": seq[:, 1:]}


def make_batch_iterator(arch: ArchConfig, shape: ShapeSpec, seed: int = 0,
                        host: int = 0, n_hosts: int = 1,
                        batch_override: int | None = None):
    """Yields (step, batch dict) matching ``arch.input_specs(shape)``."""
    m = arch.model
    bsz = batch_override or shape.global_batch
    ds = SyntheticLMDataset(m.vocab, shape.seq_len, seed)
    step = 0
    rng = np.random.default_rng(np.random.SeedSequence([seed + 1, host]))
    while True:
        batch = ds.batch(step, bsz, host, n_hosts)
        if m.frontend == "audio_stub":
            per_host = bsz // n_hosts
            batch = {
                "tokens": rng.standard_normal(
                    (per_host, shape.seq_len, m.frontend_dim),
                    dtype=np.float32),
                "labels": batch["labels"],
            }
        elif m.frontend == "vision_stub":
            per_host = bsz // n_hosts
            batch["image_embeds"] = rng.standard_normal(
                (per_host, m.n_image_tokens, m.frontend_dim),
                dtype=np.float32)
        yield step, batch
        step += 1
