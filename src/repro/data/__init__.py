from repro.data.pipeline import (  # noqa: F401
    SyntheticLMDataset,
    make_batch_iterator,
)
