"""Continuous-batching engine over the fused emulated GEMMs.

:class:`ContinuousEngine` executes the scheduler's fixed-shape plans with
exactly two jit-compiled step functions — a mixed ``(max_lanes, chunk)``
prefill+decode step and a ``(max_lanes, 1)`` pure-decode step — against a
paged KV cache. One compile serves arbitrary traffic mixes; a lane's
tokens are bit-identical whatever the rest of the cohort is doing (see
forward_step), so continuous batching changes throughput, never results.

Emulation specifics:

* **Once-per-session residue streaming** — when the resolved policy
  caches weights (``+cached``), ``prepare_params`` decomposes the dense
  projections once at construction; every subsequent serve step streams
  finished int8 slices/residues.
* **Per-request guard retry** — the jitted fast path never raises:
  under jit, strict guards only *count* trips (docs/robustness.md), so
  the engine polls ``guard.stats()`` deltas per step. A tripped step is
  re-run lane-by-lane in eager mode, where the full escalation ladder
  executes: attribution lands on the offending request(s) only
  (``guard_trips`` in its result), their corrected outputs overwrite the
  fast path's, and a request that still fails strict after
  ``guard_retries`` eager attempts is failed alone — the rest of the
  cohort never replays and never pays backoff.

The legacy whole-batch :class:`LockstepEngine` (prefill the full batch,
decode in lockstep) is kept for API back-compat; the continuous engine's
``wave_admission`` mode reproduces its schedule with the new step
functions and is the baseline the serve benchmark gates against.
"""

from __future__ import annotations

import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import guard
from repro.core.precision import EmulationAccuracyError
from repro.kernels import dispatch
from repro.models import model as M
from repro.models.common import GemmPolicy
from repro.serving.kv_cache import SCRATCH_PAGE, PagedKVCache
from repro.serving.queue import Request, RequestQueue, RequestState
from repro.serving.scheduler import ScheduleConfig, Scheduler, StepPlan

_GUARD_FIELDS = ("calls", "trips", "escalations", "recoveries",
                 "native_fallbacks", "masked")


@dataclasses.dataclass
class RequestResult:
    rid: int
    status: str                    # done | failed
    tokens: list[int]
    ttft: float | None             # first token latency (s from arrival)
    tpot: float | None             # mean per-output-token latency (s)
    guard_trips: int
    evictions: int

    @classmethod
    def of(cls, s: RequestState) -> "RequestResult":
        arr = s.request.arrival
        ttft = (s.first_token_at - arr
                if s.first_token_at is not None else None)
        n = len(s.generated)
        tpot = None
        if n > 1 and s.finished_at is not None and s.first_token_at is not None:
            tpot = (s.finished_at - s.first_token_at) / (n - 1)
        return cls(rid=s.rid, status=s.status, tokens=list(s.generated),
                   ttft=ttft, tpot=tpot, guard_trips=s.guard_trips,
                   evictions=s.evictions)


class ContinuousEngine:
    def __init__(self, arch, mesh, *, max_seq: int, policy=None, params=None,
                 seed: int = 0, prepare: bool | None = None,
                 max_lanes: int = 4, chunk: int = 16, page_size: int = 16,
                 num_pages: int | None = None, queue_policy: str = "fcfs",
                 token_budget: int | None = None, guard_retries: int = 1,
                 guard_backoff: float = 0.0, wave_admission: bool = False,
                 clock=None):
        self.arch = arch
        self.mcfg = arch.model
        self.mesh = mesh
        self.policy = dispatch.resolve_policy(
            policy if policy is not None else arch.gemm_policy(), mesh)
        self.params = params if params is not None else M.init_params(
            jax.random.PRNGKey(seed), self.mcfg)
        from repro.kernels import prepared
        if prepare is None:       # auto: +cached specs stream residues
            prepare = prepared.policy_caches_weights(self.policy)
        self.prepared = bool(prepare)
        if self.prepared:
            self.params = prepared.prepare_params(self.params, self.policy)

        if num_pages is None:     # worst case: every lane at max_seq
            import math
            num_pages = 1 + max_lanes * math.ceil(max_seq / page_size)
        self.kv = PagedKVCache(self.mcfg, page_size=page_size,
                               num_pages=num_pages, max_seq=max_seq,
                               chunk=chunk)
        self.pools = self.kv.init_pools()
        cfg = ScheduleConfig(max_lanes=max_lanes, chunk=chunk,
                             token_budget=token_budget, policy=queue_policy)
        self.sched = Scheduler(cfg, self.kv, wave=wave_admission)
        self.queue: RequestQueue = self.sched.queue

        self._step_fns = {c: self._make_step(c) for c in {1, chunk}}
        # No donated buffers: a guard replay needs the pre-step pools
        # intact, and jit invalidates donated args even on failure.
        self._jit_fns = {c: jax.jit(f) for c, f in self._step_fns.items()}
        self.guard_retries = guard_retries
        self.guard_backoff = guard_backoff
        self.last_guard: dict[str, int] = {}
        self._results: dict[int, RequestResult] = {}
        self._step_idx = 0
        self._busy_steps = 0
        self._queue_nonempty_steps = 0
        self._t0 = time.monotonic()
        self._clock = clock if clock is not None else (
            lambda: time.monotonic() - self._t0)
        from repro import telemetry
        self._telemetry = telemetry
        self._tracker = telemetry.StepTracker() if telemetry.enabled() \
            else None

    # ---- step functions -------------------------------------------------

    def _make_step(self, c: int):
        kv, mcfg, policy, vocab = self.kv, self.mcfg, self.policy, \
            self.mcfg.vocab

        def step(params, pools, tables, tokens, start, n_new):
            views = kv.gather(pools, tables)
            logits, views = M.forward_step(params, mcfg, tokens, start,
                                           n_new, views, policy)
            pools = kv.scatter(pools, tables, views, start, n_new, c)
            tok = jnp.argmax(logits[:, :vocab], axis=-1).astype(jnp.int32)
            return tok, pools

        return step

    # ---- request intake -------------------------------------------------

    def submit(self, request: Request) -> RequestState:
        return self.queue.submit(request)

    def reset_clock(self) -> None:
        """Re-zero the arrival/latency clock, e.g. after a jit warmup:
        request ``arrival`` offsets and TTFT/TPOT are then measured from
        serving start instead of engine construction (no-op under an
        injected ``clock``)."""
        self._t0 = time.monotonic()

    # ---- execution ------------------------------------------------------

    def _guard_delta(self, before) -> dict[str, int]:
        jax.effects_barrier()      # flush staged guard debug callbacks
        after = guard.stats()
        return {f: getattr(after, f) - getattr(before, f)
                for f in _GUARD_FIELDS}

    def _execute(self, plan: StepPlan, tables) -> np.ndarray:
        args = (self.params, self.pools, tables,
                jnp.asarray(plan.tokens), jnp.asarray(plan.start),
                jnp.asarray(plan.n_new))
        before = guard.stats()
        try:
            tok, pools = self._jit_fns[plan.chunk](*args)
            sampled = np.asarray(tok)
            self.pools = pools
            delta = self._guard_delta(before)
        except EmulationAccuracyError:
            # Strict trip surfaced at trace time (first call, constant
            # folding): fall straight to per-lane eager isolation.
            delta = {"trips": 1}
        self.last_guard = delta
        if delta.get("trips", 0) or delta.get("escalations", 0):
            sampled = self._isolation_replay(plan, tables)
        return sampled

    def _isolation_replay(self, plan: StepPlan, tables) -> np.ndarray:
        """Re-run the tripped step one lane at a time, eagerly.

        Eager mode runs the full guard escalation ladder, so the replay
        both *attributes* the trip to the request(s) that caused it and
        *corrects* their outputs (escalated precision / native fallback)
        instead of keeping the fast path's masked values. Only still-
        failing strict lanes are failed; innocent cohort members keep
        their (identical, row-independent) results with zero retries.
        """
        from repro.telemetry import record as _rec
        b = len(plan.rids)
        sampled = np.zeros((b,), dtype=np.int32)
        scratch_row = np.full((self.kv.view_pages,), SCRATCH_PAGE, np.int32)
        tables_np = np.asarray(tables)
        for lane in range(b):
            if plan.rids[lane] is None:
                continue
            state = self.sched.lanes[lane]
            assert state is not None and state.rid == plan.rids[lane]
            one = lambda arr, fill=0: np.full_like(arr, fill)
            t1 = np.stack([tables_np[i] if i == lane else scratch_row
                           for i in range(b)])
            toks, st, nn = (one(plan.tokens), one(plan.start),
                            one(plan.n_new))
            toks[lane], st[lane], nn[lane] = (plan.tokens[lane],
                                              plan.start[lane],
                                              plan.n_new[lane])
            attempt = 0
            while True:
                before = guard.stats()
                try:
                    tok, pools = self._step_fns[plan.chunk](
                        self.params, self.pools, jnp.asarray(t1),
                        jnp.asarray(toks), jnp.asarray(st), jnp.asarray(nn))
                    delta = self._guard_delta(before)
                    trips = delta.get("trips", 0)
                    if trips:
                        state.guard_trips += trips
                        _rec.record_event(_rec.SERVE_GUARD_TRIPS,
                                          {"rid": state.rid}, trips)
                    sampled[lane] = int(np.asarray(tok)[lane])
                    self.pools = pools
                    break
                except EmulationAccuracyError:
                    state.guard_trips += 1
                    _rec.record_event(_rec.SERVE_GUARD_TRIPS,
                                      {"rid": state.rid}, 1)
                    if attempt >= self.guard_retries:
                        self._fail_lane(lane, state)
                        plan.rids[lane] = None
                        break
                    attempt += 1
                    if self.guard_backoff:
                        time.sleep(self.guard_backoff * attempt)
        return sampled

    def _fail_lane(self, lane: int, state: RequestState) -> None:
        from repro.telemetry import record as _rec
        state.status = "failed"
        state.finished_at = self._clock()
        self.kv.release(state.rid)
        self.sched.lanes[lane] = None
        self.sched.failed.append(state)
        self._results[state.rid] = RequestResult.of(state)
        _rec.record_event(_rec.SERVE_REQUESTS, {"outcome": "guard_failed"})

    # ---- the serve loop -------------------------------------------------

    def step_once(self, now: float | None = None) -> StepPlan | None:
        """Plan + execute + commit one engine step. Returns the executed
        plan, or None when nothing was runnable at ``now``."""
        if now is None:
            now = self._clock()
        evicted_before = self.sched.evictions
        plan = self.sched.plan(now)
        self._record_gauges(now)
        if plan is None:
            return None
        tables = self.kv.tables_for(plan.rids)
        t0 = time.perf_counter()
        sampled = self._execute(plan, tables)
        dt = time.perf_counter() - t0
        retired = self.sched.commit(plan, sampled, self._clock())
        self._record_step(plan, retired, dt,
                          self.sched.evictions - evicted_before)
        self._step_idx += 1
        self._busy_steps += 1
        if self.queue.depth(now) > 0:
            self._queue_nonempty_steps += 1
        return plan

    def run(self, requests=None, max_steps: int | None = None
            ) -> dict[int, RequestResult]:
        """Serve to completion (wall clock; arrivals are seconds from
        engine start). Returns {rid: RequestResult}."""
        if requests:
            for r in requests:
                self.submit(r)
        steps = 0
        while self.sched.has_work():
            if max_steps is not None and steps >= max_steps:
                raise RuntimeError(f"serve loop exceeded {max_steps} steps")
            now = self._clock()
            plan = self.step_once(now)
            steps += 1
            if plan is None:
                nxt = self.queue.next_arrival()
                if nxt is not None and nxt > now:
                    time.sleep(min(nxt - now, 0.05))
        return dict(self._results)

    # ---- telemetry ------------------------------------------------------

    def _record_gauges(self, now: float) -> None:
        if not self._telemetry.enabled():
            return
        reg = self._telemetry.REGISTRY
        rec = self._telemetry.record
        reg.set_gauge(rec.SERVE_QUEUE_DEPTH, self.queue.depth(now))
        reg.set_gauge(rec.SERVE_PAGE_OCCUPANCY,
                      self.kv.stats()["occupancy"])
        reg.set_gauge(rec.SERVE_LANES_ACTIVE, len(self.sched.running()))

    def _record_step(self, plan: StepPlan, retired, dt: float,
                     evicted: int) -> None:
        for s in retired:
            if s.rid not in self._results:
                self._results[s.rid] = RequestResult.of(s)
        if not self._telemetry.enabled():
            return
        reg = self._telemetry.REGISTRY
        rec = self._telemetry.record
        n_pref = int(plan.n_new[plan.prefill].sum())
        n_dec = int(plan.n_new[~plan.prefill & (plan.n_new > 0)].sum())
        if n_pref:
            reg.inc(rec.SERVE_TOKENS, n_pref, {"kind": "prefill"})
        if n_dec:
            reg.inc(rec.SERVE_TOKENS, n_dec, {"kind": "decode"})
        if evicted:
            reg.inc(rec.SERVE_EVICTIONS, evicted)
        for s in retired:
            if s.status == "done":
                reg.inc(rec.SERVE_REQUESTS, 1, {"outcome": "done"})
            r = self._results[s.rid]
            if r.ttft is not None:
                reg.observe(rec.SERVE_TTFT_SECONDS, r.ttft)
            if r.tpot is not None:
                reg.observe(rec.SERVE_TPOT_SECONDS, r.tpot)
        if self._tracker is not None:
            self._tracker.step_metrics(
                self._step_idx, dt, kind="serve_step",
                tokens=plan.scheduled_tokens,
                extra={"lanes": int((plan.n_new > 0).sum()),
                       "chunk": plan.chunk,
                       "queue_depth": self.queue.depth(),
                       "page_occupancy": self.kv.stats()["occupancy"],
                       "guard_trips": self.last_guard.get("trips", 0)})

    # ---- introspection --------------------------------------------------

    def utilization(self) -> dict:
        """Deterministic schedule-quality counters (see bench_serve)."""
        return {"steps": self._step_idx,
                "busy_steps": self._busy_steps,
                "queue_nonempty_steps": self._queue_nonempty_steps,
                "evictions": self.sched.evictions,
                "admissions": self.sched.admissions,
                "kv": self.kv.stats()}


class LockstepEngine:
    """Legacy whole-batch engine: prefill the full batch once, decode all
    lanes in lockstep against a contiguous cache. Kept as the API-stable
    ``repro.launch.serve.ServeEngine``; new code and the benchmark use
    :class:`ContinuousEngine` (its ``wave_admission`` mode reproduces
    this schedule on the paged cache)."""

    def __init__(self, arch, mesh, max_seq: int, policy=None,
                 params=None, seed: int = 0, prepare: bool = False,
                 guard_retries: int = 1, guard_backoff: float = 0.25):
        self.arch = arch
        self.mcfg = arch.model
        self.mesh = mesh
        self.max_seq = max_seq
        # The one resolver decides the engine's emulation: an explicit
        # policy wins, else the arch config's gemm_sites table, else the
        # ambient repro.emulation scope / REPRO_EMULATION env configures
        # the whole serving session; resolve_policy then clamps impls to
        # what this mesh executes.
        self.policy = dispatch.resolve_policy(
            policy if policy is not None else arch.gemm_policy(), mesh)
        self.params = params if params is not None else M.init_params(
            jax.random.PRNGKey(seed), self.mcfg)
        if prepare:
            # Once-per-session weight decomposition: every prefill/decode
            # step streams the finished int8 slices instead of
            # re-splitting the projection weights (Scheme-I sites only).
            from repro.kernels import prepared
            self.params = prepared.prepare_params(self.params, self.policy)
        self._decode = jax.jit(
            lambda p, tok, pos, cache: M.forward_decode(
                p, self.mcfg, tok, pos, cache, self.policy))
        self._prefill = jax.jit(
            lambda p, inputs: M.forward_prefill(
                p, self.mcfg, inputs, self.max_seq, self.policy))
        # Guard consumption (docs/robustness.md): ``last_guard`` holds the
        # per-batch delta of the process-wide guard counters; a strict
        # accuracy trip retries the whole batch with backoff before
        # surfacing (the request-level analogue of the trainer's step
        # retry — ContinuousEngine narrows this to the offending request).
        self.guard_retries = guard_retries
        self.guard_backoff = guard_backoff
        self.last_guard: dict[str, int] = {}
        from repro import telemetry
        self._telemetry = telemetry
        self._tracker = telemetry.StepTracker() if telemetry.enabled() \
            else None
        self._batches = 0

    def _generate_once(self, prompts: np.ndarray, n_tokens: int):
        b, s = prompts.shape
        logits, cache = self._prefill(self.params,
                                      {"tokens": jnp.asarray(prompts)})
        out = []
        tok = jnp.argmax(logits[:, -1:, :self.mcfg.vocab], axis=-1)
        out.append(tok)
        for i in range(1, n_tokens):
            logits, cache = self._decode(self.params, tok, s + i - 1, cache)
            tok = jnp.argmax(logits[:, -1:, :self.mcfg.vocab], axis=-1)
            out.append(tok)
        return np.asarray(jnp.concatenate(out, axis=1))

    def generate(self, prompts: np.ndarray, n_tokens: int,
                 greedy: bool = True):
        """prompts: (B, S) int32. Returns (B, n_tokens) generated ids."""
        before = guard.stats()
        t0 = time.time()
        attempt = 0
        while True:
            try:
                toks = self._generate_once(prompts, n_tokens)
                break
            except EmulationAccuracyError as e:
                if attempt >= self.guard_retries:
                    raise
                attempt += 1
                pause = self.guard_backoff * attempt
                print(f"[serve] guard trip (retry {attempt}/"
                      f"{self.guard_retries} after {pause:.2f}s): {e}")
                time.sleep(pause)
        dt = time.time() - t0
        after = guard.stats()
        self.last_guard = {
            f: getattr(after, f) - getattr(before, f) for f in _GUARD_FIELDS}
        self.last_guard["retries"] = attempt
        # One telemetry record per served batch (docs/observability.md):
        # kind="serve", tokens = generated ids this batch, so
        # tokens_per_s is the decode throughput the operator dashboards.
        if self._tracker is None and self._telemetry.enabled():
            self._tracker = self._telemetry.StepTracker()
        if self._tracker is not None:
            self._tracker.step_metrics(
                self._batches, dt, kind="serve",
                tokens=int(prompts.shape[0]) * int(n_tokens),
                extra={"requests": int(prompts.shape[0]),
                       "guard_retries": attempt})
        self._batches += 1
        return toks
