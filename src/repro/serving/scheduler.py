"""Continuous-batching scheduler: lanes, admission, eviction, step plans.

The engine compiles exactly two step shapes — a mixed ``(max_lanes,
chunk)`` step and a pure-decode ``(max_lanes, 1)`` step — and the
scheduler's job is to keep those fixed shapes full of useful work:

  * **Lanes** are batch rows. A request occupies one lane from admission
    to completion (or eviction); idle lanes ride along as padding with
    ``n_new = 0`` and all-scratch block tables.
  * **Chunked prefill**: a prefilling lane consumes up to ``chunk``
    prompt tokens per step; decode lanes consume exactly one. Both kinds
    share a single forward, so decode latency never waits behind a long
    prompt and prefill never needs a separate compiled shape.
  * **Admission** pops the arrival queue (FCFS or SPF, see
    repro.serving.queue) while a free lane, the token budget, and one
    chunk's worth of pages are all available. Requests whose total
    footprint can never fit are failed up front instead of deadlocking.
  * **Eviction**: when a mid-flight lane cannot grow its page list, the
    running lane with the *latest* arrival is preempted — pages freed,
    request re-queued at its original arrival position, prompt + emitted
    tokens re-prefilled on re-admission. Only strictly-younger victims
    are ever evicted, so the globally oldest request always makes
    progress and no request starves.

Step accounting is position-exact: ``state.fed`` counts tokens written
into the paged cache; prefill feeds ``effective_prompt`` (original
prompt plus any tokens emitted before an eviction), and each decode step
feeds the newest emitted token at position ``fed``. Because every model
row is computed independently (see forward_step), a request's emitted
tokens are bit-identical whatever cohort, chunking, or eviction history
the scheduler produces — the property the serving tests pin down.
"""

from __future__ import annotations

import dataclasses

import numpy as np

from repro.serving.kv_cache import PagedKVCache
from repro.serving.queue import RequestQueue, RequestState


@dataclasses.dataclass(frozen=True)
class ScheduleConfig:
    max_lanes: int = 4
    chunk: int = 16              # prefill tokens per lane per mixed step
    token_budget: int | None = None   # cap on sum of running total_tokens
    policy: str = "fcfs"              # queue pop policy: fcfs | spf
    spf_age_limit: float = 10.0

    def __post_init__(self):
        if self.max_lanes < 1 or self.chunk < 1:
            raise ValueError("max_lanes and chunk must be >= 1")


@dataclasses.dataclass
class StepPlan:
    """One fixed-shape forward: which lane feeds what, where."""

    rids: list          # lane -> rid | None
    tokens: np.ndarray  # (B, C) int32, left-aligned fresh token ids
    start: np.ndarray   # (B,) int32, absolute position of first fresh token
    n_new: np.ndarray   # (B,) int32, valid token count (0 for idle lanes)
    emit: np.ndarray    # (B,) bool, lane's sampled logit becomes a new token
    prefill: np.ndarray  # (B,) bool, lane fed prompt (vs generated) tokens
    chunk: int          # C — 1 for pure-decode plans, cfg.chunk otherwise

    @property
    def scheduled_tokens(self) -> int:
        return int(self.n_new.sum())


class Scheduler:
    def __init__(self, cfg: ScheduleConfig, kv: PagedKVCache,
                 queue: RequestQueue | None = None, wave: bool = False):
        self.cfg = cfg
        self.kv = kv
        self.queue = queue if queue is not None else RequestQueue(
            policy=cfg.policy, spf_age_limit=cfg.spf_age_limit)
        # Wave admission models the lockstep engine this subsystem
        # replaces: a new cohort is admitted only once every lane has
        # drained. Kept as the reference mode for the bench gate and the
        # per-request bit-identity tests.
        self.wave = wave
        self.lanes: list[RequestState | None] = [None] * cfg.max_lanes
        self.failed: list[RequestState] = []
        self.evictions = 0
        self.admissions = 0

    # ---- bookkeeping ----------------------------------------------------

    def running(self) -> list[RequestState]:
        return [s for s in self.lanes if s is not None]

    def has_work(self) -> bool:
        return any(self.lanes) or self.queue.pending() > 0

    def _running_token_load(self) -> int:
        return sum(s.request.total_tokens for s in self.running())

    def _fits_forever(self, state: RequestState) -> bool:
        total = state.request.total_tokens
        cap_pages = min(self.kv.num_pages - 1, self.kv.view_pages)
        return (self.kv.pages_needed(total) <= cap_pages
                and total <= self.kv.max_seq)

    def _next_step_tokens(self, state: RequestState) -> int:
        if state.prompt_consumed < state.prefill_len:
            return min(self.cfg.chunk,
                       state.prefill_len - state.prompt_consumed)
        return 1

    def _running_page_deficit(self) -> int:
        """Pages the running lanes still need for their *next* step.

        Admission must leave these free: otherwise a freshly admitted (or
        freshly evicted-and-requeued) request grabs the pages a starving
        lane's eviction just released, and admit/evict livelocks."""
        deficit = 0
        for s in self.running():
            need = self.kv.pages_needed(s.fed + self._next_step_tokens(s))
            deficit += max(0, need - len(self.kv.allocator.owned_by(s.rid)))
        return deficit

    # ---- admission ------------------------------------------------------

    def admit(self, now: float) -> int:
        admitted = 0
        while None in self.lanes:
            state = self.queue.pop_ready(now)
            if state is None:
                break
            if not self._fits_forever(state):
                state.status = "failed"
                state.finished_at = now
                self.failed.append(state)
                continue
            budget = self.cfg.token_budget
            if (budget is not None and self._running_token_load()
                    + state.request.total_tokens > budget):
                self.queue.requeue(state)
                break
            first = min(len(state.effective_prompt), self.cfg.chunk)
            need = self.kv.pages_needed(first)
            if (self.kv.allocator.free_pages - need
                    < self._running_page_deficit()
                    or not self.kv.ensure(state.rid, first)):
                self.queue.requeue(state)   # pages free up as lanes retire
                break
            lane = self.lanes.index(None)
            self.lanes[lane] = state
            state.status = "running"
            state.prefill_len = len(state.effective_prompt)
            state.fed = state.prompt_consumed
            if state.admitted_at is None:
                state.admitted_at = now
            self.admissions += 1
            admitted += 1
        return admitted

    # ---- eviction -------------------------------------------------------

    def _evict_for(self, starving: RequestState, now: float) -> bool:
        """Preempt the youngest running lane strictly younger than
        ``starving`` — in (arrival, rid) order, so simultaneous arrivals
        still totally order and the globally oldest request can always
        claim pages. Returns True if pages were freed."""
        key = lambda s: (s.request.arrival, s.rid)
        victims = [s for s in self.running()
                   if s is not starving and key(s) > key(starving)]
        if not victims:
            return False
        victim = max(victims, key=key)
        self._preempt(victim)
        return True

    def _preempt(self, victim: RequestState) -> None:
        lane = self.lanes.index(victim)
        self.lanes[lane] = None
        self.kv.release(victim.rid)
        victim.reset_for_requeue()
        self.queue.requeue(victim)
        self.evictions += 1

    # ---- planning -------------------------------------------------------

    def plan(self, now: float) -> StepPlan | None:
        if not self.wave or not any(self.lanes):
            self.admit(now)
        b, chunk = self.cfg.max_lanes, self.cfg.chunk
        # (lane, state, toks, emit, prefill); state captured because a
        # later lane's page pressure may evict an earlier entry mid-plan.
        want: list[tuple[int, RequestState, list[int], bool, bool]] = []
        for lane, state in enumerate(self.lanes):
            if state is None:
                continue
            if state.prompt_consumed < state.prefill_len:
                n = min(chunk, state.prefill_len - state.prompt_consumed)
                toks = list(state.effective_prompt[
                    state.prompt_consumed:state.prompt_consumed + n])
                emit = state.prompt_consumed + n >= state.prefill_len
                pf = True
            else:
                toks = [state.generated[-1]]
                emit = True
                pf = False
            if not self.kv.ensure(state.rid, state.fed + len(toks)):
                if self._evict_for(state, now) and self.kv.ensure(
                        state.rid, state.fed + len(toks)):
                    pass
                else:
                    continue        # stall this lane one step; pages drain
            want.append((lane, state, toks, emit, pf))
        want = [w for w in want if self.lanes[w[0]] is w[1]]   # drop evicted
        if not want:
            return None

        c = 1 if all(len(t) == 1 for _, _, t, _, _ in want) else chunk
        tokens = np.zeros((b, c), dtype=np.int32)
        start = np.zeros((b,), dtype=np.int32)
        n_new = np.zeros((b,), dtype=np.int32)
        emit = np.zeros((b,), dtype=bool)
        prefill = np.zeros((b,), dtype=bool)
        for lane, state, toks, em, pf in want:
            tokens[lane, :len(toks)] = toks
            start[lane] = state.fed
            n_new[lane] = len(toks)
            emit[lane] = em
            prefill[lane] = pf
        rids = [s.rid if s is not None and n_new[i] > 0 else None
                for i, s in enumerate(self.lanes)]
        return StepPlan(rids=rids, tokens=tokens, start=start, n_new=n_new,
                        emit=emit, prefill=prefill, chunk=c)

    # ---- commit ---------------------------------------------------------

    def commit(self, plan: StepPlan, sampled: np.ndarray, now: float
               ) -> list[RequestState]:
        """Apply one executed plan: advance positions, append emitted
        tokens, retire finished lanes. Returns the retired states."""
        retired = []
        for lane, state in enumerate(self.lanes):
            if state is None or plan.rids[lane] != state.rid:
                continue
            n = int(plan.n_new[lane])
            state.fed += n
            if state.prompt_consumed < state.prefill_len:
                state.prompt_consumed += n
            if plan.emit[lane]:
                state.generated.append(int(sampled[lane]))
                if state.first_token_at is None:
                    state.first_token_at = now
                if state.done:
                    state.status = "done"
                    state.finished_at = now
                    self.kv.release(state.rid)
                    self.lanes[lane] = None
                    retired.append(state)
        return retired

    # ---- invariants (exercised by tests) --------------------------------

    def check_invariants(self) -> None:
        live = {s.rid for s in self.running()}
        assert len(live) == len(self.running()), "duplicate lane occupancy"
        self.kv.allocator.check_leaks(live)
        for s in self.running():
            assert s.fed <= s.prefill_len + len(s.generated)
            assert len(self.kv.allocator.owned_by(s.rid)) >= \
                self.kv.pages_needed(s.fed), \
                f"request {s.rid}: fed {s.fed} tokens outruns its pages"
