"""repro.serving — continuous-batching inference over emulated GEMMs.

The serving analogue of the training stack (docs/serving.md): an async
request queue with admission/eviction policy, a paged block-table KV
cache, and a scheduler that interleaves chunked prefill with decode so
one jit-compiled step shape serves mixed traffic.

    from repro.serving import ContinuousEngine, Request

    eng = ContinuousEngine(arch, mesh, max_seq=256, max_lanes=4,
                           chunk=16, page_size=16)
    results = eng.run([Request(prompt, max_new_tokens=32, arrival=t)
                       for t, prompt in trace])

``python -m repro.launch.serve`` is the CLI front-end.
"""

from repro.serving.engine import (ContinuousEngine, LockstepEngine,
                                  RequestResult)
from repro.serving.kv_cache import SCRATCH_PAGE, PageAllocator, PagedKVCache
from repro.serving.queue import Request, RequestQueue, RequestState
from repro.serving.scheduler import ScheduleConfig, Scheduler, StepPlan

__all__ = [
    "ContinuousEngine",
    "LockstepEngine",
    "PageAllocator",
    "PagedKVCache",
    "Request",
    "RequestQueue",
    "RequestResult",
    "RequestState",
    "SCRATCH_PAGE",
    "ScheduleConfig",
    "Scheduler",
    "StepPlan",
]
