"""Request admission queue for the continuous-batching serve engine.

``RequestQueue`` is the thread-safe waiting room between request arrival
and scheduler admission: callers ``submit`` from any thread (or replay a
recorded arrival trace), the scheduler ``pop_ready`` holding its own
clock, and eviction puts preempted requests back at their *original*
arrival position — FCFS order is by arrival time, so an evicted request
never loses its place and no request starves behind later traffic.

Policies:
  fcfs — strict arrival order (the default; starvation-free)
  spf  — shortest-prompt-first among the *arrived* requests, with an
         ``spf_age_limit`` anti-starvation valve: once a request has
         waited that long it is served FCFS regardless of length.
"""

from __future__ import annotations

import dataclasses
import itertools
import threading
from typing import Iterable

import numpy as np

_rid_counter = itertools.count()


@dataclasses.dataclass(frozen=True)
class Request:
    """One generation request: prompt ids in, ``max_new_tokens`` ids out."""

    prompt: tuple[int, ...]
    max_new_tokens: int
    arrival: float = 0.0
    rid: int = dataclasses.field(default_factory=lambda: next(_rid_counter))

    def __post_init__(self):
        object.__setattr__(self, "prompt",
                           tuple(int(t) for t in np.asarray(self.prompt)))
        if not self.prompt:
            raise ValueError("empty prompt")
        if self.max_new_tokens < 1:
            raise ValueError("max_new_tokens must be >= 1")

    @property
    def total_tokens(self) -> int:
        return len(self.prompt) + self.max_new_tokens


@dataclasses.dataclass
class RequestState:
    """Mutable serving-side record of one request's lifecycle."""

    request: Request
    generated: list[int] = dataclasses.field(default_factory=list)
    prompt_consumed: int = 0     # prompt tokens already prefilled
    fed: int = 0                 # tokens written into the paged cache
    prefill_len: int = 0         # effective prompt length at admission
    guard_trips: int = 0         # strict accuracy trips charged to it
    evictions: int = 0
    status: str = "queued"       # queued|running|done|failed
    admitted_at: float | None = None
    first_token_at: float | None = None
    finished_at: float | None = None

    @property
    def rid(self) -> int:
        return self.request.rid

    @property
    def done(self) -> bool:
        return len(self.generated) >= self.request.max_new_tokens

    def reset_for_requeue(self) -> None:
        """Eviction keeps emitted tokens (they were already served); on
        re-admission the prompt *plus* the emitted tokens are re-prefilled
        so decode continues exactly where it stopped."""
        self.prompt_consumed = 0
        self.fed = 0
        self.evictions += 1
        self.status = "queued"

    @property
    def effective_prompt(self) -> tuple[int, ...]:
        return self.request.prompt + tuple(self.generated)


class RequestQueue:
    """Arrival-ordered waiting room with pluggable pop policy."""

    def __init__(self, policy: str = "fcfs", spf_age_limit: float = 10.0):
        if policy not in ("fcfs", "spf"):
            raise ValueError(f"unknown queue policy {policy!r}")
        self.policy = policy
        self.spf_age_limit = float(spf_age_limit)
        self._lock = threading.Lock()
        self._waiting: list[RequestState] = []

    def submit(self, request: Request) -> RequestState:
        state = RequestState(request=request)
        self.requeue(state)
        return state

    def submit_all(self, requests: Iterable[Request]) -> list[RequestState]:
        return [self.submit(r) for r in requests]

    def requeue(self, state: RequestState) -> None:
        state.status = "queued"
        with self._lock:
            self._waiting.append(state)
            self._waiting.sort(key=lambda s: (s.request.arrival, s.rid))

    def depth(self, now: float | None = None) -> int:
        """Queued requests; with ``now``, only those that have arrived."""
        with self._lock:
            if now is None:
                return len(self._waiting)
            return sum(1 for s in self._waiting if s.request.arrival <= now)

    def __len__(self) -> int:
        return self.depth()

    def pending(self) -> int:
        """Everything still queued, arrived or not."""
        return self.depth()

    def next_arrival(self) -> float | None:
        """Earliest arrival among queued requests (None when empty)."""
        with self._lock:
            if not self._waiting:
                return None
            return min(s.request.arrival for s in self._waiting)

    def pop_ready(self, now: float) -> RequestState | None:
        """Next request to admit under the policy, or None."""
        with self._lock:
            arrived = [s for s in self._waiting if s.request.arrival <= now]
            if not arrived:
                return None
            pick = arrived[0]           # FCFS: oldest arrival
            if self.policy == "spf":
                aged = now - pick.request.arrival >= self.spf_age_limit
                if not aged:
                    pick = min(arrived,
                               key=lambda s: (len(s.effective_prompt),
                                              s.request.arrival, s.rid))
            self._waiting.remove(pick)
            return pick
