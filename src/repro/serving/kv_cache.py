"""Paged KV cache: fixed-size pages, free-list allocator, block tables.

The lockstep engine keys each request to a contiguous ``(B, max_seq, ...)``
cache slab, so memory is reserved for the worst case and a finished lane's
slab is stranded until the whole batch drains. Here the per-lane sequence
axis is virtual: every model cache leaf is re-laid-out into a **pool**
whose token axis is ``num_pages * page_size`` physical slots, and each
request owns an ordered list of pages recorded in a block table. The
jitted serving step then

  gather  — block table -> contiguous per-lane *views* (the exact pytree
            :func:`repro.models.model.init_cache` would produce), fed
            unchanged to ``forward_step``;
  scatter — the chunk of freshly written slots copied back from the views
            into the pools at ``table[pos // page] * page + pos % page``.

Page 0 is a reserved scratch page that is never allocated: padded block
table entries and out-of-range/invalid token writes all land there, so
garbage can never corrupt a live request's pages (scratch reads are
always masked off by the causal mask, since they sit past every valid
query position or belong to no lane).

Pool layout is discovered, not hard-coded: the batch and sequence axes of
every cache leaf are found by diffing ``jax.eval_shape(init_cache, ...)``
at two batch sizes and two sequence lengths. A leaf with no sequence
axis (rec/ssd recurrent state, window rings) cannot be paged, and the
constructor refuses the architecture up front.
"""

from __future__ import annotations

import dataclasses
import math

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models import model as M

SCRATCH_PAGE = 0


@dataclasses.dataclass(frozen=True)
class _LeafAxes:
    batch: int   # batch axis index in the per-lane view layout
    seq: int     # sequence axis index in the per-lane view layout


def cache_leaf_axes(mcfg: ModelConfig):
    """Locate (batch, seq) axes of every ``init_cache`` leaf by shape
    differencing. Raises NotImplementedError for unpageable leaves."""
    pb, ps = 2, 64
    base = jax.eval_shape(lambda: M.init_cache(mcfg, pb, ps))
    bdiff = jax.eval_shape(lambda: M.init_cache(mcfg, pb + 1, ps))
    sdiff = jax.eval_shape(lambda: M.init_cache(mcfg, pb, ps + 8))

    def locate(a, b, c):
        b_ax = [i for i, (x, y) in enumerate(zip(a.shape, b.shape)) if x != y]
        s_ax = [i for i, (x, y) in enumerate(zip(a.shape, c.shape)) if x != y]
        if len(s_ax) != 1:
            raise NotImplementedError(
                f"cache leaf {a.shape} has no sequence axis — its state is "
                "lane-bound (rec/ssd/window ring) and cannot be paged; "
                "repro.serving supports attention-family caches only")
        if len(b_ax) != 1 or b_ax[0] != s_ax[0] - 1:
            raise NotImplementedError(
                f"cache leaf {a.shape}: expected the batch axis immediately "
                f"before the sequence axis, found batch={b_ax} seq={s_ax}")
        return _LeafAxes(batch=b_ax[0], seq=s_ax[0])

    return jax.tree.map(locate, base, bdiff, sdiff), base


class PageAllocator:
    """Free-list page allocator over ``num_pages`` physical pages.

    Page 0 (scratch) is reserved at construction. Allocation is
    all-or-nothing per request; ownership is tracked so double-frees,
    foreign frees, and leaks are hard errors rather than silent
    corruption."""

    def __init__(self, num_pages: int):
        if num_pages < 2:
            raise ValueError("need at least one page beyond scratch")
        self.num_pages = num_pages
        self._free = list(range(num_pages - 1, 0, -1))   # pop() -> low pages
        self._owner: dict[int, int] = {}                 # page -> rid
        self.high_water = 0
        self.alloc_failures = 0

    @property
    def free_pages(self) -> int:
        return len(self._free)

    @property
    def used_pages(self) -> int:
        return len(self._owner)

    def alloc(self, n: int, rid: int) -> list[int] | None:
        """n pages for request ``rid``, or None (no partial grants)."""
        if n < 0:
            raise ValueError("negative page count")
        if n > len(self._free):
            self.alloc_failures += 1
            return None
        pages = [self._free.pop() for _ in range(n)]
        for p in pages:
            self._owner[p] = rid
        self.high_water = max(self.high_water, len(self._owner))
        return pages

    def free(self, pages: list[int], rid: int) -> None:
        for p in pages:
            if p == SCRATCH_PAGE:
                raise ValueError("attempt to free the scratch page")
            owner = self._owner.get(p)
            if owner is None:
                raise ValueError(f"double free of page {p}")
            if owner != rid:
                raise ValueError(
                    f"request {rid} freeing page {p} owned by {owner}")
            del self._owner[p]
            self._free.append(p)

    def owned_by(self, rid: int) -> list[int]:
        return [p for p, o in self._owner.items() if o == rid]

    def check_leaks(self, live_rids: set[int]) -> None:
        leaked = {p: o for p, o in self._owner.items() if o not in live_rids}
        if leaked:
            raise AssertionError(f"leaked pages (page -> rid): {leaked}")

    def stats(self) -> dict:
        return {"num_pages": self.num_pages, "used": self.used_pages,
                "free": self.free_pages, "high_water": self.high_water,
                "alloc_failures": self.alloc_failures,
                "occupancy": self.used_pages / max(1, self.num_pages - 1)}


class PagedKVCache:
    """Pools + block tables for one serving session.

    Host side (numpy): per-request page lists via :class:`PageAllocator`
    and block-table assembly. Device side (traced): :meth:`gather` /
    :meth:`scatter`, pure functions of the pools and an int32 block-table
    array, safe to call inside jit."""

    def __init__(self, mcfg: ModelConfig, *, page_size: int, num_pages: int,
                 max_seq: int, chunk: int):
        if page_size < 1 or chunk < 1:
            raise ValueError("page_size and chunk must be >= 1")
        self.mcfg = mcfg
        self.page_size = page_size
        self.num_pages = num_pages
        self.max_seq = max_seq
        self.chunk = chunk
        # Every dynamic_update/slice at ``start`` with length up to
        # ``chunk`` must stay in-bounds (lax clamps silently otherwise,
        # desyncing store and scatter positions), so views cover
        # max start (max_seq - 1) + chunk tokens.
        self.view_pages = math.ceil((max_seq - 1 + chunk) / page_size)
        self.view_tokens = self.view_pages * page_size
        self.allocator = PageAllocator(num_pages)
        self._tables: dict[int, list[int]] = {}    # rid -> ordered pages
        self._axes, self._leaf_shapes = cache_leaf_axes(mcfg)

    # ---- host-side page accounting -------------------------------------

    def pages_needed(self, total_tokens: int) -> int:
        return math.ceil(total_tokens / self.page_size)

    def ensure(self, rid: int, total_tokens: int) -> bool:
        """Grow ``rid``'s page list to cover ``total_tokens``; False if
        the allocator cannot satisfy it (caller keeps prior pages)."""
        have = self._tables.get(rid, [])
        need = self.pages_needed(total_tokens) - len(have)
        if need <= 0:
            return True
        if need > self.view_pages - len(have):
            return False            # would overflow the block-table width
        got = self.allocator.alloc(need, rid)
        if got is None:
            return False
        self._tables[rid] = have + got
        return True

    def release(self, rid: int) -> None:
        pages = self._tables.pop(rid, [])
        if pages:
            self.allocator.free(pages, rid)

    def table_row(self, rid: int) -> np.ndarray:
        """(view_pages,) int32, padded with the scratch page."""
        row = np.full((self.view_pages,), SCRATCH_PAGE, dtype=np.int32)
        pages = self._tables.get(rid, [])
        row[:len(pages)] = pages
        return row

    def tables_for(self, rids: list[int | None]) -> jnp.ndarray:
        """(len(rids), view_pages) block table; None lanes -> all-scratch."""
        rows = [self.table_row(r) if r is not None
                else np.full((self.view_pages,), SCRATCH_PAGE, np.int32)
                for r in rids]
        return jnp.asarray(np.stack(rows))

    def live_rids(self) -> set[int]:
        return set(self._tables)

    def stats(self) -> dict:
        return self.allocator.stats()

    # ---- device-side pools ---------------------------------------------

    def init_pools(self):
        t = self.num_pages * self.page_size

        def mk(leaf, ax):
            sh = list(leaf.shape)
            sh[ax.seq] = t
            del sh[ax.batch]
            return jnp.zeros(tuple(sh), leaf.dtype)

        return jax.tree.map(mk, self._leaf_shapes, self._axes)

    def gather(self, pools, tables):
        """Pools + (B, view_pages) tables -> per-lane contiguous views in
        the exact ``init_cache`` pytree layout. Traced-safe."""
        ps = self.page_size
        b = tables.shape[0]
        flat = (tables[:, :, None] * ps
                + jnp.arange(ps, dtype=jnp.int32)[None, None, :]
                ).reshape(b, -1)                       # (B, view_tokens)

        def g(pool, ax):
            return jnp.take(pool, flat, axis=ax.seq - 1)

        return jax.tree.map(g, pools, self._axes)

    def scatter(self, pools, tables, views, start, n_new, chunk: int):
        """Copy each lane's freshly written view slots
        ``[start, start + chunk)`` back into the pools. Columns past
        ``n_new`` (and any position not backed by an allocated page) land
        on the scratch page. Traced-safe; ``chunk`` is static."""
        ps = self.page_size
        cols = jnp.arange(chunk, dtype=jnp.int32)
        pos = start[:, None] + cols[None, :]                     # (B, C)
        valid = cols[None, :] < n_new[:, None]
        pidx = jnp.clip(pos // ps, 0, tables.shape[1] - 1)
        page = jnp.take_along_axis(tables, pidx, axis=1)
        dest = jnp.where(valid & (page != SCRATCH_PAGE),
                         page * ps + pos % ps, cols[None, :] % ps)
        flat = dest.reshape(-1)                                  # (B*C,)

        def s(pool, view, ax):
            def one(v, st):        # v: view leaf minus its batch axis
                return jax.lax.dynamic_slice_in_dim(v, st, chunk,
                                                    axis=ax.seq - 1)
            fresh = jax.vmap(one, in_axes=(ax.batch, 0),
                             out_axes=ax.batch)(view, start)
            sh = fresh.shape       # (..., B, C, ...) with B at ax.batch
            merged = fresh.reshape(sh[:ax.batch]
                                   + (sh[ax.batch] * sh[ax.seq],)
                                   + sh[ax.seq + 1:])
            idx = (slice(None),) * (ax.seq - 1) + (flat,)
            return pool.at[idx].set(merged)

        return jax.tree.map(s, pools, views, self._axes)
