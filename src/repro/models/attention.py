"""Grouped-query attention with memory-bounded (flash-style) execution.

Design notes
------------
* Exact online-softmax attention, chunked over both query and key/value
  blocks via ``lax.scan`` — peak live score tensor is (B, KVH, G, bq, bk)
  regardless of sequence length. This is what makes the 32k-prefill and
  4k-train cells fit HBM without a fused attention kernel.
* Causal self-attention statically skips fully-masked KV chunks: the outer
  Q-chunk loop is unrolled (few chunks), so each Q chunk's inner KV scan has
  a *static* trip count covering only chunks at or below the diagonal —
  ~2x fewer attention FLOPs in the compiled HLO than a dense-mask scan.
* Supports GQA (any q/kv head ratio), optional QKV bias (Qwen), causal /
  bidirectional (encoder) / local sliding-window (RecurrentGemma) masking,
  and single-token decode against a (possibly ring-buffered) KV cache.
"""

from __future__ import annotations

import dataclasses
import math

import jax
import jax.numpy as jnp

from repro.models import common
from repro.models.common import (NATIVE_POLICY, GemmPolicy, dense, he_init,
                                 policy_einsum)

NEG_INF = -1e30


@dataclasses.dataclass(frozen=True)
class AttnConfig:
    d_model: int
    n_heads: int
    n_kv_heads: int
    head_dim: int
    qkv_bias: bool = False
    causal: bool = True            # False => bidirectional encoder
    window: int | None = None      # local attention window (None = global)
    rope_theta: float = 10000.0
    use_rope: bool = True
    q_chunk: int = 1024
    kv_chunk: int = 1024
    softmax_scale: float | None = None
    cache_int8: bool = False       # int8-quantized KV cache (per token/head)
    sp: bool = False               # sequence/context-parallel attention

    @property
    def scale(self) -> float:
        return self.softmax_scale or 1.0 / math.sqrt(self.head_dim)


def init_attention(key, cfg: AttnConfig, dtype=jnp.float32):
    kq, kk, kv, ko = jax.random.split(key, 4)
    d, h, kvh, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    params = {
        "wq": he_init(kq, (d, h * hd), dtype),
        "wk": he_init(kk, (d, kvh * hd), dtype),
        "wv": he_init(kv, (d, kvh * hd), dtype),
        "wo": he_init(ko, (h * hd, d), dtype, fan_in=h * hd),
    }
    if cfg.qkv_bias:
        params["bq"] = jnp.zeros((h * hd,), dtype)
        params["bk"] = jnp.zeros((kvh * hd,), dtype)
        params["bv"] = jnp.zeros((kvh * hd,), dtype)
    return params


def _constrain(x, spec):
    """with_sharding_constraint that degrades to a no-op when no mesh is
    active (pure-CPU unit tests call attention without a mesh)."""
    try:
        return jax.lax.with_sharding_constraint(x, spec)
    except (RuntimeError, ValueError):
        return x


def _sp_specs():
    from jax.sharding import PartitionSpec as P
    u = P.UNCONSTRAINED
    # q sharded along the sequence, k/v replicated over 'model' — the
    # context-parallel layout: every score/output einsum is then local,
    # and the only 'model'-axis collective left in attention is the k/v
    # gather. Essential when n_heads doesn't divide the model axis
    # (56, 40, 14, 10 heads on a 16-way axis), where head sharding makes
    # GSPMD all-reduce full score tensors.
    return P(u, "model", None, None), P(u, None, None, None)


def _project_qkv(params, cfg: AttnConfig, x, positions, policy: GemmPolicy):
    b, s, _ = x.shape
    q = dense(x, params["wq"], policy, "attn", params.get("bq"))
    k = dense(x, params["wk"], policy, "attn", params.get("bk"))
    v = dense(x, params["wv"], policy, "attn", params.get("bv"))
    q = q.reshape(b, s, cfg.n_heads, cfg.head_dim)
    k = k.reshape(b, s, cfg.n_kv_heads, cfg.head_dim)
    v = v.reshape(b, s, cfg.n_kv_heads, cfg.head_dim)
    if cfg.sp and s > 1:
        q_spec, kv_spec = _sp_specs()
        q = _constrain(q, q_spec)
        k = _constrain(k, kv_spec)
        v = _constrain(v, kv_spec)
    if cfg.use_rope:
        q = common.apply_rope(q, positions, cfg.rope_theta)
        k = common.apply_rope(k, positions, cfg.rope_theta)
    return q, k, v


def _chunk_mask(cfg: AttnConfig, q_pos, k_pos):
    """(bq, bk) boolean validity mask from absolute positions."""
    rel = q_pos[:, None] - k_pos[None, :]
    mask = jnp.ones(rel.shape, bool)
    if cfg.causal:
        mask &= rel >= 0
    if cfg.window is not None:
        mask &= rel < cfg.window
    return mask


def flash_attention(cfg: AttnConfig, q, k, v, q_positions, k_positions,
                    kv_valid_len=None, policy: GemmPolicy = NATIVE_POLICY):
    """Exact chunked attention.

    q: (B, Sq, H, D); k/v: (B, Sk, KVH, D); *_positions: (Sq,)/(Sk,) int32.
    kv_valid_len: optional scalar — keys at index >= len are masked (decode
    against a partially-filled cache).
    ``policy`` selects the emulation config of the two inner contractions
    (sites 'attn_qk' / 'attn_av'); the default pins them native, exactly
    the historical ``jnp.einsum`` path.
    Returns (B, Sq, H, D).
    """
    b, sq0, h, d = q.shape
    sk0 = k.shape[1]
    kvh = cfg.n_kv_heads
    g = h // kvh
    bq = min(cfg.q_chunk, sq0)
    bk = min(cfg.kv_chunk, sk0)

    # Pad ragged sequence lengths up to the chunk grid; padded keys get a
    # +inf position sentinel (fails every mask) plus an index validity bound.
    def pad_seq(x, mult, value=0):
        extra = (-x.shape[1]) % mult
        if not extra:
            return x
        widths = [(0, 0)] * x.ndim
        widths[1] = (0, extra)
        return jnp.pad(x, widths, constant_values=value)

    if cfg.sp and sq0 > 1:
        # Sequence-parallel: one whole-S q block sharded over 'model'.
        # Chunking q would slice across shard boundaries (a collective-
        # permute per chunk); the causal static-skip is forfeited (the
        # per-device q rows span the diagonal anyway once S is sharded).
        bq = sq0
    q = pad_seq(q, bq)
    k = pad_seq(k, bk)
    v = pad_seq(v, bk)
    q_positions = pad_seq(q_positions[None], bq, 2 ** 30)[0]
    k_positions = pad_seq(k_positions[None], bk, 2 ** 30)[0]
    sq, sk = q.shape[1], k.shape[1]
    if sk != sk0 and kv_valid_len is None:
        kv_valid_len = sk0
    n_q = sq // bq
    n_k = sk // bk

    qc = q.reshape(b, n_q, bq, kvh, g, d)
    kc = k.reshape(b, n_k, bk, kvh, d)
    vc = v.reshape(b, n_k, bk, kvh, d)
    scale = cfg.scale

    def kv_step(carry, idx):
        acc, m, l, qi, q_pos = carry
        kj = jax.lax.dynamic_index_in_dim(kc, idx, 1, keepdims=False)
        vj = jax.lax.dynamic_index_in_dim(vc, idx, 1, keepdims=False)
        k_pos = jax.lax.dynamic_slice_in_dim(k_positions, idx * bk, bk)
        s_ij = policy_einsum("bqkgd,bjkd->bkgqj", qi, kj, policy, "attn_qk",
                             pet=jnp.float32) * scale
        if cfg.sp:  # pin scores so the scan *backward* also stays sharded
            from jax.sharding import PartitionSpec as P
            s_ij = _constrain(
                s_ij, P(P.UNCONSTRAINED, None, None, "model", None))
        mask = _chunk_mask(cfg, q_pos, k_pos)
        if kv_valid_len is not None:
            kidx = idx * bk + jnp.arange(bk)
            mask &= (kidx < kv_valid_len)[None, :]
        s_ij = jnp.where(mask[None, None, None], s_ij, NEG_INF)
        m_new = jnp.maximum(m, s_ij.max(-1))
        p = jnp.exp(s_ij - m_new[..., None])
        alpha = jnp.exp(m - m_new)
        l = l * alpha + p.sum(-1)
        acc = acc * alpha[..., None] + policy_einsum(
            "bkgqj,bjkd->bkgqd", p.astype(vj.dtype), vj, policy, "attn_av",
            pet=jnp.float32)
        return (acc, m_new, l, qi, q_pos), None

    if cfg.sp:
        # Scan carries are a GSPMD propagation blind spot: an unconstrained
        # replicated-zeros init makes the whole online-softmax loop (and its
        # backward) compute replicated over 'model'. Pin the carry to the
        # sequence-sharded layout the q chunks already have.
        from jax.sharding import PartitionSpec as P
        u = P.UNCONSTRAINED
        carry_spec = P(u, None, None, "model", None)
        carry_spec_2 = P(u, None, None, "model")
    outs = []
    for i in range(n_q):  # unrolled: enables static causal chunk skipping
        qi = qc[:, i]
        q_pos = jax.lax.dynamic_slice_in_dim(q_positions, i * bq, bq)
        acc0 = jnp.zeros((b, kvh, g, bq, d), jnp.float32)
        m0 = jnp.full((b, kvh, g, bq), NEG_INF, jnp.float32)
        l0 = jnp.zeros((b, kvh, g, bq), jnp.float32)
        if cfg.sp:
            qi = _constrain(qi, P(u, "model", None, None, None))
            acc0 = _constrain(acc0, carry_spec)
            m0 = _constrain(m0, carry_spec_2)
            l0 = _constrain(l0, carry_spec_2)
        if cfg.causal and sq == sk and kv_valid_len is None:
            # static diagonal bound: kv chunks covering rows < (i+1)*bq
            hi = min(n_k, ((i + 1) * bq + bk - 1) // bk)
            lo = 0
            if cfg.window is not None:       # static local-window bound
                lo = max(0, (i * bq - cfg.window + 1) // bk)
        else:
            lo, hi = 0, n_k
        (acc, m, l, _, _), _ = jax.lax.scan(
            kv_step, (acc0, m0, l0, qi, q_pos), jnp.arange(lo, hi))
        out = acc / jnp.maximum(l[..., None], 1e-30)
        outs.append(out.transpose(0, 3, 1, 2, 4).reshape(b, bq, h, d))
    out = jnp.concatenate(outs, axis=1)[:, :sq0].astype(q.dtype)
    if cfg.sp:
        from jax.sharding import PartitionSpec as P
        u = P.UNCONSTRAINED
        out = _constrain(out, P(u, "model", None, None))
    return out


# ---------------------------------------------------------------------------
# Full-sequence (train / prefill) and decode entry points.
# ---------------------------------------------------------------------------

def attention_train(params, cfg: AttnConfig, x, positions,
                    policy: GemmPolicy):
    """x: (B, S, D) -> (B, S, D); no cache."""
    b, s, _ = x.shape
    q, k, v = _project_qkv(params, cfg, x, positions, policy)
    pos1d = positions[0]
    out = flash_attention(cfg, q, k, v, pos1d, pos1d, policy=policy)
    return dense(out.reshape(b, s, -1), params["wo"], policy, "attn")


def cache_shape(cfg: AttnConfig, batch: int, max_seq: int):
    """Local-window layers allocate a ring buffer of window size."""
    length = min(max_seq, cfg.window) if cfg.window else max_seq
    return (batch, length, cfg.n_kv_heads, cfg.head_dim)


def init_cache(cfg: AttnConfig, batch: int, max_seq: int, dtype=jnp.float32):
    shape = cache_shape(cfg, batch, max_seq)
    if cfg.cache_int8:
        sshape = shape[:-1] + (1,)
        return {"k": jnp.zeros(shape, jnp.int8),
                "v": jnp.zeros(shape, jnp.int8),
                "k_scale": jnp.zeros(sshape, jnp.float32),
                "v_scale": jnp.zeros(sshape, jnp.float32)}
    return {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype)}


def quantize_kv(x):
    """Per-(token, head) symmetric int8 quantization (B, S, KVH, D)."""
    scale = jnp.max(jnp.abs(x), axis=-1, keepdims=True).astype(jnp.float32)
    scale = jnp.maximum(scale, 1e-8) / 127.0
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale), -127, 127)
    return q.astype(jnp.int8), scale


def dequantize_kv(q, scale, dtype):
    return (q.astype(jnp.float32) * scale).astype(dtype)


def _store(cfg: AttnConfig, cache, k, v, slot: "int | jax.Array"):
    """Write fresh k/v (possibly quantized) at ``slot`` along the seq axis."""
    upd = jax.lax.dynamic_update_slice_in_dim
    if cfg.cache_int8:
        kq, ks = quantize_kv(k)
        vq, vs = quantize_kv(v)
        return {"k": upd(cache["k"], kq, slot, 1),
                "v": upd(cache["v"], vq, slot, 1),
                "k_scale": upd(cache["k_scale"], ks, slot, 1),
                "v_scale": upd(cache["v_scale"], vs, slot, 1)}
    return {"k": upd(cache["k"], k, slot, 1), "v": upd(cache["v"], v, slot, 1)}


def attention_prefill(params, cfg: AttnConfig, x, positions,
                      policy: GemmPolicy, max_seq: int):
    """Forward over the prompt; returns (out, cache filled to S)."""
    b, s, _ = x.shape
    q, k, v = _project_qkv(params, cfg, x, positions, policy)
    pos1d = positions[0]
    out = flash_attention(cfg, q, k, v, pos1d, pos1d, policy=policy)
    cache = init_cache(cfg, b, max_seq, k.dtype)
    clen = cache["k"].shape[1]
    if clen >= s:
        cache = _store(cfg, cache, k, v, 0)
    else:  # ring buffer smaller than the prompt: keep the tail, in ring
        # order so that position p sits at slot p % clen (decode contract).
        shift = (s - clen) % clen
        cache = _store(cfg, cache,
                       jnp.roll(k[:, s - clen:], shift, axis=1),
                       jnp.roll(v[:, s - clen:], shift, axis=1), 0)
    return dense(out.reshape(b, s, -1), params["wo"], policy, "attn"), cache


def _store_step(cfg: AttnConfig, cache, k, v, start):
    """Per-lane chunk store: write k/v (B, C, KVH, D) at each lane's own
    ``start`` offset (vmapped ``dynamic_update_slice`` — the ragged
    analogue of :func:`_store`, which writes one shared slot)."""
    def upd1(buf, val, s):
        return jax.lax.dynamic_update_slice_in_dim(buf, val, s, 0)
    upd = jax.vmap(upd1)
    if cfg.cache_int8:
        kq, ks = quantize_kv(k)
        vq, vs = quantize_kv(v)
        return {"k": upd(cache["k"], kq, start),
                "v": upd(cache["v"], vq, start),
                "k_scale": upd(cache["k_scale"], ks, start),
                "v_scale": upd(cache["v_scale"], vs, start)}
    return {"k": upd(cache["k"], k, start), "v": upd(cache["v"], v, start)}


def attention_step(params, cfg: AttnConfig, x, start, n_new, cache,
                   policy: GemmPolicy):
    """Ragged mixed prefill/decode step over a per-lane cache view.

    x: (B, C, D) — each lane's next chunk of (at most C) fresh tokens,
    left-aligned; start: (B,) int32 absolute position of each lane's
    first fresh token; n_new: (B,) int32 valid-token count (decode lanes
    carry 1, prefill lanes up to C, idle lanes 0). cache: the standard
    {"k","v"[,scales]} dict with *per-lane* (B, L, ...) arrays — the
    serving engine gathers these views from its paged pools
    (repro.serving.kv_cache) before calling and scatters the C fresh
    slots back after.

    Per-lane computation depends only on that lane's tokens and cache
    rows (columns >= n_new are padding whose outputs callers discard and
    whose cache writes the engine masks to the scratch page), which is
    the invariant that makes continuous-batching cohorts bit-identical
    per request to a lockstep or single-request schedule.

    Only global-attention layers support ragged views: a local-window
    ring buffer (cache length < positions written) has no per-lane
    paged layout; the serving engine refuses those architectures.
    Returns (out (B, C, D), updated cache view).
    """
    b, c, _ = x.shape
    positions = start[:, None] + jnp.arange(c, dtype=jnp.int32)   # (B, C)
    q, k, v = _project_qkv(params, cfg, x, positions, policy)
    cache = _store_step(cfg, cache, k, v, start)
    if cfg.cache_int8:
        ck = dequantize_kv(cache["k"], cache["k_scale"], x.dtype)
        cv = dequantize_kv(cache["v"], cache["v_scale"], x.dtype)
    else:
        ck, cv = cache["k"], cache["v"]
    clen = ck.shape[1]
    kvh, g = cfg.n_kv_heads, cfg.n_heads // cfg.n_kv_heads
    qh = q.reshape(b, c, kvh, g, cfg.head_dim)
    s = policy_einsum("bqkgd,bjkd->bkgqj", qh, ck, policy, "attn_qk",
                      pet=jnp.float32) * cfg.scale
    # Causal against this lane's own timeline: key rows beyond the lane's
    # freshly written frontier (start + n_new) exceed every valid q_pos,
    # so one mask covers history, intra-chunk causality, and padding.
    k_pos = jnp.arange(clen, dtype=jnp.int32)
    mask = k_pos[None, None, :] <= positions[:, :, None]          # (B, C, L)
    s = jnp.where(mask[:, None, None], s, NEG_INF)
    w = jax.nn.softmax(s, axis=-1)
    out = policy_einsum("bkgqj,bjkd->bkgqd", w.astype(cv.dtype), cv,
                        policy, "attn_av", pet=jnp.float32)
    out = out.transpose(0, 3, 1, 2, 4).reshape(b, c, cfg.n_heads
                                               * cfg.head_dim).astype(x.dtype)
    return dense(out, params["wo"], policy, "attn"), cache


def attention_decode(params, cfg: AttnConfig, x, pos, cache,
                     policy: GemmPolicy):
    """One-token step. x: (B, 1, D); pos: scalar int32 (current index).

    Global layers write at index ``pos``; local layers at ``pos % window``
    (ring buffer). Returns (out (B, 1, D), new cache).
    """
    b = x.shape[0]
    positions = jnp.full((b, 1), pos, jnp.int32)
    q, k, v = _project_qkv(params, cfg, x, positions, policy)
    clen = cache["k"].shape[1]
    slot = pos % clen if cfg.window else pos
    cache = _store(cfg, cache, k, v, slot)
    if cfg.cache_int8:
        ck = dequantize_kv(cache["k"], cache["k_scale"], x.dtype)
        cv = dequantize_kv(cache["v"], cache["v_scale"], x.dtype)
    else:
        ck, cv = cache["k"], cache["v"]

    if cfg.window:
        # Ring buffer: absolute position of slot i given current write pos.
        idx = jnp.arange(clen)
        wrapped = pos >= clen
        base = jnp.where(idx <= slot, pos - slot, pos - slot - clen)
        k_positions = jnp.where(wrapped, base + idx, idx)
        valid = jnp.where(wrapped, clen, pos + 1)
    else:
        k_positions = jnp.arange(clen)
        valid = pos + 1

    kvh, g = cfg.n_kv_heads, cfg.n_heads // cfg.n_kv_heads
    qh = q.reshape(b, kvh, g, cfg.head_dim)
    s = policy_einsum("bkgd,bjkd->bkgj", qh, ck, policy, "attn_qk",
                      pet=jnp.float32) * cfg.scale
    mask = _chunk_mask(cfg, positions[0], k_positions)[0]      # (clen,)
    mask &= jnp.arange(clen) < valid if not cfg.window else mask
    s = jnp.where(mask[None, None, None], s, NEG_INF)
    w = jax.nn.softmax(s, axis=-1)
    out = policy_einsum("bkgj,bjkd->bkgd", w.astype(cv.dtype), cv,
                        policy, "attn_av", pet=jnp.float32)
    out = out.reshape(b, 1, cfg.n_heads * cfg.head_dim).astype(x.dtype)
    return dense(out, params["wo"], policy, "attn"), cache
