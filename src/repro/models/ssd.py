"""Mamba-2 SSD (state-space duality) block.

Training/prefill uses the chunked SSD algorithm: quadratic attention-like
compute *within* chunks (all matmuls — MXU-friendly, and where the paper's
emulated-GEMM backend could plug in), plus a chunk-level scan for the
inter-chunk state recurrence. Decode is the O(1) recurrent update

    h_t = exp(dt_t A) h_{t-1} + dt_t * (B_t (x)  outer)   ;  y_t = C_t h_t + D x_t

Layout follows the minimal reference implementation: heads H with head dim
P = ``head_dim``, shared scalar decay A per head, single B/C group.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import SSDConfig
from repro.models.common import (GemmPolicy, apply_norm, dense, he_init,
                                 init_norm, policy_einsum)


def d_inner(d_model: int, cfg: SSDConfig) -> int:
    return cfg.expand * d_model


def n_heads(d_model: int, cfg: SSDConfig) -> int:
    return d_inner(d_model, cfg) // cfg.head_dim


def init_ssd(key, d_model: int, cfg: SSDConfig, dtype=jnp.float32):
    di = d_inner(d_model, cfg)
    h = n_heads(d_model, cfg)
    conv_dim = di + 2 * cfg.d_state
    ks = jax.random.split(key, 5)
    dt = jnp.exp(jax.random.uniform(ks[2], (h,), jnp.float32)
                 * (jnp.log(cfg.dt_max) - jnp.log(cfg.dt_min))
                 + jnp.log(cfg.dt_min))
    return {
        # in_proj emits [z (di), x (di), B (N), C (N), dt (H)]
        "w_in": he_init(ks[0], (d_model, 2 * di + 2 * cfg.d_state + h), dtype),
        "conv_w": (jax.random.normal(ks[1], (cfg.conv_kernel, conv_dim),
                                     jnp.float32) * 0.1).astype(dtype),
        "conv_b": jnp.zeros((conv_dim,), dtype),
        "dt_bias": jnp.log(jnp.expm1(dt)),       # softplus^{-1}(dt)
        "a_log": jnp.log(jnp.arange(1, h + 1, dtype=jnp.float32)),
        "d_skip": jnp.ones((h,), jnp.float32),
        "out_norm": init_norm("rms", di, dtype),
        "w_out": he_init(ks[3], (di, d_model), dtype, fan_in=di),
    }


def _split_proj(params, d_model: int, cfg: SSDConfig, x, policy):
    di = d_inner(d_model, cfg)
    h = n_heads(d_model, cfg)
    zxbcdt = dense(x, params["w_in"], policy, "ffn")
    z = zxbcdt[..., :di]
    xbc = zxbcdt[..., di:di + di + 2 * cfg.d_state]
    dt = jax.nn.softplus(
        zxbcdt[..., -h:].astype(jnp.float32) + params["dt_bias"])
    return z, xbc, dt


def _causal_conv(x, w, b, state=None):
    k = w.shape[0]
    if state is None:
        state = jnp.zeros((x.shape[0], k - 1, x.shape[2]), x.dtype)
    xp = jnp.concatenate([state, x], axis=1)
    y = sum(xp[:, i:i + x.shape[1]] * w[i] for i in range(k)) + b
    return jax.nn.silu(y), xp[:, -(k - 1):]


def _segsum(t):
    """Stable 'segment sum': S[..., i, j] = sum_{j < k <= i} t[..., k]."""
    s = jnp.cumsum(t, axis=-1)
    ss = s[..., :, None] - s[..., None, :]
    q = t.shape[-1]
    mask = jnp.tril(jnp.ones((q, q), bool), k=0)
    return jnp.where(mask, ss, -jnp.inf)


def ssd_chunked(xh, dt, a, bmat, cmat, d_skip, chunk: int, h0=None):
    """Chunked SSD scan.

    xh: (B, S, H, P); dt: (B, S, H); a: (H,) negative decay rates;
    bmat/cmat: (B, S, N). Returns (y (B,S,H,P), final state (B,H,P,N)).
    """
    b, s0, h, p = xh.shape
    n = bmat.shape[-1]
    q = min(chunk, s0)
    extra = (-s0) % q
    if extra:  # pad with dt=0 steps: decay-neutral, zero state update
        pad = lambda t: jnp.pad(t, [(0, 0), (0, extra)] +
                                [(0, 0)] * (t.ndim - 2))
        xh, dt, bmat, cmat = pad(xh), pad(dt), pad(bmat), pad(cmat)
    s = s0 + extra
    c = s // q
    xc = xh.reshape(b, c, q, h, p)
    dtc = dt.reshape(b, c, q, h)
    bc = bmat.reshape(b, c, q, n)
    cc = cmat.reshape(b, c, q, n)

    da = dtc * a[None, None, None, :]               # (B,C,Q,H) negative
    da_cs = jnp.cumsum(da, axis=2)                  # within-chunk cumsum
    # Intra-chunk (attention-like, all matmuls):
    l = jnp.exp(_segsum(da.transpose(0, 1, 3, 2)))  # (B,C,H,Q,Q)
    att = jnp.einsum("bcqn,bckn,bchqk->bchqk", cc, bc, l)
    y_diag = jnp.einsum("bchqk,bckh,bckhp->bcqhp", att, dtc, xc)

    # Chunk-final states: (B,C,H,P,N)
    decay_states = jnp.exp(da_cs[:, :, -1:, :] - da_cs)       # (B,C,Q,H)
    states = jnp.einsum("bcqn,bcqh,bcqhp->bchpn",
                        bc, decay_states * dtc, xc)

    # Inter-chunk recurrence over the C axis (sequential scan, C is small).
    chunk_decay = jnp.exp(da_cs[:, :, -1, :])                 # (B,C,H)

    def step(carry, inp):
        st, dec = inp
        new = carry * dec[:, :, None, None] + st
        return new, carry        # emit the *incoming* state for this chunk

    init = h0 if h0 is not None else jnp.zeros((b, h, p, n), jnp.float32)
    final, prev_states = jax.lax.scan(
        step, init,
        (states.transpose(1, 0, 2, 3, 4), chunk_decay.transpose(1, 0, 2)))
    prev_states = prev_states.transpose(1, 0, 2, 3, 4)        # (B,C,H,P,N)

    # Off-diagonal contribution from the incoming state of each chunk.
    state_decay = jnp.exp(da_cs)                              # (B,C,Q,H)
    y_off = jnp.einsum("bcqn,bcqh,bchpn->bcqhp", cc, state_decay, prev_states)

    y = (y_diag + y_off).reshape(b, s, h, p)
    y = y + d_skip[None, None, :, None] * xh
    return y[:, :s0], final


def ssd_block_train(params, d_model: int, cfg: SSDConfig, x,
                    policy: GemmPolicy):
    y, _, _ = _ssd_forward(params, d_model, cfg, x, policy, None, None)
    return y


def init_ssd_cache(cfg: SSDConfig, d_model: int, batch: int,
                   dtype=jnp.float32):
    di = d_inner(d_model, cfg)
    h = n_heads(d_model, cfg)
    conv_dim = di + 2 * cfg.d_state
    return {"conv": jnp.zeros((batch, cfg.conv_kernel - 1, conv_dim), dtype),
            "ssm": jnp.zeros((batch, h, cfg.head_dim, cfg.d_state),
                             jnp.float32)}


def ssd_block_prefill(params, d_model: int, cfg: SSDConfig, x,
                      policy: GemmPolicy):
    y, conv_state, ssm_state = _ssd_forward(params, d_model, cfg, x, policy,
                                            None, None)
    return y, {"conv": conv_state, "ssm": ssm_state}


def ssd_block_decode(params, d_model: int, cfg: SSDConfig, x, cache,
                     policy: GemmPolicy):
    """x: (B, 1, D): recurrent update, no chunking."""
    z, xbc, dt = _split_proj(params, d_model, cfg, x, policy)
    xbc, conv_state = _causal_conv(xbc, params["conv_w"], params["conv_b"],
                                   cache["conv"])
    di = d_inner(d_model, cfg)
    h = n_heads(d_model, cfg)
    xh = xbc[..., :di].reshape(x.shape[0], h, cfg.head_dim)
    bmat = xbc[:, 0, di:di + cfg.d_state]
    cmat = xbc[:, 0, di + cfg.d_state:]
    a = -jnp.exp(params["a_log"])
    dt1 = dt[:, 0]                                   # (B,H)
    decay = jnp.exp(dt1 * a)                         # (B,H)
    xf = xh.astype(jnp.float32)
    upd = jnp.einsum("bh,bhp,bn->bhpn", dt1, xf, bmat.astype(jnp.float32))
    ssm = cache["ssm"] * decay[..., None, None] + upd
    y = policy_einsum("bhpn,bn->bhp", ssm, cmat.astype(jnp.float32),
                      policy, "ssd_state")
    y = y + params["d_skip"][None, :, None] * xf
    y = y.reshape(x.shape[0], 1, di).astype(x.dtype)
    y = apply_norm("rms", params["out_norm"], y * jax.nn.silu(z))
    return dense(y, params["w_out"], policy, "ffn"), \
        {"conv": conv_state, "ssm": ssm}


def _ssd_forward(params, d_model, cfg, x, policy, conv_state, h0):
    b, s, _ = x.shape
    z, xbc, dt = _split_proj(params, d_model, cfg, x, policy)
    xbc, new_conv = _causal_conv(xbc, params["conv_w"], params["conv_b"],
                                 conv_state)
    di = d_inner(d_model, cfg)
    h = n_heads(d_model, cfg)
    xh = xbc[..., :di].reshape(b, s, h, cfg.head_dim).astype(jnp.float32)
    bmat = xbc[..., di:di + cfg.d_state].astype(jnp.float32)
    cmat = xbc[..., di + cfg.d_state:].astype(jnp.float32)
    a = -jnp.exp(params["a_log"])
    y, final = ssd_chunked(xh, dt, a, bmat, cmat, params["d_skip"],
                           cfg.chunk, h0)
    y = y.reshape(b, s, di).astype(x.dtype)
    y = apply_norm("rms", params["out_norm"], y * jax.nn.silu(z))
    return dense(y, params["w_out"], policy, "ffn"), new_conv, final
