"""Shared model primitives for the architecture zoo.

Everything is pure JAX (pytree params + functions). Dense projections route
through ``repro.core.emulated.emulated_dot`` so the paper's emulated-GEMM
backend is a first-class, per-call-site-configurable feature of every model
(attention/FFN/logits), selected by a ``GemmPolicy``.
"""

from __future__ import annotations

import dataclasses
import warnings
from typing import Mapping

import jax
import jax.numpy as jnp

from repro.core.emulated import (emulated_dot, emulated_dot_prepared,
                                 prepared_dot)
from repro.core.precision import EmulationConfig, NATIVE


# ---------------------------------------------------------------------------
# GEMM policy: which emulation config each call-site family uses.
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class GemmPolicy:
    """Per-call-site emulated-GEMM selection.

    Families: 'attn' (q/k/v/o projections), 'ffn' (MLP/expert matmuls),
    'logits' (output head), 'emb' (input projections of stub frontends).
    Anything absent falls back to ``default``; a ``default`` of None
    (unset) defers to the ambient resolver (``repro.resolve_config``:
    innermost ``repro.emulation`` scope > ``REPRO_EMULATION`` env >
    native), so a model built with the bare ``GemmPolicy()`` becomes
    emulated simply by running it inside a scope.

    ``mesh`` is the launch mesh fused call-sites shard_map over — set by
    ``dispatch.resolve_policy`` when it keeps fused impls on a concrete
    multi-device mesh (the GSPMD-native path of
    ``repro.parallel.shard_gemm``); None means single-device / clamped
    launches, where ``dense`` consumes the emulated dot directly.
    """
    default: EmulationConfig | None = None
    overrides: tuple[tuple[str, EmulationConfig], ...] = ()
    mesh: object | None = None

    def for_site(self, site: str) -> EmulationConfig:
        for name, cfg in self.overrides:
            if name == site:
                return cfg
        if self.default is not None:
            return self.default
        from repro import api
        return api.resolve_config()


# Pins native explicitly — reference/oracle paths stay exact fp32 even
# inside an ambient emulation scope. (A bare GemmPolicy() is the
# ambient-deferring policy; this named constant must not defer.)
NATIVE_POLICY = GemmPolicy(default=NATIVE)


def parse_gemm_spec(spec: str) -> EmulationConfig:
    """Deprecated: use ``repro.precision`` (the unified spec grammar).

    Kept for pre-spec callers; accepts the historical grammar ('native',
    'ozaki1-p4', 'ozaki2-p9', '-cached' suffix) and pins ``impl='xla'``
    the way model-level call-sites always did. ``repro.precision`` +
    ``dispatch.resolve_policy`` subsume both jobs: the new specs carry
    '+cached'/'+xla' suffixes and the policy resolver clamps fused impls
    wherever GSPMD must partition.
    """
    warnings.warn(
        "parse_gemm_spec is deprecated; use repro.precision('<spec>') "
        "(note the '+cached' spelling) — resolve_policy pins impl where "
        "partitioning requires it",
        DeprecationWarning, stacklevel=2)
    if spec == "native":
        return NATIVE
    cached = spec.endswith("-cached")
    if cached:
        spec = spec[:-len("-cached")]
    scheme, _, ps = spec.partition("-p")
    if scheme not in ("ozaki1", "ozaki2") or not ps.isdigit():
        raise ValueError(f"bad gemm spec {spec!r}")
    if cached and scheme != "ozaki1":
        raise ValueError("'-cached' is a Scheme-I (ozaki1) feature")
    return EmulationConfig(scheme=scheme, p=int(ps),  # type: ignore[arg-type]
                           impl="xla", cache_weights=cached)


def dense(x: jax.Array, w, policy: GemmPolicy, site: str,
          bias: jax.Array | None = None) -> jax.Array:
    """x: (..., K) @ w: (K, N) under the policy's emulation config.

    When telemetry is enabled the whole call runs inside
    ``telemetry.call_site(site)``, so every emulated GEMM (and guard
    event) it dispatches is labeled with this call-site family; disabled,
    the context manager is skipped entirely.

    ``w`` may be a :class:`repro.kernels.prepared.PreparedOperand`
    (see ``prepared.prepare_params`` — once-per-session serving reuse):
    its finished int8 slices are consumed directly, whatever the policy
    says, since the decomposition choice was made at prepare time.  A
    :class:`repro.kernels.prepared.StepPrepared` pair (float weight +
    once-per-step prep, attached outside the microbatch scan by
    ``launch/steps.py``) routes through ``emulated_dot_prepared`` so the
    forward streams finished slices while dB still reaches the weight.

    When the policy carries a multi-device ``mesh`` (recorded by
    ``dispatch.resolve_policy`` on shardable launches) and the site's
    config is fused, the projection runs per-shard under ``shard_map``
    (``repro.parallel.shard_gemm.sharded_dense``) — the GSPMD-native
    path; shapes the partitioner cannot fit fall back to the direct
    routes below, which still compile under GSPMD (just unpartitioned).
    """
    from repro import telemetry
    if telemetry.enabled():
        with telemetry.call_site(site):
            return _dense(x, w, policy, site, bias)
    return _dense(x, w, policy, site, bias)


def _dense(x: jax.Array, w, policy: GemmPolicy, site: str,
           bias: jax.Array | None = None) -> jax.Array:
    cfg = policy.for_site(site)
    mesh = getattr(policy, "mesh", None)
    if (mesh is not None and cfg.scheme != "native"
            and cfg.impl in ("auto", "pallas")):
        from repro.parallel import shard_gemm
        out = shard_gemm.sharded_dense(x, w, cfg, mesh)
        if out is not None:
            out = out.astype(x.dtype)
            return out if bias is None else out + bias
    if not isinstance(w, jax.Array) and hasattr(w, "prep"):
        out = emulated_dot_prepared(x, w.w, w.prep, cfg).astype(x.dtype)
        return out if bias is None else out + bias
    if not isinstance(w, jax.Array) and hasattr(w, "slices"):
        out = prepared_dot(x, w).astype(x.dtype)
        return out if bias is None else out + bias
    if cfg.scheme == "native":
        out = jnp.einsum("...k,kn->...n", x, w)
    else:
        out = emulated_dot(x, w, cfg).astype(x.dtype)
    if bias is not None:
        out = out + bias
    return out


def policy_einsum(eq: str, x: jax.Array, y: jax.Array, policy: GemmPolicy,
                  site: str, pet=None) -> jax.Array:
    """Two-operand einsum under the policy's per-site emulation config.

    The native path is *exactly* ``jnp.einsum(eq, x, y,
    preferred_element_type=pet)`` — bit-identical to the unwrapped call —
    so wiring a model contraction through here changes nothing until a
    policy override (or the ambient resolver, for a bare ``GemmPolicy()``)
    selects an emulation scheme for ``site``.  Emulated calls route
    through :func:`repro.api.einsum`, whose canonicalized batched core
    takes the strided-batched fused lowering when the resolved backend
    advertises ``BackendCapabilities.batched``; the whole call is labeled
    with ``site`` for telemetry, same as :func:`dense`.

    Sites wired through this helper (docs/observability.md): 'attn_qk',
    'attn_av' (score / weighted-value contractions), 'moe_gate',
    'moe_expert', 'mla_latent' (KV decompression), 'ssd_state'.
    """
    cfg = policy.for_site(site)
    if cfg.scheme == "native":
        return jnp.einsum(eq, x, y, preferred_element_type=pet)
    if cfg.cache_weights:
        # '+cached' means once-per-step rhs preparation, which only the
        # dense-projection hoist in launch/steps.py provides; these
        # einsum sites sit inside the microbatch scan, where honoring
        # the flag would re-prepare every microbatch instead.
        import dataclasses
        cfg = dataclasses.replace(cfg, cache_weights=False)
    from repro import api, telemetry
    if telemetry.enabled():
        with telemetry.call_site(site):
            out = api.einsum(eq, x, y, precision=cfg)
    else:
        out = api.einsum(eq, x, y, precision=cfg)
    return out if pet is None else out.astype(pet)


# ---------------------------------------------------------------------------
# Initializers (numpy-free: jax.random so init can itself be jitted/sharded).
# ---------------------------------------------------------------------------

def he_init(key, shape, dtype=jnp.float32, fan_in: int | None = None):
    fan = fan_in if fan_in is not None else shape[0]
    return (jax.random.normal(key, shape, dtype=jnp.float32)
            * (2.0 / max(1, fan)) ** 0.5).astype(dtype)


def emb_init(key, shape, dtype=jnp.float32):
    return (jax.random.normal(key, shape, jnp.float32) * 0.02).astype(dtype)


# ---------------------------------------------------------------------------
# Norms. 'nonparam' is OLMo-style non-parametric LayerNorm (no scale/bias).
# ---------------------------------------------------------------------------

def init_norm(kind: str, d: int, dtype=jnp.float32):
    if kind == "rms":
        return {"scale": jnp.ones((d,), dtype)}
    if kind == "layernorm":
        return {"scale": jnp.ones((d,), dtype), "bias": jnp.zeros((d,), dtype)}
    if kind == "nonparam":
        return {}
    raise ValueError(f"unknown norm kind {kind!r}")


def apply_norm(kind: str, params: Mapping[str, jax.Array], x: jax.Array,
               eps: float = 1e-6) -> jax.Array:
    xf = x.astype(jnp.float32)
    if kind == "rms":
        y = xf * jax.lax.rsqrt(jnp.mean(xf * xf, -1, keepdims=True) + eps)
        return (y * params["scale"].astype(jnp.float32)).astype(x.dtype)
    mean = jnp.mean(xf, -1, keepdims=True)
    var = jnp.mean(jnp.square(xf - mean), -1, keepdims=True)
    y = (xf - mean) * jax.lax.rsqrt(var + eps)
    if kind == "layernorm":
        y = y * params["scale"].astype(jnp.float32) \
            + params["bias"].astype(jnp.float32)
    return y.astype(x.dtype)


# ---------------------------------------------------------------------------
# Rotary position embeddings (full or partial head-dim coverage).
# ---------------------------------------------------------------------------

def rope_frequencies(head_dim: int, theta: float = 10000.0) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32)
                            / head_dim))


def apply_rope(x: jax.Array, positions: jax.Array,
               theta: float = 10000.0, rot_dim: int | None = None) -> jax.Array:
    """x: (B, S, H, D); positions: (B, S). Rotates the first rot_dim dims."""
    d = x.shape[-1]
    rot = d if rot_dim is None else rot_dim
    freqs = rope_frequencies(rot, theta)                      # (rot/2,)
    angles = positions[..., None].astype(jnp.float32) * freqs  # (B, S, rot/2)
    cos = jnp.cos(angles)[:, :, None, :]
    sin = jnp.sin(angles)[:, :, None, :]
    xr, xp = x[..., :rot], x[..., rot:]
    x1, x2 = jnp.split(xr.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return jnp.concatenate([out.astype(x.dtype), xp], axis=-1)


# ---------------------------------------------------------------------------
# FFN activations.
# ---------------------------------------------------------------------------

def _pin(x, spec_parts):
    """with_sharding_constraint that no-ops without an active mesh."""
    try:
        from jax.sharding import PartitionSpec as P
        return jax.lax.with_sharding_constraint(x, P(*spec_parts))
    except (RuntimeError, ValueError):
        return x


def init_ffn(key, d_model: int, d_ff: int, act: str, dtype=jnp.float32):
    k1, k2, k3 = jax.random.split(key, 3)
    if act in ("swiglu", "geglu"):
        return {
            "wi_gate": he_init(k1, (d_model, d_ff), dtype),
            "wi_up": he_init(k2, (d_model, d_ff), dtype),
            "wo": he_init(k3, (d_ff, d_model), dtype, fan_in=d_ff),
        }
    return {
        "wi": he_init(k1, (d_model, d_ff), dtype),
        "wo": he_init(k2, (d_ff, d_model), dtype, fan_in=d_ff),
    }


def apply_ffn(params, x: jax.Array, act: str, policy: GemmPolicy,
              site: str = "ffn", sp: bool = False) -> jax.Array:
    from jax.sharding import PartitionSpec as P
    hidden_spec = (P.UNCONSTRAINED, None, "model")
    if act in ("swiglu", "geglu"):
        gate = dense(x, params["wi_gate"], policy, site)
        up = dense(x, params["wi_up"], policy, site)
        if sp and x.ndim == 3:
            # Megatron-SP: hidden stays TP-sharded on d_ff; without this
            # pin GSPMD may all-gather the weight and compute the full
            # d_ff on every device.
            gate = _pin(gate, hidden_spec)
            up = _pin(up, hidden_spec)
        gate = jax.nn.silu(gate) if act == "swiglu" else jax.nn.gelu(gate)
        return dense(gate * up, params["wo"], policy, site)
    h = dense(x, params["wi"], policy, site)
    if sp and x.ndim == 3:
        h = _pin(h, hidden_spec)
    h = jax.nn.gelu(h)
    return dense(h, params["wo"], policy, site)


# ---------------------------------------------------------------------------
# Misc.
# ---------------------------------------------------------------------------

def pad_vocab(vocab: int, multiple: int = 512) -> int:
    """Pad the embedding/logit vocab so it shards over any mesh axis and
    stays MXU-lane aligned (Megatron-style padded vocab)."""
    return ((vocab + multiple - 1) // multiple) * multiple


def cross_entropy_loss(logits: jax.Array, labels: jax.Array,
                       vocab: int) -> jax.Array:
    """Mean token NLL; labels >= vocab (padding ids) are masked out."""
    logits = logits.astype(jnp.float32)
    mask = (labels >= 0) & (labels < vocab)
    safe = jnp.where(mask, labels, 0)
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, safe[..., None], axis=-1)[..., 0]
    nll = (logz - gold) * mask
    return nll.sum() / jnp.maximum(mask.sum(), 1)
