"""Residual blocks: (pre-norm mixer) + (pre-norm FFN/MoE), per block kind.

Kinds:
  attn — GQA attention (or MLA when cfg.mla is set) + dense FFN or MoE
  rec  — RG-LRU recurrent mixer + dense FFN
  ssd  — Mamba-2 SSD mixer (no separate FFN, following Mamba-2)
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import attention, mla, moe, rglru, ssd
from repro.models.attention import AttnConfig
from repro.models.common import (GemmPolicy, apply_ffn, apply_norm, init_ffn,
                                 init_norm)


def attn_config(mcfg: ModelConfig, local: bool = False) -> AttnConfig:
    return AttnConfig(
        d_model=mcfg.d_model, n_heads=mcfg.n_heads,
        n_kv_heads=mcfg.n_kv_heads, head_dim=mcfg.resolved_head_dim,
        qkv_bias=mcfg.qkv_bias, causal=mcfg.causal,
        window=mcfg.attn_window if local or mcfg.attn_window else None,
        rope_theta=mcfg.rope_theta, use_rope=mcfg.causal,
        q_chunk=mcfg.q_chunk, kv_chunk=mcfg.kv_chunk,
        cache_int8=mcfg.kv_cache_dtype == "int8",
        sp=mcfg.attn_sharding == "sp")


def init_block(key, kind: str, mcfg: ModelConfig, dtype):
    k1, k2, k3, k4 = jax.random.split(key, 4)
    d = mcfg.d_model
    p = {"ln1": init_norm(mcfg.norm, d, dtype)}
    if kind == "attn":
        if mcfg.mla is not None:
            p["mixer"] = mla.init_mla(k1, d, mcfg.n_heads, mcfg.mla, dtype)
        else:
            p["mixer"] = attention.init_attention(k1, attn_config(mcfg), dtype)
        p["ln2"] = init_norm(mcfg.norm, d, dtype)
        if mcfg.moe is not None:
            p["moe"] = moe.init_moe(k2, d, mcfg.moe, mcfg.act, dtype)
        else:
            p["ffn"] = init_ffn(k2, d, mcfg.d_ff, mcfg.act, dtype)
    elif kind == "rec":
        p["mixer"] = rglru.init_rglru(k1, d, mcfg.rglru, dtype)
        p["ln2"] = init_norm(mcfg.norm, d, dtype)
        p["ffn"] = init_ffn(k2, d, mcfg.d_ff, mcfg.act, dtype)
    elif kind == "ssd":
        p["mixer"] = ssd.init_ssd(k1, d, mcfg.ssd, dtype)
    else:
        raise ValueError(f"unknown block kind {kind!r}")
    return p


def _sp_constrain(x, mcfg: ModelConfig):
    """Pin (B, S, D) activations to the sequence-parallel layout."""
    if mcfg.attn_sharding != "sp" or x.shape[1] <= 1:
        return x
    from jax.sharding import PartitionSpec as P
    from repro.models.attention import _constrain
    return _constrain(x, P(P.UNCONSTRAINED, "model", None))


def _ffn_part(params, mcfg: ModelConfig, x, policy):
    h = apply_norm(mcfg.norm, params["ln2"], x)
    if "moe" in params:
        out, aux = moe.apply_moe(params["moe"], h, mcfg.moe, mcfg.act, policy)
    else:
        out = apply_ffn(params["ffn"], h, mcfg.act, policy,
                        sp=mcfg.attn_sharding == "sp")
        aux = 0.0
    # Megatron-SP pattern: the TP FFN's output reduce-scatters back onto
    # the sequence axis instead of all-reducing.
    out = _sp_constrain(out, mcfg)
    return x + out, aux


def block_train(params, kind: str, mcfg: ModelConfig, x, positions,
                policy: GemmPolicy):
    h = apply_norm(mcfg.norm, params["ln1"], x)
    if kind == "attn":
        if mcfg.mla is not None:
            mix = mla.mla_train(params["mixer"], mcfg.mla, mcfg.n_heads, h,
                                positions, policy, mcfg.kv_chunk)
        else:
            mix = attention.attention_train(params["mixer"], attn_config(mcfg),
                                            h, positions, policy)
        x = x + mix
        return _ffn_part(params, mcfg, x, policy)
    if kind == "rec":
        x = x + rglru.rglru_block_train(params["mixer"], mcfg.rglru, h, policy)
        return _ffn_part(params, mcfg, x, policy)
    if kind == "ssd":
        return x + ssd.ssd_block_train(params["mixer"], mcfg.d_model,
                                       mcfg.ssd, h, policy), 0.0
    raise ValueError(kind)


def init_block_cache(kind: str, mcfg: ModelConfig, batch: int, max_seq: int,
                     dtype):
    if kind == "attn":
        if mcfg.mla is not None:
            return mla.init_mla_cache(mcfg.mla, batch, max_seq, dtype)
        return attention.init_cache(attn_config(mcfg), batch, max_seq, dtype)
    if kind == "rec":
        return rglru.init_rglru_cache(mcfg.rglru, mcfg.d_model, batch, dtype)
    if kind == "ssd":
        return ssd.init_ssd_cache(mcfg.ssd, mcfg.d_model, batch, dtype)
    raise ValueError(kind)


def block_prefill(params, kind: str, mcfg: ModelConfig, x, positions,
                  policy: GemmPolicy, max_seq: int):
    h = apply_norm(mcfg.norm, params["ln1"], x)
    if kind == "attn":
        if mcfg.mla is not None:
            mix, cache = mla.mla_prefill(params["mixer"], mcfg.mla,
                                         mcfg.n_heads, h, positions, policy,
                                         max_seq, mcfg.kv_chunk)
        else:
            mix, cache = attention.attention_prefill(
                params["mixer"], attn_config(mcfg), h, positions, policy,
                max_seq)
        x = x + mix
        x, _ = _ffn_part(params, mcfg, x, policy)
        return x, cache
    if kind == "rec":
        mix, cache = rglru.rglru_block_prefill(params["mixer"], mcfg.rglru,
                                               h, policy)
        x = x + mix
        x, _ = _ffn_part(params, mcfg, x, policy)
        return x, cache
    if kind == "ssd":
        mix, cache = ssd.ssd_block_prefill(params["mixer"], mcfg.d_model,
                                           mcfg.ssd, h, policy)
        return x + mix, cache
    raise ValueError(kind)


def block_step(params, kind: str, mcfg: ModelConfig, x, start, n_new, cache,
               policy: GemmPolicy):
    """Ragged serving step: per-lane chunk positions instead of one shared
    scalar ``pos`` (repro.serving engine; see attention.attention_step).
    Only attention-family blocks have a paged per-lane cache layout —
    rec/ssd state caches are rejected by the serving engine up front."""
    h = apply_norm(mcfg.norm, params["ln1"], x)
    if kind == "attn":
        if mcfg.mla is not None:
            mix, cache = mla.mla_step(params["mixer"], mcfg.mla,
                                      mcfg.n_heads, h, start, n_new, cache,
                                      policy)
        else:
            mix, cache = attention.attention_step(
                params["mixer"], attn_config(mcfg), h, start, n_new, cache,
                policy)
        x = x + mix
        x, _ = _ffn_part(params, mcfg, x, policy)
        return x, cache
    raise NotImplementedError(
        f"block kind {kind!r} has no ragged serving step: rec/ssd state "
        "caches are lane-bound, not paged (repro.serving supports "
        "attention-family architectures)")


def block_decode(params, kind: str, mcfg: ModelConfig, x, pos, cache,
                 policy: GemmPolicy):
    h = apply_norm(mcfg.norm, params["ln1"], x)
    if kind == "attn":
        if mcfg.mla is not None:
            mix, cache = mla.mla_decode(params["mixer"], mcfg.mla,
                                        mcfg.n_heads, h, pos, cache, policy)
        else:
            mix, cache = attention.attention_decode(
                params["mixer"], attn_config(mcfg), h, pos, cache, policy)
        x = x + mix
        x, _ = _ffn_part(params, mcfg, x, policy)
        return x, cache
    if kind == "rec":
        mix, cache = rglru.rglru_block_decode(params["mixer"], mcfg.rglru,
                                              h, cache, policy)
        x = x + mix
        x, _ = _ffn_part(params, mcfg, x, policy)
        return x, cache
    if kind == "ssd":
        mix, cache = ssd.ssd_block_decode(params["mixer"], mcfg.d_model,
                                          mcfg.ssd, h, cache, policy)
        return x + mix, cache
    raise ValueError(kind)
