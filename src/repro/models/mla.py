"""Multi-head Latent Attention (DeepSeek-V3).

Queries and keys/values are low-rank compressed; the KV cache stores only
the 512-dim latent ``c_kv`` plus the 64-dim shared RoPE key per token
(~9x smaller than a GQA cache at 128 heads).

* train/prefill: flash-style online softmax where each KV chunk is
  *decompressed on the fly* from c_kv — the full (S, H, 192) key tensor is
  never materialized (this is what lets the 32k prefill cell fit HBM).
* decode: the absorbed formulation — W_UK is folded into the query and
  W_UV into the output, so attention runs directly against the latent
  cache with per-head 512-dim scores. No decompression at all.
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.configs.base import MLAConfig
from repro.models.common import (GemmPolicy, apply_norm, dense, he_init,
                                 init_norm, policy_einsum)

NEG_INF = -1e30


def init_mla(key, d_model: int, n_heads: int, cfg: MLAConfig,
             dtype=jnp.float32):
    ks = jax.random.split(key, 6)
    qk_dim = cfg.qk_nope_dim + cfg.qk_rope_dim
    return {
        "wq_a": he_init(ks[0], (d_model, cfg.q_lora_rank), dtype),
        "q_norm": init_norm("rms", cfg.q_lora_rank, dtype),
        "wq_b": he_init(ks[1], (cfg.q_lora_rank, n_heads * qk_dim), dtype),
        "wkv_a": he_init(ks[2], (d_model, cfg.kv_lora_rank + cfg.qk_rope_dim),
                         dtype),
        "kv_norm": init_norm("rms", cfg.kv_lora_rank, dtype),
        "wkv_b": he_init(ks[3], (cfg.kv_lora_rank,
                                 n_heads * (cfg.qk_nope_dim + cfg.v_dim)),
                         dtype),
        "wo": he_init(ks[4], (n_heads * cfg.v_dim, d_model),
                      dtype, fan_in=n_heads * cfg.v_dim),
    }


def _rope_1d(x, positions, theta=10000.0):
    """x: (B, S, R) shared rope key (headless)."""
    return _rope_heads(x[:, :, None, :], positions, theta)[:, :, 0, :]


def _rope_heads(x, positions, theta=10000.0):
    from repro.models import common
    return common.apply_rope(x, positions, theta)


def _queries(params, cfg: MLAConfig, n_heads, x, positions, policy):
    from jax.sharding import PartitionSpec as P
    from repro.models.attention import _constrain
    b, s, _ = x.shape
    q_lat = dense(x, params["wq_a"], policy, "attn")
    q_lat = apply_norm("rms", params["q_norm"], q_lat)
    q = dense(q_lat, params["wq_b"], policy, "attn")
    q = q.reshape(b, s, n_heads, cfg.qk_nope_dim + cfg.qk_rope_dim)
    # Pin TP to the *head* axis (when GSPMD splits the (H*d)@model dim of
    # the projection it may otherwise shard the minor per-head dim, which
    # turns every score einsum into a partial-sum all-reduce) and the
    # batch to 'data' (UNCONSTRAINED lets the loop replicate it).
    q = _constrain(q, P("data", None, "model", None))
    q_nope, q_pe = q[..., :cfg.qk_nope_dim], q[..., cfg.qk_nope_dim:]
    q_pe = _rope_heads(q_pe, positions)
    return q_nope, q_pe


def _latents(params, cfg: MLAConfig, x, positions, policy):
    kv = dense(x, params["wkv_a"], policy, "attn")
    c_kv = apply_norm("rms", params["kv_norm"], kv[..., :cfg.kv_lora_rank])
    k_pe = _rope_1d(kv[..., cfg.kv_lora_rank:], positions)
    return c_kv, k_pe


def _wkv_b_split(params, cfg: MLAConfig, n_heads):
    from jax.sharding import PartitionSpec as P
    from repro.models.attention import _constrain
    w = params["wkv_b"].reshape(cfg.kv_lora_rank, n_heads,
                                cfg.qk_nope_dim + cfg.v_dim)
    w = _constrain(w, P(None, "model", None))  # TP on heads, not per-head d
    return w[..., :cfg.qk_nope_dim], w[..., cfg.qk_nope_dim:]  # w_uk, w_uv


def mla_train(params, cfg: MLAConfig, n_heads, x, positions,
              policy: GemmPolicy, kv_chunk: int = 1024):
    """Full-sequence MLA attention; returns (B, S, D)."""
    out, _, _ = _mla_full(params, cfg, n_heads, x, positions, policy,
                          kv_chunk)
    return out


def mla_prefill(params, cfg: MLAConfig, n_heads, x, positions,
                policy: GemmPolicy, max_seq: int, kv_chunk: int = 1024):
    out, c_kv, k_pe = _mla_full(params, cfg, n_heads, x, positions, policy,
                                kv_chunk)
    b, s = x.shape[0], x.shape[1]
    cache = init_mla_cache(cfg, b, max_seq, c_kv.dtype)
    cache = {
        "c_kv": jax.lax.dynamic_update_slice_in_dim(cache["c_kv"], c_kv, 0, 1),
        "k_pe": jax.lax.dynamic_update_slice_in_dim(cache["k_pe"], k_pe, 0, 1),
    }
    return out, cache


def init_mla_cache(cfg: MLAConfig, batch: int, max_seq: int,
                   dtype=jnp.float32):
    return {"c_kv": jnp.zeros((batch, max_seq, cfg.kv_lora_rank), dtype),
            "k_pe": jnp.zeros((batch, max_seq, cfg.qk_rope_dim), dtype)}


def _mla_full(params, cfg: MLAConfig, n_heads, x, positions, policy,
              kv_chunk):
    """Causal flash attention with on-the-fly KV decompression."""
    b, s, _ = x.shape
    q_nope, q_pe = _queries(params, cfg, n_heads, x, positions, policy)
    c_kv, k_pe = _latents(params, cfg, x, positions, policy)
    w_uk, w_uv = _wkv_b_split(params, cfg, n_heads)
    scale = 1.0 / math.sqrt(cfg.qk_nope_dim + cfg.qk_rope_dim)

    bq = min(kv_chunk, s)
    bk = min(kv_chunk, s)
    n_q, n_k = s // bq, s // bk
    assert s % bq == 0, (s, bq)
    pos1d = positions[0]

    from jax.sharding import PartitionSpec as P
    from repro.models.attention import _constrain
    head_spec = P("data", "model", None, None)   # (B@data, H@model, bq, *)

    def kv_step(carry, idx):
        acc, m, l, qn, qp, qpos = carry
        cj = jax.lax.dynamic_slice_in_dim(c_kv, idx * bk, bk, 1)  # (B,bk,L)
        pj = jax.lax.dynamic_slice_in_dim(k_pe, idx * bk, bk, 1)  # (B,bk,R)
        kpos = jax.lax.dynamic_slice_in_dim(pos1d, idx * bk, bk)
        # Decompress just this chunk: (B, bk, H, nope) and (B, bk, H, v).
        k_nope = policy_einsum("blc,chd->blhd", cj, w_uk, policy,
                               "mla_latent")
        vj = policy_einsum("blc,chd->blhd", cj, w_uv, policy, "mla_latent")
        s_ij = (jnp.einsum("bqhd,bjhd->bhqj", qn, k_nope,
                           preferred_element_type=jnp.float32)
                + jnp.einsum("bqhr,bjr->bhqj", qp, pj,
                             preferred_element_type=jnp.float32)) * scale
        # Pin the scores head-sharded so the scan backward stays sharded
        # (scan carries are a GSPMD propagation blind spot — see
        # EXPERIMENTS.md §Perf cell A iteration 2).
        s_ij = _constrain(s_ij, head_spec)
        mask = (qpos[:, None] - kpos[None, :]) >= 0
        s_ij = jnp.where(mask[None, None], s_ij, NEG_INF)
        m_new = jnp.maximum(m, s_ij.max(-1))
        pij = jnp.exp(s_ij - m_new[..., None])
        alpha = jnp.exp(m - m_new)
        l = l * alpha + pij.sum(-1)
        acc = acc * alpha[..., None] + jnp.einsum(
            "bhqj,bjhd->bhqd", pij.astype(vj.dtype), vj,
            preferred_element_type=jnp.float32)
        return (acc, m_new, l, qn, qp, qpos), None

    outs = []
    for i in range(n_q):
        qn = jax.lax.dynamic_slice_in_dim(q_nope, i * bq, bq, 1)
        qp = jax.lax.dynamic_slice_in_dim(q_pe, i * bq, bq, 1)
        qpos = jax.lax.dynamic_slice_in_dim(pos1d, i * bq, bq)
        acc0 = _constrain(jnp.zeros((b, n_heads, bq, cfg.v_dim),
                                    jnp.float32), head_spec)
        m0 = _constrain(jnp.full((b, n_heads, bq), NEG_INF, jnp.float32),
                        P("data", "model", None))
        l0 = _constrain(jnp.zeros((b, n_heads, bq), jnp.float32),
                        P("data", "model", None))
        (acc, m, l, _, _, _), _ = jax.lax.scan(
            kv_step, (acc0, m0, l0, qn, qp, qpos), jnp.arange(0, i + 1))
        out = acc / jnp.maximum(l[..., None], 1e-30)
        outs.append(out.transpose(0, 2, 1, 3).reshape(b, bq, -1))
    out = jnp.concatenate(outs, 1).astype(x.dtype)
    return dense(out, params["wo"], policy, "attn"), c_kv, k_pe


def mla_step(params, cfg: MLAConfig, n_heads, x, start, n_new, cache,
             policy: GemmPolicy):
    """Ragged mixed prefill/decode step against per-lane latent views.

    The absorbed decode formulation generalized to C queries per lane:
    x (B, C, D) fresh tokens, start (B,) per-lane absolute position of
    the first, n_new (B,) valid counts (see attention.attention_step for
    the padding/masking contract). cache holds per-lane views
    {c_kv (B, L, lora), k_pe (B, L, rope)} — paged by the serving
    engine. Returns (out (B, C, D), updated cache view).
    """
    b, c, _ = x.shape
    positions = start[:, None] + jnp.arange(c, dtype=jnp.int32)   # (B, C)
    q_nope, q_pe = _queries(params, cfg, n_heads, x, positions, policy)
    c_new, p_new = _latents(params, cfg, x, positions, policy)

    def upd1(buf, val, s):
        return jax.lax.dynamic_update_slice_in_dim(buf, val, s, 0)
    upd = jax.vmap(upd1)
    ck = upd(cache["c_kv"], c_new, start)
    pk = upd(cache["k_pe"], p_new, start)
    w_uk, w_uv = _wkv_b_split(params, cfg, n_heads)
    scale = 1.0 / math.sqrt(cfg.qk_nope_dim + cfg.qk_rope_dim)

    q_abs = jnp.einsum("bqhd,chd->bqhc", q_nope, w_uk)
    s_lat = jnp.einsum("bqhc,bsc->bhqs", q_abs, ck,
                       preferred_element_type=jnp.float32)
    s_pe = jnp.einsum("bqhr,bsr->bhqs", q_pe, pk,
                      preferred_element_type=jnp.float32)
    scores = (s_lat + s_pe) * scale
    k_pos = jnp.arange(ck.shape[1], dtype=jnp.int32)
    mask = k_pos[None, None, :] <= positions[:, :, None]          # (B, C, S)
    scores = jnp.where(mask[:, None], scores, NEG_INF)
    w = jax.nn.softmax(scores, axis=-1)
    ctx = jnp.einsum("bhqs,bsc->bqhc", w.astype(ck.dtype), ck,
                     preferred_element_type=jnp.float32)
    out = jnp.einsum("bqhc,chd->bqhd", ctx.astype(x.dtype), w_uv)
    out = out.reshape(b, c, -1)
    return dense(out, params["wo"], policy, "attn"), \
        {"c_kv": ck, "k_pe": pk}


def mla_decode(params, cfg: MLAConfig, n_heads, x, pos, cache,
               policy: GemmPolicy):
    """Absorbed one-token step against the latent cache.

    x: (B, 1, D); cache: {c_kv (B, S, L), k_pe (B, S, R)}.
    """
    b = x.shape[0]
    positions = jnp.full((b, 1), pos, jnp.int32)
    q_nope, q_pe = _queries(params, cfg, n_heads, x, positions, policy)
    c_new, p_new = _latents(params, cfg, x, positions, policy)
    ck = jax.lax.dynamic_update_slice_in_dim(cache["c_kv"], c_new, pos, 1)
    pk = jax.lax.dynamic_update_slice_in_dim(cache["k_pe"], p_new, pos, 1)
    w_uk, w_uv = _wkv_b_split(params, cfg, n_heads)
    scale = 1.0 / math.sqrt(cfg.qk_nope_dim + cfg.qk_rope_dim)

    # Absorb W_UK into the query: (B, H, L) latent-space queries.
    q_abs = jnp.einsum("bqhd,chd->bhc", q_nope, w_uk)
    s_lat = jnp.einsum("bhc,bsc->bhs", q_abs, ck,
                       preferred_element_type=jnp.float32)
    s_pe = jnp.einsum("bqhr,bsr->bhs", q_pe, pk,
                      preferred_element_type=jnp.float32)
    scores = (s_lat + s_pe) * scale
    valid = jnp.arange(ck.shape[1]) <= pos
    scores = jnp.where(valid[None, None], scores, NEG_INF)
    w = jax.nn.softmax(scores, axis=-1)
    ctx = jnp.einsum("bhs,bsc->bhc", w.astype(ck.dtype), ck,
                     preferred_element_type=jnp.float32)   # latent context
    out = jnp.einsum("bhc,chd->bhd", ctx.astype(x.dtype), w_uv)  # absorb W_UV
    out = out.reshape(b, 1, -1)
    return dense(out, params["wo"], policy, "attn"), \
        {"c_kv": ck, "k_pe": pk}
