"""Top-level language model: embeddings -> scanned blocks -> head.

Layers are grouped by the (possibly heterogeneous) ``block_pattern`` and
executed with ``jax.lax.scan`` over stacked group parameters, so compile
time and HLO size are O(1) in depth — essential for lowering 61-layer
models against a 512-device mesh. A remainder of ``n_layers % len(pattern)``
trailing blocks runs unscanned.

Entry points:
  init_params      — (also usable under jax.eval_shape for the dry-run)
  forward_train    — (B, S) tokens -> (logits, mtp_logits|None, aux_loss)
  forward_prefill  — prompt -> (last-position logits, cache)
  forward_decode   — one token + cache -> (logits, new cache)
"""

from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import blocks as B
from repro.models.common import (GemmPolicy, NATIVE_POLICY, apply_norm, dense,
                                 emb_init, he_init, init_norm, pad_vocab)


def _groups(mcfg: ModelConfig):
    pat = list(mcfg.block_pattern)
    n_groups = mcfg.n_layers // len(pat)
    tail = mcfg.pattern_for_layers()[n_groups * len(pat):]
    return pat, n_groups, tail


def init_params(key, mcfg: ModelConfig):
    dtype = jnp.dtype(mcfg.dtype)
    pat, n_groups, tail = _groups(mcfg)
    keys = jax.random.split(key, 6)
    vp = pad_vocab(mcfg.vocab)
    params = {"emb": emb_init(keys[0], (vp, mcfg.d_model), dtype),
              "ln_f": init_norm(mcfg.norm, mcfg.d_model, dtype)}
    if not mcfg.tie_embeddings:
        params["head"] = he_init(keys[1], (mcfg.d_model, vp), dtype)
    if mcfg.frontend in ("audio_stub", "vision_stub"):
        params["frontend_proj"] = he_init(
            keys[2], (mcfg.frontend_dim, mcfg.d_model), dtype)

    def init_group(k):
        gk = jax.random.split(k, len(pat))
        return {f"b{j}": B.init_block(gk[j], kind, mcfg, dtype)
                for j, kind in enumerate(pat)}

    if n_groups:
        params["layers"] = jax.vmap(init_group)(
            jax.random.split(keys[3], n_groups))
    if tail:
        tk = jax.random.split(keys[4], len(tail))
        params["tail"] = [B.init_block(tk[j], kind, mcfg, dtype)
                          for j, kind in enumerate(tail)]
    if mcfg.mtp:
        mk = jax.random.split(keys[5], 3)
        params["mtp"] = {
            "proj": he_init(mk[0], (2 * mcfg.d_model, mcfg.d_model), dtype),
            "block": B.init_block(mk[1], "attn", mcfg, dtype),
            "ln": init_norm(mcfg.norm, mcfg.d_model, dtype),
        }
    return params


# ---------------------------------------------------------------------------
# Input embedding (token / audio-stub / vision-stub frontends).
# ---------------------------------------------------------------------------

def embed_inputs(params, mcfg: ModelConfig, inputs: dict):
    if mcfg.frontend == "audio_stub":
        x = jnp.einsum("bsf,fd->bsd", inputs["tokens"],
                       params["frontend_proj"])
        b, s = x.shape[:2]
    else:
        ids = inputs["tokens"]
        b, s = ids.shape
        x = jnp.take(params["emb"], ids, axis=0)
        if mcfg.frontend == "vision_stub" and "image_embeds" in inputs:
            img = jnp.einsum("bnf,fd->bnd", inputs["image_embeds"],
                             params["frontend_proj"]).astype(x.dtype)
            x = jax.lax.dynamic_update_slice_in_dim(x, img, 0, 1)
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))
    return x, positions


def logits_from_hidden(params, mcfg: ModelConfig, x, policy: GemmPolicy):
    x = apply_norm(mcfg.norm, params["ln_f"], x)
    w = params["emb"].T if mcfg.tie_embeddings else params["head"]
    return dense(x, w, policy, "logits")


# ---------------------------------------------------------------------------
# Train forward (scanned, optionally rematerialized).
# ---------------------------------------------------------------------------

def forward_train(params, mcfg: ModelConfig, inputs: dict,
                  policy: GemmPolicy = NATIVE_POLICY, remat: bool = True):
    from repro.models.blocks import _sp_constrain
    pat, n_groups, tail = _groups(mcfg)
    x, positions = embed_inputs(params, mcfg, inputs)
    x = _sp_constrain(x, mcfg)   # sequence-parallel residual stream

    def group_fn(carry, gp):
        x, aux = carry
        for j, kind in enumerate(pat):
            x, a = B.block_train(gp[f"b{j}"], kind, mcfg, x, positions,
                                 policy)
            aux = aux + a
        return (x, aux), None

    if remat:
        group_fn = jax.checkpoint(group_fn, prevent_cse=False)

    aux = jnp.zeros((), jnp.float32)
    if n_groups:
        (x, aux), _ = jax.lax.scan(group_fn, (x, aux), params["layers"])
    for j, kind in enumerate(tail):
        x, a = B.block_train(params["tail"][j], kind, mcfg, x, positions,
                             policy)
        aux = aux + a

    mtp_logits = None
    if mcfg.mtp:
        # DeepSeek-V3 multi-token prediction: one extra block sees the
        # final hidden state fused with the embedding of the *next* token
        # and predicts token t+2 through the shared head.
        h = apply_norm(mcfg.norm, params["mtp"]["ln"], x)
        nxt = jnp.roll(inputs["tokens"], -1, axis=1)
        e = jnp.take(params["emb"], nxt, axis=0)
        fused = dense(jnp.concatenate([h, e], -1), params["mtp"]["proj"],
                      policy, "ffn")
        fused, _ = B.block_train(params["mtp"]["block"], "attn", mcfg, fused,
                                 positions, policy)
        mtp_logits = logits_from_hidden(params, mcfg, fused, policy)

    return logits_from_hidden(params, mcfg, x, policy), mtp_logits, aux


# ---------------------------------------------------------------------------
# Prefill / decode (cache threading through the scan).
# ---------------------------------------------------------------------------

def init_cache(mcfg: ModelConfig, batch: int, max_seq: int):
    dtype = jnp.dtype(mcfg.dtype)
    pat, n_groups, tail = _groups(mcfg)

    def group_cache(_):
        return {f"b{j}": B.init_block_cache(kind, mcfg, batch, max_seq, dtype)
                for j, kind in enumerate(pat)}

    cache = {}
    if n_groups:
        cache["layers"] = jax.vmap(group_cache)(jnp.arange(n_groups))
    if tail:
        cache["tail"] = [B.init_block_cache(kind, mcfg, batch, max_seq, dtype)
                         for kind in tail]
    return cache


def forward_prefill(params, mcfg: ModelConfig, inputs: dict, max_seq: int,
                    policy: GemmPolicy = NATIVE_POLICY):
    pat, n_groups, tail = _groups(mcfg)
    x, positions = embed_inputs(params, mcfg, inputs)

    def group_fn(x, gp):
        caches = {}
        for j, kind in enumerate(pat):
            x, caches[f"b{j}"] = B.block_prefill(gp[f"b{j}"], kind, mcfg, x,
                                                 positions, policy, max_seq)
        return x, caches

    cache = {}
    if n_groups:
        x, cache["layers"] = jax.lax.scan(group_fn, x, params["layers"])
    if tail:
        cache["tail"] = []
        for j, kind in enumerate(tail):
            x, c = B.block_prefill(params["tail"][j], kind, mcfg, x,
                                   positions, policy, max_seq)
            cache["tail"].append(c)
    logits = logits_from_hidden(params, mcfg, x[:, -1:], policy)
    return logits, cache


def forward_decode(params, mcfg: ModelConfig, token, pos, cache,
                   policy: GemmPolicy = NATIVE_POLICY):
    """token: (B, 1) int32 (or (B, 1, F) stub embeddings); pos scalar."""
    pat, n_groups, tail = _groups(mcfg)
    if mcfg.frontend == "audio_stub":
        x = jnp.einsum("bsf,fd->bsd", token, params["frontend_proj"])
    else:
        x = jnp.take(params["emb"], token, axis=0)

    def group_fn(x, xs):
        gp, gcache = xs
        new = {}
        for j, kind in enumerate(pat):
            x, new[f"b{j}"] = B.block_decode(gp[f"b{j}"], kind, mcfg, x, pos,
                                             gcache[f"b{j}"], policy)
        return x, new

    new_cache = {}
    if n_groups:
        x, new_cache["layers"] = jax.lax.scan(
            group_fn, x, (params["layers"], cache["layers"]))
    if tail:
        new_cache["tail"] = []
        for j, kind in enumerate(tail):
            x, c = B.block_decode(params["tail"][j], kind, mcfg, x, pos,
                                  cache["tail"][j], policy)
            new_cache["tail"].append(c)
    return logits_from_hidden(params, mcfg, x, policy), new_cache


def forward_step(params, mcfg: ModelConfig, tokens, start, n_new, cache,
                 policy: GemmPolicy = NATIVE_POLICY):
    """Ragged mixed prefill/decode step for the continuous-batching
    serving engine (repro.serving).

    tokens: (B, C) int32 — each lane's next chunk of fresh token ids,
    left-aligned and zero-padded; start: (B,) absolute position of each
    lane's first fresh token; n_new: (B,) valid counts (decode lanes 1,
    prefill chunks up to C, idle lanes 0). cache: per-lane cache views
    (same pytree as :func:`init_cache`) — the engine gathers them from
    its paged pools and scatters the fresh slots back.

    Returns (logits (B, vocab_padded) at each lane's last valid fresh
    position, updated cache views). Padding lanes/columns produce
    well-defined garbage the caller discards; per-lane rows are computed
    independently, so one lane's result is bit-identical whatever the
    rest of the cohort is doing — the invariant the serving tests pin.
    """
    pat, n_groups, tail = _groups(mcfg)
    if mcfg.frontend != "none":
        raise NotImplementedError(
            "serving steps take token ids only; stub frontends "
            f"({mcfg.frontend!r}) have no ragged chunk path")
    b, c = tokens.shape
    x = jnp.take(params["emb"], tokens, axis=0)

    def group_fn(x, xs):
        gp, gcache = xs
        new = {}
        for j, kind in enumerate(pat):
            x, new[f"b{j}"] = B.block_step(gp[f"b{j}"], kind, mcfg, x,
                                           start, n_new, gcache[f"b{j}"],
                                           policy)
        return x, new

    new_cache = {}
    if n_groups:
        x, new_cache["layers"] = jax.lax.scan(
            group_fn, x, (params["layers"], cache["layers"]))
    if tail:
        new_cache["tail"] = []
        for j, kind in enumerate(tail):
            x, cc = B.block_step(params["tail"][j], kind, mcfg, x, start,
                                 n_new, cache["tail"][j], policy)
            new_cache["tail"].append(cc)
    idx = jnp.clip(n_new - 1, 0, c - 1)
    x_last = jnp.take_along_axis(x, idx[:, None, None], axis=1)   # (B, 1, D)
    logits = logits_from_hidden(params, mcfg, x_last, policy)
    return logits[:, 0], new_cache


def param_count(params) -> int:
    return sum(int(jnp.size(p)) for p in jax.tree.leaves(params))
