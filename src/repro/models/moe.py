"""Mixture-of-Experts layer with GShard-style grouped einsum dispatch.

Tokens are reshaped into ``n_groups`` groups (groups shard over the data
axes, experts over the model axis). Dispatch/combine are one-hot einsums
with per-group capacity, so under GSPMD the group->expert exchange lowers
to the canonical all-to-all pair. Supports qwen2-moe (softmax top-4,
4 gated shared experts) and deepseek-v3 (sigmoid top-8 + 1 shared expert)
routing styles.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import MoEConfig
from repro.models.common import (NATIVE_POLICY, GemmPolicy, he_init,
                                 init_ffn, apply_ffn, policy_einsum)


def padded_experts(cfg: MoEConfig) -> int:
    """Experts padded up to a multiple of ``pad_multiple`` so the expert
    axis shards over the model mesh axis (qwen2-moe: 60 -> 64). Padding
    experts carry -inf router logits and never receive tokens."""
    mult = cfg.pad_multiple
    return ((cfg.n_experts + mult - 1) // mult) * mult if mult else cfg.n_experts


def init_moe(key, d_model: int, cfg: MoEConfig, act: str, dtype=jnp.float32):
    kr, ke1, ke2, ke3, ks, kg = jax.random.split(key, 6)
    e, f = padded_experts(cfg), cfg.d_ff_expert
    params = {
        "router": he_init(kr, (d_model, e), jnp.float32),
        "wi_gate": he_init(ke1, (e, d_model, f), dtype),
        "wi_up": he_init(ke2, (e, d_model, f), dtype),
        "wo": he_init(ke3, (e, f, d_model), dtype, fan_in=f),
    }
    if cfg.scoring == "sigmoid":
        params["router_bias"] = jnp.zeros((e,), jnp.float32)
    if cfg.n_shared:
        params["shared"] = init_ffn(ks, d_model, cfg.d_ff_shared, act, dtype)
        if cfg.shared_gate:
            params["shared_gate"] = he_init(kg, (d_model, 1), dtype)
    return params


def _route(params, cfg: MoEConfig, x_f32: jax.Array,
           policy: GemmPolicy = NATIVE_POLICY):
    """x: (G, T, D) -> (weights (G,T,K), idx (G,T,K), scores (G,T,E))."""
    logits = policy_einsum("gtd,de->gte", x_f32, params["router"],
                           policy, "moe_gate")
    e_pad = padded_experts(cfg)
    if e_pad != cfg.n_experts:             # mask padding experts out
        dead = jnp.arange(e_pad) >= cfg.n_experts
        logits = jnp.where(dead, -1e30, logits)
    if cfg.scoring == "sigmoid":           # deepseek-v3 style
        scores = jax.nn.sigmoid(logits)
        sel = scores + params["router_bias"]   # bias affects selection only
    else:
        scores = jax.nn.softmax(logits, axis=-1)
        sel = scores
    _, idx = jax.lax.top_k(sel, cfg.top_k)
    w = jnp.take_along_axis(scores, idx, axis=-1)
    if cfg.norm_topk:
        w = w / jnp.maximum(w.sum(-1, keepdims=True), 1e-9)
    return w, idx, scores


def _dispatch_combine(cfg: MoEConfig, weights, idx, t: int, dtype):
    """Build (G,T,E,C) dispatch one-hot + combine weights in ``dtype``.

    Token-priority ranking: earlier tokens win capacity slots; overflow is
    dropped (standard capacity-factor routing). The one-hot tensors are the
    dominant transient — they are built directly in the model dtype (their
    entries are exact 0/1 in any float format).
    """
    e = padded_experts(cfg)
    cap = max(1, int(t * cfg.top_k * cfg.capacity_factor / e))
    onehot = jax.nn.one_hot(idx, e, dtype=jnp.float32)       # (G,T,K,E)
    # Rank slots in (token, k) order within each expert.
    flat = onehot.reshape(onehot.shape[0], t * cfg.top_k, e)  # (G,T*K,E)
    rank = (jnp.cumsum(flat, axis=1) - 1.0) * flat            # (G,T*K,E)
    keep = (rank < cap) * flat
    rank = (rank * keep).reshape(onehot.shape[0], t, cfg.top_k, e)
    keep = keep.reshape(onehot.shape[0], t, cfg.top_k, e).astype(dtype)
    dispatch = jnp.zeros((onehot.shape[0], t, e, cap), dtype)
    combine = jnp.zeros((onehot.shape[0], t, e, cap), dtype)
    wk = weights.astype(dtype)
    for k in range(cfg.top_k):  # one (G,T,E,C) one-hot live at a time
        pos_k = jax.nn.one_hot(rank[:, :, k], cap, dtype=dtype) \
            * keep[:, :, k, :, None]
        dispatch = dispatch + pos_k
        combine = combine + pos_k * wk[:, :, k, None, None]
    return dispatch, combine, cap


def aux_load_balance_loss(cfg: MoEConfig, scores, idx) -> jax.Array:
    """Switch-style: E * sum_e (fraction_tokens_e * mean_prob_e)."""
    e = padded_experts(cfg)
    frac = jax.nn.one_hot(idx, e, dtype=jnp.float32).sum(2).mean((0, 1))
    prob = scores.mean((0, 1))
    return cfg.aux_loss_weight * cfg.n_experts * jnp.sum(frac * prob)


def apply_moe(params, x: jax.Array, cfg: MoEConfig, act: str,
              policy: GemmPolicy):
    """x: (B, S, D) -> (out, aux_loss)."""
    b, s, d = x.shape
    tokens = b * s
    g = min(cfg.n_groups, tokens)
    while tokens % g:
        g -= 1
    t = tokens // g
    xg = x.reshape(g, t, d)
    w, idx, scores = _route(params, cfg, xg.astype(jnp.float32), policy)
    dispatch, combine, cap = _dispatch_combine(cfg, w, idx, t, x.dtype)

    xs = jnp.einsum("gtec,gtd->egcd", dispatch, xg)   # a2a: groups->experts
    gate = policy_einsum("egcd,edf->egcf", xs, params["wi_gate"],
                         policy, "moe_expert")
    up = policy_einsum("egcd,edf->egcf", xs, params["wi_up"],
                       policy, "moe_expert")
    h = (jax.nn.silu(gate) if act == "swiglu" else jax.nn.gelu(gate)) * up
    ys = policy_einsum("egcf,efd->egcd", h, params["wo"],
                       policy, "moe_expert")
    out = jnp.einsum("egcd,gtec->gtd", ys, combine)   # a2a: experts->groups
    out = out.reshape(b, s, d)

    if cfg.n_shared:
        sh = apply_ffn(params["shared"], x, act, policy, site="ffn")
        if cfg.shared_gate:
            sh = sh * jax.nn.sigmoid(
                jnp.einsum("bsd,do->bso", x, params["shared_gate"]))
        out = out + sh
    return out, aux_load_balance_loss(cfg, scores, idx)
