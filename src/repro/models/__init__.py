"""Pure-JAX model zoo: decoder/encoder transformers (GQA, MLA), MoE,
RG-LRU hybrid, and Mamba-2 SSD blocks, with scan-over-layers execution."""

from repro.models.model import (  # noqa: F401
    forward_decode,
    forward_prefill,
    forward_train,
    init_cache,
    init_params,
    param_count,
)
from repro.models.common import (  # noqa: F401
    GemmPolicy,
    NATIVE_POLICY,
    cross_entropy_loss,
    parse_gemm_spec,
)
