"""RG-LRU recurrent block (RecurrentGemma / Griffin).

Block = two parallel branches from the input:
  * y-branch: linear -> causal depthwise conv1d(k) -> RG-LRU recurrence
  * gate-branch: linear -> GeLU
merged multiplicatively and projected back to d_model.

RG-LRU:  r_t = sigmoid(W_r x_t);  i_t = sigmoid(W_i x_t)
         a_t = exp(-c * softplus(Lambda) * r_t)
         h_t = a_t h_{t-1} + sqrt(1 - a_t^2) * (i_t * x_t)

Training/prefill evaluates the linear recurrence with
``jax.lax.associative_scan`` (log-depth, sequence-parallel-friendly);
decode is the O(1) state update. The recurrence is elementwise — the
paper's GEMM-emulation technique applies to the block's projections but
not to the scan itself (DESIGN.md §Arch-applicability).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import RGLRUConfig
from repro.models.common import GemmPolicy, dense, he_init


def init_rglru(key, d_model: int, cfg: RGLRUConfig, dtype=jnp.float32):
    w = cfg.lru_width or d_model
    ks = jax.random.split(key, 6)
    # Lambda init so a^(1/c) ~ U[0.9, 0.999] (Griffin appendix).
    u = jax.random.uniform(ks[4], (w,), jnp.float32, 0.9, 0.999)
    lam = jnp.log(jnp.expm1(-jnp.log(u)))  # softplus^{-1}(-log u)
    return {
        "w_y": he_init(ks[0], (d_model, w), dtype),
        "w_gate": he_init(ks[1], (d_model, w), dtype),
        "w_out": he_init(ks[2], (w, d_model), dtype, fan_in=w),
        "conv_w": (jax.random.normal(ks[3], (cfg.conv_kernel, w), jnp.float32)
                   * 0.1).astype(dtype),
        "conv_b": jnp.zeros((w,), dtype),
        "lam": lam,
        "w_r": he_init(ks[5], (w, w), dtype),
        "w_i": he_init(jax.random.fold_in(ks[5], 1), (w, w), dtype),
    }


def _causal_conv(x, w, b, state=None):
    """Depthwise causal conv. x: (B, S, W); w: (k, W).

    state: (B, k-1, W) trailing context (decode) or None (zero left-pad).
    Returns (y, new_state).
    """
    k = w.shape[0]
    if state is None:
        state = jnp.zeros((x.shape[0], k - 1, x.shape[2]), x.dtype)
    xp = jnp.concatenate([state, x], axis=1)
    y = sum(xp[:, i:i + x.shape[1]] * w[i] for i in range(k)) + b
    return y, xp[:, -(k - 1):]


def _gates(params, cfg: RGLRUConfig, x):
    r = jax.nn.sigmoid(jnp.einsum("bsw,wv->bsv", x, params["w_r"]))
    i = jax.nn.sigmoid(jnp.einsum("bsw,wv->bsv", x, params["w_i"]))
    log_a = -cfg.c * jax.nn.softplus(params["lam"]) * r.astype(jnp.float32)
    a = jnp.exp(log_a)
    beta = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-12))
    u = beta * (i.astype(jnp.float32) * x.astype(jnp.float32))
    return a, u


def rglru_scan(a, u, h0=None):
    """h_t = a_t h_{t-1} + u_t over axis 1 via associative scan."""
    if h0 is not None:
        u = u.at[:, 0].add(a[:, 0] * h0)

    def combine(left, right):
        al, ul = left
        ar, ur = right
        return al * ar, ul * ar + ur

    _, h = jax.lax.associative_scan(combine, (a, u), axis=1)
    return h


def rglru_block_train(params, cfg: RGLRUConfig, x, policy: GemmPolicy):
    """x: (B, S, D) -> (B, S, D), no cache."""
    y, _, _ = _rglru_forward(params, cfg, x, policy, conv_state=None, h0=None)
    return y


def init_rglru_cache(cfg: RGLRUConfig, d_model: int, batch: int,
                     dtype=jnp.float32):
    w = cfg.lru_width or d_model
    return {"h": jnp.zeros((batch, w), jnp.float32),
            "conv": jnp.zeros((batch, cfg.conv_kernel - 1, w), dtype)}


def rglru_block_prefill(params, cfg: RGLRUConfig, x, policy: GemmPolicy):
    y, conv_state, h_last = _rglru_forward(params, cfg, x, policy,
                                           conv_state=None, h0=None)
    return y, {"h": h_last, "conv": conv_state}


def rglru_block_decode(params, cfg: RGLRUConfig, x, cache,
                       policy: GemmPolicy):
    """x: (B, 1, D); O(1) state update."""
    y, conv_state, h_last = _rglru_forward(
        params, cfg, x, policy, conv_state=cache["conv"], h0=cache["h"])
    return y, {"h": h_last, "conv": conv_state}


def _rglru_forward(params, cfg: RGLRUConfig, x, policy, conv_state, h0):
    yb = dense(x, params["w_y"], policy, "ffn")
    gate = jax.nn.gelu(dense(x, params["w_gate"], policy, "ffn"))
    yb, new_conv = _causal_conv(yb, params["conv_w"], params["conv_b"],
                                conv_state)
    a, u = _gates(params, cfg, yb)
    h = rglru_scan(a, u, h0)
    out = (h.astype(x.dtype) * gate)
    return dense(out, params["w_out"], policy, "ffn"), new_conv, h[:, -1]
