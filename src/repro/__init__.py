"""repro: EmuGEMM (Ozaki Scheme I/II precision emulation) on TPU in JAX.

The public surface (see docs/api.md):

  repro.precision("ozaki1-p4")          spec string -> EmulationConfig
  with repro.emulation("ozaki2-m6"):    ambient emulation scope
  repro.einsum("bik,bkj->bij", a, b)    emulated general contractions
  repro.dot_general(a, b, dnums)        ... in lax dimension-number form
  repro.emulated_matmul(a, b, cfg=...)  the 2-D kernel front door
  repro.emulated_dot(a, b, cfg)         (..., K) @ (K, N) with custom VJP
  repro.plan_precision(bits, k)         Fig.-7 scheme/slice planner
  repro.GemmPolicy / repro.prepare_rhs  model policies / prepared weights
  repro.guard / "+guard" spec suffix    numerical guardrails (docs/robustness.md)
"""

from repro.api import (
    EMULATION_ENV_VAR,
    current_emulation,
    dot_general,
    einsum,
    emulation,
    precision,
    resolve_config,
)
from repro.core.precision import (
    EmulationConfig,
    NATIVE,
    plan_precision,
)

__version__ = "1.1.0"

__all__ = [
    # precision specs + planning
    "EmulationConfig",
    "NATIVE",
    "precision",
    "plan_precision",
    # ambient scopes + the resolver
    "EMULATION_ENV_VAR",
    "emulation",
    "current_emulation",
    "resolve_config",
    # contractions
    "dot_general",
    "einsum",
    "emulated_dot",
    "emulated_matmul",
    "emulated_matmul_batched",
    # model policies + prepared weights
    "GemmPolicy",
    "prepare_rhs",
    "PreparedOperand",
    # numerical guardrails (docs/robustness.md)
    "guard",
    "EmulationAccuracyError",
    "verify_gemm",
    # observability (docs/observability.md)
    "telemetry",
]

# Heavy re-exports (they pull the Pallas kernel stack) resolve lazily so
# `import repro` stays cheap for spec/scope-only users.
_LAZY = {
    "emulated_dot": ("repro.core.emulated", "emulated_dot"),
    "emulated_matmul": ("repro.kernels.dispatch", "emulated_matmul"),
    "emulated_matmul_batched": ("repro.kernels.dispatch",
                                "emulated_matmul_batched"),
    "GemmPolicy": ("repro.models.common", "GemmPolicy"),
    "prepare_rhs": ("repro.kernels.prepared", "prepare_rhs"),
    "PreparedOperand": ("repro.kernels.prepared", "PreparedOperand"),
    "guard": ("repro.guard", None),  # the subpackage itself
    "EmulationAccuracyError": ("repro.core.precision",
                               "EmulationAccuracyError"),
    "verify_gemm": ("repro.guard.verify", "verify_gemm"),
    "telemetry": ("repro.telemetry", None),  # the subpackage itself
}


def __getattr__(name):
    try:
        module, attr = _LAZY[name]
    except KeyError:
        raise AttributeError(f"module 'repro' has no attribute {name!r}") \
            from None
    import importlib
    mod = importlib.import_module(module)
    value = mod if attr is None else getattr(mod, attr)
    globals()[name] = value  # cache for subsequent lookups
    return value


def __dir__():
    return sorted(set(globals()) | set(__all__))
