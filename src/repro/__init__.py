"""repro: EmuGEMM (Ozaki Scheme I/II precision emulation) on TPU in JAX."""

__version__ = "1.0.0"
