"""Ozaki Scheme II: moduli, residues, balanced-Garner CRT, precision."""

import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import scheme2
from repro.core.precision import (DEFAULT_MODULI, EmulationConfig,
                                  default_moduli, scheme2_budget)


def test_moduli_pairwise_coprime():
    for i, a in enumerate(DEFAULT_MODULI):
        for b in DEFAULT_MODULI[i + 1:]:
            assert math.gcd(a, b) == 1, (a, b)
    assert all(m <= 256 for m in DEFAULT_MODULI)


def test_balanced_residues_congruent_and_int8(rng):
    x = jnp.asarray(rng.integers(-2 ** 20, 2 ** 20, (64, 64)), jnp.float32)
    moduli = default_moduli(6)
    res = scheme2.balanced_residues(x, moduli)
    xn = np.asarray(x, np.int64)
    for l, m in enumerate(moduli):
        r = np.asarray(res[l], np.int64)
        assert (np.abs(r) <= m // 2).all()
        assert ((r - xn) % m == 0).all(), f"not congruent mod {m}"


@given(st.integers(2, 15), st.data())
@settings(max_examples=40, deadline=None)
def test_crt_roundtrip_exact(p, data):
    """Property: any integer in (-P/2, P/2] reconstructs exactly through
    residues -> balanced Garner digits -> double-double assembly, up to
    the dd precision (~2^-48 relative for f32 pairs)."""
    moduli = default_moduli(p)
    p_prod = math.prod(moduli)
    lim = min(p_prod // 2 - 1, 2 ** 45)  # within f32-dd exact range
    xs = data.draw(st.lists(st.integers(-lim, lim), min_size=1, max_size=8))
    arr = np.asarray(xs, np.int64).reshape(1, -1)
    res = jnp.stack([jnp.asarray(((arr % m) + m) % m, jnp.int32)
                     for m in moduli])
    out = np.asarray(scheme2.crt_reconstruct(res, moduli, jnp.float32),
                     np.float64)
    # exact up to the float32 *output* rounding (the dd interior is wider)
    rel_err = np.abs(out - arr) / np.maximum(np.abs(arr), 1)
    assert (rel_err <= 2 ** -23).all(), (xs, out)


def test_balanced_garner_high_digits_vanish():
    """A small value's balanced mixed-radix digits are zero beyond the
    first few — the property that kills the catastrophic cancellation of
    'evaluate then subtract P'."""
    moduli = default_moduli(12)
    x = np.asarray([[12345]], np.int64)
    res = jnp.stack([jnp.asarray(x % m, jnp.int32) for m in moduli])
    digits = scheme2.garner_digits(res, moduli)
    assert all(int(d[0, 0]) == 0 for d in digits[3:])


@pytest.mark.parametrize("p,min_bits", [(6, 12), (8, 17), (12, 19)])
def test_precision_grows_with_moduli(make_matrix, p, min_bits):
    a = jnp.asarray(make_matrix((128, 128)))
    b = jnp.asarray(make_matrix((128, 128)))
    ref = np.asarray(a, np.float64) @ np.asarray(b, np.float64)
    c = np.asarray(scheme2.matmul(a, b, EmulationConfig(scheme="ozaki2", p=p),
                                  jnp.float32))
    rel = np.abs(c - ref).max() / np.abs(ref).max()
    assert -np.log2(rel) >= min_bits


def test_fp64_grade_with_x64(make_matrix):
    with jax.experimental.enable_x64():
        rng = np.random.default_rng(3)
        a = ((rng.random((128, 128)) - 0.5)
             * np.exp(2.0 * rng.standard_normal((128, 128))))
        b = ((rng.random((128, 128)) - 0.5)
             * np.exp(2.0 * rng.standard_normal((128, 128))))
        ref = np.asarray(a, np.longdouble) @ np.asarray(b, np.longdouble)
        c = np.asarray(scheme2.matmul(
            jnp.asarray(a), jnp.asarray(b),
            EmulationConfig(scheme="ozaki2", p=15), jnp.float64))
        rel = float(np.abs(c.astype(np.longdouble) - ref).max()
                    / np.abs(ref).max())
        assert -np.log2(rel) > 40   # far beyond fp32's 24 bits


def test_budget_respects_crt_bound():
    for p in (4, 8, 15):
        moduli = default_moduli(p)
        k = 4096
        bits = scheme2_budget(moduli, k)
        # 2 * K * 2^b * 2^b < P must hold
        assert 2 * k * (2 ** bits) ** 2 < math.prod(moduli)


def test_linear_gemm_count():
    assert EmulationConfig(scheme="ozaki2", p=15).gemm_count() == 15
