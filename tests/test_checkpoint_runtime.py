"""Fault tolerance: checkpoint atomicity, bit-exact resume, stragglers,
gradient compression determinism."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import CheckpointManager


def _state(x=0.0):
    return {"params": {"w": jnp.full((4, 4), 1.0 + x),
                       "layers": {"b0": [jnp.arange(3.0)]}},
            "step": jnp.asarray(7)}


def test_save_restore_roundtrip(tmp_path):
    mgr = CheckpointManager(str(tmp_path), async_save=False)
    state = _state()
    mgr.save(3, state)
    like = jax.eval_shape(lambda: state)
    out = mgr.restore(3, like)
    for a, b in zip(jax.tree.leaves(out), jax.tree.leaves(state)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_keep_n_gc_and_latest(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2, async_save=False)
    for s in (1, 5, 9):
        mgr.save(s, _state(s))
    assert mgr.all_steps() == [5, 9]
    assert mgr.latest_step() == 9


def test_async_save_then_restore(tmp_path):
    mgr = CheckpointManager(str(tmp_path), async_save=True)
    mgr.save(1, _state(1.0))
    mgr.wait()
    assert mgr.latest_step() == 1


def test_tmp_dirs_are_not_valid_checkpoints(tmp_path):
    mgr = CheckpointManager(str(tmp_path), async_save=False)
    os.makedirs(tmp_path / ".tmp_step_4" )
    mgr.save(2, _state())
    assert mgr.all_steps() == [2]


def test_failure_injection_and_bitexact_resume(tmp_path):
    """Train 8 steps with a crash at step 5; restart; final params must be
    bit-identical to an uninterrupted 8-step run."""
    from repro.launch import train as train_cli

    def run(ckpt, fail_at=None, steps=8):
        argv = ["--arch", "olmo-1b", "--smoke", "--steps", str(steps),
                "--batch", "2", "--seq", "32", "--ckpt-dir", ckpt,
                "--ckpt-every", "2"]
        if fail_at is not None:
            argv += ["--fail-at", str(fail_at)]
        return train_cli.main(argv)

    ref_log = run(str(tmp_path / "ref"))
    with pytest.raises(RuntimeError, match="injected failure"):
        run(str(tmp_path / "ft"), fail_at=5)
    log = run(str(tmp_path / "ft"))   # auto-resume from step 4
    # Same loss trajectory after the resume point as the reference run.
    ref_losses = {m["step"]: m["loss"] for m in ref_log}
    for m in log:
        if m["step"] >= 5:
            assert abs(ref_losses[m["step"]] - m["loss"]) < 1e-6, m


def test_straggler_monitor():
    from repro.runtime import StragglerMonitor
    mon = StragglerMonitor(z=3.0, warmup=3)
    for i in range(10):
        assert not mon.observe(i, 0.1 + 0.001 * (i % 2))
    assert mon.observe(10, 5.0)
    assert mon.stragglers[0][0] == 10


def test_data_pipeline_determinism():
    from repro import configs
    from repro.configs.base import ShapeSpec
    from repro.data import SyntheticLMDataset, make_batch_iterator
    ds = SyntheticLMDataset(vocab=100, seq_len=16, seed=1)
    b1 = ds.batch(step=4, batch_size=8, host=0, n_hosts=2)
    b2 = ds.batch(step=4, batch_size=8, host=0, n_hosts=2)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    b3 = ds.batch(step=4, batch_size=8, host=1, n_hosts=2)
    assert (b1["tokens"] != b3["tokens"]).any()   # hosts get disjoint data
    # iterator fast-forward equals direct indexing (resume correctness)
    arch = configs.get_smoke_config("olmo-1b")
    shape = ShapeSpec("t", 16, 4, "train")
    it = iter(make_batch_iterator(arch, shape, seed=2))
    for _ in range(3):
        next(it)
    _, b_at_3 = next(it)
    it2 = iter(make_batch_iterator(arch, shape, seed=2))
    for _ in range(3):
        next(it2)
    _, b_at_3b = next(it2)
    np.testing.assert_array_equal(b_at_3["tokens"], b_at_3b["tokens"])


def test_compressed_psum_exact_and_deterministic(rng):
    """Scheme-II residue reduction: simulated 8-way gradient sum matches
    the float sum to integerization precision and is order-invariant."""
    import math
    from repro.core.precision import default_moduli
    from repro.core import scheme2
    n, p = 8, 6
    moduli = default_moduli(p)
    grads = [rng.standard_normal((16, 16)).astype(np.float32)
             for _ in range(n)]
    amax = max(np.abs(g).max() for g in grads)
    budget = int(sum(math.log2(m) for m in moduli) - 2 - math.ceil(
        math.log2(n)))
    budget = min(budget, 30)
    scale = 2.0 ** (budget - 1 - np.ceil(np.log2(amax)))
    ints = [np.round(g * scale).astype(np.int64) for g in grads]

    def reduce_in_order(order):
        acc = [np.zeros((16, 16), np.int32) for _ in moduli]
        for i in order:
            for l, m in enumerate(moduli):
                half = m // 2
                r = ((ints[i] + half) % m - half).astype(np.int32)
                acc[l] = acc[l] + r
        canon = jnp.stack([jnp.asarray(a % m, jnp.int32)
                           for a, m in zip(acc, moduli)])
        out = scheme2.crt_reconstruct(canon, moduli, jnp.float32)
        return np.asarray(out) / scale

    fwd = reduce_in_order(range(n))
    rev = reduce_in_order(reversed(range(n)))
    np.testing.assert_array_equal(fwd, rev)        # bitwise deterministic
    ref = sum(ints)  # exact integer reference
    np.testing.assert_allclose(fwd * scale, ref, atol=0.5)
