"""Shard_map'ed fused GEMM: the GSPMD-clamp lift and its parity suite.

Two tiers in one module:

  * unit tests — mesh-introspection helpers, the mesh_shape block-cache
    key, fallback-warning dedupe, partition selection, and the
    analytic sharded traffic/roofline models. These run on the normal
    1-device CPU host.
  * the 8-device parity suite — tests whose names carry ``parity8`` or
    ``lift8`` need ``jax.device_count() >= 8``. The CI row that exports
    ``XLA_FLAGS=--xla_force_host_platform_device_count=8`` runs them
    in-process; on a normal host a single driver test re-launches this
    file under pytest in a subprocess with the flag set *before* jax
    initializes (the only way to grow host devices after import).
"""

import math
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.precision import DEFAULT_MODULI, EmulationConfig
from repro.kernels import dispatch, prepared
from repro.launch import mesh as mesh_lib
from repro.models.common import GemmPolicy, dense
from repro.parallel import shard_gemm

EIGHT = jax.device_count() >= 8
needs8 = pytest.mark.skipif(not EIGHT, reason="needs 8 devices "
                            "(XLA_FLAGS=--xla_force_host_platform_"
                            "device_count=8)")


# ---------------------------------------------------------------------------
# Mesh introspection: _mesh_devices across every mesh flavor the launch
# layer produces (the AbstractMesh mapping-shape regression).
# ---------------------------------------------------------------------------

class _ShapeOnly:
    """A mesh exposing only ``.shape`` (out-of-tree mesh stand-in)."""

    def __init__(self, shape):
        self.shape = shape


def test_mesh_devices_none_is_process_global():
    assert dispatch._mesh_devices(None) == len(jax.devices())


def test_mesh_devices_concrete_single():
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    assert dispatch._mesh_devices(mesh) == 1
    assert not dispatch._shardable_mesh(mesh)


def test_mesh_devices_abstract_mapping_shape():
    # AbstractMesh.shape is a mapping {axis: size}: the device count
    # must come from the product of its values, never len(jax.devices()).
    am = mesh_lib.make_abstract_mesh((2, 4), ("data", "model"))
    assert dispatch._mesh_devices(am) == 8
    assert dispatch._mesh_shape_tuple(am) == (("data", 2), ("model", 4))
    # device-free: shard_map has nothing to map over
    assert not dispatch._shardable_mesh(am)


def test_mesh_devices_shape_only_flavors():
    assert dispatch._mesh_devices(_ShapeOnly({"data": 2, "model": 4})) == 8
    assert dispatch._mesh_devices(_ShapeOnly((2, 4))) == 8
    # unusable shape falls back to the process-global count
    assert dispatch._mesh_devices(
        _ShapeOnly(("x", "y"))) == len(jax.devices())
    assert dispatch._mesh_shape_tuple(None) is None
    assert dispatch._mesh_shape_tuple(_ShapeOnly((2, 4))) == (
        ("0", 2), ("1", 4))


def test_abstract_mesh_keeps_the_clamp():
    # Dry-run lowering (AbstractMesh) still rewrites fused impls to the
    # XLA expansion — there are no devices to shard_map over.
    am = mesh_lib.make_abstract_mesh((2, 4), ("data", "model"))
    pol = GemmPolicy(default=EmulationConfig(scheme="ozaki1", p=3,
                                             backend="tpu"))
    fixed = dispatch.resolve_policy(pol, am)
    assert fixed.default.impl == "xla"
    assert fixed.mesh is None


# ---------------------------------------------------------------------------
# mesh_shape in the block-cache key: the same shard-local dims on two
# mesh layouts must occupy distinct entries.
# ---------------------------------------------------------------------------

def test_block_cache_keys_on_mesh_shape():
    dispatch.block_cache_clear("gpu")
    args = dict(m=128, n=128, k=128, p=4, backend="gpu")
    dispatch.select_blocks(**args, mesh_shape=None)
    dispatch.select_blocks(**args, mesh_shape=(("data", 1), ("model", 8)))
    dispatch.select_blocks(**args, mesh_shape=(("data", 2), ("model", 4)))
    info = dispatch.block_cache_info("gpu")
    assert info.currsize == 3 and info.misses == 3 and info.hits == 0
    # and the per-layout entries hit on re-query
    dispatch.select_blocks(**args, mesh_shape=(("data", 2), ("model", 4)))
    assert dispatch.block_cache_info("gpu").hits == 1
    dispatch.block_cache_clear("gpu")


# ---------------------------------------------------------------------------
# Fallback-warning dedupe: once per (reason, shape-class).
# ---------------------------------------------------------------------------

def test_fallback_warning_dedupes_per_shape_class(make_matrix):
    import warnings
    cfg = EmulationConfig(scheme="ozaki2", p=4,
                          moduli=DEFAULT_MODULI + (181,), backend="gpu")
    a = jnp.asarray(make_matrix((64, 64)))
    dispatch.fallback_warnings_clear()
    with pytest.warns(RuntimeWarning, match="moduli"):
        assert dispatch.auto_fused_matmul(a, a, cfg) is None
    # same shape class again: silent
    with warnings.catch_warnings():
        warnings.simplefilter("error")
        assert dispatch.auto_fused_matmul(a, a, cfg) is None
    # a different shape class warns once more
    b = jnp.asarray(make_matrix((128, 128)))
    with pytest.warns(RuntimeWarning, match="moduli"):
        assert dispatch.auto_fused_matmul(b, b, cfg) is None
    # clearing re-arms the first class
    dispatch.fallback_warnings_clear()
    with pytest.warns(RuntimeWarning, match="moduli"):
        assert dispatch.auto_fused_matmul(a, a, cfg) is None
    dispatch.fallback_warnings_clear()


# ---------------------------------------------------------------------------
# Partition selection (pure mesh.shape reads — an AbstractMesh serves).
# ---------------------------------------------------------------------------

def _am24():
    return mesh_lib.make_abstract_mesh((2, 4), ("data", "model"))


def test_gemm_partition_prefers_column():
    part = shard_gemm.gemm_partition(64, 96, 128, _am24())
    assert part.kind == "column" and part.model_axis == "model"
    assert part.reduce_axes == ()
    x_spec, w_spec, out_spec = part.specs(3)
    assert tuple(w_spec) == (None, "model")
    assert tuple(out_spec) == (("data",), None, "model")


def test_gemm_partition_row_when_n_does_not_divide():
    part = shard_gemm.gemm_partition(64, 96, 130, _am24())
    assert part.kind == "row"
    assert part.reduce_axes == ("model",)
    x_spec, w_spec, out_spec = part.specs(2)
    assert tuple(x_spec) == (("data",), "model")
    assert tuple(w_spec) == ("model", None)
    assert tuple(out_spec) == (("data",), None)


def test_gemm_partition_batch_and_none():
    part = shard_gemm.gemm_partition(64, 97, 130, _am24())
    assert part.kind == "batch" and part.model_axis is None
    assert shard_gemm.gemm_partition(63, 97, 130, _am24()) is None
    # allow_row=False skips the K-contracted layout
    assert shard_gemm.gemm_partition(
        64, 96, 130, _am24(), allow_row=False).kind == "batch"


def test_pin_row_cfg_pins_scheme1_beta():
    cfg = EmulationConfig(scheme="ozaki1", p=3)
    pinned = shard_gemm._pin_row_cfg(cfg, 1000)
    assert pinned.beta == cfg.resolved_beta(dispatch.round_up(1000))
    # explicit beta and scheme2 budgets are left alone
    cfg_b = EmulationConfig(scheme="ozaki1", p=3, beta=7)
    assert shard_gemm._pin_row_cfg(cfg_b, 1000) is cfg_b
    cfg2 = EmulationConfig(scheme="ozaki2", p=4)
    assert shard_gemm._pin_row_cfg(cfg2, 1000) is cfg2


# ---------------------------------------------------------------------------
# Analytic sharded traffic + roofline: per-shard fused bytes next to
# collective bytes, 3 shapes x 2 mesh layouts (the report the CI traffic
# benchmark regression-gates).
# ---------------------------------------------------------------------------

SHAPES_X_MESHES = [
    (m, k, n, layout)
    for (m, k, n) in [(512, 768, 1024), (1024, 1024, 1024), (256, 512, 2048)]
    for layout in [(("data", 1), ("model", 8)), (("data", 2), ("model", 4))]
]


@pytest.mark.parametrize("m,k,n,layout", SHAPES_X_MESHES)
def test_sharded_traffic_column_vs_row(m, k, n, layout):
    from repro.core import traffic as T
    tp = dict(layout)["model"]
    dp = dict(layout)["data"]
    s = T.GemmShape(m, n, k)
    col = T.sharded_gemm_traffic(s, 4, layout, "column")
    row = T.sharded_gemm_traffic(s, 4, layout, "row")
    assert col["devices"] == row["devices"] == 8
    assert col["collective_bytes_per_device"] == 0
    assert col["shard_n"] == n // tp and col["shard_k"] == k
    assert row["shard_k"] == k // tp and row["shard_n"] == n
    # ring all-reduce of the (M_local, N) float partials
    payload = 4 * (m // dp) * n
    assert row["collective_bytes_per_device"] == \
        T.ring_all_reduce_bytes(payload, tp)
    # per-shard fused bytes match the single-device model on local dims
    local = T.GemmShape(m // dp, n // tp, k)
    assert col["fused_bytes_per_shard"] == T.scheme1_fused_bytes(local, 4, 4)


def test_collective_byte_conventions():
    from repro.core import traffic as T
    assert T.ring_all_reduce_bytes(1000, 4) == 1500   # 2(n-1)/n
    assert T.all_gather_bytes(1000, 4) == 750         # (n-1)/n
    assert T.reduce_scatter_bytes(1000, 4) == 750
    assert T.ring_all_reduce_bytes(1000, 1) == 0
    with pytest.raises(ValueError, match="divide"):
        T.sharded_gemm_traffic(T.GemmShape(64, 100, 64), 4,
                               (("model", 8),), "column")
    with pytest.raises(ValueError, match="partition"):
        T.sharded_gemm_traffic(T.GemmShape(64, 64, 64), 4,
                               (("model", 8),), "diagonal")


def test_sharded_roofline_projection():
    from repro.utils import roofline as R
    layout = (("data", 2), ("model", 4))
    col = R.sharded_projected_throughput(512, 768, 1024, 4, layout,
                                         "column")
    row = R.sharded_projected_throughput(512, 768, 1024, 4, layout, "row")
    assert col["collective_s"] == 0.0
    assert row["collective_s"] == pytest.approx(
        row["collective_bytes_per_device"] / R.ICI_BW)
    for cell in col["hardware"].values():
        # no collective: effective == per-shard projection
        assert cell["effective_tops"] == pytest.approx(
            cell["shard_projected_tops"])
    for cell in row["hardware"].values():
        assert cell["effective_tops"] < cell["shard_projected_tops"]
    # scheme2 complex rides along
    r2 = R.sharded_projected_throughput(
        512, 768, 1024, 6, layout, "row", scheme="ozaki2", out_bytes=8,
        complex_3m=True)
    assert r2["collective_bytes_per_device"] > 0
    assert set(r2["hardware"]) == set(col["hardware"])


# ---------------------------------------------------------------------------
# 8-device parity: the shard_map'ed fused path against the single-device
# reference, bit-identical in the collective-free layouts.
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def mesh8():
    if not EIGHT:
        pytest.skip("needs 8 devices")
    return jax.make_mesh((2, 4), ("data", "model"))


def _mats(rng, m, k, n):
    from conftest import conditioned
    a = jnp.asarray(conditioned(rng, (m, k)))
    b = jnp.asarray(conditioned(rng, (k, n)))
    return a, b


@needs8
def test_lift8_resolve_policy_records_mesh(mesh8):
    pol = GemmPolicy(default=EmulationConfig(scheme="ozaki1", p=3,
                                             backend="tpu"))
    fixed = dispatch.resolve_policy(pol, mesh8)
    assert fixed.default.impl != "xla", "shardable pair must not clamp"
    assert fixed.mesh is mesh8
    # a bare 8-device host with no mesh still clamps (nothing to map over)
    clamped = dispatch.resolve_policy(pol, None)
    assert clamped.default.impl == "xla" and clamped.mesh is None


PARITY_CELLS = [
    # (scheme, p, (M, K, N)) — aligned and padded shard-local shapes
    ("ozaki1", 3, (64, 64, 128)),
    ("ozaki1", 4, (64, 72, 160)),     # K, per-shard N unaligned: pads
    ("ozaki2", 4, (64, 64, 128)),
    ("ozaki2", 6, (64, 72, 160)),
]


@needs8
@pytest.mark.parametrize("scheme,p,shape", PARITY_CELLS)
def test_parity8_column_bit_identical(scheme, p, shape, mesh8, rng):
    m, k, n = shape
    a, b = _mats(rng, m, k, n)
    cfg = EmulationConfig(scheme=scheme, p=p, impl="pallas",
                          backend="tpu" if scheme == "ozaki1" else "gpu")
    ref = dispatch.emulated_matmul(a, b, cfg=cfg)
    out = shard_gemm.sharded_matmul(a, b, cfg, mesh8)
    assert out is not None
    # column layout: local K == global K, so every shard runs the exact
    # single-device kernel on its slice of the output — bit-identical.
    np.testing.assert_array_equal(np.asarray(out), np.asarray(ref))


@needs8
def test_parity8_row_parallel_allclose(mesh8, rng):
    # N=130 blocks the column layout; K goes on 'model' with a psum.
    a, b = _mats(rng, 64, 128, 130)
    cfg = EmulationConfig(scheme="ozaki1", p=4, backend="tpu")
    ref = dispatch.emulated_matmul(a, b, cfg=cfg)
    out = shard_gemm.sharded_matmul(a, b, cfg, mesh8)
    assert out is not None
    # K-sharded shards slice against their *local* row maxima (pinned
    # global beta, local amax), so the truncation error differs from the
    # unsharded run's — compare both against the exact fp64 product: the
    # sharded path must stay in the same error class as the reference.
    exact = np.asarray(a, np.float64) @ np.asarray(b, np.float64)
    scale = np.abs(exact).max()
    err_ref = np.abs(np.asarray(ref, np.float64) - exact).max() / scale
    err_sh = np.abs(np.asarray(out, np.float64) - exact).max() / scale
    assert err_sh <= 2 * err_ref + 1e-7, (err_sh, err_ref)


@needs8
@pytest.mark.parametrize("scheme,p", [("ozaki1", 4), ("ozaki2", 4)])
def test_parity8_cached_prepared_localized(scheme, p, mesh8, rng):
    from repro.core.emulated import prepared_dot
    cfg = EmulationConfig(scheme=scheme, p=p, cache_weights=True,
                          backend="tpu" if scheme == "ozaki1" else "gpu")
    _, b = _mats(rng, 8, 64, 128)
    x = jnp.asarray(np.random.default_rng(1).standard_normal(
        (8, 16, 64)), jnp.float32)
    prep = prepared.prepare_rhs(b, cfg, mesh=mesh8)
    assert prep.mesh_shape == dispatch._mesh_shape_tuple(mesh8)
    ref = prepared_dot(x, prep)
    out = shard_gemm.sharded_dense(x, prep, cfg, mesh8)
    assert out is not None
    np.testing.assert_array_equal(np.asarray(out), np.asarray(ref))
    # a stack prepared under a different layout is refused, not resliced
    mesh18 = jax.make_mesh((1, 8), ("data", "model"))
    assert shard_gemm.sharded_dense(x, prep, cfg, mesh18) is None


@needs8
def test_parity8_dense_policy_mesh_and_grad(mesh8, rng):
    _, w = _mats(rng, 8, 64, 128)
    x = jnp.asarray(np.random.default_rng(2).standard_normal(
        (8, 16, 64)), jnp.float32)
    cfg = EmulationConfig(scheme="ozaki1", p=3, backend="tpu")
    pol = dispatch.resolve_policy(GemmPolicy(default=cfg), mesh8)
    ref = dense(x, w, GemmPolicy(default=pol.default), "ffn")
    out = dense(x, w, pol, "ffn")
    np.testing.assert_array_equal(np.asarray(out), np.asarray(ref))

    def loss(w, p):
        return jnp.sum(dense(x, w, p, "ffn") ** 2)
    g_ref = jax.grad(loss)(w, GemmPolicy(default=pol.default))
    g_sh = jax.grad(loss)(w, pol)
    # the backward dA contracts over the sharded N axis (per-shard
    # decomposition, psum of partials): max-normalized error, not
    # elementwise rtol on near-zero gradient entries
    err = float(jnp.abs(g_sh - g_ref).max() / jnp.abs(g_ref).max())
    assert err < 1e-4, err


@needs8
def test_parity8_step_prepared_route(mesh8, rng):
    cfg = EmulationConfig(scheme="ozaki1", p=4, cache_weights=True,
                          backend="tpu")
    _, w = _mats(rng, 8, 64, 128)
    x = jnp.asarray(np.random.default_rng(3).standard_normal(
        (8, 16, 64)), jnp.float32)
    sp = prepared.StepPrepared(w, prepared.prepare_rhs(w, cfg,
                                                       with_twin=True))
    pol = dispatch.resolve_policy(GemmPolicy(default=cfg), mesh8)
    ref = dense(x, sp, GemmPolicy(default=pol.default), "ffn")
    out = dense(x, sp, pol, "ffn")
    np.testing.assert_array_equal(np.asarray(out), np.asarray(ref))


# ---------------------------------------------------------------------------
# Driver: on a <8-device host, run the parity suite in a subprocess with
# the host-device flag exported before jax initializes.
# ---------------------------------------------------------------------------

@pytest.mark.skipif(EIGHT, reason="parity suite already runs in-process")
def test_parity8_subprocess_driver():
    env = dict(os.environ)
    env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "")
                        + " --xla_force_host_platform_device_count=8").strip()
    r = subprocess.run(
        [sys.executable, "-m", "pytest", "-q", "-p", "no:cacheprovider",
         os.path.abspath(__file__),
         "-k", "(parity8 or lift8) and not driver"],
        env=env, cwd=os.path.dirname(os.path.dirname(os.path.abspath(
            __file__))),
        capture_output=True, text=True, timeout=1800)
    assert r.returncode == 0, r.stdout[-4000:] + r.stderr[-4000:]
    # every parity cell must have RUN — all-skipped (the flag failing to
    # grow host devices) would also exit 0
    assert "10 passed" in r.stdout, r.stdout[-2000:]
