"""Minimal, deterministic stand-in for the ``hypothesis`` API this suite uses.

``hypothesis`` is a declared test dependency (see pyproject.toml), but some
execution environments cannot install it. Rather than losing 4 test modules
at collection, :func:`install` registers this module under
``sys.modules['hypothesis']`` — *only* when the real package is absent
(tests/conftest.py gates it), so an installed hypothesis always wins.

Semantics: ``@given`` runs the test body ``max_examples`` times with values
drawn from a per-test deterministic PRNG (seeded from the test name), always
including the strategy boundary values first. This is a vendored fallback,
not a property-testing engine — no shrinking, no example database — but it
executes the same assertions over the same value domains.

Supported surface (exactly what the suite imports):
  given, settings, strategies.{integers, floats, booleans, sampled_from,
  lists, data}
"""

from __future__ import annotations

import functools
import random
import struct
import sys
import types
import zlib

_DEFAULT_MAX_EXAMPLES = 20


class _Strategy:
    """A draw function plus the boundary examples tried first."""

    def __init__(self, draw, boundary=()):
        self._draw = draw
        self.boundary = tuple(boundary)

    def draw(self, rnd: random.Random):
        return self._draw(rnd)


class _DataStrategy:
    """Marker for ``st.data()`` — materialized per example as _DataObject."""


class _DataObject:
    def __init__(self, rnd: random.Random):
        self._rnd = rnd

    def draw(self, strategy: _Strategy):
        return strategy.draw(self._rnd)


def integers(min_value: int, max_value: int) -> _Strategy:
    return _Strategy(lambda r: r.randint(min_value, max_value),
                     boundary=(min_value, max_value, 0)
                     if min_value <= 0 <= max_value
                     else (min_value, max_value))


def booleans() -> _Strategy:
    return _Strategy(lambda r: bool(r.getrandbits(1)), boundary=(False, True))


def sampled_from(elements) -> _Strategy:
    elements = list(elements)
    return _Strategy(lambda r: r.choice(elements), boundary=elements[:2])


def _to_width(x: float, width: int) -> float:
    if width == 32:
        # round-trip through IEEE binary32 so draws are exactly representable
        return struct.unpack("f", struct.pack("f", x))[0]
    return x


def floats(min_value=None, max_value=None, allow_nan=None,
           allow_infinity=None, allow_subnormal=None,
           width: int = 64) -> _Strategy:
    lo = -1e300 if min_value is None else float(min_value)
    hi = 1e300 if max_value is None else float(max_value)

    def draw(r: random.Random) -> float:
        roll = r.random()
        if roll < 0.3:
            # log-uniform magnitude: floats cluster near 0 in practice
            import math
            span = max(abs(lo), abs(hi), 1.0)
            mag = math.exp(r.uniform(0.0, math.log(span + 1.0))) - 1.0
            x = mag if r.random() < 0.5 else -mag
            x = min(max(x, lo), hi)
        else:
            x = r.uniform(lo, hi)
        x = _to_width(x, width)
        return min(max(x, lo), hi)

    boundary = [_to_width(lo, width), _to_width(hi, width)]
    if lo <= 0.0 <= hi:
        boundary.append(0.0)
    return _Strategy(draw, boundary=boundary)


def lists(elements: _Strategy, min_size: int = 0,
          max_size: int = 10) -> _Strategy:
    def draw(r: random.Random):
        n = r.randint(min_size, max_size)
        return [elements.draw(r) for _ in range(n)]
    return _Strategy(draw)


def data() -> _DataStrategy:
    return _DataStrategy()


class settings:  # noqa: N801 — mirrors hypothesis' lowercase class
    def __init__(self, max_examples: int = _DEFAULT_MAX_EXAMPLES,
                 deadline=None, **_ignored):
        self.max_examples = max_examples

    def __call__(self, fn):
        fn._fallback_settings = self
        return fn


def given(*arg_strategies, **kw_strategies):
    def decorator(fn):
        @functools.wraps(fn)
        def wrapper():
            cfg = (getattr(wrapper, "_fallback_settings", None)
                   or getattr(fn, "_fallback_settings", None))
            n = cfg.max_examples if cfg else _DEFAULT_MAX_EXAMPLES
            seed0 = zlib.adler32(fn.__module__.encode()
                                 + fn.__qualname__.encode())
            for ex in range(n):
                rnd = random.Random(seed0 * 100003 + ex)

                def materialize(strat, slot):
                    if isinstance(strat, _DataStrategy):
                        return _DataObject(rnd)
                    if ex < len(strat.boundary):
                        return strat.boundary[ex]
                    return strat.draw(rnd)

                args = [materialize(s, i)
                        for i, s in enumerate(arg_strategies)]
                kwargs = {k: materialize(s, i)
                          for i, (k, s) in enumerate(kw_strategies.items())}
                try:
                    fn(*args, **kwargs)
                except Exception as e:  # noqa: BLE001 — re-raise with example
                    shown = {f"arg{i}": a for i, a in enumerate(args)}
                    shown.update(kwargs)
                    raise AssertionError(
                        f"falsifying example (#{ex}): {shown!r}") from e
        # pytest must see a zero-arg signature, not the wrapped one —
        # otherwise the strategy parameters look like missing fixtures.
        del wrapper.__wrapped__
        return wrapper
    return decorator


def install() -> None:
    """Register this module as ``hypothesis`` (call only when absent)."""
    mod = types.ModuleType("hypothesis")
    mod.given = given
    mod.settings = settings
    strategies = types.ModuleType("hypothesis.strategies")
    for name in ("integers", "floats", "booleans", "sampled_from", "lists",
                 "data"):
        setattr(strategies, name, globals()[name])
    mod.strategies = strategies
    mod.__is_repro_fallback__ = True
    sys.modules["hypothesis"] = mod
    sys.modules["hypothesis.strategies"] = strategies
