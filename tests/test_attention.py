"""Flash attention vs naive softmax oracle; int8 KV cache; MoE invariants."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.models import attention
from repro.models.attention import AttnConfig


def naive_attention(cfg: AttnConfig, q, k, v, positions):
    b, s, h, d = q.shape
    kvh = cfg.n_kv_heads
    g = h // kvh
    qk = q.reshape(b, s, kvh, g, d)
    scores = np.einsum("bqkgd,bjkd->bkgqj", np.asarray(qk, np.float32),
                       np.asarray(k, np.float32)) * cfg.scale
    rel = positions[:, None] - positions[None, :]
    mask = np.ones((s, s), bool)
    if cfg.causal:
        mask &= rel >= 0
    if cfg.window is not None:
        mask &= rel < cfg.window
    scores = np.where(mask[None, None, None], scores, -1e30)
    w = np.exp(scores - scores.max(-1, keepdims=True))
    w = w / w.sum(-1, keepdims=True)
    out = np.einsum("bkgqj,bjkd->bkgqd", w, np.asarray(v, np.float32))
    return out.transpose(0, 3, 1, 2, 4).reshape(b, s, h, d)


@pytest.mark.parametrize("causal,window", [(True, None), (False, None),
                                           (True, 16)])
@pytest.mark.parametrize("s", [64, 60])   # ragged exercises padding
def test_flash_matches_naive(rng, causal, window, s):
    cfg = AttnConfig(d_model=32, n_heads=4, n_kv_heads=2, head_dim=8,
                     causal=causal, window=window, q_chunk=32, kv_chunk=32)
    q = jnp.asarray(rng.standard_normal((2, s, 4, 8)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((2, s, 2, 8)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((2, s, 2, 8)), jnp.float32)
    pos = jnp.arange(s)
    out = attention.flash_attention(cfg, q, k, v, pos, pos)
    ref = naive_attention(cfg, q, k, v, np.arange(s))
    np.testing.assert_allclose(np.asarray(out), ref, rtol=2e-5, atol=2e-5)


@given(sq=st.integers(8, 96), bq=st.sampled_from([16, 32]),
       causal=st.booleans())
@settings(max_examples=20, deadline=None)
def test_flash_padding_property(sq, bq, causal):
    """Any (sq, chunk) combination agrees with the naive oracle."""
    rng = np.random.default_rng(sq)
    cfg = AttnConfig(d_model=16, n_heads=2, n_kv_heads=2, head_dim=8,
                     causal=causal, q_chunk=bq, kv_chunk=bq)
    q = jnp.asarray(rng.standard_normal((1, sq, 2, 8)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((1, sq, 2, 8)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((1, sq, 2, 8)), jnp.float32)
    pos = jnp.arange(sq)
    out = attention.flash_attention(cfg, q, k, v, pos, pos)
    ref = naive_attention(cfg, q, k, v, np.arange(sq))
    np.testing.assert_allclose(np.asarray(out), ref, rtol=3e-5, atol=3e-5)


def test_int8_kv_cache_roundtrip(rng):
    x = jnp.asarray(rng.standard_normal((2, 8, 4, 16)) * 3, jnp.float32)
    q, scale = attention.quantize_kv(x)
    back = attention.dequantize_kv(q, scale, jnp.float32)
    rel = np.abs(np.asarray(back - x)).max() / np.abs(np.asarray(x)).max()
    assert rel < 1.5 / 127


def test_int8_cache_decode_close_to_fp(rng):
    from repro.models.common import NATIVE_POLICY
    base = dict(d_model=32, n_heads=4, n_kv_heads=2, head_dim=8,
                q_chunk=32, kv_chunk=32)
    params = attention.init_attention(jax.random.PRNGKey(0),
                                      AttnConfig(**base))
    x = jnp.asarray(rng.standard_normal((1, 16, 32)), jnp.float32)
    xd = jnp.asarray(rng.standard_normal((1, 1, 32)), jnp.float32)
    pos = jnp.broadcast_to(jnp.arange(16), (1, 16))
    outs = {}
    for int8 in (False, True):
        cfg = AttnConfig(**base, cache_int8=int8)
        _, cache = attention.attention_prefill(params, cfg, x, pos,
                                               NATIVE_POLICY, max_seq=24)
        y, _ = attention.attention_decode(params, cfg, xd, 16, cache,
                                          NATIVE_POLICY)
        outs[int8] = np.asarray(y)
    np.testing.assert_allclose(outs[True], outs[False], rtol=0.1, atol=0.05)


# ---------------------------------------------------------------------------
# MoE dispatch invariants.
# ---------------------------------------------------------------------------

@given(seed=st.integers(0, 100), top_k=st.integers(1, 3))
@settings(max_examples=15, deadline=None)
def test_moe_dispatch_invariants(seed, top_k):
    from repro.configs.base import MoEConfig
    from repro.models.moe import _dispatch_combine, _route, init_moe, \
        padded_experts
    rng = np.random.default_rng(seed)
    cfg = MoEConfig(n_experts=6, top_k=top_k, d_ff_expert=8, pad_multiple=8,
                    n_groups=2, capacity_factor=1.0)
    params = init_moe(jax.random.PRNGKey(seed), 16, cfg, "swiglu")
    x = jnp.asarray(rng.standard_normal((2, 12, 16)), jnp.float32)
    xg = x.reshape(2, 12, 16)
    w, idx, scores = _route(params, cfg, xg)
    # padding experts (6, 7) never selected
    assert int(np.asarray(idx).max()) < cfg.n_experts
    dispatch, combine, cap = _dispatch_combine(cfg, w, idx, 12, jnp.float32)
    d = np.asarray(dispatch)
    # every (expert, slot) holds at most one token
    assert (d.sum(axis=1) <= 1.0 + 1e-6).all()
    # a token occupies at most top_k slots
    assert (d.sum(axis=(2, 3)) <= top_k + 1e-6).all()
    # combine weights are bounded by the (normalized) router weights
    assert np.asarray(combine).sum(axis=(2, 3)).max() <= 1.0 + 1e-5
