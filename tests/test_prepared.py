"""PreparedOperand + in-kernel decomposition prologue: property tests.

The prologue and the decompose kernels must be *bit-identical* to the
``scheme1.split`` + ``interleave_k`` oracle (same truncate-subtract
recurrence, same int8 slices, same int32 accumulation, same epilogue
order); PreparedOperand forward/backward must match the float64 oracle
to emulation precision on aligned and padded shapes.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import scheme1
from repro.core.emulated import emulated_dot, prepared_dot
from repro.core.precision import EmulationConfig
from repro.kernels import decompose, dispatch, ops, prepared
from repro.kernels.common import choose_blocks


def _conditioned(seed, shape, phi=2.0):
    rng = np.random.default_rng(seed)
    return jnp.asarray(((rng.random(shape) - 0.5)
                        * np.exp(phi * rng.standard_normal(shape)))
                       .astype(np.float32))


# ---------------------------------------------------------------------------
# In-kernel prologue == split + interleave + kernel, bitwise.
# ---------------------------------------------------------------------------

@given(p=st.integers(2, 6), seed=st.integers(0, 2 ** 16),
       mi=st.integers(1, 2), ki=st.integers(1, 3), ni=st.integers(1, 2))
@settings(max_examples=8, deadline=None)
def test_prologue_bit_identical_to_split_pipeline(p, seed, mi, ki, ni):
    m, k, n = 128 * mi, 128 * ki, 128 * ni
    a = _conditioned(seed, (m, k))
    b = _conditioned(seed + 1, (k, n))
    pro = ops.fused_scheme1_matmul(
        a, b, EmulationConfig(scheme="ozaki1", p=p, decomp="kernel"))
    xla = ops.fused_scheme1_matmul(
        a, b, EmulationConfig(scheme="ozaki1", p=p, decomp="xla"))
    np.testing.assert_array_equal(np.asarray(pro), np.asarray(xla))


@given(p=st.integers(2, 8), seed=st.integers(0, 2 ** 16),
       ki=st.integers(1, 3))
@settings(max_examples=8, deadline=None)
def test_decompose_rhs_kernel_matches_split_oracle(p, seed, ki):
    k, n = 128 * ki, 256
    b = _conditioned(seed, (k, n), phi=3.0)
    beta = 7 if p <= 4 else 3
    slices, nu = scheme1.split(b, p, beta, axis=0)
    ref = scheme1.interleave_k(slices, "b", 128)
    out = decompose.decompose_interleave_rhs(b, nu, p, beta, bk=128, bn=128)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(ref))


@given(p=st.integers(2, 6), seed=st.integers(0, 2 ** 16))
@settings(max_examples=6, deadline=None)
def test_decompose_pair_kernel_emits_both_layouts(p, seed):
    """One read of B -> forward rhs layout AND the K-transposed twin,
    each bit-identical to its split + interleave_k oracle."""
    k, n = 256, 128
    beta_f, beta_b = 7, 5
    b = _conditioned(seed, (k, n), phi=3.0)
    _, nu = scheme1.split(b, p, beta_f, axis=0)
    _, tau = scheme1.split(b.T, p, beta_b, axis=0)
    fwd, twin = decompose.decompose_interleave_pair(
        b, nu, tau, p, beta_f, beta_b, bk=128, bt=128)
    ref_f = scheme1.interleave_k(scheme1.split(b, p, beta_f, axis=0)[0],
                                 "b", 128)
    ref_t = scheme1.interleave_k(scheme1.split(b.T, p, beta_b, axis=0)[0],
                                 "b", 128)
    np.testing.assert_array_equal(np.asarray(fwd), np.asarray(ref_f))
    np.testing.assert_array_equal(np.asarray(twin), np.asarray(ref_t))


def test_prologue_blocks_respect_fp32_staging_budget():
    """The VMEM search must charge the fp32 staging tile: at equal
    problem/p the prologue working set can only shrink the tile."""
    for p in (2, 4, 8):
        plain = choose_blocks(2048, 2048, 2048, p)
        pro = choose_blocks(2048, 2048, 2048, p,
                            prologue_a=True, prologue_b=True)
        assert pro is not None
        acc = 4 * p * pro.bm * pro.bn
        s_op = (2 * 4 + 4 + p) * (pro.bm + pro.bn) * pro.bk
        assert acc + s_op <= 12 * 2 ** 20
        assert pro.bm * pro.bn * pro.bk <= plain.bm * plain.bn * plain.bk \
            or (2 * 4 + 4 + p) <= 2 * p


# ---------------------------------------------------------------------------
# PreparedOperand forward/backward vs the float64 oracle.
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("m,k,n", [(128, 256, 128),    # aligned
                                   (100, 200, 96)])    # padded
@pytest.mark.parametrize("impl", ["pallas", "xla"])
def test_prepared_forward_matches_oracle(m, k, n, impl):
    cfg = EmulationConfig(scheme="ozaki1", p=4, impl=impl)
    a = _conditioned(0, (m, k))
    b = _conditioned(1, (k, n))
    prep = prepared.prepare_rhs(b, cfg, with_twin=True)
    layout = "interleaved" if impl == "pallas" else "stacked"
    assert prep.layout == layout and prep.twin.layout == layout
    out = np.asarray(prepared.matmul_prepared(a, prep))
    ref = np.asarray(a, np.float64) @ np.asarray(b, np.float64)
    rel = np.abs(out - ref).max() / np.abs(ref).max()
    assert -np.log2(rel) > 18
    # the twin computes dC @ B^T
    g = _conditioned(2, (m, n))
    da = np.asarray(prepared.matmul_prepared(g, prep.twin))
    ref_da = np.asarray(g, np.float64) @ np.asarray(b, np.float64).T
    rel = np.abs(da - ref_da).max() / np.abs(ref_da).max()
    assert -np.log2(rel) > 15


@pytest.mark.parametrize("m,k,n", [(64, 128, 128), (60, 100, 72)])
def test_cached_vjp_matches_uncached(m, k, n):
    """cfg.cache_weights reroutes forward + dA through PreparedOperand;
    gradients must agree with the re-splitting path to emulation
    precision (identical slices -> near-identical results)."""
    a = _conditioned(3, (m, k))
    b = _conditioned(4, (k, n))

    def loss(cfg):
        def f(a, b):
            return jnp.sum(jnp.sin(emulated_dot(a, b, cfg)))
        return jax.grad(f, argnums=(0, 1))(a, b)

    ga_c, gb_c = loss(EmulationConfig(scheme="ozaki1", p=4,
                                      cache_weights=True))
    ga_u, gb_u = loss(EmulationConfig(scheme="ozaki1", p=4))
    for gc, gu in ((ga_c, ga_u), (gb_c, gb_u)):
        np.testing.assert_allclose(np.asarray(gc), np.asarray(gu),
                                   rtol=1e-4, atol=1e-4 * float(
                                       jnp.abs(gu).max() + 1e-9))


def test_cached_vjp_complex_falls_back_to_4m():
    """cache_weights must not hijack complex problems: the prepared path
    is real-only, so complex activations keep the 4M expansion and match
    the uncached result exactly."""
    ar = _conditioned(20, (32, 64))
    ai = _conditioned(21, (32, 64))
    a = (ar + 1j * ai).astype(jnp.complex64)
    b = _conditioned(22, (64, 32))

    def val(cfg):
        return emulated_dot(a, b, cfg)

    cached = np.asarray(val(EmulationConfig(scheme="ozaki1", p=4,
                                            cache_weights=True,
                                            out_dtype="complex64")))
    plain = np.asarray(val(EmulationConfig(scheme="ozaki1", p=4,
                                           out_dtype="complex64")))
    np.testing.assert_array_equal(cached, plain)
    # and the prepared primitives refuse complex operands loudly
    prep = prepared.prepare_rhs(b, EmulationConfig(scheme="ozaki1", p=4))
    with pytest.raises(ValueError, match="complex"):
        prepared.matmul_prepared(a, prep)
    with pytest.raises(ValueError, match="real-valued"):
        prepared.prepare_rhs(a.T @ a, EmulationConfig(scheme="ozaki1", p=4))


def test_cached_vjp_respects_bwd_p():
    """Mixed-precision emulated training: the twin is prepared at bwd_p."""
    cfg = EmulationConfig(scheme="ozaki1", p=4, bwd_p=2, cache_weights=True)
    b = _conditioned(5, (128, 128))
    prep = prepared.prepare_rhs(b, cfg, with_twin=True)
    assert prep.p == 4 and prep.twin.p == 2


def test_prepared_through_dispatch_and_batched():
    cfg = EmulationConfig(scheme="ozaki1", p=4)
    a = _conditioned(6, (2, 3, 64, 128))
    b = _conditioned(7, (128, 96))
    prep = prepared.prepare_rhs(b, cfg)
    out = np.asarray(dispatch.emulated_matmul_batched(a, prep, cfg=cfg))
    assert out.shape == (2, 3, 64, 96)
    ref = np.asarray(a, np.float64) @ np.asarray(b, np.float64)
    rel = np.abs(out - ref).max() / np.abs(ref).max()
    assert -np.log2(rel) > 18


def test_prepared_dot_jits_as_pytree():
    """PreparedOperand must cross a jit boundary (serve-session reuse)."""
    cfg = EmulationConfig(scheme="ozaki1", p=3)
    x = _conditioned(8, (4, 32, 128))
    w = _conditioned(9, (128, 128))
    prep = prepared.prepare_rhs(w, cfg)
    f = jax.jit(lambda x, w: prepared_dot(x, w))
    out = np.asarray(f(x, prep))
    ref = np.asarray(x, np.float64) @ np.asarray(w, np.float64)
    assert out.shape == (4, 32, 128)
    rel = np.abs(out - ref).max() / np.abs(ref).max()
    assert -np.log2(rel) > 12


# ---------------------------------------------------------------------------
# Scheme-II PreparedResidues: pre-encoded residue stacks.
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("m,k,n", [(64, 128, 96),      # aligned
                                   (100, 200, 72)])    # padded
def test_prepared_residues_forward_bit_identical(m, k, n):
    """A PreparedResidues rhs (encode once, stream forever) must equal
    the unprepared scheme2.matmul bitwise — the stored stack is the same
    balanced encode the reference runs per call."""
    from repro.core import scheme2
    # 6 moduli: ~19 bits per operand at these K (4 moduli would only
    # budget ~11 — the accuracy floor below is budget-dependent).
    cfg = EmulationConfig(scheme="ozaki2", p=6)
    a = _conditioned(30, (m, k))
    b = _conditioned(31, (k, n))
    prep = prepared.prepare_rhs(b, cfg, with_twin=True)
    assert isinstance(prep, prepared.PreparedResidues)
    assert prep.moduli == cfg.resolved_moduli()
    assert prep.residues.shape[0] == 6
    out = np.asarray(prepared.matmul_prepared(a, prep))
    oracle = np.asarray(scheme2.matmul(a, b, cfg, jnp.float32))
    np.testing.assert_array_equal(out, oracle)
    # the twin computes dC @ B^T at its own contraction budget
    g = _conditioned(32, (m, n))
    da = np.asarray(prepared.matmul_prepared(g, prep.twin))
    ref_da = np.asarray(g, np.float64) @ np.asarray(b, np.float64).T
    rel = np.abs(da - ref_da).max() / np.abs(ref_da).max()
    # ~19-bit operand budget at these K; conditioned matrices eat a few
    # bits of headroom.
    assert -np.log2(rel) > 12


def test_cached_vjp_ozaki2_matches_uncached():
    """'ozaki2-mN+cached' reroutes forward + dA through PreparedResidues;
    gradients must agree with the re-encoding path to emulation
    precision."""
    a = _conditioned(33, (60, 100))
    b = _conditioned(34, (100, 72))

    def loss(cfg):
        def f(a, b):
            return jnp.sum(jnp.sin(emulated_dot(a, b, cfg)))
        return jax.grad(f, argnums=(0, 1))(a, b)

    base = EmulationConfig(scheme="ozaki2", p=4, impl="xla")
    ga_c, gb_c = loss(EmulationConfig(scheme="ozaki2", p=4, impl="xla",
                                      cache_weights=True))
    ga_u, gb_u = loss(base)
    for gc, gu in ((ga_c, ga_u), (gb_c, gb_u)):
        np.testing.assert_allclose(np.asarray(gc), np.asarray(gu),
                                   rtol=1e-4, atol=1e-4 * float(
                                       jnp.abs(gu).max() + 1e-9))


def test_prepared_residues_refuse_complex_and_non2d():
    cfg = EmulationConfig(scheme="ozaki2", p=4)
    with pytest.raises(ValueError, match="real-valued"):
        prepared.prepare_rhs(
            jnp.ones((8, 8), jnp.complex64), cfg)
    with pytest.raises(ValueError, match="2-D"):
        prepared.prepare_rhs(jnp.ones((2, 8, 8)), cfg)
    prep = prepared.prepare_rhs(_conditioned(35, (64, 48)), cfg)
    with pytest.raises(ValueError, match="complex"):
        prepared.matmul_prepared(
            jnp.ones((8, 64), jnp.complex64), prep)
    with pytest.raises(ValueError, match="K="):
        prepared.matmul_prepared(jnp.ones((8, 32)), prep)


def test_prepared_residues_respect_bwd_p():
    """Mixed-precision backward: the twin keeps the leading bwd_p
    moduli, mirroring _bwd_core's replace(p=bwd_p)."""
    cfg = EmulationConfig(scheme="ozaki2", p=6, bwd_p=3,
                          cache_weights=True)
    prep = prepared.prepare_rhs(_conditioned(38, (64, 64)), cfg,
                                with_twin=True)
    assert prep.p == 6 and prep.twin.p == 3
    assert prep.twin.moduli == prep.moduli[:3]


def test_prepared_residues_layout_follows_impl_and_backend():
    """The consume route is pinned at prepare time: impl='xla' (the
    resolve_policy GSPMD clamp) or a non-gpu backend resolution stays on
    the XLA expansion; a gpu resolution takes the fused kernel."""
    b = _conditioned(39, (64, 48))
    stacked = prepared.prepare_rhs(
        b, EmulationConfig(scheme="ozaki2", p=4, impl="xla"))
    assert stacked.layout == "stacked"
    fused = prepared.prepare_rhs(
        b, EmulationConfig(scheme="ozaki2", p=4, backend="gpu"))
    assert fused.layout == "fused"
    # a Scheme-I artifact under an ozaki2 config is refused cleanly
    prep1 = prepared.prepare_rhs(b, EmulationConfig(scheme="ozaki1", p=4))
    with pytest.raises(ValueError, match="Scheme-I"):
        prepared.prepare_rhs(prep1, EmulationConfig(scheme="ozaki2", p=4))


def test_prepare_params_wraps_ozaki2_projections():
    from repro.models.common import GemmPolicy
    policy = GemmPolicy(default=EmulationConfig(scheme="ozaki2", p=4,
                                                impl="xla"))
    params = {"ffn": {"wi": _conditioned(36, (64, 128))},
              "mixer": {"w_r": _conditioned(37, (64, 64))}}
    out = prepared.prepare_params(params, policy)
    assert isinstance(out["ffn"]["wi"], prepared.PreparedResidues)
    assert isinstance(out["mixer"]["w_r"], jax.Array)  # einsum-consumed


def test_prepare_params_wraps_only_dense_projections():
    from repro.models.common import GemmPolicy
    policy = GemmPolicy(default=EmulationConfig(scheme="ozaki1", p=3,
                                                impl="xla"))
    params = {
        "mixer": {"wq": jnp.ones((128, 128)), "w_r": jnp.ones((128, 128)),
                  "conv_w": jnp.ones((4, 128))},
        "ffn": {"wi": jnp.ones((128, 256)), "wo": jnp.ones((256, 128))},
        "emb": jnp.ones((512, 128)),
        "layers": {"wi": jnp.ones((2, 128, 256))},  # scan-stacked: 3-D
    }
    out = prepared.prepare_params(params, policy)
    assert isinstance(out["mixer"]["wq"], prepared.PreparedOperand)
    assert isinstance(out["ffn"]["wi"], prepared.PreparedOperand)
    assert isinstance(out["ffn"]["wo"], prepared.PreparedOperand)
    # einsum-consumed / non-dense / stacked leaves pass through untouched
    assert isinstance(out["mixer"]["w_r"], jax.Array)
    assert isinstance(out["mixer"]["conv_w"], jax.Array)
    assert isinstance(out["emb"], jax.Array)
    assert isinstance(out["layers"]["wi"], jax.Array)


def test_prepared_serving_forward_matches_plain():
    """A prepared tiny model must produce (near-)identical logits."""
    from repro.models.common import GemmPolicy, dense
    policy = GemmPolicy(default=EmulationConfig(scheme="ozaki1", p=4,
                                                impl="xla"))
    params = {"ffn": {"wi": _conditioned(10, (64, 128)),
                      "wo": _conditioned(11, (128, 64))}}
    x = _conditioned(12, (2, 8, 64))

    def fwd(params):
        h = dense(x, params["ffn"]["wi"], policy, "ffn")
        return dense(jax.nn.gelu(h), params["ffn"]["wo"], policy, "ffn")

    plain = np.asarray(fwd(params))
    prepped = np.asarray(fwd(prepared.prepare_params(params, policy)))
    np.testing.assert_allclose(prepped, plain, rtol=1e-4,
                               atol=1e-4 * np.abs(plain).max())
