"""Kernel dispatch layer: compat feature-probe, block-selection caching,
and the padded non-aligned fused path."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest
from jax.experimental.pallas import tpu as pltpu

from repro.core.precision import EmulationConfig
from repro.kernels import compat, dispatch
from repro.kernels.common import choose_blocks


# ---------------------------------------------------------------------------
# compat: the feature probe, under both attribute names.
# ---------------------------------------------------------------------------

def test_compiler_params_probe_resolves_installed_class():
    cls = compat.compiler_params_cls()
    assert cls is not None, "installed jax exposes no TPU compiler params"
    assert cls is getattr(pltpu, "CompilerParams",
                          getattr(pltpu, "TPUCompilerParams", None))


@pytest.mark.parametrize("name", ["CompilerParams", "TPUCompilerParams"])
def test_compiler_params_probe_accepts_either_name(monkeypatch, name):
    """The shim must resolve whichever of the two names an installed jax
    carries — simulate both vintages against a stand-in namespace."""
    import dataclasses

    @dataclasses.dataclass
    class Fake:
        dimension_semantics: tuple | None = None

    for stale in ("CompilerParams", "TPUCompilerParams"):
        monkeypatch.delattr(compat.pltpu, stale, raising=False)
    monkeypatch.setattr(compat.pltpu, name, Fake, raising=False)
    compat.compiler_params_cls.cache_clear()
    compat.compiler_params_fields.cache_clear()
    try:
        assert compat.compiler_params_cls() is Fake
        params = compat.tpu_compiler_params(
            dimension_semantics=("parallel", "arbitrary"))
        assert params.dimension_semantics == ("parallel", "arbitrary")
    finally:
        compat.compiler_params_cls.cache_clear()
        compat.compiler_params_fields.cache_clear()


def test_unknown_compiler_fields_are_dropped():
    params = compat.tpu_compiler_params(
        dimension_semantics=("parallel",),
        not_a_real_field_ever=123)
    assert not hasattr(params, "not_a_real_field_ever") or \
        getattr(params, "not_a_real_field_ever", None) is None


def test_scalar_prefetch_grid_spec_constructs():
    import jax.experimental.pallas as pl
    spec = compat.scalar_prefetch_grid_spec(
        num_scalar_prefetch=1,
        grid=(2, 2),
        in_specs=[pl.BlockSpec((128, 128), lambda i, j, s: (i, j))],
        out_specs=pl.BlockSpec((128, 128), lambda i, j, s: (i, j)),
        scratch_shapes=[pltpu.VMEM((128, 128), jnp.int32)],
    )
    assert spec is not None


# ---------------------------------------------------------------------------
# dispatch: block-selection caching.
# ---------------------------------------------------------------------------

def test_select_blocks_matches_choose_blocks_and_caches():
    dispatch.block_cache_clear()
    b1 = dispatch.select_blocks(512, 512, 512, p=4, backend="tpu")
    misses = dispatch.block_cache_info().misses
    b2 = dispatch.select_blocks(512, 512, 512, p=4, backend="tpu")
    assert b1 == b2 == choose_blocks(512, 512, 512, 4)
    assert dispatch.block_cache_info().misses == misses  # second call: hit
    assert dispatch.block_cache_info().hits >= 1


def test_select_blocks_key_includes_backend():
    dispatch.block_cache_clear()
    dispatch.select_blocks(256, 256, 256, p=2, backend="cpu")
    m = dispatch.block_cache_info().misses
    dispatch.select_blocks(256, 256, 256, p=2, backend="tpu-v5e")
    assert dispatch.block_cache_info().misses == m + 1


def test_block_cache_reports_and_clears_per_backend():
    dispatch.block_cache_clear()
    dispatch.select_blocks(512, 512, 512, p=4, backend="tpu")
    dispatch.select_blocks(512, 512, 512, p=4, backend="gpu")
    info = dispatch.block_cache_info()
    assert set(info.per_backend) >= {"tpu", "gpu"}
    assert info.currsize == 2
    # per-backend stats are addressable directly
    assert dispatch.block_cache_info("gpu").currsize == 1
    # clearing one backend leaves the other's entries alone
    dispatch.block_cache_clear("gpu")
    info = dispatch.block_cache_info()
    assert "gpu" not in info.per_backend and "tpu" in info.per_backend
    assert dispatch.block_cache_info("tpu").currsize == 1
    dispatch.block_cache_clear()
    assert dispatch.block_cache_info().currsize == 0


def test_select_blocks_uses_backend_alignment():
    """The backend's capability drives alignment: a 16-lane GPU problem
    that the 128-lane TPU search refuses still gets GPU tiles."""
    dispatch.block_cache_clear()
    assert dispatch.select_blocks(48, 80, 64, p=4, backend="tpu") is None
    gpu_blocks = dispatch.select_blocks(48, 80, 64, p=4, backend="gpu")
    assert gpu_blocks is not None
    assert gpu_blocks.bm % 16 == 0 and gpu_blocks.bn % 16 == 0
    assert gpu_blocks.aligned(48, 80, 64)


# ---------------------------------------------------------------------------
# dispatch: padded non-aligned path vs the float64 oracle.
# ---------------------------------------------------------------------------

def test_padded_nonaligned_scheme1_matches_oracle(make_matrix):
    a = jnp.asarray(make_matrix((100, 200)))
    b = jnp.asarray(make_matrix((200, 96)))
    # historical behavior: ValueError("no aligned blocks ...") — now padded
    out = np.asarray(dispatch.emulated_matmul(
        a, b, cfg=EmulationConfig(scheme="ozaki1", p=4)))
    assert out.shape == (100, 96)
    ref = np.asarray(a, np.float64) @ np.asarray(b, np.float64)
    rel = np.abs(out - ref).max() / np.abs(ref).max()
    assert -np.log2(rel) > 18


def test_padded_nonaligned_scheme2_matches_oracle(make_matrix):
    a = jnp.asarray(make_matrix((100, 200)))
    b = jnp.asarray(make_matrix((200, 96)))
    out = np.asarray(dispatch.emulated_matmul(a, b, cfg="ozaki2-m8"))
    ref = np.asarray(a, np.float64) @ np.asarray(b, np.float64)
    rel = np.abs(out - ref).max() / np.abs(ref).max()
    assert -np.log2(rel) > 18


def test_aligned_shapes_skip_padding(make_matrix):
    a = jnp.asarray(make_matrix((128, 128)))
    b = jnp.asarray(make_matrix((128, 128)))
    a_p, b_p = dispatch.pad_operands(a, b)
    assert a_p is a and b_p is b


def test_pallas_impl_no_longer_raises_on_unaligned(make_matrix):
    from repro.core.emulated import emulated_dot
    a = jnp.asarray(make_matrix((100, 200)))
    b = jnp.asarray(make_matrix((200, 96)))
    cfg = EmulationConfig(scheme="ozaki1", p=3, impl="pallas")
    out = np.asarray(emulated_dot(a, b, cfg))
    ref = np.asarray(a, np.float64) @ np.asarray(b, np.float64)
    rel = np.abs(out - ref).max() / np.abs(ref).max()
    assert -np.log2(rel) > 12


def test_core_fused_wrappers_pin_their_scheme(make_matrix):
    """scheme1.fused_matmul must run Scheme I even when handed a cfg built
    for the other scheme (the wrapper pins, the dispatcher dispatches)."""
    from repro.core import scheme1, scheme2
    a = jnp.asarray(make_matrix((128, 128)))
    b = jnp.asarray(make_matrix((128, 128)))
    cfg2 = EmulationConfig(scheme="ozaki2", p=8)
    out1 = np.asarray(scheme1.fused_matmul(a, b, cfg2))
    ref = np.asarray(a, np.float64) @ np.asarray(b, np.float64)
    assert np.abs(out1 - ref).max() / np.abs(ref).max() < 1e-3
    cfg1 = EmulationConfig(scheme="ozaki1", p=4)
    out2 = np.asarray(scheme2.fused_matmul(a, b, cfg1))
    # scheme2 path is bit-identical to its XLA reference
    xla = np.asarray(scheme2.matmul(a, b,
                                    EmulationConfig(scheme="ozaki2", p=4),
                                    jnp.float32))
    np.testing.assert_allclose(out2, xla, rtol=0, atol=0)


def test_emulated_matmul_honors_cfg_out_dtype(make_matrix):
    a = jnp.asarray(make_matrix((128, 128)))
    b = jnp.asarray(make_matrix((128, 128)))
    cfg = EmulationConfig(scheme="ozaki1", p=4, out_dtype="bfloat16")
    out = dispatch.emulated_matmul(a, b, cfg=cfg)
    assert out.dtype == jnp.bfloat16
    # explicit argument wins over the config
    out2 = dispatch.emulated_matmul(a, b, cfg=cfg, out_dtype=jnp.float32)
    assert out2.dtype == jnp.float32


# ---------------------------------------------------------------------------
# dispatch: batched paths.
# ---------------------------------------------------------------------------

def test_batched_leading_dims_flatten(make_matrix):
    a = jnp.asarray(make_matrix((2, 3, 64, 128)))
    b = jnp.asarray(make_matrix((128, 128)))
    out = np.asarray(dispatch.emulated_matmul_batched(a, b, cfg="ozaki2-m8"))
    assert out.shape == (2, 3, 64, 128)
    ref = np.asarray(a, np.float64) @ np.asarray(b, np.float64)
    rel = np.abs(out - ref).max() / np.abs(ref).max()
    assert -np.log2(rel) > 18


def test_batched_vmap_over_shared_axis(make_matrix):
    a = jnp.asarray(make_matrix((3, 128, 128)))
    b = jnp.asarray(make_matrix((3, 128, 128)))
    out = np.asarray(dispatch.emulated_matmul_batched(a, b, cfg="ozaki1-p3"))
    ref = np.einsum("bij,bjk->bik", np.asarray(a, np.float64),
                    np.asarray(b, np.float64))
    rel = np.abs(out - ref).max() / np.abs(ref).max()
    assert -np.log2(rel) > 12


# ---------------------------------------------------------------------------
# dispatch: launch-policy resolution.
# ---------------------------------------------------------------------------

def test_resolve_policy_pins_xla_off_tpu(monkeypatch):
    from repro import EMULATION_ENV_VAR
    from repro.models.common import GemmPolicy
    # An externally set ambient spec (the CI row running the suite under
    # REPRO_EMULATION=ozaki2-m6) would be materialized into the unset
    # policy below — this test is about the clamps, not the resolver.
    monkeypatch.delenv(EMULATION_ENV_VAR, raising=False)
    pol = GemmPolicy(default=EmulationConfig(scheme="ozaki1", p=3,
                                             impl="pallas"),
                     overrides=(("ffn", EmulationConfig(scheme="ozaki2",
                                                        p=8, impl="auto")),))
    resolved = dispatch.resolve_policy(pol, mesh=None)
    if jax.default_backend() != "tpu":
        assert resolved.default.impl == "xla"
        assert dict(resolved.overrides)["ffn"].impl == "xla"
    # native / explicit-xla policies pass through untouched
    native = GemmPolicy()
    assert dispatch.resolve_policy(native, mesh=None) is native
