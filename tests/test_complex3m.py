"""3M complex Scheme II: correctness and the no-cancellation property."""

import jax.numpy as jnp
import numpy as np

from repro.core import complex3m, scheme1
from repro.core.precision import EmulationConfig


def test_3m_matches_reference(make_matrix):
    a = (make_matrix((96, 96)) + 1j * make_matrix((96, 96))).astype(
        np.complex64)
    b = (make_matrix((96, 96)) + 1j * make_matrix((96, 96))).astype(
        np.complex64)
    ref = a.astype(np.complex128) @ b.astype(np.complex128)
    out = np.asarray(complex3m.matmul(
        jnp.asarray(a), jnp.asarray(b),
        EmulationConfig(scheme="ozaki2", p=10)))
    rel = np.abs(out - ref).max() / np.abs(ref).max()
    assert -np.log2(rel) > 13


def test_3m_no_cancellation_when_parts_similar(rng):
    """The float 3M identity loses accuracy when |re| ~ |im| (catastrophic
    cancellation in T3-T1-T2); the modular-integer 3M must not. Compare
    against float32 3M on near-equal re/im parts."""
    n = 64
    re = rng.standard_normal((n, n)).astype(np.float32)
    im = re + 1e-5 * rng.standard_normal((n, n)).astype(np.float32)
    a = (re + 1j * im).astype(np.complex64)
    b = (re.T + 1j * (re.T + 1e-5)).astype(np.complex64)
    ref = a.astype(np.complex128) @ b.astype(np.complex128)

    # float32 3M (the cancellation-prone formulation)
    t1 = re @ re.T
    t2 = im @ (re.T + 1e-5).astype(np.float32)
    t3 = (re + im) @ (re.T + (re.T + 1e-5)).astype(np.float32)
    float3m_im = t3 - t1 - t2
    err_float = np.abs(float3m_im - ref.imag).max()

    out = np.asarray(complex3m.matmul(
        jnp.asarray(a), jnp.asarray(b),
        EmulationConfig(scheme="ozaki2", p=10)))
    err_mod = np.abs(out.imag - ref.imag).max()
    assert err_mod <= err_float * 1.5 + 1e-6
    # And the modular path is accurate in absolute terms.
    assert err_mod / np.abs(ref.imag).max() < 2 ** -12


def test_3m_gemm_count_25pct_fewer_than_4m():
    cfg = EmulationConfig(scheme="ozaki2", p=8)
    assert complex3m.gemm_count(cfg) == 24          # 3p
    # 4M via Scheme I machinery would be 4 GEMMs per slice-pair product
    assert complex3m.gemm_count(cfg) == 0.75 * 4 * cfg.p
