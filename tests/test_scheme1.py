"""Ozaki Scheme I: decomposition exactness, interleaved layout, precision."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import scheme1
from repro.core.precision import EmulationConfig, safe_beta
from conftest import conditioned


def test_split_reconstructs_to_residual_bound(make_matrix):
    a = jnp.asarray(make_matrix((64, 96)))
    p, beta = 5, 7
    slices, scale = scheme1.split(a, p, beta, axis=1)
    # reconstruct on host in true float64 (device f64 is unavailable —
    # and warns — without x64 mode)
    rec = sum(2.0 ** (-beta * (i + 1)) * np.asarray(slices[i], np.float64)
              for i in range(p)) * np.asarray(scale, np.float64)
    resid = np.abs(rec - np.asarray(a, np.float64))
    bound = np.asarray(scale) * 2.0 ** (-beta * p)
    assert (resid <= bound + 1e-30).all()


def test_slices_fit_beta_bits(make_matrix):
    a = jnp.asarray(make_matrix((32, 32), phi=4.0))
    for beta in (4, 7):
        slices, _ = scheme1.split(a, 4, beta, axis=1)
        assert np.abs(np.asarray(slices)).max() <= 2 ** beta - 1


@pytest.mark.parametrize("operand", ["a", "b"])
@pytest.mark.parametrize("t_k", [32, 128])
def test_interleave_roundtrip(rng, operand, t_k):
    p, m, k = 3, 8, 256
    shape = (p, m, k) if operand == "a" else (p, k, m)
    slices = jnp.asarray(rng.integers(-127, 127, shape), jnp.int8)
    x = scheme1.interleave_k(slices, operand, t_k)
    assert x.shape == ((m, p * k) if operand == "a" else (p * k, m))
    back = scheme1.deinterleave_k(x, p, operand, t_k)
    np.testing.assert_array_equal(np.asarray(back), np.asarray(slices))


def test_interleave_layout_eq11(rng):
    """Check the exact Eq. 11 placement: chunk c of slice i lands at
    column block c*p + i."""
    p, m, k, t_k = 3, 4, 128, 32
    slices = jnp.asarray(rng.integers(-10, 10, (p, m, k)), jnp.int8)
    a_hat = scheme1.interleave_k(slices, "a", t_k)
    for i in range(p):
        for c in range(k // t_k):
            np.testing.assert_array_equal(
                np.asarray(a_hat[:, (c * p + i) * t_k:(c * p + i + 1) * t_k]),
                np.asarray(slices[i, :, c * t_k:(c + 1) * t_k]))


@pytest.mark.parametrize("p,min_bits", [(2, 9), (3, 14), (4, 20)])
def test_precision_grows_with_p(make_matrix, p, min_bits):
    """~beta bits per slice (paper: each slice adds ~8 bits; beta=7 here)."""
    a = jnp.asarray(make_matrix((128, 128), phi=2.0))
    b = jnp.asarray(make_matrix((128, 128), phi=2.0))
    ref = np.asarray(a, np.float64) @ np.asarray(b, np.float64)
    c = np.asarray(scheme1.matmul(a, b, EmulationConfig(scheme="ozaki1", p=p),
                                  jnp.float32))
    rel = np.abs(c - ref).max() / np.abs(ref).max()
    assert -np.log2(rel) >= min_bits


def test_triangular_gemm_count():
    cfg = EmulationConfig(scheme="ozaki1", p=8)
    assert cfg.gemm_count() == 36  # p(p+1)/2, paper Table II


@given(k=st.integers(1, 2 ** 20))
@settings(max_examples=50, deadline=None)
def test_safe_beta_exactness_bound(k):
    beta = safe_beta(k)
    assert k * (2 ** beta - 1) ** 2 < 2 ** 31


def test_complex_4m(make_matrix, rng):
    a = (make_matrix((64, 64)) + 1j * make_matrix((64, 64))).astype(
        np.complex64)
    b = (make_matrix((64, 64)) + 1j * make_matrix((64, 64))).astype(
        np.complex64)
    ref = np.asarray(a, np.complex128) @ np.asarray(b, np.complex128)
    c = np.asarray(scheme1.matmul_complex_4m(
        jnp.asarray(a), jnp.asarray(b), EmulationConfig(scheme="ozaki1", p=4)))
    rel = np.abs(c - ref).max() / np.abs(ref).max()
    assert -np.log2(rel) > 18
