"""Continuous-batching serve engine: allocator, paged cache, scheduler,
and end-to-end per-request bit-identity (src/repro/serving).

The engine's load-bearing invariant is per-lane row independence: a
request's tokens must be bit-identical whatever cohort, chunking, or
eviction history the scheduler produced. The model-level tests here pin
that by comparing the continuous engine against its own wave-admission
(lockstep) schedule. The allocator/scheduler tests are pure host-side
properties: no page leaked, no double-free, no request starved.
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro import configs
from repro.core.precision import EmulationAccuracyError
from repro.models import model as M
from repro.launch.mesh import make_host_mesh
from repro.serving import (ContinuousEngine, PageAllocator, PagedKVCache,
                           Request, RequestQueue, ScheduleConfig, Scheduler,
                           SCRATCH_PAGE)


# ---------------------------------------------------------------------------
# Page allocator.
# ---------------------------------------------------------------------------

class TestPageAllocator:
    def test_scratch_page_reserved(self):
        a = PageAllocator(num_pages=4)
        got = a.alloc(3, rid=1)
        assert got is not None and SCRATCH_PAGE not in got
        assert a.alloc(1, rid=2) is None       # exhausted (3 usable)
        assert a.alloc_failures == 1

    def test_all_or_nothing(self):
        a = PageAllocator(num_pages=5)
        assert a.alloc(2, rid=1) is not None
        assert a.alloc(3, rid=2) is None       # only 2 left: no partials
        assert a.free_pages == 2

    def test_double_free_and_foreign_free_raise(self):
        a = PageAllocator(num_pages=4)
        pages = a.alloc(2, rid=1)
        a.free(pages[:1], rid=1)
        with pytest.raises(ValueError, match="double free"):
            a.free(pages[:1], rid=1)
        with pytest.raises(ValueError, match="owned by"):
            a.free(pages[1:], rid=2)
        with pytest.raises(ValueError, match="scratch"):
            a.free([SCRATCH_PAGE], rid=1)

    def test_leak_check(self):
        a = PageAllocator(num_pages=4)
        a.alloc(2, rid=7)
        a.check_leaks({7})
        with pytest.raises(AssertionError, match="leaked"):
            a.check_leaks(set())



@settings(max_examples=20)
@given(st.lists(st.integers(min_value=0, max_value=5), min_size=1,
                max_size=40))
def test_random_alloc_free_conserves_pages(ops):
    a = PageAllocator(num_pages=9)
    held: dict[int, list[int]] = {}
    for i, n in enumerate(ops):
        if n == 0 and held:                    # free the oldest holding
            rid = next(iter(held))
            a.free(held.pop(rid), rid)
            continue
        got = a.alloc(n, rid=i)
        if got is not None:
            held[i] = held.get(i, []) + got
    assert a.used_pages + a.free_pages == 8
    assert a.used_pages == sum(len(v) for v in held.values())
    in_use = [p for v in held.values() for p in v]
    assert len(set(in_use)) == len(in_use)     # no page double-granted
    a.check_leaks(set(held))


# ---------------------------------------------------------------------------
# Request queue policies.
# ---------------------------------------------------------------------------

class TestRequestQueue:
    def _req(self, n, arrival):
        return Request(prompt=list(range(1, n + 1)), max_new_tokens=2,
                       arrival=arrival)

    def test_fcfs_orders_by_arrival(self):
        q = RequestQueue(policy="fcfs")
        b = q.submit(self._req(3, arrival=2.0))
        a = q.submit(self._req(9, arrival=1.0))
        assert q.pop_ready(now=5.0) is a
        assert q.pop_ready(now=5.0) is b
        assert q.pop_ready(now=5.0) is None

    def test_not_yet_arrived_is_invisible(self):
        q = RequestQueue()
        q.submit(self._req(3, arrival=10.0))
        assert q.pop_ready(now=1.0) is None
        assert q.depth(now=1.0) == 0 and q.pending() == 1

    def test_spf_prefers_short_prompts(self):
        q = RequestQueue(policy="spf", spf_age_limit=100.0)
        long = q.submit(self._req(20, arrival=0.0))
        short = q.submit(self._req(2, arrival=1.0))
        assert q.pop_ready(now=2.0) is short
        assert q.pop_ready(now=2.0) is long

    def test_spf_age_limit_falls_back_to_fcfs(self):
        q = RequestQueue(policy="spf", spf_age_limit=5.0)
        old_long = q.submit(self._req(20, arrival=0.0))
        q.submit(self._req(2, arrival=6.0))
        assert q.pop_ready(now=6.0) is old_long   # aged past the valve

    def test_requeue_keeps_arrival_position(self):
        q = RequestQueue()
        a = q.submit(self._req(3, arrival=1.0))
        q.submit(self._req(3, arrival=2.0))
        first = q.pop_ready(now=3.0)
        assert first is a
        q.requeue(first)                          # evicted: same position
        assert q.pop_ready(now=3.0) is a


# ---------------------------------------------------------------------------
# Paged KV cache: gather/scatter bit-identity against a contiguous cache.
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def smoke_arch():
    return configs.get_smoke_config("olmo-1b")


class TestPagedKVCache:
    def test_rec_arch_refused(self):
        arch = configs.get_smoke_config("mamba2-780m")
        with pytest.raises(NotImplementedError, match="sequence axis"):
            PagedKVCache(arch.model, page_size=4, num_pages=8, max_seq=32,
                         chunk=4)

    def test_gather_bit_identical_to_contiguous(self, smoke_arch, rng):
        """Tokens scattered page-by-page gather back exactly equal to a
        contiguous cache holding the same values — whatever (shuffled)
        physical pages the allocator handed out."""
        mcfg = smoke_arch.model
        kv = PagedKVCache(mcfg, page_size=4, num_pages=20, max_seq=32,
                          chunk=8)
        n_tok, lane_count = 13, 2
        kv.ensure(101, n_tok)
        kv.ensure(202, n_tok)
        pools = kv.init_pools()
        tables = kv.tables_for([101, 202])

        # Contiguous reference: random values for every (lane, token).
        ref = jax.tree.map(
            lambda leaf: jnp.asarray(
                rng.standard_normal(leaf.shape).astype(leaf.dtype)
                if jnp.issubdtype(leaf.dtype, jnp.floating) else
                rng.integers(-100, 100, leaf.shape).astype(leaf.dtype)),
            jax.eval_shape(lambda: M.init_cache(mcfg, lane_count,
                                                kv.view_tokens)))

        chunk = kv.chunk
        for start in range(0, n_tok, chunk):
            n = min(chunk, n_tok - start)
            starts = np.full((lane_count,), start, np.int32)
            n_new = np.full((lane_count,), n, np.int32)
            pools = kv.scatter(pools, tables, ref, jnp.asarray(starts),
                               jnp.asarray(n_new), chunk)
        views = kv.gather(pools, tables)

        def cut(leaf, ax):
            sl = [slice(None)] * leaf.ndim
            sl[ax.seq] = slice(0, n_tok)
            return leaf[tuple(sl)]

        for got, want, ax in zip(jax.tree.leaves(views),
                                 jax.tree.leaves(ref),
                                 jax.tree.leaves(kv._axes)):
            np.testing.assert_array_equal(np.asarray(cut(got, ax)),
                                          np.asarray(cut(want, ax)))

    def test_invalid_writes_land_on_scratch(self, smoke_arch):
        """Padding columns and unbacked positions must never touch an
        allocated page: they are routed to the scratch page."""
        mcfg = smoke_arch.model
        kv = PagedKVCache(mcfg, page_size=4, num_pages=8, max_seq=16,
                          chunk=4)
        kv.ensure(1, 4)
        pools = kv.init_pools()
        tables = kv.tables_for([1])
        ones = jax.tree.map(
            lambda leaf: jnp.ones(leaf.shape, leaf.dtype),
            jax.eval_shape(lambda: M.init_cache(mcfg, 1, kv.view_tokens)))
        # n_new = 0: the whole chunk is padding.
        pools = kv.scatter(pools, tables, ones, jnp.zeros((1,), jnp.int32),
                           jnp.zeros((1,), jnp.int32), 4)
        page = kv.table_row(1)[0]
        for leaf, ax in zip(jax.tree.leaves(pools),
                            jax.tree.leaves(kv._axes)):
            tok_ax = ax.seq - 1
            sl = [slice(None)] * leaf.ndim
            sl[tok_ax] = slice(page * 4, page * 4 + 4)
            assert not np.asarray(leaf[tuple(sl)]).any(), \
                "padding write leaked onto an allocated page"


# ---------------------------------------------------------------------------
# Scheduler properties (no model: fake deterministic sampling).
# ---------------------------------------------------------------------------

def _drive(sched: Scheduler, max_steps: int = 2000):
    """Run the scheduler with sampling that is a pure function of
    (rid, #generated), so eviction replays reproduce tokens exactly."""
    steps = 0
    while sched.has_work():
        assert steps < max_steps, "scheduler failed to drain (starvation?)"
        plan = sched.plan(now=float(steps))
        if plan is not None:
            sampled = np.zeros((sched.cfg.max_lanes,), np.int32)
            for lane, state in enumerate(sched.lanes):
                if state is not None and plan.emit[lane]:
                    sampled[lane] = (state.rid * 31
                                     + len(state.generated)) % 97
            sched.commit(plan, sampled, now=float(steps))
        sched.check_invariants()
        steps += 1
    return steps


def _mk_sched(*, lanes=2, chunk=4, page_size=4, num_pages=8,
              max_seq=32, policy="fcfs", token_budget=None):
    arch = configs.get_smoke_config("olmo-1b")
    kv = PagedKVCache(arch.model, page_size=page_size,
                      num_pages=num_pages, max_seq=max_seq, chunk=chunk)
    cfg = ScheduleConfig(max_lanes=lanes, chunk=chunk,
                         token_budget=token_budget, policy=policy)
    return Scheduler(cfg, kv)


@settings(max_examples=15)
@given(st.data())
def test_bounded_trace_drains_without_leaks(data):
    """Property: any bounded trace completes — every page freed, every
    fitting request served, no starvation under either queue policy."""
    sched = _mk_sched(policy=data.draw(st.sampled_from(["fcfs", "spf"])))
    n = data.draw(st.integers(min_value=1, max_value=8))
    reqs = []
    for i in range(n):
        plen = data.draw(st.integers(min_value=1, max_value=24))
        gen = data.draw(st.integers(min_value=1, max_value=6))
        arr = float(data.draw(st.integers(min_value=0, max_value=20)))
        reqs.append(sched.queue.submit(
            Request(prompt=list(range(1, plen + 1)),
                    max_new_tokens=gen, arrival=arr)))
    _drive(sched)
    assert sched.kv.allocator.used_pages == 0              # no page leaked
    for s in reqs:
        assert s.status in ("done", "failed")
        if s.status == "done":
            assert len(s.generated) == s.request.max_new_tokens
        else:         # only over-capacity requests may fail
            assert not sched._fits_forever(s)


class TestSchedulerProperties:
    def _mk(self, **kw):
        return _mk_sched(**kw)

    def test_eviction_replay_reproduces_tokens(self):
        """Starved pools force evictions; re-prefilled requests must
        finish with the same tokens the no-pressure run produces."""
        tight = self._mk(lanes=3, num_pages=8)
        roomy = self._mk(lanes=3, num_pages=64)
        traces = []
        for sched in (tight, roomy):
            reqs = [sched.queue.submit(
                Request(prompt=list(range(1, 15)), max_new_tokens=5,
                        arrival=0.0, rid=1000 + i)) for i in range(5)]
            _drive(sched)
            traces.append({s.rid: list(s.generated) for s in reqs})
        assert tight.evictions > 0, "test needs page pressure"
        assert traces[0] == traces[1]

    def test_token_budget_caps_concurrency(self):
        sched = self._mk(lanes=4, num_pages=64, token_budget=30)
        for i in range(6):
            sched.queue.submit(Request(prompt=list(range(1, 11)),
                                       max_new_tokens=5, arrival=0.0))
        steps = 0
        while sched.has_work():
            assert steps < 2000
            load = sum(s.request.total_tokens for s in sched.running())
            assert load <= 30, f"token budget breached: {load}"
            plan = sched.plan(now=float(steps))
            if plan is not None:
                sampled = np.zeros((4,), np.int32)
                sched.commit(plan, sampled, now=float(steps))
            steps += 1

    def test_oversize_request_fails_not_deadlocks(self):
        sched = self._mk(num_pages=4, max_seq=32)   # 3 usable pages = 12 tok
        s = sched.queue.submit(Request(prompt=list(range(1, 30)),
                                       max_new_tokens=4, arrival=0.0))
        _drive(sched, max_steps=50)
        assert s.status == "failed"
        assert sched.kv.allocator.used_pages == 0


# ---------------------------------------------------------------------------
# End-to-end engine (real model, smoke config).
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def serve_mesh():
    return make_host_mesh()


def _trace(arch, n, seed=3, max_new=(3, 6)):
    r = np.random.default_rng(seed)
    return [Request(prompt=r.integers(1, arch.model.vocab,
                                      r.integers(4, 20)).tolist(),
                    max_new_tokens=int(r.integers(*max_new)), arrival=0.0)
            for _ in range(n)]


def _run(arch, mesh, reqs, **kw):
    with mesh:
        eng = ContinuousEngine(arch, mesh, max_seq=48, seed=0, **kw)
        res = eng.run([Request(prompt=q.prompt,
                               max_new_tokens=q.max_new_tokens,
                               arrival=q.arrival, rid=q.rid)
                       for q in reqs], max_steps=4000)
        eng.sched.check_invariants()
    return eng, res


class TestContinuousEngine:
    def test_matches_lockstep_reference_bitwise(self, smoke_arch,
                                                serve_mesh):
        """The acceptance property: mixed prefill+decode continuous steps
        are bit-identical per request to the lockstep (wave) schedule."""
        reqs = _trace(smoke_arch, 5)
        _, cont = _run(smoke_arch, serve_mesh, reqs, max_lanes=2, chunk=8,
                       page_size=8)
        _, wave = _run(smoke_arch, serve_mesh, reqs, max_lanes=2, chunk=8,
                       page_size=8, wave_admission=True)
        for q in reqs:
            assert cont[q.rid].status == "done"
            assert cont[q.rid].tokens == wave[q.rid].tokens

    def test_chunk_size_does_not_change_tokens(self, smoke_arch,
                                               serve_mesh):
        reqs = _trace(smoke_arch, 3, seed=4)
        _, a = _run(smoke_arch, serve_mesh, reqs, max_lanes=2, chunk=4,
                    page_size=8)
        _, b = _run(smoke_arch, serve_mesh, reqs, max_lanes=2, chunk=16,
                    page_size=8)
        for q in reqs:
            assert a[q.rid].tokens == b[q.rid].tokens

    def test_eviction_and_restart_identity(self, smoke_arch, serve_mesh):
        reqs = _trace(smoke_arch, 5, seed=5)
        tight, rt = _run(smoke_arch, serve_mesh, reqs, max_lanes=3,
                         chunk=8, page_size=4, num_pages=10)
        _, ref = _run(smoke_arch, serve_mesh, reqs, max_lanes=3, chunk=8,
                      page_size=4, wave_admission=True)
        assert tight.sched.evictions > 0, "test needs page pressure"
        for q in reqs:
            assert rt[q.rid].tokens == ref[q.rid].tokens
        evicted = [rt[q.rid].evictions for q in reqs]
        assert sum(evicted) == tight.sched.evictions  # attributed per req

    def test_isolation_replay_reproduces_fast_path(self, smoke_arch,
                                                   serve_mesh):
        """Force the guard-retry path on every step: the eager per-lane
        replay must produce the same tokens as the jitted fast path."""
        reqs = _trace(smoke_arch, 3, seed=6)
        _, ref = _run(smoke_arch, serve_mesh, reqs, max_lanes=2, chunk=8,
                      page_size=8)
        with serve_mesh:
            eng = ContinuousEngine(smoke_arch, serve_mesh, max_seq=48,
                                   seed=0, max_lanes=2, chunk=8,
                                   page_size=8)

            def tripping(*a, **k):
                raise EmulationAccuracyError("synthetic trip")

            eng._jit_fns = {c: tripping for c in eng._jit_fns}
            res = eng.run([Request(prompt=q.prompt,
                                   max_new_tokens=q.max_new_tokens,
                                   arrival=0.0, rid=q.rid)
                           for q in reqs], max_steps=4000)
        for q in reqs:
            assert res[q.rid].status == "done"
            assert res[q.rid].tokens == ref[q.rid].tokens

    def test_guard_failure_scoped_to_offending_request(self, smoke_arch,
                                                       serve_mesh):
        """A request whose eager replay keeps raising strict must fail
        alone: cohort members complete, untouched and untripped."""
        reqs = _trace(smoke_arch, 3, seed=7)
        victim_rid = reqs[1].rid
        with serve_mesh:
            eng = ContinuousEngine(smoke_arch, serve_mesh, max_seq=48,
                                   seed=0, max_lanes=2, chunk=8,
                                   page_size=8, guard_retries=1)
            jit_orig = dict(eng._jit_fns)
            eager_orig = dict(eng._step_fns)

            def make_tripping_jit(c):
                def f(params, pools, tables, tokens, start, n_new):
                    lanes = [s for s in eng.sched.lanes if s is not None]
                    if any(s.rid == victim_rid for s in lanes):
                        raise EmulationAccuracyError("synthetic trip")
                    return jit_orig[c](params, pools, tables, tokens,
                                       start, n_new)
                return f

            def make_failing_eager(c):
                def f(params, pools, tables, tokens, start, n_new):
                    nn = np.asarray(n_new)
                    for lane, s in enumerate(eng.sched.lanes):
                        if (s is not None and s.rid == victim_rid
                                and nn[lane] > 0):
                            raise EmulationAccuracyError("still failing")
                    return eager_orig[c](params, pools, tables, tokens,
                                         start, n_new)
                return f

            eng._jit_fns = {c: make_tripping_jit(c) for c in jit_orig}
            eng._step_fns = {c: make_failing_eager(c) for c in eager_orig}
            res = eng.run([Request(prompt=q.prompt,
                                   max_new_tokens=q.max_new_tokens,
                                   arrival=0.0, rid=q.rid)
                           for q in reqs], max_steps=4000)
        assert res[victim_rid].status == "failed"
        assert res[victim_rid].guard_trips > 0
        for q in reqs:
            if q.rid != victim_rid:
                assert res[q.rid].status == "done"
                assert res[q.rid].guard_trips == 0

    def test_serve_telemetry_recorded(self, smoke_arch, serve_mesh):
        from repro import telemetry
        telemetry.enable()
        try:
            reqs = _trace(smoke_arch, 2, seed=8)
            _run(smoke_arch, serve_mesh, reqs, max_lanes=2, chunk=8,
                 page_size=8)
            text = telemetry.render_prometheus()
        finally:
            telemetry.disable()
        for metric in ("repro_serve_tokens_total",
                       "repro_serve_requests_total",
                       "repro_serve_ttft_seconds",
                       "repro_serve_queue_depth"):
            assert metric in text, f"missing serve metric {metric}"
