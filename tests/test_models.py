"""Per-architecture smoke tests (assignment requirement) + the gold
decode-vs-teacher-forcing consistency check."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.models import model as M
from repro.models.common import pad_vocab

B, S = 2, 64


def _inputs(m, rng, seq=S):
    if m.frontend == "audio_stub":
        return {"tokens": jnp.asarray(
            rng.standard_normal((B, seq, m.frontend_dim)), jnp.float32)}
    inputs = {"tokens": jnp.asarray(rng.integers(0, m.vocab, (B, seq)),
                                    jnp.int32)}
    if m.frontend == "vision_stub":
        inputs["image_embeds"] = jnp.asarray(
            rng.standard_normal((B, m.n_image_tokens, m.frontend_dim)),
            jnp.float32)
    return inputs


@pytest.mark.parametrize("arch", configs.ARCH_IDS)
def test_smoke_forward_shapes_no_nans(arch, rng):
    m = configs.get_smoke_config(arch).model
    params = M.init_params(jax.random.PRNGKey(0), m)
    logits, mtp, aux = M.forward_train(params, m, _inputs(m, rng))
    assert logits.shape == (B, S, pad_vocab(m.vocab))
    assert not np.isnan(np.asarray(logits)).any()
    assert mtp is None or not np.isnan(np.asarray(mtp)).any()
    assert np.isfinite(float(aux))


@pytest.mark.parametrize("arch", configs.ARCH_IDS)
def test_smoke_train_step_decreases_loss(arch):
    """One SGD-ish step on repeated data lowers the loss (gradient flows
    through every block type)."""
    from repro.configs.base import ShapeSpec
    from repro.data import make_batch_iterator
    from repro.launch import steps as Steps
    from repro.launch.mesh import make_host_mesh
    from repro.optim import make_optimizer

    cfg = configs.get_smoke_config(arch)
    shape = ShapeSpec("t", 32, 4, "train")
    mesh = make_host_mesh()
    opt_init, _ = make_optimizer(cfg.train.optimizer)
    params = M.init_params(jax.random.PRNGKey(0), cfg.model)
    state = {"params": params, "opt": opt_init(params)}
    _, batch = next(iter(make_batch_iterator(cfg, shape)))
    with mesh:
        step = Steps.make_train_step(cfg, mesh, shape, donate=False)
        losses = []
        for _ in range(3):
            state, metrics = step(state, batch)
            losses.append(float(metrics["loss"]))
            assert np.isfinite(losses[-1])
    assert losses[-1] < losses[0]


@pytest.mark.parametrize("arch", [a for a in configs.ARCH_IDS
                                  if configs.get_smoke_config(a).model.causal])
def test_decode_matches_teacher_forcing(arch, rng):
    cfg = configs.get_smoke_config(arch)
    m = cfg.model
    if m.moe is not None:  # lossless capacity so no tokens are dropped
        m = dataclasses.replace(m, moe=dataclasses.replace(
            m.moe, capacity_factor=float(m.moe.n_experts)))
    params = M.init_params(jax.random.PRNGKey(0), m)
    inputs = _inputs(m, rng)
    logits, _, _ = M.forward_train(params, m, inputs)
    pre = dict(inputs)
    pre["tokens"] = inputs["tokens"][:, :S - 4]
    _, cache = M.forward_prefill(params, m, pre, max_seq=S)
    for t in range(S - 4, S):
        dl, cache = M.forward_decode(params, m, inputs["tokens"][:, t:t + 1],
                                     t, cache)
        np.testing.assert_allclose(np.asarray(dl[:, 0]),
                                   np.asarray(logits[:, t]),
                                   rtol=1e-3, atol=2e-4)


def test_encoder_is_bidirectional(rng):
    """hubert: flipping a late frame must change early-position logits."""
    m = configs.get_smoke_config("hubert-xlarge").model
    params = M.init_params(jax.random.PRNGKey(0), m)
    x = _inputs(m, rng)
    logits1, _, _ = M.forward_train(params, m, x)
    x2 = {"tokens": x["tokens"].at[:, -1].add(10.0)}
    logits2, _, _ = M.forward_train(params, m, x2)
    assert np.abs(np.asarray(logits1[:, 0] - logits2[:, 0])).max() > 1e-6


def test_causal_masking_is_strict(rng):
    """Decoder: perturbing a late token must NOT change earlier logits."""
    m = configs.get_smoke_config("granite-3-8b").model
    params = M.init_params(jax.random.PRNGKey(0), m)
    toks = rng.integers(0, m.vocab, (B, S)).astype(np.int32)
    l1, _, _ = M.forward_train(params, m, {"tokens": jnp.asarray(toks)})
    toks2 = toks.copy()
    toks2[:, -1] = (toks2[:, -1] + 7) % m.vocab
    l2, _, _ = M.forward_train(params, m, {"tokens": jnp.asarray(toks2)})
    np.testing.assert_allclose(np.asarray(l1[:, :-1]), np.asarray(l2[:, :-1]),
                               atol=1e-5)


def test_vision_stub_prefix_influences_output(rng):
    m = configs.get_smoke_config("internvl2-1b").model
    params = M.init_params(jax.random.PRNGKey(0), m)
    x = _inputs(m, rng)
    l1, _, _ = M.forward_train(params, m, x)
    x2 = dict(x)
    x2["image_embeds"] = x["image_embeds"] + 1.0
    l2, _, _ = M.forward_train(params, m, x2)
    assert np.abs(np.asarray(l1 - l2)).max() > 1e-6


def test_emu_configs_resolve_per_site_policies():
    """The -emu zoo variants ship their emulation choices as ArchConfig
    gemm_sites tables: 'default' sets the policy default, other rows
    become per-site overrides (the repro.precision spec grammar)."""
    for arch_id in ("olmo-1b-emu", "qwen2-moe-a2.7b-emu"):
        for cfg in (configs.get_config(arch_id),
                    configs.get_smoke_config(arch_id)):
            pol = cfg.gemm_policy()
            assert pol.default is not None
            assert pol.default.scheme == "ozaki1"
            assert pol.default.p == 4 and pol.default.cache_weights
            overrides = dict(pol.overrides)
            assert overrides["attn_qk"].scheme == "ozaki2"
            assert overrides["attn_qk"].p == 6
            assert overrides["attn_av"].scheme == "ozaki1"
    moe = dict(configs.get_config("qwen2-moe-a2.7b-emu")
               .gemm_policy().overrides)
    assert moe["moe_expert"].scheme == "ozaki1"
    assert moe["moe_gate"].scheme == "ozaki2"
    # plain archs carry an empty table -> the bare ambient-deferring
    # policy (native unless a repro.emulation scope / env says otherwise)
    plain = configs.get_config("olmo-1b").gemm_policy()
    assert plain.default is None and plain.overrides == ()


def test_policy_einsum_native_is_bitwise_jnp_einsum(rng):
    """The native path of the model-zoo einsum shim is EXACTLY
    jnp.einsum — no emulation machinery touches reference runs."""
    from repro.models.common import NATIVE_POLICY, policy_einsum
    q = jnp.asarray(rng.standard_normal((2, 4, 2, 3, 8)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((2, 5, 2, 8)), jnp.float32)
    got = policy_einsum("bqkgd,bjkd->bkgqj", q, k, NATIVE_POLICY,
                        "attn_qk", pet=jnp.float32)
    want = jnp.einsum("bqkgd,bjkd->bkgqj", q, k,
                      preferred_element_type=jnp.float32)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_forward_bit_identical_under_native_site_resolution(rng):
    """A bare GemmPolicy() (empty gemm_sites table) resolving in a
    native ambient must produce bit-identical model outputs to the
    explicit NATIVE_POLICY — wiring the attention/MoE/MLA/SSD einsums
    through policy_einsum changed nothing for native runs."""
    import os
    from repro.models.common import GemmPolicy
    assert not os.environ.get("REPRO_EMULATION")
    for arch in ("olmo-1b", "qwen2-moe-a2.7b", "mamba2-780m"):
        m = configs.get_smoke_config(arch).model
        params = M.init_params(jax.random.PRNGKey(0), m)
        inputs = _inputs(m, rng)
        ref, _, _ = M.forward_train(params, m, inputs)
        got, _, _ = M.forward_train(params, m, inputs,
                                    policy=GemmPolicy())
        np.testing.assert_array_equal(np.asarray(got), np.asarray(ref))


def test_emu_smoke_forward_runs_emulated_sites(rng):
    """The emu smoke config's own policy drives every wired site through
    the emulated path: finite logits, close to (not bitwise) native."""
    cfg = configs.get_smoke_config("qwen2-moe-a2.7b-emu")
    m = cfg.model
    params = M.init_params(jax.random.PRNGKey(0), m)
    inputs = _inputs(m, rng)
    ref, _, _ = M.forward_train(params, m, inputs)
    got, _, _ = M.forward_train(params, m, inputs,
                                policy=cfg.gemm_policy())
    got_np, ref_np = np.asarray(got), np.asarray(ref)
    assert np.isfinite(got_np).all()
    # near-native accuracy (abs: near-zero logits have wild rel error)
    np.testing.assert_allclose(got_np, ref_np, rtol=0, atol=1e-3)
    assert not np.array_equal(got_np, ref_np)  # emulation actually ran


def test_local_window_attention_limits_context(rng):
    """recurrentgemma attention layers: tokens beyond the window cannot
    influence the current logit through the attention path. (They still
    can via the RG-LRU, so test the attention block in isolation.)"""
    from repro.models import attention
    from repro.models.attention import AttnConfig
    cfg = AttnConfig(d_model=32, n_heads=2, n_kv_heads=1, head_dim=16,
                     window=8, q_chunk=16, kv_chunk=16)
    params = attention.init_attention(jax.random.PRNGKey(1), cfg)
    x = jnp.asarray(rng.standard_normal((1, 64, 32)), jnp.float32)
    pos = jnp.broadcast_to(jnp.arange(64), (1, 64))
    from repro.models.common import NATIVE_POLICY
    y1 = attention.attention_train(params, cfg, x, pos, NATIVE_POLICY)
    x2 = x.at[:, 10].add(5.0)   # token 10 is outside window of position 40+
    y2 = attention.attention_train(params, cfg, x2, pos, NATIVE_POLICY)
    np.testing.assert_allclose(np.asarray(y1[:, 40:]),
                               np.asarray(y2[:, 40:]), atol=1e-5)
