"""The pluggable kernel-backend subsystem: registry semantics, selection
precedence, capability-driven fallback, and the Mosaic-GPU/Triton
Scheme-I lowering's bit-parity (interpret mode) against the
``scheme1.split`` / ``scheme1.matmul`` oracles."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import scheme1, scheme2
from repro.core.precision import EmulationConfig
from repro.kernels import backends, dispatch
from repro.kernels.backends import gpu as gpu_backend
from repro.kernels.common import Blocks, carve_slices


# ---------------------------------------------------------------------------
# Registry: registration, lookup, selection precedence.
# ---------------------------------------------------------------------------

def test_builtin_backends_registered():
    assert {"tpu", "gpu", "xla"} <= set(backends.available_backends())
    assert backends.get_backend("tpu").capabilities.align == 128
    assert backends.get_backend("gpu").capabilities.align == 16
    assert backends.get_backend("xla").capabilities.align == 1
    assert backends.get_backend("gpu").capabilities.schemes == {"ozaki1"}
    assert "ozaki2" in backends.get_backend("tpu").capabilities.schemes


def test_get_backend_unknown_raises():
    with pytest.raises(KeyError):
        backends.get_backend("hexagon")


def test_register_backend_guards_duplicates():
    class Fake(backends.KernelBackend):
        name = "tpu"
        capabilities = backends.get_backend("tpu").capabilities

        def choose_blocks(self, *a, **k):
            return None

        def matmul(self, *a, **k):
            raise NotImplementedError

    with pytest.raises(ValueError):
        backends.register_backend(Fake())


def test_register_and_unregister_custom_backend():
    tpu = backends.get_backend("tpu")

    class Custom(backends.KernelBackend):
        name = "my-npu"

        @property
        def capabilities(self):
            return tpu.capabilities

        def choose_blocks(self, *a, **k):
            return tpu.choose_blocks(*a, **k)

        def matmul(self, *a, **k):
            return tpu.matmul(*a, **k)

    try:
        backends.register_backend(Custom())
        assert "my-npu" in backends.available_backends()
        assert backends.resolve_backend_name("my-npu") == "my-npu"
    finally:
        backends.unregister_backend("my-npu")
    assert "my-npu" not in backends.available_backends()


def test_resolution_precedence(monkeypatch):
    monkeypatch.delenv(backends.ENV_VAR, raising=False)
    cfg = EmulationConfig(scheme="ozaki1", p=4, backend="gpu")
    # cfg.backend wins over the platform default...
    assert backends.resolve_backend_name(None, cfg) == "gpu"
    # ...the env override wins over cfg...
    monkeypatch.setenv(backends.ENV_VAR, "xla")
    assert backends.resolve_backend_name(None, cfg) == "xla"
    # ...and the explicit argument wins over everything.
    assert backends.resolve_backend_name("tpu", cfg) == "tpu"


def test_resolution_falls_back_for_unknown_names(monkeypatch):
    monkeypatch.delenv(backends.ENV_VAR, raising=False)
    assert backends.resolve_backend_name("tpu-v5e") == "tpu"
    default = backends.default_backend_name()
    assert backends.resolve_backend_name("never-heard-of-it") == default
    assert backends.resolve_backend_name(None) == default


def test_env_override_routes_plan(monkeypatch):
    monkeypatch.setenv(backends.ENV_VAR, "gpu")
    a = jnp.zeros((64, 64), jnp.float32)
    cfg = EmulationConfig(scheme="ozaki1", p=4)
    plan = dispatch.plan_emulated(a, a, cfg)
    assert plan.backend == "gpu"
    assert plan.align == 16


# ---------------------------------------------------------------------------
# Capability fallback: unsupported (scheme, backend) -> 'xla' reference.
# ---------------------------------------------------------------------------

def test_unsupported_scheme_falls_back_to_xla_reference(make_matrix):
    a = jnp.asarray(make_matrix((100, 72)))
    b = jnp.asarray(make_matrix((72, 56)))
    cfg = EmulationConfig(scheme="ozaki2", p=8, backend="gpu")
    plan = dispatch.plan_emulated(a, b, cfg)
    assert plan.backend == "xla"
    out = dispatch.emulated_matmul(a, b, cfg=cfg)
    ref = scheme2.matmul(a, b, cfg, jnp.float32)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=0, atol=0)  # bit-identical reference


def test_fallback_is_not_offered_to_auto_sites(make_matrix):
    """auto_fused_matmul must return None (let the caller run its own
    XLA expansion) when the selected backend fell back, instead of
    pretending the reference path is a fused win."""
    a = jnp.asarray(make_matrix((64, 64)))
    cfg = EmulationConfig(scheme="ozaki2", p=8, backend="gpu")
    assert dispatch.auto_fused_matmul(a, a, cfg) is None


# ---------------------------------------------------------------------------
# GPU backend: block search and the Scheme-I bit-parity suite.
# ---------------------------------------------------------------------------

def test_gpu_blocks_respect_budgets_and_alignment():
    for p in (3, 4, 6):
        blocks = gpu_backend.choose_blocks_gpu(256, 256, 256, p)
        assert blocks is not None
        assert blocks.bm % 16 == 0 and blocks.bn % 16 == 0 \
            and blocks.bk % 16 == 0
        assert 4 * p * blocks.bm * blocks.bn <= gpu_backend.ACC_BUDGET
        smem = (2 * 4 + p) * (blocks.bm + blocks.bn) * blocks.bk \
            + 4 * blocks.bm * blocks.bn
        assert smem <= gpu_backend.SMEM_BUDGET


def test_gpu_higher_p_shrinks_accumulator_tile():
    b1 = gpu_backend.choose_blocks_gpu(512, 512, 512, p=1)
    b8 = gpu_backend.choose_blocks_gpu(512, 512, 512, p=8)
    assert b1.bm * b1.bn >= b8.bm * b8.bn


@pytest.mark.parametrize("m,k,n", [(64, 96, 80), (128, 128, 128),
                                   (48, 112, 16)])
@pytest.mark.parametrize("p", [3, 4, 6])
def test_gpu_scheme1_bit_parity_aligned(make_matrix, m, k, n, p):
    """16-aligned shapes: the GPU lowering must be bit-identical to the
    scheme1.matmul oracle (same slices, same exact int32 interior, same
    shift-reduce order)."""
    a = jnp.asarray(make_matrix((m, k)))
    b = jnp.asarray(make_matrix((k, n)))
    cfg = EmulationConfig(scheme="ozaki1", p=p, backend="gpu")
    out = dispatch.emulated_matmul(a, b, cfg=cfg)
    oracle = scheme1.matmul(a, b, cfg, jnp.float32)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(oracle))


@pytest.mark.parametrize("m,k,n", [(100, 200, 96), (50, 70, 30)])
@pytest.mark.parametrize("p", [3, 4, 6])
def test_gpu_scheme1_bit_parity_unaligned_padded(make_matrix, m, k, n, p):
    """Non-16-aligned shapes pad to the GPU tile, run fused, slice back —
    still bit-identical to the unpadded oracle (zero rows/cols carve to
    zero slices and leave every kept row/col scale untouched)."""
    a = jnp.asarray(make_matrix((m, k)))
    b = jnp.asarray(make_matrix((k, n)))
    cfg = EmulationConfig(scheme="ozaki1", p=p, backend="gpu")
    out = dispatch.emulated_matmul(a, b, cfg=cfg)
    assert out.shape == (m, n)
    oracle = scheme1.matmul(a, b, cfg, jnp.float32)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(oracle))


def test_gpu_kernel_slices_match_scheme1_split(make_matrix):
    """The in-kernel carve (shared-memory staging prologue) is the same
    truncate-and-subtract recurrence as scheme1.split: per-tile carving
    of a/scale reproduces the full-array slices bit-exactly."""
    a = jnp.asarray(make_matrix((64, 96)))
    p, beta = 4, 7
    a_sl, mu = scheme1.split(a, p, beta, axis=1)
    carved = list(carve_slices(a / mu, p, beta))
    for got, want in zip(carved, a_sl):
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_gpu_fused_matmul_rejects_misaligned_blocks(make_matrix):
    a = jnp.asarray(make_matrix((64, 64)))
    with pytest.raises(ValueError):
        gpu_backend.fused_matmul_scheme1(
            a, a, jnp.ones((64, 1)), jnp.ones((1, 64)), 3, 7,
            Blocks(48, 48, 48))


def test_gpu_out_dtype_and_batching(make_matrix):
    a = jnp.asarray(make_matrix((2, 3, 32, 64)))
    b = jnp.asarray(make_matrix((64, 48)))
    cfg = EmulationConfig(scheme="ozaki1", p=4, backend="gpu",
                          out_dtype="bfloat16")
    out = dispatch.emulated_matmul_batched(a, b, cfg=cfg)
    assert out.shape == (2, 3, 32, 48)
    assert out.dtype == jnp.bfloat16


# ---------------------------------------------------------------------------
# resolve_policy: (scheme, backend) clamping.
# ---------------------------------------------------------------------------

def test_resolve_policy_clamps_unsupported_scheme_backend(monkeypatch):
    """On a launch target that would otherwise keep fused impls (a
    single-device host natively compiling the selected backend), a
    (scheme, backend) pair without a fused lowering pins impl='xla'
    while supported pairs keep their request."""
    from repro.models.common import GemmPolicy
    monkeypatch.delenv(backends.ENV_VAR, raising=False)
    monkeypatch.setattr(dispatch.jax, "default_backend", lambda: "gpu")
    pol = GemmPolicy(
        default=EmulationConfig(scheme="ozaki2", p=8, impl="pallas",
                                backend="gpu"),
        overrides=(("ffn", EmulationConfig(scheme="ozaki1", p=4,
                                           impl="pallas", backend="gpu")),))
    resolved = dispatch.resolve_policy(pol, mesh=None)
    assert resolved.default.impl == "xla"          # ozaki2 x gpu: clamped
    assert dict(resolved.overrides)["ffn"].impl == "pallas"  # supported


def test_resolve_policy_clamps_cross_platform_backend(monkeypatch):
    """A backend the host cannot natively compile (tpu kernels on a GPU
    host and vice versa) pins impl='xla' even single-device."""
    from repro.models.common import GemmPolicy
    monkeypatch.delenv(backends.ENV_VAR, raising=False)
    monkeypatch.setattr(dispatch.jax, "default_backend", lambda: "gpu")
    pol = GemmPolicy(default=EmulationConfig(scheme="ozaki1", p=4,
                                             impl="pallas", backend="tpu"))
    assert dispatch.resolve_policy(pol, mesh=None).default.impl == "xla"


# ---------------------------------------------------------------------------
# Per-backend roofline projection.
# ---------------------------------------------------------------------------

def test_projected_throughput_tables():
    from repro.utils import roofline
    proj = roofline.projected_throughput(4096, 4096, 4096, p=4,
                                         backend="gpu")
    hw = proj["hardware"]
    assert set(hw) == {"h100", "b200"}
    for cell in hw.values():
        assert 0.0 < cell["fraction_of_peak"] <= 1.0
        assert cell["projected_tops"] <= cell["peak_int8_tops"]
    # Blackwell peak dominates Hopper's
    assert hw["b200"]["peak_int8_tops"] > hw["h100"]["peak_int8_tops"]
    tpu = roofline.projected_throughput(4096, 4096, 4096, p=4,
                                        backend="tpu")["hardware"]
    assert set(tpu) == {"v5e"}
    # family-prefixed and unknown names resolve to a table, not a KeyError
    from repro.core import traffic
    assert traffic.backend_peaks("tpu-v5e") is traffic.BACKEND_PEAKS["tpu"]
    assert traffic.backend_peaks("mystery") is traffic.BACKEND_PEAKS["tpu"]
