"""The pluggable kernel-backend subsystem: registry semantics, selection
precedence, capability-driven fallback, and the Mosaic-GPU/Triton
Scheme-I lowering's bit-parity (interpret mode) against the
``scheme1.split`` / ``scheme1.matmul`` oracles."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import scheme1, scheme2
from repro.core.precision import EmulationConfig
from repro.kernels import backends, dispatch
from repro.kernels.backends import gpu as gpu_backend
from repro.kernels.common import Blocks, carve_slices


# ---------------------------------------------------------------------------
# Registry: registration, lookup, selection precedence.
# ---------------------------------------------------------------------------

def test_builtin_backends_registered():
    assert {"tpu", "gpu", "xla"} <= set(backends.available_backends())
    assert backends.get_backend("tpu").capabilities.align == 128
    assert backends.get_backend("gpu").capabilities.align == 16
    assert backends.get_backend("xla").capabilities.align == 1
    assert backends.get_backend("gpu").capabilities.schemes \
        == {"ozaki1", "ozaki2"}
    assert "ozaki2" in backends.get_backend("tpu").capabilities.schemes


def test_get_backend_unknown_raises():
    with pytest.raises(KeyError):
        backends.get_backend("hexagon")


def test_register_backend_guards_duplicates():
    class Fake(backends.KernelBackend):
        name = "tpu"
        capabilities = backends.get_backend("tpu").capabilities

        def choose_blocks(self, *a, **k):
            return None

        def matmul(self, *a, **k):
            raise NotImplementedError

    with pytest.raises(ValueError):
        backends.register_backend(Fake())


def test_register_and_unregister_custom_backend():
    tpu = backends.get_backend("tpu")

    class Custom(backends.KernelBackend):
        name = "my-npu"

        @property
        def capabilities(self):
            return tpu.capabilities

        def choose_blocks(self, *a, **k):
            return tpu.choose_blocks(*a, **k)

        def matmul(self, *a, **k):
            return tpu.matmul(*a, **k)

    try:
        backends.register_backend(Custom())
        assert "my-npu" in backends.available_backends()
        assert backends.resolve_backend_name("my-npu") == "my-npu"
    finally:
        backends.unregister_backend("my-npu")
    assert "my-npu" not in backends.available_backends()


def test_resolution_precedence(monkeypatch):
    monkeypatch.delenv(backends.ENV_VAR, raising=False)
    cfg = EmulationConfig(scheme="ozaki1", p=4, backend="gpu")
    # cfg.backend wins over the platform default...
    assert backends.resolve_backend_name(None, cfg) == "gpu"
    # ...the env override wins over cfg...
    monkeypatch.setenv(backends.ENV_VAR, "xla")
    assert backends.resolve_backend_name(None, cfg) == "xla"
    # ...and the explicit argument wins over everything.
    assert backends.resolve_backend_name("tpu", cfg) == "tpu"


def test_resolution_falls_back_for_unknown_names(monkeypatch):
    monkeypatch.delenv(backends.ENV_VAR, raising=False)
    assert backends.resolve_backend_name("tpu-v5e") == "tpu"
    default = backends.default_backend_name()
    assert backends.resolve_backend_name("never-heard-of-it") == default
    assert backends.resolve_backend_name(None) == default


def test_env_override_routes_plan(monkeypatch):
    monkeypatch.setenv(backends.ENV_VAR, "gpu")
    a = jnp.zeros((64, 64), jnp.float32)
    cfg = EmulationConfig(scheme="ozaki1", p=4)
    plan = dispatch.plan_emulated(a, a, cfg)
    assert plan.backend == "gpu"
    assert plan.align == 16


# ---------------------------------------------------------------------------
# Capability fallback: unsupported (scheme, backend) -> 'xla' reference.
# ---------------------------------------------------------------------------

# A moduli set the fused GPU Scheme-II kernel cannot carry (count >
# MAX_MODULI=16) but that is still valid Scheme-II data everywhere
# else: the 16-entry default table plus one more coprime prime.
from repro.core.precision import DEFAULT_MODULI  # noqa: E402

_WIDE_MODULI = DEFAULT_MODULI + (181,)


def test_unsupported_moduli_fall_back_to_xla_reference(make_matrix):
    a = jnp.asarray(make_matrix((100, 72)))
    b = jnp.asarray(make_matrix((72, 56)))
    cfg = EmulationConfig(scheme="ozaki2", p=4, moduli=_WIDE_MODULI,
                          backend="gpu")
    plan = dispatch.plan_emulated(a, b, cfg)
    assert plan.backend == "xla"
    out = dispatch.emulated_matmul(a, b, cfg=cfg)
    ref = scheme2.matmul(a, b, cfg, jnp.float32)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=0, atol=0)  # bit-identical reference


def test_fallback_is_not_offered_to_auto_sites(make_matrix):
    """auto_fused_matmul must return None (let the caller run its own
    XLA expansion) when the selected backend fell back — but loudly,
    naming the fused path being skipped and its moduli limit."""
    a = jnp.asarray(make_matrix((64, 64)))
    cfg = EmulationConfig(scheme="ozaki2", p=4, moduli=_WIDE_MODULI,
                          backend="gpu")
    dispatch.fallback_warnings_clear()  # warning is deduped per process
    with pytest.warns(RuntimeWarning, match="moduli"):
        assert dispatch.auto_fused_matmul(a, a, cfg) is None


def test_gpu_matmul_names_moduli_limit(make_matrix):
    from repro.kernels.backends.gpu import MAX_MODULI
    a = jnp.asarray(make_matrix((64, 64)))
    cfg = EmulationConfig(scheme="ozaki2", p=4, moduli=_WIDE_MODULI)
    with pytest.raises(ValueError, match=str(MAX_MODULI)):
        backends.get_backend("gpu").matmul(a, a, cfg, jnp.float32, None)


# ---------------------------------------------------------------------------
# GPU backend: block search and the Scheme-I bit-parity suite.
# ---------------------------------------------------------------------------

def test_gpu_blocks_respect_budgets_and_alignment():
    for p in (3, 4, 6):
        blocks = gpu_backend.choose_blocks_gpu(256, 256, 256, p)
        assert blocks is not None
        assert blocks.bm % 16 == 0 and blocks.bn % 16 == 0 \
            and blocks.bk % 16 == 0
        assert 4 * p * blocks.bm * blocks.bn <= gpu_backend.ACC_BUDGET
        smem = (2 * 4 + p) * (blocks.bm + blocks.bn) * blocks.bk \
            + 4 * blocks.bm * blocks.bn
        assert smem <= gpu_backend.SMEM_BUDGET


def test_gpu_higher_p_shrinks_accumulator_tile():
    b1 = gpu_backend.choose_blocks_gpu(512, 512, 512, p=1)
    b8 = gpu_backend.choose_blocks_gpu(512, 512, 512, p=8)
    assert b1.bm * b1.bn >= b8.bm * b8.bn


@pytest.mark.parametrize("m,k,n", [(64, 96, 80), (128, 128, 128),
                                   (48, 112, 16)])
@pytest.mark.parametrize("p", [3, 4, 6])
def test_gpu_scheme1_bit_parity_aligned(make_matrix, m, k, n, p):
    """16-aligned shapes: the GPU lowering must be bit-identical to the
    scheme1.matmul oracle (same slices, same exact int32 interior, same
    shift-reduce order)."""
    a = jnp.asarray(make_matrix((m, k)))
    b = jnp.asarray(make_matrix((k, n)))
    cfg = EmulationConfig(scheme="ozaki1", p=p, backend="gpu")
    out = dispatch.emulated_matmul(a, b, cfg=cfg)
    oracle = scheme1.matmul(a, b, cfg, jnp.float32)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(oracle))


@pytest.mark.parametrize("m,k,n", [(100, 200, 96), (50, 70, 30)])
@pytest.mark.parametrize("p", [3, 4, 6])
def test_gpu_scheme1_bit_parity_unaligned_padded(make_matrix, m, k, n, p):
    """Non-16-aligned shapes pad to the GPU tile, run fused, slice back —
    still bit-identical to the unpadded oracle (zero rows/cols carve to
    zero slices and leave every kept row/col scale untouched)."""
    a = jnp.asarray(make_matrix((m, k)))
    b = jnp.asarray(make_matrix((k, n)))
    cfg = EmulationConfig(scheme="ozaki1", p=p, backend="gpu")
    out = dispatch.emulated_matmul(a, b, cfg=cfg)
    assert out.shape == (m, n)
    oracle = scheme1.matmul(a, b, cfg, jnp.float32)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(oracle))


def test_gpu_kernel_slices_match_scheme1_split(make_matrix):
    """The in-kernel carve (shared-memory staging prologue) is the same
    truncate-and-subtract recurrence as scheme1.split: per-tile carving
    of a/scale reproduces the full-array slices bit-exactly."""
    a = jnp.asarray(make_matrix((64, 96)))
    p, beta = 4, 7
    a_sl, mu = scheme1.split(a, p, beta, axis=1)
    carved = list(carve_slices(a / mu, p, beta))
    for got, want in zip(carved, a_sl):
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_gpu_fused_matmul_rejects_misaligned_blocks(make_matrix):
    a = jnp.asarray(make_matrix((64, 64)))
    with pytest.raises(ValueError):
        gpu_backend.fused_matmul_scheme1(
            a, a, jnp.ones((64, 1)), jnp.ones((1, 64)), 3, 7,
            Blocks(48, 48, 48))


def test_gpu_out_dtype_and_batching(make_matrix):
    a = jnp.asarray(make_matrix((2, 3, 32, 64)))
    b = jnp.asarray(make_matrix((64, 48)))
    cfg = EmulationConfig(scheme="ozaki1", p=4, backend="gpu",
                          out_dtype="bfloat16")
    out = dispatch.emulated_matmul_batched(a, b, cfg=cfg)
    assert out.shape == (2, 3, 32, 48)
    assert out.dtype == jnp.bfloat16


# ---------------------------------------------------------------------------
# GPU backend: the fused Scheme-II residue pipeline's bit-parity suite.
# ---------------------------------------------------------------------------

def _complex(make_matrix, shape):
    return (jnp.asarray(make_matrix(shape))
            + 1j * jnp.asarray(make_matrix(shape))).astype(jnp.complex64)


@pytest.mark.parametrize("m,k,n", [(64, 96, 80), (128, 128, 128),
                                   (100, 200, 96)])
@pytest.mark.parametrize("p", [4, 6])
def test_gpu_scheme2_bit_parity(make_matrix, m, k, n, p):
    """The fused residue pipeline (integerize + carve prologue, p modular
    int8 MMAs, in-register modular reduce + Garner + double-double CRT
    epilogue) must be bit-identical to the scheme2.matmul oracle —
    aligned shapes run fused directly, non-16-aligned shapes pad, run
    fused, and slice back (zero rows/cols encode to zero residues)."""
    a = jnp.asarray(make_matrix((m, k)))
    b = jnp.asarray(make_matrix((k, n)))
    cfg = EmulationConfig(scheme="ozaki2", p=p, backend="gpu")
    plan = dispatch.plan_emulated(a, b, cfg)
    assert plan.backend == "gpu"          # no more (ozaki2, gpu) clamp
    out = dispatch.emulated_matmul(a, b, cfg=cfg)
    oracle = scheme2.matmul(a, b, cfg, jnp.float32)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(oracle))


def test_gpu_scheme2_bit_parity_bf16(make_matrix):
    """Half-precision operands budget from their own mantissa (8 bits
    for bf16), exactly like the oracle — the widened-f32 kernel interior
    is value-identical because every recurrence step is exact."""
    a = jnp.asarray(make_matrix((32, 64))).astype(jnp.bfloat16)
    b = jnp.asarray(make_matrix((64, 48))).astype(jnp.bfloat16)
    cfg = EmulationConfig(scheme="ozaki2", p=4, backend="gpu")
    out = dispatch.emulated_matmul(a, b, cfg=cfg)
    oracle = scheme2.matmul(a, b, cfg)
    assert out.dtype == oracle.dtype == jnp.bfloat16
    np.testing.assert_array_equal(np.asarray(out.astype(jnp.float32)),
                                  np.asarray(oracle.astype(jnp.float32)))


@pytest.mark.parametrize("m,k,n", [(64, 96, 80), (50, 70, 30)])
@pytest.mark.parametrize("p", [4, 6])
def test_gpu_complex3m_bit_parity(make_matrix, m, k, n, p):
    """Complex Scheme II rides the fused 3M kernel: the three residue
    phases carve from one staged read, and the modular 3M combination +
    two CRT reconstructions run in the epilogue — bit-identical to
    complex3m.matmul, aligned and padded."""
    from repro.core import complex3m
    a = _complex(make_matrix, (m, k))
    b = _complex(make_matrix, (k, n))
    cfg = EmulationConfig(scheme="ozaki2", p=p, backend="gpu")
    out = dispatch.emulated_matmul(a, b, cfg=cfg, out_dtype=jnp.complex64)
    assert out.shape == (m, n) and out.dtype == jnp.complex64
    oracle = complex3m.matmul(a, b, cfg, jnp.float32)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(oracle))


@pytest.mark.parametrize("m,k,n", [(64, 96, 80), (100, 200, 96)])
@pytest.mark.parametrize("p", [4, 6])
def test_gpu_scheme2_prepared_rhs_bit_parity(make_matrix, m, k, n, p):
    """A PreparedResidues rhs streams its stored residue stack while the
    prologue encodes only the lhs — still bit-identical to the
    unprepared oracle on the same float operands."""
    from repro.kernels import prepared
    a = jnp.asarray(make_matrix((m, k)))
    b = jnp.asarray(make_matrix((k, n)))
    cfg = EmulationConfig(scheme="ozaki2", p=p, backend="gpu")
    prep = prepared.prepare_rhs(b, cfg)
    assert isinstance(prep, prepared.PreparedResidues)
    assert prep.p == p and prep.k == k and prep.n == n
    assert prep.residues.dtype == jnp.int8
    out = dispatch.emulated_matmul(a, prep, cfg=cfg)
    oracle = scheme2.matmul(a, b, cfg, jnp.float32)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(oracle))


def test_gpu_scheme2_blocks_respect_residue_budgets():
    """The residue-count-aware block search must charge p (3p for 3M)
    int32 accumulators and the CRT epilogue's double-double pair."""
    for p in (4, 6, 8):
        b2 = gpu_backend.choose_blocks_gpu(256, 256, 256, p,
                                           scheme="ozaki2")
        assert b2 is not None
        assert 4 * p * b2.bm * b2.bn <= gpu_backend.ACC_BUDGET
        smem = (2 * 4 + p) * (b2.bm + b2.bn) * b2.bk \
            + (4 + 8) * b2.bm * b2.bn
        assert smem <= gpu_backend.SMEM_BUDGET
        b3 = gpu_backend.choose_blocks_gpu(256, 256, 256, p,
                                           scheme="ozaki2-3m")
        assert b3 is not None
        assert 4 * 3 * p * b3.bm * b3.bn <= gpu_backend.ACC_BUDGET
        assert b3.bm * b3.bn <= b2.bm * b2.bn  # 3x accumulators bind


def test_scheme2_invariant_guards():
    """Moduli > 256 (no int8 residue representation) and K past the
    int32 accumulator bound are refused loudly on every pipeline, not
    silently wrapped."""
    from repro.core import scheme2
    with pytest.raises(ValueError, match="256"):
        scheme2.balanced_residues(jnp.ones((4, 4)), (521, 523))
    with pytest.raises(ValueError, match="int32"):
        scheme2.check_exact_k(200_000, (256, 255))
    scheme2.check_exact_k(131_071, (256, 255))   # at the documented bound
    with pytest.raises(ValueError, match="int32"):
        # K * 128^2 == 2^31 already wraps (int32 max is 2^31 - 1)
        scheme2.check_exact_k(131_072, (256, 255))
    with pytest.raises(ValueError, match="int32"):
        scheme2.matmul(jnp.ones((4, 200_000), jnp.float32),
                       jnp.ones((200_000, 4), jnp.float32),
                       EmulationConfig(scheme="ozaki2", p=4))


def test_prepared_residues_cross_jit_and_refuse_mismatched_scheme(
        make_matrix):
    from repro.kernels import prepared
    b = jnp.asarray(make_matrix((64, 48)))
    cfg2 = EmulationConfig(scheme="ozaki2", p=4)
    cfg1 = EmulationConfig(scheme="ozaki1", p=4)
    prep = prepared.prepare_rhs(b, cfg2)
    a = jnp.asarray(make_matrix((32, 64)))
    # PreparedResidues is a pytree: it crosses a jit boundary
    out = jax.jit(lambda a, w: prepared.matmul_prepared(a, w))(a, prep)
    assert out.shape == (32, 48)
    # scheme mismatches are refused loudly, both ways
    with pytest.raises(ValueError, match="Scheme-II"):
        dispatch.emulated_matmul(a, prep, cfg=cfg1)
    prep1 = prepared.prepare_rhs(b, cfg1)
    with pytest.raises(ValueError, match="Scheme-I"):
        dispatch.emulated_matmul(a, prep1, cfg=cfg2)


# ---------------------------------------------------------------------------
# Strided-batched fused launches: one pallas_call over (batch, bM, bN),
# bit-identical to the vmapped 2-D dispatch (the batched kernels run the
# unchanged 2-D kernel body per batch grid step).
# ---------------------------------------------------------------------------

def _vmap_ref(a, b, cfg):
    return jax.vmap(
        lambda x, y: dispatch.emulated_matmul(x, y, cfg=cfg))(a, b)


def test_backend_batched_capabilities():
    assert backends.get_backend("gpu").capabilities.batched
    assert backends.get_backend("xla").capabilities.batched
    # Mosaic's sequential-K VMEM scratch accumulator cannot re-zero per
    # batch element; the TPU backend keeps the vmap route.
    assert not backends.get_backend("tpu").capabilities.batched


@pytest.mark.parametrize("p", [3, 4, 6])
def test_batched_scheme1_bit_parity_aligned(make_matrix, p):
    a = jnp.asarray(make_matrix((4, 64, 96)))
    b = jnp.asarray(make_matrix((4, 96, 80)))
    cfg = EmulationConfig(scheme="ozaki1", p=p, backend="gpu")
    assert dispatch.batched_fused_eligible(a, b, cfg)
    plan = dispatch.plan_emulated_batched(a, b, cfg)
    assert plan.batch == 4 and plan.backend == "gpu"
    out = dispatch.emulated_matmul_batched(a, b, cfg=cfg)
    np.testing.assert_array_equal(np.asarray(out),
                                  np.asarray(_vmap_ref(a, b, cfg)))


@pytest.mark.parametrize("p", [4, 6])
def test_batched_scheme2_bit_parity_aligned(make_matrix, p):
    a = jnp.asarray(make_matrix((3, 64, 96)))
    b = jnp.asarray(make_matrix((3, 96, 80)))
    cfg = EmulationConfig(scheme="ozaki2", p=p, backend="gpu")
    assert dispatch.batched_fused_eligible(a, b, cfg)
    out = dispatch.emulated_matmul_batched(a, b, cfg=cfg)
    np.testing.assert_array_equal(np.asarray(out),
                                  np.asarray(_vmap_ref(a, b, cfg)))


@pytest.mark.parametrize("scheme,p", [("ozaki1", 4), ("ozaki2", 6)])
def test_batched_bit_parity_unaligned_padded(make_matrix, scheme, p):
    """Non-16-aligned trailing axes pad once for the whole stack, run one
    strided-batched launch, slice back — still bit-identical to vmapping
    the (also padding) 2-D dispatch per element."""
    a = jnp.asarray(make_matrix((3, 50, 70)))
    b = jnp.asarray(make_matrix((3, 70, 30)))
    cfg = EmulationConfig(scheme=scheme, p=p, backend="gpu")
    out = dispatch.emulated_matmul_batched(a, b, cfg=cfg)
    assert out.shape == (3, 50, 30)
    np.testing.assert_array_equal(np.asarray(out),
                                  np.asarray(_vmap_ref(a, b, cfg)))


def test_batched_collapses_higher_leading_axes(make_matrix):
    """(2, 3, M, K) @ (2, 3, K, N): leading axes collapse into one batch
    dimension for a single launch, and the result folds back."""
    a = jnp.asarray(make_matrix((2, 3, 32, 64)))
    b = jnp.asarray(make_matrix((2, 3, 64, 48)))
    cfg = EmulationConfig(scheme="ozaki1", p=4, backend="gpu")
    out = dispatch.emulated_matmul_batched(a, b, cfg=cfg)
    assert out.shape == (2, 3, 32, 48)
    ref = _vmap_ref(a.reshape(6, 32, 64), b.reshape(6, 64, 48), cfg)
    np.testing.assert_array_equal(np.asarray(out),
                                  np.asarray(ref.reshape(2, 3, 32, 48)))


def test_batched_grad_matches_vmapped_2d(make_matrix):
    """The batched custom VJP re-enters the batched emulated path for
    both backward GEMMs — gradients bit-identical to differentiating the
    vmapped 2-D emulated_dot."""
    from repro.core import emulated
    a = jnp.asarray(make_matrix((2, 32, 48)))
    b = jnp.asarray(make_matrix((2, 48, 32)))
    cfg = EmulationConfig(scheme="ozaki1", p=4, backend="gpu")

    def loss_batched(a, b):
        return emulated.emulated_dot_batched(a, b, cfg).sum()

    def loss_vmap(a, b):
        return jax.vmap(
            lambda x, y: emulated.emulated_dot(x, y, cfg))(a, b).sum()

    ga, gb = jax.grad(loss_batched, argnums=(0, 1))(a, b)
    ra, rb = jax.grad(loss_vmap, argnums=(0, 1))(a, b)
    np.testing.assert_array_equal(np.asarray(ga), np.asarray(ra))
    np.testing.assert_array_equal(np.asarray(gb), np.asarray(rb))


def test_batched_prepared_rhs_flattens_to_one_launch(make_matrix):
    """A prepared (2-D) rhs under a batched lhs: leading axes flatten
    into M (activations @ weights) — bit-identical to the 2-D prepared
    dispatch on the flattened stack."""
    from repro.kernels import prepared
    a = jnp.asarray(make_matrix((3, 32, 64)))
    b = jnp.asarray(make_matrix((64, 48)))
    cfg = EmulationConfig(scheme="ozaki2", p=4, backend="gpu")
    prep = prepared.prepare_rhs(b, cfg)
    out = dispatch.emulated_matmul_batched(a, prep, cfg=cfg)
    assert out.shape == (3, 32, 48)
    ref = dispatch.emulated_matmul(a.reshape(-1, 64), prep, cfg=cfg)
    np.testing.assert_array_equal(np.asarray(out),
                                  np.asarray(ref.reshape(3, 32, 48)))


def test_batched_ineligible_configs_keep_vmap_route(make_matrix):
    """Guarded configs and complex operands stay on the per-element vmap
    fallback (no strided-batched lowering), and still agree with it."""
    a = _complex(make_matrix, (2, 32, 64))
    b = _complex(make_matrix, (2, 64, 48))
    cfg = EmulationConfig(scheme="ozaki2", p=4, backend="gpu")
    assert not dispatch.batched_fused_eligible(a, b, cfg)
    out = dispatch.emulated_matmul_batched(a, b, cfg=cfg,
                                           out_dtype=jnp.complex64)
    assert out.shape == (2, 32, 48) and out.dtype == jnp.complex64


def test_fallback_warning_dedupes_across_batch_sizes(make_matrix):
    """The fused-fallback warning keys on the 2-D problem (K, N), not the
    full operand shape: sweeping batch/M through the same falling-back
    call-site fires exactly one warning, not one per shape."""
    import warnings as _warnings
    b = jnp.asarray(make_matrix((64, 48)))
    cfg = EmulationConfig(scheme="ozaki2", p=4, moduli=_WIDE_MODULI,
                          backend="gpu")
    dispatch.fallback_warnings_clear()
    with _warnings.catch_warnings(record=True) as caught:
        _warnings.simplefilter("always")
        for m in (32, 128, 256):
            a = jnp.asarray(make_matrix((m, 64)))
            assert dispatch.auto_fused_matmul(a, b, cfg) is None
    runtime = [w for w in caught if issubclass(w.category, RuntimeWarning)]
    assert len(runtime) == 1
    # a different 2-D problem (new N) is a new site: it warns again
    with _warnings.catch_warnings(record=True) as caught2:
        _warnings.simplefilter("always")
        b2 = jnp.asarray(make_matrix((64, 96)))
        a = jnp.asarray(make_matrix((32, 64)))
        assert dispatch.auto_fused_matmul(a, b2, cfg) is None
    runtime2 = [w for w in caught2
                if issubclass(w.category, RuntimeWarning)]
    assert len(runtime2) == 1


# ---------------------------------------------------------------------------
# resolve_policy: (scheme, backend) clamping.
# ---------------------------------------------------------------------------

def test_resolve_policy_clamps_unsupported_scheme_backend(monkeypatch):
    """On a launch target that would otherwise keep fused impls (a
    single-device host natively compiling the selected backend), a
    (scheme, backend) pair without a fused lowering — a >int8 moduli set
    on the gpu backend — pins impl='xla' while supported pairs
    (including ozaki2 on the fused gpu residue kernel) keep their
    request. The geometry is pinned with a concrete 1-device mesh so
    the test means the same thing on the 8-device CI host (mesh=None
    there reads the process-global device count and clamps
    everything)."""
    import jax
    from repro.models.common import GemmPolicy
    monkeypatch.delenv(backends.ENV_VAR, raising=False)
    monkeypatch.setattr(dispatch.jax, "default_backend", lambda: "gpu")
    mesh = jax.make_mesh((1, 1), ("data", "model"),
                         devices=jax.devices()[:1])
    pol = GemmPolicy(
        default=EmulationConfig(scheme="ozaki2", p=4, moduli=_WIDE_MODULI,
                                impl="pallas", backend="gpu"),
        overrides=(("ffn", EmulationConfig(scheme="ozaki1", p=4,
                                           impl="pallas", backend="gpu")),
                   ("attn", EmulationConfig(scheme="ozaki2", p=6,
                                            impl="pallas",
                                            backend="gpu"))))
    resolved = dispatch.resolve_policy(pol, mesh=mesh)
    assert resolved.default.impl == "xla"      # wide moduli: clamped
    assert dict(resolved.overrides)["ffn"].impl == "pallas"   # supported
    assert dict(resolved.overrides)["attn"].impl == "pallas"  # fused II


def test_resolve_policy_clamps_cross_platform_backend(monkeypatch):
    """A backend the host cannot natively compile (tpu kernels on a GPU
    host and vice versa) pins impl='xla' even single-device."""
    from repro.models.common import GemmPolicy
    monkeypatch.delenv(backends.ENV_VAR, raising=False)
    monkeypatch.setattr(dispatch.jax, "default_backend", lambda: "gpu")
    pol = GemmPolicy(default=EmulationConfig(scheme="ozaki1", p=4,
                                             impl="pallas", backend="tpu"))
    assert dispatch.resolve_policy(pol, mesh=None).default.impl == "xla"


# ---------------------------------------------------------------------------
# Per-backend roofline projection.
# ---------------------------------------------------------------------------

def test_projected_throughput_tables():
    from repro.utils import roofline
    proj = roofline.projected_throughput(4096, 4096, 4096, p=4,
                                         backend="gpu")
    hw = proj["hardware"]
    assert set(hw) == {"h100", "b200"}
    for cell in hw.values():
        assert 0.0 < cell["fraction_of_peak"] <= 1.0
        assert cell["projected_tops"] <= cell["peak_int8_tops"]
    # Blackwell peak dominates Hopper's
    assert hw["b200"]["peak_int8_tops"] > hw["h100"]["peak_int8_tops"]
    tpu = roofline.projected_throughput(4096, 4096, 4096, p=4,
                                        backend="tpu")["hardware"]
    assert set(tpu) == {"v5e"}
    # family-prefixed and unknown names resolve to a table, not a KeyError
    from repro.core import traffic
    assert traffic.backend_peaks("tpu-v5e") is traffic.BACKEND_PEAKS["tpu"]
    assert traffic.backend_peaks("mystery") is traffic.BACKEND_PEAKS["tpu"]
