"""repro.telemetry: registry semantics, the strict disabled-mode no-op
contract, instrumented dispatch on all three backends, JSONL round-trips
through the report aggregator, Prometheus text validity, thread safety,
and the guard/fallback shims that now ride on the one registry."""

import json
import threading
import urllib.request

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import guard, telemetry
from repro.core.precision import EmulationConfig
from repro.kernels import dispatch, prepared
from repro.telemetry import record as tele_rec
from repro.telemetry import report as tele_report
from repro.telemetry.registry import MetricsRegistry


@pytest.fixture(autouse=True)
def _restore_enabled_state():
    """Every test leaves the process-wide enabled flag as it found it."""
    was = telemetry.enabled()
    yield
    (telemetry.enable if was else telemetry.disable)()


def _rand(shape, seed=0):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.standard_normal(shape).astype(np.float32))


# ---------------------------------------------------------------------------
# MetricsRegistry semantics.
# ---------------------------------------------------------------------------

def test_registry_counter_label_aggregation():
    reg = MetricsRegistry()
    reg.inc("calls", 1, {"site": "attn", "backend": "tpu"})
    reg.inc("calls", 2, {"backend": "tpu", "site": "attn"})  # order-free
    reg.inc("calls", 4, {"site": "ffn", "backend": "tpu"})
    assert reg.total("calls") == 7
    assert reg.total("calls", site="attn") == 3
    assert reg.total("calls", site="ffn", backend="tpu") == 4
    assert reg.total("calls", site="logits") == 0
    rows = list(reg.series("calls", site="attn"))
    assert rows == [({"site": "attn", "backend": "tpu"}, 3.0)]


def test_registry_labels_stringified():
    reg = MetricsRegistry()
    reg.inc("c", 1, {"p": 4})
    reg.inc("c", 1, {"p": "4"})
    assert reg.total("c", p=4) == 2
    assert reg.total("c", p="4") == 2


def test_registry_gauge_and_histogram():
    reg = MetricsRegistry()
    reg.set_gauge("g", 1.5, {"kind": "train"})
    reg.set_gauge("g", 2.5, {"kind": "train"})  # gauges overwrite
    for v in (0.1, 0.3, 0.2):
        reg.observe("h", v)
    snap = reg.snapshot()
    assert snap["gauges"] == [
        {"name": "g", "labels": {"kind": "train"}, "value": 2.5}]
    (h,) = snap["histograms"]
    assert h["count"] == 3
    assert h["sum"] == pytest.approx(0.6)
    assert h["min"] == pytest.approx(0.1)
    assert h["max"] == pytest.approx(0.3)


def test_registry_clear_by_prefix():
    reg = MetricsRegistry()
    reg.inc("repro_guard_events_total", 1, {"event": "calls"})
    reg.inc("repro_emulated_calls_total", 1)
    reg.clear("repro_guard")
    assert reg.total("repro_guard_events_total") == 0
    assert reg.total("repro_emulated_calls_total") == 1
    reg.clear()
    assert reg.total("repro_emulated_calls_total") == 0


def test_registry_once_and_forget():
    reg = MetricsRegistry()
    assert reg.once(("fallback", "gpu", "256x256x256"))
    assert not reg.once(("fallback", "gpu", "256x256x256"))
    assert reg.once(("other", "x"))
    reg.forget_once("fallback")
    assert reg.once(("fallback", "gpu", "256x256x256"))
    assert not reg.once(("other", "x"))  # untouched by the prefix forget


def test_registry_thread_safety():
    reg = MetricsRegistry()

    def worker(i):
        for _ in range(500):
            reg.inc("c", 1, {"w": i % 2})
            reg.observe("h", 1.0)

    threads = [threading.Thread(target=worker, args=(i,)) for i in range(8)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert reg.total("c") == 8 * 500
    assert reg.snapshot()["histograms"][0]["count"] == 8 * 500


# ---------------------------------------------------------------------------
# Label helpers.
# ---------------------------------------------------------------------------

def test_gemm_tag_units():
    assert telemetry.gemm_tag("ozaki1", 4, "tpu", "pallas") \
        == "emugemm/ozaki1-p4/tpu/pallas"
    assert telemetry.gemm_tag("ozaki2", 6, "gpu", "prepared-pallas") \
        == "emugemm/ozaki2-m6/gpu/prepared-pallas"
    assert telemetry.gemm_tag("ozaki2-3m", 8, "xla", "xla") \
        == "emugemm/ozaki2-3m-m8/xla/xla"


def test_call_site_stack():
    assert telemetry.current_site() == "-"
    with telemetry.call_site("attn"):
        assert telemetry.current_site() == "attn"
        with telemetry.call_site("ffn"):
            assert telemetry.current_site() == "ffn"
        assert telemetry.current_site() == "attn"
    assert telemetry.current_site() == "-"


def test_mesh_label():
    assert telemetry.mesh_label(None) == "-"
    assert telemetry.mesh_label((("data", 2), ("model", 4))) \
        == "data=2,model=4"
    assert telemetry.mesh_label({"model": 8}) == "model=8"


def test_modeled_gemm_bytes_matches_traffic():
    from repro.core import traffic
    s = traffic.GemmShape(128, 64, 256)  # (m, n, k)
    assert telemetry.modeled_gemm_bytes("ozaki1", 4, 128, 256, 64) \
        == traffic.scheme1_fused_bytes(s, 4, 4)
    per_mod = traffic.scheme2_fused_bytes_per_modulus(s)
    assert telemetry.modeled_gemm_bytes("ozaki2", 6, 128, 256, 64) \
        == 6 * per_mod + 4 * 128 * 64


# ---------------------------------------------------------------------------
# Disabled mode: strict no-op.
# ---------------------------------------------------------------------------

def test_disabled_mode_stages_no_callbacks():
    telemetry.disable()
    cfg = EmulationConfig(scheme="ozaki1", p=3)
    a, b = _rand((128, 128), 1), _rand((128, 128), 2)
    jaxpr = str(jax.make_jaxpr(
        lambda a, b: dispatch.emulated_matmul(a, b, cfg=cfg))(a, b))
    assert "debug_callback" not in jaxpr


def test_disabled_mode_records_nothing():
    telemetry.disable()
    before = telemetry.REGISTRY.counter_snapshot()
    cfg = EmulationConfig(scheme="ozaki1", p=3)
    dispatch.emulated_matmul(_rand((128, 128), 1), _rand((128, 128), 2),
                             cfg=cfg)
    after = telemetry.REGISTRY.counter_snapshot()
    changed = {k for k in set(before) | set(after)
               if before.get(k) != after.get(k)
               # guard counters are always-on by design
               and not k[0].startswith("repro_guard")}
    assert not changed, changed


def test_enabled_vs_disabled_bit_identical():
    cfg = EmulationConfig(scheme="ozaki1", p=3)
    a, b = _rand((128, 128), 1), _rand((128, 128), 2)
    telemetry.disable()
    off = dispatch.emulated_matmul(a, b, cfg=cfg)
    telemetry.enable()
    on = dispatch.emulated_matmul(a, b, cfg=cfg)
    assert jnp.array_equal(off, on)


# ---------------------------------------------------------------------------
# Instrumented dispatch: counters on all three backends, under jit.
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("backend", ["tpu", "gpu", "xla"])
def test_counters_under_jit(backend):
    telemetry.enable()
    cfg = EmulationConfig(scheme="ozaki1", p=3, backend=backend)
    a, b = _rand((128, 128), 3), _rand((128, 128), 4)

    reg = telemetry.REGISTRY
    calls0 = reg.total(tele_rec.EMULATED_CALLS, backend=backend)
    traces0 = reg.total(tele_rec.EMULATED_TRACES, backend=backend)
    bytes0 = reg.total(tele_rec.MODELED_HBM_BYTES, backend=backend)

    f = jax.jit(lambda a, b: dispatch.emulated_matmul(a, b, cfg=cfg))
    jax.block_until_ready(f(a, b))
    jax.block_until_ready(f(a, b))  # second execution, no retrace
    jax.effects_barrier()  # debug callbacks run async; flush them

    assert reg.total(tele_rec.EMULATED_TRACES, backend=backend) > traces0
    assert reg.total(tele_rec.EMULATED_CALLS, backend=backend) \
        >= calls0 + 2
    assert reg.total(tele_rec.MODELED_HBM_BYTES, backend=backend) > bytes0


def test_site_label_attached():
    telemetry.enable()
    reg = telemetry.REGISTRY
    before = reg.total(tele_rec.EMULATED_TRACES, site="attn")
    cfg = EmulationConfig(scheme="ozaki1", p=3)
    with telemetry.call_site("attn"):
        dispatch.emulated_matmul(_rand((128, 128), 5), _rand((128, 128), 6),
                                 cfg=cfg)
    assert reg.total(tele_rec.EMULATED_TRACES, site="attn") > before


def test_site_label_survives_grad_and_remat():
    # custom-VJP rules are re-traced at partial-eval/transpose time,
    # after the call_site block has exited; the site rides along as a
    # static argument so the re-traces re-enter the scope.
    from repro.core.emulated import emulated_dot
    telemetry.enable()
    reg = telemetry.REGISTRY
    cfg = EmulationConfig(scheme="ozaki1", p=3, backend="tpu")
    a, b = _rand((128, 128), 30), _rand((128, 128), 31)

    def layer(a, b):
        with telemetry.call_site("attn"):
            return emulated_dot(a, b, cfg).sum()

    calls0 = reg.total(tele_rec.EMULATED_CALLS, site="attn")
    unsited0 = reg.total(tele_rec.EMULATED_CALLS, site="-")
    f = jax.jit(jax.grad(jax.checkpoint(layer)))
    jax.block_until_ready(f(a, b))
    jax.effects_barrier()
    # remat forward + both backward GEMMs all carry the site.
    assert reg.total(tele_rec.EMULATED_CALLS, site="attn") >= calls0 + 3
    assert reg.total(tele_rec.EMULATED_CALLS, site="-") == unsited0


def test_block_cache_counters():
    telemetry.enable()
    reg = telemetry.REGISTRY
    hits0 = reg.total(tele_rec.BLOCK_CACHE, result="hit")
    miss0 = reg.total(tele_rec.BLOCK_CACHE, result="miss")
    cfg = EmulationConfig(scheme="ozaki1", p=3)
    a, b = _rand((160, 128), 7), _rand((128, 160), 8)
    dispatch.emulated_matmul(a, b, cfg=cfg)
    dispatch.emulated_matmul(a, b, cfg=cfg)
    hits = reg.total(tele_rec.BLOCK_CACHE, result="hit") - hits0
    miss = reg.total(tele_rec.BLOCK_CACHE, result="miss") - miss0
    assert hits + miss >= 2
    assert hits >= 1  # second call reuses the cached block choice


def test_modeled_bytes_traced_by_tag():
    telemetry.enable()
    reg = telemetry.REGISTRY
    tag = telemetry.gemm_tag("ozaki1", 4, "tpu", "pallas")
    before = reg.total(tele_rec.MODELED_BYTES_TRACED, tag=tag)
    cfg = EmulationConfig(scheme="ozaki1", p=4, backend="tpu")
    dispatch.emulated_matmul(_rand((128, 128), 9), _rand((128, 128), 10),
                             cfg=cfg)
    got = reg.total(tele_rec.MODELED_BYTES_TRACED, tag=tag) - before
    assert got == telemetry.modeled_gemm_bytes("ozaki1", 4, 128, 128, 128)


def test_prepared_consume_counters():
    telemetry.enable()
    reg = telemetry.REGISTRY
    built0 = reg.total(tele_rec.PREPARED_BUILD, scheme="ozaki1")
    consumed0 = reg.total(tele_rec.PREPARED_CONSUME, scheme="ozaki1")
    cfg = EmulationConfig(scheme="ozaki1", p=3)
    b = _rand((128, 128), 11)
    prep = prepared.prepare_rhs(b, cfg)
    dispatch.emulated_matmul(_rand((128, 128), 12), prep, cfg=cfg)
    assert reg.total(tele_rec.PREPARED_BUILD, scheme="ozaki1") == built0 + 1
    assert reg.total(tele_rec.PREPARED_CONSUME, scheme="ozaki1") \
        == consumed0 + 1


def test_emugemm_scope_in_compiled_hlo():
    cfg = EmulationConfig(scheme="ozaki1", p=3, backend="tpu")
    a, b = _rand((128, 128), 13), _rand((128, 128), 14)
    txt = jax.jit(
        lambda a, b: dispatch.emulated_matmul(a, b, cfg=cfg)
    ).lower(a, b).compile().as_text()
    assert "emugemm/ozaki1-p3/tpu/pallas" in txt


# ---------------------------------------------------------------------------
# Step records: JSONL round-trip through the report aggregator.
# ---------------------------------------------------------------------------

def test_step_tracker_jsonl_roundtrip(tmp_path, capsys):
    path = tmp_path / "steps.jsonl"
    with telemetry.recording(str(path)):
        tracker = telemetry.StepTracker()
        cfg = EmulationConfig(scheme="ozaki1", p=3)
        with telemetry.call_site("ffn"):
            dispatch.emulated_matmul(_rand((128, 128), 15),
                                     _rand((128, 128), 16), cfg=cfg)
        tracker.step_metrics(0, 0.5, kind="train", tokens=1024, loss=3.25)
        dispatch.emulated_matmul(_rand((128, 128), 17),
                                 _rand((128, 128), 18), cfg=cfg)
        tracker.step_metrics(1, 0.25, kind="train", tokens=1024)

    records = [json.loads(line) for line in path.read_text().splitlines()]
    assert len(records) == 2
    assert all(r["record"] == "repro.telemetry/v1" for r in records)
    assert records[0]["loss"] == 3.25
    assert records[0]["tokens_per_s"] == pytest.approx(2048.0)
    assert records[0]["emulated_calls"] >= 1
    assert records[0]["modeled_hbm_bytes"] > 0

    summary = tele_report.aggregate(records)
    assert summary["steps"] == 2
    assert summary["kinds"] == {"train": 2}
    sites = {row["site"] for row in summary["sites"]}
    assert "ffn" in sites
    ffn = [r for r in summary["sites"] if r["site"] == "ffn"][0]
    assert ffn["scheme"] == "ozaki1"
    assert ffn["calls"] >= 1
    assert ffn["hbm_bytes"] > 0

    # The CLI renders the same file without error.
    assert tele_report.main([str(path)]) == 0
    out = capsys.readouterr().out
    assert "ffn" in out and "steps=2" in out


def test_recording_scope_restores_state():
    telemetry.disable()
    with telemetry.recording():
        assert telemetry.enabled()
    assert not telemetry.enabled()
    telemetry.enable()
    with telemetry.recording():
        pass
    assert telemetry.enabled()


# ---------------------------------------------------------------------------
# Prometheus exposition.
# ---------------------------------------------------------------------------

def test_render_prometheus_text_format():
    reg = MetricsRegistry()
    reg.inc(tele_rec.EMULATED_CALLS, 3, {"site": "attn", "scheme": "ozaki1",
                                         "backend": "tpu"})
    reg.set_gauge(tele_rec.STEP_TOKENS_PER_S, 512.5, {"kind": "train"})
    reg.observe(tele_rec.STEP_SECONDS, 0.25, {"kind": "train"})
    text = telemetry.render_prometheus(reg)
    assert "# TYPE repro_emulated_calls_total counter" in text
    assert ('repro_emulated_calls_total{backend="tpu",scheme="ozaki1",'
            'site="attn"} 3') in text
    assert "# TYPE repro_step_tokens_per_s gauge" in text
    assert "repro_step_seconds_count" in text
    assert "repro_step_seconds_sum" in text
    # every non-comment line is `name{labels} value`
    for line in text.strip().splitlines():
        if line.startswith("#"):
            continue
        name_part, value = line.rsplit(" ", 1)
        float(value)
        assert name_part[0].isalpha()


def test_prometheus_label_escaping():
    reg = MetricsRegistry()
    reg.inc("c", 1, {"reason": 'say "hi"\nback\\slash'})
    text = telemetry.render_prometheus(reg)
    assert r'reason="say \"hi\"\nback\\slash"' in text


def test_metrics_server_serves_registry():
    reg = MetricsRegistry()
    reg.inc(tele_rec.EMULATED_CALLS, 7, {"backend": "xla"})
    server = telemetry.serve_metrics(0, reg)
    try:
        url = f"http://127.0.0.1:{server.port}/metrics"
        with urllib.request.urlopen(url, timeout=5) as resp:
            assert resp.status == 200
            assert "0.0.4" in resp.headers["Content-Type"]
            body = resp.read().decode("utf-8")
        assert 'repro_emulated_calls_total{backend="xla"} 7' in body
        with pytest.raises(urllib.error.HTTPError):
            urllib.request.urlopen(
                f"http://127.0.0.1:{server.port}/nope", timeout=5)
    finally:
        server.close()


# ---------------------------------------------------------------------------
# Guard + fallback shims over the registry.
# ---------------------------------------------------------------------------

def test_guard_stats_ride_on_registry():
    guard.stats_clear()
    from repro.guard import policy
    policy.record("calls")
    policy.record("trips", 2)
    assert guard.stats().calls == 1
    assert guard.stats().trips == 2
    assert telemetry.REGISTRY.total(tele_rec.GUARD_EVENTS, event="calls") \
        == 1


def test_guard_stats_clear_leaves_other_counters():
    telemetry.enable()
    telemetry.REGISTRY.inc(tele_rec.EMULATED_CALLS, 1, {"backend": "xla"})
    base = telemetry.REGISTRY.total(tele_rec.EMULATED_CALLS)
    from repro.guard import policy
    policy.record("calls")
    guard.stats_clear()
    assert guard.stats() == type(guard.stats())()  # all-zero dataclass
    assert telemetry.REGISTRY.total(tele_rec.EMULATED_CALLS) == base


def test_guard_events_carry_site_label():
    guard.stats_clear()
    from repro.guard import policy
    with telemetry.call_site("logits"):
        policy.record("trips")
    assert telemetry.REGISTRY.total(
        tele_rec.GUARD_EVENTS, event="trips", site="logits") == 1
    guard.stats_clear()


def test_fallback_warning_once_via_registry():
    import warnings
    dispatch.fallback_warnings_clear()
    reason = ("gpu", "ozaki2", "float32", "float32")
    with warnings.catch_warnings(record=True) as w:
        warnings.simplefilter("always")
        dispatch._warn_fallback_once(reason, ((128, 128), (128, 128)), "m")
        dispatch._warn_fallback_once(reason, ((128, 128), (128, 128)), "m")
        dispatch._warn_fallback_once(reason, ((256, 256), (256, 256)), "m")
    assert len(w) == 2  # deduped per (reason, shape)
    dispatch.fallback_warnings_clear()
    with warnings.catch_warnings(record=True) as w:
        warnings.simplefilter("always")
        dispatch._warn_fallback_once(reason, ((128, 128), (128, 128)), "m")
    assert len(w) == 1


def test_fallback_event_counter():
    telemetry.enable()
    reg = telemetry.REGISTRY
    before = reg.total(tele_rec.FALLBACK_EVENTS, reason="unsupported")
    # a modulus above the fused gpu kernel's <=256 cap -> xla fallback.
    cfg = EmulationConfig(scheme="ozaki2", moduli=(521, 251, 247),
                          backend="gpu")
    a, b = _rand((128, 128), 19), _rand((128, 128), 20)
    plan = dispatch.plan_emulated(a, b, cfg)
    assert plan.backend == "xla"
    assert reg.total(tele_rec.FALLBACK_EVENTS, reason="unsupported") \
        == before + 1
