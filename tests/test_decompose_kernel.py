"""Fused decomposition+interleave kernel vs the split()+interleave_k
oracle (exact integer agreement)."""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import scheme1
from repro.kernels import decompose


@pytest.mark.parametrize("p,beta", [(2, 7), (4, 7), (8, 3)])
@pytest.mark.parametrize("m,k,bk", [(128, 256, 128), (256, 512, 256)])
def test_decompose_interleave_matches_oracle(make_matrix, p, beta, m, k, bk):
    a = jnp.asarray(make_matrix((m, k), phi=3.0))
    slices, mu = scheme1.split(a, p, beta, axis=1)
    ref = scheme1.interleave_k(slices, "a", bk)
    out = decompose.decompose_interleave(a, mu, p, beta, bm=128, bk=bk)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(ref))


def test_single_pass_traffic_advantage():
    """One read of A + one write of Â vs split-then-interleave's extra
    (p, M, K) materialization — the Sec. III-A preprocessing argument."""
    m = k = 4096
    p = 8
    fused = 4 * m * k + p * m * k              # read f32 A, write int8 Â
    unfused = 4 * m * k + 2 * p * m * k + p * m * k
    assert unfused / fused > 1.6
