"""Paper traffic models (Eqs. 9/10/14/15/17/18) and their headline ratios."""

import pytest

from repro.core import traffic
from repro.core.traffic import GemmShape


@pytest.fixture
def s():
    return GemmShape(4096, 4096, 4096)


def test_eq9_eq10_scheme1(s):
    p = 8
    naive = traffic.scheme1_naive_bytes(s, p)
    fused = traffic.scheme1_fused_bytes(s, p)
    assert naive == (p * (p + 1) // 2 * (s.m + s.n) * s.k
                     + 4 * p * (p + 1) * s.m * s.n + 8 * s.m * s.n)
    assert fused == p * (s.m + s.n) * s.k + 8 * s.m * s.n
    assert naive > fused


def test_scheme1_intensity_gain_is_half_p_plus_1(s):
    """Operand-load intensity rises exactly (p+1)/2 (paper Sec. III:
    4.5x at p=8); including the naive INT32 round-trips the full gain is
    even larger."""
    p = 8
    assert abs(traffic.scheme1_intensity_gain(p) - 4.5) < 1e-9
    operand_naive = p * (p + 1) // 2 * (s.m + s.n) * s.k
    operand_fused = p * (s.m + s.n) * s.k
    assert operand_naive / operand_fused == (p + 1) / 2
    full_gain = (traffic.scheme1_naive_bytes(s, p)
                 / traffic.scheme1_fused_bytes(s, p))
    assert full_gain > (p + 1) / 2


def test_eq14_eq15_8x_output_reduction(s):
    naive = traffic.scheme2_naive_bytes_per_modulus(s)
    fused = traffic.scheme2_fused_bytes_per_modulus(s)
    out_naive = naive - (s.m + s.n) * s.k
    out_fused = fused - (s.m + s.n) * s.k
    assert out_naive == 9 * s.m * s.n and out_fused == s.m * s.n
    assert out_naive / out_fused == 9  # 8MN round-trip + MN write -> MN


def test_eq17_eq18_3m(s):
    naive = traffic.scheme2_3m_naive_bytes_per_modulus(s)
    fused = traffic.scheme2_3m_fused_bytes_per_modulus(s)
    assert naive - fused == 24 * s.m * s.n  # the 24MN int32 term vanishes
    # fused 3M writes 2MN vs 3MN for three independent fused real GEMMs
    three_real = 3 * traffic.scheme2_fused_bytes_per_modulus(s) \
        - 3 * (s.m + s.n) * s.k + 3 * (s.m + s.n) * s.k
    assert fused < three_real


def test_workspace_scheme2_exceeds_scheme1(s):
    """Paper Sec. V-F: Scheme II workspace > Scheme I at matched p."""
    p = 8
    assert traffic.scheme2_workspace_bytes(s, p) > \
        traffic.scheme1_workspace_bytes(s, p)


@pytest.mark.parametrize("p", [3, 4, 6])
def test_decomposition_traffic_reductions(p):
    """The PR-2 headline: the in-kernel prologue cuts decomposition-side
    bytes >= 2x and PreparedOperand weight reuse >= 3x (over the 3
    per-step decompositions: forward, remat re-forward, backward B^T)."""
    elems = 4096 * 4096
    xla = traffic.scheme1_decomp_xla_bytes(elems, p, uses=3)
    pro = traffic.scheme1_decomp_prologue_bytes(elems, p, uses=3)
    prep = traffic.scheme1_decomp_prepared_bytes(elems, p, preps=1)
    assert xla / pro >= 2.0
    assert xla / prep >= 3.0
    r_pro, r_prep = traffic.scheme1_decomp_reduction(p, uses=3)
    assert abs(r_pro - xla / pro) < 1e-9
    assert abs(r_prep - xla / prep) < 1e-9


def test_decomposition_terms_match_component_model():
    """utils.roofline surfaces the core.traffic model per-GEMM: both
    operands decompose on the xla/prologue paths, only the activation
    on the prepared path (the weight preps once)."""
    from repro.utils import roofline
    m, k, n, p = 256, 512, 1024, 4
    t = roofline.scheme1_decomposition_terms(m, k, n, p, uses=3)
    both = m * k + k * n
    assert t["xla_bytes"] == traffic.scheme1_decomp_xla_bytes(both, p, 3)
    assert t["prologue_bytes"] == \
        traffic.scheme1_decomp_prologue_bytes(both, p, 3)
    assert t["prepared_bytes"] == \
        (traffic.scheme1_decomp_prologue_bytes(m * k, p, 3)
         + traffic.scheme1_decomp_prepared_bytes(k * n, p, 1))
    assert t["xla_bytes"] > t["prologue_bytes"] > t["prepared_bytes"]
