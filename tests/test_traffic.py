"""Paper traffic models (Eqs. 9/10/14/15/17/18) and their headline ratios."""

import pytest

from repro.core import traffic
from repro.core.traffic import GemmShape


@pytest.fixture
def s():
    return GemmShape(4096, 4096, 4096)


def test_eq9_eq10_scheme1(s):
    p = 8
    naive = traffic.scheme1_naive_bytes(s, p)
    fused = traffic.scheme1_fused_bytes(s, p)
    assert naive == (p * (p + 1) // 2 * (s.m + s.n) * s.k
                     + 4 * p * (p + 1) * s.m * s.n + 8 * s.m * s.n)
    assert fused == p * (s.m + s.n) * s.k + 8 * s.m * s.n
    assert naive > fused


def test_scheme1_intensity_gain_is_half_p_plus_1(s):
    """Operand-load intensity rises exactly (p+1)/2 (paper Sec. III:
    4.5x at p=8); including the naive INT32 round-trips the full gain is
    even larger."""
    p = 8
    assert abs(traffic.scheme1_intensity_gain(p) - 4.5) < 1e-9
    operand_naive = p * (p + 1) // 2 * (s.m + s.n) * s.k
    operand_fused = p * (s.m + s.n) * s.k
    assert operand_naive / operand_fused == (p + 1) / 2
    full_gain = (traffic.scheme1_naive_bytes(s, p)
                 / traffic.scheme1_fused_bytes(s, p))
    assert full_gain > (p + 1) / 2


def test_eq14_eq15_8x_output_reduction(s):
    naive = traffic.scheme2_naive_bytes_per_modulus(s)
    fused = traffic.scheme2_fused_bytes_per_modulus(s)
    out_naive = naive - (s.m + s.n) * s.k
    out_fused = fused - (s.m + s.n) * s.k
    assert out_naive == 9 * s.m * s.n and out_fused == s.m * s.n
    assert out_naive / out_fused == 9  # 8MN round-trip + MN write -> MN


def test_eq17_eq18_3m(s):
    naive = traffic.scheme2_3m_naive_bytes_per_modulus(s)
    fused = traffic.scheme2_3m_fused_bytes_per_modulus(s)
    assert naive - fused == 24 * s.m * s.n  # the 24MN int32 term vanishes
    # fused 3M writes 2MN vs 3MN for three independent fused real GEMMs
    three_real = 3 * traffic.scheme2_fused_bytes_per_modulus(s) \
        - 3 * (s.m + s.n) * s.k + 3 * (s.m + s.n) * s.k
    assert fused < three_real


def test_workspace_scheme2_exceeds_scheme1(s):
    """Paper Sec. V-F: Scheme II workspace > Scheme I at matched p."""
    p = 8
    assert traffic.scheme2_workspace_bytes(s, p) > \
        traffic.scheme1_workspace_bytes(s, p)


@pytest.mark.parametrize("p", [3, 4, 6])
def test_decomposition_traffic_reductions(p):
    """The PR-2 headline: the in-kernel prologue cuts decomposition-side
    bytes >= 2x and PreparedOperand weight reuse >= 3x (over the 3
    per-step decompositions: forward, remat re-forward, backward B^T)."""
    elems = 4096 * 4096
    xla = traffic.scheme1_decomp_xla_bytes(elems, p, uses=3)
    pro = traffic.scheme1_decomp_prologue_bytes(elems, p, uses=3)
    prep = traffic.scheme1_decomp_prepared_bytes(elems, p, preps=1)
    assert xla / pro >= 2.0
    assert xla / prep >= 3.0
    r_pro, r_prep = traffic.scheme1_decomp_reduction(p, uses=3)
    assert abs(r_pro - xla / pro) < 1e-9
    assert abs(r_prep - xla / prep) < 1e-9


def test_decomposition_terms_match_component_model():
    """utils.roofline surfaces the core.traffic model per-GEMM: both
    operands decompose on the xla/prologue paths, only the activation
    on the prepared path (the weight preps once)."""
    from repro.utils import roofline
    m, k, n, p = 256, 512, 1024, 4
    t = roofline.scheme1_decomposition_terms(m, k, n, p, uses=3)
    both = m * k + k * n
    assert t["xla_bytes"] == traffic.scheme1_decomp_xla_bytes(both, p, 3)
    assert t["prologue_bytes"] == \
        traffic.scheme1_decomp_prologue_bytes(both, p, 3)
    assert t["prepared_bytes"] == \
        (traffic.scheme1_decomp_prologue_bytes(m * k, p, 3)
         + traffic.scheme1_decomp_prepared_bytes(k * n, p, 1))
    assert t["xla_bytes"] > t["prologue_bytes"] > t["prepared_bytes"]


@pytest.mark.parametrize("p", [4, 6])
def test_scheme2_residue_traffic_reductions(p):
    """The fused residue pipeline kills both the (p, M, K) residue
    encodes and the (p, M, N) int32/canonical round-trips: on
    output-heavy shapes the modelled reduction is >= p-fold, and the
    prepared-rhs path beats the per-call fused encode."""
    s = GemmShape(256, 256, 128)
    xla = traffic.scheme2_decomp_xla_bytes(s, p, uses=3)
    pro = traffic.scheme2_decomp_prologue_bytes(s, p, uses=3)
    prep = traffic.scheme2_decomp_prepared_bytes(s, p, uses=3, preps=1)
    assert xla / pro >= p
    assert prep < pro < xla
    r_pro, r_prep = traffic.scheme2_decomp_reduction(s, p, uses=3)
    assert abs(r_pro - xla / pro) < 1e-9
    assert abs(r_prep - xla / prep) < 1e-9
    # 3M: more int32 round-trips vanish (Eq. 17's 24MN term per modulus)
    xla_3m = traffic.scheme2_decomp_xla_bytes(s, p, uses=3,
                                              complex_3m=True)
    pro_3m = traffic.scheme2_decomp_prologue_bytes(s, p, uses=3,
                                                   complex_3m=True)
    assert xla_3m / pro_3m > xla / pro


def test_scheme2_decomposition_terms_match_component_model():
    from repro.utils import roofline
    m, k, n, p = 256, 512, 1024, 6
    s = GemmShape(m, n, k)
    t = roofline.scheme2_decomposition_terms(m, k, n, p, uses=3)
    assert t["xla_bytes"] == traffic.scheme2_decomp_xla_bytes(s, p, 3)
    assert t["prologue_bytes"] == \
        traffic.scheme2_decomp_prologue_bytes(s, p, 3)
    assert t["prepared_bytes"] == \
        traffic.scheme2_decomp_prepared_bytes(s, p, 3, 1)
    assert t["xla_bytes"] > t["prologue_bytes"] > t["prepared_bytes"]


def test_projected_throughput_zgemm_baseline():
    """GPU hardware entries carry the paper's headline framing: fused
    Scheme-II (real/3M) projected time vs the FP64 D/ZGEMM baseline."""
    from repro.utils import roofline
    proj = roofline.projected_throughput(4096, 4096, 4096, p=6,
                                         scheme="ozaki2", backend="gpu",
                                         complex_3m=True)
    for cell in proj["hardware"].values():
        assert cell["fp64_baseline"] == "zgemm"
        assert cell["baseline_speedup"] > 1.0
    real = roofline.projected_throughput(4096, 4096, 4096, p=6,
                                         scheme="ozaki2", backend="gpu")
    assert all(c["fp64_baseline"] == "dgemm"
               for c in real["hardware"].values())
    # no FP64 units -> no baseline report (TPU v5e)
    tpu = roofline.projected_throughput(4096, 4096, 4096, p=6,
                                        scheme="ozaki2", backend="tpu")
    assert all("baseline_speedup" not in c
               for c in tpu["hardware"].values())


def test_guard_verify_model_formulas():
    """The a posteriori verifier's fused cost is vector-only: r probe
    round-trips over the M/K/N edges, never a matrix re-read."""
    s = GemmShape(4096, 4096, 4096)
    r = 2
    assert traffic.guard_verify_bytes_fused(s, r) == \
        4 * r * (s.m + 2 * s.k + 2 * s.n)
    assert traffic.guard_verify_flops(s, r) == \
        2 * r * (s.k * s.n + s.m * s.k + s.m * s.n)
    # Unfused verification re-streams both operands (GEMV reads) plus
    # the output once -- orders of magnitude above the fused path.
    assert traffic.guard_verify_bytes_unfused(s, r) > \
        100 * traffic.guard_verify_bytes_fused(s, r)


@pytest.mark.parametrize("scheme,p", [("ozaki1", 4), ("ozaki2", 6)])
def test_guard_overhead_within_ceiling(scheme, p):
    """Modeled guard overhead stays under the 5% acceptance ceiling on
    the benchmarked shapes (bench_traffic.py gates the same bound)."""
    for m, k, n in [(4096, 4096, 4096), (8192, 8192, 8192),
                    (2048, 8192, 2048)]:
        cell = traffic.guard_overhead_model(GemmShape(m, n, k), p,
                                            scheme=scheme)
        assert 0.0 < cell["time_ratio"] <= 0.05
        assert 0.0 < cell["bytes_ratio"] <= 0.05
        assert cell["verify_bytes_fused"] < cell["verify_bytes_unfused"]


@pytest.mark.parametrize("k,n", [(2048, 2048), (2048, 8192)])
def test_decode_step_model(k, n):
    """Decode-step serving traffic (docs/serving.md): the prepared
    weight stream is batch-invariant, so per-token bytes amortize
    ~linearly with the decode batch, and the prepared path beats the
    per-step XLA re-decomposition by (8 + 4p)/p on the weight term."""
    p = 4
    for b in (1, 8, 32):
        step = traffic.scheme1_decode_step_bytes(k, n, b, p, "prepared")
        assert step == p * k * n + 8 * b * k + 4 * b * n
        # Exactly the weight term above the batch-scaled act/out terms.
        assert (traffic.scheme1_decode_step_bytes(k, n, b, p, "xla")
                - step) == (8 + 3 * p) * k * n
    amort = traffic.decode_batch_amortization(k, n, p, 32)
    assert 24.0 <= amort < 32.0    # near-linear, never super-linear
    per_tok = [traffic.scheme1_decode_per_token_bytes(k, n, b, p)
               for b in (1, 8, 32)]
    assert per_tok[0] > per_tok[1] > per_tok[2]
    ratio = (traffic.scheme1_decode_per_token_bytes(k, n, 1, p, "xla")
             / per_tok[0])
    assert 4.0 <= ratio <= (8 + 4 * p) / p
    with pytest.raises(ValueError):
        traffic.scheme1_decode_step_bytes(k, n, 1, p, "cached")
