"""Pallas kernel sweeps (interpret mode) vs the pure-jnp oracles in ref.py.

Every kernel is swept over shapes and slice/modulus counts; integer
kernels must match the oracle bit-exactly, the Scheme-I kernel (float
epilogue) to f32 summation-order tolerance.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import scheme1, scheme2
from repro.core.precision import EmulationConfig, default_moduli
from repro.kernels import matmul_int8, ops, ozaki1, ozaki2, ozaki3m
from repro.kernels import ref as kref
from repro.kernels.common import Blocks, choose_blocks

SHAPES = [(128, 128, 128), (256, 512, 128), (384, 256, 256)]


@pytest.mark.parametrize("m,n,k", SHAPES)
def test_int8_matmul_exact(rng, m, n, k):
    a8 = jnp.asarray(rng.integers(-127, 128, (m, k)), jnp.int8)
    b8 = jnp.asarray(rng.integers(-127, 128, (k, n)), jnp.int8)
    out = matmul_int8.int8_matmul(a8, b8)
    np.testing.assert_array_equal(np.asarray(out),
                                  np.asarray(kref.int8_matmul(a8, b8)))


@pytest.mark.parametrize("m,n,k", SHAPES)
@pytest.mark.parametrize("p", [2, 4, 8])
def test_ozaki1_kernel_vs_oracle(make_matrix, m, n, k, p):
    a = jnp.asarray(make_matrix((m, k)))
    b = jnp.asarray(make_matrix((k, n)))
    blocks = choose_blocks(m, n, k, p)
    beta = EmulationConfig(scheme="ozaki1", p=p).resolved_beta(k)
    a_sl, mu = scheme1.split(a, p, beta, axis=1)
    b_sl, nu = scheme1.split(b, p, beta, axis=0)
    a_hat = scheme1.interleave_k(a_sl, "a", blocks.bk)
    b_hat = scheme1.interleave_k(b_sl, "b", blocks.bk)
    out = ozaki1.fused_matmul_interleaved(a_hat, b_hat, mu, nu, p, beta,
                                          blocks)
    ref = kref.scheme1_interleaved(a_hat, b_hat, mu, nu, p, beta, blocks.bk)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-5, atol=1e-5 * float(
                                   jnp.abs(ref).max()))


@pytest.mark.parametrize("m,n,k", SHAPES)
@pytest.mark.parametrize("p", [4, 9, 15])
def test_ozaki2_kernel_exact(rng, m, n, k, p):
    moduli = default_moduli(p)
    a_res = jnp.asarray(rng.integers(-127, 128, (p, m, k)), jnp.int8)
    b_res = jnp.asarray(rng.integers(-127, 128, (p, k, n)), jnp.int8)
    out = ozaki2.fused_residue_matmul(a_res, b_res, moduli)
    ref = kref.scheme2_residues(a_res, b_res, moduli)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(ref))


@pytest.mark.parametrize("m,n,k", [(128, 128, 128), (256, 256, 128)])
@pytest.mark.parametrize("p", [3, 8])
def test_ozaki3m_kernel_exact(rng, m, n, k, p):
    moduli = default_moduli(p)
    a3 = jnp.asarray(rng.integers(-100, 101, (p, 3, m, k)), jnp.int8)
    b3 = jnp.asarray(rng.integers(-100, 101, (p, 3, k, n)), jnp.int8)
    c_re, c_im = ozaki3m.fused_3m_residue_matmul(a3, b3, moduli)
    r_re, r_im = kref.scheme2_3m(a3, b3, moduli)
    np.testing.assert_array_equal(np.asarray(c_re), np.asarray(r_re))
    np.testing.assert_array_equal(np.asarray(c_im), np.asarray(r_im))


@pytest.mark.parametrize("p,min_bits", [(2, 9), (4, 19)])
def test_fused_scheme1_end_to_end(make_matrix, p, min_bits):
    a = jnp.asarray(make_matrix((256, 256)))
    b = jnp.asarray(make_matrix((256, 256)))
    cfg = EmulationConfig(scheme="ozaki1", p=p)
    out = np.asarray(ops.fused_scheme1_matmul(a, b, cfg))
    ref = np.asarray(a, np.float64) @ np.asarray(b, np.float64)
    rel = np.abs(out - ref).max() / np.abs(ref).max()
    assert -np.log2(rel) >= min_bits  # ~beta bits per slice with margin


@pytest.mark.parametrize("p", [6, 10])
def test_fused_scheme2_end_to_end_matches_xla(make_matrix, p):
    a = jnp.asarray(make_matrix((256, 256)))
    b = jnp.asarray(make_matrix((256, 256)))
    cfg = EmulationConfig(scheme="ozaki2", p=p)
    fused = np.asarray(ops.fused_scheme2_matmul(a, b, cfg))
    xla = np.asarray(scheme2.matmul(a, b, cfg, jnp.float32))
    np.testing.assert_allclose(fused, xla, rtol=0, atol=0)  # bit-identical


def test_fused_3m_end_to_end(make_matrix):
    ar, ai = make_matrix((128, 128)), make_matrix((128, 128))
    br, bi = make_matrix((128, 128)), make_matrix((128, 128))
    a = jnp.asarray((ar + 1j * ai).astype(np.complex64))
    b = jnp.asarray((br + 1j * bi).astype(np.complex64))
    cfg = EmulationConfig(scheme="ozaki2", p=9)
    out = np.asarray(ops.fused_3m_matmul(a, b, cfg))
    ref = (ar + 1j * ai).astype(np.complex128) @ \
        (br + 1j * bi).astype(np.complex128)
    rel = np.abs(out - ref).max() / np.abs(ref).max()
    assert -np.log2(rel) > 12


def test_blocks_respect_vmem_budget():
    for p in (1, 4, 8):
        blocks = choose_blocks(1024, 1024, 1024, p)
        assert blocks is not None
        acc = 4 * p * blocks.bm * blocks.bn
        s_op = 2 * p * (blocks.bm + blocks.bn) * blocks.bk
        assert acc + s_op <= 12 * 2 ** 20
        # MXU alignment
        assert blocks.bm % 32 == 0 and blocks.bn % 128 == 0


def test_higher_p_forces_smaller_tiles():
    """Paper Eq. 12: the p-fold accumulator scaling shrinks alpha_max."""
    b1 = choose_blocks(2048, 2048, 2048, p=1)
    b8 = choose_blocks(2048, 2048, 2048, p=8)
    assert b1.bm * b1.bn >= b8.bm * b8.bn
