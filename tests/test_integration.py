"""End-to-end integration: training on the emulated-GEMM path, serving,
optimizers, and the dd arithmetic properties."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import dd


def test_train_on_emulated_path_decreases_loss(tmp_path):
    """The paper's kernels as a *training* backend: a small LM trained
    entirely through ozaki1-p3 int8 GEMMs learns."""
    from repro.launch import train as train_cli
    log = train_cli.main([
        "--arch", "olmo-1b", "--smoke", "--steps", "10", "--batch", "4",
        "--seq", "32", "--gemm", "ozaki1-p3",
        "--ckpt-dir", str(tmp_path / "emu")])
    assert log[-1]["loss"] < log[0]["loss"]
    assert np.isfinite(log[-1]["loss"])


def test_emulated_and_native_training_agree_initially(tmp_path):
    from repro.launch import train as train_cli
    log_n = train_cli.main([
        "--arch", "granite-3-8b", "--smoke", "--steps", "3", "--batch", "2",
        "--seq", "32", "--ckpt-dir", str(tmp_path / "n")])
    log_e = train_cli.main([
        "--arch", "granite-3-8b", "--smoke", "--steps", "3", "--batch", "2",
        "--seq", "32", "--gemm", "ozaki1-p4",
        "--ckpt-dir", str(tmp_path / "e")])
    # same data, same init: first-step losses agree to emulation precision
    assert abs(log_n[0]["loss"] - log_e[0]["loss"]) < 1e-2


def test_serve_generates_consistent_greedy_tokens():
    from repro.launch import serve as serve_cli
    t1 = serve_cli.main(["--arch", "olmo-1b", "--smoke", "--requests", "2",
                         "--prompt-len", "24", "--gen", "6"])
    t2 = serve_cli.main(["--arch", "olmo-1b", "--smoke", "--requests", "2",
                         "--prompt-len", "24", "--gen", "6"])
    np.testing.assert_array_equal(t1, t2)   # greedy decode is deterministic


@pytest.mark.parametrize("kind", ["adamw", "adafactor"])
def test_optimizers_descend_quadratic(kind):
    from repro.optim import make_optimizer
    init, update = make_optimizer(kind)
    params = {"w": jnp.asarray([3.0, -2.0]), "m": jnp.ones((2, 2))}
    state = init(params)
    target = {"w": jnp.asarray([1.0, 1.0]), "m": jnp.zeros((2, 2))}

    def loss(p):
        return sum(jnp.sum((a - b) ** 2)
                   for a, b in zip(jax.tree.leaves(p),
                                   jax.tree.leaves(target)))

    l0 = float(loss(params))
    for _ in range(50):
        grads = jax.grad(loss)(params)
        params, state = update(grads, state, params, lr=0.05)
    assert float(loss(params)) < 0.2 * l0


def test_clip_by_global_norm():
    from repro.optim import clip_by_global_norm, global_norm
    g = {"a": jnp.full((10,), 10.0)}
    clipped, norm = clip_by_global_norm(g, 1.0)
    assert abs(float(global_norm(clipped)) - 1.0) < 1e-5
    assert float(norm) > 1.0


# ---------------------------------------------------------------------------
# Double-double arithmetic properties (hypothesis).
# ---------------------------------------------------------------------------

# subnormals excluded: XLA's CPU path flushes them to zero, and the
# two_sum/two_prod exactness theorems assume normalized IEEE arithmetic.
finite = st.floats(min_value=-(2.0 ** 50), max_value=2.0 ** 50,
                   allow_nan=False, width=32, allow_subnormal=False)


@given(a=finite, b=finite)
@settings(max_examples=100, deadline=None)
def test_two_sum_exact(a, b):
    s, e = dd.two_sum(jnp.float32(a), jnp.float32(b))
    # s + e == a + b exactly (compare in float64)
    assert float(s) + float(e) == float(jnp.float32(a)) + float(jnp.float32(b))


@given(a=st.floats(min_value=-1e6, max_value=1e6, allow_nan=False, width=32,
                   allow_subnormal=False),
       b=st.floats(min_value=-1e6, max_value=1e6, allow_nan=False, width=32,
                   allow_subnormal=False))
@settings(max_examples=100, deadline=None)
def test_two_prod_exact(a, b):
    p, e = dd.two_prod(jnp.float32(a), jnp.float32(b))
    exact = float(jnp.float32(a)) * float(jnp.float32(b))
    assert abs((float(p) + float(e)) - exact) <= 1e-7 * abs(exact) + 1e-30
