"""SSD chunked algorithm vs naive recurrence; RG-LRU scan vs step loop."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import RGLRUConfig, SSDConfig
from repro.models import rglru, ssd


def naive_ssd(xh, dt, a, bmat, cmat, d_skip, h0=None):
    """Direct recurrence h_t = exp(dt_t a) h_{t-1} + dt_t B_t x_t."""
    b, s, h, p = xh.shape
    n = bmat.shape[-1]
    st = np.zeros((b, h, p, n)) if h0 is None else np.asarray(h0, np.float64)
    xs = np.asarray(xh, np.float64)
    dts = np.asarray(dt, np.float64)
    bs = np.asarray(bmat, np.float64)
    cs = np.asarray(cmat, np.float64)
    av = np.asarray(a, np.float64)
    ys = np.zeros((b, s, h, p))
    for t in range(s):
        decay = np.exp(dts[:, t] * av)                      # (B,H)
        upd = np.einsum("bh,bhp,bn->bhpn", dts[:, t], xs[:, t], bs[:, t])
        st = st * decay[..., None, None] + upd
        ys[:, t] = np.einsum("bhpn,bn->bhp", st, cs[:, t])
    ys = ys + np.asarray(d_skip)[None, None, :, None] * xs
    return ys, st


@pytest.mark.parametrize("s,chunk", [(32, 8), (40, 16), (64, 64)])
def test_ssd_chunked_matches_recurrence(rng, s, chunk):
    b, h, p, n = 2, 3, 4, 8
    xh = jnp.asarray(rng.standard_normal((b, s, h, p)), jnp.float32)
    dt = jnp.asarray(rng.random((b, s, h)) * 0.1 + 0.01, jnp.float32)
    a = -jnp.asarray(rng.random(h) + 0.5, jnp.float32)
    bmat = jnp.asarray(rng.standard_normal((b, s, n)), jnp.float32)
    cmat = jnp.asarray(rng.standard_normal((b, s, n)), jnp.float32)
    d_skip = jnp.asarray(rng.random(h), jnp.float32)
    y, final = ssd.ssd_chunked(xh, dt, a, bmat, cmat, d_skip, chunk)
    y_ref, final_ref = naive_ssd(xh, dt, a, bmat, cmat, d_skip)
    np.testing.assert_allclose(np.asarray(y), y_ref, rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(final), final_ref,
                               rtol=2e-4, atol=2e-4)


def test_ssd_prefill_then_decode_matches_full(rng):
    """Chunked prefill state + recurrent decode == full-sequence scan."""
    b, h, p, n, s = 1, 2, 4, 8, 24
    xh = jnp.asarray(rng.standard_normal((b, s, h, p)), jnp.float32)
    dt = jnp.asarray(rng.random((b, s, h)) * 0.1 + 0.01, jnp.float32)
    a = -jnp.asarray(rng.random(h) + 0.5, jnp.float32)
    bmat = jnp.asarray(rng.standard_normal((b, s, n)), jnp.float32)
    cmat = jnp.asarray(rng.standard_normal((b, s, n)), jnp.float32)
    d0 = jnp.zeros(h)
    _, st8 = ssd.ssd_chunked(xh[:, :8], dt[:, :8], a, bmat[:, :8],
                             cmat[:, :8], d0, chunk=8)
    y_rest, st_full = ssd.ssd_chunked(xh[:, 8:], dt[:, 8:], a, bmat[:, 8:],
                                      cmat[:, 8:], d0, chunk=8, h0=st8)
    y_all, st_all = ssd.ssd_chunked(xh, dt, a, bmat, cmat, d0, chunk=8)
    np.testing.assert_allclose(np.asarray(y_rest), np.asarray(y_all[:, 8:]),
                               rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(st_full), np.asarray(st_all),
                               rtol=2e-4, atol=2e-4)


def test_rglru_scan_matches_step_loop(rng):
    b, s, w = 2, 16, 8
    a = jnp.asarray(rng.random((b, s, w)) * 0.8 + 0.1, jnp.float32)
    u = jnp.asarray(rng.standard_normal((b, s, w)), jnp.float32)
    h = rglru.rglru_scan(a, u)
    ref = np.zeros((b, w))
    for t in range(s):
        ref = np.asarray(a[:, t]) * ref + np.asarray(u[:, t])
        np.testing.assert_allclose(np.asarray(h[:, t]), ref,
                                   rtol=1e-5, atol=1e-5)


def test_rglru_scan_with_initial_state(rng):
    b, s, w = 1, 8, 4
    a = jnp.asarray(rng.random((b, s, w)) * 0.9, jnp.float32)
    u = jnp.asarray(rng.standard_normal((b, s, w)), jnp.float32)
    h0 = jnp.asarray(rng.standard_normal((b, w)), jnp.float32)
    h = rglru.rglru_scan(a, u, h0)
    ref = np.asarray(h0)
    for t in range(s):
        ref = np.asarray(a[:, t]) * ref + np.asarray(u[:, t])
    np.testing.assert_allclose(np.asarray(h[:, -1]), ref, rtol=1e-5,
                               atol=1e-5)


def test_causal_conv_decode_matches_train(rng):
    from repro.models.rglru import _causal_conv
    b, s, w, k = 1, 12, 4, 4
    x = jnp.asarray(rng.standard_normal((b, s, w)), jnp.float32)
    cw = jnp.asarray(rng.standard_normal((k, w)), jnp.float32)
    cb = jnp.zeros(w)
    y_full, _ = _causal_conv(x, cw, cb)
    state = jnp.zeros((b, k - 1, w))
    ys = []
    for t in range(s):
        y, state = _causal_conv(x[:, t:t + 1], cw, cb, state)
        ys.append(y)
    np.testing.assert_allclose(np.asarray(jnp.concatenate(ys, 1)),
                               np.asarray(y_full), rtol=1e-5, atol=1e-5)
