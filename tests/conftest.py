import numpy as np
import pytest

# NOTE: no XLA_FLAGS here — tests must see the real (1-device) CPU;
# only launch/dryrun.py fakes 512 devices.

# hypothesis is a declared dev dependency (pyproject.toml), but some
# sandboxes cannot pip-install: fall back to the vendored deterministic
# shim so the 4 property-based modules still collect and run. The real
# package always wins when importable.
try:
    import hypothesis  # noqa: F401
except ImportError:
    import _hypothesis_fallback
    _hypothesis_fallback.install()


@pytest.fixture
def rng():
    return np.random.default_rng(0)


def conditioned(rng, shape, phi=2.0, dtype=np.float32):
    """Paper Eq. 19 test matrices: (rand-0.5)*exp(phi*randn)."""
    return ((rng.random(shape) - 0.5)
            * np.exp(phi * rng.standard_normal(shape))).astype(dtype)


@pytest.fixture
def make_matrix(rng):
    def _make(shape, phi=2.0, dtype=np.float32):
        return conditioned(rng, shape, phi, dtype)
    return _make
