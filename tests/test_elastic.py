"""Elastic re-mesh: a checkpoint written under one sharding restores onto
a different mesh shape (the checkpoint stores logical arrays)."""

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.checkpoint import CheckpointManager


def test_restore_onto_different_mesh(tmp_path):
    devs = jax.devices()
    mesh1 = jax.make_mesh((1, 1), ("data", "model"))
    state = {"params": {"w": jax.device_put(
        jnp.arange(64.0).reshape(8, 8),
        NamedSharding(mesh1, P("data", "model")))}}
    mgr = CheckpointManager(str(tmp_path), async_save=False)
    mgr.save(1, state)

    # 'new cluster': different logical mesh + different target sharding
    mesh2 = jax.make_mesh((1,), ("data",))
    sh = {"params": {"w": NamedSharding(mesh2, P(None, "data"))}}
    like = jax.eval_shape(lambda: state)
    restored = mgr.restore(1, like, sh)
    np.testing.assert_array_equal(np.asarray(restored["params"]["w"]),
                                  np.arange(64.0).reshape(8, 8))
    assert restored["params"]["w"].sharding.mesh.shape == {"data": 1}


def test_trainer_resume_across_mesh_change(tmp_path):
    """Auto-resume with a *changed* state sharding (the elastic path the
    runtime uses after a topology change)."""
    from repro.runtime import Trainer

    calls = {"n": 0}

    def step_fn(state, batch):
        calls["n"] += 1
        return {"w": state["w"] + 1.0}, {"loss": float(jnp.sum(state["w"]))}

    def init_state():
        return {"w": jnp.zeros((4, 4))}

    def batches():
        i = 0
        while True:
            yield i, {}
            i += 1

    t1 = Trainer(step_fn=step_fn, init_state_fn=init_state,
                 batch_iterator=batches(), ckpt_dir=str(tmp_path),
                 ckpt_every=2)
    t1.run(4)
    t1.close()

    mesh = jax.make_mesh((1,), ("data",))
    sh = {"w": NamedSharding(mesh, P("data", None))}
    t2 = Trainer(step_fn=step_fn, init_state_fn=init_state,
                 batch_iterator=batches(), ckpt_dir=str(tmp_path),
                 state_shardings=sh, ckpt_every=2)
    assert t2.start_step == 4
    np.testing.assert_array_equal(np.asarray(t2.state["w"]),
                                  np.full((4, 4), 4.0))
    t2.close()
