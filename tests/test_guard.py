"""repro.guard: sentinels, a posteriori verification, the escalation
ladder, fault injection, and the denormal/scale regression suite."""

import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

import repro
from repro import guard
from repro.core import scheme1, scheme2
from repro.core.precision import EmulationAccuracyError, EmulationConfig
from repro.kernels import dispatch
from conftest import conditioned

DN = (((1,), (0,)), ((), ()))


def _int_operands(m=32, k=64, n=24, seed=0):
    """Small nonzero integers: exactly emulated at any p, so recovery is
    checkable as bit-identity, and no slice/residue plane annihilates an
    injected fault by multiplying it with zeros."""
    rng = np.random.default_rng(seed)
    a = rng.integers(1, 9, (m, k)) * rng.choice([-1.0, 1.0], (m, k))
    b = rng.integers(1, 9, (k, n)) * rng.choice([-1.0, 1.0], (k, n))
    return jnp.asarray(a, jnp.float32), jnp.asarray(b, jnp.float32)


# ---------------------------------------------------------------------------
# Spec grammar: the +guard / +guard:strict suffixes.
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("spec, mode", [
    ("ozaki1-p4+guard", "on"),
    ("ozaki1-p4+guard:strict", "strict"),
    ("ozaki2-m6@gpu+guard", "on"),
    ("bits=40:k1024+guard:strict", "strict"),
])
def test_guard_spec_roundtrip(spec, mode):
    cfg = EmulationConfig.parse(spec)
    assert cfg.guard == mode
    assert EmulationConfig.parse(cfg.to_spec()) == cfg


def test_guard_requires_emulation_scheme():
    with pytest.raises(ValueError, match="guard"):
        EmulationConfig.parse("native+guard")


def test_guard_spec_through_api_resolver():
    cfg = repro.precision("ozaki1-p4+guard:strict")
    assert cfg.guard == "strict" and cfg.scheme == "ozaki1"


# ---------------------------------------------------------------------------
# Satellite: power-of-two scale handling on denormal / zero / extreme rows.
# ---------------------------------------------------------------------------


def test_exact_pow2_is_exact_across_the_normal_range():
    exps = jnp.arange(-126, 128)
    got = scheme1.exact_pow2(exps, jnp.float32)
    want = np.asarray([2.0 ** e for e in range(-126, 128)], np.float32)
    np.testing.assert_array_equal(np.asarray(got), want)


def test_exact_pow2_clamps_and_saturates():
    got = scheme1.exact_pow2(jnp.asarray([-200, -127, 128, 300]),
                             jnp.float32)
    assert float(got[0]) == 2.0 ** -126  # clamped to smallest normal
    assert float(got[1]) == 2.0 ** -126
    assert np.isposinf(float(got[2])) and np.isposinf(float(got[3]))


def test_exact_pow2_large_exponents_bit_exact():
    # jnp.exp2 lands ulp off up here eagerly; the bit-built scale must not.
    for e in (100, 120, 126, 127):
        assert float(scheme1.exact_pow2(jnp.asarray(e), jnp.float32)) \
            == 2.0 ** e


@pytest.mark.parametrize("scheme", ["ozaki1", "ozaki2"])
def test_all_zero_rows_are_exact(scheme):
    a = np.zeros((3, 16), np.float32)
    a[1] = np.arange(16)
    b = np.asarray(np.random.default_rng(0).integers(-3, 4, (16, 5)),
                   np.float32)
    cfg = EmulationConfig(scheme=scheme, p=4 if scheme == "ozaki1" else 6)
    mod = scheme1 if scheme == "ozaki1" else scheme2
    out = np.asarray(mod.matmul(jnp.asarray(a), jnp.asarray(b), cfg,
                                jnp.float32))
    np.testing.assert_array_equal(out[0], 0.0)
    np.testing.assert_array_equal(out[2], 0.0)
    np.testing.assert_allclose(out[1], a[1] @ b, rtol=1e-6)


@pytest.mark.parametrize("scheme", ["ozaki1", "ozaki2"])
def test_subnormal_only_rows_match_native(scheme):
    """Denormal regression: subnormal-only rows used to round the
    power-of-two scale itself to zero (scheme1: 0 scale -> 0/0 NaN rows;
    scheme2: inf scale -> int-wraparound garbage).  The fixed scales are
    finite and exactly invertible, so the result now matches the native
    dot bit for bit — on this platform XLA:CPU flushes subnormal inputs
    to zero (DAZ), and the emulated path inherits exactly that semantic
    instead of manufacturing NaNs."""
    a = np.array([[2.0 ** -149, 2.0 ** -140, 0.0, 2.0 ** -130],
                  [0.0, 2.0 ** -127, 2.0 ** -135, 2.0 ** -149]], np.float32)
    b = np.asarray(np.random.default_rng(1).integers(-3, 4, (4, 3)),
                   np.float32)
    cfg = EmulationConfig(scheme=scheme, p=4 if scheme == "ozaki1" else 6)
    mod = scheme1 if scheme == "ozaki1" else scheme2
    out = np.asarray(mod.matmul(jnp.asarray(a), jnp.asarray(b), cfg,
                                jnp.float32))
    native = np.asarray(jnp.asarray(a) @ jnp.asarray(b))
    assert np.all(np.isfinite(out))
    np.testing.assert_array_equal(out, native)


def test_subnormal_row_scale_is_finite_and_invertible():
    a = jnp.asarray([[2.0 ** -149, 2.0 ** -130]], jnp.float32)
    mu = scheme1._pow2_row_scale(a, axis=1)
    assert np.isfinite(float(mu[0, 0])) and float(mu[0, 0]) > 0
    assert np.isfinite(float(1.0 / mu[0, 0]))


def test_scheme2_integer_scale_subnormal_rows_flush_gracefully():
    a = jnp.asarray([[2.0 ** -149, 2.0 ** -140]], jnp.float32)
    a_int, mu = scheme2.integerize(a, axis=1, budget_bits=24)
    assert np.isfinite(float(mu[0, 0]))
    np.testing.assert_array_equal(np.asarray(a_int), 0.0)


# ---------------------------------------------------------------------------
# Satellite: check_exact_k raises the dedicated error, naming remediation.
# ---------------------------------------------------------------------------


def test_check_exact_k_remediation_message():
    with pytest.raises(EmulationAccuracyError) as ei:
        scheme2.check_exact_k(200_000, (256, 255))
    msg = str(ei.value)
    assert "bits=" in msg and "shard" in msg and "131071" in msg
    assert issubclass(EmulationAccuracyError, ValueError)  # compat


# ---------------------------------------------------------------------------
# Special-value semantics: NaN/Inf parity with the native dot.
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("backend", ["tpu", "gpu", "xla"])
@pytest.mark.parametrize("scheme", ["ozaki1-p4", "ozaki2-m6"])
def test_nan_inf_parity_fused(backend, scheme, rng):
    a = conditioned(rng, (16, 32))
    b = conditioned(rng, (32, 12))
    a[3, 5], a[7, 0] = np.nan, np.inf
    b[2, 4] = -np.inf
    out = np.asarray(dispatch.emulated_matmul(
        jnp.asarray(a), jnp.asarray(b), cfg=f"{scheme}@{backend}+guard"))
    native = np.asarray(jnp.asarray(a) @ jnp.asarray(b))
    # Exactly the rows/cols a non-finite entry contaminates are NaN...
    assert np.all(np.isnan(out[3])) and np.all(np.isnan(out[7]))
    assert np.all(np.isnan(out[:, 4]))
    # ...they cover everything native reports non-finite...
    assert np.all(np.isnan(out[~np.isfinite(native)]))
    # ...and nothing else: clean lanes are finite and bit-identical to
    # the unguarded emulated product of the sanitized operands.
    clean = np.ones_like(out, bool)
    clean[3], clean[7], clean[:, 4] = False, False, False
    assert np.all(np.isfinite(out[clean]))
    san_a = np.where(np.isfinite(a), a, 0.0)
    san_b = np.where(np.isfinite(b), b, 0.0)
    ref = np.asarray(dispatch.emulated_matmul(
        jnp.asarray(san_a), jnp.asarray(san_b), cfg=f"{scheme}@{backend}"))
    np.testing.assert_array_equal(out[clean], ref[clean])


@pytest.mark.parametrize("scheme", ["ozaki1-p4", "ozaki2-m6"])
def test_nan_inf_parity_prepared_lhs(scheme, rng):
    """Prepared weights are decomposed clean; the sentinel masking must
    still cover non-finite *activations* (the realistic serving case)."""
    a = conditioned(rng, (16, 32))
    a[5, 1] = np.nan
    b = conditioned(rng, (32, 12))
    prep = repro.prepare_rhs(jnp.asarray(b), repro.precision(scheme))
    out = np.asarray(repro.dot_general(jnp.asarray(a), prep, DN,
                                       precision=f"{scheme}+guard"))
    assert np.all(np.isnan(out[5]))
    clean = np.delete(out, 5, axis=0)
    assert np.all(np.isfinite(clean))
    san_a = np.where(np.isfinite(a), a, 0.0)
    ref = np.delete(np.asarray(repro.dot_general(
        jnp.asarray(san_a), prep, DN, precision=scheme)), 5, axis=0)
    np.testing.assert_array_equal(clean, ref)


def test_guarded_clean_run_counts_and_is_bit_identical():
    a, b = _int_operands()
    guard.stats_clear()
    ref = dispatch.emulated_matmul(a, b, cfg="ozaki1-p4")
    out = dispatch.emulated_matmul(a, b, cfg="ozaki1-p4+guard")
    s = guard.stats()
    assert s.calls == 1 and s.verified == 1 and s.trips == 0
    assert jnp.array_equal(out, ref)


# ---------------------------------------------------------------------------
# Wide exponent spread: the sentinel flags operands whose dynamic range
# exceeds the planned precision budget.
# ---------------------------------------------------------------------------


def test_wide_spread_warns_against_precision_budget(rng):
    a = conditioned(rng, (16, 32)).astype(np.float64)
    a[0, 0], a[1, 1] = 1e30, 1e-30  # ~200-bit spread vs a ~27-bit budget
    b = conditioned(rng, (32, 8)).astype(np.float64)
    dispatch.fallback_warnings_clear()
    with warnings.catch_warnings(record=True) as rec:
        warnings.simplefilter("always")
        dispatch.emulated_matmul(jnp.asarray(a, jnp.float32),
                                 jnp.asarray(b, jnp.float32),
                                 cfg="ozaki1-p4+guard")
    spread_msgs = [str(w.message) for w in rec
                   if "exponent spread" in str(w.message)]
    assert spread_msgs, [str(w.message) for w in rec]
    assert "bits" in spread_msgs[0]


def test_narrow_spread_does_not_warn(rng):
    a, b = _int_operands()
    dispatch.fallback_warnings_clear()
    with warnings.catch_warnings(record=True) as rec:
        warnings.simplefilter("always")
        dispatch.emulated_matmul(a, b, cfg="ozaki1-p4+guard")
    assert not [w for w in rec if "exponent spread" in str(w.message)]


# ---------------------------------------------------------------------------
# Fault injection: the verifier catches what it claims to catch, and the
# ladder recovers.  @xla pins the reference backend, whose decomposition
# runs through the hooked scheme1.split / scheme2.balanced_residues.
# ---------------------------------------------------------------------------


@given(bit=st.integers(4, 6), operand=st.sampled_from(["a", "b"]),
       kind=st.sampled_from(["bitflip_slice", "zero_modulus"]))
@settings(max_examples=8, deadline=None)
def test_injected_slice_fault_caught_and_recovered_scheme1(bit, operand,
                                                           kind):
    # Plane 0 (the top mantissa slice): a high-bit flip there perturbs
    # the effective operand by ~2^(bit-beta) of its column scale, well
    # above the verifier's analytic tolerance at these shapes.  Faults
    # in the *last* plane at low bits are of the order of the
    # decomposition residual itself and are tolerated by construction
    # (see test_injection_last_plane_lsb_below_bound_is_tolerated).
    a, b = _int_operands(m=16, k=16, n=8)
    guard.stats_clear()
    ref = dispatch.emulated_matmul(a, b, cfg="ozaki1-p4@xla")
    with guard.inject(kind, count=1, bit=bit, plane=0,
                      operand=operand) as fault:
        out = dispatch.emulated_matmul(a, b, cfg="ozaki1-p4@xla+guard")
    s = guard.stats()
    assert fault.fired == 1
    assert s.trips == 1 and s.recoveries == 1
    assert jnp.array_equal(out, ref)


@given(plane=st.integers(1, 5),
       kind=st.sampled_from(["bitflip_slice", "zero_modulus"]))
@settings(max_examples=6, deadline=None)
def test_injected_residue_fault_caught_scheme2(plane, kind):
    # plane >= 1: plane 0's modulus is 256 and integer operands scaled by
    # a power of two have identically-zero residues there, so corrupting
    # it cannot change the product (degenerate by construction).
    a, b = _int_operands(seed=3)
    guard.stats_clear()
    ref = dispatch.emulated_matmul(a, b, cfg="ozaki2-m6@xla")
    with guard.inject(kind, count=1, plane=plane) as fault:
        out = dispatch.emulated_matmul(a, b, cfg="ozaki2-m6@xla+guard")
    s = guard.stats()
    assert fault.fired == 1
    assert s.trips == 1 and s.recoveries == 1
    assert jnp.array_equal(out, ref)


def test_injection_last_plane_lsb_below_bound_is_tolerated():
    """A last-plane LSB flip is of the order of the decomposition's own
    residual bound — the verifier is *specified* not to trip on it (the
    tolerance is the analytic bound, not zero)."""
    rng = np.random.default_rng(7)
    a = jnp.asarray(conditioned(rng, (32, 64)))
    b = jnp.asarray(conditioned(rng, (64, 24)))
    guard.stats_clear()
    with guard.inject("bitflip_slice", count=1, bit=0, plane=3) as fault:
        dispatch.emulated_matmul(a, b, cfg="ozaki1-p4@xla+guard")
    assert fault.fired == 1
    assert guard.stats().trips == 0


def test_inject_validates_arguments():
    with pytest.raises(ValueError):
        with guard.inject("not_a_kind"):
            pass
    with pytest.raises(ValueError):
        with guard.inject("bitflip_slice", bit=9):
            pass
    with pytest.raises(ValueError):
        with guard.inject("bitflip_slice", operand="c"):
            pass


def test_strict_exhausted_ladder_raises():
    a, b = _int_operands(seed=5)
    guard.stats_clear()
    with pytest.raises(EmulationAccuracyError, match="strict"):
        with guard.inject("zero_modulus", count=99, plane=1):
            dispatch.emulated_matmul(a, b, cfg="ozaki2-m6@xla+guard:strict")
    s = guard.stats()
    assert s.trips == 1 and s.escalations >= 1 and s.recoveries == 0


def test_on_mode_exhausted_ladder_falls_back_to_native():
    a, b = _int_operands(seed=6)
    guard.stats_clear()
    dispatch.fallback_warnings_clear()
    with pytest.warns(RuntimeWarning, match="native"):
        with guard.inject("zero_modulus", count=99, plane=1):
            out = dispatch.emulated_matmul(a, b, cfg="ozaki2-m6@xla+guard")
    s = guard.stats()
    assert s.native_fallbacks == 1
    np.testing.assert_allclose(np.asarray(out), np.asarray(a) @ np.asarray(b),
                               rtol=1e-6)


# ---------------------------------------------------------------------------
# verify_gemm directly.
# ---------------------------------------------------------------------------


def test_verify_gemm_passes_good_and_catches_corruption(rng):
    a = conditioned(rng, (32, 48))
    b = conditioned(rng, (48, 16))
    c = np.asarray(jnp.asarray(a) @ jnp.asarray(b))
    assert guard.verify_gemm(a, b, c, cfg="ozaki1-p4")
    bad = c.copy()
    bad[3, 3] += 0.1 * np.abs(c).max()
    res = guard.verify_gemm(a, b, bad, cfg="ozaki1-p4")
    assert not res and float(res.err) > res.tol


def test_verify_gemm_accepts_prepared_rhs(rng):
    a = conditioned(rng, (16, 32))
    b = conditioned(rng, (32, 8))
    prep = repro.prepare_rhs(jnp.asarray(b), repro.precision("ozaki1-p6"))
    c = np.asarray(jnp.asarray(a) @ jnp.asarray(b))
    assert guard.verify_gemm(a, prep, c, cfg="ozaki1-p6")


def test_verify_tolerance_tracks_plan_precision_bound():
    # More precision bits -> tighter trip threshold, monotonically.
    from repro.guard.verify import tolerance
    tols = [tolerance(bits, 64, 64, 64) for bits in (14, 27, 40)]
    assert tols[0] > tols[1] > tols[2]
    # The 2^(1-bits) term is exactly the plan_precision residual model.
    assert tolerance(20, 64, 64, 64, tol_factor=1.0) \
        == pytest.approx(2.0 ** -19 + 128 * np.finfo(np.float32).eps)


# ---------------------------------------------------------------------------
# Traced path: sanitize + verify + mask, counted via debug.callback.
# ---------------------------------------------------------------------------


def test_traced_guard_masks_and_counts(rng):
    a = conditioned(rng, (16, 32))
    a[4, 0] = np.inf
    b = conditioned(rng, (32, 8))
    guard.stats_clear()
    f = jax.jit(lambda x, y: repro.dot_general(
        x, y, DN, precision="ozaki1-p4+guard"))
    out = f(jnp.asarray(a), jnp.asarray(b))
    out.block_until_ready()
    s = guard.stats()
    assert s.calls == 1 and s.verified == 1 and s.masked == 1
    out = np.asarray(out)
    assert np.all(np.isnan(out[4])) and np.all(np.isfinite(out[:4]))


def test_guarded_grad_runs_and_is_finite(rng):
    a = jnp.asarray(conditioned(rng, (8, 16)))
    b = jnp.asarray(conditioned(rng, (16, 4)))
    g = jax.grad(lambda x: repro.dot_general(
        x, b, DN, precision="ozaki1-p4+guard").sum())(a)
    assert np.all(np.isfinite(np.asarray(g)))


def test_guard_skips_prepared_vjp_shortcut():
    # cache_weights + guard: the forward must NOT pin a prepared stack
    # (the ladder may re-plan p); the guarded engine handles it instead.
    from repro.core import emulated
    cfg = EmulationConfig.parse("ozaki1-p4+cached+guard")
    a, b = _int_operands(m=8, k=16, n=4)
    assert emulated._cacheable(a, b, cfg)  # cacheable, but...
    guard.stats_clear()
    out, _ = emulated._fwd(a, b, cfg, "-")
    assert guard.stats().calls == 1  # ...went through the guarded engine
    ref = dispatch.emulated_matmul(a, b, cfg="ozaki1-p4")
    assert jnp.array_equal(out, ref)


# ---------------------------------------------------------------------------
# Runtime consumption: the trainer retries strict trips with backoff and
# folds guard deltas into its metrics.
# ---------------------------------------------------------------------------


def test_trainer_retries_strict_guard_trips(tmp_path):
    from repro.runtime.trainer import Trainer

    calls = {"n": 0}

    def step_fn(state, batch):
        calls["n"] += 1
        if calls["n"] == 1:
            raise EmulationAccuracyError("synthetic strict trip")
        return {"w": state["w"] + 1.0}, {"loss": jnp.float32(0.0)}

    t = Trainer(step_fn=step_fn, init_state_fn=lambda: {"w": jnp.zeros(2)},
                batch_iterator=((i, {}) for i in range(10)),
                ckpt_dir=str(tmp_path), guard_backoff=0.0)
    log = t.run(2)
    t.close()
    assert calls["n"] == 3  # step 0 tripped once, retried; step 1 clean
    assert log[0]["guard_retries"] == 1 and log[1]["guard_retries"] == 0
    assert "guard_trips" in log[0]


def test_trainer_reraises_when_retries_exhausted(tmp_path):
    from repro.runtime.trainer import Trainer

    def step_fn(state, batch):
        raise EmulationAccuracyError("always trips")

    t = Trainer(step_fn=step_fn, init_state_fn=lambda: {"w": jnp.zeros(2)},
                batch_iterator=((i, {}) for i in range(10)),
                ckpt_dir=str(tmp_path), guard_retries=1, guard_backoff=0.0)
    with pytest.raises(EmulationAccuracyError):
        t.run(1)
    t.close()


def test_guard_monitor_observes_step_deltas():
    from repro.runtime.trainer import GuardMonitor
    mon = GuardMonitor()
    a, b = _int_operands(m=8, k=16, n=4)
    dispatch.emulated_matmul(a, b, cfg="ozaki1-p4+guard")
    delta = mon.observe(step=0)
    assert delta["calls"] == 1 and delta["trips"] == 0
    assert mon.observe(step=1)["calls"] == 0  # delta, not cumulative


# ---------------------------------------------------------------------------
# Stats bookkeeping.
# ---------------------------------------------------------------------------


def test_stats_clear_resets_all_counters():
    a, b = _int_operands(m=8, k=16, n=4)
    dispatch.emulated_matmul(a, b, cfg="ozaki1-p4+guard")
    assert guard.stats().calls >= 1
    guard.stats_clear()
    s = guard.stats()
    assert s == guard.GuardStats()
    assert not s.tripped
