"""Once-per-step weight preparation under gradient accumulation.

The microbatch scan in launch/steps.py must not re-run Scheme-I weight
decomposition per microbatch: with ``cache_weights`` policies the
PreparedOperand is built *outside* the scan body (once per optimizer
step) and the scan closes over the finished slices.  Asserted with a
runtime prep-call counter (a host callback fires once per executed
``prepare_rhs``, so scan iterations — which share one trace — are
counted per execution, not per trace)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import Mesh

from repro.configs.base import ArchConfig, ModelConfig, ShapeSpec, TrainPolicy
from repro.kernels import prepared
from repro.launch import steps as S
from repro.models import model as M
import repro
from repro.models.common import GemmPolicy
from repro.optim import make_optimizer

N_MICRO = 4


def _tiny_arch(n_micro: int) -> ArchConfig:
    mcfg = ModelConfig(name="tiny", family="dense", n_layers=2, d_model=32,
                      n_heads=2, n_kv_heads=2, d_ff=64, vocab=128)
    return ArchConfig(model=mcfg,
                      train=TrainPolicy(microbatches=n_micro, remat=False))


def _run_one_step(arch, policy, counter):
    shape = ShapeSpec("train_tiny", 16, 8, "train")
    mesh = Mesh(np.array(jax.devices()[:1]).reshape(1, 1), ("data", "model"))
    step = S.make_train_step(arch, mesh, shape, policy, donate=False)
    params = jax.jit(lambda k: M.init_params(k, mcfg=arch.model))(
        jax.random.PRNGKey(0))
    opt_init, _ = make_optimizer(arch.train.optimizer)
    state = {"params": params, "opt": jax.jit(opt_init)(params)}
    batch = {"tokens": jnp.zeros((8, 16), jnp.int32),
             "labels": jnp.ones((8, 16), jnp.int32)}
    counter["n"] = 0
    state, metrics = step(state, batch)
    jax.block_until_ready(metrics["loss"])
    first = counter["n"]
    counter["n"] = 0
    state, metrics = step(state, batch)  # steady state: no retrace
    jax.block_until_ready(metrics["loss"])
    return first, counter["n"], params, float(metrics["loss"])


@pytest.fixture
def prep_counter(monkeypatch):
    """Count runtime executions of prepare_rhs via a host callback."""
    counter = {"n": 0}
    orig = prepared.prepare_rhs

    def counting(b, cfg, **kw):
        jax.debug.callback(lambda: counter.__setitem__("n", counter["n"] + 1))
        return orig(b, cfg, **kw)

    monkeypatch.setattr(prepared, "prepare_rhs", counting)
    return counter


def _expected_preps(params, policy) -> int:
    """One prep per cacheable weight per step: stacked layer groups count
    once per layer (they were prepared per layer per *microbatch* before
    the hoist), unstacked weights once."""
    preps = prepared.build_step_preps(params, policy)
    total = 0
    for prep in preps.values():
        sl = prep.slices
        # stacked-over-layers preps carry a leading group axis
        total += sl.shape[0] if sl.ndim == 4 else 1
    return total


def test_prepared_once_per_step_under_grad_accum(prep_counter):
    arch = _tiny_arch(N_MICRO)
    policy = GemmPolicy(default=repro.precision("ozaki1-p3+xla+cached"))
    first, steady, params, loss = _run_one_step(arch, policy, prep_counter)
    assert np.isfinite(loss)
    expected = _expected_preps(params, policy)
    assert expected > 0
    # Exactly once per optimizer step — NOT once per microbatch.
    assert first == expected, (first, expected)
    assert steady == expected, (steady, expected)
    assert first < expected * N_MICRO


def test_grad_accum_matches_unaccumulated_loss(prep_counter):
    """The hoisted prepared path computes the same loss as n_micro=1
    (same weights, same decomposition artifact)."""
    policy = GemmPolicy(default=repro.precision("ozaki1-p3+xla+cached"))
    _, _, _, loss_acc = _run_one_step(_tiny_arch(N_MICRO), policy,
                                      prep_counter)
    _, _, _, loss_one = _run_one_step(_tiny_arch(1), policy, prep_counter)
    np.testing.assert_allclose(loss_acc, loss_one, rtol=1e-5)


def test_native_policy_builds_no_preps(prep_counter):
    arch = _tiny_arch(N_MICRO)
    first, steady, _, loss = _run_one_step(arch, GemmPolicy(), prep_counter)
    assert first == 0 and steady == 0
    assert np.isfinite(loss)


def test_step_prepared_gradients_flow(make_matrix):
    """emulated_dot_prepared: forward from the prep, dB to the weight —
    gradients agree with the native float path to emulation precision."""
    from repro.core.emulated import emulated_dot_prepared
    a = jnp.asarray(make_matrix((16, 32)))
    b = jnp.asarray(make_matrix((32, 24)))
    cfg = repro.precision("ozaki1-p4+xla+cached")
    prep = prepared.prepare_rhs(b, cfg, with_twin=True)

    def f_emu(a, b):
        return jnp.sum(jnp.sin(emulated_dot_prepared(a, b, prep, cfg)))

    def f_nat(a, b):
        return jnp.sum(jnp.sin(a @ b))

    ga_e, gb_e = jax.grad(f_emu, argnums=(0, 1))(a, b)
    ga_n, gb_n = jax.grad(f_nat, argnums=(0, 1))(a, b)
    for ge, gn in ((ga_e, ga_n), (gb_e, gb_n)):
        np.testing.assert_allclose(
            np.asarray(ge), np.asarray(gn), rtol=1e-2,
            atol=1e-2 * float(jnp.abs(gn).max() + 1e-9))


def test_attach_step_preps_roundtrip():
    """attach_step_preps swaps exactly the prepared leaves and leaves the
    rest of the tree untouched."""
    params = {"head": jnp.ones((32, 16)), "ln": {"scale": jnp.ones((4,))}}
    policy = GemmPolicy(default=repro.precision("ozaki1-p3+xla+cached"))
    preps = prepared.build_step_preps(params, policy)
    assert set(preps) == {"head"}
    wrapped = prepared.attach_step_preps(params, preps)
    assert isinstance(wrapped["head"], prepared.StepPrepared)
    assert wrapped["ln"]["scale"] is params["ln"]["scale"]
    # no preps -> identity
    assert prepared.attach_step_preps(params, {}) is params
