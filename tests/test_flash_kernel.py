"""Pallas fused flash-attention kernel vs the softmax oracle
(interpret-mode shape/config sweep)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import flash_attn
from repro.kernels import ref as kref


def _mats(rng, b, h, kvh, sq, sk, d, dtype=jnp.float32):
    q = jnp.asarray(rng.standard_normal((b, h, sq, d)), dtype)
    k = jnp.asarray(rng.standard_normal((b, kvh, sk, d)), dtype)
    v = jnp.asarray(rng.standard_normal((b, kvh, sk, d)), dtype)
    return q, k, v


@pytest.mark.parametrize("causal", [True, False])
@pytest.mark.parametrize("h,kvh", [(4, 4), (8, 2)])
def test_flash_kernel_matches_oracle(rng, causal, h, kvh):
    q, k, v = _mats(rng, 2, h, kvh, 256, 256, 64)
    out = flash_attn.flash_attention(q, k, v, causal=causal, bq=128, bk=128)
    ref = kref.flash_attention(q, k, v, causal=causal)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-5, atol=2e-5)


def test_flash_kernel_local_window(rng):
    q, k, v = _mats(rng, 1, 2, 1, 256, 256, 32)
    out = flash_attn.flash_attention(q, k, v, causal=True, window=64,
                                     bq=64, bk=64)
    ref = kref.flash_attention(q, k, v, causal=True, window=64)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-5, atol=2e-5)


def test_flash_kernel_rectangular_and_bf16(rng):
    q, k, v = _mats(rng, 1, 4, 4, 128, 512, 64, jnp.bfloat16)
    out = flash_attn.flash_attention(q, k, v, causal=False, bq=128, bk=256)
    ref = kref.flash_attention(q, k, v, causal=False)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32),
                               rtol=2e-2, atol=2e-2)


def test_flash_kernel_attention_hbm_traffic_model():
    """The fusion claim, quantified like the paper's Eqs. 9->10: unfused
    attention round-trips the (Sq, Sk) scores through HBM; the fused
    kernel streams only q/k/v/o."""
    b, h, s, d = 2, 40, 32768, 128
    score_bytes = 4 * b * h * s * s * 2          # write + read, f32
    qkvo_bytes = 2 * b * h * s * d * 2 + 2 * b * h * s * d * 2
    assert score_bytes / qkvo_bytes > 60         # >60x less HBM traffic
