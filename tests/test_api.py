"""The unified precision API: specs, ambient scopes, einsum/dot_general.

Covers the three pillars of repro.api plus the public-surface snapshot:

* parse/print round-trip properties of the precision-spec mini-language,
  and the plan_precision routing (``bits=N`` specs);
* scope nesting / threading semantics of ``repro.emulation`` and the
  documented resolver precedence (explicit > scope > env > default);
* ``repro.einsum``/``dot_general`` vs the ``jnp.einsum`` oracle across
  the contraction-pattern zoo (batch dims, multi-axis contractions,
  implicit outputs, ellipses, complex, PreparedOperand rhs), plus
  bit-identity with the 2-D dispatcher where the fused path is exact;
* the deprecation shims (old entry points warn but keep working);
* an API snapshot so public-surface drift fails loudly.
"""

import inspect
import threading

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro
from repro.core.precision import (DEFAULT_MODULI, EmulationConfig,
                                  default_moduli, plan_precision)
from repro.kernels import dispatch, prepared


@pytest.fixture(autouse=True)
def _clean_ambient_env(monkeypatch):
    """These tests probe the resolver's *own* semantics: an externally
    set REPRO_EMULATION (e.g. the CI row that runs the whole suite under
    ambient ozaki2-m6) must not leak in.  Tests that exercise the env
    rank set it explicitly via monkeypatch, which runs after this."""
    monkeypatch.delenv(repro.EMULATION_ENV_VAR, raising=False)

# ---------------------------------------------------------------------------
# Pillar 1: precision specs.
# ---------------------------------------------------------------------------

CANONICAL_SPECS = [
    "native",
    "ozaki1-p2",
    "ozaki1-p4",
    "ozaki2-m6",
    "ozaki2-m12",
    "ozaki1-p4@gpu",
    "ozaki1-p3+cached",
    "ozaki1-p4@gpu+cached",
    "ozaki2-m6+cached",
    "ozaki2-m4@gpu+cached",
    "ozaki1-p4+xla",
    "ozaki2-m8@tpu+pallas",
    "native@xla",
]


@pytest.mark.parametrize("spec", CANONICAL_SPECS)
def test_spec_roundtrip(spec):
    """to_spec is the inverse of parse on canonical specs."""
    cfg = repro.precision(spec)
    assert cfg.to_spec() == spec
    assert EmulationConfig.parse(cfg.to_spec()) == cfg


def test_parse_is_idempotent_on_configs():
    cfg = repro.precision("ozaki1-p4")
    assert repro.precision(cfg) is cfg
    assert EmulationConfig.parse(cfg) is cfg


def test_spec_suffix_order_is_canonicalized():
    a = repro.precision("ozaki1-p4+cached@gpu")
    b = repro.precision("ozaki1-p4@gpu+cached")
    assert a == b
    assert a.to_spec() == "ozaki1-p4@gpu+cached"


def test_ozaki2_spec_pins_moduli():
    cfg = repro.precision("ozaki2-m6")
    assert cfg.moduli == default_moduli(6)
    # legacy '-p' alias accepted, canonicalized to '-m'
    assert repro.precision("ozaki2-p6") == cfg
    assert cfg.to_spec() == "ozaki2-m6"


def test_bits_spec_routes_through_plan_precision():
    cfg = repro.precision("bits=40")
    assert cfg == plan_precision(40, 4096)
    assert cfg.scheme == "ozaki2" and cfg.moduli is not None
    # planned configs round-trip (the pinned moduli make this hold)
    assert EmulationConfig.parse(cfg.to_spec()) == cfg
    cfg_k = repro.precision("bits=20:k256")
    assert cfg_k == plan_precision(20, 256)


def test_precision_overrides_kwargs():
    cfg = repro.precision("ozaki1-p4", bwd_p=2)
    assert cfg.p == 4 and cfg.bwd_p == 2


@pytest.mark.parametrize("bad", [
    "ozaki3-p4",        # unknown scheme
    "ozaki1-m4",        # ozaki1 counts slices with -p
    "ozaki1-p0",        # count must be >= 1
    "ozaki1p4",         # missing dash
    "bits=",            # missing number
    "native+cached",    # cached needs an emulation scheme
    "ozaki1-p4+frobnicate",
    "ozaki1-p4@gpu@tpu",
    "",
])
def test_bad_specs_raise(bad):
    with pytest.raises(ValueError):
        repro.precision(bad)


def test_precision_rejects_non_spec_types():
    with pytest.raises(TypeError):
        repro.precision(42)


def test_to_spec_names_unrepresentable_fields():
    cfg = EmulationConfig(scheme="ozaki1", p=4, beta=5, bwd_p=2)
    with pytest.raises(ValueError, match="beta.*bwd_p"):
        cfg.to_spec()
    with pytest.raises(ValueError, match="moduli"):
        EmulationConfig(scheme="ozaki2", p=2, moduli=(251, 241)).to_spec()


# ---------------------------------------------------------------------------
# Satellite: plan_precision prefer semantics + pinned moduli.
# ---------------------------------------------------------------------------

def test_plan_precision_pins_ozaki2_moduli():
    cfg = plan_precision(48, 4096)
    assert cfg.scheme == "ozaki2"
    assert cfg.moduli == default_moduli(cfg.p)


def test_plan_precision_prefer_unreachable_raises():
    max2 = EmulationConfig(
        scheme="ozaki2", p=len(DEFAULT_MODULI)).bits(4096)
    with pytest.raises(ValueError, match=f"at most {max2} bits"):
        plan_precision(max2 + 10, 4096, prefer="ozaki2")
    with pytest.raises(ValueError, match="ozaki1.*at most"):
        plan_precision(1000, 4096, prefer="ozaki1")
    with pytest.raises(ValueError, match="prefer"):
        plan_precision(20, 4096, prefer="native")


def test_plan_precision_prefer_reachable_is_honored():
    cfg = plan_precision(20, 4096, prefer="ozaki2")
    assert cfg.scheme == "ozaki2" and cfg.bits(4096) >= 20


# ---------------------------------------------------------------------------
# Pillar 2: ambient scopes + the resolver.
# ---------------------------------------------------------------------------

def test_scope_nesting_innermost_wins():
    assert repro.current_emulation() is None
    with repro.emulation("ozaki1-p4") as outer:
        assert repro.resolve_config() is outer
        with repro.emulation("ozaki2-m6") as inner:
            assert repro.resolve_config() is inner
        with repro.emulation("native"):
            assert repro.resolve_config().scheme == "native"
        assert repro.resolve_config() is outer
    assert repro.current_emulation() is None
    assert repro.resolve_config().scheme == "native"


def test_scope_pops_on_exception():
    with pytest.raises(RuntimeError):
        with repro.emulation("ozaki1-p4"):
            raise RuntimeError("boom")
    assert repro.current_emulation() is None


def test_resolver_precedence(monkeypatch):
    """explicit arg > innermost scope > env > (call-site) default."""
    monkeypatch.setenv(repro.EMULATION_ENV_VAR, "ozaki2-m8")
    assert repro.resolve_config().scheme == "ozaki2"      # env
    with repro.emulation("ozaki1-p3"):
        assert repro.resolve_config().p == 3              # scope beats env
        assert repro.resolve_config("ozaki1-p5").p == 5   # arg beats scope
    monkeypatch.delenv(repro.EMULATION_ENV_VAR)
    assert repro.resolve_config().scheme == "native"      # platform default
    assert repro.resolve_config(default="ozaki1-p2").p == 2


def test_scopes_are_thread_local():
    seen = {}

    def worker():
        seen["ambient"] = repro.current_emulation()
        with repro.emulation("ozaki2-m6"):
            seen["scoped"] = repro.resolve_config().scheme

    with repro.emulation("ozaki1-p4"):
        t = threading.Thread(target=worker)
        t.start()
        t.join()
        # the worker never saw this thread's scope...
        assert seen["ambient"] is None
        assert seen["scoped"] == "ozaki2"
        # ...and its scope never leaked back
        assert repro.resolve_config().scheme == "ozaki1"


def test_gemm_policy_defers_to_ambient():
    from repro.models.common import GemmPolicy
    pol = GemmPolicy()
    assert pol.for_site("ffn").scheme == "native"
    with repro.emulation("ozaki1-p4"):
        assert pol.for_site("ffn").scheme == "ozaki1"
        # an explicit default still wins over the scope
        pinned = GemmPolicy(default=repro.precision("ozaki2-m6"))
        assert pinned.for_site("ffn").scheme == "ozaki2"


def test_resolve_policy_materializes_ambient():
    from repro.models.common import GemmPolicy
    with repro.emulation("ozaki1-p3"):
        resolved = dispatch.resolve_policy(GemmPolicy(), mesh=None)
    assert resolved.default is not None
    assert resolved.default.scheme == "ozaki1" and resolved.default.p == 3
    # '+xla' specs short-circuit the clamps but must still materialize:
    # the step functions trace lazily, possibly after the scope exits
    with repro.emulation("ozaki1-p3+xla+cached"):
        resolved = dispatch.resolve_policy(GemmPolicy(), mesh=None)
    assert resolved.default is not None and resolved.default.cache_weights
    assert resolved.default.p == 3
    # native ambient: pass-through untouched (identity preserved)
    pol = GemmPolicy()
    assert dispatch.resolve_policy(pol, mesh=None) is pol


def test_native_policy_pins_native_inside_scope(make_matrix):
    """NATIVE_POLICY is the oracle policy: it must stay exact fp32 even
    inside an ambient emulation scope (unlike the deferring GemmPolicy())."""
    from repro.models.common import NATIVE_POLICY, dense
    x = jnp.asarray(make_matrix((4, 32)))
    w = jnp.asarray(make_matrix((32, 16)))
    with repro.emulation("ozaki1-p2"):
        assert NATIVE_POLICY.for_site("ffn").scheme == "native"
        out = dense(x, w, NATIVE_POLICY, "ffn")
    np.testing.assert_array_equal(np.asarray(out),
                                  np.asarray(jnp.einsum("ij,jk->ik", x, w)))


def test_ops_wrappers_survive_mismatched_ambient(make_matrix, monkeypatch):
    """An ambient config of another scheme is not for a scheme-pinned
    wrapper: it falls back to its own default instead of erroring."""
    from repro.kernels import ops
    a = jnp.asarray(make_matrix((128, 128)))
    b = jnp.asarray(make_matrix((128, 128)))
    monkeypatch.setenv(repro.EMULATION_ENV_VAR, "native")
    out_env = np.asarray(ops.fused_scheme1_matmul(a, b))
    monkeypatch.delenv(repro.EMULATION_ENV_VAR)
    expected = np.asarray(ops.fused_scheme1_matmul(
        a, b, EmulationConfig(scheme="ozaki1", p=4)))
    np.testing.assert_array_equal(out_env, expected)
    with repro.emulation("ozaki2-m8"):
        out_scope = np.asarray(ops.fused_scheme1_matmul(a, b))
    np.testing.assert_array_equal(out_scope, expected)
    # a *matching* ambient scope is consumed
    with repro.emulation("ozaki1-p3"):
        out_p3 = np.asarray(ops.fused_scheme1_matmul(a, b))
    np.testing.assert_array_equal(
        out_p3, np.asarray(ops.fused_scheme1_matmul(
            a, b, EmulationConfig(scheme="ozaki1", p=3))))
    # an explicit wrong-scheme cfg is still a caller error
    with pytest.raises(ValueError, match="ozaki1-only"):
        ops.fused_scheme1_matmul(a, b, EmulationConfig(scheme="ozaki2", p=8))


def test_prepared_rhs_refused_under_native_everywhere(make_matrix):
    cfg = repro.precision("ozaki1-p4")
    prep = prepared.prepare_rhs(jnp.asarray(make_matrix((32, 16))), cfg)
    a = jnp.asarray(make_matrix((4, 32)))
    with pytest.raises(ValueError, match="native"):
        dispatch.emulated_matmul(a, prep, cfg="native")
    with repro.emulation("native"):
        with pytest.raises(ValueError, match="native"):
            dispatch.emulated_matmul(a, prep)


def test_einsum_broadcasts_size1_dims(make_matrix):
    a = jnp.asarray(make_matrix((1, 4, 8)))
    b = jnp.asarray(make_matrix((3, 8, 5)))
    ref = np.asarray(jnp.einsum("bij,bjk->bik", a, b))
    out = np.asarray(repro.einsum("bij,bjk->bik", a, b,
                                  precision="ozaki1-p4"))
    assert out.shape == ref.shape == (3, 4, 5)
    assert np.abs(out - ref).max() / np.abs(ref).max() < 1e-5
    # size-1 contracted dim broadcasts too
    a1 = jnp.asarray(make_matrix((4, 1)))
    b1 = jnp.asarray(make_matrix((8, 5)))
    ref1 = np.asarray(jnp.einsum("ij,jk->ik", a1, b1))
    out1 = np.asarray(repro.einsum("ij,jk->ik", a1, b1,
                                   precision="ozaki1-p4"))
    assert np.abs(out1 - ref1).max() / np.abs(ref1).max() < 1e-5


def test_prepared_dot_general_validates_dims(make_matrix):
    cfg = repro.precision("ozaki1-p4")
    prep = prepared.prepare_rhs(jnp.asarray(make_matrix((32, 16))), cfg)
    x = jnp.asarray(make_matrix((2, 3, 32)))
    with pytest.raises(ValueError, match="out of range"):
        repro.dot_general(x, prep, (((5,), (0,)), ((), ())), precision=cfg)


def test_dispatch_default_consults_scope(make_matrix):
    a = jnp.asarray(make_matrix((32, 32)))
    b = jnp.asarray(make_matrix((32, 32)))
    with repro.emulation("native"):
        out = dispatch.emulated_matmul(a, b)
        assert jnp.array_equal(out, a @ b)


def test_dense_under_ambient_scope(make_matrix):
    from repro.models.common import GemmPolicy, dense
    x = jnp.asarray(make_matrix((4, 32)))
    w = jnp.asarray(make_matrix((32, 16)))
    ref = np.asarray(x, np.float64) @ np.asarray(w, np.float64)
    with repro.emulation("ozaki1-p4+xla"):
        out = np.asarray(dense(x, w, GemmPolicy(), "ffn"))
    rel = np.abs(out - ref).max() / np.abs(ref).max()
    assert -np.log2(rel) > 18
    native = np.asarray(dense(x, w, GemmPolicy(), "ffn"))
    assert np.allclose(native, np.asarray(x @ w))


# ---------------------------------------------------------------------------
# Pillar 3: einsum / dot_general vs the jnp.einsum oracle.
# ---------------------------------------------------------------------------

# (subscripts, lhs shape, rhs shape) — the contraction-pattern zoo.
EINSUM_CASES = [
    ("ij,jk->ik", (24, 48), (48, 16)),          # plain 2-D
    ("bij,bjk->bik", (3, 16, 32), (3, 32, 8)),  # shared batch axis
    ("...k,kn->...n", (2, 3, 32), (32, 16)),    # model-zoo projection
    ("bqhd,bkhd->bhqk", (2, 5, 3, 16), (2, 7, 3, 16)),   # attention scores
    ("bhqk,bkhd->bqhd", (2, 3, 5, 7), (2, 7, 3, 16)),    # attention values
    ("abij,abjk->abik", (2, 2, 8, 16), (2, 2, 16, 4)),   # two batch axes
    ("ijk,kjl->il", (6, 3, 16), (16, 3, 5)),    # two contraction axes
    ("ij,jk", (16, 24), (24, 8)),               # implicit output
    ("ij,jk->k", (16, 24), (24, 8)),            # summed-out lhs free axis
    ("ij,kj->ik", (12, 32), (8, 32)),           # transposed rhs
    ("i,ij->j", (24,), (24, 8)),                # vector-matrix
    ("i,j->ij", (9, ), (11,)),                  # outer product (K=1)
]


@pytest.mark.parametrize("sub,sa,sb", EINSUM_CASES,
                         ids=[c[0] for c in EINSUM_CASES])
@pytest.mark.parametrize("spec", ["ozaki1-p4", "ozaki2-m8"])
def test_einsum_matches_oracle(make_matrix, sub, sa, sb, spec):
    a = jnp.asarray(make_matrix(sa))
    b = jnp.asarray(make_matrix(sb))
    ref = np.einsum(sub, np.asarray(a, np.float64), np.asarray(b, np.float64))
    out = np.asarray(repro.einsum(sub, a, b, precision=spec))
    assert out.shape == ref.shape
    rel = np.abs(out - ref).max() / (np.abs(ref).max() + 1e-30)
    assert rel < 1e-5, (sub, spec, rel)


def test_einsum_complex_both_schemes(make_matrix):
    a = jnp.asarray(make_matrix((16, 32))) \
        + 1j * jnp.asarray(make_matrix((16, 32)))
    b = jnp.asarray(make_matrix((32, 8))) \
        + 1j * jnp.asarray(make_matrix((32, 8)))
    ref = np.asarray(jnp.einsum("ij,jk->ik", a, b))
    for spec in ("ozaki1-p4", "ozaki2-m10"):   # 4M and 3M formulations
        out = np.asarray(repro.einsum("ij,jk->ik", a, b, precision=spec))
        rel = np.abs(out - ref).max() / np.abs(ref).max()
        assert rel < 1e-5, (spec, rel)


def test_einsum_native_matches_jnp(make_matrix):
    a = jnp.asarray(make_matrix((8, 16)))
    b = jnp.asarray(make_matrix((16, 4)))
    out = repro.einsum("ij,jk->ik", a, b, precision="native")
    np.testing.assert_allclose(np.asarray(out),
                               np.asarray(jnp.einsum("ij,jk->ik", a, b)),
                               rtol=1e-6)


def test_einsum_bit_identical_to_dispatcher_where_fused(make_matrix):
    """On an aligned 2-D problem the front door lowers through exactly the
    dispatcher's fused path — bit-identical, not merely close."""
    a = jnp.asarray(make_matrix((128, 128)))
    b = jnp.asarray(make_matrix((128, 128)))
    cfg = repro.precision("ozaki1-p4")
    via_einsum = np.asarray(repro.einsum("ij,jk->ik", a, b, precision=cfg))
    via_dispatch = np.asarray(dispatch.emulated_matmul(a, b, cfg=cfg))
    np.testing.assert_array_equal(via_einsum, via_dispatch)


def test_einsum_under_ambient_scope(make_matrix):
    a = jnp.asarray(make_matrix((16, 32)))
    b = jnp.asarray(make_matrix((32, 8)))
    with repro.emulation("ozaki1-p4"):
        scoped = np.asarray(repro.einsum("ij,jk->ik", a, b))
    explicit = np.asarray(repro.einsum("ij,jk->ik", a, b,
                                       precision="ozaki1-p4"))
    np.testing.assert_array_equal(scoped, explicit)
    # no scope, no spec -> native
    native = repro.einsum("ij,jk->ik", a, b)
    np.testing.assert_allclose(np.asarray(native), np.asarray(a @ b),
                               rtol=1e-6)


def test_einsum_gradients_match_native(make_matrix):
    a = jnp.asarray(make_matrix((2, 8, 16)))
    b = jnp.asarray(make_matrix((2, 16, 4)))

    def f_emu(a, b):
        return jnp.sum(jnp.sin(repro.einsum("bij,bjk->bik", a, b,
                                            precision="ozaki1-p4")))

    def f_nat(a, b):
        return jnp.sum(jnp.sin(jnp.einsum("bij,bjk->bik", a, b)))

    ga_e, gb_e = jax.grad(f_emu, argnums=(0, 1))(a, b)
    ga_n, gb_n = jax.grad(f_nat, argnums=(0, 1))(a, b)
    for ge, gn in ((ga_e, ga_n), (gb_e, gb_n)):
        np.testing.assert_allclose(
            np.asarray(ge), np.asarray(gn), rtol=1e-2,
            atol=1e-2 * float(jnp.abs(gn).max() + 1e-9))


def test_dot_general_matches_lax(make_matrix):
    a = jnp.asarray(make_matrix((3, 8, 16)))
    b = jnp.asarray(make_matrix((3, 16, 4)))
    dnums = (((2,), (1,)), ((0,), (0,)))
    ref = np.asarray(jax.lax.dot_general(a, b, dnums))
    out = np.asarray(repro.dot_general(a, b, dnums, precision="ozaki1-p4"))
    rel = np.abs(out - ref).max() / np.abs(ref).max()
    assert rel < 1e-5
    # negative axis indices normalize
    out2 = np.asarray(repro.dot_general(a, b, (((-1,), (-2,)), ((0,), (0,))),
                                        precision="ozaki1-p4"))
    np.testing.assert_array_equal(out, out2)


def test_dot_general_out_dtype_and_validation(make_matrix):
    a = jnp.asarray(make_matrix((8, 16)))
    b = jnp.asarray(make_matrix((16, 4)))
    out = repro.dot_general(a, b, (((1,), (0,)), ((), ())),
                            precision="ozaki1-p4", out_dtype=jnp.bfloat16)
    assert out.dtype == jnp.bfloat16
    with pytest.raises(ValueError, match="contracting dim"):
        repro.dot_general(a, jnp.asarray(make_matrix((8, 4))),
                          (((1,), (0,)), ((), ())), precision="ozaki1-p4")
    with pytest.raises(ValueError, match="batch dim count"):
        repro.dot_general(a, b, (((1,), (0,)), ((0,), ())),
                          precision="ozaki1-p4")


def test_einsum_prepared_rhs(make_matrix):
    cfg = repro.precision("ozaki1-p4")
    w = jnp.asarray(make_matrix((32, 16)))
    prep = prepared.prepare_rhs(w, cfg)
    x = jnp.asarray(make_matrix((2, 3, 32)))
    ref = np.asarray(jnp.einsum("...k,kn->...n", x, w))
    out = np.asarray(repro.einsum("...k,kn->...n", x, prep, precision=cfg))
    rel = np.abs(out - ref).max() / np.abs(ref).max()
    assert rel < 1e-5
    # dot_general spelling of the same contraction
    out2 = np.asarray(repro.dot_general(x, prep, (((2,), (0,)), ((), ())),
                                        precision=cfg))
    np.testing.assert_array_equal(out, out2)
    # the lhs contraction axis is free to sit anywhere...
    xt = jnp.asarray(make_matrix((32, 4)))
    out_t = np.asarray(repro.einsum("kb,kn->bn", xt, prep, precision=cfg))
    ref_t = np.asarray(jnp.einsum("kb,kn->bn", xt, w))
    assert np.abs(out_t - ref_t).max() / np.abs(ref_t).max() < 1e-5
    # ...but the rhs layout is fixed at prepare time: transposing or
    # batching the prepared operand is refused
    with pytest.raises(ValueError, match="prepared rhs"):
        repro.einsum("bn,kn->bk", jnp.asarray(make_matrix((4, 16))), prep,
                     precision=cfg)
    with pytest.raises(ValueError, match="prepared rhs"):
        repro.dot_general(x, prep, (((2,), (0,)), ((0,), (0,))),
                          precision=cfg)
    with pytest.raises(ValueError, match="native"):
        repro.einsum("bk,kn->bn", jnp.asarray(make_matrix((4, 32))), prep,
                     precision="native")


def test_einsum_prepared_residues_rhs(make_matrix):
    """A Scheme-II PreparedResidues rhs rides the same front door: the
    stored residue stack streams through the fused consumption path and
    mismatched schemes are refused."""
    from repro.core import scheme2
    cfg = repro.precision("ozaki2-m6")
    w = jnp.asarray(make_matrix((32, 16)))
    prep = prepared.prepare_rhs(w, cfg)
    assert isinstance(prep, prepared.PreparedResidues)
    x = jnp.asarray(make_matrix((2, 3, 32)))
    out = np.asarray(repro.einsum("...k,kn->...n", x, prep, precision=cfg))
    oracle = np.asarray(scheme2.matmul(x.reshape(-1, 32), w, cfg,
                                       jnp.float32)).reshape(2, 3, 16)
    np.testing.assert_array_equal(out, oracle)
    with pytest.raises(ValueError, match="Scheme-II"):
        repro.einsum("...k,kn->...n", x, prep, precision="ozaki1-p4")


@pytest.mark.parametrize("sub,sa,sb", [
    ("ij,jk,kl->il", (8, 8), (8, 8)),         # three operands
    ("ii,ij->j", (8, 8), (8, 8)),             # in-operand repeat (diagonal)
    ("ij,jk->ikz", (8, 8), (8, 8)),           # output label from nowhere
    ("...ij,...jk->ik", (2, 8, 8), (2, 8, 8)),  # output drops ellipsis dims
    ("ijk,jk->i", (2, 8), (8, 8)),            # subscript/rank mismatch
])
def test_einsum_unsupported_patterns_raise(make_matrix, sub, sa, sb):
    a = jnp.asarray(make_matrix(sa))
    b = jnp.asarray(make_matrix(sb))
    with pytest.raises(ValueError):
        repro.einsum(sub, a, b, precision="ozaki1-p4")


# ---------------------------------------------------------------------------
# Deprecated shims: old entry points warn but keep working.
# ---------------------------------------------------------------------------

def test_deprecated_scheme_precision_kwargs(make_matrix):
    a = jnp.asarray(make_matrix((32, 32)))
    b = jnp.asarray(make_matrix((32, 32)))
    with pytest.warns(DeprecationWarning, match="repro.precision"):
        out = dispatch.emulated_matmul(a, b, scheme="ozaki1", precision=3)
    expected = dispatch.emulated_matmul(
        a, b, cfg=EmulationConfig(scheme="ozaki1", p=3))
    np.testing.assert_array_equal(np.asarray(out), np.asarray(expected))
    with pytest.raises(TypeError, match="not both"):
        dispatch.emulated_matmul(a, b, cfg="ozaki1-p3", scheme="ozaki1")


def test_deprecated_maybe_emulated_matmul(make_matrix):
    a = jnp.asarray(make_matrix((128, 128)))
    cfg = EmulationConfig(scheme="ozaki1", p=4)
    with pytest.warns(DeprecationWarning, match="auto_fused_matmul"):
        out = dispatch.maybe_emulated_matmul(a, a, cfg)
    expected = dispatch.auto_fused_matmul(a, a, cfg)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(expected))


def test_deprecated_parse_gemm_spec():
    from repro.models.common import parse_gemm_spec
    with pytest.warns(DeprecationWarning, match="repro.precision"):
        cfg = parse_gemm_spec("ozaki1-p3-cached")
    assert cfg == repro.precision("ozaki1-p3+xla+cached")


def test_deprecated_ops_maybe_fused(make_matrix):
    from repro.kernels import ops
    a = jnp.asarray(make_matrix((128, 128)))
    with pytest.warns(DeprecationWarning, match="auto_fused_matmul"):
        ops.maybe_fused_matmul(a, a, EmulationConfig(scheme="ozaki1", p=4))


# ---------------------------------------------------------------------------
# Satellite: improved shape errors.
# ---------------------------------------------------------------------------

def test_2d_errors_point_at_front_door(make_matrix):
    a = jnp.asarray(make_matrix((2, 8, 16)))
    b = jnp.asarray(make_matrix((16, 4)))
    with pytest.raises(ValueError, match=r"repro\.dot_general"):
        dispatch.emulated_matmul(a, b, cfg="ozaki1-p4")
    prep = prepared.prepare_rhs(b, repro.precision("ozaki1-p4"))
    with pytest.raises(ValueError, match=r"repro\.dot_general"):
        dispatch.emulated_matmul(a, prep, cfg="ozaki1-p4")


def test_batched_mismatch_names_shapes(make_matrix):
    a = jnp.asarray(make_matrix((2, 8, 16)))
    b = jnp.asarray(make_matrix((3, 16, 4)))
    with pytest.raises(ValueError) as ei:
        dispatch.emulated_matmul_batched(a, b, cfg="ozaki1-p4")
    msg = str(ei.value)
    assert "(2, 8, 16)" in msg and "(3, 16, 4)" in msg
    assert "repro.dot_general" in msg


# ---------------------------------------------------------------------------
# Public-API snapshot: surface drift fails loudly.
# ---------------------------------------------------------------------------

EXPECTED_ALL = [
    "EMULATION_ENV_VAR",
    "EmulationAccuracyError",
    "EmulationConfig",
    "GemmPolicy",
    "NATIVE",
    "PreparedOperand",
    "current_emulation",
    "dot_general",
    "einsum",
    "emulated_dot",
    "emulated_matmul",
    "emulated_matmul_batched",
    "emulation",
    "guard",
    "plan_precision",
    "precision",
    "prepare_rhs",
    "resolve_config",
    "telemetry",
    "verify_gemm",
]

# (name, kind, has_default) per parameter — annotation-rendering-agnostic.
EXPECTED_SIGNATURES = {
    "precision": [("spec", "POSITIONAL_ONLY", False),
                  ("overrides", "VAR_KEYWORD", False)],
    "resolve_config": [("explicit", "POSITIONAL_OR_KEYWORD", True),
                       ("default", "KEYWORD_ONLY", True)],
    "dot_general": [("a", "POSITIONAL_OR_KEYWORD", False),
                    ("b", "POSITIONAL_OR_KEYWORD", False),
                    ("dimension_numbers", "POSITIONAL_OR_KEYWORD", False),
                    ("precision", "KEYWORD_ONLY", True),
                    ("out_dtype", "KEYWORD_ONLY", True),
                    ("backend", "KEYWORD_ONLY", True),
                    ("mesh", "KEYWORD_ONLY", True)],
    "einsum": [("subscripts", "POSITIONAL_OR_KEYWORD", False),
               ("a", "POSITIONAL_OR_KEYWORD", False),
               ("b", "POSITIONAL_OR_KEYWORD", False),
               ("precision", "KEYWORD_ONLY", True),
               ("out_dtype", "KEYWORD_ONLY", True),
               ("backend", "KEYWORD_ONLY", True),
               ("mesh", "KEYWORD_ONLY", True)],
    "emulated_matmul": [("a", "POSITIONAL_OR_KEYWORD", False),
                        ("b", "POSITIONAL_OR_KEYWORD", False),
                        ("cfg", "KEYWORD_ONLY", True),
                        ("out_dtype", "KEYWORD_ONLY", True),
                        ("backend", "KEYWORD_ONLY", True),
                        ("scheme", "KEYWORD_ONLY", True),
                        ("precision", "KEYWORD_ONLY", True),
                        ("mesh_shape", "KEYWORD_ONLY", True)],
}


def test_public_api_snapshot():
    assert sorted(repro.__all__) == EXPECTED_ALL
    for name in EXPECTED_ALL:
        assert getattr(repro, name) is not None, name
    for name, expected in EXPECTED_SIGNATURES.items():
        fn = getattr(repro, name)
        got = [(p.name, p.kind.name,
                p.default is not inspect.Parameter.empty)
               for p in inspect.signature(fn).parameters.values()]
        assert got == expected, (name, got)
