"""Sharding rules (all archs x both mesh shapes) and the HLO analyzer."""

import math

import jax
import jax.numpy as jnp
import pytest
from jax.sharding import PartitionSpec as P

from repro import configs
from repro.launch import mesh as mesh_lib
from repro.launch import steps as S
from repro.parallel import sharding as shd
from repro.utils import roofline


def abstract_mesh(multi):
    shape = (2, 16, 16) if multi else (16, 16)
    axes = ("pod", "data", "model") if multi else ("data", "model")
    # Constructor signature drifts across jax releases; the launch layer
    # owns the feature-probed shim.
    return mesh_lib.make_abstract_mesh(shape, axes)


@pytest.mark.parametrize("arch", configs.ARCH_IDS)
@pytest.mark.parametrize("multi", [False, True])
def test_param_specs_divisible_everywhere(arch, multi):
    """Every sharded dim must divide the mesh axes it is sharded over —
    the invariant that makes all 62 dry-run cells compile."""
    cfg = configs.get_config(arch)
    mesh = abstract_mesh(multi)
    params = S.abstract_params(cfg)
    specs = shd.param_pspecs(params, mesh, fsdp=cfg.train.fsdp)
    flat_p = jax.tree_util.tree_leaves(params)
    flat_s = jax.tree_util.tree_leaves(
        specs, is_leaf=lambda x: isinstance(x, P))
    assert len(flat_p) == len(flat_s)
    for leaf, spec in zip(flat_p, flat_s):
        for d, ax in enumerate(spec):
            if ax is None:
                continue
            axes = (ax,) if isinstance(ax, str) else ax
            size = math.prod(mesh.shape[a] for a in axes)
            assert leaf.shape[d] % size == 0, (arch, leaf.shape, spec)


@pytest.mark.parametrize("arch", ["granite-3-8b", "deepseek-v3-671b",
                                  "mamba2-780m", "recurrentgemma-2b",
                                  "qwen1.5-32b"])
def test_cache_specs_divisible(arch):
    cfg = configs.get_config(arch)
    mesh = abstract_mesh(False)
    cache = S.abstract_cache(cfg, 128, 32768)
    specs = shd.cache_pspecs(cache, mesh)
    flat_c = jax.tree_util.tree_leaves(cache)
    flat_s = jax.tree_util.tree_leaves(
        specs, is_leaf=lambda x: isinstance(x, P))
    for leaf, spec in zip(flat_c, flat_s):
        for d, ax in enumerate(spec):
            if ax is None:
                continue
            axes = (ax,) if isinstance(ax, str) else ax
            size = math.prod(mesh.shape[a] for a in axes)
            assert leaf.shape[d] % size == 0, (arch, leaf.shape, spec)


def test_tp_fallback_for_indivisible_heads():
    """qwen1.5's 40 heads don't divide 16: attention projections must fall
    back to contraction-dim sharding rather than fail."""
    cfg = configs.get_config("qwen1.5-32b")
    mesh = abstract_mesh(False)
    params = S.abstract_params(cfg)
    specs = shd.param_pspecs(params, mesh, fsdp=cfg.train.fsdp)
    # stacked wq spec: (group, d_model, out); out = 40*128 = 5120 divides
    # 16 so the column-parallel path applies here.
    wq_spec = specs["layers"]["b0"]["mixer"]["wq"]
    assert wq_spec[-1] == "model"
    # The real indivisibility: the 40-kv-head cache must fall back to
    # sequence-axis sharding (index 2 = seq under the stacked group axis).
    cache = S.abstract_cache(cfg, 128, 32768)
    cspecs = shd.cache_pspecs(cache, mesh)
    k_spec = cspecs["layers"]["b0"]["k"]
    assert k_spec[2] == "model" and k_spec[3] is None
    # int8-quantized cache is enabled for this arch
    assert cache["layers"]["b0"]["k"].dtype == jnp.int8
    assert "k_scale" in cache["layers"]["b0"]


def test_hlo_analyzer_on_toy_scan():
    """Trip-count scaling: a 16-iteration scanned matmul must report 16x
    the flops of its body."""
    def f(w, x):
        def body(c, wi):
            return jnp.tanh(c @ wi), None
        return jax.lax.scan(body, x, w)[0]

    w = jax.ShapeDtypeStruct((16, 64, 64), jnp.float32)
    x = jax.ShapeDtypeStruct((8, 64), jnp.float32)
    txt = jax.jit(f).lower(w, x).compile().as_text()
    res = roofline.analyze_hlo(txt)
    assert res["flops"] == 16 * 2 * 8 * 64 * 64
    assert res["mem_bytes"] > 0


def test_roofline_terms_classification():
    t = roofline.roofline_terms(197e12, 10e9, 1e9)   # 1s compute-bound
    assert t["bottleneck"] == "compute"
    assert abs(t["compute_s"] - 1.0) < 1e-9
    t2 = roofline.roofline_terms(1e12, 819e9, 1e9)   # 1s memory-bound
    assert t2["bottleneck"] == "memory"


def test_batch_specs_fall_back_for_tiny_batch():
    """long_500k has global_batch=1: inputs must replicate, not fail."""
    cfg = configs.get_config("mamba2-780m")
    from repro.configs.base import LONG_500K
    mesh = abstract_mesh(False)
    specs = S.batch_specs(cfg, LONG_500K, mesh)
    assert specs["tokens"][0] is None
