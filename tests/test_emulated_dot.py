"""emulated_dot as a framework feature: dispatch, VJP, batching."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.emulated import emulated_dot
from repro.core.precision import EmulationConfig, NATIVE, plan_precision


@pytest.mark.parametrize("scheme,p", [("ozaki1", 3), ("ozaki2", 8)])
def test_matches_native_forward(make_matrix, scheme, p):
    a = jnp.asarray(make_matrix((4, 32, 64)))   # batched leading dims
    b = jnp.asarray(make_matrix((64, 48)))
    cfg = EmulationConfig(scheme=scheme, p=p)
    out = emulated_dot(a, b, cfg)
    ref = jnp.einsum("bik,kn->bin", a, b)
    assert out.shape == (4, 32, 48)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-3, atol=2e-3 * float(
                                   jnp.abs(ref).max()))


@pytest.mark.parametrize("scheme,p", [("ozaki1", 4), ("ozaki2", 9)])
def test_vjp_matches_native(make_matrix, scheme, p):
    """Training through the int8 emulated path: gradients agree with the
    native float path to emulation precision."""
    a = jnp.asarray(make_matrix((16, 32)))
    b = jnp.asarray(make_matrix((32, 24)))
    cfg = EmulationConfig(scheme=scheme, p=p)

    def f_emu(a, b):
        return jnp.sum(jnp.sin(emulated_dot(a, b, cfg)))

    def f_nat(a, b):
        return jnp.sum(jnp.sin(a @ b))

    ga_e, gb_e = jax.grad(f_emu, argnums=(0, 1))(a, b)
    ga_n, gb_n = jax.grad(f_nat, argnums=(0, 1))(a, b)
    for ge, gn in ((ga_e, ga_n), (gb_e, gb_n)):
        np.testing.assert_allclose(np.asarray(ge), np.asarray(gn),
                                   rtol=1e-2, atol=1e-2 * float(
                                       jnp.abs(gn).max() + 1e-9))


def test_native_passthrough(make_matrix):
    a = jnp.asarray(make_matrix((8, 16)))
    b = jnp.asarray(make_matrix((16, 8)))
    np.testing.assert_allclose(np.asarray(emulated_dot(a, b, NATIVE)),
                               np.asarray(a @ b), rtol=1e-6)


def test_jit_and_grad_compose(make_matrix):
    cfg = EmulationConfig(scheme="ozaki1", p=3)
    a = jnp.asarray(make_matrix((16, 16)))
    b = jnp.asarray(make_matrix((16, 16)))
    f = jax.jit(lambda a, b: jnp.sum(emulated_dot(a, b, cfg) ** 2))
    g = jax.jit(jax.grad(f))
    assert np.isfinite(float(f(a, b)))
    assert np.isfinite(np.asarray(g(a, b))).all()


def test_precision_planner_crossover():
    """Paper Fig. 7: Scheme I below ~fp32, Scheme II above."""
    low = plan_precision(target_bits=20, k_dim=4096)
    high = plan_precision(target_bits=48, k_dim=4096)
    assert low.scheme == "ozaki1"
    assert high.scheme == "ozaki2"
    # and the planner's choices meet their targets
    assert low.bits(4096) >= 20
    assert high.bits(4096) >= 48
