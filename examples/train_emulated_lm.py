"""End-to-end driver: train a ~100M-parameter LM with every dense
projection running on the paper's emulated int8 GEMM path.

  PYTHONPATH=src python examples/train_emulated_lm.py --steps 300

(Use --small for a quick CPU demo; the 100M config at the default
300 steps takes a while on CPU, the point is that the full pipeline —
data, sharded step, emulated matmuls, checkpoints, resume — is exercised
by one command.)
"""

import argparse
import dataclasses

import jax

import repro
from repro.configs.base import ArchConfig, ModelConfig, ShapeSpec, TrainPolicy
from repro.data import make_batch_iterator
from repro.launch import steps as S
from repro.launch.mesh import make_host_mesh
from repro.models import model as M
from repro.models.common import GemmPolicy
from repro.optim import make_optimizer
from repro.runtime import Trainer

LM_100M = ArchConfig(
    model=ModelConfig(
        name="lm-100m", family="dense",
        n_layers=12, d_model=768, n_heads=12, n_kv_heads=12,
        d_ff=3072, vocab=32768, norm="rms", act="swiglu",
        tie_embeddings=True, q_chunk=256, kv_chunk=256),
    train=TrainPolicy(microbatches=1, learning_rate=3e-4),
)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--gemm", default="ozaki1-p3",
                    help="every dense projection runs through this")
    ap.add_argument("--small", action="store_true")
    ap.add_argument("--ckpt-dir", default="/tmp/repro_100m")
    args = ap.parse_args(argv)

    arch = LM_100M
    if args.small:
        arch = dataclasses.replace(arch, model=dataclasses.replace(
            arch.model, n_layers=4, d_model=256, n_heads=4, n_kv_heads=4,
            d_ff=1024, vocab=4096))
    shape = ShapeSpec("cli", args.seq, args.batch, "train")
    mesh = make_host_mesh()
    policy = GemmPolicy(default=repro.precision(args.gemm))
    opt_init, _ = make_optimizer(arch.train.optimizer)

    def init_state():
        params = M.init_params(jax.random.PRNGKey(0), arch.model)
        print(f"[100m] {M.param_count(params) / 1e6:.1f}M parameters, "
              f"gemm backend = {args.gemm}")
        return {"params": params, "opt": opt_init(params)}

    with mesh:
        trainer = Trainer(
            step_fn=S.make_train_step(arch, mesh, shape, policy,
                                      donate=False),
            init_state_fn=init_state,
            batch_iterator=make_batch_iterator(arch, shape),
            ckpt_dir=args.ckpt_dir,
            ckpt_every=50)
        log = trainer.run(args.steps)
        trainer.close()
    print(f"[100m] loss {log[0]['loss']:.4f} -> {log[-1]['loss']:.4f}")


if __name__ == "__main__":
    main()
