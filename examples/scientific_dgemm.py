"""Scientific-computing scenario: FP64-grade GEMM on int8 hardware.

TPUs have NO native FP64 matrix units at all — the precision-throughput
gap the paper worries about is strictly worse than on GPUs. This example
emulates double-precision GEMM from int8 products (Scheme II, p=15) and
compares its accuracy against a true float64 matmul on ill-conditioned
inputs.

  PYTHONPATH=src python examples/scientific_dgemm.py
"""

import numpy as np
import jax
import jax.numpy as jnp

from repro.core import scheme2
from repro.core.precision import EmulationConfig


def main():
    rng = np.random.default_rng(7)
    n = 512
    with jax.experimental.enable_x64():
        a = ((rng.random((n, n)) - 0.5)
             * np.exp(4.0 * rng.standard_normal((n, n))))
        b = ((rng.random((n, n)) - 0.5)
             * np.exp(4.0 * rng.standard_normal((n, n))))
        ref = a.astype(np.longdouble) @ b.astype(np.longdouble)

        f64 = np.asarray(jnp.asarray(a) @ jnp.asarray(b))
        for p in (9, 12, 15):
            cfg = EmulationConfig(scheme="ozaki2", p=p)
            emu = np.asarray(scheme2.matmul(jnp.asarray(a), jnp.asarray(b),
                                            cfg, jnp.float64))
            for name, c in (("native f64", f64), (f"Ozaki-II p={p}", emu)):
                rel = float(np.abs(c.astype(np.longdouble) - ref).max()
                            / np.abs(ref).max())
                print(f"{name:16s}: {-np.log2(rel):5.1f} effective bits "
                      f"({cfg.gemm_count() if 'Ozaki' in name else 1} GEMMs)")
            print()
    print("On TPU v5e the int8 path peaks at 394 Top/s vs no FP64 MXU at "
          "all;\n15 int8 GEMMs at ~50 effective bits is the only "
          "double-precision-class\nmatmul the hardware offers.")


if __name__ == "__main__":
    main()
