"""Quickstart: emulated high-precision GEMM from int8 building blocks.

  PYTHONPATH=src python examples/quickstart.py

Kernel-backend selection (TPU Mosaic / Mosaic-GPU-Triton / XLA
reference) is documented in docs/backends.md; set REPRO_BACKEND=gpu or
EmulationConfig(backend="gpu") to route through the GPU Scheme-I
lowering (interpret mode off-GPU — bit-identical results).
"""

import numpy as np
import jax.numpy as jnp

from repro.core import emulated_dot
from repro.core.precision import EmulationConfig, plan_precision

rng = np.random.default_rng(0)
n = 512
# ill-conditioned inputs (paper Eq. 19, phi=4)
a = ((rng.random((n, n)) - 0.5) * np.exp(4 * rng.standard_normal((n, n)))
     ).astype(np.float32)
b = ((rng.random((n, n)) - 0.5) * np.exp(4 * rng.standard_normal((n, n)))
     ).astype(np.float32)
ref = a.astype(np.float64) @ b.astype(np.float64)


def bits(c):
    return -np.log2(np.abs(np.asarray(c) - ref).max() / np.abs(ref).max())


print(f"native fp32 matmul:              {bits(a @ b):5.1f} bits")
for p in (2, 3, 4):
    cfg = EmulationConfig(scheme="ozaki1", p=p)   # mantissa slicing
    c = emulated_dot(jnp.asarray(a), jnp.asarray(b), cfg)
    print(f"Ozaki-I  p={p} ({cfg.gemm_count():2d} int8 GEMMs): "
          f"{bits(c):5.1f} bits")
for p in (8, 12):
    cfg = EmulationConfig(scheme="ozaki2", p=p)   # CRT modular
    c = emulated_dot(jnp.asarray(a), jnp.asarray(b), cfg)
    print(f"Ozaki-II p={p:2d} ({cfg.gemm_count():2d} int8 GEMMs): "
          f"{bits(c):5.1f} bits")

# The precision planner (paper Fig. 7 crossover, automated):
for target in (16, 22, 40):
    cfg = plan_precision(target_bits=target, k_dim=n)
    print(f"planner: {target} bits at K={n} -> {cfg.scheme} p={cfg.p}")

# Kernel backends (docs/backends.md): the same GEMM through the GPU
# Scheme-I lowering — bit-identical slicing, 16-lane tiles.
cfg = EmulationConfig(scheme="ozaki1", p=4, backend="gpu")
c = emulated_dot(jnp.asarray(a), jnp.asarray(b), cfg)
print(f"Ozaki-I  p=4 via backend='gpu':   {bits(c):5.1f} bits")
