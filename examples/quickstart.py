"""Quickstart: emulated high-precision GEMM through the unified API.

  PYTHONPATH=src python examples/quickstart.py

Everything below runs through the three pillars of the public surface
(docs/api.md): precision specs (`repro.precision`), ambient emulation
scopes (`with repro.emulation(...)`), and the emulated `repro.einsum` /
`repro.dot_general` front door. Kernel-backend selection is documented
in docs/backends.md; the `@gpu` spec suffix (or REPRO_BACKEND=gpu)
routes through the GPU Scheme-I lowering — interpret mode off-GPU,
bit-identical results.
"""

import numpy as np
import jax.numpy as jnp

import repro

rng = np.random.default_rng(0)
n = 512
# ill-conditioned inputs (paper Eq. 19, phi=4)
a = ((rng.random((n, n)) - 0.5) * np.exp(4 * rng.standard_normal((n, n)))
     ).astype(np.float32)
b = ((rng.random((n, n)) - 0.5) * np.exp(4 * rng.standard_normal((n, n)))
     ).astype(np.float32)
ref = a.astype(np.float64) @ b.astype(np.float64)
aj, bj = jnp.asarray(a), jnp.asarray(b)


def bits(c):
    return -np.log2(np.abs(np.asarray(c) - ref).max() / np.abs(ref).max())


# Precision specs are loggable one-liners: scheme + slice/modulus count,
# parsed by repro.precision (grammar in docs/api.md).
print(f"native fp32 matmul:              {bits(a @ b):5.1f} bits")
for spec in ("ozaki1-p2", "ozaki1-p3", "ozaki1-p4"):   # mantissa slicing
    cfg = repro.precision(spec)
    c = repro.einsum("ij,jk->ik", aj, bj, precision=spec)
    print(f"Ozaki-I  {spec} ({cfg.gemm_count():2d} int8 GEMMs): "
          f"{bits(c):5.1f} bits")
for spec in ("ozaki2-m8", "ozaki2-m12"):               # CRT modular
    cfg = repro.precision(spec)
    c = repro.einsum("ij,jk->ik", aj, bj, precision=spec)
    print(f"Ozaki-II {spec} ({cfg.gemm_count():2d} int8 GEMMs): "
          f"{bits(c):5.1f} bits")

# 'bits=N' specs route through the planner (paper Fig. 7 crossover,
# automated): name the precision you need, get the cheaper scheme.
for target in (16, 22, 40):
    cfg = repro.precision(f"bits={target}:k{n}")
    print(f"planner: bits={target}:k{n} -> {cfg.to_spec()}")

# Ambient scopes: emulate a whole block without threading configs —
# every emulation-aware call-site inside resolves to the scoped spec
# (explicit arg > innermost scope > REPRO_EMULATION env > native).
with repro.emulation("ozaki2-m8"):
    c = repro.einsum("ij,jk->ik", aj, bj)
print(f"ambient scope ozaki2-m8:          {bits(c):5.1f} bits")

# General contractions: einsum shapes beyond plain 2-D — batch dims,
# multi-axis contractions, attention-style patterns — lower onto the
# same fused kernels via transpose/reshape/vmap canonicalization.
q = jnp.asarray(rng.standard_normal((2, 64, 4, 32)).astype(np.float32))
k = jnp.asarray(rng.standard_normal((2, 64, 4, 32)).astype(np.float32))
scores = repro.einsum("bqhd,bkhd->bhqk", q, k, precision="ozaki1-p4")
ref_scores = np.einsum("bqhd,bkhd->bhqk", np.asarray(q, np.float64),
                       np.asarray(k, np.float64))
err = np.abs(np.asarray(scores) - ref_scores).max() / np.abs(ref_scores).max()
print(f"attention scores (bqhd,bkhd->bhqk): {-np.log2(err):5.1f} bits, "
      f"shape {scores.shape}")

# Kernel backends (docs/backends.md): the same GEMM through the GPU
# Scheme-I lowering — bit-identical slicing, 16-lane tiles.
c = repro.einsum("ij,jk->ik", aj, bj, precision="ozaki1-p4@gpu")
print(f"Ozaki-I  ozaki1-p4@gpu:           {bits(c):5.1f} bits")
