"""Batched serving example: prefill + decode against a shared KV cache,
optionally with the int8-quantized cache and an emulated-GEMM backend.

  PYTHONPATH=src python examples/serve_lm.py --requests 8 --gen 32
"""

import argparse
import dataclasses
import time

import numpy as np

import repro
from repro import configs
from repro.launch.mesh import make_host_mesh
from repro.launch.serve import ServeEngine
from repro.models.common import GemmPolicy


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="granite-3-8b")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=48)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--gemm", default=None,
                    help="precision spec (repro.precision grammar); "
                         "omitted, REPRO_EMULATION / the ambient scope "
                         "decides")
    ap.add_argument("--int8-cache", action="store_true")
    args = ap.parse_args(argv)

    arch = configs.get_smoke_config(args.arch)
    if args.int8_cache:
        arch = dataclasses.replace(arch, model=dataclasses.replace(
            arch.model, kv_cache_dtype="int8"))
    rng = np.random.default_rng(0)
    prompts = rng.integers(0, arch.model.vocab,
                           (args.requests, args.prompt_len)).astype(np.int32)
    mesh = make_host_mesh()
    with mesh:
        gemm = repro.precision(args.gemm) if args.gemm else None
        eng = ServeEngine(arch, mesh, args.prompt_len + args.gen,
                          GemmPolicy(default=gemm))
        t0 = time.time()
        toks = eng.generate(prompts, args.gen)
        dt = time.time() - t0
    print(f"[serve] {args.requests} req x {args.gen} tok in {dt:.2f}s "
          f"({args.requests * args.gen / dt:.1f} tok/s, "
          f"cache={'int8' if args.int8_cache else arch.model.dtype})")
    print("[serve] first request:", toks[0].tolist())


if __name__ == "__main__":
    main()
