"""Paper Fig. 7: precision–throughput–memory trade-off at one size.

Projects native baselines, Scheme I (p=1..8) and Scheme II (p=8..15) onto
(bits, effective Tflop/s, workspace bytes); the derived column carries the
workspace from the paper's Sec. V-F accounting, which must show Scheme II
above Scheme I at matched p.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import scheme1, scheme2, traffic
from repro.core.precision import EmulationConfig, plan_precision
from repro.core.traffic import GemmShape

from benchmarks.common import (bits_of_precision, conditioned, csv_row,
                               effective_tflops, time_fn)


def main(quick: bool = True):
    rng = np.random.default_rng(3)
    n = 512 if quick else 1024
    s = GemmShape(n, n, n)
    a = conditioned(rng, (n, n))
    b = conditioned(rng, (n, n))
    ref = a.astype(np.float64) @ b.astype(np.float64)
    aj, bj = jnp.asarray(a), jnp.asarray(b)
    rows = []

    for p in (1, 2, 4, 8):
        cfg = EmulationConfig(scheme="ozaki1", p=p)
        f = jax.jit(lambda x, y, cfg=cfg: scheme1.matmul(x, y, cfg,
                                                         jnp.float32))
        t = time_fn(f, aj, bj)
        bits = bits_of_precision(np.asarray(f(aj, bj)), ref)
        ws = traffic.scheme1_workspace_bytes(s, p)
        csv_row(f"fig7_emu1_p{p}", t * 1e6,
                f"bits={bits:.1f};tflops={effective_tflops(n, t):.3f};"
                f"workspace_mb={ws / 1e6:.1f}")
        rows.append(("emu1", p, bits, ws))

    for p in (8, 10, 12, 15):
        cfg = EmulationConfig(scheme="ozaki2", p=p)
        f = jax.jit(lambda x, y, cfg=cfg: scheme2.matmul(x, y, cfg,
                                                         jnp.float32))
        t = time_fn(f, aj, bj)
        bits = bits_of_precision(np.asarray(f(aj, bj)), ref)
        ws = traffic.scheme2_workspace_bytes(s, p)
        csv_row(f"fig7_emu2_p{p}", t * 1e6,
                f"bits={bits:.1f};tflops={effective_tflops(n, t):.3f};"
                f"workspace_mb={ws / 1e6:.1f}")
        rows.append(("emu2", p, bits, ws))

    # the planner = the paper's crossover, automated
    for target in (20, 45):
        cfg = plan_precision(target, n)
        csv_row(f"fig7_planner_{target}bits", 0.0,
                f"scheme={cfg.scheme};p={cfg.p}")
    return rows


if __name__ == "__main__":
    main(quick=False)
