"""Benchmark harness: one module per paper table/figure.

  PYTHONPATH=src python -m benchmarks.run [--full]

Prints ``name,us_per_call,derived`` CSV rows. --full sweeps the paper's
larger sizes (slow on CPU); default is the quick grid.
"""

from __future__ import annotations

import argparse
import sys
import time


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--only", default=None,
                    help="fig4|fig5|fig6|fig7|tab2")
    args = ap.parse_args()

    from benchmarks import (fig4_intensity, fig5_grid, fig6_scheme2,
                            fig7_tradeoff, tab2_counts)
    modules = {"fig4": fig4_intensity, "fig5": fig5_grid,
               "fig6": fig6_scheme2, "fig7": fig7_tradeoff,
               "tab2": tab2_counts}
    print("name,us_per_call,derived")
    for name, mod in modules.items():
        if args.only and name != args.only:
            continue
        t0 = time.time()
        mod.main(quick=not args.full)
        print(f"# {name} done in {time.time() - t0:.1f}s", file=sys.stderr)


if __name__ == "__main__":
    main()
