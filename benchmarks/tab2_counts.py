"""Paper Table II: scheme comparison — GEMM counts, scaling, precision."""

from __future__ import annotations

from repro.core import complex3m
from repro.core.precision import EmulationConfig, default_moduli, \
    scheme2_bits, safe_beta

from benchmarks.common import csv_row


def main(quick: bool = True):
    k = 4096
    beta = safe_beta(k)
    rows = []
    for p in (2, 4, 8, 15):
        c1 = EmulationConfig(scheme="ozaki1", p=p)
        c2 = EmulationConfig(scheme="ozaki2", p=p)
        csv_row(f"tab2_p{p}", 0.0,
                f"s1_gemms={c1.gemm_count()};s2_gemms={c2.gemm_count()};"
                f"s1_bits~{p * beta};s2_bits~"
                f"{scheme2_bits(default_moduli(p), k)};"
                f"s2_3m_gemms={complex3m.gemm_count(c2)}")
        rows.append((p, c1.gemm_count(), c2.gemm_count()))
    assert all(r[1] == r[0] * (r[0] + 1) // 2 for r in rows)
    assert all(r[2] == r[0] for r in rows)
    return rows


if __name__ == "__main__":
    main(quick=False)
