"""Paper Fig. 5: effective throughput x precision grid.

Rows sweep slice/modulus counts for Schemes I and II (real and complex),
against native f32/f64 matmul baselines; each cell reports effective
Tflop/s (2N^3 / t) and measured effective bits — the CPU analogue of the
paper's throughput(text)/precision(color) panels.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import complex3m, scheme1, scheme2
from repro.core.precision import EmulationConfig

from benchmarks.common import (bits_of_precision, conditioned, csv_row,
                               effective_tflops, time_fn)


def main(quick: bool = True):
    rng = np.random.default_rng(1)
    sizes = (256,) if quick else (256, 512, 1024)
    rows = []
    for n in sizes:
        a = conditioned(rng, (n, n))
        b = conditioned(rng, (n, n))
        ref = a.astype(np.float64) @ b.astype(np.float64)
        aj, bj = jnp.asarray(a), jnp.asarray(b)

        # native baselines
        nat32 = jax.jit(lambda x, y: x @ y)
        t = time_fn(nat32, aj, bj)
        out = np.asarray(nat32(aj, bj))
        csv_row("fig5_native_f32", t * 1e6,
                f"N={n};tflops={effective_tflops(n, t):.3f};"
                f"bits={bits_of_precision(out, ref):.1f}")

        for p in (1, 2, 3, 4, 6, 8):
            cfg = EmulationConfig(scheme="ozaki1", p=p)
            f = jax.jit(lambda x, y, cfg=cfg: scheme1.matmul(
                x, y, cfg, jnp.float32))
            t = time_fn(f, aj, bj)
            out = np.asarray(f(aj, bj))
            bits = bits_of_precision(out, ref)
            csv_row(f"fig5_emu1_p{p}", t * 1e6,
                    f"N={n};tflops={effective_tflops(n, t):.3f};"
                    f"bits={bits:.1f}")
            rows.append(("emu1", n, p, bits))

        for p in (8, 9, 11, 13, 15):
            cfg = EmulationConfig(scheme="ozaki2", p=p)
            f = jax.jit(lambda x, y, cfg=cfg: scheme2.matmul(
                x, y, cfg, jnp.float32))
            t = time_fn(f, aj, bj)
            out = np.asarray(f(aj, bj))
            bits = bits_of_precision(out, ref)
            csv_row(f"fig5_emu2_p{p}", t * 1e6,
                    f"N={n};tflops={effective_tflops(n, t):.3f};"
                    f"bits={bits:.1f}")
            rows.append(("emu2", n, p, bits))

        # complex panel
        ac = (conditioned(rng, (n, n)) + 1j * conditioned(rng, (n, n))
              ).astype(np.complex64)
        bc = (conditioned(rng, (n, n)) + 1j * conditioned(rng, (n, n))
              ).astype(np.complex64)
        refc = ac.astype(np.complex128) @ bc.astype(np.complex128)
        acj, bcj = jnp.asarray(ac), jnp.asarray(bc)
        natc = jax.jit(lambda x, y: x @ y)
        t = time_fn(natc, acj, bcj)
        csv_row("fig5_native_cgemm", t * 1e6,
                f"N={n};bits="
                f"{bits_of_precision(np.abs(np.asarray(natc(acj, bcj))), np.abs(refc)):.1f}")
        for p in (4, 8):
            cfg = EmulationConfig(scheme="ozaki1", p=p)
            f4m = jax.jit(lambda x, y, cfg=cfg: scheme1.matmul_complex_4m(
                x, y, cfg))
            t = time_fn(f4m, acj, bcj)
            out = np.asarray(f4m(acj, bcj))
            csv_row(f"fig5_emu1_cgemm4m_p{p}", t * 1e6,
                    f"N={n};bits="
                    f"{bits_of_precision(np.abs(out), np.abs(refc)):.1f}")
        for p in (8, 12, 15):
            cfg = EmulationConfig(scheme="ozaki2", p=p)
            f3m = jax.jit(lambda x, y, cfg=cfg: complex3m.matmul(x, y, cfg))
            t = time_fn(f3m, acj, bcj)
            out = np.asarray(f3m(acj, bcj))
            csv_row(f"fig5_emu2_zgemm3m_p{p}", t * 1e6,
                    f"N={n};bits="
                    f"{bits_of_precision(np.abs(out), np.abs(refc)):.1f}")
    return rows


if __name__ == "__main__":
    main(quick=False)
