"""Paper Fig. 4: throughput vs arithmetic intensity — fused vs naive.

The naive path issues one dispatch per slice-pair GEMM and materializes
every INT32 accumulator (the paper's Eq. 9 traffic); the fused path is a
single compiled program (Eq. 10). We report the measured wall-time ratio
next to the analytical intensity gain (p+1)/2.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import scheme1
from repro.core.precision import EmulationConfig
from repro.core import traffic
from repro.core.traffic import GemmShape
from repro.utils import roofline

from benchmarks.common import conditioned, csv_row, time_fn


def naive_scheme1(a, b, p, beta):
    """One jit dispatch per slice-pair product + a separate reconstruction
    dispatch, int32 accumulators round-tripping through host-visible
    buffers — the kernel-launch structure of a naive implementation."""
    a_sl, mu = scheme1.split(a, p, beta, axis=1)
    b_sl, nu = scheme1.split(b, p, beta, axis=0)
    dot = jax.jit(lambda x, y: jax.lax.dot_general(
        x, y, (((1,), (0,)), ((), ())), preferred_element_type=jnp.int32))
    accs = []
    for s in range(p):
        acc = dot(a_sl[0], b_sl[s])
        jax.block_until_ready(acc)            # materialize (Eq. 9 traffic)
        for i in range(1, s + 1):
            nxt = dot(a_sl[i], b_sl[s - i])
            jax.block_until_ready(nxt)
            acc = acc + nxt
        accs.append(acc)
    rec = jax.jit(lambda accs, mu, nu: scheme1.shift_reduce(
        jnp.stack(accs), beta, mu, nu, jnp.float32))
    return rec(accs, mu, nu)


def main(quick: bool = True):
    rng = np.random.default_rng(0)
    sizes = (512,) if quick else (512, 1024, 2048)
    rows = []
    for n in sizes:
        a = jnp.asarray(conditioned(rng, (n, n)))
        b = jnp.asarray(conditioned(rng, (n, n)))
        for p in (2, 4, 8):
            cfg = EmulationConfig(scheme="ozaki1", p=p)
            beta = cfg.resolved_beta(n)
            fused = jax.jit(lambda a, b, cfg=cfg: scheme1.matmul(
                a, b, cfg, jnp.float32))
            t_fused = time_fn(fused, a, b)
            t_naive = time_fn(lambda a, b: naive_scheme1(a, b, p, beta),
                              a, b, iters=3, warmup=1)
            s = GemmShape(n, n, n)
            ai_fused = traffic.arithmetic_intensity(
                traffic.scheme1_flops(s, p), traffic.scheme1_fused_bytes(s, p))
            ai_naive = traffic.arithmetic_intensity(
                traffic.scheme1_flops(s, p), traffic.scheme1_naive_bytes(s, p))
            # Projected Top/s against the per-backend peak tables: the
            # paper reports fraction-of-INT8-peak on Hopper/Blackwell.
            proj = roofline.projected_throughput(n, n, n, p, backend="gpu")
            hw = proj["hardware"]
            tpu_hw = roofline.projected_throughput(
                n, n, n, p, backend="tpu")["hardware"]["v5e"]
            derived = (f"N={n};p={p};speedup={t_naive / t_fused:.2f}x;"
                       f"AI_fused={ai_fused:.0f};AI_naive={ai_naive:.0f};"
                       f"AI_gain={ai_fused / ai_naive:.2f};"
                       f"proj_h100_tops={hw['h100']['projected_tops']:.0f};"
                       f"proj_b200_tops={hw['b200']['projected_tops']:.0f};"
                       f"proj_v5e_tops={tpu_hw['projected_tops']:.0f}")
            csv_row("fig4_scheme1", t_fused * 1e6, derived)
            rows.append((n, p, t_naive / t_fused))
    return rows


if __name__ == "__main__":
    main(quick=False)
